"""Decoder-only transformer LM covering the dense, MoE, and VLM (interleaved
cross-attention) families. Layers are stacked along a leading axis and executed
with ``lax.scan`` so 40–64-layer configs lower/compile quickly at 512 devices.

Public API (used by models.registry):
    init(cfg, key)                      -> params
    param_logical(cfg)                  -> pytree of logical axis tuples
    forward(params, cfg, tokens, ...)   -> logits, aux      (train / prefill)
    init_cache(cfg, batch, s_max, ...)  -> cache
    decode_step(params, cfg, token, cache, ...) -> logits, cache
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, Family
from repro.models import layers as L
from repro.sharding.specs import shard


# ------------------------------------------------------------------ helpers
def _attn_dims(cfg: ArchConfig, causal: bool = True) -> L.AttnDims:
    return L.AttnDims(
        d_model=cfg.d_model, num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.head_dim, qkv_bias=cfg.qkv_bias, window=cfg.window,
        rope_theta=cfg.rope_theta, causal=causal)


def _mla_dims(cfg: ArchConfig) -> L.MLADims:
    return L.MLADims(
        d_model=cfg.d_model, num_heads=cfg.num_heads,
        kv_lora_rank=cfg.kv_lora_rank, qk_rope_head_dim=cfg.qk_rope_head_dim,
        head_dim=cfg.head_dim, rope_theta=cfg.rope_theta)


def _is_mla(cfg: ArchConfig) -> bool:
    """Static (trace-time) MLA gate. MLA replaces per-head K/V with a latent
    cache; it is defined for plain causal decoder stacks only (no sliding
    window, no interleaved cross-attention)."""
    if not cfg.kv_lora_rank:
        return False
    if cfg.window or cfg.cross_attn_every:
        raise ValueError("MLA (kv_lora_rank > 0) supports only full-causal "
                         "decoder stacks (no window / cross-attn)")
    return True


def _cross_dims(cfg: ArchConfig) -> L.AttnDims:
    d = _attn_dims(cfg, causal=False)
    return L.AttnDims(**{**d.__dict__, "causal": False, "window": 0, "rope_theta": 0.0})


def _moe_dims(cfg: ArchConfig) -> L.MoEDims:
    return L.MoEDims(d_model=cfg.d_model, d_ff=cfg.d_ff,
                     num_experts=cfg.moe.num_experts, top_k=cfg.moe.top_k,
                     capacity_factor=cfg.moe.capacity_factor)


def _remat_policy(remat):
    """remat=True/'nothing' -> save only layer inputs; 'save_outs' -> also
    keep the named post-collective attention/MLP outputs (skips their
    recompute — and the recomputed collectives — in backward)."""
    if remat == "save_outs":
        return jax.checkpoint_policies.save_only_these_names(
            "attn_out", "mlp_out")
    return jax.checkpoint_policies.nothing_saveable


def _gated(cfg: ArchConfig) -> bool:
    return cfg.norm == "rmsnorm" or cfg.family in (Family.DENSE, Family.MOE, Family.VLM)


# ------------------------------------------------------------------ init
def _layer_init(key, cfg: ArchConfig):
    ks = jax.random.split(key, 4)
    p = {
        "ln1": L.norm_init(cfg.d_model, cfg.norm),
        "attn": (L.mla_init(ks[0], _mla_dims(cfg)) if _is_mla(cfg)
                 else L.attn_init(ks[0], _attn_dims(cfg))),
        "ln2": L.norm_init(cfg.d_model, cfg.norm),
    }
    if cfg.moe:
        p["moe"] = L.moe_init(ks[1], _moe_dims(cfg))
    else:
        p["mlp"] = L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, gated=_gated(cfg),
                              bias=cfg.mlp_bias)
    return p


def _layer_logical(cfg: ArchConfig):
    p = {
        "ln1": L.norm_logical(cfg.norm),
        "attn": (L.mla_logical(_mla_dims(cfg)) if _is_mla(cfg)
                 else L.attn_logical(_attn_dims(cfg))),
        "ln2": L.norm_logical(cfg.norm),
    }
    if cfg.moe:
        p["moe"] = L.moe_logical()
    else:
        p["mlp"] = L.mlp_logical(gated=_gated(cfg), bias=cfg.mlp_bias)
    return p


def _cross_init(key, cfg: ArchConfig):
    return {"ln": L.norm_init(cfg.d_model, cfg.norm),
            "attn": L.attn_init(key, _cross_dims(cfg)),
            "gate": jnp.zeros((), jnp.float32)}


def _cross_logical(cfg: ArchConfig):
    return {"ln": L.norm_logical(cfg.norm),
            "attn": L.attn_logical(_cross_dims(cfg)),
            "gate": ()}


def _stack(key, n, init_fn):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def init(cfg: ArchConfig, key) -> dict:
    k_emb, k_layers, k_cross, k_head = jax.random.split(key, 4)
    params = {
        "embed": L.embed_init(k_emb, cfg.padded_vocab, cfg.d_model),
        "final_norm": L.norm_init(cfg.d_model, cfg.norm),
    }
    if cfg.cross_attn_every:
        per = cfg.cross_attn_every
        n_super = cfg.num_layers // per
        def super_init(k):
            k1, k2 = jax.random.split(k)
            return {"blocks": _stack(k1, per, lambda kk: _layer_init(kk, cfg)),
                    "cross": _cross_init(k2, cfg)}
        params["super"] = _stack(k_layers, n_super, super_init)
    else:
        params["layers"] = _stack(k_layers, cfg.num_layers,
                                  lambda kk: _layer_init(kk, cfg))
    if not cfg.tie_embeddings:
        params["unembed"] = {"w": L._dense(k_head, (cfg.d_model, cfg.padded_vocab))}
    return params


def param_logical(cfg: ArchConfig) -> dict:
    def stacked(tree):  # prepend None for the layer-stack dim
        return jax.tree.map(lambda ax: (None,) + ax, tree,
                            is_leaf=lambda v: isinstance(v, tuple) and not isinstance(v, dict))
    out = {
        "embed": L.embed_logical(),
        "final_norm": L.norm_logical(cfg.norm),
    }
    if cfg.cross_attn_every:
        out["super"] = {"blocks": stacked(stacked(_layer_logical(cfg))),
                        "cross": stacked(_cross_logical(cfg))}
    else:
        out["layers"] = stacked(_layer_logical(cfg))
    if not cfg.tie_embeddings:
        out["unembed"] = {"w": ("fsdp", "vocab")}
    return out


def _super_apply_unrolled(cfg: ArchConfig, sp, x, positions, img, attn_impl):
    """One VLM super-layer (cross_attn_every dense layers + cross block) with
    the inner loop unrolled — used by roofline probes so no nested scan hides
    FLOPs from cost_analysis."""
    for i in range(cfg.cross_attn_every):
        lp = jax.tree.map(lambda t: t[i], sp["blocks"])
        x, _ = _layer_apply(cfg, lp, x, positions, attn_impl)
    return _cross_apply(cfg, sp["cross"], x, img, attn_impl)


def _super_decode_unrolled(cfg: ArchConfig, sp, x, ck, cv, img, pos, positions,
                           block_tables=None, paged_impl: str = "einsum",
                           kscale=None, vscale=None):
    quantized = kscale is not None
    cks, cvs, kss, vss = [], [], [], []
    for i in range(cfg.cross_attn_every):
        lp = jax.tree.map(lambda t: t[i], sp["blocks"])
        if quantized:
            x, c1, c2, s1, s2 = _decode_layer(cfg, lp, x, ck[i], cv[i], pos,
                                              positions, block_tables,
                                              paged_impl, kscale[i], vscale[i])
            kss.append(s1)
            vss.append(s2)
        else:
            x, c1, c2 = _decode_layer(cfg, lp, x, ck[i], cv[i], pos, positions,
                                      block_tables, paged_impl)
        cks.append(c1)
        cvs.append(c2)
    x = _cross_apply(cfg, sp["cross"], x, img, "einsum")
    if quantized:
        return x, jnp.stack(cks), jnp.stack(cvs), jnp.stack(kss), jnp.stack(vss)
    return x, jnp.stack(cks), jnp.stack(cvs)


# ------------------------------------------------------------------ forward
def _mla_full_attention(cfg: ArchConfig, lp_attn, h, positions):
    """Full-sequence MLA attention for the train/forward path: one prefill
    chunk spanning the whole sequence against a transient latent cache —
    the same absorbed op order every serving path uses."""
    dims = _mla_dims(cfg)
    B, S, _ = h.shape
    cache_c = jnp.zeros((B, S, 1, dims.latent_dim), h.dtype)
    out, _ = L.mla_attention_prefill_chunk(lp_attn, h, dims, cache_c,
                                           jnp.zeros((), jnp.int32), positions)
    return out


def _layer_apply(cfg: ArchConfig, lp, x, positions, attn_impl):
    from jax.ad_checkpoint import checkpoint_name
    h = L.apply_norm(x, lp["ln1"], cfg.norm)
    if _is_mla(cfg):
        a = _mla_full_attention(cfg, lp["attn"], h, positions)
    else:
        a = L.attention(lp["attn"], h, _attn_dims(cfg), positions,
                        impl=attn_impl)
    # named saves: under the 'save_outs' remat policy the backward pass reuses
    # these post-collective tensors instead of re-running attention/MLP (and
    # their all-to-all / all-reduce resharding) — hillclimb B iteration 2
    x = x + checkpoint_name(a, "attn_out")
    x = shard(x, "batch", "seq_sp", None)
    h = L.apply_norm(x, lp["ln2"], cfg.norm)
    if cfg.moe:
        y, aux = L.moe(lp["moe"], h, _moe_dims(cfg))
    else:
        y, aux = L.mlp(lp["mlp"], h), {"moe_aux": jnp.zeros(()), "moe_z": jnp.zeros(())}
    x = shard(x + checkpoint_name(y, "mlp_out"), "batch", "seq_sp", None)
    return x, aux


def _cross_apply(cfg: ArchConfig, cp, x, image_kv, attn_impl):
    """Gated cross-attention to (precomputed) image K/V embeds: (B, T_img, D)."""
    h = L.apply_norm(x, cp["ln"], cfg.norm)
    B, S, _ = x.shape
    t_img = image_kv.shape[1]
    img_pos = jnp.zeros((B, t_img), jnp.int32)
    dims = _cross_dims(cfg)
    # project image tokens with this layer's k/v weights
    k = (image_kv @ cp["attn"]["wk"].astype(x.dtype)).reshape(B, t_img, dims.num_kv_heads, dims.head_dim)
    v = (image_kv @ cp["attn"]["wv"].astype(x.dtype)).reshape(B, t_img, dims.num_kv_heads, dims.head_dim)
    out = L.attention(cp["attn"], h, dims, jnp.zeros((B, S), jnp.int32),
                      impl="einsum", kv_override=(k, v, img_pos))
    return x + jnp.tanh(cp["gate"]).astype(x.dtype) * out


def forward(params, cfg: ArchConfig, tokens, *, image_embeds=None,
            compute_dtype=jnp.bfloat16, attn_impl: str = "einsum",
            remat: bool = False, positions=None, return_features: bool = False):
    """tokens: (B, S) int32 -> logits (B, S, V) in float32, aux dict."""
    B, S = tokens.shape
    x = L.embed_lookup(params["embed"], tokens, compute_dtype)
    x = shard(x, "batch", "seq_sp", None)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(x, lp):
        return _layer_apply(cfg, lp, x, positions, attn_impl)

    if remat:
        body = jax.checkpoint(body, policy=_remat_policy(remat))

    if cfg.cross_attn_every:
        assert image_embeds is not None, "VLM forward needs image_embeds"
        img = image_embeds.astype(compute_dtype)

        def super_body(x, sp):
            x, aux = jax.lax.scan(body, x, sp["blocks"])
            x = _cross_apply(cfg, sp["cross"], x, img, attn_impl)
            return x, jax.tree.map(jnp.sum, aux)
        if remat:
            super_body = jax.checkpoint(super_body,
                                        policy=jax.checkpoint_policies.nothing_saveable)
        x, aux = jax.lax.scan(super_body, x, params["super"])
    else:
        x, aux = jax.lax.scan(body, x, params["layers"])

    x = L.apply_norm(x, params["final_norm"], cfg.norm)
    aux = jax.tree.map(jnp.sum, aux)
    if return_features:
        return x, aux
    w_un = params["unembed"]["w"] if not cfg.tie_embeddings else None
    logits = L.lm_logits(params["embed"], x, w_un, vocab=cfg.vocab_size)
    return logits.astype(jnp.float32), aux


# ------------------------------------------------------------------ decode
def init_cache(cfg: ArchConfig, batch: int, s_max: int, dtype=jnp.bfloat16):
    if _is_mla(cfg):
        # latent cache: ONE (c_kv + r)-wide row per token under the "k" key
        # (shaped like a single-kv-head cache so every generic splice/page
        # path applies unchanged); there is no "v" leaf — values are the
        # leading c_kv columns of the same rows, read via the absorb path.
        d = _mla_dims(cfg)
        shape = (cfg.num_layers, batch, s_max, 1, d.latent_dim)
        return {"k": jnp.zeros(shape, dtype), "pos": jnp.zeros((), jnp.int32)}
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    shape = (cfg.num_layers, batch, s_max, kv, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "pos": jnp.zeros((), jnp.int32)}


def cache_logical(cfg: ArchConfig):
    """Adaptive: shard kv heads when they divide the model axis, else shard
    the cache sequence dim (context-parallel decode)."""
    from repro.sharding import specs as _sp
    if _is_mla(cfg):
        # the latent axis is shared by all heads — nothing head-like to shard
        return {"k": (None, "batch", None, None, None), "pos": ()}
    if cfg.num_kv_heads % max(_sp.axis_size("kv_heads"), 1) == 0:
        kv = (None, "batch", None, "kv_heads", None)
    else:
        kv = (None, "batch", "seq_sp", None, None)
    return {"k": kv, "v": kv, "pos": ()}


def _decode_layer(cfg: ArchConfig, lp, x, ck, cv, pos, positions,
                  block_tables=None, paged_impl: str = "einsum",
                  kscale=None, vscale=None):
    """One decode layer: returns (x, new_ck, new_cv). Exposed for roofline
    probes (launch/probes.py) as well as the decode scan body. When
    ``block_tables`` is given, ck/cv are one layer's (P, ps, KV, hd) page-pool
    slice and attention goes through the paged path (models/layers.py);
    ``paged_impl`` selects the Pallas block-gather kernel or the
    masked-einsum reference read. ``kscale``/``vscale`` are this layer's
    (P, tp) per-page per-kv-head-group dequant scales for int8 pools; when
    given the return grows to (x, ck, cv, kscale, vscale)."""
    quantized = kscale is not None
    h = L.apply_norm(x, lp["ln1"], cfg.norm)
    if block_tables is not None:
        if quantized:
            out, ck, cv, kscale, vscale = L.attention_decode_paged(
                lp["attn"], h, _attn_dims(cfg), ck, cv, block_tables, pos,
                positions, impl=paged_impl, k_scale=kscale, v_scale=vscale)
        else:
            out, ck, cv = L.attention_decode_paged(
                lp["attn"], h, _attn_dims(cfg), ck, cv, block_tables, pos,
                positions, impl=paged_impl)
    else:
        out, ck, cv = L.attention_decode(lp["attn"], h, _attn_dims(cfg), ck,
                                         cv, pos, positions)
    x = x + out
    h = L.apply_norm(x, lp["ln2"], cfg.norm)
    if cfg.moe:
        y, _ = L.moe(lp["moe"], h, _moe_dims(cfg))
    else:
        y = L.mlp(lp["mlp"], h)
    if quantized:
        return x + y, ck, cv, kscale, vscale
    return x + y, ck, cv


def _index_tree(tree, i):
    return jax.tree.map(
        lambda t: jax.lax.dynamic_index_in_dim(t, i, 0, keepdims=False), tree)


# ------------------------------------------------------------- MLA layers
# The latent-cache twins of _decode_layer / _prefill_chunk_layer(_paged):
# one "k" latent carry instead of (ck, cv), same residual structure. Kept as
# separate bodies (and separate fori_loop drivers below) because the carry
# pytree differs — a dummy "v" leaf would defeat the whole representation.
def _decode_layer_mla(cfg: ArchConfig, lp, x, ck, pos, positions,
                      block_tables=None, paged_impl: str = "einsum"):
    h = L.apply_norm(x, lp["ln1"], cfg.norm)
    if block_tables is not None:
        out, ck = L.mla_attention_decode_paged(
            lp["attn"], h, _mla_dims(cfg), ck, block_tables, pos, positions,
            impl=paged_impl)
    else:
        out, ck = L.mla_attention_decode(lp["attn"], h, _mla_dims(cfg), ck,
                                         pos, positions)
    x = x + out
    h = L.apply_norm(x, lp["ln2"], cfg.norm)
    y = L.moe(lp["moe"], h, _moe_dims(cfg))[0] if cfg.moe else L.mlp(lp["mlp"], h)
    return x + y, ck


def _prefill_chunk_layer_mla(cfg: ArchConfig, lp, x, ck, start, positions):
    h = L.apply_norm(x, lp["ln1"], cfg.norm)
    out, ck = L.mla_attention_prefill_chunk(lp["attn"], h, _mla_dims(cfg),
                                            ck, start, positions)
    x = x + out
    h = L.apply_norm(x, lp["ln2"], cfg.norm)
    y = L.moe(lp["moe"], h, _moe_dims(cfg))[0] if cfg.moe else L.mlp(lp["mlp"], h)
    return x + y, ck


def _prefill_chunk_layer_paged_mla(cfg: ArchConfig, lp, x, pk, bt, positions,
                                   write_floor, impl):
    h = L.apply_norm(x, lp["ln1"], cfg.norm)
    out, pk = L.mla_attention_prefill_chunk_paged(
        lp["attn"], h, _mla_dims(cfg), pk, bt, positions, write_floor,
        impl=impl)
    x = x + out
    h = L.apply_norm(x, lp["ln2"], cfg.norm)
    y = L.moe(lp["moe"], h, _moe_dims(cfg))[0] if cfg.moe else L.mlp(lp["mlp"], h)
    return x + y, pk


def _mla_layer_loop(params, cfg: ArchConfig, x, ck0, layer_fn):
    """fori_loop over layers carrying (x, latent cache) — the MLA driver
    shared by decode/prefill/paged-prefill (see decode_step's docstring for
    why fori_loop-with-DUS beats scan here)."""
    def body(i, carry):
        x, ck_all = carry
        lp = _index_tree(params["layers"], i)
        ck = jax.lax.dynamic_index_in_dim(ck_all, i, 0, keepdims=False)
        x, ck = layer_fn(lp, x, ck)
        return x, jax.lax.dynamic_update_index_in_dim(ck_all, ck, i, 0)
    return jax.lax.fori_loop(0, cfg.num_layers, body, (x, ck0))


# ------------------------------------------------------- parallel prefill
def _prefill_chunk_layer(cfg: ArchConfig, lp, x, ck, cv, start, positions,
                         use_kernel: bool):
    """One layer over a whole prompt chunk (matmul-wide ``_decode_layer``):
    writes the chunk's K/V rows into the per-request cache and attends all
    chunk positions jointly. Mirrors ``_decode_layer``'s math exactly (same
    residual structure, same masked-softmax validity) so the parallel
    prefill reproduces the scan-prefill anchor's greedy tokens."""
    h = L.apply_norm(x, lp["ln1"], cfg.norm)
    out, ck, cv = L.attention_prefill_chunk(lp["attn"], h, _attn_dims(cfg),
                                            ck, cv, start, positions,
                                            use_kernel=use_kernel)
    x = x + out
    h = L.apply_norm(x, lp["ln2"], cfg.norm)
    if cfg.moe:
        y, _ = L.moe(lp["moe"], h, _moe_dims(cfg))
    else:
        y = L.mlp(lp["mlp"], h)
    return x + y, ck, cv


def _super_prefill_chunk_unrolled(cfg: ArchConfig, sp, x, ck, cv, img, start,
                                  positions, use_kernel):
    cks, cvs = [], []
    for i in range(cfg.cross_attn_every):
        lp = jax.tree.map(lambda t: t[i], sp["blocks"])
        x, c1, c2 = _prefill_chunk_layer(cfg, lp, x, ck[i], cv[i], start,
                                         positions, use_kernel)
        cks.append(c1)
        cvs.append(c2)
    x = _cross_apply(cfg, sp["cross"], x, img, "einsum")
    return x, jnp.stack(cks), jnp.stack(cvs)


def prefill_chunk(params, cfg: ArchConfig, tokens, cache, *, image_embeds=None,
                  compute_dtype=jnp.bfloat16, attn_impl: str = "einsum",
                  first: bool = False, **_):
    """Full-width parallel prefill over one prompt chunk.

    tokens: (B, C) — C consecutive prompt positions starting at
    ``cache["pos"]`` (0 for a first chunk, where the position is static so
    the flash prefill kernel path applies). Every position is computed in
    ONE matmul-wide pass per layer — prompt ingestion runs at prefill
    arithmetic intensity instead of the decode_step-under-scan's one token
    of matmul width per step — and the per-layer post-RoPE K/V land
    directly in the request cache, ready for the engine's (paged) splice.
    Returns (last-position logits (B, 1, Vp) float32, cache with pos += C);
    the same output contract as the scan prefill, which stays the
    bit-exactness anchor."""
    B, C = tokens.shape
    start = jnp.zeros((), jnp.int32) if first else cache["pos"]
    positions = start + jnp.broadcast_to(jnp.arange(C, dtype=jnp.int32), (B, C))
    use_kernel = first and attn_impl == "pallas"
    x = L.embed_lookup(params["embed"], tokens, compute_dtype)

    if _is_mla(cfg):
        x, new_k = _mla_layer_loop(
            params, cfg, x, cache["k"],
            lambda lp, x, ck: _prefill_chunk_layer_mla(cfg, lp, x, ck, start,
                                                       positions))
        x = L.apply_norm(x[:, -1:], params["final_norm"], cfg.norm)
        w_un = params["unembed"]["w"] if not cfg.tie_embeddings else None
        logits = L.lm_logits(params["embed"], x, w_un, vocab=cfg.vocab_size)
        return logits.astype(jnp.float32), dict(cache, k=new_k, pos=start + C)

    if cfg.cross_attn_every:
        assert image_embeds is not None, "VLM prefill needs image_embeds"
        img = image_embeds.astype(compute_dtype)
        per = cfg.cross_attn_every
        n_super = cfg.num_layers // per
        ck0 = cache["k"].reshape(n_super, per, *cache["k"].shape[1:])
        cv0 = cache["v"].reshape(n_super, per, *cache["v"].shape[1:])

        def body(i, carry):
            x, ck_all, cv_all = carry
            sp = _index_tree(params["super"], i)
            ck = jax.lax.dynamic_index_in_dim(ck_all, i, 0, keepdims=False)
            cv = jax.lax.dynamic_index_in_dim(cv_all, i, 0, keepdims=False)
            x, ck, cv = _super_prefill_chunk_unrolled(
                cfg, sp, x, ck, cv, img, start, positions, use_kernel)
            ck_all = jax.lax.dynamic_update_index_in_dim(ck_all, ck, i, 0)
            cv_all = jax.lax.dynamic_update_index_in_dim(cv_all, cv, i, 0)
            return x, ck_all, cv_all

        x, ck, cv = jax.lax.fori_loop(0, n_super, body, (x, ck0, cv0))
        new_k = ck.reshape(cache["k"].shape)
        new_v = cv.reshape(cache["v"].shape)
    else:
        def body(i, carry):
            x, ck_all, cv_all = carry
            lp = _index_tree(params["layers"], i)
            ck = jax.lax.dynamic_index_in_dim(ck_all, i, 0, keepdims=False)
            cv = jax.lax.dynamic_index_in_dim(cv_all, i, 0, keepdims=False)
            x, ck, cv = _prefill_chunk_layer(cfg, lp, x, ck, cv, start,
                                             positions, use_kernel)
            ck_all = jax.lax.dynamic_update_index_in_dim(ck_all, ck, i, 0)
            cv_all = jax.lax.dynamic_update_index_in_dim(cv_all, cv, i, 0)
            return x, ck_all, cv_all

        x, new_k, new_v = jax.lax.fori_loop(
            0, cfg.num_layers, body, (x, cache["k"], cache["v"]))

    x = L.apply_norm(x[:, -1:], params["final_norm"], cfg.norm)
    w_un = params["unembed"]["w"] if not cfg.tie_embeddings else None
    logits = L.lm_logits(params["embed"], x, w_un, vocab=cfg.vocab_size)
    return logits.astype(jnp.float32), dict(cache, k=new_k, v=new_v,
                                            pos=start + C)


# ------------------------------------------------- paged parallel prefill
def _prefill_chunk_layer_paged(cfg: ArchConfig, lp, x, pk, pv, bt, positions,
                               write_floor, impl, kscale=None, vscale=None):
    """One layer over a prompt chunk attending the PAGED pool directly:
    the chunk's K/V rows scatter into the slot's own pages (the incremental
    splice) and attention reads everything — prior chunks, aliased prefix
    pages, the current chunk — through the block table. Same residual
    structure as ``_prefill_chunk_layer``/``_decode_layer``. Int8 pools
    carry per-layer (P, tp) per-group scales and the return grows
    accordingly."""
    quantized = kscale is not None
    h = L.apply_norm(x, lp["ln1"], cfg.norm)
    if quantized:
        out, pk, pv, kscale, vscale = L.attention_prefill_chunk_paged(
            lp["attn"], h, _attn_dims(cfg), pk, pv, bt, positions,
            write_floor, impl=impl, k_scale=kscale, v_scale=vscale)
    else:
        out, pk, pv = L.attention_prefill_chunk_paged(
            lp["attn"], h, _attn_dims(cfg), pk, pv, bt, positions,
            write_floor, impl=impl)
    x = x + out
    h = L.apply_norm(x, lp["ln2"], cfg.norm)
    if cfg.moe:
        y, _ = L.moe(lp["moe"], h, _moe_dims(cfg))
    else:
        y = L.mlp(lp["mlp"], h)
    if quantized:
        return x + y, pk, pv, kscale, vscale
    return x + y, pk, pv


def _super_prefill_chunk_paged_unrolled(cfg: ArchConfig, sp, x, pk, pv, bt,
                                        img, positions, write_floor, impl,
                                        kscale=None, vscale=None):
    quantized = kscale is not None
    pks, pvs, kss, vss = [], [], [], []
    for i in range(cfg.cross_attn_every):
        lp = jax.tree.map(lambda t: t[i], sp["blocks"])
        if quantized:
            x, p1, p2, s1, s2 = _prefill_chunk_layer_paged(
                cfg, lp, x, pk[i], pv[i], bt, positions, write_floor, impl,
                kscale[i], vscale[i])
            kss.append(s1)
            vss.append(s2)
        else:
            x, p1, p2 = _prefill_chunk_layer_paged(cfg, lp, x, pk[i], pv[i],
                                                   bt, positions, write_floor,
                                                   impl)
        pks.append(p1)
        pvs.append(p2)
    x = _cross_apply(cfg, sp["cross"], x, img, "einsum")
    if quantized:
        return x, jnp.stack(pks), jnp.stack(pvs), jnp.stack(kss), jnp.stack(vss)
    return x, jnp.stack(pks), jnp.stack(pvs)


def prefill_chunk_paged(params, cfg: ArchConfig, tokens, cache, *, bt_rows,
                        start, write_floor, image_embeds=None,
                        compute_dtype=jnp.bfloat16, attn_impl: str = "kernel",
                        **_):
    """Full-width prefill over one prompt chunk, spliced into the RESIDENT
    paged cache incrementally (no transient request cache, no completion
    splice — the tentpole path).

    tokens: (K, C) — C consecutive prompt positions for a group of K slots,
    starting at the traced scalar ``start``; ``cache`` is the engine's
    resident PAGED cache (page pools + per-slot leaves); ``bt_rows``:
    (K, mps) the group's block-table rows; ``write_floor``: traced scalar —
    rows below it live in shared immutable prefix pages and are dropped by
    the scatter. Every chunk is uniform (no first/continuation split): the
    chunk writes its K/V rows into the group's pages, then attends the
    pages through the block table, so a prefix-cache hit needs NO gather
    seeding — aliased pages are read in place. Returns (last-position
    logits (K, 1, Vp) float32, cache with updated pools); the engine
    advances the group's ``pos`` at job completion."""
    K, C = tokens.shape
    start = jnp.asarray(start, jnp.int32)
    write_floor = jnp.asarray(write_floor, jnp.int32)
    positions = start + jnp.broadcast_to(jnp.arange(C, dtype=jnp.int32),
                                         (K, C))
    x = L.embed_lookup(params["embed"], tokens, compute_dtype)
    # an int8-backend cache carries (L, P, tp) per-page per-group scale
    # leaves alongside the pools; the scales thread through the layer loop
    # exactly like the pools do. Gated at trace time, so the fp32 jaxpr is
    # unchanged.
    quantized = "k_scale" in cache
    scales = {}

    if _is_mla(cfg):
        x, new_k = _mla_layer_loop(
            params, cfg, x, cache["k"],
            lambda lp, x, pk: _prefill_chunk_layer_paged_mla(
                cfg, lp, x, pk, bt_rows, positions, write_floor, attn_impl))
        x = L.apply_norm(x[:, -1:], params["final_norm"], cfg.norm)
        w_un = params["unembed"]["w"] if not cfg.tie_embeddings else None
        logits = L.lm_logits(params["embed"], x, w_un, vocab=cfg.vocab_size)
        return logits.astype(jnp.float32), dict(cache, k=new_k)

    if cfg.cross_attn_every:
        assert image_embeds is not None, "VLM prefill needs image_embeds"
        img = image_embeds.astype(compute_dtype)
        per = cfg.cross_attn_every
        n_super = cfg.num_layers // per
        pk0 = cache["k"].reshape(n_super, per, *cache["k"].shape[1:])
        pv0 = cache["v"].reshape(n_super, per, *cache["v"].shape[1:])

        if quantized:
            ks0 = cache["k_scale"].reshape(n_super, per,
                                           *cache["k_scale"].shape[1:])
            vs0 = cache["v_scale"].reshape(n_super, per,
                                           *cache["v_scale"].shape[1:])

            def bodyq(i, carry):
                x, pk_all, pv_all, ks_all, vs_all = carry
                sp = _index_tree(params["super"], i)
                idx = lambda t: jax.lax.dynamic_index_in_dim(
                    t, i, 0, keepdims=False)
                x, pk, pv, ks, vs = _super_prefill_chunk_paged_unrolled(
                    cfg, sp, x, idx(pk_all), idx(pv_all), bt_rows, img,
                    positions, write_floor, attn_impl, idx(ks_all),
                    idx(vs_all))
                upd = jax.lax.dynamic_update_index_in_dim
                return (x, upd(pk_all, pk, i, 0), upd(pv_all, pv, i, 0),
                        upd(ks_all, ks, i, 0), upd(vs_all, vs, i, 0))

            x, pk, pv, ks, vs = jax.lax.fori_loop(
                0, n_super, bodyq, (x, pk0, pv0, ks0, vs0))
            scales = dict(k_scale=ks.reshape(cache["k_scale"].shape),
                          v_scale=vs.reshape(cache["v_scale"].shape))
        else:
            def body(i, carry):
                x, pk_all, pv_all = carry
                sp = _index_tree(params["super"], i)
                pk = jax.lax.dynamic_index_in_dim(pk_all, i, 0, keepdims=False)
                pv = jax.lax.dynamic_index_in_dim(pv_all, i, 0, keepdims=False)
                x, pk, pv = _super_prefill_chunk_paged_unrolled(
                    cfg, sp, x, pk, pv, bt_rows, img, positions, write_floor,
                    attn_impl)
                pk_all = jax.lax.dynamic_update_index_in_dim(pk_all, pk, i, 0)
                pv_all = jax.lax.dynamic_update_index_in_dim(pv_all, pv, i, 0)
                return x, pk_all, pv_all

            x, pk, pv = jax.lax.fori_loop(0, n_super, body, (x, pk0, pv0))
        new_k = pk.reshape(cache["k"].shape)
        new_v = pv.reshape(cache["v"].shape)
    elif quantized:
        def bodyq(i, carry):
            x, pk_all, pv_all, ks_all, vs_all = carry
            lp = _index_tree(params["layers"], i)
            idx = lambda t: jax.lax.dynamic_index_in_dim(t, i, 0,
                                                         keepdims=False)
            x, pk, pv, ks, vs = _prefill_chunk_layer_paged(
                cfg, lp, x, idx(pk_all), idx(pv_all), bt_rows, positions,
                write_floor, attn_impl, idx(ks_all), idx(vs_all))
            upd = jax.lax.dynamic_update_index_in_dim
            return (x, upd(pk_all, pk, i, 0), upd(pv_all, pv, i, 0),
                    upd(ks_all, ks, i, 0), upd(vs_all, vs, i, 0))

        x, new_k, new_v, ks, vs = jax.lax.fori_loop(
            0, cfg.num_layers, bodyq,
            (x, cache["k"], cache["v"], cache["k_scale"], cache["v_scale"]))
        scales = dict(k_scale=ks, v_scale=vs)
    else:
        def body(i, carry):
            x, pk_all, pv_all = carry
            lp = _index_tree(params["layers"], i)
            pk = jax.lax.dynamic_index_in_dim(pk_all, i, 0, keepdims=False)
            pv = jax.lax.dynamic_index_in_dim(pv_all, i, 0, keepdims=False)
            x, pk, pv = _prefill_chunk_layer_paged(cfg, lp, x, pk, pv,
                                                   bt_rows, positions,
                                                   write_floor, attn_impl)
            pk_all = jax.lax.dynamic_update_index_in_dim(pk_all, pk, i, 0)
            pv_all = jax.lax.dynamic_update_index_in_dim(pv_all, pv, i, 0)
            return x, pk_all, pv_all

        x, new_k, new_v = jax.lax.fori_loop(
            0, cfg.num_layers, body, (x, cache["k"], cache["v"]))

    x = L.apply_norm(x[:, -1:], params["final_norm"], cfg.norm)
    w_un = params["unembed"]["w"] if not cfg.tie_embeddings else None
    logits = L.lm_logits(params["embed"], x, w_un, vocab=cfg.vocab_size)
    return logits.astype(jnp.float32), dict(cache, k=new_k, v=new_v, **scales)


def decode_step(params, cfg: ArchConfig, token, cache, *, image_embeds=None,
                compute_dtype=jnp.bfloat16, paged_attn_impl: str = "einsum"):
    """token: (B, 1) int32. Returns (logits (B,1,V), new cache).

    Layers run in a fori_loop carrying the FULL (L,B,S,KV,hd) cache with
    in-place dynamic updates — a lax.scan over per-layer cache slices stacks
    fresh output buffers (a full extra cache copy in HBM) because XLA cannot
    alias scan ys to donated inputs.

    cache["pos"] may be a scalar (lockstep batch) or a (B,) per-slot vector
    (serving engine with continuous batching). A cache carrying a
    "block_tables" leaf is PAGED (models/registry.py::init_paged_cache):
    "k"/"v" are (L, P, page_size, KV, hd) page pools and decode routes
    through the block-table-indirect attention path — through the Pallas
    block-gather kernel with ``paged_attn_impl='kernel'``, the masked-einsum
    reference otherwise."""
    B = token.shape[0]
    pos = cache["pos"]
    bt = cache.get("block_tables")
    positions = L.decode_positions(pos, B)
    x = L.embed_lookup(params["embed"], token, compute_dtype)
    # int8-backend caches carry (L, P, tp) per-page per-group scale leaves;
    # see prefill_chunk_paged — trace-time gate, fp32 jaxpr unchanged
    quantized = bt is not None and "k_scale" in cache
    scales = {}

    if _is_mla(cfg):
        x, new_k = _mla_layer_loop(
            params, cfg, x, cache["k"],
            lambda lp, x, ck: _decode_layer_mla(cfg, lp, x, ck, pos,
                                                positions, bt,
                                                paged_attn_impl))
        x = L.apply_norm(x, params["final_norm"], cfg.norm)
        w_un = params["unembed"]["w"] if not cfg.tie_embeddings else None
        logits = L.lm_logits(params["embed"], x, w_un, vocab=cfg.vocab_size)
        return logits.astype(jnp.float32), dict(cache, k=new_k, pos=pos + 1)

    if cfg.cross_attn_every:
        assert image_embeds is not None
        img = image_embeds.astype(compute_dtype)
        per = cfg.cross_attn_every
        n_super = cfg.num_layers // per
        ck0 = cache["k"].reshape(n_super, per, *cache["k"].shape[1:])
        cv0 = cache["v"].reshape(n_super, per, *cache["v"].shape[1:])

        if quantized:
            ks0 = cache["k_scale"].reshape(n_super, per,
                                           *cache["k_scale"].shape[1:])
            vs0 = cache["v_scale"].reshape(n_super, per,
                                           *cache["v_scale"].shape[1:])

            def bodyq(i, carry):
                x, ck_all, cv_all, ks_all, vs_all = carry
                sp = _index_tree(params["super"], i)
                idx = lambda t: jax.lax.dynamic_index_in_dim(
                    t, i, 0, keepdims=False)
                x, ck, cv, ks, vs = _super_decode_unrolled(
                    cfg, sp, x, idx(ck_all), idx(cv_all), img, pos, positions,
                    bt, paged_attn_impl, idx(ks_all), idx(vs_all))
                upd = jax.lax.dynamic_update_index_in_dim
                return (x, upd(ck_all, ck, i, 0), upd(cv_all, cv, i, 0),
                        upd(ks_all, ks, i, 0), upd(vs_all, vs, i, 0))

            x, ck, cv, ks, vs = jax.lax.fori_loop(
                0, n_super, bodyq, (x, ck0, cv0, ks0, vs0))
            scales = dict(k_scale=ks.reshape(cache["k_scale"].shape),
                          v_scale=vs.reshape(cache["v_scale"].shape))
        else:
            def body(i, carry):
                x, ck_all, cv_all = carry
                sp = _index_tree(params["super"], i)
                ck = jax.lax.dynamic_index_in_dim(ck_all, i, 0, keepdims=False)
                cv = jax.lax.dynamic_index_in_dim(cv_all, i, 0, keepdims=False)
                x, ck, cv = _super_decode_unrolled(cfg, sp, x, ck, cv, img,
                                                   pos, positions, bt,
                                                   paged_attn_impl)
                ck_all = jax.lax.dynamic_update_index_in_dim(ck_all, ck, i, 0)
                cv_all = jax.lax.dynamic_update_index_in_dim(cv_all, cv, i, 0)
                return x, ck_all, cv_all

            x, ck, cv = jax.lax.fori_loop(0, n_super, body, (x, ck0, cv0))
        new_k = ck.reshape(cache["k"].shape)
        new_v = cv.reshape(cache["v"].shape)
    elif quantized:
        def bodyq(i, carry):
            x, ck_all, cv_all, ks_all, vs_all = carry
            lp = _index_tree(params["layers"], i)
            idx = lambda t: jax.lax.dynamic_index_in_dim(t, i, 0,
                                                         keepdims=False)
            x, ck, cv, ks, vs = _decode_layer(
                cfg, lp, x, idx(ck_all), idx(cv_all), pos, positions, bt,
                paged_attn_impl, idx(ks_all), idx(vs_all))
            upd = jax.lax.dynamic_update_index_in_dim
            return (x, upd(ck_all, ck, i, 0), upd(cv_all, cv, i, 0),
                    upd(ks_all, ks, i, 0), upd(vs_all, vs, i, 0))

        x, new_k, new_v, ks, vs = jax.lax.fori_loop(
            0, cfg.num_layers, bodyq,
            (x, cache["k"], cache["v"], cache["k_scale"], cache["v_scale"]))
        scales = dict(k_scale=ks, v_scale=vs)
    else:
        def body(i, carry):
            x, ck_all, cv_all = carry
            lp = _index_tree(params["layers"], i)
            ck = jax.lax.dynamic_index_in_dim(ck_all, i, 0, keepdims=False)
            cv = jax.lax.dynamic_index_in_dim(cv_all, i, 0, keepdims=False)
            x, ck, cv = _decode_layer(cfg, lp, x, ck, cv, pos, positions, bt,
                                      paged_attn_impl)
            ck_all = jax.lax.dynamic_update_index_in_dim(ck_all, ck, i, 0)
            cv_all = jax.lax.dynamic_update_index_in_dim(cv_all, cv, i, 0)
            return x, ck_all, cv_all

        x, new_k, new_v = jax.lax.fori_loop(
            0, cfg.num_layers, body, (x, cache["k"], cache["v"]))

    x = L.apply_norm(x, params["final_norm"], cfg.norm)
    w_un = params["unembed"]["w"] if not cfg.tie_embeddings else None
    logits = L.lm_logits(params["embed"], x, w_un, vocab=cfg.vocab_size)
    new_cache = dict(cache, k=new_k, v=new_v, pos=pos + 1, **scales)
    return logits.astype(jnp.float32), new_cache
