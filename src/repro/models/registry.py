"""Uniform model facade: every architecture family exposes
init / param_logical / forward / init_cache / cache_logical / decode_step /
input_specs through a single ``Model`` object keyed by arch id.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, Family, ShapeConfig
from repro.models import encdec, hybrid, ssm, transformer
from repro.models.encdec import ENC_LEN
from repro.models.layers import INACTIVE_POS

_FAMILY_MODULES = {
    Family.DENSE: transformer,
    Family.MOE: transformer,
    Family.VLM: transformer,
    Family.ENCDEC: encdec,
    Family.SSM: ssm,
    Family.HYBRID: hybrid,
}


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    mod: Any

    def init(self, key):
        return self.mod.init(self.cfg, key)

    def param_logical(self):
        return self.mod.param_logical(self.cfg)

    def forward(self, params, tokens, **kw):
        return self.mod.forward(params, self.cfg, tokens, **kw)

    def init_cache(self, batch: int, s_max: int, dtype=jnp.bfloat16):
        return self.mod.init_cache(self.cfg, batch, s_max, dtype)

    def cache_logical(self):
        return self.mod.cache_logical(self.cfg)

    def decode_step(self, params, token, cache, **kw):
        return self.mod.decode_step(params, self.cfg, token, cache, **kw)

    def prefill_chunk(self, params, tokens, cache, **kw):
        """Full-width parallel prefill over one prompt chunk (all families):
        (last logits (B,1,Vp), cache with pos advanced by the chunk length).
        See launch/steps.py::make_prefill_chunk for the serving contract."""
        return self.mod.prefill_chunk(params, self.cfg, tokens, cache, **kw)

    @property
    def supports_paged_prefill(self) -> bool:
        """True for families whose prompt state is exactly (k, v, pos) — the
        ones the incremental paged prefill (chunks splicing straight into
        pages, attention through the block table) can serve. Mirrors the
        prefix-cache support set: hybrid's recurrent carry and encdec's
        encoder/cross-K/V are not page-resident."""
        return hasattr(self.mod, "prefill_chunk_paged")

    def prefill_chunk_paged(self, params, tokens, cache, **kw):
        """Prompt chunk computed at full width and spliced into the RESIDENT
        paged cache incrementally (no transient request cache). See
        launch/steps.py::make_prefill_chunk_paged for the serving contract."""
        return self.mod.prefill_chunk_paged(params, self.cfg, tokens, cache,
                                            **kw)

    # -------------------------------------------------- input specs
    def extra_inputs(self, batch: int, seq: int, dtype=jnp.bfloat16) -> dict:
        """Modality-frontend STUB inputs (precomputed embeddings), per assignment."""
        cfg = self.cfg
        if cfg.family == Family.VLM:
            return {"image_embeds": jax.ShapeDtypeStruct(
                (batch, cfg.num_image_tokens, cfg.d_model), dtype)}
        if cfg.family == Family.ENCDEC:
            return {"frames": jax.ShapeDtypeStruct((batch, ENC_LEN, cfg.d_model), dtype)}
        return {}

    def input_specs(self, shape: ShapeConfig, dtype=jnp.bfloat16) -> dict:
        """ShapeDtypeStruct stand-ins for every model input of a shape cell."""
        B, S = shape.global_batch, shape.seq_len
        if shape.kind == "train":
            specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                     "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        elif shape.kind == "prefill":
            specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        else:  # decode: one new token against a cache of length S
            specs = {"token": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
        specs.update(self.extra_inputs(B, S, dtype))
        return specs


def get_model(cfg: ArchConfig) -> Model:
    if cfg.family == Family.CNN:
        raise ValueError("resnet20 uses models.resnet directly (paper pipeline)")
    return Model(cfg=cfg, mod=_FAMILY_MODULES[cfg.family])


# ------------------------------------------------------------------ serving
# Cache conventions. DENSE: every family's cache leaves are (L, B, ...) with
# the slot/batch axis at position 1, plus a "pos" leaf that is a scalar
# (lockstep batch) or a (B,) per-slot position vector. PAGED
# (init_paged_cache): the attention K/V leaves (and the hybrid ring's
# "slot_pos") are replaced by SHARED page pools (L, num_pages, page_size, ...)
# with NO batch axis, plus a "block_tables" leaf (B, max_pages_per_slot)
# int32 mapping each slot's logical blocks to pool pages (-1 = unallocated) —
# memory scales with allocated pages, not slots x s_max. Per-slot leaves
# without a sequence axis (SSM state, encdec cross K/V, "pos") keep the dense
# layout. A slot whose pos is >= layers.INACTIVE_POS is free: its writes are
# dropped by every decode path, so freed rows are bit-stable. The serving
# engine relies on these conventions to splice per-request prefill caches
# into the resident cache without touching other slots.

# pool leaves of a paged cache (when "block_tables" is present); everything
# else keeps the dense (L, B, ...) per-slot layout
PAGED_POOL_LEAVES = ("k", "v", "slot_pos")


def vectorize_cache_pos(cache, batch: int, inactive: bool = False):
    """Scalar-pos cache (init_cache output) -> per-slot (B,) position cache
    for the continuous-batching decode path. ``inactive=True`` starts every
    slot at the INACTIVE_POS sentinel (no slot admitted yet), so empty slots
    never scatter stale K/V rows while idle."""
    pos = cache["pos"]
    if jnp.ndim(pos) == 0:
        fill = INACTIVE_POS if inactive else pos
        cache = dict(cache, pos=jnp.full((batch,), fill, jnp.int32))
    return cache


def insert_cache_slot(cache, request_cache, slot):
    """Write a batch-1 request cache (a fresh prefill) into slot ``slot`` of a
    batched per-slot-pos serving cache — other slots' entries are untouched
    bit-for-bit. Thin wrapper over insert_cache_rows so there is exactly one
    implementation of the batch-axis splice. ``slot`` may be a traced scalar,
    so one jit covers every slot."""
    return insert_cache_rows(cache, request_cache,
                             jnp.reshape(jnp.asarray(slot, jnp.int32), (1,)))


def insert_cache_rows(cache, request_cache, slots):
    """Write a batch-K request cache (one joint prefill of K same-length
    prompts) into rows ``slots`` (a (K,) index vector) of a batched serving
    cache. Same isolation contract as insert_cache_slot: a scatter on the
    batch axis only."""
    slots = jnp.asarray(slots, jnp.int32)
    out = {}
    for key, leaf in cache.items():
        req = request_cache[key]
        if key == "pos":
            # prefill pos is a scalar (all K rows at prompt_len) or (K,)
            out[key] = leaf.at[slots].set(jnp.asarray(req, leaf.dtype))
        else:
            out[key] = leaf.at[:, slots].set(req.astype(leaf.dtype))
    return out


# ------------------------------------------------------------------ paged
def cache_capacity(cfg: ArchConfig, s_max: int) -> int:
    """Per-slot sequence capacity of the attention cache: the hybrid family
    keeps a ring buffer of width min(window, s_max); everything else stores
    the full s_max rows. This is the row count the page allocator must be
    able to cover for one slot."""
    if cfg.family == Family.HYBRID:
        return min(cfg.window, s_max)
    return s_max


def init_paged_cache(model: Model, batch: int, s_max: int, *, page_size: int,
                     num_pages: int, dtype=jnp.bfloat16):
    """Paged serving cache: the dense per-slot K/V (and hybrid ring
    ``slot_pos``) leaves become shared page pools (L, num_pages, page_size,
    ...) addressed through per-slot ``block_tables`` (B, max_pages_per_slot).
    All other leaves (SSM state, encdec cross K/V, conv carries) keep the
    dense per-slot layout — they are O(1) in sequence length. ``pos`` starts
    at the INACTIVE_POS sentinel for every slot (nothing admitted).

    s_max must be a page_size multiple so the paged logical view is exactly
    s_max rows (the bit-exactness anchor vs the dense path). Hybrid caches
    additionally carry a ``ring_iota`` (W,) leaf whose shape tells the decode
    path the ring width. The SSM family has no K/V to page — callers should
    keep it dense."""
    cfg = model.cfg
    if cfg.family == Family.SSM:
        raise ValueError("rwkv/ssm caches are O(1) in s_max; use init_cache")
    if s_max % page_size:
        raise ValueError(f"s_max {s_max} must be a multiple of page_size "
                         f"{page_size} (paged view == dense view)")
    dense = model.init_cache(batch, s_max, dtype)
    mps = s_max // page_size
    out = {}
    for key, leaf in dense.items():
        if key in ("k", "v"):               # (L, B, C, KV, hd) -> pool
            Lr, _, _, KV, hd = leaf.shape
            out[key] = jnp.zeros((Lr, num_pages, page_size, KV, hd),
                                 leaf.dtype)
        elif key == "slot_pos":             # hybrid ring positions -> pool
            out[key] = jnp.full((leaf.shape[0], num_pages, page_size), -1,
                                jnp.int32)
        elif key == "pos":
            out[key] = jnp.full((batch,), INACTIVE_POS, jnp.int32)
        else:
            out[key] = leaf
    out["block_tables"] = jnp.full((batch, mps), -1, jnp.int32)
    if cfg.family == Family.HYBRID:
        out["ring_iota"] = jnp.arange(cache_capacity(cfg, s_max),
                                      dtype=jnp.int32)
    return out


def insert_cache_rows_paged(cache, request_cache, slots, phys_rows):
    """Paged variant of insert_cache_rows: splice a batch-K DENSE prefill
    cache into the page pools of a paged serving cache.

    ``phys_rows`` is a (K, C) int32 map from each request's logical cache row
    (C = the family's per-slot capacity, s_max or the ring width) to a
    flattened pool row (page * page_size + offset); entries >= num_pages *
    page_size (unallocated logical blocks beyond the request's reservation)
    are DROPPED by the scatter, so a short request can never write into pages
    it does not own. Per-slot leaves and "pos" splice exactly like the dense
    path; "block_tables" is host-managed by the engine and passes through."""
    slots = jnp.asarray(slots, jnp.int32)
    phys_rows = jnp.asarray(phys_rows, jnp.int32)
    out = {}
    for key, leaf in cache.items():
        if key in ("block_tables", "ring_iota"):
            out[key] = leaf
            continue
        req = request_cache[key]
        if key in PAGED_POOL_LEAVES:
            Lr, P, ps = leaf.shape[:3]
            flat = leaf.reshape((Lr, P * ps) + leaf.shape[3:])
            C = phys_rows.shape[1]
            flat = flat.at[:, phys_rows].set(
                req[:, :, :C].astype(leaf.dtype), mode="drop")
            out[key] = flat.reshape(leaf.shape)
        elif key == "pos":
            out[key] = leaf.at[slots].set(jnp.asarray(req, leaf.dtype))
        else:
            out[key] = leaf.at[:, slots].set(req.astype(leaf.dtype))
    return out


def copy_pool_rows(cache, src_rows, dst_rows):
    """Copy K/V rows between flattened pool positions — the incremental
    prefill's copy-on-write materialisation: a prefix hit's PARTIAL source
    page rows are copied into the fresh page standing in for it, using the
    same gather/scatter the per-chunk splice uses (no transient cache, no
    extra device pass shape).

    ``src_rows``/``dst_rows``: (K, R) int32 flattened pool rows
    (page * page_size + offset); entries with dst >= num_pages * page_size
    are DROPPED (the masked tail of a partial copy). Only the pool K/V
    leaves move; everything else passes through untouched."""
    src_rows = jnp.asarray(src_rows, jnp.int32)
    dst_rows = jnp.asarray(dst_rows, jnp.int32)
    out = dict(cache)
    for key in ("k", "v"):
        if key not in cache:                # MLA latent pool: "k" only
            continue
        pool = cache[key]                   # (L, P, ps, KV, hd)
        Lr, P, ps = pool.shape[:3]
        flat = pool.reshape((Lr, P * ps) + pool.shape[3:])
        rows = flat[:, jnp.clip(src_rows, 0, P * ps - 1)]
        flat = flat.at[:, dst_rows].set(rows, mode="drop")
        out[key] = flat.reshape(pool.shape)
    return out


def seed_prefix_cache(model: Model, cache, phys_rows, row_ok, pos,
                      s_max: int, dtype=jnp.float32):
    """Build a dense batch-K transient prefill cache whose leading rows are
    GATHERED from a paged serving cache's page pools — the prefix-cache hit
    path: instead of recomputing a shared prompt prefix, the engine seeds the
    request's transient cache with the prefix K/V already resident in shared
    pages and runs only the uncached tail through ``prefill_chunk``.

    ``phys_rows`` is a (K, s_max) int32 map from each request's logical cache
    row to a flattened pool row (page * page_size + offset) covering exactly
    the cached prefix; ``row_ok`` masks rows beyond it (gathered as zeros —
    identical to the never-written rows of a fresh transient cache, and
    causally invisible: their k_pos exceeds every tail query position).
    ``pos`` is the scalar position the tail continuation chunks start at.

    Only valid for families whose transient prefill state is exactly
    (k, v, pos) — dense / MoE / VLM transformers; the engine gates on this
    (hybrid ring carry and SSM state are not reconstructible from pages)."""
    K = phys_rows.shape[0]
    out = model.init_cache(K, s_max, dtype)
    idx = jnp.where(row_ok, phys_rows, 0)
    for key in ("k", "v"):
        if key not in out:                  # MLA latent cache: "k" only
            continue
        pool = cache[key]                   # (L, P, ps, KV, hd)
        Lr, P, ps = pool.shape[:3]
        flat = pool.reshape((Lr, P * ps) + pool.shape[3:])
        rows = flat[:, idx]                 # (L, K, s_max, KV, hd)
        mask = row_ok.reshape((1,) + row_ok.shape + (1,) * (rows.ndim - 3))
        out[key] = jnp.where(mask, rows, 0).astype(out[key].dtype)
    out["pos"] = jnp.asarray(pos, jnp.int32)
    return out


def extract_cache_slot(cache, slot: int):
    """Batch-1 view of one slot's cache entries (testing/debug helper). For a
    paged cache, pool leaves are gathered through the slot's block table into
    the dense per-slot layout (rows of unallocated pages read as zeros / -1,
    matching a never-written dense cache). Int8 pools (an ``<key>_scale``
    leaf rides alongside) are DEQUANTIZED page-wise, so the view is a
    directly comparable f32 dense cache; the scale leaves themselves are
    per-page pool metadata with no dense counterpart and are skipped."""
    bt = cache.get("block_tables")
    out = {}
    for key, leaf in cache.items():
        if key in ("block_tables", "ring_iota") or key.endswith("_scale"):
            continue
        if key == "pos":
            out[key] = leaf if jnp.ndim(leaf) == 0 else leaf[slot]
        elif bt is not None and key in PAGED_POOL_LEAVES:
            from repro.models.layers import paged_row_indices
            Lr, P, ps = leaf.shape[:3]
            n_rows = bt.shape[1] * ps
            if key == "slot_pos":
                n_rows = cache["ring_iota"].shape[0]
            phys, ok = paged_row_indices(bt[slot:slot + 1], ps, n_rows)
            flat = leaf.reshape((Lr, P * ps) + leaf.shape[3:])
            view = flat[:, phys[0]]
            if key + "_scale" in cache:
                pg = jnp.clip(phys[0] // ps, 0, P - 1)
                view = (view.astype(jnp.float32)
                        * cache[key + "_scale"][:, pg][..., None, None])
            fill = -1 if key == "slot_pos" else 0
            mask = ok[0].reshape((1, -1) + (1,) * (view.ndim - 2))
            view = jnp.where(mask, view, fill)
            if key in ("k", "v") and "ring_iota" in cache:
                view = view[:, : cache["ring_iota"].shape[0]]
            out[key] = view[:, None]        # (L, 1, C, ...)
        else:
            out[key] = leaf[:, slot:slot + 1]
    return out


def reduced_config(cfg: ArchConfig, **overrides) -> ArchConfig:
    """Smoke-test-sized config of the same family (small dims, same structure)."""
    defaults = dict(
        num_layers=2 if not cfg.cross_attn_every else cfg.cross_attn_every,
        d_model=64,
        num_heads=4, num_kv_heads=max(1, 4 * cfg.num_kv_heads // max(cfg.num_heads, 1)),
        d_ff=128, vocab_size=512, head_dim=16,
        encoder_layers=2 if cfg.encoder_layers else 0,
        num_image_tokens=8 if cfg.cross_attn_every else 0,
        window=8 if cfg.window else 0,
        ssm_state=cfg.ssm_state and 4,
        kv_lora_rank=8 if cfg.kv_lora_rank else 0,
        qk_rope_head_dim=2 if cfg.qk_rope_head_dim else 0,
    )
    if cfg.moe:
        from repro.configs.base import MoEConfig
        defaults["moe"] = MoEConfig(num_experts=4, top_k=2,
                                    capacity_factor=cfg.moe.capacity_factor)
    if cfg.cross_attn_every:
        defaults["num_layers"] = 2 * cfg.cross_attn_every  # 2 super-layers
    defaults.update(overrides)
    return dataclasses.replace(cfg, **defaults)
