"""Uniform model facade: every architecture family exposes
init / param_logical / forward / init_cache / cache_logical / decode_step /
input_specs through a single ``Model`` object keyed by arch id.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, Family, ShapeConfig
from repro.models import encdec, hybrid, ssm, transformer
from repro.models.encdec import ENC_LEN

_FAMILY_MODULES = {
    Family.DENSE: transformer,
    Family.MOE: transformer,
    Family.VLM: transformer,
    Family.ENCDEC: encdec,
    Family.SSM: ssm,
    Family.HYBRID: hybrid,
}


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    mod: Any

    def init(self, key):
        return self.mod.init(self.cfg, key)

    def param_logical(self):
        return self.mod.param_logical(self.cfg)

    def forward(self, params, tokens, **kw):
        return self.mod.forward(params, self.cfg, tokens, **kw)

    def init_cache(self, batch: int, s_max: int, dtype=jnp.bfloat16):
        return self.mod.init_cache(self.cfg, batch, s_max, dtype)

    def cache_logical(self):
        return self.mod.cache_logical(self.cfg)

    def decode_step(self, params, token, cache, **kw):
        return self.mod.decode_step(params, self.cfg, token, cache, **kw)

    # -------------------------------------------------- input specs
    def extra_inputs(self, batch: int, seq: int, dtype=jnp.bfloat16) -> dict:
        """Modality-frontend STUB inputs (precomputed embeddings), per assignment."""
        cfg = self.cfg
        if cfg.family == Family.VLM:
            return {"image_embeds": jax.ShapeDtypeStruct(
                (batch, cfg.num_image_tokens, cfg.d_model), dtype)}
        if cfg.family == Family.ENCDEC:
            return {"frames": jax.ShapeDtypeStruct((batch, ENC_LEN, cfg.d_model), dtype)}
        return {}

    def input_specs(self, shape: ShapeConfig, dtype=jnp.bfloat16) -> dict:
        """ShapeDtypeStruct stand-ins for every model input of a shape cell."""
        B, S = shape.global_batch, shape.seq_len
        if shape.kind == "train":
            specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                     "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        elif shape.kind == "prefill":
            specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        else:  # decode: one new token against a cache of length S
            specs = {"token": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
        specs.update(self.extra_inputs(B, S, dtype))
        return specs


def get_model(cfg: ArchConfig) -> Model:
    if cfg.family == Family.CNN:
        raise ValueError("resnet20 uses models.resnet directly (paper pipeline)")
    return Model(cfg=cfg, mod=_FAMILY_MODULES[cfg.family])


# ------------------------------------------------------------------ serving
# Every family's cache obeys one layout convention: leaves are (L, B, ...)
# with the slot/batch axis at position 1, plus a "pos" leaf that is a scalar
# (lockstep batch) or a (B,) per-slot position vector. The serving engine
# relies on that convention to splice per-request prefill caches into the
# resident batched cache without touching other slots.

def vectorize_cache_pos(cache, batch: int):
    """Scalar-pos cache (init_cache output) -> per-slot (B,) position cache
    for the continuous-batching decode path."""
    pos = cache["pos"]
    if jnp.ndim(pos) == 0:
        cache = dict(cache, pos=jnp.full((batch,), pos, jnp.int32))
    return cache


def insert_cache_slot(cache, request_cache, slot):
    """Write a batch-1 request cache (a fresh prefill) into slot ``slot`` of a
    batched per-slot-pos serving cache — other slots' entries are untouched
    bit-for-bit. Thin wrapper over insert_cache_rows so there is exactly one
    implementation of the batch-axis splice. ``slot`` may be a traced scalar,
    so one jit covers every slot."""
    return insert_cache_rows(cache, request_cache,
                             jnp.reshape(jnp.asarray(slot, jnp.int32), (1,)))


def insert_cache_rows(cache, request_cache, slots):
    """Write a batch-K request cache (one joint prefill of K same-length
    prompts) into rows ``slots`` (a (K,) index vector) of a batched serving
    cache. Same isolation contract as insert_cache_slot: a scatter on the
    batch axis only."""
    slots = jnp.asarray(slots, jnp.int32)
    out = {}
    for key, leaf in cache.items():
        req = request_cache[key]
        if key == "pos":
            # prefill pos is a scalar (all K rows at prompt_len) or (K,)
            out[key] = leaf.at[slots].set(jnp.asarray(req, leaf.dtype))
        else:
            out[key] = leaf.at[:, slots].set(req.astype(leaf.dtype))
    return out


def extract_cache_slot(cache, slot: int):
    """Batch-1 view of one slot's cache entries (testing/debug helper)."""
    out = {}
    for key, leaf in cache.items():
        if key == "pos":
            out[key] = leaf if jnp.ndim(leaf) == 0 else leaf[slot]
        else:
            out[key] = leaf[:, slot:slot + 1]
    return out


def reduced_config(cfg: ArchConfig, **overrides) -> ArchConfig:
    """Smoke-test-sized config of the same family (small dims, same structure)."""
    defaults = dict(
        num_layers=2 if not cfg.cross_attn_every else cfg.cross_attn_every,
        d_model=64,
        num_heads=4, num_kv_heads=max(1, 4 * cfg.num_kv_heads // max(cfg.num_heads, 1)),
        d_ff=128, vocab_size=512, head_dim=16,
        encoder_layers=2 if cfg.encoder_layers else 0,
        num_image_tokens=8 if cfg.cross_attn_every else 0,
        window=8 if cfg.window else 0,
        ssm_state=cfg.ssm_state and 4,
    )
    if cfg.moe:
        from repro.configs.base import MoEConfig
        defaults["moe"] = MoEConfig(num_experts=4, top_k=2,
                                    capacity_factor=cfg.moe.capacity_factor)
    if cfg.cross_attn_every:
        defaults["num_layers"] = 2 * cfg.cross_attn_every  # 2 super-layers
    defaults.update(overrides)
    return dataclasses.replace(cfg, **defaults)
