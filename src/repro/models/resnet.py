"""ResNet20 / CIFAR-10 — the paper's evaluation model (Tensil ResNet20-ZCU104).

Convolutions are expressed two ways, mirroring how the Tensil systolic array
executes them:
  * ``conv_impl="lax"``    — jax.lax.conv_general_dilated (oracle / CPU-fast)
  * ``conv_impl="im2col"`` — explicit im2col + matmul, the exact lowering a
    32x32 (FPGA) / 128x128 (MXU) systolic array performs; this path can route
    through the Pallas systolic matmul kernel and is what the capacity planner
    partitions (stages x partitions, DESIGN.md C3/C4).

BatchNorm is folded at inference (``fold_bn``) exactly as Tensil's compiler does.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.resnet20_cifar import ResNetConfig


# ------------------------------------------------------------------ init
def _conv_init(key, k, cin, cout):
    fan_in = k * k * cin
    w = jax.random.normal(key, (k, k, cin, cout), jnp.float32)
    return w * jnp.sqrt(2.0 / fan_in)


def _bn_init(c):
    return {"scale": jnp.ones((c,), jnp.float32), "bias": jnp.zeros((c,), jnp.float32),
            "mean": jnp.zeros((c,), jnp.float32), "var": jnp.ones((c,), jnp.float32)}


def init(cfg: ResNetConfig, key):
    keys = iter(jax.random.split(key, 64))
    params = {"stem": {"w": _conv_init(next(keys), 3, cfg.in_channels, cfg.widths[0]),
                       "bn": _bn_init(cfg.widths[0])}}
    cin = cfg.widths[0]
    stages = []
    for si, (n, cout) in enumerate(zip(cfg.num_blocks, cfg.widths)):
        blocks = []
        for bi in range(n):
            stride = 2 if (si > 0 and bi == 0) else 1
            blk = {"conv1": {"w": _conv_init(next(keys), 3, cin, cout), "bn": _bn_init(cout)},
                   "conv2": {"w": _conv_init(next(keys), 3, cout, cout), "bn": _bn_init(cout)}}
            if stride != 1 or cin != cout:
                blk["proj"] = {"w": _conv_init(next(keys), 1, cin, cout), "bn": _bn_init(cout)}
            blocks.append(blk)
            cin = cout
        stages.append(blocks)
    params["stages"] = stages
    params["head"] = {"w": jax.random.normal(next(keys), (cin, cfg.num_classes),
                                             jnp.float32) * 0.01,
                      "b": jnp.zeros((cfg.num_classes,), jnp.float32)}
    return params


# ------------------------------------------------------------------ conv paths
def _im2col(x, k, stride, pad):
    """x: (B,H,W,C) -> patches (B, Ho, Wo, k*k*C)."""
    B, H, W, C = x.shape
    x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    Ho = (H + 2 * pad - k) // stride + 1
    Wo = (W + 2 * pad - k) // stride + 1
    cols = []
    for di in range(k):
        for dj in range(k):
            cols.append(jax.lax.slice(
                x, (0, di, dj, 0),
                (B, di + (Ho - 1) * stride + 1, dj + (Wo - 1) * stride + 1, C),
                (1, stride, stride, 1)))
    return jnp.concatenate(cols, axis=-1)


def conv2d(x, w, stride=1, impl="lax", matmul_fn=None):
    """x: (B,H,W,Cin), w: (k,k,Cin,Cout), SAME padding."""
    k = w.shape[0]
    pad = (k - 1) // 2
    if impl == "lax":
        return jax.lax.conv_general_dilated(
            x, w, (stride, stride), [(pad, pad), (pad, pad)],
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
    patches = _im2col(x, k, stride, pad)                   # (B,Ho,Wo,k*k*Cin)
    B, Ho, Wo, P = patches.shape
    wm = w.reshape(-1, w.shape[-1])                        # (k*k*Cin, Cout)
    lhs = patches.reshape(B * Ho * Wo, P)
    out = matmul_fn(lhs, wm) if matmul_fn is not None else lhs @ wm
    return out.reshape(B, Ho, Wo, w.shape[-1])


def _bn(x, p, eps=1e-5):
    inv = jax.lax.rsqrt(p["var"] + eps) * p["scale"]
    return x * inv + (p["bias"] - p["mean"] * inv)


def fold_bn(params):
    """Fold BN into conv weights (Tensil-compiler style) for inference."""
    def fold(conv):
        p = conv["bn"]
        inv = jax.lax.rsqrt(p["var"] + 1e-5) * p["scale"]
        w = conv["w"] * inv[None, None, None, :]
        b = p["bias"] - p["mean"] * inv
        return {"w": w, "b": b}
    out = {"stem": fold(params["stem"]), "head": params["head"], "stages": []}
    for blocks in params["stages"]:
        nb = []
        for blk in blocks:
            f = {"conv1": fold(blk["conv1"]), "conv2": fold(blk["conv2"])}
            if "proj" in blk:
                f["proj"] = fold(blk["proj"])
            nb.append(f)
        out["stages"].append(nb)
    return out


# ------------------------------------------------------------------ forward
def forward(params, cfg: ResNetConfig, images, *, folded=False, impl="lax",
            matmul_fn=None, compute_dtype=jnp.float32):
    """images: (B, H, W, C) -> logits (B, num_classes)."""
    conv = functools.partial(conv2d, impl=impl, matmul_fn=matmul_fn)
    x = images.astype(compute_dtype)

    def apply_cb(cb, x, stride):
        y = conv(x, cb["w"].astype(compute_dtype), stride)
        if folded:
            return y + cb["b"].astype(compute_dtype)
        return _bn(y, jax.tree.map(lambda t: t.astype(compute_dtype), cb["bn"]))

    x = jax.nn.relu(apply_cb(params["stem"], x, 1))
    for si, blocks in enumerate(params["stages"]):
        for bi, blk in enumerate(blocks):
            stride = 2 if (si > 0 and bi == 0) else 1   # derived, not a param
            h = jax.nn.relu(apply_cb(blk["conv1"], x, stride))
            h = apply_cb(blk["conv2"], h, 1)
            sc = apply_cb(blk["proj"], x, stride) if "proj" in blk else x
            x = jax.nn.relu(h + sc)
    x = x.mean(axis=(1, 2))
    return x @ params["head"]["w"].astype(compute_dtype) + params["head"]["b"].astype(compute_dtype)


def conv_layer_shapes(cfg: ResNetConfig, batch: int = 1):
    """(name, M, K, N) im2col GEMM dims per conv — input to the capacity planner
    (the paper's per-layer stage/partition table)."""
    shapes = []
    hw = cfg.image_size
    shapes.append(("stem", batch * hw * hw, 3 * 3 * cfg.in_channels, cfg.widths[0]))
    cin = cfg.widths[0]
    for si, (n, cout) in enumerate(zip(cfg.num_blocks, cfg.widths)):
        for bi in range(n):
            stride = 2 if (si > 0 and bi == 0) else 1
            hw_out = hw // stride
            shapes.append((f"s{si}b{bi}c1", batch * hw_out * hw_out, 3 * 3 * cin, cout))
            shapes.append((f"s{si}b{bi}c2", batch * hw_out * hw_out, 3 * 3 * cout, cout))
            if stride != 1 or cin != cout:
                shapes.append((f"s{si}b{bi}proj", batch * hw_out * hw_out, cin, cout))
            cin, hw = cout, hw_out
    return shapes
