"""Hymba — hybrid-head LM: each layer runs sliding-window GQA attention and a
selective-SSM (mamba-style, state=16) branch in parallel on the same input and
averages the normalized branch outputs (arXiv:2411.13676, simplified: meta
tokens omitted; windowed attention keeps long_500k sub-quadratic).

Decode uses a ring-buffer window KV cache (O(window), not O(seq)) + SSM state.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.sharding.specs import shard

DT_RANK = 64
CONV_K = 4


def _attn_dims(cfg: ArchConfig) -> L.AttnDims:
    return L.AttnDims(d_model=cfg.d_model, num_heads=cfg.num_heads,
                      num_kv_heads=cfg.num_kv_heads, head_dim=cfg.head_dim,
                      qkv_bias=False, window=cfg.window, rope_theta=cfg.rope_theta)


# ------------------------------------------------------------------ init
def _layer_init(key, cfg: ArchConfig):
    D, Nst = cfg.d_model, cfg.ssm_state
    ks = jax.random.split(key, 10)
    return {
        "ln1": L.norm_init(D, cfg.norm),
        "ln2": L.norm_init(D, cfg.norm),
        "attn": L.attn_init(ks[0], _attn_dims(cfg)),
        "attn_norm": L.norm_init(cfg.num_heads * cfg.head_dim, "rmsnorm"),
        "ssm_norm": L.norm_init(D, "rmsnorm"),
        "mlp": L.mlp_init(ks[1], D, cfg.d_ff, gated=True),
        # mamba branch
        "w_in": L._dense(ks[2], (D, D)),
        "w_out": L._dense(ks[3], (D, D)),
        "conv": L._dense(ks[4], (CONV_K, D)) * 0.1,
        "w_B": L._dense(ks[5], (D, Nst)),
        "w_C": L._dense(ks[6], (D, Nst)),
        "w_dtA": L._dense(ks[7], (D, DT_RANK)),
        "w_dtB": L._dense(ks[8], (DT_RANK, D)),
        "dt_bias": jnp.full((D,), -4.0, jnp.float32),
        "logA": jnp.zeros((D, Nst), jnp.float32),
        "d_skip": jnp.ones((D,), jnp.float32),
    }


def _layer_logical(cfg: ArchConfig):
    return {
        "ln1": L.norm_logical(cfg.norm), "ln2": L.norm_logical(cfg.norm),
        "attn": L.attn_logical(_attn_dims(cfg)),
        "attn_norm": L.norm_logical("rmsnorm"),
        "ssm_norm": L.norm_logical("rmsnorm"),
        "mlp": L.mlp_logical(gated=True),
        "w_in": ("fsdp", "d_ff"), "w_out": ("d_ff", "fsdp"),
        "conv": (None, "d_ff"),
        "w_B": ("fsdp", None), "w_C": ("fsdp", None),
        "w_dtA": ("fsdp", None), "w_dtB": (None, "d_ff"),
        "dt_bias": ("d_ff",), "logA": ("d_ff", None), "d_skip": ("d_ff",),
    }


def init(cfg: ArchConfig, key):
    k1, k2, k3 = jax.random.split(key, 3)
    keys = jax.random.split(k2, cfg.num_layers)
    return {
        "embed": L.embed_init(k1, cfg.padded_vocab, cfg.d_model),
        "layers": jax.vmap(lambda kk: _layer_init(kk, cfg))(keys),
        "final_norm": L.norm_init(cfg.d_model, cfg.norm),
        "unembed": {"w": L._dense(k3, (cfg.d_model, cfg.padded_vocab))},
    }


def param_logical(cfg: ArchConfig):
    def stacked(tree):
        return jax.tree.map(lambda ax: (None,) + ax, tree,
                            is_leaf=lambda v: isinstance(v, tuple))
    return {
        "embed": L.embed_logical(),
        "layers": stacked(_layer_logical(cfg)),
        "final_norm": L.norm_logical(cfg.norm),
        "unembed": {"w": ("fsdp", "vocab")},
    }


# ------------------------------------------------------------------ SSM branch
def _ssm_scan(xin, dt, B_t, C_t, A, h0):
    """Selective scan. xin,dt: (B,T,D); B_t,C_t: (B,T,N); A: (D,N); h0: (B,D,N)."""
    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        decay = jnp.exp(A[None] * dt_t[..., None])               # (B,D,N)
        h = decay * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (xin, dt, B_t, C_t))
    h, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h


def _ssm_scan_assoc(xin, dt, B_t, C_t, A, h0):
    """Parallel (associative-scan) selective scan: the recurrence
    ``h_t = a_t * h_{t-1} + b_t`` is associative under
    ``(a1,b1) ∘ (a2,b2) = (a1*a2, a2*b1 + b2)``, so all T states come out of
    a log-depth ``lax.associative_scan`` instead of a length-T sequential
    scan — the recurrent carry stops being the prefill's critical path
    (same loop-width lever as the attention chunk). Same signature/returns
    as ``_ssm_scan``; h0 folds into step 0's additive term."""
    a = jnp.exp(A[None, None] * dt[..., None])               # (B,T,D,N)
    b = (dt * xin)[..., None] * B_t[:, :, None, :]           # (B,T,D,N)
    b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, hs = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = jnp.einsum("btdn,btn->btd", hs, C_t)
    return y, hs[:, -1]


def _mamba_branch(lp, x, cfg: ArchConfig, state, impl: str = "scan"):
    Bsz, T, D = x.shape
    xin = x @ lp["w_in"].astype(x.dtype)
    xin = shard(xin, "batch", None, "d_ff")
    # depthwise causal conv over time (kernel CONV_K)
    conv_w = lp["conv"].astype(x.dtype)                          # (K, D)
    tail = (state["conv"].astype(x.dtype) if state is not None
            else jnp.zeros((Bsz, CONV_K - 1, D), x.dtype))
    xpad = jnp.concatenate([tail, xin], axis=1)
    xc = sum(xpad[:, i:i + T] * conv_w[i] for i in range(CONV_K))
    xc = jax.nn.silu(xc)

    f32 = jnp.float32
    dt = jax.nn.softplus((xc.astype(f32) @ lp["w_dtA"].astype(f32))
                         @ lp["w_dtB"].astype(f32) + lp["dt_bias"])
    B_t = xc.astype(f32) @ lp["w_B"].astype(f32)
    C_t = xc.astype(f32) @ lp["w_C"].astype(f32)
    A = -jnp.exp(lp["logA"].astype(f32))
    h0 = (state["h"].astype(f32) if state is not None
          else jnp.zeros((Bsz, D, cfg.ssm_state), f32))
    if impl == "pallas":
        from repro.kernels import ops as kops
        y, h = kops.selective_scan(xc.astype(f32), dt, B_t, C_t, A, h0)
    elif impl == "assoc":
        y, h = _ssm_scan_assoc(xc.astype(f32), dt, B_t, C_t, A, h0)
    else:
        y, h = _ssm_scan(xc.astype(f32), dt, B_t, C_t, A, h0)
    y = y + lp["d_skip"].astype(f32) * xc.astype(f32)
    out = y.astype(x.dtype) @ lp["w_out"].astype(x.dtype)
    new_state = {"h": h, "conv": xpad[:, -(CONV_K - 1):].astype(f32)}
    return out, new_state


def _layer_apply(cfg, lp, x, positions, attn_impl, ssm_impl="scan"):
    h = L.apply_norm(x, lp["ln1"], cfg.norm)
    a = L.attention(lp["attn"], h, _attn_dims(cfg), positions, impl=attn_impl)
    s, _ = _mamba_branch(lp, h, cfg, None, ssm_impl)
    a = L.rmsnorm(a, lp["attn_norm"]["scale"])
    s = L.rmsnorm(s, lp["ssm_norm"]["scale"])
    x = x + 0.5 * (a + s)
    x = shard(x, "batch", "seq_sp", None)
    h = L.apply_norm(x, lp["ln2"], cfg.norm)
    x = shard(x + L.mlp(lp["mlp"], h), "batch", "seq_sp", None)
    return x


# ------------------------------------------------------------------ public
def forward(params, cfg: ArchConfig, tokens, *, compute_dtype=jnp.bfloat16,
            attn_impl: str = "einsum", remat: bool = False, scan_impl: str = "scan",
            return_features: bool = False, **_):
    B, S = tokens.shape
    x = L.embed_lookup(params["embed"], tokens, compute_dtype)
    x = shard(x, "batch", "seq_sp", None)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(x, lp):
        return _layer_apply(cfg, lp, x, positions, attn_impl, scan_impl), jnp.zeros(())
    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = L.apply_norm(x, params["final_norm"], cfg.norm)
    if return_features:
        return x, {"moe_aux": jnp.zeros(()), "moe_z": jnp.zeros(())}
    logits = L.lm_logits(params["embed"], x, params["unembed"]["w"], vocab=cfg.vocab_size)
    return logits.astype(jnp.float32), {"moe_aux": jnp.zeros(()), "moe_z": jnp.zeros(())}


def init_cache(cfg: ArchConfig, batch: int, s_max: int, dtype=jnp.bfloat16):
    """Ring-buffer window KV cache + SSM state: O(window + state), not O(s_max)."""
    W = min(cfg.window, s_max)
    Lr, KV, hd, D = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    return {
        "k": jnp.zeros((Lr, batch, W, KV, hd), dtype),
        "v": jnp.zeros((Lr, batch, W, KV, hd), dtype),
        "slot_pos": jnp.full((Lr, batch, W), -1, jnp.int32),
        "h": jnp.zeros((Lr, batch, D, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((Lr, batch, CONV_K - 1, D), jnp.float32),
        "pos": jnp.zeros((), jnp.int32),
    }


def cache_logical(cfg: ArchConfig):
    return {"k": (None, "batch", None, "kv_heads", None),
            "v": (None, "batch", None, "kv_heads", None),
            "slot_pos": (None, "batch", None),
            "h": (None, "batch", "d_ff", None),
            "conv": (None, "batch", None, None),
            "pos": ()}


def _ring_sdpa(lp, h, q, ck, cv, valid, dims):
    """Masked attention over a ring/key view. q: (B,Sq,H,hd) as produced by
    ``L._qkv`` (an equivalent flat (B,Sq,H*hd) also works — (H, hd) and
    (KV, G, hd) are the same contiguous layout); ck/cv: (B,S,KV,hd);
    valid: (B,S) bool (decode: one query, mask shared) or (B,Sq,S)
    (prefill chunk: per-query mask). Shared by the dense ring path, the
    paged path, and the parallel prefill chunk so all three produce
    bit-identical outputs for equal views."""
    B, Sq = q.shape[0], q.shape[1]
    H, KV, hd = dims.num_heads, dims.num_kv_heads, dims.head_dim
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, ck.astype(q.dtype)) / math.sqrt(hd)
    if valid.ndim == 2:
        valid = valid[:, None, :]                        # (B,1,S): all queries
    scores = jnp.where(valid[:, None, None, :, :], scores.astype(jnp.float32),
                       L.mask_value(jnp.float32))
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, cv.astype(q.dtype)
                     ).reshape(B, Sq, H * hd)
    return out @ lp["attn"]["wo"].astype(h.dtype)


def _window_attn_decode(lp, h, cfg, ck, cv, slot_pos, pos, positions):
    """Decode attention over a ring-buffer window cache. ``pos`` is a scalar
    (lockstep batch) or a (B,) per-slot position vector (serving engine).
    Vector-pos writes from INACTIVE slots (pos >= layers.INACTIVE_POS — freed
    serving slots) are dropped, so a finished request's ring rows stay
    bit-stable while other slots keep decoding."""
    dims = _attn_dims(cfg)
    q, k, v = L._qkv(lp["attn"], h, dims, positions)
    W = ck.shape[1]
    B = q.shape[0]
    if jnp.ndim(pos) == 1:
        # per-slot ring-buffer writes: row b lands in ring slot pos[b] % W;
        # inactive rows are steered to index W and dropped by the scatter
        slot = jnp.where(pos < L.INACTIVE_POS, pos % W, W)
        b_idx = jnp.arange(B)
        ck = ck.at[b_idx, slot].set(k[:, 0].astype(ck.dtype), mode="drop")
        cv = cv.at[b_idx, slot].set(v[:, 0].astype(cv.dtype), mode="drop")
        slot_pos = slot_pos.at[b_idx, slot].set(pos, mode="drop")
        mask_pos = pos[:, None]                              # (B,1) -> (B,W)
    else:
        slot = pos % W
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), slot, axis=1)
        slot_pos = jax.lax.dynamic_update_slice_in_dim(
            slot_pos, jnp.broadcast_to(pos, slot_pos[:, :1].shape), slot, axis=1)
        mask_pos = pos
    valid = (slot_pos >= 0) & (slot_pos <= mask_pos) & \
        (slot_pos > mask_pos - cfg.window)
    out = _ring_sdpa(lp, h, q, ck, cv, valid, dims)
    return out, ck, cv, slot_pos


def _window_attn_decode_paged(lp, h, cfg, pool_k, pool_v, pool_spos,
                              block_tables, ring_w: int, pos, positions):
    """Paged ring-buffer decode attention: the ring's W rows live in shared
    pages reached through per-slot block tables (models/layers.py paged
    helpers). pool_k/v: (P, ps, KV, hd); pool_spos: (P, ps) absolute position
    per pool row (-1 = never written); pos: (B,) per-slot positions.

    The gathered view is exactly ``ring_w`` rows in ring order, so for equal
    page contents this is bit-identical to the dense ring path (_ring_sdpa is
    shared); rows of unallocated pages are masked out, matching the dense
    ring's never-written slot_pos == -1 rows."""
    dims = _attn_dims(cfg)
    q, k, v = L._qkv(lp["attn"], h, dims, positions)
    ps = pool_k.shape[1]

    # write: ring index pos % W -> page block_tables[b, idx // ps]
    ridx = jnp.where(pos < L.INACTIVE_POS, pos % ring_w, 0)
    w_row, page_ok = L.paged_write_target(block_tables, ridx, ps)
    w_ok = (pos < L.INACTIVE_POS) & page_ok
    pool_k = L.paged_write_rows(pool_k, k[:, 0], w_row, w_ok)
    pool_v = L.paged_write_rows(pool_v, v[:, 0], w_row, w_ok)
    pool_spos = L.paged_write_rows(pool_spos, pos, w_row, w_ok)

    # read: gather the W-row ring view through the block table
    phys, ok = L.paged_row_indices(block_tables, ps, ring_w)
    KV, hd = dims.num_kv_heads, dims.head_dim
    view_k = pool_k.reshape(-1, KV, hd)[phys]        # (B, W, KV, hd)
    view_v = pool_v.reshape(-1, KV, hd)[phys]
    spos = jnp.where(ok, pool_spos.reshape(-1)[phys], -1)
    mask_pos = pos[:, None]
    valid = (spos >= 0) & (spos <= mask_pos) & (spos > mask_pos - cfg.window)
    out = _ring_sdpa(lp, h, q, view_k, view_v, valid, dims)
    return out, pool_k, pool_v, pool_spos


# ------------------------------------------------------- parallel prefill
def _window_attn_prefill_chunk(lp, h, cfg, ck, cv, slot_pos, positions,
                               use_kernel: bool):
    """Chunk-wide windowed attention against the ring cache: all C queries
    attend jointly over [pre-chunk ring rows (validity from slot_pos), the
    chunk's own K/V (causal + window)], then the chunk's LAST min(C, W)
    positions — exactly the rows a sequential ring write would leave behind
    — are scattered into the ring. ``use_kernel`` (first chunk only: the
    pre-ring is empty, so chunk-local causal+window IS the full mask) routes
    through the K/V-exporting flash kernel."""
    dims = _attn_dims(cfg)
    q, k, v = L._qkv(lp["attn"], h, dims, positions)         # (B,C,·)
    B, C = q.shape[:2]
    W = ck.shape[1]
    if use_kernel:
        from repro.kernels import ops as kops
        out, k, v = kops.flash_prefill(q, k, v, causal=True,
                                       window=cfg.window)
        out = out.reshape(B, C, -1) @ lp["attn"]["wo"].astype(h.dtype)
    else:
        keys = jnp.concatenate([ck.astype(q.dtype), k], axis=1)   # (B,W+C,·)
        vals = jnp.concatenate([cv.astype(q.dtype), v], axis=1)
        kp = jnp.concatenate([slot_pos, positions], axis=1)       # (B,W+C)
        qp = positions[:, :, None]
        valid = (kp[:, None, :] >= 0) & (kp[:, None, :] <= qp) & \
            (kp[:, None, :] > qp - cfg.window)                    # (B,C,W+C)
        out = _ring_sdpa(lp, h, q, keys, vals, valid, dims)
    # ring write: the last min(C, W) chunk positions have distinct ring
    # slots and are exactly the survivors of C sequential modular writes
    nw = min(C, W)
    tail_pos = positions[:, C - nw:]                              # (B,nw)
    ridx = tail_pos % W
    b_idx = jnp.arange(B)[:, None]
    ck = ck.at[b_idx, ridx].set(k[:, C - nw:].astype(ck.dtype))
    cv = cv.at[b_idx, ridx].set(v[:, C - nw:].astype(cv.dtype))
    slot_pos = slot_pos.at[b_idx, ridx].set(tail_pos)
    return out, ck, cv, slot_pos


def _prefill_chunk_layer(cfg, lp, x, ck, cv, sp, hst, conv, positions,
                         use_kernel):
    """One hybrid layer over a whole prompt chunk: windowed ring attention at
    chunk width + the mamba branch with its recurrent carry computed by the
    parallel associative scan. Mirrors ``_decode_layer``'s residual math."""
    h = L.apply_norm(x, lp["ln1"], cfg.norm)
    a, ck, cv, sp = _window_attn_prefill_chunk(lp, h, cfg, ck, cv, sp,
                                               positions, use_kernel)
    s, st = _mamba_branch(lp, h, cfg, {"h": hst, "conv": conv}, "assoc")
    a = L.rmsnorm(a, lp["attn_norm"]["scale"])
    s = L.rmsnorm(s, lp["ssm_norm"]["scale"])
    x = x + 0.5 * (a + s)
    h = L.apply_norm(x, lp["ln2"], cfg.norm)
    x = x + L.mlp(lp["mlp"], h)
    return x, ck, cv, sp, st["h"], st["conv"]


def prefill_chunk(params, cfg: ArchConfig, tokens, cache, *,
                  compute_dtype=jnp.bfloat16, attn_impl: str = "einsum",
                  first: bool = False, **_):
    """Matmul-wide parallel prefill over one prompt chunk (hybrid family):
    the attention branch runs chunk-wide against the ring, the selective-SSM
    carry comes out of a log-depth associative scan, and the ring + recurrent
    state land in the request cache exactly as C sequential ``decode_step``
    calls would have left them. Returns (last logits (B,1,Vp), cache)."""
    B, C = tokens.shape
    start = jnp.zeros((), jnp.int32) if first else cache["pos"]
    positions = start + jnp.broadcast_to(jnp.arange(C, dtype=jnp.int32), (B, C))
    use_kernel = first and attn_impl == "pallas"
    x = L.embed_lookup(params["embed"], tokens, compute_dtype)

    def body(x, xs):
        lp, ck, cv, sp, hst, conv = xs
        x, ck, cv, sp, hh, cc = _prefill_chunk_layer(
            cfg, lp, x, ck, cv, sp, hst, conv, positions, use_kernel)
        return x, (ck, cv, sp, hh, cc)

    x, (ck, cv, sp, hst, conv) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"], cache["slot_pos"],
                  cache["h"], cache["conv"]))
    x = L.apply_norm(x[:, -1:], params["final_norm"], cfg.norm)
    logits = L.lm_logits(params["embed"], x, params["unembed"]["w"],
                         vocab=cfg.vocab_size)
    return logits.astype(jnp.float32), dict(cache, k=ck, v=cv, slot_pos=sp,
                                            h=hst, conv=conv, pos=start + C)


def _decode_layer(cfg, lp, x, ck, cv, sp, hst, conv, pos, positions,
                  block_tables=None, ring_w: int = 0):
    """One hybrid decode layer (windowed ring-buffer attention + SSM state).
    Exposed for roofline probes. With ``block_tables``, ck/cv/sp are one
    layer's page-pool slices and attention uses the paged ring path."""
    h = L.apply_norm(x, lp["ln1"], cfg.norm)
    if block_tables is not None:
        a, ck, cv, sp = _window_attn_decode_paged(
            lp, h, cfg, ck, cv, sp, block_tables, ring_w, pos, positions)
    else:
        a, ck, cv, sp = _window_attn_decode(lp, h, cfg, ck, cv, sp, pos,
                                            positions)
    # freed serving slots keep their recurrent h/conv bit-for-bit
    s, st = _mamba_branch(lp, h, cfg, {"h": hst, "conv": conv})
    st = L.freeze_inactive_rows(pos, st, {"h": hst, "conv": conv})
    a = L.rmsnorm(a, lp["attn_norm"]["scale"])
    s = L.rmsnorm(s, lp["ssm_norm"]["scale"])
    x = x + 0.5 * (a + s)
    h = L.apply_norm(x, lp["ln2"], cfg.norm)
    x = x + L.mlp(lp["mlp"], h)
    return x, ck, cv, sp, st["h"], st["conv"]


def decode_step(params, cfg: ArchConfig, token, cache, *, compute_dtype=jnp.bfloat16,
                **_):
    B = token.shape[0]
    pos = cache["pos"]
    bt = cache.get("block_tables")
    # paged caches carry a (W,) iota leaf whose SHAPE is the ring width — the
    # one static the paged ring path needs that pool shapes cannot express
    ring_w = cache["ring_iota"].shape[0] if bt is not None else 0
    positions = L.decode_positions(pos, B)
    x = L.embed_lookup(params["embed"], token, compute_dtype)

    def body(x, xs):
        lp, ck, cv, sp, hst, conv = xs
        x, ck, cv, sp, hh, cc = _decode_layer(cfg, lp, x, ck, cv, sp, hst, conv,
                                              pos, positions, bt, ring_w)
        return x, (ck, cv, sp, hh, cc)

    x, (ck, cv, sp, hst, conv) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"], cache["slot_pos"],
                  cache["h"], cache["conv"]))
    x = L.apply_norm(x, params["final_norm"], cfg.norm)
    logits = L.lm_logits(params["embed"], x, params["unembed"]["w"], vocab=cfg.vocab_size)
    new_cache = dict(cache, k=ck, v=cv, slot_pos=sp, h=hst, conv=conv,
                     pos=pos + 1)
    return logits.astype(jnp.float32), new_cache
