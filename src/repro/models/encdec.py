"""Whisper-large-v3-style encoder-decoder. The conv/mel frontend is a STUB per
the assignment: ``input_specs()`` provides precomputed frame embeddings
(B, S_enc, d_model). Encoder: bidirectional attention + GELU MLP + learned
positions. Decoder: causal self-attn + cross-attn to encoder states.

Shape-cell convention (DESIGN.md): decoder length = the cell's seq_len;
encoder length = ENC_LEN (1500, whisper's 30 s window).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.sharding.specs import shard

ENC_LEN = 1500


def _self_dims(cfg: ArchConfig, causal: bool) -> L.AttnDims:
    return L.AttnDims(d_model=cfg.d_model, num_heads=cfg.num_heads,
                      num_kv_heads=cfg.num_kv_heads, head_dim=cfg.head_dim,
                      qkv_bias=True, rope_theta=0.0, causal=causal)


def _enc_layer_init(key, cfg):
    ks = jax.random.split(key, 2)
    return {"ln1": L.norm_init(cfg.d_model, "layernorm"),
            "attn": L.attn_init(ks[0], _self_dims(cfg, causal=False)),
            "ln2": L.norm_init(cfg.d_model, "layernorm"),
            "mlp": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, gated=False, bias=True)}


def _dec_layer_init(key, cfg):
    ks = jax.random.split(key, 3)
    return {"ln1": L.norm_init(cfg.d_model, "layernorm"),
            "attn": L.attn_init(ks[0], _self_dims(cfg, causal=True)),
            "ln_x": L.norm_init(cfg.d_model, "layernorm"),
            "xattn": L.attn_init(ks[1], _self_dims(cfg, causal=False)),
            "ln2": L.norm_init(cfg.d_model, "layernorm"),
            "mlp": L.mlp_init(ks[2], cfg.d_model, cfg.d_ff, gated=False, bias=True)}


def _enc_layer_logical(cfg):
    return {"ln1": L.norm_logical("layernorm"),
            "attn": L.attn_logical(_self_dims(cfg, False)),
            "ln2": L.norm_logical("layernorm"),
            "mlp": L.mlp_logical(gated=False, bias=True)}


def _dec_layer_logical(cfg):
    return {"ln1": L.norm_logical("layernorm"),
            "attn": L.attn_logical(_self_dims(cfg, True)),
            "ln_x": L.norm_logical("layernorm"),
            "xattn": L.attn_logical(_self_dims(cfg, False)),
            "ln2": L.norm_logical("layernorm"),
            "mlp": L.mlp_logical(gated=False, bias=True)}


def init(cfg: ArchConfig, key):
    ks = jax.random.split(key, 6)
    enc_keys = jax.random.split(ks[0], cfg.encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.num_layers)
    return {
        "embed": L.embed_init(ks[2], cfg.padded_vocab, cfg.d_model),
        "pos_dec": jax.random.normal(ks[3], (8192, cfg.d_model), jnp.float32) * 0.01,
        "enc_layers": jax.vmap(lambda kk: _enc_layer_init(kk, cfg))(enc_keys),
        "enc_norm": L.norm_init(cfg.d_model, "layernorm"),
        "dec_layers": jax.vmap(lambda kk: _dec_layer_init(kk, cfg))(dec_keys),
        "final_norm": L.norm_init(cfg.d_model, "layernorm"),
    }


def param_logical(cfg: ArchConfig):
    def stacked(tree):
        return jax.tree.map(lambda ax: (None,) + ax, tree,
                            is_leaf=lambda v: isinstance(v, tuple))
    return {
        "embed": L.embed_logical(),
        "pos_dec": (None, "fsdp"),
        "enc_layers": stacked(_enc_layer_logical(cfg)),
        "enc_norm": L.norm_logical("layernorm"),
        "dec_layers": stacked(_dec_layer_logical(cfg)),
        "final_norm": L.norm_logical("layernorm"),
    }


def _sinusoid(s, d):
    pos = jnp.arange(s, dtype=jnp.float32)[:, None]
    i = jnp.arange(d // 2, dtype=jnp.float32)[None]
    ang = pos / jnp.power(10000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def encode(params, cfg: ArchConfig, frames, *, compute_dtype=jnp.bfloat16,
           attn_impl="einsum", remat=False):
    """frames: (B, S_enc, D) precomputed frame embeddings (stub frontend)."""
    B, S, _ = frames.shape
    x = frames.astype(compute_dtype) + _sinusoid(S, cfg.d_model).astype(compute_dtype)
    x = shard(x, "batch", "seq_sp", None)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(x, lp):
        return _enc_layer(cfg, lp, x, positions, attn_impl), None
    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.apply_norm(x, params["enc_norm"], "layernorm")


def _enc_layer(cfg, lp, x, positions, attn_impl):
    h = L.apply_norm(x, lp["ln1"], "layernorm")
    x = x + L.attention(lp["attn"], h, _self_dims(cfg, False), positions,
                        impl=attn_impl)
    h = L.apply_norm(x, lp["ln2"], "layernorm")
    return shard(x + L.mlp(lp["mlp"], h, act="gelu"), "batch", "seq_sp", None)


def _dec_layer(cfg, lp, x, positions, enc_out, enc_pos, attn_impl):
    h = L.apply_norm(x, lp["ln1"], "layernorm")
    x = x + L.attention(lp["attn"], h, _self_dims(cfg, True), positions,
                        impl=attn_impl)
    h = L.apply_norm(x, lp["ln_x"], "layernorm")
    dims = _self_dims(cfg, False)
    B, Se, _ = enc_out.shape
    k = (enc_out @ lp["xattn"]["wk"].astype(x.dtype)
         + lp["xattn"]["bk"].astype(x.dtype)).reshape(B, Se, dims.num_kv_heads, dims.head_dim)
    v = (enc_out @ lp["xattn"]["wv"].astype(x.dtype)
         + lp["xattn"]["bv"].astype(x.dtype)).reshape(B, Se, dims.num_kv_heads, dims.head_dim)
    x = x + L.attention(lp["xattn"], h, dims, positions, impl="einsum",
                        kv_override=(k, v, enc_pos))
    h = L.apply_norm(x, lp["ln2"], "layernorm")
    x = shard(x + L.mlp(lp["mlp"], h, act="gelu"), "batch", "seq_sp", None)
    return x


def forward(params, cfg: ArchConfig, tokens, *, frames=None,
            compute_dtype=jnp.bfloat16, attn_impl="einsum", remat=False,
            return_features: bool = False, **_):
    """tokens: (B, S_dec); frames: (B, S_enc, D). Returns decoder logits."""
    B, S = tokens.shape
    if frames is None:
        frames = jnp.zeros((B, ENC_LEN, cfg.d_model), compute_dtype)
    enc_out = encode(params, cfg, frames, compute_dtype=compute_dtype,
                     attn_impl=attn_impl, remat=remat)
    enc_pos = jnp.broadcast_to(jnp.arange(enc_out.shape[1], dtype=jnp.int32),
                               (B, enc_out.shape[1]))
    x = L.embed_lookup(params["embed"], tokens, compute_dtype)
    pos_emb = jax.lax.dynamic_slice_in_dim(params["pos_dec"], 0, min(S, 8192), axis=0)
    if S > 8192:  # tile learned positions beyond table (structural stand-in)
        reps = -(-S // 8192)
        pos_emb = jnp.tile(pos_emb, (reps, 1))[:S]
    x = x + pos_emb.astype(compute_dtype)[None]
    x = shard(x, "batch", "seq_sp", None)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(x, lp):
        return _dec_layer(cfg, lp, x, positions, enc_out, enc_pos, attn_impl), None
    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = L.apply_norm(x, params["final_norm"], "layernorm")
    if return_features:
        return x, {"moe_aux": jnp.zeros(()), "moe_z": jnp.zeros(())}
    logits = L.lm_logits(params["embed"], x, None, vocab=cfg.vocab_size)  # tied embeddings
    return logits.astype(jnp.float32), {"moe_aux": jnp.zeros(()), "moe_z": jnp.zeros(())}


# ------------------------------------------------------------------ decode
def init_cache(cfg: ArchConfig, batch: int, s_max: int, dtype=jnp.bfloat16):
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    Lr = cfg.num_layers
    return {
        "k": jnp.zeros((Lr, batch, s_max, kv, hd), dtype),
        "v": jnp.zeros((Lr, batch, s_max, kv, hd), dtype),
        # cross-attn K/V precomputed once from encoder output at prefill time
        "xk": jnp.zeros((Lr, batch, ENC_LEN, kv, hd), dtype),
        "xv": jnp.zeros((Lr, batch, ENC_LEN, kv, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def cache_logical(cfg: ArchConfig):
    from repro.sharding import specs as _sp
    if cfg.num_kv_heads % max(_sp.axis_size("kv_heads"), 1) == 0:
        kv = (None, "batch", None, "kv_heads", None)
        xkv = (None, "batch", None, "kv_heads", None)
    else:
        kv = (None, "batch", "seq_sp", None, None)
        xkv = (None, "batch", "seq_sp", None, None)
    return {"k": kv, "v": kv, "xk": xkv, "xv": xkv, "pos": ()}


def precompute_cross_kv(params, cfg: ArchConfig, enc_out):
    """(L, B, S_enc, KV, hd) cross K/V from encoder output."""
    dims = _self_dims(cfg, False)
    B, Se, _ = enc_out.shape

    def per_layer(lp):
        k = (enc_out @ lp["xattn"]["wk"].astype(enc_out.dtype)
             + lp["xattn"]["bk"].astype(enc_out.dtype))
        v = (enc_out @ lp["xattn"]["wv"].astype(enc_out.dtype)
             + lp["xattn"]["bv"].astype(enc_out.dtype))
        return (k.reshape(B, Se, dims.num_kv_heads, dims.head_dim),
                v.reshape(B, Se, dims.num_kv_heads, dims.head_dim))
    return jax.lax.map(per_layer, params["dec_layers"])


def _decode_layer(cfg, lp, x, ck, cv, xk, xv, pos, positions, enc_pos,
                  block_tables=None, paged_impl: str = "einsum"):
    """One decoder decode layer (self-attn against cache + cross-attn).
    Exposed for roofline probes. With ``block_tables``, ck/cv are one layer's
    (P, ps, KV, hd) page-pool slices (paged self-attn KV; the cross-attn
    xk/xv stay dense per slot — they are written once at prefill and fixed
    at ENC_LEN, so paging buys nothing); ``paged_impl`` selects the Pallas
    block-gather kernel or the masked-einsum reference read."""
    h = L.apply_norm(x, lp["ln1"], "layernorm")
    if block_tables is not None:
        out, ck, cv = L.attention_decode_paged(
            lp["attn"], h, _self_dims(cfg, True), ck, cv, block_tables, pos,
            positions, impl=paged_impl)
    else:
        out, ck, cv = L.attention_decode(lp["attn"], h, _self_dims(cfg, True),
                                         ck, cv, pos, positions)
    x = x + out
    h = L.apply_norm(x, lp["ln_x"], "layernorm")
    x = x + L.attention(lp["xattn"], h, _self_dims(cfg, False), positions,
                        impl="einsum", kv_override=(xk.astype(h.dtype),
                                                    xv.astype(h.dtype), enc_pos))
    h = L.apply_norm(x, lp["ln2"], "layernorm")
    x = x + L.mlp(lp["mlp"], h, act="gelu")
    return x, ck, cv


# ------------------------------------------------------- parallel prefill
def _prefill_chunk_dec_layer(cfg, lp, x, ck, cv, xk, xv, start, positions,
                             enc_pos, use_kernel):
    """One decoder layer over a whole prompt chunk: chunk-wide causal
    self-attention against the request cache plus full-width cross-attention
    to the precomputed encoder K/V. Mirrors ``_decode_layer``'s math."""
    h = L.apply_norm(x, lp["ln1"], "layernorm")
    out, ck, cv = L.attention_prefill_chunk(lp["attn"], h,
                                            _self_dims(cfg, True), ck, cv,
                                            start, positions,
                                            use_kernel=use_kernel)
    x = x + out
    h = L.apply_norm(x, lp["ln_x"], "layernorm")
    x = x + L.attention(lp["xattn"], h, _self_dims(cfg, False), positions,
                        impl="einsum", kv_override=(xk.astype(h.dtype),
                                                    xv.astype(h.dtype),
                                                    enc_pos))
    h = L.apply_norm(x, lp["ln2"], "layernorm")
    x = x + L.mlp(lp["mlp"], h, act="gelu")
    return x, ck, cv


def prefill_chunk(params, cfg: ArchConfig, tokens, cache, *,
                  compute_dtype=jnp.bfloat16, attn_impl: str = "einsum",
                  first: bool = False, **_):
    """Matmul-wide parallel prefill over one decoder prompt chunk. The cache
    must already carry the encoder cross K/V (``xk``/``xv`` — precomputed
    exactly once by the first-chunk builder in launch/steps.py, same as the
    scan prefill). Returns (last logits (B,1,Vp), cache with pos += C)."""
    B, C = tokens.shape
    start = jnp.zeros((), jnp.int32) if first else cache["pos"]
    positions = start + jnp.broadcast_to(jnp.arange(C, dtype=jnp.int32), (B, C))
    use_kernel = first and attn_impl == "pallas"
    x_pos = params["pos_dec"][jnp.minimum(positions, 8191)].astype(compute_dtype)
    x = L.embed_lookup(params["embed"], tokens, compute_dtype) + x_pos
    Se = cache["xk"].shape[2]
    enc_pos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), (B, Se))

    def body(i, carry):
        x, ck_all, cv_all = carry
        lp = jax.tree.map(
            lambda t: jax.lax.dynamic_index_in_dim(t, i, 0, keepdims=False),
            params["dec_layers"])
        ck = jax.lax.dynamic_index_in_dim(ck_all, i, 0, keepdims=False)
        cv = jax.lax.dynamic_index_in_dim(cv_all, i, 0, keepdims=False)
        xk = jax.lax.dynamic_index_in_dim(cache["xk"], i, 0, keepdims=False)
        xv = jax.lax.dynamic_index_in_dim(cache["xv"], i, 0, keepdims=False)
        x, ck, cv = _prefill_chunk_dec_layer(cfg, lp, x, ck, cv, xk, xv,
                                             start, positions, enc_pos,
                                             use_kernel)
        ck_all = jax.lax.dynamic_update_index_in_dim(ck_all, ck, i, 0)
        cv_all = jax.lax.dynamic_update_index_in_dim(cv_all, cv, i, 0)
        return x, ck_all, cv_all

    x, ck, cv = jax.lax.fori_loop(0, cfg.num_layers, body,
                                  (x, cache["k"], cache["v"]))
    x = L.apply_norm(x[:, -1:], params["final_norm"], "layernorm")
    logits = L.lm_logits(params["embed"], x, None, vocab=cfg.vocab_size)
    return logits.astype(jnp.float32), dict(cache, k=ck, v=cv, pos=start + C)


def decode_step(params, cfg: ArchConfig, token, cache, *, compute_dtype=jnp.bfloat16,
                paged_attn_impl: str = "einsum", **_):
    B = token.shape[0]
    pos = cache["pos"]
    bt = cache.get("block_tables")
    positions = L.decode_positions(pos, B)
    # learned decoder position embedding, per-row: (B,1) -> (B,1,D)
    x_pos = params["pos_dec"][jnp.minimum(positions, 8191)].astype(compute_dtype)
    x = L.embed_lookup(params["embed"], token, compute_dtype)
    x = x + x_pos
    Se = cache["xk"].shape[2]
    enc_pos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), (B, Se))

    def body(i, carry):
        x, ck_all, cv_all = carry
        lp = jax.tree.map(
            lambda t: jax.lax.dynamic_index_in_dim(t, i, 0, keepdims=False),
            params["dec_layers"])
        ck = jax.lax.dynamic_index_in_dim(ck_all, i, 0, keepdims=False)
        cv = jax.lax.dynamic_index_in_dim(cv_all, i, 0, keepdims=False)
        xk = jax.lax.dynamic_index_in_dim(cache["xk"], i, 0, keepdims=False)
        xv = jax.lax.dynamic_index_in_dim(cache["xv"], i, 0, keepdims=False)
        x, ck, cv = _decode_layer(cfg, lp, x, ck, cv, xk, xv, pos, positions,
                                  enc_pos, bt, paged_attn_impl)
        ck_all = jax.lax.dynamic_update_index_in_dim(ck_all, ck, i, 0)
        cv_all = jax.lax.dynamic_update_index_in_dim(cv_all, cv, i, 0)
        return x, ck_all, cv_all

    x, ck, cv = jax.lax.fori_loop(0, cfg.num_layers, body,
                                  (x, cache["k"], cache["v"]))
    x = L.apply_norm(x, params["final_norm"], "layernorm")
    logits = L.lm_logits(params["embed"], x, None, vocab=cfg.vocab_size)
    new_cache = dict(cache, k=ck, v=cv, pos=pos + 1)
    return logits.astype(jnp.float32), new_cache
