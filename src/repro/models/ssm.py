"""RWKV6 "Finch" — attention-free LM with data-dependent per-channel decay.

Time-mix:  y_t = r_t · (S_{t-1} + diag(u) k_t v_tᵀ),  S_t = diag(w_t) S_{t-1} + k_t v_tᵀ
with w_t = exp(-exp(w0 + lora_w(x_w))) (data-dependent decay) and DDLerp token-shift
mixing for r/k/v/w/g. Channel-mix: squared-relu MLP with token shift.

Heads: cfg.num_heads x head_dim (64). State per layer: (B, H, N, N).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.sharding.specs import shard

LORA_R = 32
DECAY_R = 64


def _shift(x):
    """Token shift: x_{t-1} (zeros at t=0). x: (B, T, D)."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


# ------------------------------------------------------------------ init
def _layer_init(key, cfg: ArchConfig):
    D, F = cfg.d_model, cfg.d_ff
    H, N = cfg.num_heads, cfg.head_dim
    ks = jax.random.split(key, 12)
    return {
        "ln1": L.norm_init(D, "layernorm"),
        "ln2": L.norm_init(D, "layernorm"),
        # DDLerp mixing params: mu_x plus one per stream (r,k,v,w,g)
        "mu_x": jnp.zeros((D,), jnp.float32),
        "mu": jnp.zeros((5, D), jnp.float32),
        "lora_A": L._dense(ks[0], (5, D, LORA_R)),
        "lora_B": jnp.zeros((5, LORA_R, D), jnp.float32),
        # decay
        "w0": jnp.full((D,), -2.0, jnp.float32),
        "wA": L._dense(ks[1], (D, DECAY_R)),
        "wB": jnp.zeros((DECAY_R, D), jnp.float32),
        "u": jnp.zeros((H, N), jnp.float32),  # bonus
        "wr": L._dense(ks[2], (D, D)),
        "wk": L._dense(ks[3], (D, D)),
        "wv": L._dense(ks[4], (D, D)),
        "wg": L._dense(ks[5], (D, D)),
        "wo": L._dense(ks[6], (D, D)),
        "gn_scale": jnp.ones((H, N), jnp.float32),
        # channel mix
        "cmu_k": jnp.zeros((D,), jnp.float32),
        "cmu_r": jnp.zeros((D,), jnp.float32),
        "ck": L._dense(ks[7], (D, F)),
        "cv": L._dense(ks[8], (F, D), scale_dim=F),
        "cr": L._dense(ks[9], (D, D)),
    }


def _layer_logical(cfg: ArchConfig):
    return {
        "ln1": L.norm_logical("layernorm"), "ln2": L.norm_logical("layernorm"),
        "mu_x": (None,), "mu": (None, None),
        "lora_A": (None, "fsdp", None), "lora_B": (None, None, "fsdp"),
        "w0": (None,), "wA": ("fsdp", None), "wB": (None, "fsdp"),
        "u": ("heads", None),
        "wr": ("fsdp", "heads"), "wk": ("fsdp", "heads"), "wv": ("fsdp", "heads"),
        "wg": ("fsdp", "heads"), "wo": ("heads", "fsdp"),
        "gn_scale": ("heads", None),
        "cmu_k": (None,), "cmu_r": (None,),
        "ck": ("fsdp", "d_ff"), "cv": ("d_ff", "fsdp"), "cr": ("fsdp", None),
    }


def init(cfg: ArchConfig, key):
    k1, k2, k3 = jax.random.split(key, 3)
    keys = jax.random.split(k2, cfg.num_layers)
    return {
        "embed": L.embed_init(k1, cfg.padded_vocab, cfg.d_model),
        "layers": jax.vmap(lambda kk: _layer_init(kk, cfg))(keys),
        "final_norm": L.norm_init(cfg.d_model, "layernorm"),
        "unembed": {"w": L._dense(k3, (cfg.d_model, cfg.padded_vocab))},
    }


def param_logical(cfg: ArchConfig):
    def stacked(tree):
        return jax.tree.map(lambda ax: (None,) + ax, tree,
                            is_leaf=lambda v: isinstance(v, tuple))
    return {
        "embed": L.embed_logical(),
        "layers": stacked(_layer_logical(cfg)),
        "final_norm": L.norm_logical("layernorm"),
        "unembed": {"w": ("fsdp", "vocab")},
    }


# ------------------------------------------------------------------ time-mix
def _ddlerp(lp, x, xprev):
    """Data-dependent lerp producing (x_r, x_k, x_v, x_w, x_g)."""
    xx = xprev - x
    base = x + xx * lp["mu_x"].astype(x.dtype)
    lo = jnp.einsum("btd,sdr->sbtr", jnp.tanh(base), lp["lora_A"].astype(x.dtype))
    lo = jnp.einsum("sbtr,srd->sbtd", lo, lp["lora_B"].astype(x.dtype))
    mix = lp["mu"].astype(x.dtype)[:, None, None, :] + lo        # (5,B,T,D)
    return x[None] + xx[None] * mix


def _wkv_scan(r, k, v, w, u, state):
    """Sequential reference recurrence.
    r,k,v,w: (B,T,H,N); u: (H,N); state: (B,H,N,N) -> (y (B,T,H,N), state)."""
    def step(S, inp):
        rt, kt, vt, wt = inp  # (B,H,N)
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        y = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * kv)
        S = wt[..., None] * S + kv
        return S, y
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    state, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1), state


def _time_mix(lp, x, cfg: ArchConfig, state, impl: str = "scan"):
    B, T, D = x.shape
    H, N = cfg.num_heads, cfg.head_dim
    xprev = _shift(x)
    if state is not None and "x_tm" in state:
        xprev = xprev.at[:, 0].set(state["x_tm"].astype(x.dtype))
    xs = _ddlerp(lp, x, xprev)
    x_r, x_k, x_v, x_w, x_g = xs[0], xs[1], xs[2], xs[3], xs[4]
    r = (x_r @ lp["wr"].astype(x.dtype)).reshape(B, T, H, N)
    k = (x_k @ lp["wk"].astype(x.dtype)).reshape(B, T, H, N)
    v = (x_v @ lp["wv"].astype(x.dtype)).reshape(B, T, H, N)
    g = jax.nn.silu(x_g @ lp["wg"].astype(x.dtype))
    r = shard(r, "batch", None, "heads", None)
    k = shard(k, "batch", None, "heads", None)
    v = shard(v, "batch", None, "heads", None)
    dec = (lp["w0"].astype(jnp.float32)
           + jnp.tanh(x_w.astype(jnp.float32) @ lp["wA"].astype(jnp.float32))
           @ lp["wB"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(dec)).reshape(B, T, H, N).astype(x.dtype)

    S0 = (state["S"].astype(jnp.float32) if state is not None
          else jnp.zeros((B, H, N, N), jnp.float32))
    if impl == "pallas":
        from repro.kernels import ops as kops
        y, S = kops.wkv6(r, k, v, w, lp["u"].astype(x.dtype), S0)
    else:
        y, S = _wkv_scan(r.astype(jnp.float32), k.astype(jnp.float32),
                         v.astype(jnp.float32), w.astype(jnp.float32),
                         lp["u"].astype(jnp.float32), S0)
    # per-head groupnorm
    mu = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    y = (y - mu) * jax.lax.rsqrt(var + 1e-5) * lp["gn_scale"].astype(jnp.float32)[None, None]
    y = (y.reshape(B, T, D).astype(x.dtype)) * g
    out = y @ lp["wo"].astype(x.dtype)
    new_state = {"S": S, "x_tm": x[:, -1].astype(jnp.float32)}
    return out, new_state


def _channel_mix(lp, x, state):
    xprev = _shift(x)
    if state is not None and "x_cm" in state:
        xprev = xprev.at[:, 0].set(state["x_cm"].astype(x.dtype))
    xx = xprev - x
    xk = x + xx * lp["cmu_k"].astype(x.dtype)
    xr = x + xx * lp["cmu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(xk @ lp["ck"].astype(x.dtype)))
    k = shard(k, "batch", None, "d_ff")
    r = jax.nn.sigmoid(xr @ lp["cr"].astype(x.dtype))
    out = r * (k @ lp["cv"].astype(x.dtype))
    return out, {"x_cm": x[:, -1].astype(jnp.float32)}


def _layer_apply(cfg, lp, x, state, impl):
    h = L.apply_norm(x, lp["ln1"], "layernorm")
    tm, st1 = _time_mix(lp, h, cfg, state, impl)
    x = shard(x + tm, "batch", "seq_sp", None)
    h = L.apply_norm(x, lp["ln2"], "layernorm")
    cm, st2 = _channel_mix(lp, h, state)
    x = shard(x + cm, "batch", "seq_sp", None)
    return x, {**st1, **st2}


# ------------------------------------------------------------------ public
def forward(params, cfg: ArchConfig, tokens, *, compute_dtype=jnp.bfloat16,
            attn_impl: str = "einsum", remat: bool = False, scan_impl: str = "scan",
            return_features: bool = False, **_):
    del attn_impl
    x = L.embed_lookup(params["embed"], tokens, compute_dtype)
    x = shard(x, "batch", "seq_sp", None)

    def body(x, lp):
        x, _ = _layer_apply(cfg, lp, x, None, scan_impl)
        return x, jnp.zeros(())
    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = L.apply_norm(x, params["final_norm"], "layernorm")
    if return_features:
        return x, {"moe_aux": jnp.zeros(()), "moe_z": jnp.zeros(())}
    logits = L.lm_logits(params["embed"], x, params["unembed"]["w"], vocab=cfg.vocab_size)
    return logits.astype(jnp.float32), {"moe_aux": jnp.zeros(()), "moe_z": jnp.zeros(())}


def init_cache(cfg: ArchConfig, batch: int, s_max: int, dtype=jnp.bfloat16):
    """Decode state: wkv state + token-shift carries per layer. O(1) in s_max."""
    H, N, D, Lr = cfg.num_heads, cfg.head_dim, cfg.d_model, cfg.num_layers
    return {
        "S": jnp.zeros((Lr, batch, H, N, N), jnp.float32),
        "x_tm": jnp.zeros((Lr, batch, D), jnp.float32),
        "x_cm": jnp.zeros((Lr, batch, D), jnp.float32),
        "pos": jnp.zeros((), jnp.int32),
    }


def cache_logical(cfg: ArchConfig):
    return {"S": (None, "batch", "heads", None, None),
            "x_tm": (None, "batch", None), "x_cm": (None, "batch", None),
            "pos": ()}


# ------------------------------------------------------- parallel prefill
def prefill_chunk(params, cfg: ArchConfig, tokens, cache, *,
                  compute_dtype=jnp.bfloat16, attn_impl: str = "einsum",
                  first: bool = False, **_):
    """Matmul-wide parallel prefill over one prompt chunk. rwkv has no KV
    cache to export — the whole story is the O(1) carry (wkv state +
    token-shift rows), which ``_layer_apply`` already threads through a
    full-width chunk: every projection runs at chunk width, only the
    per-channel wkv recurrence is sequential. Returns
    (last logits (B,1,Vp), cache with pos += C)."""
    del attn_impl, first
    C = tokens.shape[1]
    x = L.embed_lookup(params["embed"], tokens, compute_dtype)   # (B,C,D)

    def body(x, xs):
        lp, S, x_tm, x_cm = xs
        st = {"S": S, "x_tm": x_tm, "x_cm": x_cm}
        x, new_st = _layer_apply(cfg, lp, x, st, "scan")
        return x, (new_st["S"], new_st["x_tm"], new_st["x_cm"])

    x, (S, x_tm, x_cm) = jax.lax.scan(
        body, x, (params["layers"], cache["S"], cache["x_tm"], cache["x_cm"]))
    x = L.apply_norm(x[:, -1:], params["final_norm"], "layernorm")
    logits = L.lm_logits(params["embed"], x, params["unembed"]["w"],
                         vocab=cfg.vocab_size)
    return logits.astype(jnp.float32), dict(cache, S=S, x_tm=x_tm, x_cm=x_cm,
                                            pos=cache["pos"] + C)


def decode_step(params, cfg: ArchConfig, token, cache, *, compute_dtype=jnp.bfloat16,
                **_):
    x = L.embed_lookup(params["embed"], token, compute_dtype)  # (B,1,D)
    pos = cache["pos"]

    def body(x, xs):
        lp, S, x_tm, x_cm = xs
        st = {"S": S, "x_tm": x_tm, "x_cm": x_cm}
        x, new_st = _layer_apply(cfg, lp, x, st, "scan")
        # freed serving slots keep their recurrent state bit-for-bit; rwkv
        # has no KV cache to page, so this is the whole freed-slot story
        new_st = L.freeze_inactive_rows(pos, new_st, st)
        return x, (new_st["S"], new_st["x_tm"], new_st["x_cm"])

    x, (S, x_tm, x_cm) = jax.lax.scan(
        body, x, (params["layers"], cache["S"], cache["x_tm"], cache["x_cm"]))
    x = L.apply_norm(x, params["final_norm"], "layernorm")
    logits = L.lm_logits(params["embed"], x, params["unembed"]["w"], vocab=cfg.vocab_size)
    new_cache = {"S": S, "x_tm": x_tm, "x_cm": x_cm, "pos": cache["pos"] + 1}
    return logits.astype(jnp.float32), new_cache
