"""Shared model layers: norms, RoPE, GQA attention (einsum / chunked / pallas),
gated MLP, and the grouped-capacity MoE layer with expert parallelism.

All layers are pure functions over pytrees of parameters. Initializers return
param trees whose leaves carry a ``.logical`` sharding hint consumed by
``sharding.specs.spec_tree`` via the companion ``*_logical`` functions.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.sharding.specs import shard


# ---------------------------------------------------------------- numerics
# canonical definition lives in kernels/ref.py (the dependency-free numerics
# layer); re-exported here because every model-side masking site uses it
from repro.kernels.ref import mask_value  # noqa: E402  (re-export)


def cast_compute(x, dtype):
    return x.astype(dtype) if dtype is not None else x


def rmsnorm(x, scale, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layernorm(x, scale, bias, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def apply_norm(x, params, kind: str):
    if kind == "rmsnorm":
        return rmsnorm(x, params["scale"])
    return layernorm(x, params["scale"], params["bias"])


def norm_init(d: int, kind: str):
    if kind == "rmsnorm":
        return {"scale": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def norm_logical(kind: str):
    if kind == "rmsnorm":
        return {"scale": (None,)}
    return {"scale": (None,), "bias": (None,)}


# ---------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    sin = sin[..., :, None, :]  # broadcast over heads
    cos = cos[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- dense init
def _dense(key, shape, scale_dim=None, dtype=jnp.float32):
    fan_in = scale_dim if scale_dim is not None else shape[0]
    std = 1.0 / math.sqrt(fan_in)
    return jax.random.normal(key, shape, dtype) * std


# ---------------------------------------------------------------- attention
@dataclasses.dataclass(frozen=True)
class AttnDims:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    window: int = 0          # 0 = full causal
    rope_theta: float = 10000.0
    causal: bool = True


def attn_init(key, dims: AttnDims):
    ks = jax.random.split(key, 4)
    D, H, KV, hd = dims.d_model, dims.num_heads, dims.num_kv_heads, dims.head_dim
    p = {
        "wq": _dense(ks[0], (D, H * hd)),
        "wk": _dense(ks[1], (D, KV * hd)),
        "wv": _dense(ks[2], (D, KV * hd)),
        "wo": _dense(ks[3], (H * hd, D), scale_dim=H * hd),
    }
    if dims.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), jnp.float32)
        p["bk"] = jnp.zeros((KV * hd,), jnp.float32)
        p["bv"] = jnp.zeros((KV * hd,), jnp.float32)
    return p


def attn_logical(dims: AttnDims):
    p = {
        "wq": ("fsdp", "heads"),
        "wk": ("fsdp", "kv_heads"),
        "wv": ("fsdp", "kv_heads"),
        "wo": ("heads", "fsdp"),
    }
    if dims.qkv_bias:
        p.update({"bq": ("heads",), "bk": ("kv_heads",), "bv": ("kv_heads",)})
    return p


def _qkv(params, x, dims: AttnDims, positions):
    B, S, _ = x.shape
    H, KV, hd = dims.num_heads, dims.num_kv_heads, dims.head_dim
    q = x @ params["wq"].astype(x.dtype)
    k = x @ params["wk"].astype(x.dtype)
    v = x @ params["wv"].astype(x.dtype)
    if dims.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    if dims.rope_theta > 0:
        q = apply_rope(q, positions, dims.rope_theta)
        k = apply_rope(k, positions, dims.rope_theta)
    # Adaptive TP: shard heads when they divide the model axis; otherwise fall
    # back to sequence-parallel q (context parallelism) with replicated KV —
    # keeps e.g. 25-head/5-kv archs runnable on a 16-way model axis.
    from repro.sharding import specs as _sp
    if H % max(_sp.axis_size("heads"), 1) == 0:
        q = shard(q, "batch", None, "heads", None)
    elif S > 1:
        q = shard(q, "batch", "seq_sp", None, None)
    if KV % max(_sp.axis_size("kv_heads"), 1) == 0:
        k = shard(k, "batch", None, "kv_heads", None)
        v = shard(v, "batch", None, "kv_heads", None)
    return q, k, v


def _mask_bias(q_pos, k_pos, window: int, causal: bool):
    """(..., Sq, Sk) additive mask from absolute positions."""
    diff = q_pos[..., :, None] - k_pos[..., None, :]
    ok = jnp.ones(diff.shape, bool)
    if causal:
        ok &= diff >= 0
    if window > 0:
        ok &= diff < window
    return jnp.where(ok, 0.0, mask_value(jnp.float32)).astype(jnp.float32)


def _sdpa_einsum(q, k, v, q_pos, k_pos, dims: AttnDims):
    """Reference attention. q:(B,Sq,H,hd) k,v:(B,Sk,KV,hd).

    GQA K/V are expanded to H heads so every attention tensor carries ONE
    consistent head axis — a (KV,G) split head axis forces the SPMD
    partitioner into 'involuntary full rematerialization' (replication) at
    fwd/bwd sharding transitions. The expansion is a broadcast that shards
    over 'heads' with everything else; the flash kernel path keeps true GQA."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
        from repro.sharding import specs as _sp
        if H % max(_sp.axis_size("heads"), 1) == 0:
            k = shard(k, "batch", None, "heads", None)
            v = shard(v, "batch", None, "heads", None)
    scores = jnp.einsum("bqhe,bshe->bhqs", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    scores = scores + _mask_bias(q_pos, k_pos, dims.window, dims.causal)[:, None]
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhqs,bshe->bqhe", probs, v)
    return out


def _sdpa_chunked(q, k, v, q_pos, k_pos, dims: AttnDims, q_chunk: int = 1024):
    """Flash-style chunked attention in pure jnp: scan over query blocks —
    bounds live memory to O(q_chunk * Sk). The chunk body is checkpointed so
    scan-backward stores only chunk INPUTS (not scores/probs residuals) and
    recomputes the chunk forward — without this, bwd stacks O(S^2) residuals
    across chunks and defeats the memory bound entirely."""
    B, Sq, H, hd = q.shape
    n_chunks = max(1, Sq // q_chunk)
    q_chunk = Sq // n_chunks

    qs = q.reshape(B, n_chunks, q_chunk, H, hd).transpose(1, 0, 2, 3, 4)
    qp = q_pos.reshape(B, n_chunks, q_chunk).transpose(1, 0, 2)

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def chunk_fwd(qc, qpc):
        return _sdpa_einsum(qc, k, v, qpc, k_pos, dims)

    def one_chunk(carry, inp):
        qc, qpc = inp
        return carry, chunk_fwd(qc, qpc)

    _, outs = jax.lax.scan(one_chunk, None, (qs, qp))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)


def _sdpa_banded(q, k, v, dims: AttnDims, q_chunk: int = 1024):
    """Sliding-window attention computing ONLY the diagonal band: each query
    chunk attends to k/v rows [chunk_start - window, chunk_end) — work is
    O(S * (window + chunk)), not O(S^2). Assumes prefill layout (positions
    0..S-1). Unrolled over chunks so HLO FLOPs are exact (no scan-once
    undercount); this is the beyond-paper optimization for windowed archs
    (EXPERIMENTS.md §Perf, hymba prefill hillclimb)."""
    B, Sq, H, hd = q.shape
    W = dims.window
    n_chunks = max(1, Sq // q_chunk)
    q_chunk = Sq // n_chunks

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def chunk_fn(qc, kc, vc, q_pos, k_pos):
        return _sdpa_einsum(qc, kc, vc, q_pos, k_pos, dims)

    outs = []
    for ci in range(n_chunks):
        qs = ci * q_chunk
        ks = max(0, qs - W)
        ke = qs + q_chunk
        qc = jax.lax.slice_in_dim(q, qs, qs + q_chunk, axis=1)
        kc = jax.lax.slice_in_dim(k, ks, ke, axis=1)
        vc = jax.lax.slice_in_dim(v, ks, ke, axis=1)
        q_pos = jnp.broadcast_to(jnp.arange(qs, qs + q_chunk), (B, q_chunk))
        k_pos = jnp.broadcast_to(jnp.arange(ks, ke), (B, ke - ks))
        outs.append(chunk_fn(qc, kc, vc, q_pos, k_pos))
    return jnp.concatenate(outs, axis=1)


def _sdpa_banded_cp(q, k, v, dims: AttnDims, q_chunk: int = 1024):
    """Context-parallel banded attention: the chunk axis is sharded over the
    'seq_sp' mesh axis via shard_map — every model-shard computes its OWN
    whole chunks against (replicated) K/V band slices, so no per-chunk
    resharding collectives occur (hillclimb C iteration 2; iteration 1's
    plain banded form re-sharded a seq-sharded q at every slice)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.sharding import specs as _sp

    mesh = _sp.active_mesh()
    B, S, H, hd = q.shape
    n_chunks = max(1, S // q_chunk)
    C = S // n_chunks
    seq_ax = _sp._resolve_one("seq_sp", mesh) if mesh is not None else None
    batch_ax = _sp._resolve_one("batch", mesh) if mesh is not None else None
    n_seq = 1 if seq_ax is None else (
        mesh.shape[seq_ax] if isinstance(seq_ax, str)
        else int(np_prod([mesh.shape[a] for a in seq_ax])))
    if mesh is None or seq_ax is None or n_chunks % n_seq or S < dims.window + C:
        return _sdpa_banded(q, k, v, dims, q_chunk)
    nc_local = n_chunks // n_seq
    W = dims.window
    band = W + C

    if W > C * nc_local:   # halo wider than a shard's rows: fall back
        return _sdpa_banded(q, k, v, dims, q_chunk)
    n_shards = n_seq
    perm = [(s, s + 1) for s in range(n_shards - 1)]   # send tail to next

    def local(q_r, k_r, v_r):
        # q_r: (B_l, nc_local, C, H, hd); k_r/v_r: (B_l, nc_local, C, KV, hd)
        # K/V stay sequence-sharded; only a window-sized halo moves between
        # neighbouring shards (ppermute) instead of all-gathering full K/V.
        ci0 = jax.lax.axis_index(seq_ax) * nc_local
        Bl = q_r.shape[0]
        k_flat = k_r.reshape(Bl, nc_local * C, *k_r.shape[3:])
        v_flat = v_r.reshape(Bl, nc_local * C, *v_r.shape[3:])
        halo_k = jax.lax.ppermute(k_flat[:, -W:], seq_ax, perm)
        halo_v = jax.lax.ppermute(v_flat[:, -W:], seq_ax, perm)
        k_ext = jnp.concatenate([halo_k, k_flat], axis=1)  # rows [loc0-W, locN)
        v_ext = jnp.concatenate([halo_v, v_flat], axis=1)

        @functools.partial(jax.checkpoint,
                           policy=jax.checkpoint_policies.nothing_saveable)
        def chunk_fn(qc, kc, q_pos, k_pos, vc):
            with _sp.use_mesh(None):
                return _sdpa_einsum(qc, kc, vc, q_pos, k_pos, dims)

        outs = []
        for i in range(nc_local):
            ci = ci0 + i
            kc = jax.lax.slice_in_dim(k_ext, i * C, i * C + band, axis=1)
            vc = jax.lax.slice_in_dim(v_ext, i * C, i * C + band, axis=1)
            q_pos = jnp.broadcast_to(ci * C + jnp.arange(C), (Bl, C))
            # k_ext row j holds global position ci0*C - W + i*C + j; rows
            # before position 0 are shard-0's zero halo -> sentinel-masked
            raw = (ci0 * C - W) + i * C + jnp.arange(band)
            raw = jnp.where(raw >= 0, raw, S + W + 1)   # causal-masks zeros
            k_pos = jnp.broadcast_to(raw, (Bl, band))
            outs.append(chunk_fn(q_r[:, i], kc, q_pos, k_pos, vc))
        return jnp.stack(outs, axis=1)

    q_r = q.reshape(B, n_chunks, C, H, hd)
    KV = k.shape[2]
    k_r = k.reshape(B, n_chunks, C, KV, hd)
    v_r = v.reshape(B, n_chunks, C, KV, hd)
    out = shard_map(
        local, mesh=mesh,
        in_specs=(P(batch_ax, seq_ax, None, None, None),
                  P(batch_ax, seq_ax, None, None, None),
                  P(batch_ax, seq_ax, None, None, None)),
        out_specs=P(batch_ax, seq_ax, None, None, None),
        check_rep=False)(q_r, k_r,
                         v_r)
    return out.reshape(B, S, H, hd)


def np_prod(xs):
    out = 1
    for x in xs:
        out *= x
    return out


def attention(params, x, dims: AttnDims, positions, impl: str = "einsum",
              kv_override=None):
    """Self-attention (or cross-attention when kv_override=(k,v,k_pos))."""
    q, k, v = _qkv(params, x, dims, positions)
    k_pos = positions
    if kv_override is not None:
        k, v, k_pos = kv_override
    if impl == "banded" or (impl == "chunked" and dims.window > 0
                            and dims.causal and kv_override is None):
        out = _sdpa_banded_cp(q, k, v, dims)
    elif impl == "chunked":
        out = _sdpa_chunked(q, k, v, positions, k_pos, dims)
    elif impl == "pallas":
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=dims.causal, window=dims.window,
                                   q_positions=positions, k_positions=k_pos)
    else:
        out = _sdpa_einsum(q, k, v, positions, k_pos, dims)
    B, S, H, hd = out.shape
    out = out.reshape(B, S, H * hd)
    out = out @ params["wo"].astype(x.dtype)
    if S > 1:  # row-parallel wo output -> sequence-parallel (reduce-scatter)
        out = shard(out, "batch", "seq_sp", None)
    return out


# Sentinel cache position for an INACTIVE (freed / never-admitted) serving
# slot. It is >= any reachable sequence position, so the dense decode scatter
# drops the slot's K/V write (index out of range, mode="drop") and the paged /
# ring-buffer paths gate on ``pos < INACTIVE_POS`` explicitly. The engine sets
# a slot's pos to this on _finish; pos keeps advancing by +1 per tick but
# stays >= INACTIVE_POS, so freed rows are bit-stable indefinitely.
INACTIVE_POS = 1 << 30


def freeze_inactive_rows(pos, new, old):
    """Per-slot recurrent-state update gate for serving decode: rows of
    INACTIVE slots (vector ``pos`` at the sentinel) keep their ``old`` value
    bit-for-bit; scalar (lockstep) pos is a no-op. ``new``/``old`` are
    matching pytrees whose leaves lead with the batch axis. The single
    implementation of the sentinel convention for recurrent families
    (hybrid SSM branch, rwkv state) — keep them from diverging."""
    if jnp.ndim(pos) != 1:
        return new
    act = pos < INACTIVE_POS
    return jax.tree.map(
        lambda n, o: jnp.where(act.reshape((-1,) + (1,) * (n.ndim - 1)), n, o),
        new, old)


def decode_positions(pos, batch: int):
    """(B,1) query positions from a cache ``pos`` that is either a scalar
    (lockstep batch) or a (B,) per-slot vector — THE cross-family convention
    for serving decode (see models/registry.py); every family's decode_step
    goes through here so the two layouts cannot desynchronize."""
    if jnp.ndim(pos) == 1:
        return pos[:, None]
    return jnp.full((batch, 1), pos, jnp.int32)


def _decode_sdpa_local(q, ck, cv, cache_pos, k_positions, window, hd):
    """Partial-softmax decode attention over a LOCAL cache slice.
    q: (B,1,KV,G,hd); ck/cv: (B,S_loc,KV,hd); k_positions: (S_loc,) global or
    (B,S_loc) per-row (the paged path, where each slot views its own pages);
    cache_pos: scalar (lockstep) or (B,1) per-slot positions.
    Returns (m (B,KV,G,1), l, acc (B,KV,G,1,hd)) for cross-shard combining."""
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, ck.astype(q.dtype)
                        ).astype(jnp.float32) / math.sqrt(hd)
    kp = k_positions if jnp.ndim(k_positions) == 2 else k_positions[None, :]
    valid = kp <= cache_pos
    if window > 0:
        valid &= kp > cache_pos - window
    scores = jnp.where(valid[:, None, None, None, :], scores,
                       mask_value(scores.dtype))
    m = scores.max(axis=-1)                                   # (B,KV,G,1)
    p = jnp.exp(scores - m[..., None])
    l = p.sum(axis=-1)
    acc = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(q.dtype),
                     cv.astype(q.dtype)).astype(jnp.float32)
    return m, l, acc


def attention_decode(params, x, dims: AttnDims, cache_k, cache_v, cache_pos,
                     positions):
    """Single-token decode: x (B,1,D); cache_{k,v}: (B,S_max,KV,hd).
    Returns (out, new_k, new_v). Cache positions < cache_pos are valid.

    ``cache_pos`` is either a scalar (every batch row at the same position —
    the lockstep train/dryrun path) or a (B,) vector of PER-SLOT positions
    (the serving engine's continuous-batching path, where each slot is at a
    different point in its own sequence). The vector path writes the new K/V
    row with a per-batch scatter and masks per-row; out-of-range positions
    (already-finished slots) are dropped by the scatter.

    When the cache sequence dim is sharded (adaptive cache_logical), attention
    runs as flash-decode context parallelism via shard_map: each shard scans
    ONLY its local cache rows and partial softmax stats (m, l, acc) combine
    with three tiny psums — without this the SPMD partitioner replicates the
    whole cache per chip (hillclimb A iteration 2)."""
    q, k, v = _qkv(params, x, dims, positions)
    B, S_max, KV, hd = cache_k.shape
    H = dims.num_heads
    G = H // KV
    qg = q.reshape(B, 1, KV, G, hd)
    vector_pos = jnp.ndim(cache_pos) == 1

    from repro.sharding import specs as _sp
    mesh = _sp.active_mesh()
    seq_ax = _sp._resolve_one("seq_sp", mesh) if mesh is not None else None
    kv_sharded = KV % max(_sp.axis_size("kv_heads"), 1) == 0 and \
        _sp.axis_size("kv_heads") > 1
    use_cp = (mesh is not None and seq_ax is not None and not kv_sharded
              and not vector_pos
              and isinstance(seq_ax, str) and S_max % mesh.shape[seq_ax] == 0)

    if use_cp:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        batch_ax = _sp._resolve_one("batch", mesh)
        n_shards = mesh.shape[seq_ax]
        s_loc = S_max // n_shards

        def local(qg, k_new, v_new, ck, cv, pos):
            sid = jax.lax.axis_index(seq_ax)
            # cache write happens LOCALLY on the owning shard (a global DUS
            # on the sharded dim makes the partitioner replicate the cache)
            rel = pos - sid * s_loc
            safe = jnp.clip(rel, 0, s_loc - 1)
            in_rng = (rel >= 0) & (rel < s_loc)
            cur_k = jax.lax.dynamic_slice_in_dim(ck, safe, 1, axis=1)
            cur_v = jax.lax.dynamic_slice_in_dim(cv, safe, 1, axis=1)
            wk = jnp.where(in_rng, k_new.astype(ck.dtype), cur_k)
            wv = jnp.where(in_rng, v_new.astype(cv.dtype), cur_v)
            ck = jax.lax.dynamic_update_slice_in_dim(ck, wk, safe, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cv, wv, safe, axis=1)

            k_positions = sid * s_loc + jnp.arange(s_loc)
            m, l, acc = _decode_sdpa_local(qg, ck, cv, pos, k_positions,
                                           dims.window, hd)
            m_g = jax.lax.pmax(m, seq_ax)
            corr = jnp.exp(m - m_g)
            l_g = jax.lax.psum(l * corr, seq_ax)
            acc_g = jax.lax.psum(acc * corr[..., None], seq_ax)
            out = (acc_g / jnp.maximum(l_g, 1e-30)[..., None]).astype(qg.dtype)
            return out, ck, cv

        out, cache_k, cache_v = shard_map(
            local, mesh=mesh,
            in_specs=(P(batch_ax, None, None, None, None),
                      P(batch_ax, None, None, None),
                      P(batch_ax, None, None, None),
                      P(batch_ax, seq_ax, None, None),
                      P(batch_ax, seq_ax, None, None), P()),
            out_specs=(P(batch_ax, None, None, None, None),
                       P(batch_ax, seq_ax, None, None),
                       P(batch_ax, seq_ax, None, None)),
            check_rep=False)(qg, k, v, cache_k, cache_v, cache_pos)
        out = out.transpose(0, 3, 1, 2, 4)       # (B,1,KV,G,hd)
    else:
        if vector_pos:
            # per-slot positions: scatter row b's new K/V at cache_pos[b];
            # OOB rows (finished slots stepped past S_max) are dropped
            b_idx = jnp.arange(B)
            cache_k = cache_k.at[b_idx, cache_pos].set(
                k[:, 0].astype(cache_k.dtype), mode="drop")
            cache_v = cache_v.at[b_idx, cache_pos].set(
                v[:, 0].astype(cache_v.dtype), mode="drop")
            mask_pos = cache_pos[:, None]                    # (B,1) -> (B,S)
        else:
            cache_k = jax.lax.dynamic_update_slice_in_dim(
                cache_k, k.astype(cache_k.dtype), cache_pos, axis=1)
            cache_v = jax.lax.dynamic_update_slice_in_dim(
                cache_v, v.astype(cache_v.dtype), cache_pos, axis=1)
            mask_pos = cache_pos
        k_positions = jnp.arange(S_max)
        m, l, acc = _decode_sdpa_local(qg, cache_k, cache_v, mask_pos,
                                       k_positions, dims.window, hd)
        out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        out = out.transpose(0, 3, 1, 2, 4)

    out = out.reshape(B, 1, H * hd)
    return out @ params["wo"].astype(x.dtype), cache_k, cache_v


# ------------------------------------------------------- parallel prefill
def attention_prefill_chunk(params, x, dims: AttnDims, cache_k, cache_v,
                            start, positions, use_kernel: bool = False):
    """Multi-token prefill-chunk attention against a dense per-request cache.

    The matmul-wide counterpart of ``attention_decode``: instead of one query
    row per dispatch, a whole CHUNK of prompt positions is projected, its
    post-RoPE K/V written into cache rows ``[start, start + C)`` in one
    dynamic-update, and all C queries attend jointly — full matmul width on
    the q axis, which is the loop-width/tiling lever the paper pulls for
    throughput (and the reason parallel prefill beats teacher-forcing
    ``decode_step`` under a scan).

    x: (B, C, D); cache_k/v: (B, S_max, KV, hd); ``start`` is the chunk's
    first absolute position (a traced scalar for continuation chunks, the
    literal 0 for a first chunk); positions: (B, C) absolute query positions.
    Validity is ``k_pos <= q_pos`` (and the sliding window) over ALL cache
    rows, so a continuation chunk sees every previously-written row and
    never a future/unwritten one (unwritten rows have k_pos > q_pos).

    ``use_kernel`` routes the chunk-local causal attention through the
    K/V-exporting flash kernel (``kernels.ops.flash_prefill``) — only valid
    when the cache holds NO prior rows (a first chunk at start == 0), where
    chunk-local causal+window attention IS the full mask. Returns
    (out (B, C, H*hd) @ wo, new_ck, new_cv)."""
    q, k, v = _qkv(params, x, dims, positions)
    B, C, KV, hd = k.shape
    H = dims.num_heads
    if use_kernel:
        from repro.kernels import ops as kops
        out, k_tiles, v_tiles = kops.flash_prefill(
            q, k, v, causal=dims.causal, window=dims.window)
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache_k, k_tiles.astype(cache_k.dtype), start, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache_v, v_tiles.astype(cache_v.dtype), start, axis=1)
        out = out.reshape(B, C, H * hd)
    else:
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache_k, k.astype(cache_k.dtype), start, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache_v, v.astype(cache_v.dtype), start, axis=1)
        S_max = ck.shape[1]
        G = H // KV
        qg = q.reshape(B, C, KV, G, hd)
        scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, ck.astype(q.dtype)
                            ).astype(jnp.float32) / math.sqrt(hd)
        k_pos = jnp.arange(S_max)
        valid = k_pos[None, None, :] <= positions[:, :, None]      # (B,C,S)
        if dims.window > 0:
            valid &= k_pos[None, None, :] > positions[:, :, None] - dims.window
        scores = jnp.where(valid[:, None, None, :, :], scores,
                           mask_value(scores.dtype))
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out = jnp.einsum("bkgqs,bskh->bqkgh", probs, cv.astype(q.dtype)
                         ).reshape(B, C, H * hd)
    return out @ params["wo"].astype(x.dtype), ck, cv


# ------------------------------------------------------- paged KV decode
def paged_row_indices(block_tables, page_size: int, n_rows: int):
    """Flattened pool-row index of each LOGICAL row of every slot.

    block_tables: (B, mps) int32 page ids, -1 = unallocated. Returns
    ((B, n_rows) int32 physical rows into a (P*page_size, ...) flattened pool,
    (B, n_rows) bool page-allocated mask). Rows of unallocated pages map to 0
    (callers must mask with the bool) — keeps the gather in-bounds."""
    j = jnp.arange(n_rows)
    page = jnp.take_along_axis(
        block_tables, jnp.broadcast_to(j // page_size,
                                       (block_tables.shape[0], n_rows)), axis=1)
    ok = page >= 0
    phys = jnp.where(ok, page * page_size + j[None, :] % page_size, 0)
    return phys, ok


def paged_write_target(block_tables, idx, page_size: int):
    """Write-side block-table lookup shared by every paged decode path.
    idx: (B,) logical row per slot (sequence position, or ring index for the
    hybrid ring). Returns ((B,) flattened pool row, (B,) bool valid — false
    where the page is unallocated). Callers add their own in-range gate on
    idx before passing it (it must be >= 0 here)."""
    mps = block_tables.shape[1]
    page = jnp.take_along_axis(
        block_tables, jnp.clip(idx // page_size, 0, mps - 1)[:, None],
        axis=1)[:, 0]
    return page * page_size + idx % page_size, page >= 0


def paged_write_rows(pool, rows, row_idx, valid):
    """Scatter one new row per slot into a flattened page pool.
    pool: (P, ps, ...) -> returns same shape; rows: (B, ...) new values;
    row_idx: (B,) flattened pool row per slot; valid: (B,) bool (invalid
    writes are dropped — freed slots, unallocated pages)."""
    P, ps = pool.shape[:2]
    flat = pool.reshape((P * ps,) + pool.shape[2:])
    idx = jnp.where(valid, row_idx, P * ps)          # OOB -> dropped
    flat = flat.at[idx].set(rows.astype(flat.dtype), mode="drop")
    return flat.reshape(pool.shape)


# ------------------------------------------- int8 page writes (q8 backend)
def _requant_page(blk, content, groups: int = 1):
    """Symmetric int8 scales per page from its LIVE rows only — one scale
    per kv-head GROUP (``groups`` is the serving tp degree; group t covers
    the contiguous KV/groups kv heads shard t owns, so each scale is an
    amax over shard-local values and the requant write partitions comm-free
    under a kv-head-sharded pool; groups=1 is the original whole-page
    scale, bitwise). blk: (B, ps, KV, hd) f32 dequantized page content;
    content: (B, ps) bool — rows beyond the sequence frontier may hold
    stale payload from a recycled page, so they are excluded from the amax
    AND zeroed in the output. Returns (q (B,ps,KV,hd) int8,
    scale (B, groups) f32)."""
    from repro.core.quantize import page_scale
    B, ps, KV, hd = blk.shape
    vm = content[..., None, None]
    masked = jnp.where(vm, blk, 0.0)
    g = masked.reshape(B, ps, groups, KV // groups, hd)
    scale = page_scale(jnp.max(jnp.abs(g), axis=(1, 3, 4)))
    q = jnp.clip(jnp.round(g / scale[:, None, :, None, None]),
                 -127, 127).astype(jnp.int8)
    return q.reshape(B, ps, KV, hd), scale


def _dequant_page_block(pool_pg, scale_pg):
    """Dequantize gathered int8 pages (B, ps, KV, hd) with their per-group
    scales (B, T) — group t scales the contiguous KV/T kv-head slab t."""
    B, ps, KV, hd = pool_pg.shape
    T = scale_pg.shape[-1]
    g = pool_pg.astype(jnp.float32).reshape(B, ps, T, KV // T, hd)
    return (g * scale_pg[:, None, :, None, None]).reshape(B, ps, KV, hd)


def paged_append_row_q8(pool, scale, rows, block_tables, safe_pos, valid):
    """Decode-append one K/V row per slot into an INT8 page pool.

    The page is a quantization block: appending a row changes the page's
    max-abs, so the slot's CURRENT page is dequantized (one page per slot —
    never the full pool), the new row overlaid at ``safe_pos % ps``, and the
    page re-quantized with fresh symmetric per-group scales. Rows past the
    append offset are treated as stale (recycled-page payload) and zeroed.
    Invalid writes (freed slots, unallocated pages) drop both the page and
    its scale update. pool: (P, ps, KV, hd) int8; scale: (P, T) f32 — one
    column per kv-head group (T = serving tp degree, 1 when unsharded);
    rows: (B, KV, hd); safe_pos: (B,) clipped positions; valid: (B,)."""
    P, ps = pool.shape[:2]
    mps = block_tables.shape[1]
    B = rows.shape[0]
    T = scale.shape[-1]
    page = jnp.take_along_axis(
        block_tables, jnp.clip(safe_pos // ps, 0, mps - 1)[:, None],
        axis=1)[:, 0]
    pg = jnp.clip(page, 0, P - 1)
    blk = _dequant_page_block(pool[pg], scale[pg])
    off = safe_pos % ps
    blk = blk.at[jnp.arange(B), off].set(rows.astype(jnp.float32))
    content = jnp.arange(ps)[None, :] <= off[:, None]
    q, new_scale = _requant_page(blk, content, T)
    tgt = jnp.where(valid & (page >= 0), pg, P)      # OOB -> dropped
    pool = pool.at[tgt].set(q, mode="drop")
    scale = scale.at[tgt].set(new_scale, mode="drop")
    return pool, scale


def paged_splice_chunk_q8(pool, scale, rows, block_tables, positions,
                          write_floor):
    """Chunk-splice C rows per slot into an INT8 page pool (the incremental
    prefill splice, quantized). Visits each logical page the chunk overlaps
    (a static loop of at most C//ps + 2 pages), overlays the chunk's rows on
    the page's dequantized live content, and re-quantizes the whole page —
    so a COW-rematerialised partial page gets its fresh scales here, exactly
    once. Pages the chunk does NOT write (aliased prefix pages below
    ``write_floor``, including a full-hit's recomputed last row) are left
    untouched: their payload AND scales stay shared.

    pool: (P, ps, KV, hd) int8; scale: (P, T) f32 — one column per kv-head
    group (T = serving tp degree, 1 when unsharded); rows: (B, C, KV, hd);
    positions: (B, C) absolute query positions (contiguous, shared start);
    write_floor: scalar first writable logical row."""
    P, ps = pool.shape[:2]
    B, C = positions.shape
    mps = block_tables.shape[1]
    n_rows = mps * ps
    T = scale.shape[-1]
    start = positions[:, :1]                          # (B, 1)
    b_idx = jnp.arange(B)[:, None]
    for t in range((C - 1) // ps + 2):
        lpg = positions[:, 0] // ps + t               # (B,) logical page
        page = jnp.take_along_axis(
            block_tables, jnp.clip(lpg, 0, mps - 1)[:, None], axis=1)[:, 0]
        in_range = (lpg < mps) & (page >= 0)
        pg = jnp.clip(page, 0, P - 1)
        blk = _dequant_page_block(pool[pg], scale[pg])
        row_pos = lpg[:, None] * ps + jnp.arange(ps)[None, :]   # (B, ps)
        ci = row_pos - start                          # chunk-relative index
        from_chunk = ((ci >= 0) & (ci < C) & (row_pos >= write_floor)
                      & (row_pos < n_rows))
        chunk_rows = rows[b_idx, jnp.clip(ci, 0, C - 1)]        # (B,ps,KV,hd)
        blk = jnp.where(from_chunk[..., None, None],
                        chunk_rows.astype(jnp.float32), blk)
        content = (row_pos <= start + C - 1) & (row_pos < n_rows)
        q, new_scale = _requant_page(blk, content, T)
        writable = from_chunk.any(axis=1) & in_range
        tgt = jnp.where(writable, pg, P)
        pool = pool.at[tgt].set(q, mode="drop")
        scale = scale.at[tgt].set(new_scale, mode="drop")
    return pool, scale


def dequant_paged_view(view, phys, scale, page_size: int, dtype):
    """Dequantize a block-table-gathered int8 view (B, n_rows, KV, hd) using
    the per-page — (P,), or per-kv-head-group (P, T) — scales of the pages
    each row was gathered from."""
    P = scale.shape[0]
    pg = jnp.clip(phys // page_size, 0, P - 1)
    sc = scale[pg]                       # (B, n_rows) or (B, n_rows, T)
    if sc.ndim == 2:
        sc = sc[..., None]
    B, n, KV, hd = view.shape
    T = sc.shape[-1]
    g = view.astype(jnp.float32).reshape(B, n, T, KV // T, hd)
    return (g * sc[..., None, None]).reshape(view.shape).astype(dtype)


def attention_decode_paged(params, x, dims: AttnDims, pool_k, pool_v,
                           block_tables, cache_pos, positions,
                           impl: str = "einsum", *, k_scale=None,
                           v_scale=None):
    """Single-token decode against a PAGED KV cache (vLLM-style block tables).

    x: (B,1,D); pool_k/pool_v: (P, page_size, KV, hd) — ONE layer's slice of
    the shared page pool (no batch axis: memory scales with allocated pages,
    not slots x s_max); block_tables: (B, mps) int32, -1 = unallocated;
    cache_pos: (B,) per-slot positions (the paged path is serving-only, so
    positions are always a vector). Returns (out, new_pool_k, new_pool_v).

    Writes go through block-table indirection: slot b's new K/V row lands in
    page block_tables[b, pos//ps] at offset pos % ps; writes from slots whose
    position is out of range (>= mps*ps — freed slots at INACTIVE_POS) or
    whose page is unallocated are DROPPED.

    Reads: ``impl='kernel'`` routes through the Pallas paged-attention
    kernel (``kernels.ops.paged_decode``) — K/V blocks are gathered through
    the block table INSIDE the kernel and fully-masked pages (unallocated,
    or beyond the causal frontier) are skipped, so read work scales with a
    slot's live pages. ``impl='einsum'`` is the masked-gather reference:
    materialize the slot's logical view (B, mps*ps, KV, hd) and mask to
    allocated-page AND position <= pos (AND the sliding window) — rows of
    never-allocated trailing pages carry an INACTIVE_POS key position, so
    they can never win the causal mask for a live slot.

    With page_size == s_max (one page per slot) the einsum path reproduces
    the dense ``attention_decode`` vector path bit-for-bit (the gathered
    view IS the slot's dense cache row and the masks coincide); the kernel
    path matches it to greedy-token exactness (its online softmax uses the
    same dot-then-scale f32 operation order).

    ``k_scale``/``v_scale``: optional (P, T) f32 per-page per-kv-head-group
    symmetric scales (T = serving tp degree, 1 when unsharded) — the
    int8-backend path. The new row's write re-quantizes the slot's
    current page in place (``paged_append_row_q8``), reads dequantize
    per-page (inside the Pallas kernel's gather on the kernel path, each
    tp shard using its own group's scale column), and the return grows to
    (out, pool_k, pool_v, k_scale, v_scale)."""
    q, k, v = _qkv(params, x, dims, positions)
    P, ps, KV, hd = pool_k.shape
    B = q.shape[0]
    mps = block_tables.shape[1]
    n_rows = mps * ps
    H = dims.num_heads
    G = H // KV
    quantized = k_scale is not None

    # ---- write the new K/V row via the block table
    safe_pos = jnp.clip(cache_pos, 0, n_rows - 1)
    w_row, page_ok = paged_write_target(block_tables, safe_pos, ps)
    w_ok = (cache_pos >= 0) & (cache_pos < n_rows) & page_ok
    if quantized:
        pool_k, k_scale = paged_append_row_q8(pool_k, k_scale, k[:, 0],
                                              block_tables, safe_pos, w_ok)
        pool_v, v_scale = paged_append_row_q8(pool_v, v_scale, v[:, 0],
                                              block_tables, safe_pos, w_ok)
    else:
        pool_k = paged_write_rows(pool_k, k[:, 0], w_row, w_ok)
        pool_v = paged_write_rows(pool_v, v[:, 0], w_row, w_ok)

    if impl == "kernel":
        from repro.kernels import ops as kops
        from repro.sharding import specs as _sp
        # freed slots (cache_pos >= n_rows) carry an all--1 table: every
        # page is skipped and the kernel returns 0 rows for them, so no
        # clamping of start is needed for the skip logic to stay sound
        tp_mesh, tp_axis = _sp.head_shard_axis(H, KV)
        if quantized:
            out = kops.paged_decode_q8(q, pool_k, pool_v, k_scale, v_scale,
                                       block_tables, cache_pos,
                                       window=dims.window,
                                       mesh=tp_mesh, shard_axis=tp_axis)
        else:
            out = kops.paged_decode(q, pool_k, pool_v, block_tables,
                                    cache_pos, window=dims.window,
                                    mesh=tp_mesh, shard_axis=tp_axis)
        out = out.reshape(B, 1, H * hd)
    else:
        # ---- gather each slot's logical view and attend
        qg = q.reshape(B, 1, KV, G, hd)
        phys, ok = paged_row_indices(block_tables, ps, n_rows)
        flat_k = pool_k.reshape(P * ps, KV, hd)
        flat_v = pool_v.reshape(P * ps, KV, hd)
        view_k = flat_k[phys]                        # (B, n_rows, KV, hd)
        view_v = flat_v[phys]
        if quantized:
            view_k = dequant_paged_view(view_k, phys, k_scale, ps, q.dtype)
            view_v = dequant_paged_view(view_v, phys, v_scale, ps, q.dtype)
        k_positions = jnp.where(ok, jnp.arange(n_rows)[None, :], INACTIVE_POS)
        m, l, acc = _decode_sdpa_local(qg, view_k, view_v, cache_pos[:, None],
                                       k_positions, dims.window, hd)
        out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        out = out.transpose(0, 3, 1, 2, 4).reshape(B, 1, H * hd)
    # tp serving: all-gather the head-sharded attention output BEFORE the
    # output projection (NOT a psum of per-shard partial projections — an
    # un-split wo contraction is what keeps tp>1 bitwise equal to tp=1)
    from repro.sharding import specs as _sp
    out = _sp.replicate(out)
    out = out @ params["wo"].astype(x.dtype)
    if quantized:
        return out, pool_k, pool_v, k_scale, v_scale
    return out, pool_k, pool_v


def attention_prefill_chunk_paged(params, x, dims: AttnDims, pool_k, pool_v,
                                  block_tables, positions, write_floor,
                                  impl: str = "kernel", *, k_scale=None,
                                  v_scale=None):
    """Multi-token prefill-chunk attention DIRECTLY against the paged pool —
    the incremental-splice counterpart of ``attention_prefill_chunk``.

    x: (B, C, D); pool_k/pool_v: one layer's (P, ps, KV, hd) pool slice;
    block_tables: (B, mps) rows for the chunk's slots; positions: (B, C)
    absolute query positions (row i at ``positions[:, 0] + i`` — the engine
    groups jobs so a chunk's positions are contiguous and share a start);
    write_floor: scalar — the first logical row this request may WRITE.

    The chunk's post-RoPE K/V scatter straight into the slot's own pages
    (the per-chunk incremental splice: there is no transient request cache
    to fill and no completion splice to pay). Rows below ``write_floor``
    are DROPPED — they live in shared immutable prefix pages aliased by
    other block tables (copy-on-write's no-write half); the COW partial
    page is re-materialised by the engine with the same scatter before the
    first chunk runs. Attention then reads prior chunks, aliased prefix
    pages, and the current chunk uniformly through the block table:
    ``impl='kernel'`` uses the block-skipping Pallas kernel
    (``ops.paged_prefill``); ``impl='einsum'`` is the masked-gather
    reference over the full block-table span. Returns
    (out (B, C, H*hd) @ wo, new_pool_k, new_pool_v).

    ``k_scale``/``v_scale``: optional (P, T) f32 per-page per-kv-head-group
    scales (T = serving tp degree) — the int8 backend. The splice
    re-quantizes each page the chunk writes
    (``paged_splice_chunk_q8``; untouched aliased prefix pages keep their
    shared scale), reads dequantize per-page, and the return grows to
    (out, pool_k, pool_v, k_scale, v_scale)."""
    q, k, v = _qkv(params, x, dims, positions)
    B, C, KV, hd = k.shape
    P, ps = pool_k.shape[:2]
    mps = block_tables.shape[1]
    n_rows = mps * ps
    H = dims.num_heads
    quantized = k_scale is not None

    # ---- incremental splice: scatter the chunk's K/V rows via block table
    if quantized:
        pool_k, k_scale = paged_splice_chunk_q8(pool_k, k_scale, k,
                                                block_tables, positions,
                                                write_floor)
        pool_v, v_scale = paged_splice_chunk_q8(pool_v, v_scale, v,
                                                block_tables, positions,
                                                write_floor)
        flat_k = pool_k.reshape(P * ps, KV, hd)
        flat_v = pool_v.reshape(P * ps, KV, hd)
    else:
        page = jnp.take_along_axis(
            block_tables, jnp.clip(positions // ps, 0, mps - 1), axis=1)
        w_ok = ((page >= 0) & (positions >= write_floor)
                & (positions >= 0) & (positions < n_rows))
        w_rows = jnp.where(w_ok, page * ps + positions % ps, P * ps)  # drop
        flat_k = pool_k.reshape(P * ps, KV, hd)
        flat_v = pool_v.reshape(P * ps, KV, hd)
        flat_k = flat_k.at[w_rows].set(k.astype(flat_k.dtype), mode="drop")
        flat_v = flat_v.at[w_rows].set(v.astype(flat_v.dtype), mode="drop")
        pool_k = flat_k.reshape(pool_k.shape)
        pool_v = flat_v.reshape(pool_v.shape)

    if impl == "kernel":
        from repro.kernels import ops as kops
        from repro.sharding import specs as _sp
        tp_mesh, tp_axis = _sp.head_shard_axis(H, KV)
        if quantized:
            out = kops.paged_prefill_q8(q, pool_k, pool_v, k_scale, v_scale,
                                        block_tables, positions[:, 0],
                                        window=dims.window,
                                        mesh=tp_mesh, shard_axis=tp_axis)
        else:
            out = kops.paged_prefill(q, pool_k, pool_v, block_tables,
                                     positions[:, 0], window=dims.window,
                                     mesh=tp_mesh, shard_axis=tp_axis)
        out = out.reshape(B, C, H * hd)
    else:
        G = H // KV
        qg = q.reshape(B, C, KV, G, hd)
        phys, ok = paged_row_indices(block_tables, ps, n_rows)
        view_k = flat_k[phys]                        # (B, n_rows, KV, hd)
        view_v = flat_v[phys]
        if quantized:
            view_k = dequant_paged_view(view_k, phys, k_scale, ps, q.dtype)
            view_v = dequant_paged_view(view_v, phys, v_scale, ps, q.dtype)
        scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, view_k.astype(q.dtype)
                            ).astype(jnp.float32) / math.sqrt(hd)
        k_pos = jnp.where(ok, jnp.arange(n_rows)[None, :], INACTIVE_POS)
        valid = k_pos[:, None, :] <= positions[:, :, None]       # (B,C,S)
        if dims.window > 0:
            valid &= k_pos[:, None, :] > positions[:, :, None] - dims.window
        scores = jnp.where(valid[:, None, None, :, :], scores,
                           mask_value(scores.dtype))
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out = jnp.einsum("bkgqs,bskh->bqkgh", probs, view_v.astype(q.dtype)
                         ).reshape(B, C, H * hd)
    # all-gather head-sharded chunk outputs before wo (see decode path note)
    from repro.sharding import specs as _sp
    out = _sp.replicate(out)
    out = out @ params["wo"].astype(x.dtype)
    if quantized:
        return out, pool_k, pool_v, k_scale, v_scale
    return out, pool_k, pool_v


# ------------------------------------------------- MLA (latent attention)
# Multi-head latent attention (DeepSeek-V3 style). The cache stores, per
# token, ONE row of ``kv_lora_rank + qk_rope_head_dim`` floats: a compressed
# KV latent (wkv_a output, rms-normed) concatenated with a small decoupled
# RoPE key head shared by all query heads. Decode runs the ABSORB path:
# wkv_b's key half is folded into the query projection (q_nope -> latent
# space) and its value half into the output projection, so attention's
# score/value contractions run directly over the latent rows — per-head K/V
# never materialize. Every dense/paged variant below shares the same
# absorbed operation order, which is what makes the dense-MLA path and the
# degenerate-page latent path bit-exact (the house anchor rule).
@dataclasses.dataclass(frozen=True)
class MLADims:
    d_model: int
    num_heads: int
    kv_lora_rank: int        # c_kv: compressed KV latent width
    qk_rope_head_dim: int    # r: decoupled RoPE key head width
    head_dim: int            # qk_nope width == value head width
    rope_theta: float = 10000.0

    @property
    def latent_dim(self) -> int:
        """Cached floats per token: c_kv + r (one latent page row)."""
        return self.kv_lora_rank + self.qk_rope_head_dim

    @property
    def scale_dim(self) -> int:
        """Softmax scale denominator: the EFFECTIVE per-head query width
        (qk_nope + rope), not the latent width the absorbed dot runs over."""
        return self.head_dim + self.qk_rope_head_dim


def mla_init(key, dims: MLADims):
    ks = jax.random.split(key, 4)
    D, H = dims.d_model, dims.num_heads
    c, r, hd = dims.kv_lora_rank, dims.qk_rope_head_dim, dims.head_dim
    return {
        "wq": _dense(ks[0], (D, H * (hd + r))),
        "wkv_a": _dense(ks[1], (D, c + r)),
        "kv_norm": jnp.zeros((c,), jnp.float32),
        "wkv_b": _dense(ks[2], (c, H * 2 * hd), scale_dim=c),
        "wo": _dense(ks[3], (H * hd, D), scale_dim=H * hd),
    }


def mla_logical(dims: MLADims):
    return {
        "wq": ("fsdp", "heads"),
        "wkv_a": ("fsdp", None),
        "kv_norm": (None,),
        "wkv_b": (None, "heads"),
        "wo": ("heads", "fsdp"),
    }


def _mla_wkv_b(params, dims: MLADims, dtype):
    """Split wkv_b into its absorbable halves:
    (wb_k (H, hd, c) — folds q_nope into latent space,
     wb_v (H, c, hd) — expands latent attention output to value heads)."""
    c, H, hd = dims.kv_lora_rank, dims.num_heads, dims.head_dim
    wb = params["wkv_b"].astype(dtype).reshape(c, H, 2 * hd)
    wb_k = wb[:, :, :hd].transpose(1, 2, 0)      # (H, hd, c)
    wb_v = wb[:, :, hd:].transpose(1, 0, 2)      # (H, c, hd)
    return wb_k, wb_v


def mla_absorbed_queries(params, x, dims: MLADims, positions):
    """Project x to ABSORBED queries (B, S, H, c_kv + r): the nope half is
    pushed through wb_k into latent space, the rope half gets RoPE; their
    concatenation dots directly against cached latent rows."""
    B, S, _ = x.shape
    H, hd, r = dims.num_heads, dims.head_dim, dims.qk_rope_head_dim
    q = (x @ params["wq"].astype(x.dtype)).reshape(B, S, H, hd + r)
    q_nope, q_pe = q[..., :hd], q[..., hd:]
    q_pe = apply_rope(q_pe, positions, dims.rope_theta)
    wb_k, _ = _mla_wkv_b(params, dims, x.dtype)
    q_abs = jnp.einsum("bshd,hdc->bshc", q_nope, wb_k)
    return jnp.concatenate([q_abs, q_pe], axis=-1)


def mla_latent_rows(params, x, dims: MLADims, positions):
    """Per-token latent cache rows (B, S, 1, c_kv + r): rms-normed compressed
    KV latent ++ RoPE'd decoupled key head (a single shared 'kv head')."""
    c = dims.kv_lora_rank
    kv = x @ params["wkv_a"].astype(x.dtype)     # (B, S, c + r)
    ckv = rmsnorm(kv[..., :c], params["kv_norm"])
    k_pe = apply_rope(kv[..., None, c:], positions, dims.rope_theta)
    return jnp.concatenate([ckv[:, :, None, :], k_pe], axis=-1)


def _mla_out(params, attn, dims: MLADims, x):
    """Absorbed output projection: latent attention output (B, S, H, c_kv)
    -> value heads via wb_v -> wo. The wb_v einsum contracts only the
    latent width c (head-local), so a head-sharded ``attn`` stays
    head-sharded through it; the tp serve path then all-gathers the value
    heads BEFORE wo (one un-split contraction — the same replicate-before-
    wo structure as the K/V paths, and what keeps latent tp>1 bitwise
    equal to tp=1). Identity outside a mesh context."""
    from repro.sharding import specs as _sp
    B, S, H, _ = attn.shape
    _, wb_v = _mla_wkv_b(params, dims, x.dtype)
    out = jnp.einsum("bshc,hcd->bshd", attn, wb_v)
    out = _sp.replicate(out.reshape(B, S, H * dims.head_dim))
    return out @ params["wo"].astype(x.dtype)


def mla_attention_decode(params, x, dims: MLADims, cache_c, cache_pos,
                         positions):
    """Single-token MLA decode against a DENSE latent cache — the reference
    path. x: (B,1,D); cache_c: (B, S_max, 1, c_kv + r). Same scalar/vector
    ``cache_pos`` contract as ``attention_decode``. Returns (out, new_cache).

    Scores and values both read the latent rows (values = the leading c_kv
    columns); shares ``_decode_sdpa_local`` with the standard path so the
    dense and degenerate-page gathers stay bit-identical."""
    B = x.shape[0]
    H, c = dims.num_heads, dims.kv_lora_rank
    q = mla_absorbed_queries(params, x, dims, positions)     # (B,1,H,c+r)
    rows = mla_latent_rows(params, x, dims, positions)       # (B,1,1,c+r)
    if jnp.ndim(cache_pos) == 1:
        b_idx = jnp.arange(B)
        cache_c = cache_c.at[b_idx, cache_pos].set(
            rows[:, 0].astype(cache_c.dtype), mode="drop")
        mask_pos = cache_pos[:, None]
    else:
        cache_c = jax.lax.dynamic_update_slice_in_dim(
            cache_c, rows.astype(cache_c.dtype), cache_pos, axis=1)
        mask_pos = cache_pos
    qg = q.reshape(B, 1, 1, H, dims.latent_dim)              # KV=1, G=H
    k_positions = jnp.arange(cache_c.shape[1])
    m, l, acc = _decode_sdpa_local(qg, cache_c, cache_c[..., :c], mask_pos,
                                   k_positions, 0, dims.scale_dim)
    out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
    attn = out.transpose(0, 3, 1, 2, 4).reshape(B, 1, H, c)
    return _mla_out(params, attn, dims, x), cache_c


def mla_attention_prefill_chunk(params, x, dims: MLADims, cache_c, start,
                                positions):
    """Multi-token MLA prefill chunk against a dense latent cache — the
    absorb-path counterpart of ``attention_prefill_chunk`` (einsum branch).
    Returns (out (B,C,D), new_cache)."""
    c = dims.kv_lora_rank
    q = mla_absorbed_queries(params, x, dims, positions)     # (B,C,H,c+r)
    rows = mla_latent_rows(params, x, dims, positions)       # (B,C,1,c+r)
    cache_c = jax.lax.dynamic_update_slice_in_dim(
        cache_c, rows.astype(cache_c.dtype), start, axis=1)
    B, C, H, _ = q.shape
    S_max = cache_c.shape[1]
    qg = q.reshape(B, C, 1, H, dims.latent_dim)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, cache_c.astype(q.dtype)
                        ).astype(jnp.float32) / math.sqrt(dims.scale_dim)
    k_pos = jnp.arange(S_max)
    valid = k_pos[None, None, :] <= positions[:, :, None]    # (B,C,S)
    scores = jnp.where(valid[:, None, None, :, :], scores,
                       mask_value(scores.dtype))
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    attn = jnp.einsum("bkgqs,bskh->bqkgh", probs,
                      cache_c[..., :c].astype(q.dtype)).reshape(B, C, H, c)
    return _mla_out(params, attn, dims, x), cache_c


def mla_attention_decode_paged(params, x, dims: MLADims, pool_c,
                               block_tables, cache_pos, positions,
                               impl: str = "einsum"):
    """Single-token MLA decode against a LATENT page pool.

    pool_c: one layer's (P, page_size, 1, c_kv + r) latent pool slice — a
    page row is the whole per-token cache. Write/gather indirection is the
    standard block-table machinery (same helpers as the K/V path); the read
    is the absorbed dot over latent rows, values = the leading c_kv columns
    of the SAME gathered block. ``impl='kernel'`` routes through the
    latent-page Pallas kernel (``ops.paged_decode_latent``); 'einsum' is the
    masked-gather reference, bit-exact with ``mla_attention_decode`` at
    page_size == s_max. Returns (out, new_pool)."""
    H, c = dims.num_heads, dims.kv_lora_rank
    q = mla_absorbed_queries(params, x, dims, positions)     # (B,1,H,c+r)
    rows = mla_latent_rows(params, x, dims, positions)       # (B,1,1,c+r)
    P, ps = pool_c.shape[:2]
    B = q.shape[0]
    n_rows = block_tables.shape[1] * ps
    safe_pos = jnp.clip(cache_pos, 0, n_rows - 1)
    w_row, page_ok = paged_write_target(block_tables, safe_pos, ps)
    w_ok = (cache_pos >= 0) & (cache_pos < n_rows) & page_ok
    pool_c = paged_write_rows(pool_c, rows[:, 0], w_row, w_ok)

    if impl == "kernel":
        from repro.kernels import ops as kops
        from repro.sharding import specs as _sp
        # tp shards the ABSORBED queries/outputs on their head axis; the
        # latent pool itself is replicated (no kv-head axis to shard)
        tp_mesh, tp_axis = _sp.latent_head_shard_axis(H)
        attn = kops.paged_decode_latent(q, pool_c, block_tables, cache_pos,
                                        scale_dim=dims.scale_dim, d_v=c,
                                        mesh=tp_mesh, shard_axis=tp_axis)
    else:
        qg = q.reshape(B, 1, 1, H, dims.latent_dim)
        phys, ok = paged_row_indices(block_tables, ps, n_rows)
        view = pool_c.reshape(P * ps, 1, dims.latent_dim)[phys]
        k_positions = jnp.where(ok, jnp.arange(n_rows)[None, :], INACTIVE_POS)
        m, l, acc = _decode_sdpa_local(qg, view, view[..., :c],
                                       cache_pos[:, None], k_positions, 0,
                                       dims.scale_dim)
        out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        attn = out.transpose(0, 3, 1, 2, 4).reshape(B, 1, H, c)
    return _mla_out(params, attn, dims, x), pool_c


def mla_attention_prefill_chunk_paged(params, x, dims: MLADims, pool_c,
                                      block_tables, positions, write_floor,
                                      impl: str = "kernel"):
    """Multi-token MLA prefill chunk splicing latent rows DIRECTLY into the
    page pool (incremental splice) and attending through the block table —
    the latent twin of ``attention_prefill_chunk_paged``. Rows below
    ``write_floor`` (aliased prefix pages) are dropped, exactly as in the
    K/V path: COW materialisation copies latent rows, never per-head K/V.
    Returns (out (B,C,D), new_pool)."""
    H, c = dims.num_heads, dims.kv_lora_rank
    q = mla_absorbed_queries(params, x, dims, positions)     # (B,C,H,c+r)
    rows = mla_latent_rows(params, x, dims, positions)       # (B,C,1,c+r)
    B, C = positions.shape
    P, ps = pool_c.shape[:2]
    mps = block_tables.shape[1]
    n_rows = mps * ps

    page = jnp.take_along_axis(
        block_tables, jnp.clip(positions // ps, 0, mps - 1), axis=1)
    w_ok = ((page >= 0) & (positions >= write_floor)
            & (positions >= 0) & (positions < n_rows))
    w_rows = jnp.where(w_ok, page * ps + positions % ps, P * ps)  # drop
    flat = pool_c.reshape(P * ps, 1, dims.latent_dim)
    flat = flat.at[w_rows].set(rows.astype(flat.dtype), mode="drop")
    pool_c = flat.reshape(pool_c.shape)

    if impl == "kernel":
        from repro.kernels import ops as kops
        from repro.sharding import specs as _sp
        tp_mesh, tp_axis = _sp.latent_head_shard_axis(H)
        attn = kops.paged_prefill_latent(q, pool_c, block_tables,
                                         positions[:, 0],
                                         scale_dim=dims.scale_dim, d_v=c,
                                         mesh=tp_mesh, shard_axis=tp_axis)
    else:
        qg = q.reshape(B, C, 1, H, dims.latent_dim)
        phys, ok = paged_row_indices(block_tables, ps, n_rows)
        view = flat[phys]                        # (B, n_rows, 1, c+r)
        scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, view.astype(q.dtype)
                            ).astype(jnp.float32) / math.sqrt(dims.scale_dim)
        k_pos = jnp.where(ok, jnp.arange(n_rows)[None, :], INACTIVE_POS)
        valid = k_pos[:, None, :] <= positions[:, :, None]   # (B,C,S)
        scores = jnp.where(valid[:, None, None, :, :], scores,
                           mask_value(scores.dtype))
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        attn = jnp.einsum("bkgqs,bskh->bqkgh", probs,
                          view[..., :c].astype(q.dtype)).reshape(B, C, H, c)
    return _mla_out(params, attn, dims, x), pool_c


# ---------------------------------------------------------------- MLP
def mlp_init(key, d_model: int, d_ff: int, gated: bool = True, bias: bool = False):
    ks = jax.random.split(key, 3)
    p = {"w_up": _dense(ks[0], (d_model, d_ff)),
         "w_down": _dense(ks[1], (d_ff, d_model), scale_dim=d_ff)}
    if gated:
        p["w_gate"] = _dense(ks[2], (d_model, d_ff))
    if bias:
        p["b_up"] = jnp.zeros((d_ff,), jnp.float32)
        p["b_down"] = jnp.zeros((d_model,), jnp.float32)
    return p


def mlp_logical(gated: bool = True, bias: bool = False):
    p = {"w_up": ("fsdp", "d_ff"), "w_down": ("d_ff", "fsdp")}
    if gated:
        p["w_gate"] = ("fsdp", "d_ff")
    if bias:
        p["b_up"] = ("d_ff",)
        p["b_down"] = (None,)
    return p


def mlp(params, x, act: str = "silu"):
    up = x @ params["w_up"].astype(x.dtype)
    if "b_up" in params:
        up = up + params["b_up"].astype(x.dtype)
    if "w_gate" in params:
        gate = x @ params["w_gate"].astype(x.dtype)
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up) if act == "gelu" else jax.nn.silu(up)
    h = shard(h, "batch", None, "d_ff")
    out = h @ params["w_down"].astype(x.dtype)
    if "b_down" in params:
        out = out + params["b_down"].astype(x.dtype)
    # constrain the row-parallel output to sequence-parallel BEFORE the
    # residual add so the TP reduction lowers to reduce-scatter, not
    # all-reduce (hillclimb C iteration 4: 1/TP the reduction wire bytes)
    if out.ndim == 3 and out.shape[1] > 1:
        out = shard(out, "batch", "seq_sp", None)
    return out


# ---------------------------------------------------------------- MoE
@dataclasses.dataclass(frozen=True)
class MoEDims:
    d_model: int
    d_ff: int
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    group_size: int = 128     # tokens per dispatch group (GShard-style)


def moe_init(key, dims: MoEDims):
    ks = jax.random.split(key, 4)
    E, D, F = dims.num_experts, dims.d_model, dims.d_ff
    return {
        "router": _dense(ks[0], (D, E)),
        "w_gate": _dense(ks[1], (E, D, F), scale_dim=D),
        "w_up": _dense(ks[2], (E, D, F), scale_dim=D),
        "w_down": _dense(ks[3], (E, F, D), scale_dim=F),
    }


def moe_logical():
    return {
        "router": (None, None),
        "w_gate": ("expert", "fsdp", None),
        "w_up": ("expert", "fsdp", None),
        "w_down": ("expert", None, "fsdp"),
    }


def moe(params, x, dims: MoEDims):
    """Grouped-capacity top-k MoE (GShard dispatch), expert-parallel over the
    'expert' logical axis. x: (B, S, D) -> (B, S, D), plus aux losses."""
    B, S, D = x.shape
    E, K = dims.num_experts, dims.top_k
    T = B * S
    xt = x.reshape(T, D)

    logits = (xt @ params["router"].astype(jnp.float32)).astype(jnp.float32)  # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)                            # (T,K)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- aux losses (Switch-style load balance + router z-loss)
    me = probs.mean(0)                                     # (E,)
    onehot_top1 = jax.nn.one_hot(expert_idx[:, 0], E)
    ce = onehot_top1.mean(0)
    aux_loss = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    # ---- grouped dispatch with fixed capacity
    G = max(1, T // dims.group_size)
    Sg = T // G
    cap = max(1, int(math.ceil(Sg * K / E * dims.capacity_factor)))
    xg = shard(xt.reshape(G, Sg, D), "batch", None, None)
    idx_g = expert_idx.reshape(G, Sg, K)
    gate_g = gate_vals.reshape(G, Sg, K)

    # position of each (token, k) within its expert's capacity buffer.
    # Everything carrying an E axis is sharded over 'expert' as well as the
    # token-group axis — these (G,Sg,K,E[,cap]) tensors are the MoE dispatch
    # working set and dominate backward memory if left expert-replicated.
    eo = jax.nn.one_hot(idx_g, E, dtype=jnp.int32)          # (G,Sg,K,E)
    eo = shard(eo, "batch", None, None, "expert")
    flat = eo.reshape(G, Sg * K, E)
    pos_in_e = jnp.cumsum(flat, axis=1) - flat              # (G,Sg*K,E)
    pos = pos_in_e.reshape(G, Sg, K, E)
    slot = (pos * eo).sum(-1)                               # (G,Sg,K)
    keep = (slot < cap) & (gate_g > 0)
    gate_g = jnp.where(keep, gate_g, 0.0)

    # dispatch/combine one-hots: (G,Sg,K,E,cap) folded over K -> (G,Sg,E,cap)
    kec = (jax.nn.one_hot(idx_g, E, dtype=jnp.float32)[..., None]
           * jax.nn.one_hot(slot, cap, dtype=jnp.float32)[..., None, :]
           * keep[..., None, None].astype(jnp.float32))
    kec = shard(kec, "batch", None, None, "expert", None)
    disp = shard(kec.sum(2).astype(x.dtype), "batch", None, "expert", None)
    comb = shard((kec * gate_g[..., None, None]).sum(2),
                 "batch", None, "expert", None)

    # expert inputs: (E, G, cap, D) — sharded 'expert' x 'batch' (all_to_all here)
    ein = jnp.einsum("gsec,gsd->egcd", disp, xg)
    ein = shard(ein, "expert", "batch", None, None)
    h = jnp.einsum("egcd,edf->egcf", ein, params["w_gate"].astype(x.dtype))
    u = jnp.einsum("egcd,edf->egcf", ein, params["w_up"].astype(x.dtype))
    h = jax.nn.silu(h) * u
    eout = jnp.einsum("egcf,efd->egcd", h, params["w_down"].astype(x.dtype))
    eout = shard(eout, "expert", "batch", None, None)

    out = jnp.einsum("gsec,egcd->gsd", comb.astype(x.dtype), eout)
    return out.reshape(B, S, D), {"moe_aux": aux_loss, "moe_z": z_loss}


# ---------------------------------------------------------------- embeddings
def embed_init(key, padded_vocab: int, d_model: int):
    """Table rows are the PADDED vocab (configs.base.ArchConfig.padded_vocab)
    so the vocab dim shards evenly; lm_logits masks the padding columns."""
    return {"table": jax.random.normal(key, (padded_vocab, d_model), jnp.float32) * 0.02}


def embed_logical():
    return {"table": ("vocab", "fsdp")}


def embed_lookup(params, ids, dtype):
    return params["table"].astype(dtype)[ids]


def lm_logits(params_embed, x, w_unembed=None, vocab: Optional[int] = None):
    """x:(B,S,D) -> (B,S,V_padded), padding columns masked to -inf.
    Uses the tied embedding table if w_unembed is None."""
    table = w_unembed if w_unembed is not None else params_embed["table"]
    logits = x @ table.astype(x.dtype).T if w_unembed is None else x @ table.astype(x.dtype)
    logits = shard(logits, "batch", None, "vocab")
    vp = logits.shape[-1]
    if vocab is not None and vocab < vp:
        mask = jax.lax.broadcasted_iota(jnp.int32, (vp,), 0) < vocab
        logits = jnp.where(mask, logits,
                           jnp.asarray(mask_value(logits.dtype), logits.dtype))
    return logits
