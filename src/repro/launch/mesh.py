"""Production mesh construction.

Single pod : (data=16, model=16)            = 256 chips (one v5e pod slice)
Multi-pod  : (pod=2, data=16, model=16)     = 512 chips

``make_production_mesh`` is a function (never a module-level constant) so
importing this module touches no JAX device state. The dry-run forces 512
host devices via XLA_FLAGS before any JAX import; real launches get the same
shapes from the TPU runtime.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/elastic re-sharding (e.g. (2,2) on 4 CPUs)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def describe(mesh) -> str:
    return f"mesh{dict(zip(mesh.axis_names, mesh.devices.shape))}" \
           f" ({mesh.devices.size} devices)"
