"""Serving driver — thin CLI shim over ``repro.serve.ServeEngine``.

The engine owns the real serving path: single-dispatch batched prefill per
request (never stepping other slots), per-slot cache positions, continuous
batching with a priority/FIFO scheduler, greedy or temperature sampling, and
TTFT / tokens-per-s / p50-p95 metrics (see ``repro/serve/__init__.py`` for
the request lifecycle).

  PYTHONPATH=src python -m repro.launch.serve --arch hymba-1.5b --reduced \
      --requests 8 --gen-len 16

``LegacyServer`` preserves the seed's token-by-token prefill path, which
stepped the ENTIRE batch once per prompt token — O(prompt_len) dispatches
and, worse, it advanced every other active slot's cache while doing so
(cross-slot corruption). It exists only as the regression baseline for
``tests/test_serve.py`` and ``benchmarks/serve_bench.py``. Do not serve with
it.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import logging
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models.registry import get_model, reduced_config
from repro.serve.config import ServeConfig as EngineConfig
from repro.serve.engine import ServeEngine

log = logging.getLogger("repro.serve")


@dataclasses.dataclass
class ServeConfig:
    """CLI run description: the engine build knobs (mapped onto
    :class:`repro.serve.config.ServeConfig` by :func:`build_engine`) plus
    the synthetic-traffic shape (``requests``/``prompt_len``/``gen_len``)
    this driver generates."""
    arch: str = "hymba-1.5b"
    reduced: bool = True
    batch_slots: int = 4
    s_max: int = 64
    requests: int = 8
    prompt_len: int = 8
    gen_len: int = 16
    seed: int = 0
    quantize_int8: bool = False
    temperature: float = 0.0
    top_k: int = 0            # 0 = off; >0 restricts sampling to k best
    top_p: float = 1.0        # 1.0 = off; <1 nucleus sampling
    page_size: int = 0        # 0 = dense cache; >0 enables paged KV
    num_pages: int = 0        # 0 = dense-equivalent pool (slots x s_max/ps)
    kv_backend: str = ""      # "" = layout follows page_size; else a
    #                           kvcache.BACKENDS name (e.g. paged_latent)
    prefill_mode: str = "parallel"   # 'parallel' (chunked) | 'scan' (anchor)
    prefill_chunk: int = 64   # max prompt tokens ingested between decode ticks
    # True = auto (page-level prefix caching whenever the config supports it:
    # paged + parallel prefill + dense/MoE/VLM family); False = hard off
    prefix_cache: bool = True


def build_engine(sc: ServeConfig) -> ServeEngine:
    return ServeEngine.build(sc.arch, config=EngineConfig(
        reduced=sc.reduced, batch_slots=sc.batch_slots,
        s_max=sc.s_max, seed=sc.seed, quantize_int8=sc.quantize_int8,
        temperature=sc.temperature, top_k=sc.top_k, top_p=sc.top_p,
        page_size=sc.page_size or None, num_pages=sc.num_pages or None,
        kv_backend=sc.kv_backend or None,
        prefix_cache=None if sc.prefix_cache else False,
        prefill_mode=sc.prefill_mode,
        prefill_chunk_tokens=sc.prefill_chunk))


class Server:
    """Backwards-compatible slot API over the engine.

    ``add_request`` prefills into a free slot with ONE jitted batch-1 call —
    it can no longer advance other active slots' caches (the seed bug).
    """

    def __init__(self, sc: ServeConfig):
        self.sc = sc
        self.engine = build_engine(sc)
        self.cfg = self.engine.cfg
        self.model = self.engine.model
        self.params = self.engine.params
        # last request to occupy each slot (outputs survive slot recycling
        # until the slot is reused, matching the legacy outputs[] contract)
        self._slot_hist: List[Optional[object]] = [None] * sc.batch_slots

    @property
    def cache(self):
        return self.engine.cache

    @property
    def slot_free(self) -> List[bool]:
        return [r is None for r in self.engine.slot_req]

    @property
    def outputs(self) -> List[List[int]]:
        return [list(r.tokens) if r is not None else []
                for r in self._slot_hist]

    def add_request(self, prompt: np.ndarray, gen_len: int) -> Optional[int]:
        """Prefill a prompt into a free slot; returns the slot or None."""
        free = self.engine.free_slots
        if not free:
            return None
        req = self.engine.submit(prompt, gen_len)
        self.engine.admit()
        self._slot_hist[req.slot] = req
        return req.slot

    def step_all(self) -> int:
        """One decode tick for every active slot; returns #active."""
        return self.engine.step()


class LegacyServer:
    """SEED-PATH REPLICA (quarantined): token-by-token full-batch prefill.

    Prefill reuses the lockstep decode step once per prompt token at the FULL
    batch width, so every other active slot's cache advances too — the
    cross-slot corruption the engine's isolated prefill fixes. Kept verbatim
    so tests can demonstrate the bug and benchmarks can quantify the win.
    """

    def __init__(self, sc: ServeConfig):
        cfg = configs.get_config(sc.arch)
        if sc.reduced:
            cfg = reduced_config(cfg)
        self.cfg, self.sc = cfg, sc
        self.model = get_model(cfg)
        self.params = self.model.init(jax.random.PRNGKey(sc.seed))
        if sc.quantize_int8:
            from repro.core.quantize import dequantize_params, quantize_params
            self.params = dequantize_params(quantize_params(self.params),
                                            jnp.float32)
        self.cache = self.model.init_cache(sc.batch_slots, sc.s_max, jnp.float32)
        # share the engine's jit cache so legacy-vs-engine benchmarks compare
        # steady-state serving, not compile amortization
        from repro.serve.engine import _jitted_decode
        self.decode = _jitted_decode(self.model, jnp.float32)
        self.slot_free = [True] * sc.batch_slots
        self.slot_remaining = [0] * sc.batch_slots
        self.cur_token = np.zeros((sc.batch_slots, 1), np.int32)
        self.outputs: List[List[int]] = [[] for _ in range(sc.batch_slots)]

    def add_request(self, prompt: np.ndarray, gen_len: int) -> Optional[int]:
        if True not in self.slot_free:
            return None
        slot = self.slot_free.index(True)
        self.slot_free[slot] = False
        self.slot_remaining[slot] = gen_len
        self.outputs[slot] = []
        for tok in prompt:
            self.cur_token[slot, 0] = tok
            logits, self.cache = self._step()
        return slot

    def _step(self):
        batch = {"token": jnp.asarray(self.cur_token)}
        if self.cfg.cross_attn_every:
            batch["image_embeds"] = jnp.zeros(
                (self.sc.batch_slots, self.cfg.num_image_tokens, self.cfg.d_model),
                jnp.float32)
        logits, cache = self.decode(self.params, self.cache, batch)
        return logits, cache

    def step_all(self) -> int:
        logits, self.cache = self._step()
        nxt = np.asarray(jnp.argmax(logits[:, 0, : self.cfg.vocab_size], -1))
        active = 0
        for s in range(self.sc.batch_slots):
            if self.slot_free[s]:
                continue
            self.outputs[s].append(int(nxt[s]))
            self.cur_token[s, 0] = nxt[s]
            self.slot_remaining[s] -= 1
            if self.slot_remaining[s] <= 0:
                self.slot_free[s] = True
            else:
                active += 1
        return active


def make_prompts(sc: ServeConfig, vocab: int) -> List[np.ndarray]:
    rng = np.random.default_rng(sc.seed)
    return [rng.integers(0, vocab, sc.prompt_len) for _ in range(sc.requests)]


def run(sc: ServeConfig) -> dict:
    """Serve sc.requests synthetic prompts through the engine; returns stats
    (legacy keys ``requests``/``wall_s``/``tokens_per_s`` plus the full
    engine metrics summary under ``metrics``)."""
    engine = build_engine(sc)
    for prompt in make_prompts(sc, engine.cfg.vocab_size):
        engine.submit(prompt, sc.gen_len)
    summary = engine.run()
    return {"requests": summary["requests"], "wall_s": summary["wall_s"],
            "tokens_per_s": summary["throughput_tokens_per_s"],
            "metrics": summary}


def run_legacy(sc: ServeConfig) -> dict:
    """Seed-path driver loop over LegacyServer (benchmark baseline only)."""
    server = LegacyServer(sc)
    pending = make_prompts(sc, server.cfg.vocab_size)
    t0 = time.time()
    while pending or not all(server.slot_free):
        while pending and True in server.slot_free:
            server.add_request(pending.pop(), sc.gen_len)
        server.step_all()
    dt = time.time() - t0
    total = sc.requests * sc.gen_len
    return {"requests": sc.requests, "wall_s": dt, "tokens_per_s": total / dt}


def main():
    logging.basicConfig(level=logging.INFO, format="%(message)s")
    ap = argparse.ArgumentParser()
    for f in dataclasses.fields(ServeConfig):
        name = "--" + f.name.replace("_", "-")
        if isinstance(f.default, bool):
            # BooleanOptionalAction also emits --no-<name>: a True default
            # (e.g. --reduced) was previously impossible to turn off
            ap.add_argument(name, action=argparse.BooleanOptionalAction,
                            default=f.default)
        else:
            ap.add_argument(name, type=type(f.default), default=f.default)
    ap.add_argument("--json", action="store_true", help="print full metrics")
    args = ap.parse_args()
    sc = ServeConfig(**{f.name: getattr(args, f.name)
                        for f in dataclasses.fields(ServeConfig)})
    stats = run(sc)
    if args.json:
        print(json.dumps(stats["metrics"], indent=2, default=float))
    m = stats["metrics"]
    print(f"served {stats['requests']} requests, "
          f"{stats['tokens_per_s']:.1f} tok/s | "
          f"ttft p50 {m['ttft_s']['p50'] * 1e3:.1f} ms | "
          f"latency p95 {m['latency_s']['p95'] * 1e3:.1f} ms")


if __name__ == "__main__":
    main()
