"""Serving driver: continuous-batched decode against a KV/state cache, with
optional int8 weight quantization (the paper's C5 on the TPU path).

Request flow: prefill each new request (computing its cache entries via the
forward pass), then step the whole batch one token at a time; finished
requests free their slot for waiting ones (continuous batching).

  PYTHONPATH=src python -m repro.launch.serve --arch hymba-1.5b --reduced \
      --requests 8 --gen-len 16
"""
from __future__ import annotations

import argparse
import dataclasses
import logging
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch import steps as steps_mod
from repro.models.registry import Model, get_model, reduced_config
from repro.sharding import specs

log = logging.getLogger("repro.serve")


@dataclasses.dataclass
class ServeConfig:
    arch: str = "hymba-1.5b"
    reduced: bool = True
    batch_slots: int = 4
    s_max: int = 64
    requests: int = 8
    prompt_len: int = 8
    gen_len: int = 16
    seed: int = 0
    quantize_int8: bool = False


class Server:
    """Slot-based continuous batching decode server."""

    def __init__(self, sc: ServeConfig):
        cfg = configs.get_config(sc.arch)
        if sc.reduced:
            cfg = reduced_config(cfg)
        self.cfg, self.sc = cfg, sc
        self.model = get_model(cfg)
        self.params = self.model.init(jax.random.PRNGKey(sc.seed))
        if sc.quantize_int8:
            from repro.core.quantize import dequantize_params, quantize_params
            # PTQ then dequant-on-load (structural int8 path; the pallas
            # quant_matmul kernel consumes q directly on TPU)
            self.params = dequantize_params(quantize_params(self.params),
                                            jnp.float32)
        self.cache = self.model.init_cache(sc.batch_slots, sc.s_max, jnp.float32)
        self.decode = jax.jit(
            steps_mod.make_decode_step(self.model, compute_dtype=jnp.float32),
            donate_argnums=(1,))
        self.slot_free = [True] * sc.batch_slots
        self.slot_remaining = [0] * sc.batch_slots
        self.cur_token = np.zeros((sc.batch_slots, 1), np.int32)
        self.outputs: List[List[int]] = [[] for _ in range(sc.batch_slots)]

    def add_request(self, prompt: np.ndarray, gen_len: int) -> Optional[int]:
        """Prefill a prompt into a free slot (teacher-forced decode prefill —
        batch-1 models reuse the decode path per prompt token)."""
        if True not in self.slot_free:
            return None
        slot = self.slot_free.index(True)
        self.slot_free[slot] = False
        self.slot_remaining[slot] = gen_len
        self.outputs[slot] = []
        for tok in prompt:
            self.cur_token[slot, 0] = tok
            logits, self.cache = self._step()
        return slot

    def _step(self):
        batch = {"token": jnp.asarray(self.cur_token)}
        if self.cfg.cross_attn_every:
            batch["image_embeds"] = jnp.zeros(
                (self.sc.batch_slots, self.cfg.num_image_tokens, self.cfg.d_model),
                jnp.float32)
        logits, cache = self.decode(self.params, self.cache, batch)
        return logits, cache

    def step_all(self) -> int:
        """One decode tick for every active slot; returns #active."""
        logits, self.cache = self._step()
        nxt = np.asarray(jnp.argmax(logits[:, 0, : self.cfg.vocab_size], -1))
        active = 0
        for s in range(self.sc.batch_slots):
            if self.slot_free[s]:
                continue
            self.outputs[s].append(int(nxt[s]))
            self.cur_token[s, 0] = nxt[s]
            self.slot_remaining[s] -= 1
            if self.slot_remaining[s] <= 0:
                self.slot_free[s] = True
            else:
                active += 1
        return active


def run(sc: ServeConfig) -> dict:
    server = Server(sc)
    rng = np.random.default_rng(sc.seed)
    pending = [rng.integers(0, server.cfg.vocab_size, sc.prompt_len)
               for _ in range(sc.requests)]
    done = 0
    t0 = time.time()
    tokens_out = 0
    while done < sc.requests or not all(server.slot_free):
        while pending and True in server.slot_free:
            server.add_request(pending.pop(), sc.gen_len)
        server.step_all()
        tokens_out += sum(0 if f else 1 for f in server.slot_free) + \
            sum(1 for s in range(sc.batch_slots)
                if server.slot_free[s] and server.outputs[s])
        done = sc.requests - len(pending) - sum(
            0 if f else 1 for f in server.slot_free)
    dt = time.time() - t0
    total_tokens = sum(len(o) for o in server.outputs if o) + \
        sc.requests * sc.gen_len  # approximation across recycled slots
    return {"wall_s": dt, "requests": sc.requests,
            "tokens_per_s": sc.requests * sc.gen_len / dt}


def main():
    logging.basicConfig(level=logging.INFO, format="%(message)s")
    ap = argparse.ArgumentParser()
    for f in dataclasses.fields(ServeConfig):
        name = "--" + f.name.replace("_", "-")
        if isinstance(f.default, bool):
            ap.add_argument(name, action="store_true", default=f.default)
        else:
            ap.add_argument(name, type=type(f.default), default=f.default)
    args = ap.parse_args()
    sc = ServeConfig(**{f.name: getattr(args, f.name)
                        for f in dataclasses.fields(ServeConfig)})
    stats = run(sc)
    print(f"served {stats['requests']} requests, "
          f"{stats['tokens_per_s']:.1f} tok/s")


if __name__ == "__main__":
    main()
