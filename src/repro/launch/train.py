"""Training driver: data pipeline -> pjit train step -> checkpoint manager,
with auto-resume, straggler detection, and restart-on-failure.

On this CPU container it trains reduced configs end-to-end (examples/ use it
for the ~100M-param run); on a TPU fleet the same driver runs the full
configs — the mesh comes from the runtime, everything else is identical.

  PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b --reduced \
      --steps 100 --batch 8 --seq-len 128 --ckpt-dir /tmp/run1
"""
from __future__ import annotations

import argparse
import dataclasses
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint.manager import CheckpointManager
from repro.data.synthetic import TokenStream
from repro.launch import steps as steps_mod
from repro.models.registry import Model, get_model, reduced_config
from repro.optim.adamw import AdamW
from repro.optim.schedules import cosine, wsd
from repro.runtime.fault import RestartPolicy, StragglerDetector
from repro.sharding import specs

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class TrainConfig:
    arch: str = "minicpm-2b"
    reduced: bool = True
    steps: int = 100
    batch: int = 8
    seq_len: int = 128
    lr: float = 3e-4
    warmup: int = 10
    schedule: str = "cosine"      # cosine | wsd | constant
    microbatches: int = 1
    checkpoint_every: int = 50
    ckpt_dir: str = ""
    seed: int = 0
    mesh_shape: tuple = ()        # () => single device
    log_every: int = 10


def make_optimizer(tc: TrainConfig) -> AdamW:
    if tc.schedule == "wsd":   # minicpm's schedule (arXiv:2404.06395)
        lr = wsd(tc.lr, tc.warmup, int(tc.steps * 0.8) - tc.warmup,
                 max(tc.steps - int(tc.steps * 0.8), 1))
    else:
        lr = cosine(tc.lr, tc.warmup, tc.steps)
    return AdamW(learning_rate=lr)


def extras_for(model: Model, batch_np, dtype=jnp.float32):
    cfg = model.cfg
    B = batch_np["tokens"].shape[0]
    out = {}
    if cfg.cross_attn_every:
        out["image_embeds"] = jnp.ones((B, cfg.num_image_tokens, cfg.d_model),
                                       dtype) * 0.02
    if cfg.encoder_layers:
        out["frames"] = jnp.ones((B, 24, cfg.d_model), dtype) * 0.02
    return out


def train(tc: TrainConfig) -> dict:
    cfg = configs.get_config(tc.arch)
    if tc.reduced:
        cfg = reduced_config(cfg)
    model = get_model(cfg)
    optimizer = make_optimizer(tc)

    mesh = None
    if tc.mesh_shape:
        mesh = jax.make_mesh(tuple(tc.mesh_shape),
                             ("data", "model")[: len(tc.mesh_shape)])

    mgr = CheckpointManager(tc.ckpt_dir) if tc.ckpt_dir else None
    detector = StragglerDetector()
    stream = TokenStream(cfg.vocab_size, tc.batch, tc.seq_len, tc.seed)

    with specs.use_mesh(mesh):
        step_fn = steps_mod.make_train_step(
            model, optimizer, compute_dtype=jnp.float32 if tc.reduced else jnp.bfloat16,
            attn_impl="einsum", remat=not tc.reduced,
            microbatches=tc.microbatches)
        jit_step = jax.jit(step_fn, donate_argnums=(0,))

        start = 0
        state = None
        if mgr is not None and mgr.latest_step() is not None:
            state_sds = jax.eval_shape(
                lambda k: steps_mod.init_train_state(model, optimizer, k),
                jax.random.PRNGKey(tc.seed))
            sh = steps_mod.state_shardings(model, state_sds) if mesh else None
            state, meta = mgr.restore(shardings=sh)
            start = meta["step"]
            log.info("resumed from step %d", start)
        if state is None:
            state = steps_mod.init_train_state(model, optimizer,
                                               jax.random.PRNGKey(tc.seed))

        losses = []
        for step in range(start, tc.steps):
            t0 = time.time()
            raw = stream.batch_at(step)
            batch = {k: jnp.asarray(v) for k, v in raw.items()}
            batch.update(extras_for(model, raw))
            state, metrics = jit_step(state, batch)
            if (step + 1) % tc.log_every == 0 or step == start:
                loss = float(metrics["loss"])
                losses.append(loss)
                log.info("step %d loss %.4f (%.2fs)", step + 1, loss,
                         time.time() - t0)
            detector.record(time.time() - t0)
            if mgr is not None and (step + 1) % tc.checkpoint_every == 0:
                mgr.save(step + 1, state)
        if mgr is not None:
            mgr.save(tc.steps, state, block=True)
        final_loss = float(metrics["loss"])
    return {"final_loss": final_loss, "losses": losses,
            "stragglers": len(detector.flagged)}


def main():
    logging.basicConfig(level=logging.INFO, format="%(message)s")
    ap = argparse.ArgumentParser()
    for f in dataclasses.fields(TrainConfig):
        name = "--" + f.name.replace("_", "-")
        if f.type == "bool" or isinstance(f.default, bool):
            ap.add_argument(name, action="store_true", default=f.default)
        elif isinstance(f.default, tuple):
            ap.add_argument(name, type=int, nargs="*", default=list(f.default))
        else:
            ap.add_argument(name, type=type(f.default), default=f.default)
    args = ap.parse_args()
    tc = TrainConfig(**{f.name: tuple(v) if isinstance(v, list) else v
                        for f, v in ((f, getattr(args, f.name))
                                     for f in dataclasses.fields(TrainConfig))})
    stats = train(tc)
    print(f"final_loss={stats['final_loss']:.4f}")


if __name__ == "__main__":
    main()
