"""Distributed step builders: train_step (value_and_grad + AdamW, microbatch
accumulation, remat, mixed precision), serve prefill and decode steps — all
mesh-agnostic via logical shardings (sharding/specs.py).

These are the functions the multi-pod dry-run lowers/compiles and the
train.py / serve.py drivers execute.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.registry import Model
from repro.optim.adamw import AdamW, apply_updates
from repro.sharding import specs
from repro.sharding.specs import shard

MOE_AUX_WEIGHT = 0.01
MOE_Z_WEIGHT = 1e-3


# ------------------------------------------------------------------ loss
CE_CHUNK = 1024


def cross_entropy(logits, labels):
    """logits: (B,S,V) fp32 (possibly vocab-sharded); labels: (B,S) int32."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def head_ce_chunk(x_c, head_w, labels_c, vocab: int, tied: bool):
    """CE over one sequence chunk without keeping logits alive.
    x_c: (B,C,D); head_w: (D,Vp) or tied table (Vp,D); labels_c: (B,C)."""
    from repro.kernels.ref import mask_value
    w = head_w.astype(x_c.dtype)
    logits = (x_c @ w.T if tied else x_c @ w).astype(jnp.float32)
    vp = logits.shape[-1]
    if vocab < vp:
        mask = jax.lax.broadcasted_iota(jnp.int32, (vp,), 0) < vocab
        logits = jnp.where(mask, logits, mask_value(logits.dtype))
    logits = shard(logits, "batch", None, "vocab")
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels_c[..., None], axis=-1)[..., 0]
    return jnp.sum(lse - gold)


def chunked_cross_entropy(features, head_w, labels, vocab: int, tied: bool,
                          chunk: int = CE_CHUNK):
    """Never materializes (B,S,V) logits: scans S in chunks with a remat'd
    body (logits recomputed in backward) — the memory-side requirement for
    150k+ vocabs at 4k sequence (DESIGN.md; same trick as fused-CE kernels)."""
    B, S, D = features.shape
    chunk = min(chunk, S)
    n = S // chunk
    rem = S - n * chunk

    body = jax.checkpoint(
        lambda x_c, l_c: head_ce_chunk(x_c, head_w, l_c, vocab, tied),
        policy=jax.checkpoint_policies.nothing_saveable)

    def scan_body(acc, xs):
        x_c, l_c = xs
        return acc + body(x_c, l_c), None

    xs = (features[:, :n * chunk].reshape(B, n, chunk, D).swapaxes(0, 1),
          labels[:, :n * chunk].reshape(B, n, chunk).swapaxes(0, 1))
    total, _ = jax.lax.scan(scan_body, jnp.zeros((), jnp.float32), xs)
    if rem:
        total = total + body(features[:, n * chunk:], labels[:, n * chunk:])
    return total / (B * S)


def _batch_extras(model: Model, batch: Dict[str, Any]) -> Dict[str, Any]:
    out = {}
    if "image_embeds" in batch:
        out["image_embeds"] = batch["image_embeds"]
    if "frames" in batch:
        out["frames"] = batch["frames"]
    return out


def head_weight(model: Model, params):
    """(weights, tied?) for the LM head."""
    if "unembed" in params:
        return params["unembed"]["w"], False
    return params["embed"]["table"], True


def make_loss_fn(model: Model, *, compute_dtype=jnp.bfloat16,
                 attn_impl: str = "einsum", remat: bool = True):
    def loss_fn(params, batch):
        feats, aux = model.forward(params, batch["tokens"],
                                   compute_dtype=compute_dtype,
                                   attn_impl=attn_impl, remat=remat,
                                   return_features=True,
                                   **_batch_extras(model, batch))
        w, tied = head_weight(model, params)
        ce = chunked_cross_entropy(feats, w, batch["labels"],
                                   model.cfg.vocab_size, tied)
        loss = ce + MOE_AUX_WEIGHT * aux.get("moe_aux", 0.0) \
                  + MOE_Z_WEIGHT * aux.get("moe_z", 0.0)
        return loss, {"ce": ce, **aux}
    return loss_fn


# ------------------------------------------------------------------ train
def init_train_state(model: Model, optimizer: AdamW, key) -> Dict[str, Any]:
    params = model.init(key)
    return {"params": params, "opt": optimizer.init(params),
            "step": jnp.zeros((), jnp.int32)}


def _strip_fsdp(tree):
    """Remove the 'fsdp' (data-axis) factor from a logical tree: ZeRO-1
    params — replicated across data, sharded across model only."""
    def fix(ax):
        return tuple(None if a == "fsdp" else a for a in ax)
    return jax.tree.map(fix, tree,
                        is_leaf=lambda v: isinstance(v, tuple) and not isinstance(v, dict))


def train_state_logical(model: Model, zero_stage: int = 3) -> Dict[str, Any]:
    """zero_stage=3: params AND optimizer state sharded over data x model
    (ZeRO-3; weights all-gathered per layer — minimum memory).
    zero_stage=1: params model-sharded only (resident per chip, NO per-layer
    weight all-gathers); m/v stay data-sharded — the classic memory/collective
    trade (hillclimb B iteration 1)."""
    pl = model.param_logical()
    p_log = pl if zero_stage >= 3 else _strip_fsdp(pl)
    return {"params": p_log, "opt": {"m": pl, "v": pl, "count": ()},
            "step": ()}


def batch_logical(model: Model, batch_keys) -> Dict[str, Any]:
    out = {}
    for k in batch_keys:
        if k in ("tokens", "labels"):
            out[k] = ("batch", None)
        elif k == "token":
            out[k] = ("batch", None)
        elif k in ("image_embeds", "frames"):
            out[k] = ("batch", None, None)
        else:
            raise KeyError(k)
    return out


def make_train_step(model: Model, optimizer: AdamW, *,
                    compute_dtype=jnp.bfloat16, attn_impl: str = "einsum",
                    remat: bool = True, microbatches: int = 1):
    """Returns train_step(state, batch) -> (state, metrics).

    With microbatches>1, gradients are accumulated over sequential microbatch
    slices (lax.scan) — the standard activation-memory / collective-overlap
    trade at scale (each microbatch's backward overlaps the next's compute
    under XLA async collectives).
    """
    loss_fn = make_loss_fn(model, compute_dtype=compute_dtype,
                           attn_impl=attn_impl, remat=remat)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state, batch):
        params = state["params"]
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])
            mb = jax.tree.map(split, batch)

            def acc_body(carry, mbatch):
                (loss_a, grads_a) = carry
                (loss, metrics), grads = grad_fn(params, mbatch)
                grads = jax.tree.map(jnp.add, grads_a, grads)
                return (loss_a + loss, grads), metrics

            zero_grads = jax.tree.map(jnp.zeros_like, params)
            (loss, grads), metrics_seq = jax.lax.scan(
                acc_body, (jnp.zeros(()), zero_grads), mb)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = jax.tree.map(lambda m: m[-1], metrics_seq)
        updates, opt, gnorm = optimizer.update(grads, state["opt"], params)
        params = apply_updates(params, updates)
        new_state = {"params": params, "opt": opt, "step": state["step"] + 1}
        metrics = {"loss": loss, "grad_norm": gnorm, **metrics}
        return new_state, metrics

    return train_step


# ------------------------------------------------------------------ serve
def make_prefill(model: Model, *, compute_dtype=jnp.bfloat16,
                 attn_impl: str = "chunked", batch_chunks: int = 1,
                 return_cache: bool = False, s_max: int = 0,
                 cache_dtype=jnp.float32):
    """Prefill step builder.

    Default (``return_cache=False``): full-sequence forward returning
    LAST-position logits only (the decode bootstrap a serving system actually
    needs — avoids a (B,S,V) output). batch_chunks > 1 processes the request
    batch in sequential slices (lax.scan) — bounds prefill activation memory
    exactly like gradient-accumulation microbatching does for training.

    ``return_cache=True`` (the serving engine's path): returns
    ``(last_logits, cache)`` where the cache holds every prompt position's
    K/V / recurrent state at pos == prompt_len, ready for decode. The prompt
    is teacher-forced through ``decode_step`` under a single ``lax.scan``
    inside ONE jitted call — one dispatch per request instead of one per
    prompt token, and crucially at the REQUEST's batch size (1 in the engine)
    so it never touches other slots' cache entries. ``s_max`` sizes the
    returned cache's sequence capacity: for a dense serving cache it must
    match the resident cache; for a PAGED one it is the per-slot LOGICAL
    capacity (the block-table span) — the returned cache is always the dense
    per-request layout, a transient at the group's batch size that
    ``registry.insert_cache_rows_paged`` then scatters into exactly the pages
    the admitted slots reserved. For encoder-decoder models the
    cross-attention K/V are precomputed from the encoder pass first, exactly
    once."""
    if return_cache:
        if s_max <= 0:
            raise ValueError("return_cache=True requires s_max > 0")
        from repro.configs.base import Family

        def prefill_cache(params, batch):
            tokens = batch["tokens"]
            B, S = tokens.shape
            cache = model.init_cache(B, s_max, cache_dtype)
            extras = _batch_extras(model, batch)
            if model.cfg.family == Family.ENCDEC:
                from repro.models import encdec
                frames = batch.get("frames")
                if frames is None:
                    frames = jnp.zeros((B, encdec.ENC_LEN, model.cfg.d_model),
                                       compute_dtype)
                enc_out = encdec.encode(params, model.cfg,
                                        frames.astype(compute_dtype),
                                        compute_dtype=compute_dtype,
                                        attn_impl="einsum", remat=False)
                xk, xv = encdec.precompute_cross_kv(params, model.cfg, enc_out)
                cache = dict(cache, xk=xk.astype(cache["xk"].dtype),
                             xv=xv.astype(cache["xv"].dtype))
                extras = {}

            def body(carry, tok):
                cache, _ = carry
                logits, cache = model.decode_step(params, tok, cache,
                                                  compute_dtype=compute_dtype,
                                                  **extras)
                return (cache, logits), None

            logits0 = jnp.zeros((B, 1, model.cfg.padded_vocab), jnp.float32)
            toks = jnp.moveaxis(tokens, 1, 0)[:, :, None]        # (S, B, 1)
            (cache, logits), _ = jax.lax.scan(body, (cache, logits0), toks)
            return logits, cache
        return prefill_cache

    def one(params, batch):
        feats, _ = model.forward(params, batch["tokens"],
                                 compute_dtype=compute_dtype,
                                 attn_impl=attn_impl, remat=False,
                                 return_features=True,
                                 **_batch_extras(model, batch))
        w, tied = head_weight(model, params)
        last = feats[:, -1:]
        wd = w.astype(last.dtype)
        return (last @ wd.T if tied else last @ wd).astype(jnp.float32)

    def prefill(params, batch):
        if batch_chunks == 1:
            return one(params, batch)
        def split(x):
            return x.reshape((batch_chunks, x.shape[0] // batch_chunks)
                             + x.shape[1:])
        mb = jax.tree.map(split, batch)
        def body(_, mbatch):
            return None, one(params, mbatch)
        _, outs = jax.lax.scan(body, None, mb)
        return outs.reshape((-1,) + outs.shape[2:])
    return prefill


def make_prefill_chunk(model: Model, *, compute_dtype=jnp.bfloat16,
                       s_max: int = 0, cache_dtype=jnp.float32,
                       first: bool = False, attn_impl: str = "einsum"):
    """Parallel (matmul-wide) chunked prefill step builder — the serving
    engine's fast path; the scan prefill (``make_prefill(return_cache=True)``)
    stays the bit-exactness anchor.

    Each call computes ALL of a chunk's prompt positions in one full-width
    pass per layer and exports the per-layer K/V (ring + recurrent carry for
    hybrid, O(1) state for ssm/rwkv) directly into the request's dense
    transient cache, which the engine then splices into the resident cache
    (``insert_cache_rows`` / ``insert_cache_rows_paged``) when the prompt
    completes.

    ``first=True``: returns ``first_chunk(params, batch) -> (logits, cache)``
    — creates the transient cache inside the jit, runs the encoder +
    cross-KV precompute exactly once for encoder-decoder models, and
    processes the chunk at STATIC position 0 (which is what lets
    ``attn_impl='pallas'`` route chunk-local causal attention through the
    K/V-exporting flash kernel). ``first=False``: returns
    ``chunk(params, cache, batch) -> (logits, cache)`` — a continuation at
    the traced ``cache['pos']``; callers should donate the cache."""
    if s_max <= 0 and first:
        raise ValueError("first=True requires s_max > 0")
    from repro.configs.base import Family

    def run_chunk(params, cache, batch):
        return model.prefill_chunk(params, batch["tokens"], cache,
                                   compute_dtype=compute_dtype,
                                   attn_impl=attn_impl, first=first,
                                   **_batch_extras(model, batch))

    if not first:
        return run_chunk

    def first_chunk(params, batch):
        tokens = batch["tokens"]
        B = tokens.shape[0]
        cache = model.init_cache(B, s_max, cache_dtype)
        if model.cfg.family == Family.ENCDEC:
            from repro.models import encdec
            frames = batch.get("frames")
            if frames is None:
                frames = jnp.zeros((B, encdec.ENC_LEN, model.cfg.d_model),
                                   compute_dtype)
            enc_out = encdec.encode(params, model.cfg,
                                    frames.astype(compute_dtype),
                                    compute_dtype=compute_dtype,
                                    attn_impl="einsum", remat=False)
            xk, xv = encdec.precompute_cross_kv(params, model.cfg, enc_out)
            cache = dict(cache, xk=xk.astype(cache["xk"].dtype),
                         xv=xv.astype(cache["xv"].dtype))
            batch = {k: v for k, v in batch.items() if k != "frames"}
        return run_chunk(params, cache, batch)

    return first_chunk


def make_prefill_chunk_paged(model: Model, *, compute_dtype=jnp.bfloat16,
                             attn_impl: str = "kernel"):
    """Incremental paged prefill step builder: each call computes one prompt
    chunk for a group of K slots and splices its per-layer K/V STRAIGHT into
    the resident paged cache's pools through the group's block tables —
    there is no transient request cache and no completion splice, and a
    prefix-cache hit's aliased pages are read in place (no gather seeding).

    Returns ``chunk(params, cache, batch) -> (last_logits, cache)`` where
    ``cache`` is the engine's resident paged cache (callers donate it) and
    ``batch`` carries ``tokens`` (K, C), ``bt`` (K, mps) block-table rows,
    and traced scalars ``start`` (the chunk's first absolute position — the
    engine groups jobs so the whole group shares it) and ``floor`` (the
    first row the group may write; rows below live in shared immutable
    prefix pages — copy-on-write's no-write half). ``attn_impl='kernel'``
    attends through the block-skipping Pallas kernel, ``'einsum'`` through
    the masked-gather reference. Only families with
    ``supports_paged_prefill`` (dense/MoE/VLM) accept this path."""
    def chunk(params, cache, batch):
        return model.prefill_chunk_paged(
            params, batch["tokens"], cache, bt_rows=batch["bt"],
            start=batch["start"], write_floor=batch["floor"],
            compute_dtype=compute_dtype, attn_impl=attn_impl,
            **_batch_extras(model, batch))
    return chunk


def make_decode_step(model: Model, *, compute_dtype=jnp.bfloat16,
                     paged_attn_impl: Optional[str] = None):
    """One-token decode against a KV/state cache; cache buffers are donated.
    ``paged_attn_impl`` ('kernel' | 'einsum') selects the paged-cache read
    path for the families that page through ``attention_decode_paged``
    (dense/MoE/VLM/encdec); None keeps each family's default (the
    masked-einsum reference) — hybrid's ring path has its own gather."""
    # function-level import: launch.steps is imported by serve.engine, and
    # serve/__init__ imports engine — a module-level kvcache import here
    # would cycle through the serve package at import time
    from repro.serve.kvcache import PAGED_KERNEL_FAMILIES
    extra = {}
    if (paged_attn_impl is not None
            and model.cfg.family in PAGED_KERNEL_FAMILIES):
        extra["paged_attn_impl"] = paged_attn_impl

    def decode(params, cache, batch):
        logits, cache = model.decode_step(params, batch["token"], cache,
                                          compute_dtype=compute_dtype,
                                          **extra,
                                          **_batch_extras(model, batch))
        return logits, cache
    return decode


# ------------------------------------------------------------------ shardings
# All builders are shape-aware (specs.shardings_for): logical axes that do not
# divide a leaf's dim are dropped per-leaf (pjit arguments require exact
# divisibility; e.g. batch=1 long-context cells, kv=5 heads on 16-way TP).
def state_shardings(model: Model, state_sds, zero_stage: int = 3):
    return specs.shardings_for(train_state_logical(model, zero_stage), state_sds)


def param_shardings(model: Model, params_sds):
    return specs.shardings_for(model.param_logical(), params_sds)


def batch_shardings(model: Model, batch_sds):
    return specs.shardings_for(batch_logical(model, batch_sds.keys()), batch_sds)


def cache_shardings(model: Model, cache_sds):
    return specs.shardings_for(model.cache_logical(), cache_sds)
