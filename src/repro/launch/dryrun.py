import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
against the production meshes, prove memory fit and shardability, and record
cost/memory/collective statistics + per-layer roofline probes as JSON.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-32b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only-gate]

The XLA_FLAGS line above MUST precede any jax import (device count locks at
first init); smoke tests and benchmarks never import this module.
"""

import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.launch import hlo_stats, steps
from repro.launch.mesh import make_production_mesh
from repro.launch.probes import probes_for, recurrence_extra
from repro.models.registry import get_model
from repro.optim.adamw import AdamW
from repro.optim.schedules import cosine
from repro.sharding import specs

ART_DIR = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _attn_impl_for(shape):
    # chunked (flash-style) attention bounds live scores to O(q_chunk * S);
    # einsum attention at S>=2k materializes multi-GB score tensors in bwd.
    return "chunked" if shape.seq_len >= 2048 else "einsum"


def _serve_param_sds(model, int8: bool = False):
    """Serve-time parameter shapes: bf16, or int8 for >=2-D (matmul/embed)
    weights — the paper's C5 quantization as it lands on the TPU weight
    stream (models upcast with .astype at use; per-channel scales add O(N)
    negligible work and are folded into the upcast on the real kernel path
    via kernels/quant_matmul.py)."""
    p = jax.eval_shape(model.init, jax.random.PRNGKey(0))

    def conv(s):
        if not jnp.issubdtype(s.dtype, jnp.floating):
            return s
        if int8 and len(s.shape) >= 2:
            return jax.ShapeDtypeStruct(s.shape, jnp.int8)
        return jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
    return jax.tree.map(conv, p)


def build_gate(model, shape, mesh, *, microbatches: int = 1,
               int8_weights: bool = False, zero_stage: int = 3,
               remat="nothing"):
    """Returns (jitted_fn, args_sds) for the cell's step under `mesh`."""
    cfg = model.cfg
    batch_sds = model.input_specs(shape)
    batch_sh = steps.batch_shardings(model, batch_sds)
    if shape.kind == "train":
        opt = AdamW(learning_rate=cosine(3e-4, 100, 10000))
        state_sds = jax.eval_shape(
            lambda k: steps.init_train_state(model, opt, k), jax.random.PRNGKey(0))
        state_sh = steps.state_shardings(model, state_sds, zero_stage)
        step = steps.make_train_step(model, opt, attn_impl=_attn_impl_for(shape),
                                     remat=remat, microbatches=microbatches)
        fn = jax.jit(step, in_shardings=(state_sh, batch_sh),
                     donate_argnums=(0,))
        return fn, (state_sds, batch_sds)
    if shape.kind == "prefill":
        params_sds = _serve_param_sds(model, int8=int8_weights)
        params_sh = steps.param_shardings(model, params_sds)
        fn = jax.jit(steps.make_prefill(model, attn_impl=_attn_impl_for(shape),
                                        batch_chunks=microbatches),
                     in_shardings=(params_sh, batch_sh))
        return fn, (params_sds, batch_sds)
    # decode; int8 serving also quantizes the KV cache (per-head scales are
    # O(B*KV) extra — negligible; kernels/quant_matmul holds the real path)
    params_sds = _serve_param_sds(model, int8=int8_weights)
    params_sh = steps.param_shardings(model, params_sds)
    kv_dtype = jnp.int8 if int8_weights else jnp.bfloat16
    cache_sds = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len, kv_dtype))
    cache_sh = steps.cache_shardings(model, cache_sds)
    fn = jax.jit(steps.make_decode_step(model),
                 in_shardings=(params_sh, cache_sh, batch_sh),
                 donate_argnums=(1,))
    return fn, (params_sds, cache_sds, batch_sds)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, with_probes: bool,
             verbose: bool = True, int8_weights: bool = False,
             zero_stage: int = 3, remat="nothing", mesh_shape=None) -> dict:
    cfg = configs.get_config(arch)
    shape = configs.get_shape(shape_name)
    model = get_model(cfg)
    if mesh_shape:
        mesh = jax.make_mesh(tuple(mesh_shape),
                             ("pod", "data", "model")[-len(mesh_shape):])
        mesh_name = "pod" + "x".join(map(str, mesh_shape))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
        mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "chips": int(mesh.devices.size),
           "params_total": cfg.total_params(),
           "params_active": cfg.active_params(),
           "int8_weights": int8_weights, "zero_stage": zero_stage,
           "remat": remat}
    t0 = time.time()
    HBM_BUDGET = 15.5 * 2**30   # v5e 16 GB minus runtime reserve
    # Serving cells: replicate weights across the data axis (SERVE_RULES)
    # whenever the bf16 model fits its 1/TP slice — kills the per-layer FSDP
    # weight all-gathers (hillclimb A); fall back to ZeRO-style fsdp sharding
    # for models too large (dbrx: 263 GB bf16 > 16-way TP slice).
    rules = specs.DEFAULT_RULES
    if shape.kind in ("prefill", "decode"):
        model_axis = 16
        if 2 * cfg.total_params() / model_axis <= 6 * 2**30:
            rules = specs.SERVE_RULES
            rec["serve_rules"] = "model_only"
    with specs.use_mesh(mesh, rules):
        # auto-microbatching: grow gradient-accumulation splits until the
        # per-device footprint fits HBM (production frameworks auto-tune this).
        # A split is only valid if the per-microbatch batch still divides the
        # data axes -- otherwise the batch de-shards and replicates (worse!).
        dp = 1
        for ax in ("pod", "data"):
            if ax in mesh.axis_names:
                dp *= mesh.shape[ax]
        mb_candidates = tuple(
            m for m in (1, 2, 4, 8)
            if (shape.global_batch // max(m, 1)) % dp == 0) or (1,)
        if shape.kind not in ("train", "prefill"):
            mb_candidates = (1,)
        for mb in mb_candidates:
            fn, args = build_gate(model, shape, mesh, microbatches=mb,
                                  int8_weights=int8_weights,
                                  zero_stage=zero_stage, remat=remat)
            compiled = fn.lower(*args).compile()
            m = hlo_stats.memory_stats(compiled)
            footprint = m["argument_bytes"] + m["temp_bytes"] + m["output_bytes"] \
                - m["alias_bytes"]
            if footprint <= HBM_BUDGET or mb == mb_candidates[-1]:
                break
            print(f"  [mb] {arch} x {shape_name}: mb={mb} footprint="
                  f"{footprint/2**30:.1f}GiB > budget; retrying mb={mb*2}",
                  flush=True)
        rec["microbatches"] = mb
        rec["gate"] = {
            "cost": hlo_stats.cost_stats(compiled),
            "memory": hlo_stats.memory_stats(compiled),
            "collectives": hlo_stats.collective_bytes(compiled.as_text()),
        }
        rec["gate"]["compile_s"] = round(time.time() - t0, 1)
        if with_probes:
            rec["probes"] = []
            # windowed archs probe with banded attention (exact sub-quadratic
            # flops); full-attention archs probe with einsum (exact O(S^2))
            probe_attn = "banded" if cfg.window else "einsum"
            for pr in probes_for(model, shape, attn_impl=probe_attn,
                                 remat=(remat if shape.kind == "train" else False),
                                 microbatches=mb, zero_stage=zero_stage):
                t1 = time.time()
                shd = tuple(specs.shardings_for(lg, sd)
                            for lg, sd in zip(pr.shardings, pr.args)) \
                    if pr.shardings else None
                pfn = jax.jit(pr.fn, in_shardings=shd)
                pcomp = pfn.lower(*pr.args).compile()
                rec["probes"].append({
                    "name": pr.name, "mult": pr.mult,
                    "cost": hlo_stats.cost_stats(pcomp),
                    "collectives": hlo_stats.collective_bytes(pcomp.as_text()),
                    "compile_s": round(time.time() - t1, 1),
                })
            rec["recurrence_extra"] = recurrence_extra(cfg, shape, shape.kind)
    rec["wall_s"] = round(time.time() - t0, 1)
    if verbose:
        g = rec["gate"]
        print(f"[OK] {arch} x {shape_name} x {mesh_name}: "
              f"flops={g['cost']['flops']:.3g} bytes={g['cost']['bytes']:.3g} "
              f"coll={g['collectives'].get('total', 0):.3g}B "
              f"arg={g['memory']['argument_bytes']/2**30:.2f}GiB/dev "
              f"temp={g['memory']['temp_bytes']/2**30:.2f}GiB/dev "
              f"({rec['wall_s']}s)", flush=True)
    return rec


def save(rec: dict):
    ART_DIR.mkdir(parents=True, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    (ART_DIR / name).write_text(json.dumps(rec, indent=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true",
                    help="run the 2x16x16 multi-pod mesh (default single-pod)")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--int8-weights", action="store_true")
    ap.add_argument("--zero", type=int, default=3)
    ap.add_argument("--remat", default="nothing")
    ap.add_argument("--mesh-shape", type=int, nargs="*", default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        for cfg, shape, skipped in configs.cells(include_skips=True):
            if skipped:
                print(f"[SKIP] {cfg.name} x {shape.name}: rule-based skip "
                      f"({cfg.notes.split(';')[-1].strip()})", flush=True)
                continue
            cells.append((cfg.name, shape.name))
    else:
        cells.append((args.arch, args.shape))

    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    failures = []
    for arch, shape in cells:
        for mp in meshes:
            mesh_name = "pod2x16x16" if mp else "pod16x16"
            out = ART_DIR / f"{arch}__{shape}__{mesh_name}.json"
            if args.skip_existing and out.exists():
                print(f"[CACHED] {arch} x {shape} x {mesh_name}", flush=True)
                continue
            try:
                # probes only needed on the single-pod mesh (roofline table)
                rec = run_cell(arch, shape, multi_pod=mp,
                               with_probes=(not args.no_probes and not mp),
                               int8_weights=args.int8_weights,
                               zero_stage=args.zero, remat=args.remat,
                               mesh_shape=args.mesh_shape)
                save(rec)
            except Exception as e:
                failures.append((arch, shape, mesh_name, repr(e)))
                print(f"[FAIL] {arch} x {shape} x {mesh_name}: {e}", flush=True)
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", *f[:3], f[3][:200])
        raise SystemExit(1)
    print("\nAll dry-run cells compiled successfully.")


if __name__ == "__main__":
    main()
