"""Per-layer roofline probes.

XLA's ``cost_analysis()`` counts a ``lax.scan`` body ONCE (verified; DESIGN.md
§5), and every LM here scans over layers. So per-cell roofline terms are
composed as::

    total(metric) = full_step(metric) + sum_probes (mult) * probe(metric)
                    + analytic_recurrence_extra

where each probe lowers ONE scan-body worth of computation with pinned
shardings (mult = trip_count - 1), and the analytic extra covers recurrent
scans *inside* a layer (rwkv6 wkv / hymba SSM), whose per-step bodies are
likewise counted once.

Probes intentionally use einsum attention: identical FLOPs to the chunked/
flash path the full-step gate compiles, exact in HLO; HLO 'bytes accessed' for
attention consequently reflects materialized scores — an upper bound vs the
flash kernel; benchmarks/roofline.py substitutes the flash-optimal analytic
bytes for the memory term and reports both.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, Family, ShapeConfig
from repro.models import encdec, hybrid, ssm, transformer
from repro.models.encdec import ENC_LEN
from repro.models.registry import Model
from repro.sharding import specs


@dataclasses.dataclass
class Probe:
    name: str
    mult: int
    fn: Callable
    args: tuple
    shardings: Optional[tuple]   # logical-axis trees matching args


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _layer_param_sds(model: Model, key_name: str, extra_lead: int = 0):
    """SDS tree for ONE scan slice of params[key_name] (drop leading L dim)."""
    full = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    sub = full[key_name]
    drop = 1
    return jax.tree.map(lambda s: _sds(s.shape[drop:], s.dtype), sub)


def _layer_logical(model: Model, key_name: str, zero_stage: int = 3):
    """Logical tree for one scan slice (drop the leading None axis).
    zero_stage=1 strips the 'fsdp' factor (params replicated across data)."""
    lg = model.param_logical()[key_name]
    def fix(ax):
        ax = ax[1:]
        if zero_stage < 3:
            ax = tuple(None if a == "fsdp" else a for a in ax)
        return ax
    return jax.tree.map(fix, lg,
                        is_leaf=lambda v: isinstance(v, tuple) and not isinstance(v, dict))


def _x_sds(B, S, D, dtype):
    return _sds((B, S, D), dtype)


X_LOGICAL = ("batch", "seq_sp", None)


def _grad_wrap(fn, remat: bool):
    """fwd+bwd probe: grad of sum(output) wrt (x, layer_params) — the same
    fwd+recompute+bwd structure the remat'd training scan body has."""
    inner = jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable) \
        if remat else fn

    def probe(x, lp, *rest):
        def scalar(x, lp):
            return jnp.sum(inner(x, lp, *rest).astype(jnp.float32))
        return jax.grad(scalar, argnums=(0, 1))(x, lp)
    return probe


# ------------------------------------------------------------ per family
def probes_for(model: Model, shape: ShapeConfig, *, compute_dtype=jnp.bfloat16,
               attn_impl: str = "einsum", remat: bool = True,
               microbatches: int = 1, zero_stage: int = 3) -> List[Probe]:
    """With gradient-accumulation microbatching, the full graph holds ONE
    microbatch-scan body (itself holding one layer-scan body), so probes run
    at B/microbatches and multiplicities scale by `microbatches`."""
    cfg = model.cfg
    B, S = shape.global_batch, shape.seq_len
    kind = shape.kind
    mb = microbatches if kind in ("train", "prefill") else 1
    B = B // mb
    D = cfg.d_model
    probes: List[Probe] = []

    def _mult(trips: int) -> int:
        return mb * trips - 1

    if cfg.family in (Family.DENSE, Family.MOE):
        lp_sds = _layer_param_sds(model, "layers")
        lp_log = _layer_logical(model, "layers", zero_stage)
        if kind in ("train", "prefill"):
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

            def fwd(x, lp):
                return transformer._layer_apply(cfg, lp, x, positions, attn_impl)[0]
            fn = _grad_wrap(fwd, remat) if kind == "train" else fwd
            probes.append(Probe("layer", _mult(cfg.num_layers), fn,
                                (_x_sds(B, S, D, compute_dtype), lp_sds),
                                (X_LOGICAL, lp_log)))
        else:
            kv, hd = cfg.num_kv_heads, cfg.head_dim
            ck = _sds((B, S, kv, hd), compute_dtype)
            kv_log = model.cache_logical()["k"][1:]   # adaptive (drop L dim)

            def dec(x, lp, ck, cv):
                pos = jnp.asarray(S - 1, jnp.int32)
                positions = jnp.full((B, 1), pos, jnp.int32)
                return transformer._decode_layer(cfg, lp, x, ck, cv, pos, positions)
            probes.append(Probe("layer", _mult(cfg.num_layers), dec,
                                (_x_sds(B, 1, D, compute_dtype), lp_sds, ck, ck),
                                (X_LOGICAL, lp_log, kv_log, kv_log)))

    elif cfg.family == Family.VLM:
        sp_sds = _layer_param_sds(model, "super")
        sp_log = _layer_logical(model, "super", zero_stage)
        n_super = cfg.num_layers // cfg.cross_attn_every
        img = _sds((B, cfg.num_image_tokens, D), compute_dtype)
        if kind in ("train", "prefill"):
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

            def fwd(x, sp, image):
                return transformer._super_apply_unrolled(cfg, sp, x, positions,
                                                         image, attn_impl)
            fn = _grad_wrap(fwd, remat) if kind == "train" else fwd
            probes.append(Probe("super_layer", _mult(n_super), fn,
                                (_x_sds(B, S, D, compute_dtype), sp_sds, img),
                                (X_LOGICAL, sp_log, ("batch", None, None))))
        else:
            kv, hd = cfg.num_kv_heads, cfg.head_dim
            per = cfg.cross_attn_every
            ck = _sds((per, B, S, kv, hd), compute_dtype)

            def dec(x, sp, ck, cv, image):
                pos = jnp.asarray(S - 1, jnp.int32)
                positions = jnp.full((B, 1), pos, jnp.int32)
                return transformer._super_decode_unrolled(cfg, sp, x, ck, cv,
                                                          image, pos, positions)
            kv_log = (None,) + model.cache_logical()["k"][1:]
            probes.append(Probe("super_layer", _mult(n_super), dec,
                                (_x_sds(B, 1, D, compute_dtype), sp_sds, ck, ck, img),
                                (X_LOGICAL, sp_log, kv_log, kv_log,
                                 ("batch", None, None))))

    elif cfg.family == Family.ENCDEC:
        enc_sds = _layer_param_sds(model, "enc_layers")
        enc_log = _layer_logical(model, "enc_layers", zero_stage)
        dec_sds = _layer_param_sds(model, "dec_layers")
        dec_log = _layer_logical(model, "dec_layers", zero_stage)
        Se = ENC_LEN
        enc_pos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), (B, Se))
        if kind in ("train", "prefill"):
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

            def enc_fwd(x, lp):
                return encdec._enc_layer(cfg, lp, x, enc_pos, attn_impl)

            def dec_fwd(x, lp, enc_out):
                return encdec._dec_layer(cfg, lp, x, positions, enc_out,
                                         enc_pos, attn_impl)
            enc_fn = _grad_wrap(enc_fwd, remat) if kind == "train" else enc_fwd
            dec_fn = _grad_wrap(dec_fwd, remat) if kind == "train" else dec_fwd
            probes.append(Probe("enc_layer", _mult(cfg.encoder_layers), enc_fn,
                                (_x_sds(B, Se, D, compute_dtype), enc_sds),
                                (X_LOGICAL, enc_log)))
            probes.append(Probe("dec_layer", _mult(cfg.num_layers), dec_fn,
                                (_x_sds(B, S, D, compute_dtype), dec_sds,
                                 _x_sds(B, Se, D, compute_dtype)),
                                (X_LOGICAL, dec_log, X_LOGICAL)))
        else:
            kv, hd = cfg.num_kv_heads, cfg.head_dim
            ck = _sds((B, S, kv, hd), compute_dtype)
            xk = _sds((B, Se, kv, hd), compute_dtype)

            def dec(x, lp, ck, cv, xk, xv):
                pos = jnp.asarray(S - 1, jnp.int32)
                positions = jnp.full((B, 1), pos, jnp.int32)
                return encdec._decode_layer(cfg, lp, x, ck, cv, xk, xv, pos,
                                            positions, enc_pos)
            cl = model.cache_logical()
            kv_log = cl["k"][1:]
            xkv_log = cl["xk"][1:]
            probes.append(Probe("dec_layer", _mult(cfg.num_layers), dec,
                                (_x_sds(B, 1, D, compute_dtype), dec_sds, ck, ck,
                                 xk, xk),
                                (X_LOGICAL, dec_log, kv_log, kv_log,
                                 xkv_log, xkv_log)))

    elif cfg.family == Family.SSM:
        lp_sds = _layer_param_sds(model, "layers")
        lp_log = _layer_logical(model, "layers", zero_stage)
        H, N = cfg.num_heads, cfg.head_dim
        if kind in ("train", "prefill"):
            def fwd(x, lp):
                return ssm._layer_apply(cfg, lp, x, None, "scan")[0]
            fn = _grad_wrap(fwd, remat) if kind == "train" else fwd
            probes.append(Probe("layer", _mult(cfg.num_layers), fn,
                                (_x_sds(B, S, D, compute_dtype), lp_sds),
                                (X_LOGICAL, lp_log)))
        else:
            st = {"S": _sds((B, H, N, N), jnp.float32),
                  "x_tm": _sds((B, D), jnp.float32),
                  "x_cm": _sds((B, D), jnp.float32)}
            st_log = {"S": ("batch", "heads", None, None),
                      "x_tm": ("batch", None), "x_cm": ("batch", None)}

            def dec(x, lp, st):
                return ssm._layer_apply(cfg, lp, x, st, "scan")
            probes.append(Probe("layer", _mult(cfg.num_layers), dec,
                                (_x_sds(B, 1, D, compute_dtype), lp_sds, st),
                                (X_LOGICAL, lp_log, st_log)))

    elif cfg.family == Family.HYBRID:
        lp_sds = _layer_param_sds(model, "layers")
        lp_log = _layer_logical(model, "layers", zero_stage)
        kv, hd, Nst = cfg.num_kv_heads, cfg.head_dim, cfg.ssm_state
        if kind in ("train", "prefill"):
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

            def fwd(x, lp):
                return hybrid._layer_apply(cfg, lp, x, positions, attn_impl)
            fn = _grad_wrap(fwd, remat) if kind == "train" else fwd
            probes.append(Probe("layer", _mult(cfg.num_layers), fn,
                                (_x_sds(B, S, D, compute_dtype), lp_sds),
                                (X_LOGICAL, lp_log)))
        else:
            W = min(cfg.window, S)
            ck = _sds((B, W, kv, hd), compute_dtype)
            sp = _sds((B, W), jnp.int32)
            hs = _sds((B, D, Nst), jnp.float32)
            cv_t = _sds((B, hybrid.CONV_K - 1, D), jnp.float32)

            def dec(x, lp, ck, cv, spos, hst, conv):
                pos = jnp.asarray(S - 1, jnp.int32)
                positions = jnp.full((B, 1), pos, jnp.int32)
                return hybrid._decode_layer(cfg, lp, x, ck, cv, spos, hst, conv,
                                            pos, positions)
            probes.append(Probe("layer", _mult(cfg.num_layers), dec,
                                (_x_sds(B, 1, D, compute_dtype), lp_sds, ck, ck,
                                 sp, hs, cv_t),
                                (X_LOGICAL, lp_log,
                                 ("batch", None, "kv_heads", None),
                                 ("batch", None, "kv_heads", None),
                                 ("batch", None), ("batch", "d_ff", None),
                                 ("batch", None, None))))
    else:
        raise ValueError(cfg.family)

    # chunked-CE head: its scan body is likewise counted once by HLO
    if kind == "train":
        from repro.launch import steps as _steps
        chunk = min(_steps.CE_CHUNK, S)
        n_chunks = S // chunk
        if mb * n_chunks > 1:
            tied = cfg.tie_embeddings or cfg.family == Family.ENCDEC
            Vp = cfg.padded_vocab
            w_sds = _sds((Vp, D) if tied else (D, Vp), jnp.float32)
            w_log = ("vocab", "fsdp") if tied else ("fsdp", "vocab")
            vocab = cfg.vocab_size

            def head_probe(x_c, w, labels_c):
                f = jax.checkpoint(
                    lambda x, ww: _steps.head_ce_chunk(x, ww, labels_c, vocab, tied),
                    policy=jax.checkpoint_policies.nothing_saveable)
                return jax.grad(f, argnums=(0, 1))(x_c, w)

            probes.append(Probe("head_ce", _mult(n_chunks), head_probe,
                                (_x_sds(B, chunk, D, compute_dtype), w_sds,
                                 _sds((B, chunk), jnp.int32)),
                                (X_LOGICAL, w_log, ("batch", None))))
    return probes


# ------------------------------------------------- analytic recurrence extras
def recurrence_extra(cfg: ArchConfig, shape: ShapeConfig, kind: str) -> dict:
    """FLOPs/bytes of per-token recurrent scans (counted once by HLO, so added
    analytically for train/prefill; decode probes are scan-free and exact)."""
    if kind == "decode" or cfg.family not in (Family.SSM, Family.HYBRID):
        return {"flops": 0.0, "bytes": 0.0}
    tokens = shape.tokens
    mult = 3.0 if kind == "train" else 1.0   # fwd+recompute+bwd
    if cfg.family == Family.SSM:
        H, N = cfg.num_heads, cfg.head_dim
        per_tok = 10.0 * H * N * N           # kv outer + bonus + read + decay-update
    else:
        per_tok = 8.0 * cfg.d_model * cfg.ssm_state
    flops = mult * per_tok * tokens * cfg.num_layers
    # recurrent state stays in VMEM in the chunked kernel; HBM extra ~ 0
    return {"flops": flops, "bytes": 0.0}
