"""Extract roofline inputs from lowered/compiled XLA artifacts:

  * flops / bytes from ``compiled.cost_analysis()``
  * per-collective wire bytes parsed from the (SPMD-partitioned) HLO text —
    the assignment's formula: sum of operand sizes over all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_LINE_RE = re.compile(
    r"=\s+(.+?)\s+(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(-start|-done)?\(")
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """PER-DEVICE wire bytes per collective kind, from the SPMD-partitioned
    HLO (shapes there are per-device shards; operands print as names only, so
    sizes come from the RESULT shape):

      all-reduce / all-to-all / collective-permute : result == operand size
      all-gather                                   : result ~= wire bytes recv
      reduce-scatter                               : operand = result * group

    collective term = per_chip_bytes / link_bw  ==  global/(chips * link_bw).
    Bodies of while loops (lax.scan) appear once — callers compose with trip
    multipliers (launch/probes.py)."""
    out: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _COLL_LINE_RE.search(line)
        if not m:
            continue
        result_ty, kind, suffix = m.group(1), m.group(2), m.group(3)
        if suffix == "-done":
            continue  # async pair: counted at -start
        total = sum(shape_bytes(d, dims)
                    for d, dims in _SHAPE_RE.findall(result_ty))
        if kind == "all-reduce" and suffix == "-start":
            total //= 2  # start result tuples alias (operand, result)
        if kind == "reduce-scatter":
            g = _GROUPS_RE.search(line)
            total *= int(g.group(2)) if g else 1
        out[kind] += total
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return dict(out)


def cost_stats(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}


def memory_stats(compiled) -> Dict[str, int]:
    ma = compiled.memory_analysis()
    return {
        "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
        "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
        "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
        "alias_bytes": int(getattr(ma, "alias_size_in_bytes", 0)),
        "code_bytes": int(getattr(ma, "generated_code_size_in_bytes", 0)),
    }
