"""Fault tolerance & straggler mitigation for long multi-pod runs.

On real fleets, failures surface as (a) raised exceptions / lost heartbeats
from a host, (b) tail-latency steps from a degrading chip. This module gives
the train loop:

  * StragglerDetector — robust per-step-time tracker (median/MAD z-score).
    On TPU fleets the action hook triggers a re-slice request; here it logs
    and records, and the policy object is what tests exercise.
  * RestartPolicy — bounded exponential backoff restart budget.
  * run_with_recovery — drives step_fn with checkpoint/restore + restart
    accounting; simulated-failure tests kill a step and assert bitwise resume.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, List, Optional

log = logging.getLogger("repro.fault")


class StragglerDetector:
    """Flags steps whose duration is a z-score outlier vs the trailing window
    (median/MAD — robust to the compile-step spike)."""

    def __init__(self, window: int = 50, z_threshold: float = 4.0,
                 min_steps: int = 10, action: Optional[Callable] = None):
        self.window = window
        self.z = z_threshold
        self.min_steps = min_steps
        self.times: List[float] = []
        self.flagged: List[int] = []
        self.action = action
        self._step = 0

    def record(self, duration_s: float) -> bool:
        self.times.append(duration_s)
        self.times = self.times[-self.window:]
        self._step += 1
        if len(self.times) < self.min_steps:
            return False
        med = _median(self.times)
        mad = _median([abs(t - med) for t in self.times]) or 1e-9
        is_straggler = (duration_s - med) / (1.4826 * mad) > self.z
        if is_straggler:
            self.flagged.append(self._step)
            log.warning("straggler step %d: %.3fs vs median %.3fs",
                        self._step, duration_s, med)
            if self.action:
                self.action(self._step, duration_s, med)
        return is_straggler


def _median(xs):
    s = sorted(xs)
    n = len(s)
    return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 5
    backoff_s: float = 1.0
    backoff_mult: float = 2.0
    restarts: int = 0

    def on_failure(self, exc: BaseException) -> float:
        """Returns backoff seconds, or raises if the budget is exhausted."""
        self.restarts += 1
        if self.restarts > self.max_restarts:
            raise RuntimeError(
                f"restart budget exhausted ({self.max_restarts})") from exc
        return self.backoff_s * (self.backoff_mult ** (self.restarts - 1))


def run_with_recovery(*, num_steps: int, step_fn: Callable[[int], dict],
                      save_fn: Callable[[int], None],
                      restore_fn: Callable[[], int],
                      checkpoint_every: int = 50,
                      policy: Optional[RestartPolicy] = None,
                      detector: Optional[StragglerDetector] = None,
                      sleep=time.sleep) -> dict:
    """Checkpointed step loop: on any step exception, back off, restore the
    latest checkpoint, and continue from its step. Returns run stats."""
    policy = policy or RestartPolicy()
    detector = detector or StragglerDetector()
    step = restore_fn()
    failures = 0
    while step < num_steps:
        try:
            t0 = time.time()
            step_fn(step)
            detector.record(time.time() - t0)
            step += 1
            if step % checkpoint_every == 0 or step == num_steps:
                save_fn(step)
        except Exception as exc:   # noqa: BLE001 — any step failure
            failures += 1
            backoff = policy.on_failure(exc)
            log.warning("step %d failed (%s); restoring after %.1fs",
                        step, exc, backoff)
            sleep(backoff)
            step = restore_fn()
    return {"final_step": step, "failures": failures,
            "restarts": policy.restarts, "stragglers": len(detector.flagged)}
