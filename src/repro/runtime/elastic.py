"""Elastic scaling: resume a run on a different device count / mesh shape.

The checkpoint stores full logical arrays (checkpoint/manager.py), so scaling
is a matter of (1) choosing a new mesh for the surviving devices, (2) building
shardings for that mesh, (3) device_put on restore. ``choose_mesh_shape``
picks the (data, model) factorization for an arbitrary surviving chip count,
preferring to shrink the data axis first (keeps TP intact so per-chip layer
shards — and therefore compiled kernels' tile sizes — are unchanged).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.sharding import specs


def choose_mesh_shape(n_devices: int, *, model_parallel: int = 16,
                      with_pod_axis: bool = False) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Largest (data, model) grid with model axis <= model_parallel that
    divides n_devices; shrinks model parallelism only when unavoidable."""
    mp = min(model_parallel, n_devices)
    while mp > 1 and n_devices % mp:
        mp //= 2
    dp = n_devices // mp
    if with_pod_axis and dp % 2 == 0 and dp > 1:
        return (2, dp // 2, mp), ("pod", "data", "model")
    return (dp, mp), ("data", "model")


def remesh(n_devices: Optional[int] = None, *, model_parallel: int = 16):
    devs = jax.devices()[: (n_devices or len(jax.devices()))]
    shape, axes = choose_mesh_shape(len(devs), model_parallel=model_parallel)
    import numpy as np
    return jax.sharding.Mesh(np.asarray(devs).reshape(shape), axes)


def elastic_restore(manager, model, optimizer, *, mesh, step=None):
    """Restore a train state onto `mesh` (any shape). Returns (state, meta)."""
    from repro.launch import steps as steps_mod
    with specs.use_mesh(mesh):
        state_sds = jax.eval_shape(
            lambda k: steps_mod.init_train_state(model, optimizer, k),
            jax.random.PRNGKey(0))
        shardings = steps_mod.state_shardings(model, state_sds)
        return manager.restore(step, shardings=shardings)
