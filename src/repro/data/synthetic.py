"""Deterministic synthetic data pipelines (no network access in this
environment): token streams for LM training and a CIFAR-like separable image
task for the paper's ResNet20 experiments.

The token pipeline is a real input pipeline, not a stub: deterministic
per-step RNG (restart-safe — resuming at step k reproduces the same batch),
host-side prefetch thread, and device sharding via jax.device_put when a mesh
is active.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding import specs


class TokenStream:
    """Markov-chain token stream: next-token structure exists, so CE loss
    falling below log(vocab) demonstrates actual learning."""

    def __init__(self, vocab: int, batch: int, seq_len: int, seed: int = 0,
                 branching: int = 32):
        self.vocab, self.batch, self.seq_len = vocab, batch, seq_len
        self.seed = seed
        rng = np.random.default_rng(seed)
        # sparse transition table: each token can be followed by `branching`
        self.next_tokens = rng.integers(0, vocab, size=(vocab, branching),
                                        dtype=np.int32)

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        toks = np.empty((self.batch, self.seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, self.batch)
        choices = rng.integers(0, self.next_tokens.shape[1],
                               size=(self.batch, self.seq_len))
        for t in range(self.seq_len):
            toks[:, t + 1] = self.next_tokens[toks[:, t], choices[:, t]]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Host-side prefetch: overlaps batch generation with device compute."""

    def __init__(self, it: Iterator, depth: int = 2, sharding=None):
        self.it = it
        self.sharding = sharding
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._worker, daemon=True)
        self.thread.start()

    def _worker(self):
        for item in self.it:
            if self._stop.is_set():
                return
            if self.sharding is not None:
                item = jax.tree.map(
                    lambda x, s: jax.device_put(x, s), item, self.sharding)
            self.q.put(item)

    def __iter__(self):
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            self.q.get_nowait()
        except queue.Empty:
            pass


def make_lm_pipeline(vocab: int, global_batch: int, seq_len: int, *,
                     seed: int = 0, start_step: int = 0, prefetch: int = 2):
    stream = TokenStream(vocab, global_batch, seq_len, seed)

    def gen():
        step = start_step
        while True:
            yield stream.batch_at(step)
            step += 1

    sharding = None
    if specs.active_mesh() is not None:
        sharding = {"tokens": specs.named_sharding("batch", None),
                    "labels": specs.named_sharding("batch", None)}
    return Prefetcher(gen(), depth=prefetch, sharding=sharding)


# ----------------------------------------------------------- CIFAR-like task
def synthetic_cifar(n: int, *, seed: int = 0, num_classes: int = 10,
                    image_size: int = 32, template_seed: int = 0):
    """Separable image classification task with CIFAR-10 geometry: each class
    is a smooth random template + noise. ResNet20 trains to high accuracy in a
    few hundred steps on CPU, enabling the paper's quantization-accuracy
    experiment (92%->90% story) without the real dataset.

    Class templates come from `template_seed` (fixed across train/test splits);
    `seed` only draws the samples/noise."""
    rng_t = np.random.default_rng(template_seed)
    rng = np.random.default_rng(seed)
    base = rng_t.normal(0, 1, size=(num_classes, image_size, image_size, 3))
    # low-pass the templates so convs have spatial structure to find
    k = np.ones((5, 5)) / 25.0
    from numpy.lib.stride_tricks import sliding_window_view
    pad = np.pad(base, ((0, 0), (2, 2), (2, 2), (0, 0)), mode="edge")
    win = sliding_window_view(pad, (5, 5), axis=(1, 2))
    base = np.einsum("cijdkl,kl->cijd", win, k)
    labels = rng.integers(0, num_classes, n).astype(np.int32)
    images = base[labels] + rng.normal(0, 0.6, size=(n, image_size, image_size, 3))
    return images.astype(np.float32), labels
