"""CIFAR-10 *binary* format reader — the exact format the paper streams from
the ZCU104's SD card (§4.1: "We will use the binary format that is more
suitable for the embedded application").

Each record: 1 label byte + 3072 image bytes (3 x 32 x 32, channel-planar).
Files: data_batch_{1..5}.bin (train), test_batch.bin (10k test records).
"""
from __future__ import annotations

import pathlib
from typing import Tuple

import numpy as np

RECORD_BYTES = 1 + 3 * 32 * 32
CIFAR10_MEAN = np.array([0.4914, 0.4822, 0.4465], np.float32)
CIFAR10_STD = np.array([0.2470, 0.2435, 0.2616], np.float32)


def read_binary(path) -> Tuple[np.ndarray, np.ndarray]:
    """-> images (N,32,32,3) float32 in [0,1]; labels (N,) int32."""
    raw = np.frombuffer(pathlib.Path(path).read_bytes(), np.uint8)
    assert raw.size % RECORD_BYTES == 0, f"corrupt CIFAR binary: {path}"
    rec = raw.reshape(-1, RECORD_BYTES)
    labels = rec[:, 0].astype(np.int32)
    imgs = rec[:, 1:].reshape(-1, 3, 32, 32).transpose(0, 2, 3, 1)
    return imgs.astype(np.float32) / 255.0, labels


def write_binary(path, images: np.ndarray, labels: np.ndarray):
    """Inverse of read_binary (used by tests and the synthetic-CIFAR bridge)."""
    imgs = np.clip(images * 255.0, 0, 255).astype(np.uint8)
    imgs = imgs.transpose(0, 3, 1, 2).reshape(len(labels), -1)
    rec = np.concatenate([labels.astype(np.uint8)[:, None], imgs], axis=1)
    pathlib.Path(path).write_bytes(rec.tobytes())


def normalize(images: np.ndarray) -> np.ndarray:
    return (images - CIFAR10_MEAN) / CIFAR10_STD


def batches(images, labels, batch_size: int, *, seed: int = 0, train: bool = True):
    n = len(labels)
    rng = np.random.default_rng(seed)
    while True:
        idx = rng.permutation(n) if train else np.arange(n)
        for i in range(0, n - batch_size + 1, batch_size):
            sel = idx[i:i + batch_size]
            yield normalize(images[sel]), labels[sel]
        if not train:
            return
