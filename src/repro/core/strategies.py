"""The paper's four optimization rungs as planner configurations.

Budgets are scaled analogues of the paper's on-chip memory ladder
(§4.1: 16 KV local + 4 KV acc BRAM; §4.3: +48 KV URAM => 3.4x capacity):

  baseline              small budget, no overlap   (§4.1, 133.54 FPS)
  dual_clock            small budget, overlap      (§4.2, 152.04 FPS)
  ultra_ram             large budget, overlap      (§4.3, 170.16 FPS)
  compiler_large_local  large budget, overlap, residency (§4.4, 293.58 FPS)

On the FPGA the budgets are BRAM/URAM KV counts; on TPU they are VMEM bytes.
Both hardware profiles are exposed so the analytic perf model (perfmodel.py)
can reproduce the paper's ladder on ZCU104 constants and project it on v5e.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import MemoryStrategy
from repro.core.planner import PlannerConfig

KV_BYTES = 1024 * 32 * 2          # paper: 1 KV = 1024 vectors x 32 lanes x 16 bit


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    name: str
    peak_flops: float             # at the op's compute dtype
    hbm_bw: float                 # bytes/s (baseline clock domain)
    hbm_bw_fast: float            # bytes/s with the dual-clock/wide-port path
    local_small: int              # bytes: baseline local memory
    local_large: int              # bytes: ultra-RAM-augmented local memory
    mxu: int                      # systolic array edge
    watts: float                  # on-chip power for GOPs/W projections


# ZCU104 / Tensil (paper §4-5): 32x32 MAC @ 100 MHz, 16-bit => 204.8 GOP/s peak.
# AXI 128-bit @ 100 MHz x 2 ports = 3.2 GB/s; dual clock 333 MHz => 10.66 GB/s.
# Local: 16 KV + 4 KV = 20 KV BRAM; + 48 KV URAM = 68 KV (§4.3, Table 1).
ZCU104 = HardwareProfile(
    name="zcu104-tensil",
    peak_flops=32 * 32 * 2 * 100e6,
    hbm_bw=3.2e9, hbm_bw_fast=10.66e9,
    local_small=20 * KV_BYTES, local_large=68 * KV_BYTES,
    mxu=32, watts=5.21,
)

# TPU v5e (assignment constants): 197 TFLOP/s bf16, 819 GB/s HBM.
# VMEM budgets: a conservative 1/4 of VMEM for the baseline rung and the
# full ~64 MiB working budget for the ultra_ram rung.
TPU_V5E = HardwareProfile(
    name="tpu-v5e",
    peak_flops=197e12,
    hbm_bw=819e9, hbm_bw_fast=819e9,   # no second clock domain on TPU
    local_small=16 * 2**20, local_large=64 * 2**20,
    mxu=128, watts=200.0,
)

HW_PROFILES = {p.name: p for p in (ZCU104, TPU_V5E)}


def planner_config(strategy: MemoryStrategy, hw: HardwareProfile) -> PlannerConfig:
    s = MemoryStrategy(strategy)
    if s == MemoryStrategy.BASELINE:
        return PlannerConfig(vmem_budget=hw.local_small, overlap=False,
                             dataflow="weight_stationary", mxu=hw.mxu)
    if s == MemoryStrategy.DUAL_CLOCK:
        return PlannerConfig(vmem_budget=hw.local_small, overlap=True,
                             dataflow="weight_stationary", mxu=hw.mxu)
    if s == MemoryStrategy.ULTRA_RAM:
        return PlannerConfig(vmem_budget=hw.local_large, overlap=True,
                             dataflow="weight_stationary", mxu=hw.mxu)
    if s == MemoryStrategy.COMPILER_LARGE_LOCAL:
        return PlannerConfig(vmem_budget=hw.local_large, overlap=True,
                             dataflow="auto", allow_resident=True, mxu=hw.mxu)
    raise ValueError(strategy)


def mem_bandwidth(strategy: MemoryStrategy, hw: HardwareProfile) -> float:
    """Dual-clock and later rungs use the fast (wide/2nd-clock) memory path."""
    return hw.hbm_bw if MemoryStrategy(strategy) == MemoryStrategy.BASELINE \
        else hw.hbm_bw_fast
