"""Analytic performance model reproducing the paper's FPS ladder (Fig. 6).

The model is a *network-level* traffic/compute model on top of the capacity
planner, mirroring how the Tensil compiler actually schedules per strategy:

  baseline              every layer round-trips DRAM (weights + in/out
                        activations per image), movement NOT overlapped with
                        compute, slow (100 MHz) memory path, per load-compute-
                        save block a fixed DRAM/instruction overhead.
  dual_clock            same traffic, but movement overlaps compute (second
                        clock domain + wider AXI -> faster memory path).
  ultra_ram             larger local memory: inter-layer activations that fit
                        stay on chip (no spill), partition reloads vanish.
  compiler_large_local  whole-model residency (§4.4): weights pinned on-chip
                        and amortized across images; only the input image and
                        the logits cross DRAM.

time(strategy) = sum_l combine(t_c, t_m) + n_dram_blocks * block_overhead
  t_c = flops_l / (peak * efficiency); t_m = traffic_l / bw(strategy)
  combine = '+' for baseline (no overlap), 'max' otherwise.

Hardware constants (efficiency, bw_slow, bw_fast, block_overhead) are fitted
once against the paper's four measured FPS points (calibrate()); the planner's
traffic/stage structure is NOT fitted — so the fit quality directly validates
the paper's mechanism. The v5e projection uses independent datasheet constants.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
from typing import List, Sequence

from repro.configs.base import MemoryStrategy
from repro.core.dataflow import Gemm
from repro.core.planner import PlannerConfig, plan_gemm
from repro.core.strategies import HardwareProfile, ZCU104, planner_config

LADDER_ORDER = (MemoryStrategy.BASELINE, MemoryStrategy.DUAL_CLOCK,
                MemoryStrategy.ULTRA_RAM, MemoryStrategy.COMPILER_LARGE_LOCAL)

# Paper-reported reference points (Fig. 6 / Tables 2-3) for validation.
PAPER_FPS = {
    "baseline": 133.54,
    "dual_clock": 152.04,
    "ultra_ram": 170.16,
    "compiler_large_local": 293.58,
}
PAPER_GOPS = 21.12
PAPER_WATTS = 5.21
PAPER_ACCURACY = {"fp32": 0.92, "fixed16": 0.90}


@dataclasses.dataclass(frozen=True)
class FitConstants:
    efficiency: float        # achieved fraction of peak MACs
    bw_slow: float           # bytes/s, single-clock path
    bw_fast: float           # bytes/s, dual-clock/wide path
    block_overhead: float    # s per load-compute-save block (DRAM latency+issue)


# Defaults in the right physical regime before calibration
# (AXI 128b@100MHz ~1.1 GB/s effective; 333 MHz ~2.5x; Tensil eff ~0.12).
DEFAULT_FIT = FitConstants(efficiency=0.12, bw_slow=1.1e9, bw_fast=2.6e9,
                           block_overhead=20e-6)

V5E_FIT = FitConstants(efficiency=0.55, bw_slow=819e9, bw_fast=819e9,
                       block_overhead=2e-6)


@dataclasses.dataclass(frozen=True)
class StrategyEval:
    strategy: str
    fps: float
    gops: float
    gops_per_watt: float
    t_compute: float
    t_mem: float
    traffic: int
    blocks: int
    bottleneck: str


@functools.lru_cache(maxsize=None)
def _layer_traffic(g: Gemm, strategy: MemoryStrategy, cfg: PlannerConfig,
                   amortize_weights: bool) -> tuple:
    """(bytes moved for this layer per image, dram blocks).

    Memoized: traffic depends only on (gemm, strategy, planner config) — all
    frozen/hashable — and NOT on the FitConstants being searched, so
    ``calibrate()``'s grid search prices thousands of candidate fits without
    re-running the partition planner (~20x faster calibration)."""
    plan = plan_gemm(g, cfg)
    p = plan.partitions
    w = 0 if amortize_weights else g.w_size
    ws_layer = g.w_size + g.in_raw + g.out_raw
    resident_ok = ws_layer <= cfg.vmem_budget
    if strategy in (MemoryStrategy.BASELINE, MemoryStrategy.DUAL_CLOCK):
        # always spills activations; partitions reload inputs (paper Fig. 3)
        traffic = w + p * g.in_raw + g.out_raw
        blocks = max(p, 1) * max(plan.stages, 1)
    elif strategy == MemoryStrategy.ULTRA_RAM:
        # larger memory: single partition for anything that fits; activations
        # still round-trip (weight-stationary compiler, §4.3)
        traffic = w + g.in_raw + g.out_raw
        blocks = max(plan.stages, 1)
    else:  # COMPILER_LARGE_LOCAL
        traffic = (0 if resident_ok else w + g.in_raw + g.out_raw)
        blocks = 1
    return traffic, blocks


def evaluate(gemms: Sequence[Gemm], strategy: MemoryStrategy,
             hw: HardwareProfile = ZCU104, fit: FitConstants = DEFAULT_FIT,
             *, io_bytes: int = 32 * 32 * 3 * 2 + 10 * 4) -> StrategyEval:
    strategy = MemoryStrategy(strategy)
    cfg = planner_config(strategy, hw)
    overlap = strategy != MemoryStrategy.BASELINE
    bw = fit.bw_slow if strategy == MemoryStrategy.BASELINE else fit.bw_fast
    amortize = strategy == MemoryStrategy.COMPILER_LARGE_LOCAL
    t_total = t_c_sum = t_m_sum = 0.0
    traffic_sum = 0
    blocks_sum = 0
    for g in gemms:
        traffic, blocks = _layer_traffic(g, strategy, cfg, amortize)
        t_c = g.flops / (hw.peak_flops * fit.efficiency)
        t_m = traffic / bw
        t_total += max(t_c, t_m) if overlap else (t_c + t_m)
        t_c_sum += t_c
        t_m_sum += t_m
        traffic_sum += traffic
        blocks_sum += blocks
    t_total += blocks_sum * fit.block_overhead + io_bytes / bw
    fps = 1.0 / t_total
    flops = sum(g.flops for g in gemms)
    gops = flops * fps / 1e9
    return StrategyEval(strategy=strategy.value, fps=fps, gops=gops,
                        gops_per_watt=gops / hw.watts, t_compute=t_c_sum,
                        t_mem=t_m_sum, traffic=traffic_sum, blocks=blocks_sum,
                        bottleneck="compute" if t_c_sum >= t_m_sum else "memory")


def ladder(gemms: Sequence[Gemm], hw: HardwareProfile = ZCU104,
           fit: FitConstants = DEFAULT_FIT) -> List[StrategyEval]:
    return [evaluate(gemms, s, hw, fit) for s in LADDER_ORDER]


def calibrate(gemms: Sequence[Gemm], hw: HardwareProfile = ZCU104,
              targets=PAPER_FPS) -> FitConstants:
    """Fit the four hardware constants to the paper's measured ladder by
    coarse-to-fine grid search on relative FPS error."""
    best, best_err = DEFAULT_FIT, float("inf")
    effs = [0.06, 0.08, 0.10, 0.117, 0.13, 0.15, 0.2, 0.3]
    slows = [0.1e9, 0.2e9, 0.35e9, 0.6e9, 0.9e9, 1.1e9, 1.4e9, 1.8e9]
    fasts = [0.2e9, 0.35e9, 0.6e9, 1.0e9, 1.4e9, 2.0e9, 2.6e9, 3.4e9, 5.0e9]
    ovhs = [2e-6, 5e-6, 10e-6, 20e-6, 40e-6, 80e-6, 160e-6]
    for eff, bs, bf, ov in itertools.product(effs, slows, fasts, ovhs):
        # physical constraint: dual-clock path is 1-3.4x the single-clock path
        if not (bs <= bf <= 3.4 * bs):
            continue
        fit = FitConstants(eff, bs, bf, ov)
        err = 0.0
        for s in LADDER_ORDER:
            pred = evaluate(gemms, s, hw, fit).fps
            tgt = targets[s.value]
            err += ((pred - tgt) / tgt) ** 2
        if err < best_err:
            best, best_err = fit, err
    # refine efficiency & overhead locally (keep fast path >= slow path)
    for eff in [best.efficiency * f for f in (0.85, 0.93, 1.0, 1.08, 1.15)]:
        for ov in [best.block_overhead * f for f in (0.5, 0.75, 1.0, 1.33, 2.0)]:
            for bs in [best.bw_slow * f for f in (0.8, 0.9, 1.0, 1.1, 1.25)]:
                for bf in [best.bw_fast * f for f in (0.8, 0.9, 1.0, 1.1, 1.25)]:
                    if bf < bs:
                        continue
                    fit = FitConstants(eff, bs, bf, ov)
                    err = sum(((evaluate(gemms, s, hw, fit).fps - targets[s.value])
                               / targets[s.value]) ** 2 for s in LADDER_ORDER)
                    if err < best_err:
                        best, best_err = fit, err
    return best


# ---------------------------------------------------------------- serving
def decode_roofline(n_params: int, hw: HardwareProfile = ZCU104,
                    fit: FitConstants = DEFAULT_FIT,
                    bytes_per_param: int = 2) -> dict:
    """Analytic tokens/s ceiling for batch-1 autoregressive decode — the
    serving-side counterpart of the FPS ladder. Each generated token
    touches every live parameter once: 2 FLOPs per MAC on the compute
    side, ``bytes_per_param`` of weight traffic on the memory side (KV
    reads are second-order for the model sizes served here), so

        compute_bound = peak_flops * efficiency / (2 * n_params)
        memory_bound  = bw_fast / (n_params * bytes_per_param)

    and the roofline is their min. The serve bench uses this as a sanity
    ceiling: measured open-loop GOODPUT can never exceed the roofline of a
    profile calibrated from the same machine's closed-loop capacity —
    queueing and SLO misses only ever subtract."""
    if n_params <= 0:
        raise ValueError(f"n_params must be positive, got {n_params}")
    compute = hw.peak_flops * fit.efficiency / (2.0 * n_params)
    memory = fit.bw_fast / (n_params * bytes_per_param)
    return {
        "n_params": int(n_params),
        "compute_tokens_per_s": compute,
        "memory_tokens_per_s": memory,
        "tokens_per_s": min(compute, memory),
        "bound": "compute" if compute <= memory else "memory",
    }
