"""Dataflow traffic models for tiled GEMM on a systolic array.

Exact HBM(DRAM)<->local-memory byte counts for each dataflow of a tiled
(M,K)x(K,N) matmul with tiles (bm, bk, bn) — the quantities the Tensil
compiler implicitly trades when it splits a layer into stages/partitions
(paper §4.3 Figs 3-4), made explicit:

  output_stationary: A streamed once per N-tile, W once per M-tile, O written once.
  weight_stationary: W loaded ONCE (Tensil's dataflow: "weights loaded only
      once, activations re-loaded"), A re-streamed per N-tile, O partials
      re-streamed per K-tile (read+write).
  input_stationary:  A loaded once (the paper's future-work dataflow), W
      re-streamed per M-tile, O partials re-streamed per K-tile.
  resident:          everything fits local memory -> each tensor moves once
      (paper §4.4, the "compiler strategy with large local memory").
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

DATAFLOWS = ("output_stationary", "weight_stationary", "input_stationary",
             "resident")


@dataclasses.dataclass(frozen=True)
class Gemm:
    """One layer as a GEMM (convs arrive here via im2col, attention via
    per-head GEMMs). ``in_elems``/``out_elems`` are the *raw* inter-layer
    activation element counts (pre-im2col) used by the network-level
    residency/spill model; they default to the GEMM operand sizes."""
    name: str
    m: int
    k: int
    n: int
    act_bytes: int = 2      # bf16 activations (paper: 16-bit fixed)
    weight_bytes: int = 2   # bf16 / int8 (quantized) weights
    out_bytes: int = 2
    acc_bytes: int = 4      # fp32 accumulators
    in_elems: int = 0       # raw input activation elements (0 => m*k)
    out_elems: int = 0      # raw output activation elements (0 => m*n)

    @property
    def in_raw(self) -> int:
        return (self.in_elems or self.m * self.k) * self.act_bytes

    @property
    def out_raw(self) -> int:
        return (self.out_elems or self.m * self.n) * self.out_bytes

    @property
    def macs(self) -> int:
        return self.m * self.k * self.n

    @property
    def flops(self) -> int:
        return 2 * self.macs

    @property
    def a_size(self) -> int:
        return self.m * self.k * self.act_bytes

    @property
    def w_size(self) -> int:
        return self.k * self.n * self.weight_bytes

    @property
    def o_size(self) -> int:
        return self.m * self.n * self.out_bytes


@dataclasses.dataclass(frozen=True)
class Tiling:
    bm: int
    bk: int
    bn: int

    def grid(self, g: Gemm) -> Tuple[int, int, int]:
        return (math.ceil(g.m / self.bm), math.ceil(g.k / self.bk),
                math.ceil(g.n / self.bn))

    def vmem_bytes(self, g: Gemm, double_buffer: bool) -> int:
        """Working set: one tile of each operand + fp32 accumulator tile.
        Double buffering doubles the *streamed* operands (not the accumulator),
        exactly like the paper's dual-clock second bank."""
        mult = 2 if double_buffer else 1
        a = self.bm * self.bk * g.act_bytes * mult
        w = self.bk * self.bn * g.weight_bytes * mult
        o = self.bm * self.bn * g.acc_bytes
        return a + w + o


def traffic_bytes(g: Gemm, t: Tiling, dataflow: str) -> int:
    """Total HBM bytes moved for the full GEMM under a dataflow."""
    nm, nk, nn = t.grid(g)
    if dataflow == "resident":
        return g.a_size + g.w_size + g.o_size
    if dataflow == "output_stationary":
        return g.a_size * nn + g.w_size * nm + g.o_size
    if dataflow == "weight_stationary":
        partial = g.m * g.n * g.acc_bytes
        return g.w_size + g.a_size * nn + partial * nk + partial * max(nk - 1, 0)
    if dataflow == "input_stationary":
        partial = g.m * g.n * g.acc_bytes
        return g.a_size + g.w_size * nm + partial * nk + partial * max(nk - 1, 0)
    raise ValueError(dataflow)


def reload_factor(g: Gemm, t: Tiling, dataflow: str) -> float:
    """How many times the average byte is moved vs the resident optimum —
    the paper's Fig 3 'same input activations are loaded multiple times'."""
    opt = g.a_size + g.w_size + g.o_size
    return traffic_bytes(g, t, dataflow) / opt
