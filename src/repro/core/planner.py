"""Capacity-aware partition planner — the Tensil compiler's stage/partition
model (paper §4.3-4.4) reimplemented against TPU VMEM.

Given a layer (GEMM), a local-memory budget, and a strategy, the planner:
  1. enumerates MXU-aligned tile shapes that fit the budget (with double
     buffering when the strategy overlaps movement and compute),
  2. prices each (tiling, dataflow) by its HBM traffic (core/dataflow.py),
  3. emits a MemoryPlan: tile shapes for the Pallas kernel, the Tensil-style
     (stages, partitions) decomposition, predicted traffic and arithmetic
     intensity.

A whole-network plan (plan_network) reproduces the paper's compilation story:
small budget -> multi-stage multi-partition (Fig 3); large budget -> single
stage/partition (Fig 4); 'compiler_large_local' additionally pins weights
resident when the whole layer fits (§4.4).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

from repro.core.dataflow import DATAFLOWS, Gemm, Tiling, reload_factor, traffic_bytes

MXU_DIM = 128   # v5e systolic array edge (paper's array is 32x32)


@dataclasses.dataclass(frozen=True)
class PlannerConfig:
    vmem_budget: int            # bytes of local memory available to one op
    overlap: bool               # dual-clock analogue: double-buffer + overlap
    dataflow: str = "auto"      # force a dataflow or 'auto'
    allow_resident: bool = False  # §4.4 whole-layer residency
    mxu: int = MXU_DIM


@dataclasses.dataclass(frozen=True)
class MemoryPlan:
    gemm: Gemm
    tiling: Tiling
    dataflow: str
    stages: int                 # Tensil: unique weight subsets loaded
    partitions: int             # Tensil: activation/output splits per stage
    traffic: int                # predicted HBM bytes
    vmem_used: int
    reload: float               # traffic / resident-optimum

    @property
    def arithmetic_intensity(self) -> float:
        return self.gemm.flops / max(self.traffic, 1)


def _aligned_sizes(dim: int, mxu: int) -> List[int]:
    """Candidate tile sizes: MXU multiples up to dim (plus dim itself)."""
    out = []
    step = mxu
    s = step
    while s < dim:
        out.append(s)
        s *= 2
    out.append(_round_up(dim, mxu) if dim > mxu else mxu)
    return sorted(set(out))


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def plan_gemm(g: Gemm, cfg: PlannerConfig) -> MemoryPlan:
    """Choose (tiling, dataflow) minimizing traffic under the VMEM budget."""
    # §4.4 residency: whole layer fits -> single stage, single partition
    whole = (g.a_size + g.w_size + g.m * g.n * g.acc_bytes)
    if cfg.allow_resident and whole <= cfg.vmem_budget:
        t = Tiling(_round_up(g.m, cfg.mxu), _round_up(g.k, cfg.mxu),
                   _round_up(g.n, cfg.mxu))
        return MemoryPlan(g, t, "resident", 1, 1, traffic_bytes(g, t, "resident"),
                          whole, 1.0)

    flows = DATAFLOWS[:-1] if cfg.dataflow == "auto" else (cfg.dataflow,)
    best: Optional[MemoryPlan] = None
    for bm in _aligned_sizes(g.m, cfg.mxu):
        for bk in _aligned_sizes(g.k, cfg.mxu):
            for bn in _aligned_sizes(g.n, cfg.mxu):
                t = Tiling(bm, bk, bn)
                used = t.vmem_bytes(g, double_buffer=cfg.overlap)
                if used > cfg.vmem_budget:
                    continue
                for df in flows:
                    traf = traffic_bytes(g, t, df)
                    if best is None or traf < best.traffic or (
                            traf == best.traffic and used > best.vmem_used):
                        nm, nk, nn = t.grid(g)
                        # Tensil semantics: a stage loads one unique weight
                        # subset (one (bk,bn) tile); each stage splits the
                        # activation side into partitions ((bm) tiles).
                        stages = nk * nn
                        partitions = nm
                        best = MemoryPlan(g, t, df, max(stages, 1),
                                          max(partitions, 1), traf, used,
                                          reload_factor(g, t, df))
    if best is None:
        raise ValueError(
            f"no tiling of {g.name} ({g.m}x{g.k}x{g.n}) fits budget "
            f"{cfg.vmem_budget} bytes (min tile {cfg.mxu})")
    return best


def plan_network(gemms: Sequence[Gemm], cfg: PlannerConfig) -> List[MemoryPlan]:
    return [plan_gemm(g, cfg) for g in gemms]


def network_traffic(plans: Sequence[MemoryPlan]) -> int:
    return sum(p.traffic for p in plans)


def network_flops(plans: Sequence[MemoryPlan]) -> int:
    return sum(p.gemm.flops for p in plans)
