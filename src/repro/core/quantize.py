"""Quantization (paper C5): fp32 -> 16-bit fixed point (the paper's numeric
scheme, emulated bit-exactly) and int8 per-channel PTQ (the TPU-idiomatic
deployment path feeding kernels/quant_matmul.py).

The paper's headline: CIFAR-10 accuracy drops only 92% -> 90% when rounding
fp32 down to 16-bit fixed point. tests/test_quantize.py reproduces the
"<= 2% drop" claim on our trained ResNet20.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp


# ------------------------------------------------------- fixed point (paper)
def fixed_point(x, int_bits: int = 4, frac_bits: int = 11):
    """Round to signed 16-bit fixed point Q(int_bits).(frac_bits) (1 sign bit).
    Tensil's 16-bit fixed default is Q4.11-like."""
    scale = 2.0 ** frac_bits
    lo = -(2.0 ** (int_bits + frac_bits))
    hi = 2.0 ** (int_bits + frac_bits) - 1
    q = jnp.clip(jnp.round(x * scale), lo, hi)
    return q / scale


def fixed_point_tree(tree, int_bits: int = 4, frac_bits: int = 11):
    return jax.tree.map(
        lambda t: fixed_point(t, int_bits, frac_bits)
        if jnp.issubdtype(t.dtype, jnp.floating) else t, tree)


# ------------------------------------------------------------- int8 PTQ
@dataclasses.dataclass
class QuantizedTensor:
    q: jax.Array          # int8
    scale: jax.Array      # per-channel (last dim) fp32

    def dequant(self, dtype=jnp.float32):
        return (self.q.astype(jnp.float32) * self.scale).astype(dtype)


def page_scale(amax):
    """Symmetric int8 scale for a quantization block with max-abs ``amax``.

    An all-zero block (a freshly-released KV page, a fully-masked row group)
    has amax == 0; dividing by amax/127 would produce inf/NaN scales that
    poison every later dequant. Such blocks get scale 1.0 — their quantized
    payload is all zeros, so dequant returns exact zeros either way."""
    amax = jnp.asarray(amax, jnp.float32)
    return jnp.where(amax > 0, amax / 127.0, 1.0)


def quantize_page(x, valid=None) -> Tuple[jax.Array, jax.Array]:
    """Quantize one block (e.g. a KV page) to int8 with ONE symmetric scale.

    ``valid`` optionally masks rows along the leading axis (a partial page:
    only rows below the write frontier are content); masked rows are
    excluded from the amax and stored as 0. Returns ``(q int8, scale f32
    scalar)``; dequant is ``q.astype(f32) * scale``."""
    x = jnp.asarray(x, jnp.float32)
    if valid is not None:
        vm = jnp.reshape(jnp.asarray(valid, bool),
                         (-1,) + (1,) * (x.ndim - 1))
        x = jnp.where(vm, x, 0.0)
    scale = page_scale(jnp.max(jnp.abs(x)))
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def quantize_per_channel(w, axis: int = -1) -> QuantizedTensor:
    """Symmetric int8 per-output-channel quantization along `axis`."""
    amax = jnp.max(jnp.abs(w), axis=tuple(i for i in range(w.ndim) if i != axis % w.ndim),
                   keepdims=True)
    scale = page_scale(amax)
    q = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
    return QuantizedTensor(q=q, scale=scale.astype(jnp.float32))


def quantize_params(params, *, predicate: Optional[Callable[[str, Any], bool]] = None):
    """Quantize every >=2D floating leaf to int8 (per last-dim channel).
    Returns a pytree where selected leaves become QuantizedTensor."""
    # jax.tree.flatten_with_path only exists on newer jax; use tree_util
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        name = "/".join(str(p) for p in path)
        ok = (hasattr(leaf, "ndim") and leaf.ndim >= 2
              and jnp.issubdtype(leaf.dtype, jnp.floating))
        if predicate is not None:
            ok = ok and predicate(name, leaf)
        out.append(quantize_per_channel(leaf) if ok else leaf)
    return jax.tree.unflatten(treedef, out)


def dequantize_params(qparams, dtype=jnp.bfloat16):
    return jax.tree.map(
        lambda t: t.dequant(dtype) if isinstance(t, QuantizedTensor) else t,
        qparams, is_leaf=lambda t: isinstance(t, QuantizedTensor))


def quantized_bytes(qparams) -> int:
    total = 0
    for leaf in jax.tree.leaves(qparams, is_leaf=lambda t: isinstance(t, QuantizedTensor)):
        if isinstance(leaf, QuantizedTensor):
            total += leaf.q.size + leaf.scale.size * 4
        elif hasattr(leaf, "nbytes"):
            total += leaf.nbytes
    return total


# -------------------------------------------------------- activation calib
def calibrate_activation_scale(samples: jax.Array, percentile: float = 99.9):
    """Max-abs (clipped percentile) activation scale for static quantization."""
    a = jnp.abs(samples.reshape(-1))
    k = max(1, int(a.size * (1.0 - percentile / 100.0)))
    top = jax.lax.top_k(a, k)[0][-1]
    return jnp.maximum(top, 1e-8) / 127.0


def quantization_error(params, qparams) -> dict:
    """Relative L2 error per quantized leaf (property-tested bound)."""
    errs = {}
    flat, _ = jax.tree.flatten_with_path(params)
    qflat = jax.tree.leaves(qparams, is_leaf=lambda t: isinstance(t, QuantizedTensor))
    for (path, w), q in zip(flat, qflat):
        if isinstance(q, QuantizedTensor):
            d = q.dequant()
            errs["/".join(map(str, path))] = float(
                jnp.linalg.norm(w - d) / jnp.maximum(jnp.linalg.norm(w), 1e-8))
    return errs
