"""Checkpointing for multi-pod training: atomic directory commits, an async
writer thread (checkpoint I/O overlaps the next steps), retention, auto-resume,
and — critically for elastic scaling — restore onto a DIFFERENT mesh than the
one that saved (leaves are saved as full logical arrays and re-sharded on
load, so a 512-chip job can resume on 256 chips after losing a pod).

Format: one .npz per pytree (params/opt/...) + a JSON manifest; directory
renamed into place only after fsync (a crash mid-write never corrupts the
latest checkpoint).
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: Dict[str, Any]):
    root: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def fix(node):
        if isinstance(node, dict) and node and all(k.isdigit() for k in node):
            return tuple(fix(node[str(i)]) for i in range(len(node)))
        if isinstance(node, dict):
            return {k: fix(v) for k, v in node.items()}
        return node
    return fix(root)


class CheckpointManager:
    def __init__(self, directory, *, keep: int = 3, async_save: bool = True):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree, *, metadata: Optional[dict] = None,
             block: bool = False):
        """Snapshot to host (device->host copy happens NOW, so training can
        mutate donated buffers), then write in a background thread."""
        self.wait()  # one in-flight save at a time
        host_flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
        meta = {"step": int(step), "time": time.time(), **(metadata or {})}

        def write():
            tmp = self.dir / f".tmp_step_{step}"
            final = self.dir / f"step_{step:010d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir()
            np.savez(tmp / "state.npz", **host_flat)
            (tmp / "manifest.json").write_text(json.dumps(meta))
            with open(tmp / "manifest.json") as f:
                os.fsync(f.fileno())
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)          # atomic commit
            self._gc()

        if self.async_save and not block:
            self._thread = threading.Thread(target=self._guard(write), daemon=True)
            self._thread.start()
        else:
            write()

    def _guard(self, fn):
        def run():
            try:
                fn()
            except BaseException as e:   # surfaced on next wait()
                self._error = e
        return run

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # ------------------------------------------------------------- load
    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, *, shardings=None):
        """Load a checkpoint; if `shardings` (a pytree of NamedShardings
        matching the saved tree) is given, leaves are placed onto that mesh —
        which may be a different shape than the saving mesh (elastic
        restore)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            return None, None
        d = self.dir / f"step_{step:010d}"
        meta = json.loads((d / "manifest.json").read_text())
        with np.load(d / "state.npz") as z:
            flat = {k: z[k] for k in z.files}
        tree = _unflatten(flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s) if s is not None else
                jax.device_put(x), tree, shardings)
        return tree, meta
