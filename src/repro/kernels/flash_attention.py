"""Blockwise online-softmax attention (FlashAttention on TPU, GQA-aware).

Grid: (B, H, Sq/bq, Sk/bk) with the KV index derived as h // (H // KV) so GQA
shares K/V blocks across grouped query heads. Running max/denominator/acc live
in VMEM scratch and persist across the innermost (kv) grid steps — the same
"accumulators in on-chip RAM" structure as the paper's systolic design.

Positions are block-index-derived (prefill layout: positions 0..S-1), causal
and sliding-window masks are applied in-kernel; fully-masked kv blocks are
skipped (pl.when), which is how the kernel keeps the long-context windowed
archs sub-quadratic in *work*, not just memory.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int, block_q: int, block_k: int,
            k_out_ref=None, v_out_ref=None):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    if k_out_ref is not None:
        # K/V-exporting prefill variant: the K/V block is already resident in
        # VMEM for the attention pass, so emitting it to the export outputs
        # costs no extra HBM read — the fused path a serving prefill uses to
        # land post-RoPE K/V tiles ready for the cache (block-table) scatter.
        # Every (h, qi) grid step that maps to this kv block writes the same
        # bytes, so output-block revisiting is well-defined.
        k_out_ref[...] = k_ref[...]
        v_out_ref[...] = v_ref[...]

    @pl.when(ki == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # whole-block skip test (static per grid step under interpret; cheap on TPU)
    def in_range():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale          # (bq, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)                  # (bk, hd)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)    # (bq, bk)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        ok = jnp.ones((block_q, block_k), bool)
        if causal:
            ok &= q_pos >= k_pos
        if window > 0:
            ok &= (q_pos - k_pos) < window
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal or window > 0:
        # block fully below the causal diagonal or outside the window -> skip
        relevant = jnp.array(True)
        if causal:
            relevant &= (q_start + block_q - 1) >= k_start
        if window > 0:
            relevant &= (k_start + block_k - 1) > (q_start - window)
        pl.when(relevant)(in_range)
    else:
        in_range()

    @pl.when(ki == nk - 1)
    def _():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: (B,Sq,H,hd); k,v: (B,Sk,KV,hd) -> (B,Sq,H,hd)."""
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    assert H % KV == 0
    G = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0
    grid = (B, H, Sq // block_q, Sk // block_k)
    scale = 1.0 / math.sqrt(hd)
    kernel = functools.partial(_kernel, scale=scale, causal=causal, window=window,
                               block_q=block_q, block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, h, qi, ki: (b, ki, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b, h, qi, ki: (b, ki, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd), lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q,), jnp.float32),
                        pltpu.VMEM((block_q,), jnp.float32),
                        pltpu.VMEM((block_q, hd), jnp.float32)],
        interpret=interpret,
    )(q, k, v)


def flash_attention_kv(q, k, v, *, causal: bool = True, window: int = 0,
                       block_q: int = 128, block_k: int = 128,
                       interpret: bool = False):
    """Causal prefill variant that returns ``(O, K, V)``.

    Same grid/accumulator structure as :func:`flash_attention`, but the
    kernel additionally EXPORTS the K/V tiles it streams through VMEM as two
    extra outputs shaped ``(B, Sk, KV, hd)`` — the per-layer cache rows a
    serving prefill scatters into its (paged) KV cache. Today the projection
    and RoPE happen outside the kernel (layers._qkv), so the export is a
    passthrough of the inputs: what this variant establishes is the
    (O, K, V) OUTPUT CONTRACT the serving path consumes, so a future kernel
    that fuses qkv projection + RoPE in-kernel (where K/V first materialize
    in VMEM and an HBM round-trip really is saved) can drop in without
    touching any caller. Under ``interpret`` (CPU CI) the same body runs as
    traced JAX ops.

    q: (B,Sq,H,hd); k,v: (B,Sk,KV,hd) -> (O (B,Sq,H,hd), K, V (B,Sk,KV,hd)).
    """
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    assert H % KV == 0
    G = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0
    grid = (B, H, Sq // block_q, Sk // block_k)
    scale = 1.0 / math.sqrt(hd)

    def kernel(q_ref, k_ref, v_ref, o_ref, k_out_ref, v_out_ref,
               m_ref, l_ref, acc_ref):
        _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                scale=scale, causal=causal, window=window, block_q=block_q,
                block_k=block_k, k_out_ref=k_out_ref, v_out_ref=v_out_ref)

    kv_spec = pl.BlockSpec((1, block_k, 1, hd), lambda b, h, qi, ki: (b, ki, h // G, 0))
    o, k_out, v_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd), lambda b, h, qi, ki: (b, qi, h, 0)),
            kv_spec,
            kv_spec,
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, 1, hd), lambda b, h, qi, ki: (b, qi, h, 0)),
            kv_spec,
            kv_spec,
        ],
        out_shape=[jax.ShapeDtypeStruct((B, Sq, H, hd), q.dtype),
                   jax.ShapeDtypeStruct((B, Sk, KV, hd), k.dtype),
                   jax.ShapeDtypeStruct((B, Sk, KV, hd), v.dtype)],
        scratch_shapes=[pltpu.VMEM((block_q,), jnp.float32),
                        pltpu.VMEM((block_q,), jnp.float32),
                        pltpu.VMEM((block_q, hd), jnp.float32)],
        interpret=interpret,
    )(q, k, v)
    return o, k_out, v_out
