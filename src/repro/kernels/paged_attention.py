"""Paged attention: block-table-indirect blockwise softmax over a shared
KV page pool (the serving engine's vLLM-style cache layout).

The repo's first kernel whose memory access pattern is INDIRECT: K/V blocks
are not a function of grid indices alone — each (slot, kv-page) grid step
reads the page named by ``block_tables[slot, page_idx]`` out of the shared
pool ``(num_pages, page_size, KV, hd)``. The block table and the per-slot
start positions ride in as SCALAR-PREFETCH operands
(``pltpu.PrefetchScalarGridSpec``) so the index map can steer each block's
DMA before the body runs — the same "compute never waits on a dense,
oversized buffer" dataflow the paper builds around Ultra RAM placement.

One kernel serves both serving attention shapes:

* **decode** — Sq == 1, one new query row per slot at position ``start[b]``;
* **prefill chunk** — Sq == C consecutive prompt positions starting at
  ``start[b]`` (the engine's incremental per-chunk splice writes the chunk's
  K/V rows into the pool FIRST, so the kernel reads prior chunks, aliased
  prefix pages, and the current chunk uniformly through the block table).

Fully-masked pages are SKIPPED (``pl.when``): unallocated block-table slots
(page id -1), pages wholly beyond the causal frontier
(``page_start > start + Sq - 1``), and — for windowed layers — pages wholly
behind the sliding window. Work therefore scales with each slot's LIVE
pages, not with the block-table span (s_max), which is exactly the
O(C x s_max) masked-einsum cost this kernel replaces. Partially-filled last
pages and partially-visible pages are handled by per-row masking inside the
body; masked probabilities are explicitly zeroed (not just sentinel-masked)
so a row with no valid key in a visited page contributes nothing, and a row
with no valid key anywhere (a freed slot parked at INACTIVE_POS with an
all--1 block table) returns exactly 0 through the ``l == 0`` guard.

Grid: (B, H, mps) with the kv page index innermost so the online-softmax
accumulators (m, l, acc) persist in VMEM scratch across a slot's pages —
the paper's "accumulators in on-chip RAM" structure, same as the flash
kernel. GQA shares each K/V block across ``H // KV`` query heads via the
``h // G`` index map.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30   # f32 scratch sentinel (never materialized in low precision)


def _kernel(bt_ref, start_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, scale: float, window: int,
            block_q: int, page_size: int):
    b = pl.program_id(0)
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    page = bt_ref[b, j]
    start = start_ref[b]
    k_start = j * page_size

    def visit():
        q = q_ref[0, :, 0, :].astype(jnp.float32)              # (bq, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)              # (ps, hd)
        # dot-then-scale in f32: the same operation order as the masked-
        # einsum reference, so the degenerate one-page config stays
        # numerically aligned with it
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        q_pos = start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, page_size), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, page_size), 1)
        ok = k_pos <= q_pos
        if window > 0:
            ok &= k_pos > q_pos - window
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        # explicit zeroing, not exp(sentinel): a row fully masked in THIS
        # page while m is still NEG_INF would otherwise turn exp(0) == 1
        # into garbage mass from rows it may never attend
        p = jnp.where(ok, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    # whole-page skip: unallocated, beyond the causal frontier of the LAST
    # query row, or (windowed) wholly behind the FIRST query row's window
    relevant = (page >= 0) & (k_start <= start + block_q - 1)
    if window > 0:
        relevant &= (k_start + page_size - 1) > (start - window)
    pl.when(relevant)(visit)

    @pl.when(j == nj - 1)
    def _():
        # l == 0 (no valid key anywhere — freed slot, all pages skipped)
        # yields exactly 0, matching the reference oracle
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def _kernel_q8(bt_ref, start_ref, ks_ref, vs_ref, q_ref, k_ref, v_ref, o_ref,
               m_ref, l_ref, acc_ref, *, scale: float, window: int,
               block_q: int, page_size: int):
    """Int8-page variant: identical online softmax, but the gathered K/V
    block is DEQUANTIZED in-register right after the DMA — the page's
    symmetric scale rides in as a scalar-prefetch operand, so HBM only ever
    moves int8 payload (the ~4x KV-bandwidth win) and no fp32 page is
    materialized outside VMEM."""
    b = pl.program_id(0)
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    page = bt_ref[b, j]
    start = start_ref[b]
    k_start = j * page_size
    pg = jnp.maximum(page, 0)

    def visit():
        q = q_ref[0, :, 0, :].astype(jnp.float32)              # (bq, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32) * ks_ref[pg]  # (ps, hd)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        q_pos = start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, page_size), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, page_size), 1)
        ok = k_pos <= q_pos
        if window > 0:
            ok &= k_pos > q_pos - window
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.where(ok, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        v = v_ref[0, :, 0, :].astype(jnp.float32) * vs_ref[pg]
        acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    relevant = (page >= 0) & (k_start <= start + block_q - 1)
    if window > 0:
        relevant &= (k_start + page_size - 1) > (start - window)
    pl.when(relevant)(visit)

    @pl.when(j == nj - 1)
    def _():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def _kernel_latent(bt_ref, start_ref, q_ref, k_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, scale: float,
                   block_q: int, page_size: int, d_v: int):
    """MLA latent-page variant: each gathered block is ``(page_size,
    c_kv + r)`` — one compressed latent row per token, shared by ALL query
    heads (the absorb path pushed the per-head projections into the query
    and output einsums). Scores contract the FULL latent row; the value
    contribution reuses the leading ``d_v`` (= c_kv) columns of the SAME
    rows, so each page is DMA'd exactly once for both roles — the
    bandwidth shape MLA exists to buy."""
    b = pl.program_id(0)
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    page = bt_ref[b, j]
    start = start_ref[b]
    k_start = j * page_size

    def visit():
        q = q_ref[0, :, 0, :].astype(jnp.float32)              # (bq, c+r)
        k = k_ref[0, :, 0, :].astype(jnp.float32)              # (ps, c+r)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        q_pos = start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, page_size), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, page_size), 1)
        ok = k_pos <= q_pos
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.where(ok, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
            p, k[:, :d_v], preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    # MLA is full-causal only (no sliding window): skip unallocated pages
    # and pages wholly beyond the last query row's causal frontier
    relevant = (page >= 0) & (k_start <= start + block_q - 1)
    pl.when(relevant)(visit)

    @pl.when(j == nj - 1)
    def _():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def paged_attention_latent(q, pool_c, block_tables, start, *,
                           scale_dim: int, d_v: int, interpret: bool = False):
    """Paged attention over MLA latent pages.

    q: (B, Sq, H, c+r) ABSORBED queries (q_nope pushed through wkv_b's key
    half, concat decoupled RoPE head); pool_c: (P, page_size, 1, c+r) — one
    latent row per token, no per-head K/V; block_tables/start as in
    :func:`paged_attention`. ``scale_dim`` is the logical attention width
    (qk_nope_head_dim + qk_rope_head_dim) the softmax is scaled by — NOT
    the latent width the dot products contract over. Values are the leading
    ``d_v`` (= kv_lora_rank) columns of the same latent rows; output is
    (B, Sq, H, d_v), still in latent space (the caller applies wkv_b's
    value half and wo)."""
    B, Sq, H, L = q.shape
    P, ps, KV, _ = pool_c.shape
    assert KV == 1, "latent pool carries one shared row per token"
    mps = block_tables.shape[1]
    scale = 1.0 / math.sqrt(scale_dim)
    kernel = functools.partial(_kernel_latent, scale=scale,
                               block_q=Sq, page_size=ps, d_v=d_v)
    # one shared latent block per (slot, page) step — every query head h
    # reads kv head 0 of the page named by the prefetched block table
    kv_map = lambda b, h, j, bt, st: (jnp.maximum(bt[b, j], 0), 0, 0, 0)
    q_map = lambda b, h, j, bt, st: (b, 0, h, 0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, H, mps),
        in_specs=[pl.BlockSpec((1, Sq, 1, L), q_map),
                  pl.BlockSpec((1, ps, 1, L), kv_map)],
        out_specs=pl.BlockSpec((1, Sq, 1, d_v), q_map),
        scratch_shapes=[pltpu.VMEM((Sq,), jnp.float32),
                        pltpu.VMEM((Sq,), jnp.float32),
                        pltpu.VMEM((Sq, d_v), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, d_v), q.dtype),
        interpret=interpret,
    )(jnp.asarray(block_tables, jnp.int32), jnp.asarray(start, jnp.int32),
      q, pool_c)


def paged_attention(q, pool_k, pool_v, block_tables, start, *,
                    window: int = 0, interpret: bool = False,
                    k_scale=None, v_scale=None):
    """q: (B, Sq, H, hd); pool_k/pool_v: (P, page_size, KV, hd);
    block_tables: (B, mps) int32 page ids (-1 = unallocated);
    start: (B,) int32 — the position of each slot's FIRST query row (query
    row i is at ``start[b] + i``; logical key row r lives in page ``r // ps``
    at offset ``r % ps``). Returns (B, Sq, H, hd) in q.dtype.

    k_scale/v_scale: optional (P,) f32 per-page symmetric scales for int8
    pools; when given, the q8 kernel dequantizes each gathered page inside
    the kernel body (scales prefetched to SMEM alongside the block table)."""
    B, Sq, H, hd = q.shape
    P, ps, KV, _ = pool_k.shape
    assert H % KV == 0
    G = H // KV
    mps = block_tables.shape[1]
    scale = 1.0 / math.sqrt(hd)
    quantized = k_scale is not None
    kern = _kernel_q8 if quantized else _kernel
    kernel = functools.partial(kern, scale=scale, window=window,
                               block_q=Sq, page_size=ps)
    # the kv index maps read the PREFETCHED block table: the page a grid
    # step streams is data-dependent (clamped at 0 for unallocated slots —
    # the body skips those steps entirely, the clamp only keeps the
    # prefetch in bounds). Scalar-prefetch operands land FIRST in the
    # kernel signature and as trailing index-map params; the q8 path adds
    # the two scale tables after (bt, start).
    if quantized:
        kv_map = lambda b, h, j, bt, st, ks, vs: (
            jnp.maximum(bt[b, j], 0), 0, h // G, 0)
        q_map = lambda b, h, j, bt, st, ks, vs: (b, 0, h, 0)
        num_prefetch = 4
        prefetch = (jnp.asarray(block_tables, jnp.int32),
                    jnp.asarray(start, jnp.int32),
                    jnp.asarray(k_scale, jnp.float32),
                    jnp.asarray(v_scale, jnp.float32))
    else:
        kv_map = lambda b, h, j, bt, st: (jnp.maximum(bt[b, j], 0), 0,
                                          h // G, 0)
        q_map = lambda b, h, j, bt, st: (b, 0, h, 0)
        num_prefetch = 2
        prefetch = (jnp.asarray(block_tables, jnp.int32),
                    jnp.asarray(start, jnp.int32))
    kv_spec = pl.BlockSpec((1, ps, 1, hd), kv_map)
    q_spec = pl.BlockSpec((1, Sq, 1, hd), q_map)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=num_prefetch,
        grid=(B, H, mps),
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=q_spec,
        scratch_shapes=[pltpu.VMEM((Sq,), jnp.float32),
                        pltpu.VMEM((Sq,), jnp.float32),
                        pltpu.VMEM((Sq, hd), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, hd), q.dtype),
        interpret=interpret,
    )(*prefetch, q, pool_k, pool_v)


def paged_attention_head_sharded(dispatch, mesh, axis, q, pool_k, pool_v,
                                 block_tables, start, *, window: int = 0,
                                 k_scale=None, v_scale=None):
    """Tensor-parallel head-shard dispatch around the paged kernel.

    ``pallas_call`` lowers to a CustomCall that GSPMD cannot partition, so
    the tp serve path wraps the local dispatch in an explicit ``shard_map``:
    q and both pools split on their head axes over the ``axis`` mesh axis
    (the pool leaves are already RESIDENT with exactly this sharding, so no
    data moves for them); block tables and start positions are replicated —
    page ids are shard-invariant. The q8 page scales arrive as (P, tp)
    tables — one column per kv-head GROUP, resident sharded on the group
    axis alongside their kv heads — so each shard slices out its own (P, 1)
    column and squeezes it to the (P,) layout the local dispatch expects:
    the scale each shard dequantizes with was computed from that shard's
    kv heads alone and never crosses the mesh. Each shard runs the
    unmodified kernel on its (B, H/tp, pages) sub-grid, and the outputs
    concatenate back on the head axis. Per-head attention is independent,
    so every output element is computed by exactly one shard with the same
    op sequence as tp=1 — the basis of the bitwise tp equivalence anchor.

    ``dispatch`` is the single-device dispatch to run per shard
    (``ops._paged_dispatch_local`` — passed in so the interpret-grid guard
    and the einsum oracle fallback see per-shard grid sizes). The caller
    guarantees the axis size divides both H and KV on whole-GQA-group
    boundaries (see sharding.specs.head_shard_axis)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as SP

    heads = SP(None, None, axis, None)    # q/out (B,Sq,H,hd); pools (P,ps,KV,hd)
    repl1 = SP(None)
    repl2 = SP(None, None)

    if k_scale is not None:
        scales = SP(None, axis)           # (P, tp) -> per-shard (P, 1)
        def body(q_, pk_, pv_, bt_, st_, ks_, vs_):
            return dispatch(q_, pk_, pv_, bt_, st_, window,
                            k_scale=ks_[:, 0], v_scale=vs_[:, 0])
        return shard_map(
            body, mesh=mesh,
            in_specs=(heads, heads, heads, repl2, repl1, scales, scales),
            out_specs=heads, check_rep=False,
        )(q, pool_k, pool_v, block_tables, start, k_scale, v_scale)

    def body(q_, pk_, pv_, bt_, st_):
        return dispatch(q_, pk_, pv_, bt_, st_, window)
    return shard_map(
        body, mesh=mesh,
        in_specs=(heads, heads, heads, repl2, repl1),
        out_specs=heads, check_rep=False,
    )(q, pool_k, pool_v, block_tables, start)


def paged_attention_latent_head_sharded(dispatch, mesh, axis, q, pool_c,
                                        block_tables, start, *,
                                        scale_dim: int, d_v: int):
    """Tensor-parallel dispatch around the LATENT paged kernel.

    The latent pool has no kv-head axis (KV == 1; every query head reads
    the same compressed rows) and is resident REPLICATED, so the split
    lives entirely on the ABSORBED queries/outputs: q (B, Sq, H, c+r) and
    the (B, Sq, H, d_v) output shard on their head axis while pool, block
    tables, and start positions replicate. Per-head attention over the
    shared latent is head-independent — each output element is computed by
    exactly one shard with the same op sequence as tp=1, so the latent tp
    path inherits the bitwise equivalence anchor (the caller's all-gather
    before ``wo`` does the rest).

    ``dispatch`` is the single-device latent dispatch
    (``ops._paged_dispatch_latent`` — passed in so the interpret-grid guard
    sees per-shard H). The caller guarantees the axis size divides H
    (sharding.specs.latent_head_shard_axis)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as SP

    heads = SP(None, None, axis, None)    # q (B,Sq,H,c+r) / out (B,Sq,H,d_v)
    repl4 = SP(None, None, None, None)    # pool_c (P,ps,1,c+r)
    repl2 = SP(None, None)
    repl1 = SP(None)

    def body(q_, pc_, bt_, st_):
        return dispatch(q_, pc_, bt_, st_, scale_dim, d_v)
    return shard_map(
        body, mesh=mesh,
        in_specs=(heads, repl4, repl2, repl1),
        out_specs=heads, check_rep=False,
    )(q, pool_c, block_tables, start)
