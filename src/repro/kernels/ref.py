"""Pure-jnp oracles for every Pallas kernel. These are the ground truth the
kernel tests assert against (and the CPU execution path for small problems)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def matmul(a, b):
    """a: (M, K), b: (K, N) -> (M, N), f32 accumulation."""
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32)).astype(a.dtype)


def quant_matmul(a, w_q, scales):
    """a: (M, K) float; w_q: (K, N) int8; scales: (N,) per-output-channel.
    out = a @ (w_q * scales) with f32 accumulation."""
    w = w_q.astype(jnp.float32) * scales.astype(jnp.float32)[None, :]
    return jnp.dot(a.astype(jnp.float32), w).astype(a.dtype)


def flash_attention(q, k, v, *, causal=True, window=0, q_positions=None,
                    k_positions=None):
    """q: (B,Sq,H,hd); k,v: (B,Sk,KV,hd) -> (B,Sq,H,hd). GQA by head grouping."""
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    if q_positions is None:
        q_positions = jnp.broadcast_to(jnp.arange(Sq), (B, Sq))
    if k_positions is None:
        k_positions = jnp.broadcast_to(jnp.arange(Sk), (B, Sk))
    qg = q.reshape(B, Sq, KV, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32) / math.sqrt(hd)
    diff = q_positions[:, None, None, :, None] - k_positions[:, None, None, None, :]
    ok = jnp.ones(diff.shape, bool)
    if causal:
        ok &= diff >= 0
    if window > 0:
        ok &= diff < window
    s = jnp.where(ok, s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgqs,bskh->bqkgh", p, v).reshape(B, Sq, H, hd)


def flash_attention_kv(q, k, v, *, causal=True, window=0):
    """Oracle for the K/V-exporting prefill kernel: attention output plus the
    (unchanged) K/V tiles, matching flash_attention_kv's (O, K, V) contract."""
    return flash_attention(q, k, v, causal=causal, window=window), k, v


def wkv6(r, k, v, w, u, s0):
    """RWKV6 recurrence oracle.
    r,k,v,w: (B,T,H,N); u: (H,N); s0: (B,H,N,N) -> y (B,T,H,N), sT."""
    def step(S, inp):
        rt, kt, vt, wt = inp
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        y = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * kv)
        S = wt[..., None] * S + kv
        return S, y
    xs = tuple(jnp.moveaxis(t, 1, 0).astype(jnp.float32) for t in (r, k, v, w))
    sT, ys = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1), sT


def selective_scan(x, dt, b, c, a, h0):
    """Mamba-style scan oracle. x,dt: (B,T,D); b,c: (B,T,N); a: (D,N); h0: (B,D,N)."""
    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        decay = jnp.exp(a[None] * dt_t[..., None])
        h = decay * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (x, dt, b, c))
    hT, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), hT
