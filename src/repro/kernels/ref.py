"""Pure-jnp oracles for every Pallas kernel. These are the ground truth the
kernel tests assert against (and the CPU execution path for small problems).
Also home to :func:`mask_value`, the shared masking-sentinel helper — it
lives at the kernels layer (no model dependency) so both kernels and models
can import it at module scope without a package cycle."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def mask_value(dtype) -> float:
    """Finite large-negative sentinel for additive/where masking in
    ``dtype``. -1e30 where representable (float32/bfloat16 — keeps the
    historical numerics bit-for-bit), else half the dtype's minimum:
    float16's max is 65504, so -1e30 silently overflows to -inf there and a
    fully-masked softmax row (a freed serving slot parked at INACTIVE_POS)
    turns into NaN via exp(-inf - -inf) instead of a harmless row."""
    fi = jnp.finfo(jnp.dtype(dtype))
    if float(fi.max) > 1e30:
        return -1e30
    return float(fi.min) / 2


def matmul(a, b):
    """a: (M, K), b: (K, N) -> (M, N), f32 accumulation."""
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32)).astype(a.dtype)


def quant_matmul(a, w_q, scales):
    """a: (M, K) float; w_q: (K, N) int8; scales: (N,) per-output-channel.
    out = a @ (w_q * scales) with f32 accumulation."""
    w = w_q.astype(jnp.float32) * scales.astype(jnp.float32)[None, :]
    return jnp.dot(a.astype(jnp.float32), w).astype(a.dtype)


def paged_attention(q, pool_k, pool_v, block_tables, start, *, window=0,
                    k_scale=None, v_scale=None):
    """Oracle for the paged-attention kernel: gather each slot's logical
    view through its block table and run a masked partial softmax.

    q: (B, Sq, H, hd); pool_k/pool_v: (P, ps, KV, hd); block_tables:
    (B, mps) int32 (-1 = unallocated); start: (B,) int32 first query
    position per slot (query row i is at start[b] + i; logical key row r
    lives in page r // ps at offset r % ps). Masked probabilities are
    ZEROED (not sentinel-softmaxed): a query row with no valid key anywhere
    — a freed slot with an all--1 block table — returns exactly 0, matching
    the kernel's l == 0 guard.

    k_scale/v_scale: optional (P,) — or per-kv-head-group (P, T), group t
    covering the contiguous KV/T kv heads — f32 per-page symmetric dequant
    scales for int8 pools: the gathered view is dequantized page-wise
    before the softmax, mirroring the kernel's in-gather dequant (under tp
    each shard's kernel sees its own group's column)."""
    B, Sq, H, hd = q.shape
    P, ps, KV, _ = pool_k.shape
    mps = block_tables.shape[1]
    G = H // KV
    n_rows = mps * ps
    j = jnp.arange(n_rows)
    page = jnp.take_along_axis(
        block_tables, jnp.broadcast_to(j // ps, (B, n_rows)), axis=1)
    ok = page >= 0
    phys = jnp.where(ok, page * ps + j % ps, 0)
    flat_k = pool_k.reshape(P * ps, KV, hd)
    flat_v = pool_v.reshape(P * ps, KV, hd)
    view_k = flat_k[phys]                       # (B, n_rows, KV, hd)
    view_v = flat_v[phys]
    if k_scale is not None:
        pg = jnp.where(ok, page, 0)
        ks, vs = k_scale[pg], v_scale[pg]       # (B, n_rows) or (B, n_rows, T)
        if ks.ndim == 2:
            ks, vs = ks[..., None], vs[..., None]
        rep = KV // ks.shape[-1]                # heads per scale group
        ks = jnp.repeat(ks, rep, axis=-1)       # (B, n_rows, KV)
        vs = jnp.repeat(vs, rep, axis=-1)
        view_k = (view_k.astype(jnp.float32) * ks[..., None]).astype(q.dtype)
        view_v = (view_v.astype(jnp.float32) * vs[..., None]).astype(q.dtype)
    q_pos = start[:, None] + jnp.arange(Sq)[None, :]        # (B, Sq)
    valid = ok[:, None, :] & (j[None, None, :] <= q_pos[:, :, None])
    if window > 0:
        valid &= j[None, None, :] > q_pos[:, :, None] - window
    qg = q.reshape(B, Sq, KV, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, view_k.astype(q.dtype)
                   ).astype(jnp.float32) / math.sqrt(hd)
    vm = valid[:, None, None, :, :]
    s = jnp.where(vm, s, mask_value(s.dtype))
    m = s.max(axis=-1)
    p = jnp.where(vm, jnp.exp(s - m[..., None]), 0.0)
    l = p.sum(axis=-1)
    acc = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(q.dtype),
                     view_v.astype(q.dtype)).astype(jnp.float32)
    out = acc / jnp.maximum(l, 1e-30)[..., None]            # (B,KV,G,Sq,hd)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd).astype(q.dtype)


def paged_attention_latent(q, pool_c, block_tables, start, *, scale_dim,
                           d_v):
    """Oracle for the MLA latent-page kernel: gather each slot's latent
    rows through its block table and run a masked partial softmax directly
    in latent space.

    q: (B, Sq, H, c+r) absorbed queries; pool_c: (P, ps, 1, c+r) — ONE
    shared latent row per token (no per-head K/V, no separate value pool:
    values are the leading ``d_v`` columns of the same rows). ``scale_dim``
    is the logical head width (qk_nope + qk_rope) the scores divide by.
    Masked probabilities are zeroed so a freed slot (all--1 block table)
    returns exactly 0, matching the kernel's l == 0 guard. Returns
    (B, Sq, H, d_v) — still latent-space; callers apply wkv_b's value half."""
    B, Sq, H, L = q.shape
    P, ps = pool_c.shape[:2]
    mps = block_tables.shape[1]
    n_rows = mps * ps
    j = jnp.arange(n_rows)
    page = jnp.take_along_axis(
        block_tables, jnp.broadcast_to(j // ps, (B, n_rows)), axis=1)
    ok = page >= 0
    phys = jnp.where(ok, page * ps + j % ps, 0)
    view = pool_c.reshape(P * ps, L)[phys]                 # (B, n_rows, c+r)
    q_pos = start[:, None] + jnp.arange(Sq)[None, :]       # (B, Sq)
    valid = ok[:, None, :] & (j[None, None, :] <= q_pos[:, :, None])
    s = jnp.einsum("bqhl,bsl->bhqs", q, view.astype(q.dtype)
                   ).astype(jnp.float32) / math.sqrt(scale_dim)
    vm = valid[:, None, :, :]
    s = jnp.where(vm, s, mask_value(s.dtype))
    m = s.max(axis=-1)
    p = jnp.where(vm, jnp.exp(s - m[..., None]), 0.0)
    l = p.sum(axis=-1)
    acc = jnp.einsum("bhqs,bsl->bhql", p.astype(q.dtype),
                     view[..., :d_v].astype(q.dtype)).astype(jnp.float32)
    out = acc / jnp.maximum(l, 1e-30)[..., None]           # (B, H, Sq, d_v)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def mla_attention_naive(q_nope, q_pe, latent, wb_k, wb_v, q_positions,
                        k_positions):
    """Naive-expansion MLA oracle: materialize per-head K/V from the latent
    rows and attend conventionally. The absorb path (wkv_b folded into the
    query/output einsums, attention run directly over latents) must stay
    allclose to this — same math, reassociated contractions.

    q_nope: (B, Sq, H, hd) pre-absorption content queries; q_pe:
    (B, Sq, H, r) RoPE'd decoupled queries; latent: (B, Sk, c + r) cached
    rows (normalized latent ++ RoPE'd shared key head); wb_k: (H, hd, c),
    wb_v: (H, c, hd) — the split halves of wkv_b. Returns (B, Sq, H, hd)
    pre-``wo`` per-head attention output."""
    hd = q_nope.shape[-1]
    r = q_pe.shape[-1]
    c = latent.shape[-1] - r
    ck, k_pe = latent[..., :c], latent[..., c:]
    k_nope = jnp.einsum("bsc,hdc->bshd", ck, wb_k)         # expand keys
    v = jnp.einsum("bsc,hcd->bshd", ck, wb_v)              # expand values
    s = (jnp.einsum("bqhd,bshd->bhqs", q_nope, k_nope)
         + jnp.einsum("bqhr,bsr->bhqs", q_pe, k_pe)        # shared RoPE key
         ).astype(jnp.float32) / math.sqrt(hd + r)
    diff = (q_positions[:, None, :, None] - k_positions[:, None, None, :])
    s = jnp.where(diff >= 0, s, mask_value(s.dtype))
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqs,bshd->bqhd", p, v)


def flash_attention(q, k, v, *, causal=True, window=0, q_positions=None,
                    k_positions=None):
    """q: (B,Sq,H,hd); k,v: (B,Sk,KV,hd) -> (B,Sq,H,hd). GQA by head grouping."""
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    if q_positions is None:
        q_positions = jnp.broadcast_to(jnp.arange(Sq), (B, Sq))
    if k_positions is None:
        k_positions = jnp.broadcast_to(jnp.arange(Sk), (B, Sk))
    qg = q.reshape(B, Sq, KV, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32) / math.sqrt(hd)
    diff = q_positions[:, None, None, :, None] - k_positions[:, None, None, None, :]
    ok = jnp.ones(diff.shape, bool)
    if causal:
        ok &= diff >= 0
    if window > 0:
        ok &= diff < window
    s = jnp.where(ok, s, mask_value(s.dtype))
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgqs,bskh->bqkgh", p, v).reshape(B, Sq, H, hd)


def flash_attention_kv(q, k, v, *, causal=True, window=0):
    """Oracle for the K/V-exporting prefill kernel: attention output plus the
    (unchanged) K/V tiles, matching flash_attention_kv's (O, K, V) contract."""
    return flash_attention(q, k, v, causal=causal, window=window), k, v


def wkv6(r, k, v, w, u, s0):
    """RWKV6 recurrence oracle.
    r,k,v,w: (B,T,H,N); u: (H,N); s0: (B,H,N,N) -> y (B,T,H,N), sT."""
    def step(S, inp):
        rt, kt, vt, wt = inp
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        y = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * kv)
        S = wt[..., None] * S + kv
        return S, y
    xs = tuple(jnp.moveaxis(t, 1, 0).astype(jnp.float32) for t in (r, k, v, w))
    sT, ys = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1), sT


def selective_scan(x, dt, b, c, a, h0):
    """Mamba-style scan oracle. x,dt: (B,T,D); b,c: (B,T,N); a: (D,N); h0: (B,D,N)."""
    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        decay = jnp.exp(a[None] * dt_t[..., None])
        h = decay * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (x, dt, b, c))
    hT, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), hT
