"""Public jit'd wrappers for the Pallas kernels.

On CPU (this container) kernels execute with ``interpret=True`` — the kernel
body runs as traced JAX ops so correctness is validated end-to-end; on TPU the
same calls compile to Mosaic. Wrappers pad inputs to block multiples and crop,
and fall back to the jnp oracle for degenerate shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import linear_scan as _ls
from repro.kernels import matmul as _mm
from repro.kernels import paged_attention as _pa
from repro.kernels import quant_matmul as _qm
from repro.kernels import ref as _ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x, mult, axis):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def _matmul_vjp(x, w, block_m, block_n, block_k, dataflow):
    M, K = x.shape
    _, N = w.shape
    bm, bn, bk = (min(block_m, M), min(block_n, N), min(block_k, K))
    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(w, bk, 0), bn, 1)
    out = _mm.matmul(xp, wp, block_m=bm, block_n=bn, block_k=bk,
                     dataflow=dataflow, interpret=_interpret(), out_dtype=x.dtype)
    return out[:M, :N]


def _matmul_fwd(x, w, bm, bn, bk, df):
    return _matmul_vjp(x, w, bm, bn, bk, df), (x, w)


def _matmul_bwd(bm, bn, bk, df, res, g):
    x, w = res
    # dX = g @ W^T ; dW = X^T @ g — both through the systolic kernel
    dx = _matmul_vjp(g, w.T, bm, bn, bk, df)
    dw = _matmul_vjp(x.T, g, bm, bn, bk, df)
    return dx.astype(x.dtype), dw.astype(w.dtype)


_matmul_vjp.defvjp(_matmul_fwd, _matmul_bwd)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "dataflow"))
def matmul(x, w, *, block_m: int = 128, block_n: int = 128, block_k: int = 128,
           dataflow: str = "output_stationary"):
    """Systolic tiled matmul; pads to block multiples, crops the result.
    Differentiable: the custom VJP routes both gradient GEMMs back through
    the kernel (training-usable, not just inference)."""
    return _matmul_vjp(x, w, block_m, block_n, block_k, dataflow)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def quant_matmul(x, w_q, scales, *, block_m: int = 128, block_n: int = 128,
                 block_k: int = 128):
    M, K = x.shape
    _, N = w_q.shape
    bm, bn, bk = (min(block_m, M), min(block_n, N), min(block_k, K))
    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(w_q, bk, 0), bn, 1)
    sp = _pad_to(scales, bn, 0)
    out = _qm.quant_matmul(xp, wp, sp, block_m=bm, block_n=bn, block_k=bk,
                           interpret=_interpret(), out_dtype=x.dtype)
    return out[:M, :N]


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    q_positions=None, k_positions=None):
    """q: (B,Sq,H,hd); k,v: (B,Sk,KV,hd). Positions args accepted for API
    parity with ref; the kernel derives prefill positions from block indices
    (non-standard positions fall back to the oracle)."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    if q_positions is not None or k_positions is not None:
        return _ref.flash_attention(q, k, v, causal=causal, window=window,
                                    q_positions=q_positions,
                                    k_positions=k_positions)
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    if Sq % bq or Sk % bk:
        return _ref.flash_attention(q, k, v, causal=causal, window=window)
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=bq, block_k=bk, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k"))
def flash_prefill(q, k, v, *, causal: bool = True, window: int = 0,
                  block_q: int = 128, block_k: int = 128):
    """K/V-exporting prefill attention: returns ``(O, K, V)`` where K/V are
    the post-RoPE tiles ready for the serving cache scatter (paged block
    tables or dense rows). On TPU the export rides the kernel's existing
    VMEM residency (one fused HBM pass); non-block-multiple shapes fall back
    to the jnp oracle so CPU CI always runs."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    if Sq % bq or Sk % bk:
        return _ref.flash_attention_kv(q, k, v, causal=causal, window=window)
    return _fa.flash_attention_kv(q, k, v, causal=causal, window=window,
                                  block_q=bq, block_k=bk,
                                  interpret=_interpret())


# trace-size guard for the paged kernel: interpret mode inlines one kernel
# body per grid step (B * H * mps), so an oversized grid would explode trace
# time on CPU; on TPU the Mosaic grid is free but tiny tiles are not worth
# steering through the MXU — both ends route to the jnp oracle
_PAGED_MAX_INTERPRET_GRID = 4096


def _paged_dispatch_local(q, pool_k, pool_v, block_tables, start, window: int,
                          k_scale=None, v_scale=None):
    """Single-device paged-attention dispatch (also the per-shard body under
    the tp shard_map — the interpret-grid guard and oracle fallback then see
    per-shard H, which is the point of passing this in whole)."""
    B, Sq, H, hd = q.shape
    ps = pool_k.shape[1]
    mps = block_tables.shape[1]
    sc = dict(k_scale=k_scale, v_scale=v_scale)
    if _interpret():
        if B * H * mps > _PAGED_MAX_INTERPRET_GRID:
            return _ref.paged_attention(q, pool_k, pool_v, block_tables,
                                        start, window=window, **sc)
        return _pa.paged_attention(q, pool_k, pool_v, block_tables, start,
                                   window=window, interpret=True, **sc)
    if hd % 128 or ps % 8:
        return _ref.paged_attention(q, pool_k, pool_v, block_tables, start,
                                    window=window, **sc)
    return _pa.paged_attention(q, pool_k, pool_v, block_tables, start,
                               window=window, interpret=False, **sc)


def _squeeze_scale(s):
    """Accept a (P,) scale table or the int8 backend's (P, 1) single-group
    column (tp=1 keeps one whole-page group; multi-group tables only ever
    meet the kernel inside the head-sharded shard_map, which slices each
    shard's own column)."""
    if s is not None and s.ndim == 2:
        s = s[:, 0]
    return s


def _paged_dispatch(q, pool_k, pool_v, block_tables, start, window: int,
                    k_scale=None, v_scale=None, mesh=None, shard_axis=None):
    if mesh is not None and shard_axis is not None:
        return _pa.paged_attention_head_sharded(
            _paged_dispatch_local, mesh, shard_axis, q, pool_k, pool_v,
            block_tables, start, window=window,
            k_scale=k_scale, v_scale=v_scale)
    return _paged_dispatch_local(q, pool_k, pool_v, block_tables, start,
                                 window, k_scale=_squeeze_scale(k_scale),
                                 v_scale=_squeeze_scale(v_scale))


# mesh/shard_axis are STATIC jit args (Mesh is hashable), not read from the
# sharding contextvar inside the traced body: these wrappers are module-level
# jits whose trace cache keys on abstract args only, so a contextvar read
# could silently reuse a non-mesh trace across engines. Callers resolve the
# head-shard decision at their own trace time (sharding.specs.head_shard_axis)
# and pass it down explicitly.
@functools.partial(jax.jit, static_argnames=("window", "mesh", "shard_axis"))
def paged_decode(q, pool_k, pool_v, block_tables, cache_pos, *,
                 window: int = 0, mesh=None, shard_axis=None):
    """Single-token decode attention against a paged KV cache.

    q: (B, 1, H, hd); pool_k/pool_v: (P, page_size, KV, hd) — one layer's
    slice of the shared pool; block_tables: (B, mps) int32 (-1 =
    unallocated); cache_pos: (B,) int32 per-slot positions (the new K/V row
    must already be WRITTEN at logical row cache_pos[b] — the write stays a
    plain block-table scatter outside the kernel). Gathers K/V blocks
    through the block table inside the kernel and skips fully-masked pages;
    a freed slot (all--1 table) returns exactly 0. mesh/shard_axis (from
    specs.head_shard_axis) route through the head-sharded shard_map."""
    return _paged_dispatch(q, pool_k, pool_v, block_tables, cache_pos,
                           window, mesh=mesh, shard_axis=shard_axis)


@functools.partial(jax.jit, static_argnames=("window", "mesh", "shard_axis"))
def paged_prefill(q, pool_k, pool_v, block_tables, start, *,
                  window: int = 0, mesh=None, shard_axis=None):
    """Continuation-chunk prefill attention against a paged KV cache.

    q: (B, C, H, hd) — C consecutive prompt positions, row i of slot b at
    position ``start[b] + i``; the chunk's post-RoPE K/V rows must already
    be spliced into the slot's pages (the engine's incremental per-chunk
    scatter), so prior chunks, aliased prefix pages, and the current chunk
    are all read uniformly through the block table. Causal masking is
    ``k_pos <= q_pos`` over the slot's logical rows; pages wholly beyond
    the chunk's causal frontier (or unallocated) are skipped, so mask work
    scales with the slot's LIVE pages instead of O(C x s_max)."""
    return _paged_dispatch(q, pool_k, pool_v, block_tables, start, window,
                           mesh=mesh, shard_axis=shard_axis)


@functools.partial(jax.jit, static_argnames=("window", "mesh", "shard_axis"))
def paged_decode_q8(q, pool_k, pool_v, k_scale, v_scale, block_tables,
                    cache_pos, *, window: int = 0, mesh=None,
                    shard_axis=None):
    """paged_decode over INT8 pools: pool_k/pool_v are int8, k_scale/v_scale
    are (P,) — or per-kv-head-group (P, tp) — f32 per-page symmetric
    scales. Dequant happens inside the kernel's gather (scales prefetched
    to SMEM) — HBM traffic stays int8. mesh/shard_axis (from
    specs.head_shard_axis) route through the head-sharded shard_map, where
    each shard dequantizes with its own group's scale column."""
    return _paged_dispatch(q, pool_k, pool_v, block_tables, cache_pos,
                           window, k_scale=k_scale, v_scale=v_scale,
                           mesh=mesh, shard_axis=shard_axis)


@functools.partial(jax.jit, static_argnames=("window", "mesh", "shard_axis"))
def paged_prefill_q8(q, pool_k, pool_v, k_scale, v_scale, block_tables,
                     start, *, window: int = 0, mesh=None, shard_axis=None):
    """paged_prefill over INT8 pools (see paged_decode_q8)."""
    return _paged_dispatch(q, pool_k, pool_v, block_tables, start,
                           window, k_scale=k_scale, v_scale=v_scale,
                           mesh=mesh, shard_axis=shard_axis)


def _paged_dispatch_latent(q, pool_c, block_tables, start, scale_dim: int,
                           d_v: int):
    """MLA latent-page dispatch: same guard ladder as the per-head paged
    dispatch, but over the single shared latent pool."""
    B, Sq, H, L = q.shape
    ps = pool_c.shape[1]
    mps = block_tables.shape[1]
    if _interpret():
        if B * H * mps > _PAGED_MAX_INTERPRET_GRID:
            return _ref.paged_attention_latent(q, pool_c, block_tables,
                                               start, scale_dim=scale_dim,
                                               d_v=d_v)
        return _pa.paged_attention_latent(q, pool_c, block_tables, start,
                                          scale_dim=scale_dim, d_v=d_v,
                                          interpret=True)
    if L % 128 or d_v % 128 or ps % 8:
        return _ref.paged_attention_latent(q, pool_c, block_tables, start,
                                           scale_dim=scale_dim, d_v=d_v)
    return _pa.paged_attention_latent(q, pool_c, block_tables, start,
                                      scale_dim=scale_dim, d_v=d_v,
                                      interpret=False)


@functools.partial(jax.jit, static_argnames=("scale_dim", "d_v", "mesh",
                                             "shard_axis"))
def paged_decode_latent(q, pool_c, block_tables, cache_pos, *,
                        scale_dim: int, d_v: int, mesh=None,
                        shard_axis=None):
    """Single-token decode attention over MLA latent pages.

    q: (B, 1, H, c+r) ABSORBED queries; pool_c: (P, page_size, 1, c+r) —
    one shared latent row per token, gathered once per page for both the
    score contraction and (its leading ``d_v`` columns) the value
    accumulation. ``scale_dim`` is the logical head width the softmax
    divides by. Returns (B, 1, H, d_v) in latent space — the caller owns
    the wkv_b value-half and ``wo`` projections. The latent pool itself
    has no kv-head axis (it stays replicated under tp); mesh/shard_axis
    (from specs.latent_head_shard_axis) shard the ABSORBED queries/outputs
    on their head axis through the latent shard_map wrapper."""
    if mesh is not None and shard_axis is not None:
        return _pa.paged_attention_latent_head_sharded(
            _paged_dispatch_latent, mesh, shard_axis, q, pool_c,
            block_tables, cache_pos, scale_dim=scale_dim, d_v=d_v)
    return _paged_dispatch_latent(q, pool_c, block_tables, cache_pos,
                                  scale_dim, d_v)


@functools.partial(jax.jit, static_argnames=("scale_dim", "d_v", "mesh",
                                             "shard_axis"))
def paged_prefill_latent(q, pool_c, block_tables, start, *,
                         scale_dim: int, d_v: int, mesh=None,
                         shard_axis=None):
    """Continuation-chunk prefill attention over MLA latent pages (see
    paged_decode_latent). q: (B, C, H, c+r); the chunk's latent rows must
    already be spliced into the slot's pages."""
    if mesh is not None and shard_axis is not None:
        return _pa.paged_attention_latent_head_sharded(
            _paged_dispatch_latent, mesh, shard_axis, q, pool_c,
            block_tables, start, scale_dim=scale_dim, d_v=d_v)
    return _paged_dispatch_latent(q, pool_c, block_tables, start,
                                  scale_dim, d_v)


@functools.partial(jax.jit, static_argnames=("chunk",))
def wkv6(r, k, v, w, u, s0, *, chunk: int = 32):
    T = r.shape[1]
    c = min(chunk, T)
    if T % c:
        return _ref.wkv6(r, k, v, w, u, s0)
    y, sT = _ls.wkv6(r, k, v, w, u, s0, chunk=c, interpret=_interpret())
    return y, sT


@functools.partial(jax.jit, static_argnames=("chunk",))
def selective_scan(x, dt, b, c, a, h0, *, chunk: int = 64):
    T = x.shape[1]
    ck = min(chunk, T)
    if T % ck:
        return _ref.selective_scan(x, dt, b, c, a, h0)
    return _ls.selective_scan(x, dt, b, c, a, h0, chunk=ck, interpret=_interpret())
