"""int8-weight x float-activation matmul with per-output-channel dequant.

The TPU-idiomatic realization of the paper's quantization contribution (C5):
weights live in HBM at int8 (half the bytes of bf16 — directly halves the
memory roofline term for weight-bound decode), are dequantized in VMEM right
before hitting the MXU, and accumulate in fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, wq_ref, scale_ref, o_ref, acc_ref):
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = wq_ref[...].astype(jnp.float32) * scale_ref[...].astype(jnp.float32)[None, :]
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w,
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def quant_matmul(x, w_q, scales, *, block_m: int = 128, block_n: int = 128,
                 block_k: int = 128, interpret: bool = False, out_dtype=None):
    """x: (M, K) float; w_q: (K, N) int8; scales: (N,) -> (M, N)."""
    M, K = x.shape
    K2, N = w_q.shape
    assert K == K2 and scales.shape == (N,)
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0
    out_dtype = out_dtype or x.dtype
    grid = (M // block_m, N // block_n, K // block_k)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_m, block_k), lambda m, n, k: (m, k)),
                  pl.BlockSpec((block_k, block_n), lambda m, n, k: (k, n)),
                  pl.BlockSpec((block_n,), lambda m, n, k: (n,))],
        out_specs=pl.BlockSpec((block_m, block_n), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, w_q, scales)
