"""Systolic-array tiled matmul (Pallas, MXU target).

This kernel is the TPU analogue of the Tensil 32x32 MAC array the paper sizes
in §4.1: BlockSpec tiles play the role of the FPGA's local-memory (BRAM/URAM)
vectors, the fp32 VMEM scratch plays the accumulators, and the *grid iteration
order* selects the dataflow the paper discusses (§4.3):

  output-stationary  grid (m, n, k): accumulator block resident, k streams.
  weight-stationary  grid (n, k, m): weight block resident while M sweeps —
                     Tensil's default dataflow; output partials re-stream to HBM.
  input-stationary   grid (m, k, n): activation block resident, weights stream —
                     the paper's "future work" dataflow, implemented here.

The planner (core/planner.py) chooses block shapes so (bm*bk + bk*bn + bm*bn)
bytes fit the VMEM budget — exactly the paper's stage/partition computation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DATAFLOWS = ("output_stationary", "weight_stationary", "input_stationary")


def _os_kernel(x_ref, w_ref, o_ref, acc_ref):
    """Output-stationary: k innermost, fp32 accumulator scratch."""
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _acc_kernel(x_ref, w_ref, o_ref, *, k_axis: int):
    """Weight-/input-stationary: output block is revisited across k, so
    partials accumulate through the (fp32) output ref itself — this is the
    extra output-restreaming traffic WS/IS dataflows pay, which the planner's
    traffic model (core/dataflow.py) charges them for."""
    k = pl.program_id(k_axis)

    @pl.when(k == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                          preferred_element_type=jnp.float32)


def matmul(x, w, *, block_m: int = 128, block_n: int = 128, block_k: int = 128,
           dataflow: str = "output_stationary", interpret: bool = False,
           out_dtype=None):
    """x: (M, K) @ w: (K, N) -> (M, N). Shapes must divide the block sizes
    (ops.py pads). fp32 accumulation in all dataflows."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (x.shape, w.shape)
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0, (
        (M, K, N), (block_m, block_k, block_n))
    out_dtype = out_dtype or x.dtype
    nm, nn, nk = M // block_m, N // block_n, K // block_k

    if dataflow == "output_stationary":
        grid = (nm, nn, nk)
        x_map = lambda m, n, k: (m, k)
        w_map = lambda m, n, k: (k, n)
        o_map = lambda m, n, k: (m, n)
        return pl.pallas_call(
            _os_kernel,
            grid=grid,
            in_specs=[pl.BlockSpec((block_m, block_k), x_map),
                      pl.BlockSpec((block_k, block_n), w_map)],
            out_specs=pl.BlockSpec((block_m, block_n), o_map),
            out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
            scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
            interpret=interpret,
        )(x, w)

    if dataflow == "weight_stationary":
        grid = (nn, nk, nm)   # m innermost: weight block (k,n) held across m
        x_map = lambda n, k, m: (m, k)
        w_map = lambda n, k, m: (k, n)
        o_map = lambda n, k, m: (m, n)
        kernel = functools.partial(_acc_kernel, k_axis=1)
    elif dataflow == "input_stationary":
        grid = (nm, nk, nn)   # n innermost: input block (m,k) held across n
        x_map = lambda m, k, n: (m, k)
        w_map = lambda m, k, n: (k, n)
        o_map = lambda m, k, n: (m, n)
        kernel = functools.partial(_acc_kernel, k_axis=1)
    else:
        raise ValueError(f"unknown dataflow {dataflow!r}")

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_m, block_k), x_map),
                  pl.BlockSpec((block_k, block_n), w_map)],
        out_specs=pl.BlockSpec((block_m, block_n), o_map),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(x, w)
    return out.astype(out_dtype)
