"""Chunked linear-attention scan kernels (RWKV6 wkv + mamba selective scan).

TPU adaptation (DESIGN.md §2): GPU RWKV kernels exploit per-warp shuffles; the
TPU-native structure is *chunked recurrence* — the sequence is cut into chunks
that fit VMEM, the O(N^2) state is carried in VMEM scratch across the
(sequential) grid steps, and within a chunk the interaction is computed in
closed form in fp32 log-space (numerically safe for data-dependent decays).
HBM traffic: each of r/k/v/w is read exactly once — the memory-roofline
optimum for this op.

wkv6 recurrence (per head, key-dim N):
    y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T),   S_t = diag(w_t) S_{t-1} + k_t v_t^T
Chunked closed form with L_t = sum_{i<=t} log w_i:
    y_t = (r_t * exp(L_{t-1})) @ S_chunk0
        + sum_{j<t} [sum_n r_tn k_jn exp(L_{t-1,n} - L_{j,n})] v_j
        + (sum_n r_tn u_n k_tn) v_t
    S' = diag(exp(L_last)) S_chunk0 + (k * exp(L_last - L))^T @ v
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# --------------------------------------------------------------------- wkv6
def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sT_ref,
                 s_scr, *, chunk: int):
    t = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(t == 0)
    def _():
        s_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, :, 0, :].astype(jnp.float32)      # (C, N)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    w = w_ref[0, :, 0, :].astype(jnp.float32)
    u = u_ref[0, :].astype(jnp.float32)            # (N,)
    S = s_scr[...]                                 # (N, N)

    logw = jnp.log(jnp.maximum(w, 1e-38))
    Lc = jnp.cumsum(logw, axis=0)                  # (C, N)
    Lprev = Lc - logw                              # L_{t-1}

    # state contribution
    y = jnp.dot(r * jnp.exp(Lprev), S, preferred_element_type=jnp.float32)

    # intra-chunk: A[t, j] = sum_n r_tn k_jn exp(Lprev_t - Lc_j), j < t
    diff = Lprev[:, None, :] - Lc[None, :, :]      # (C, C, N)
    tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
           > jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    A = jnp.sum(r[:, None, :] * k[None, :, :] * jnp.exp(diff), axis=-1)
    A = jnp.where(tri, A, 0.0)
    A = A + jnp.diag(jnp.sum(r * u[None, :] * k, axis=-1))   # bonus diagonal
    y = y + jnp.dot(A, v, preferred_element_type=jnp.float32)
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    # state update
    Llast = Lc[-1]
    kd = k * jnp.exp(Llast[None, :] - Lc)
    s_scr[...] = (jnp.exp(Llast)[:, None] * S
                  + jnp.dot(kd.T, v, preferred_element_type=jnp.float32))

    @pl.when(t == nt - 1)
    def _():
        sT_ref[0, 0] = s_scr[...].astype(sT_ref.dtype)


def wkv6(r, k, v, w, u, s0, *, chunk: int = 32, interpret: bool = False):
    """r,k,v,w: (B,T,H,N); u: (H,N); s0: (B,H,N,N) -> (y (B,T,H,N), sT)."""
    B, T, H, N = r.shape
    chunk = min(chunk, T)
    assert T % chunk == 0, (T, chunk)
    grid = (B, H, T // chunk)
    kernel = functools.partial(_wkv6_kernel, chunk=chunk)
    y, sT = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, N), lambda b, h, t: (h, 0)),
            pl.BlockSpec((1, 1, N, N), lambda b, h, t: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, 1, N, N), lambda b, h, t: (b, h, 0, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((B, T, H, N), jnp.float32),
                   jax.ShapeDtypeStruct((B, H, N, N), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return y, sT


# ------------------------------------------------------------ selective scan
def _sscan_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, h0_ref, y_ref, hT_ref,
                  h_scr, *, chunk: int):
    t = pl.program_id(1)
    nt = pl.num_programs(1)

    @pl.when(t == 0)
    def _():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[...].astype(jnp.float32)             # (D, N)

    def step(i, h):
        x_t = x_ref[0, i, :].astype(jnp.float32)   # (D,)
        dt_t = dt_ref[0, i, :].astype(jnp.float32)
        b_t = b_ref[0, i, :].astype(jnp.float32)   # (N,)
        c_t = c_ref[0, i, :].astype(jnp.float32)
        h = jnp.exp(a * dt_t[:, None]) * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y_ref[0, i, :] = jnp.dot(h, c_t, preferred_element_type=jnp.float32
                                 ).astype(y_ref.dtype)
        return h

    h_scr[...] = jax.lax.fori_loop(0, chunk, step, h_scr[...])

    @pl.when(t == nt - 1)
    def _():
        hT_ref[0] = h_scr[...].astype(hT_ref.dtype)


def selective_scan(x, dt, b, c, a, h0, *, chunk: int = 64, interpret: bool = False):
    """x,dt: (B,T,D); b,c: (B,T,N); a: (D,N); h0: (B,D,N) -> (y (B,T,D), hT)."""
    B, T, D = x.shape
    N = b.shape[-1]
    chunk = min(chunk, T)
    assert T % chunk == 0
    grid = (B, T // chunk)
    kernel = functools.partial(_sscan_kernel, chunk=chunk)
    y, hT = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, D), lambda bi, t: (bi, t, 0)),
            pl.BlockSpec((1, chunk, D), lambda bi, t: (bi, t, 0)),
            pl.BlockSpec((1, chunk, N), lambda bi, t: (bi, t, 0)),
            pl.BlockSpec((1, chunk, N), lambda bi, t: (bi, t, 0)),
            pl.BlockSpec((D, N), lambda bi, t: (0, 0)),
            pl.BlockSpec((1, D, N), lambda bi, t: (bi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, D), lambda bi, t: (bi, t, 0)),
            pl.BlockSpec((1, D, N), lambda bi, t: (bi, 0, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((B, T, D), jnp.float32),
                   jax.ShapeDtypeStruct((B, D, N), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((D, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, b, c, a, h0)
    return y, hT
