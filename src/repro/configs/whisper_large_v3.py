"""whisper-large-v3 — encoder-decoder audio transformer; conv frontend is a STUB
(input_specs() provides precomputed frame embeddings). [arXiv:2212.04356; unverified]"""
from repro.configs.base import ArchConfig, Family

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family=Family.ENCDEC,
    num_layers=32, d_model=1280, num_heads=20, num_kv_heads=20,
    d_ff=5120, vocab_size=51866,
    encoder_layers=32,
    norm="layernorm", qkv_bias=True, mlp_bias=True,
    skip_shapes=("long_500k",),
    notes="enc-dec; decode shapes exercise the DECODER (self-attn KV cache + cross-attn "
          "to encoder states); full attention => skip long_500k",
)
