"""ResNet20 / CIFAR-10 — the paper's own model (Tensil ResNet20-ZCU104 tutorial).
Not part of the assigned LM pool; used for the faithful reproduction of the
paper's FPS/accuracy ladder."""
import dataclasses
from repro.configs.base import ArchConfig, Family


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    name: str = "resnet20-cifar"
    num_blocks: tuple = (3, 3, 3)     # ResNet20 = 3 stages x 3 basic blocks
    widths: tuple = (16, 32, 64)
    num_classes: int = 10
    image_size: int = 32
    in_channels: int = 3


CONFIG = ResNetConfig()

# ArchConfig facade so the registry can treat it uniformly where needed.
ARCH_FACADE = ArchConfig(
    name="resnet20-cifar", family=Family.CNN,
    num_layers=20, d_model=64, num_heads=0, num_kv_heads=0,
    d_ff=64, vocab_size=10,
    skip_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    notes="paper model; evaluated via its own CIFAR shapes, not the LM shape pool",
)
