"""rwkv6-7b (Finch) — attention-free, data-dependent decay. [arXiv:2404.05892; hf]"""
from repro.configs.base import ArchConfig, Family

CONFIG = ArchConfig(
    name="rwkv6-7b",
    family=Family.SSM,
    num_layers=32, d_model=4096, num_heads=64, num_kv_heads=64,
    d_ff=14336, vocab_size=65536,
    head_dim=64, ssm_state=64,
    notes="attn-free: num_heads used as RWKV time-mix heads (head_dim=64); "
          "O(1) decode state; long_500k runs",
)
