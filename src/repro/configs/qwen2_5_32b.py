"""qwen2.5-32b — dense GQA kv=8, QKV bias. [hf:Qwen/Qwen2.5-0.5B; hf]"""
from repro.configs.base import ArchConfig, Family

CONFIG = ArchConfig(
    name="qwen2.5-32b",
    family=Family.DENSE,
    num_layers=64, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=27648, vocab_size=152064,
    qkv_bias=True,
    skip_shapes=("long_500k",),
    notes="hillclimb target (decode_32k); full attention => skip long_500k",
)
