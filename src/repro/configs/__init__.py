"""Config registry: ``get_config(name)`` / ``list_archs()``.

Arch ids use the assignment's hyphenated names; module files use underscores.
"""
from repro.configs.base import (ALL_SHAPES, SHAPES_BY_NAME, ArchConfig, Family,
                                MemoryStrategy, MoEConfig, ShapeConfig,
                                TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)

from repro.configs import (moonshot_v1_16b_a3b, dbrx_132b, whisper_large_v3,
                           minicpm_2b, command_r_35b, codeqwen1_5_7b,
                           qwen2_5_32b, qwen2_5_32b_mla, hymba_1_5b, rwkv6_7b,
                           llama_3_2_vision_11b, resnet20_cifar)

_ARCHS = {}
for _m in (moonshot_v1_16b_a3b, dbrx_132b, whisper_large_v3, minicpm_2b,
           command_r_35b, codeqwen1_5_7b, qwen2_5_32b, qwen2_5_32b_mla,
           hymba_1_5b, rwkv6_7b, llama_3_2_vision_11b):
    _ARCHS[_m.CONFIG.name] = _m.CONFIG

RESNET20 = resnet20_cifar.CONFIG


def list_archs():
    return sorted(_ARCHS)


def get_config(name: str) -> ArchConfig:
    if name in ("resnet20-cifar", "resnet20"):
        return resnet20_cifar.ARCH_FACADE
    if name not in _ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {list_archs()}")
    return _ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    return SHAPES_BY_NAME[name]


def cells(include_skips: bool = False):
    """All (arch, shape) cells; skips excluded unless include_skips."""
    out = []
    for a in list_archs():
        cfg = _ARCHS[a]
        for s in ALL_SHAPES:
            skipped = s.name in cfg.skip_shapes
            if include_skips or not skipped:
                out.append((cfg, s, skipped))
    return out
