"""dbrx-132b — 16 experts top-4, fine-grained MoE.
[hf:databricks/dbrx-base; unverified]"""
from repro.configs.base import ArchConfig, Family, MoEConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    family=Family.MOE,
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=10752, vocab_size=100352,
    moe=MoEConfig(num_experts=16, top_k=4),
    skip_shapes=("long_500k",),
    notes="GQA kv=8; full attention => skip long_500k",
)
