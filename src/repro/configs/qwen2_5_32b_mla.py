"""qwen2.5-32b-mla — the qwen2.5-32b stack with MLA latent KV.

Multi-head latent attention (DeepSeek-V3 style): instead of per-head
K/V the cache stores a per-token ``kv_lora_rank``-dim compressed latent
plus a small ``qk_rope_head_dim`` decoupled RoPE head, and decode folds
``wkv_b`` into the query/output einsums (absorb path) so attention runs
directly over the latent. Resident KV per token per layer drops from
``2 * num_kv_heads * head_dim`` floats to ``kv_lora_rank +
qk_rope_head_dim`` — here 576 vs the GQA parent's 2048 (0.28x).
"""
from repro.configs.base import ArchConfig, Family

CONFIG = ArchConfig(
    name="qwen2.5-32b-mla",
    family=Family.DENSE,
    num_layers=64, d_model=5120, num_heads=40, num_kv_heads=40,
    d_ff=27648, vocab_size=152064,
    head_dim=128,
    kv_lora_rank=512, qk_rope_head_dim=64,
    skip_shapes=("long_500k",),
    notes="MLA variant of qwen2.5-32b; latent page rows are c_kv+r=576 floats",
)
