"""Config dataclasses for the repro framework.

Every assigned architecture is expressed as an ``ArchConfig``; the four
assigned input shapes are ``ShapeConfig``s. ``MemoryStrategy`` names the
paper's four optimization rungs (baseline / dual_clock / ultra_ram /
compiler_large_local) — see DESIGN.md §2 for the FPGA→TPU mapping.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Tuple


class Family(str, enum.Enum):
    DENSE = "dense"
    MOE = "moe"
    ENCDEC = "encdec"  # [audio] whisper
    SSM = "ssm"        # rwkv6
    HYBRID = "hybrid"  # hymba
    VLM = "vlm"        # llama-3.2-vision
    CNN = "cnn"        # resnet20 (the paper's own model)


class MemoryStrategy(str, enum.Enum):
    """The paper's optimization ladder (§4.1-4.4), adapted to TPU VMEM."""

    BASELINE = "baseline"                # small VMEM budget, no overlap credit
    DUAL_CLOCK = "dual_clock"            # + movement/compute overlap (double buffering)
    ULTRA_RAM = "ultra_ram"              # + large VMEM budget (fewer partitions)
    COMPILER_LARGE_LOCAL = "compiler_large_local"  # + whole-layer residency planning


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input shape (seq_len x global_batch)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int          # query heads (0 for attn-free)
    num_kv_heads: int       # GQA kv heads
    d_ff: int
    vocab_size: int
    head_dim: int = 0       # 0 => d_model // num_heads
    qkv_bias: bool = False
    mlp_bias: bool = False
    tie_embeddings: bool = False
    norm: str = "rmsnorm"   # rmsnorm | layernorm
    rope_theta: float = 10000.0
    moe: Optional[MoEConfig] = None
    # MLA (multi-head latent attention): >0 => cache a per-token
    # kv_lora_rank-dim latent + a qk_rope_head_dim decoupled RoPE head
    # instead of per-head K/V; head_dim doubles as qk_nope/v head width
    kv_lora_rank: int = 0
    qk_rope_head_dim: int = 0
    # hybrid / ssm
    ssm_state: int = 0
    window: int = 0              # sliding-window size for attention heads (0 = full)
    # enc-dec
    encoder_layers: int = 0      # >0 => enc-dec; num_layers is decoder depth
    # vlm
    cross_attn_every: int = 0    # >0 => cross-attn image layers every N layers
    num_image_tokens: int = 0
    # training shape overrides / skips
    skip_shapes: Tuple[str, ...] = ()
    notes: str = ""

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def padded_vocab(self) -> int:
        """Embedding/unembedding tables are padded to a 512 multiple so the
        vocab dim shards evenly on any mesh axis up to 512; logits beyond
        vocab_size are masked to -inf (layers.lm_logits)."""
        return ((self.vocab_size + 511) // 512) * 512

    # ----- parameter counting (for 6ND roofline + FSDP sizing) -----
    def _attn_params(self) -> int:
        if self.num_heads == 0:
            return 0
        hd = self.head_dim
        if self.kv_lora_rank:
            c, r = self.kv_lora_rank, self.qk_rope_head_dim
            q = self.d_model * self.num_heads * (hd + r)
            kv_a = self.d_model * (c + r) + c  # wkv_a + latent rmsnorm
            kv_b = c * self.num_heads * 2 * hd
            o = self.num_heads * hd * self.d_model
            return q + kv_a + kv_b + o
        q = self.d_model * self.num_heads * hd
        kv = 2 * self.d_model * self.num_kv_heads * hd
        o = self.num_heads * hd * self.d_model
        b = (self.num_heads * hd + 2 * self.num_kv_heads * hd) if self.qkv_bias else 0
        return q + kv + o + b

    def _ffn_params(self, gated: bool = True) -> int:
        mult = 3 if gated else 2
        return mult * self.d_model * self.d_ff

    def layer_params(self) -> int:
        """Params of one decoder layer (dense part + routed experts)."""
        p = self._attn_params() + 2 * self.d_model  # 2 norms
        if self.moe:
            p += self.moe.num_experts * self._ffn_params() + self.d_model * self.moe.num_experts
        else:
            p += self._ffn_params()
        if self.family == Family.SSM:
            # rwkv6: replaces attention with time-mix (r,k,v,w,g,o ~ 6 d^2) + channel-mix
            p = 6 * self.d_model * self.d_model + 2 * self.d_model * self.d_ff + 2 * self.d_model
        if self.family == Family.HYBRID:
            p += 2 * self.d_model * self.d_model  # parallel SSM in/out projections
        return p

    def total_params(self) -> int:
        emb = self.vocab_size * self.d_model
        unemb = 0 if self.tie_embeddings else self.vocab_size * self.d_model
        layers = self.num_layers * self.layer_params()
        if self.encoder_layers:
            enc = self.encoder_layers * (self._attn_params() + self._ffn_params(gated=False)
                                         + 2 * self.d_model)
            # decoder cross-attn blocks
            layers += self.num_layers * self._attn_params()
            layers += enc
        if self.cross_attn_every:
            n_cross = self.num_layers // self.cross_attn_every
            layers += n_cross * self._attn_params()
        return emb + unemb + layers + self.d_model  # final norm

    def active_params(self) -> int:
        """Activated params per token (= total for dense; routed top-k for MoE)."""
        if not self.moe:
            return self.total_params()
        dense_layer = self._attn_params() + 2 * self.d_model + self.d_model * self.moe.num_experts
        active_ffn = self.moe.top_k * self._ffn_params()
        layers = self.num_layers * (dense_layer + active_ffn)
        emb = self.vocab_size * self.d_model * (1 if self.tie_embeddings else 2)
        return emb + layers + self.d_model
