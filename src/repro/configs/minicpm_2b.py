"""minicpm-2b — dense llama-like arch, WSD schedule. [arXiv:2404.06395; hf]"""
from repro.configs.base import ArchConfig, Family

CONFIG = ArchConfig(
    name="minicpm-2b",
    family=Family.DENSE,
    num_layers=40, d_model=2304, num_heads=36, num_kv_heads=36,
    d_ff=5760, vocab_size=122753,
    tie_embeddings=True,
    skip_shapes=("long_500k",),
    notes="WSD (warmup-stable-decay) schedule wired in optim/schedules.py; "
          "full attention => skip long_500k",
)
