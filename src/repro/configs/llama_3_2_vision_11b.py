"""llama-3.2-vision-11b — cross-attn image layers every 5th layer; vision
frontend is a STUB (input_specs() provides patch embeddings).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from repro.configs.base import ArchConfig, Family

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    family=Family.VLM,
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=128256,
    cross_attn_every=5, num_image_tokens=1601,
    skip_shapes=("long_500k",),
    notes="cross-attn every 5th layer to 1601 patch embeddings; "
          "full attention => skip long_500k",
)
