"""command-r-35b — dense GQA, no biases. [hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from repro.configs.base import ArchConfig, Family

CONFIG = ArchConfig(
    name="command-r-35b",
    family=Family.DENSE,
    num_layers=40, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=22528, vocab_size=256000,
    norm="layernorm",
    skip_shapes=("long_500k",),
    notes="cohere-style parallel-ish block approximated as sequential; no-bias; "
          "full attention => skip long_500k",
)
