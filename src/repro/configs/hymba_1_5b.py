"""hymba-1.5b — hybrid: parallel attention + mamba heads per layer, ssm_state=16.
Attention heads use sliding-window (1024) => sub-quadratic => long_500k RUNS.
[arXiv:2411.13676; hf]"""
from repro.configs.base import ArchConfig, Family

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family=Family.HYBRID,
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
    d_ff=5504, vocab_size=32001,
    head_dim=64, ssm_state=16, window=1024,
    notes="parallel attn+mamba heads; windowed attention => long_500k runs",
)
