"""AdamW with decoupled weight decay, global-norm clipping and schedule
support — pure-pytree (no optax dependency). Optimizer state mirrors the
parameter tree so it inherits parameter shardings (ZeRO-3 under FSDP rules).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: Callable[[jax.Array], jax.Array] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0

    def init(self, params):
        zeros = lambda p: jnp.zeros_like(p)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def _lr(self, count):
        if callable(self.learning_rate):
            return self.learning_rate(count)
        return jnp.asarray(self.learning_rate, jnp.float32)

    def update(self, grads, state, params):
        count = state["count"] + 1
        if self.clip_norm is not None:
            gn = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gn, 1e-12))
            grads = jax.tree.map(lambda g: g * scale, grads)
        else:
            gn = global_norm(grads)
        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g, state["m"], grads)
        v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * g * g, state["v"], grads)
        c = count.astype(jnp.float32)
        mhat_scale = 1.0 / (1 - b1 ** c)
        vhat_scale = 1.0 / (1 - b2 ** c)
        lr = self._lr(count)

        def upd(p, mm, vv):
            u = (mm * mhat_scale) / (jnp.sqrt(vv * vhat_scale) + self.eps)
            u = u + self.weight_decay * p
            return (-lr * u).astype(p.dtype)

        updates = jax.tree.map(upd, params, m, v)
        return updates, {"m": m, "v": v, "count": count}, gn


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)
