"""Gradient compression for the data-parallel all-reduce, with error feedback.

At 1000+ nodes the DP gradient reduction is the dominant inter-pod collective;
int8 compression cuts its wire bytes 4x vs fp32 (2x vs bf16). Implemented as a
shard_map over the data axes: each shard quantizes its local gradient with a
per-tensor scale, psums the int32 accumulation (wire-compressed in spirit; XLA
reduces int8->int32 to avoid overflow), dequantizes, and keeps the
quantization residual locally as error feedback added to the NEXT step's
gradient — the standard EF-SGD trick that restores convergence.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def quantize_int8(g, scale_floor: float = 1e-12):
    amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax, scale_floor) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum_tree(grads, error, axis_names: Tuple[str, ...], n_shards: int):
    """Per-leaf: EF-add -> int8 quantize on a COMMON (pmax) scale -> psum of
    int32 -> dequant -> mean. The shared scale makes sum(q_i)*scale ==
    sum(q_i*scale_i) exact; the wire carries int8/int32 instead of fp32.
    Returns (mean_grads, new_error). Runs INSIDE shard_map."""
    def one(g, e):
        g = g + e
        amax = jax.lax.pmax(jnp.max(jnp.abs(g)), axis_names)
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        total = jax.lax.psum(q.astype(jnp.int32), axis_names)
        deq_local = q.astype(jnp.float32) * scale
        new_e = g - deq_local                      # local quantization residual
        mean = total.astype(jnp.float32) * scale / n_shards
        return mean.astype(g.dtype), new_e.astype(g.dtype)
    pairs = jax.tree.map(one, grads, error)
    mean = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda v: isinstance(v, tuple))
    new_e = jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda v: isinstance(v, tuple))
    return mean, new_e


def make_compressed_allreduce(mesh, param_specs, dp_axes=("pod", "data")):
    """Returns allreduce(grads, error) -> (mean_grads, new_error), a shard_map
    whose collective is the compressed DP reduction. `param_specs`: pytree of
    PartitionSpecs for the gradient leaves (grads enter sharded, leave sharded
    the same way; only the DP axes are reduced)."""
    axes = tuple(a for a in dp_axes if a in mesh.axis_names)
    n = 1
    for a in axes:
        n *= mesh.shape[a]

    fn = functools.partial(compressed_psum_tree, axis_names=axes, n_shards=n)
    return shard_map(fn, mesh=mesh,
                     in_specs=(param_specs, param_specs),
                     out_specs=(param_specs, param_specs),
                     check_rep=False)


def init_error(params):
    return jax.tree.map(jnp.zeros_like, params)
