"""LR schedules: cosine (default) and WSD (warmup-stable-decay — MiniCPM,
arXiv:2404.06395 §4), both as count->lr callables for AdamW."""
from __future__ import annotations

import jax.numpy as jnp


def cosine(peak_lr: float, warmup_steps: int, total_steps: int,
           min_ratio: float = 0.1):
    def fn(count):
        c = count.astype(jnp.float32)
        warm = peak_lr * c / max(warmup_steps, 1)
        frac = jnp.clip((c - warmup_steps) / max(total_steps - warmup_steps, 1),
                        0.0, 1.0)
        cos = peak_lr * (min_ratio + (1 - min_ratio) * 0.5
                         * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(c < warmup_steps, warm, cos)
    return fn


def wsd(peak_lr: float, warmup_steps: int, stable_steps: int, decay_steps: int,
        min_ratio: float = 0.01):
    """Warmup-Stable-Decay: linear warmup, long flat stable phase, short
    exponential-ish (linear here) decay tail."""
    def fn(count):
        c = count.astype(jnp.float32)
        warm = peak_lr * c / max(warmup_steps, 1)
        stable = jnp.asarray(peak_lr, jnp.float32)
        dfrac = jnp.clip((c - warmup_steps - stable_steps) / max(decay_steps, 1),
                         0.0, 1.0)
        decay = peak_lr * (1.0 - (1.0 - min_ratio) * dfrac)
        out = jnp.where(c < warmup_steps, warm,
                        jnp.where(c < warmup_steps + stable_steps, stable, decay))
        return out
    return fn


def constant(lr: float):
    return lambda count: jnp.asarray(lr, jnp.float32)
