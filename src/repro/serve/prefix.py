"""Page-level prefix cache: chain-hashed immutable KV pages shared across
requests, with copy-on-write and LRU eviction.

The serving analogue of the paper's on-chip data-reuse lever (Ultra-RAM
residency; Guo et al.'s decisive efficiency knob): identical prompt prefixes
— few-shot headers, system prompts — dominate production traffic, and their
K/V pages are a pure function of the token prefix, so recomputing them per
request moves and computes bytes the pool already holds.

Design
------

* **Identity = chain hash at page granularity.** Page ``i`` of a prompt is
  keyed by ``h_i = H(h_{i-1}, tokens[i*ps:(i+1)*ps])`` — a page's identity
  includes every predecessor, so a hit on ``h_i`` guarantees the whole
  aligned prefix matches, and lookup is a forward walk that stops at the
  first miss. A prompt's unaligned tail (``len % ps`` tokens) registers one
  PARTIAL entry keyed the same way over the shorter slice.
* **Entries hold references, never copies.** ``register`` takes one
  allocator reference per indexed page (``PageAllocator.share``); a page
  leaves the index only through ``evict``, which releases that reference —
  the page returns to the free list iff no live block table still aliases
  it. An indexed page can therefore never be on the free list (the
  refcount/COW property tests pin this).
* **Sharing is alias-only for full pages; partial pages are COW sources.**
  A hit's full pages go straight into the new request's block table (reads
  only — every row the request will ever write lies beyond them). A partial
  hit's page WOULD be written (the tail splice, or decode appending past the
  prefix), so the engine gives the request a fresh page instead and
  re-materialises the shared rows into it through the normal splice scatter
  — copy-on-write with zero extra device passes.
* **Eviction is LRU over index-only pages.** Lookup touches its hits;
  ``evict`` walks oldest-first and frees entries whose page has no block
  table reference left (allocator refcount 1 — the index's own), leaving
  admission's defer-in-FIFO-order logic untouched: deferral now simply
  happens after eviction has been given the chance to replenish the free
  list.

Only families whose per-request recurrent state is exactly the attention
K/V rows (dense / MoE / VLM transformers) are cacheable: the hybrid ring's
mamba carry and the SSM/rwkv state at an arbitrary split point are not
reconstructible from pages, and the encoder-decoder cross-K/V is not
page-resident. The engine gates on this and falls back to full prefill.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

_SEED = b"repro-prefix-v1"


def chain_hash(prev: bytes, tokens: np.ndarray) -> bytes:
    """One link of the page chain hash: H(predecessor digest || token bytes).
    blake2b is stable across processes (unlike ``hash()``) and fast enough
    that a lookup is O(prompt_len) bytes hashed."""
    return hashlib.blake2b(
        prev + np.ascontiguousarray(tokens, np.int32).tobytes(),
        digest_size=16).digest()


@dataclasses.dataclass
class PrefixPlan:
    """One request's prefix-cache resolution, computed at admission.

    ``shared_pages`` alias directly into the block table (immutable full
    pages); ``partial`` names a copy-on-write SOURCE page — the engine
    allocates a fresh page in its place and the splice re-materialises the
    shared rows. ``full_hashes``/``partial_key`` are the prompt's complete
    chain (hits and misses alike) so registration after prefill needs no
    re-hashing."""
    cached_len: int                          # prefix rows reusable from pool
    shared_pages: List[int]                  # aliased full pages, chain order
    partial: Optional[Tuple[int, int]]       # (source page id, valid rows)
    full_hashes: List[bytes]                 # chain keys of ALL full pages
    partial_key: Optional[bytes]             # chain key of the unaligned tail
    partial_rows: int                        # rows of that tail (p % ps)

    @property
    def hit(self) -> bool:
        return self.cached_len > 0

    @property
    def cow(self) -> bool:
        return self.partial is not None


class PrefixIndex:
    """Refcounted hash -> page index over a :class:`PageAllocator`'s pool.

    Host-side bookkeeping only (like the allocator): nothing here touches
    device memory. The engine owns the device-side consequences — aliasing
    pages into block tables, gathering prefix rows into transient prefill
    caches, and re-materialising COW pages via the splice scatter."""

    def __init__(self, allocator, page_size: int):
        self.allocator = allocator
        self.page_size = int(page_size)
        # key -> (page id, valid rows); OrderedDict order IS the LRU order
        # (move_to_end on every hit), oldest first
        self._entries: "OrderedDict[bytes, Tuple[int, int]]" = OrderedDict()
        self.evictions = 0
        # monotone content version (bumped on register/evict): lets the
        # engine skip re-resolving a deferred head request's plan when
        # neither the free list nor the index has changed since it deferred
        self.version = 0

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def pages(self) -> Dict[int, int]:
        """page id -> valid rows for every indexed page (test/debug view)."""
        return {page: rows for page, rows in self._entries.values()}

    @property
    def reclaimable(self) -> int:
        """Pages ``evict`` could free right now (refcount 1: held only by
        the index). Admission-control pressure counts these as available —
        a warm cache legitimately parks most of the free list in
        index-only pages, and shedding load over memory that one ``evict``
        call would hand back is a false positive."""
        return sum(1 for page, _ in self._entries.values()
                   if self.allocator.refcount(page) == 1)

    # ------------------------------------------------------------ lookup
    def lookup(self, prompt: np.ndarray, touch: bool = True) -> PrefixPlan:
        """Longest cached page-aligned prefix of ``prompt``.

        Walks the chain over the prompt's full pages until the first miss,
        then probes the first missed region for a PARTIAL entry, longest
        slice first (an unaligned prefix another request registered). Always
        returns the complete hash chain so the caller can register its own
        pages after prefill without re-hashing. ``touch=False`` (the
        scheduler's ordering hint probe) leaves the LRU order unchanged."""
        prompt = np.asarray(prompt, np.int32)
        p = len(prompt)
        ps = self.page_size
        n_full, rem = divmod(p, ps)
        h = _SEED
        full_hashes: List[bytes] = []
        for i in range(n_full):
            h = chain_hash(h, prompt[i * ps:(i + 1) * ps])
            full_hashes.append(h)
        partial_key = chain_hash(h, prompt[n_full * ps:]) if rem else None

        shared: List[int] = []
        hit_keys: List[bytes] = []
        for hh in full_hashes:
            entry = self._entries.get(hh)
            if entry is None or entry[1] != ps:
                break
            shared.append(entry[0])
            hit_keys.append(hh)
        k = len(shared)

        # probe the first missed region for a shorter (partial) entry
        partial = None
        base = full_hashes[k - 1] if k else _SEED
        region = prompt[k * ps:min((k + 1) * ps, p)]
        for j in range(min(ps - 1, len(region)), 0, -1):
            key = chain_hash(base, region[:j])
            entry = self._entries.get(key)
            if entry is not None and entry[1] == j:
                partial = (entry[0], j)
                hit_keys.append(key)
                break

        if touch:
            self._touch_chain(hit_keys)
        cached_len = k * ps + (partial[1] if partial else 0)
        return PrefixPlan(cached_len=cached_len, shared_pages=shared,
                          partial=partial, full_hashes=full_hashes,
                          partial_key=partial_key, partial_rows=rem)

    def _touch_chain(self, keys: List[bytes]):
        """Refresh a chain's LRU position DEEPEST-FIRST, root last, so the
        root ends most-recently-used. Eviction walks oldest-first: touching
        the chain root first would make IT the chain's eviction victim,
        which breaks every lookup of the prefix at the first link while the
        still-held descendant pages become unreachable dead weight. With
        root-last touching, chains shrink from the deep end — each evicted
        page only shortens the longest hit, never zeroes it."""
        for key in reversed(keys):
            self._entries.move_to_end(key)

    def probe_len(self, prompt) -> int:
        """Cached-prefix length WITHOUT touching the LRU order — the
        scheduler's prefix-aware admission ordering hint."""
        return self.lookup(prompt, touch=False).cached_len

    # ---------------------------------------------------------- register
    def register(self, plan: PrefixPlan, pages: List[int], prompt_len: int):
        """Index a freshly prefilled request's prompt pages.

        Full prompt pages register under their chain hash; the unaligned
        tail registers as a partial entry. Each NEW entry takes one
        allocator reference (released only by eviction). Hashes already
        present keep their existing page — a duplicate prompt admitted
        before the first copy registered simply never shares, and its own
        pages free normally at completion."""
        ps = self.page_size
        n_full = prompt_len // ps
        chain: List[bytes] = []
        for i in range(n_full):
            key = plan.full_hashes[i]
            if key not in self._entries:
                self.allocator.share(pages[i])
                self._entries[key] = (pages[i], ps)
            chain.append(key)
        if plan.partial_rows and plan.partial_key is not None:
            key = plan.partial_key
            if key not in self._entries:
                self.allocator.share(pages[n_full])
                self._entries[key] = (pages[n_full], plan.partial_rows)
            chain.append(key)
        self._touch_chain(chain)
        self.version += 1

    # ------------------------------------------------------------ evict
    def evict(self, need_pages: int) -> int:
        """Free up to ``need_pages`` pages by dropping LRU entries whose page
        no live block table references (allocator refcount 1 — the index's
        own reference). Entries still aliased by running requests are
        skipped: their pages cannot be reclaimed, and evicting the entry
        alone would only lose future hits. Returns pages actually freed."""
        freed = 0
        for key in list(self._entries):
            if freed >= need_pages:
                break
            page, _ = self._entries[key]
            if self.allocator.refcount(page) == 1:
                del self._entries[key]
                self.allocator.release([page])
                freed += 1
                self.evictions += 1
                self.version += 1
        return freed
