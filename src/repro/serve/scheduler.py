"""Request queue for the serving engine: priority levels, FIFO within a
level, O(log n) admission. The engine pops a request the moment a batch slot
frees (continuous batching); nothing here touches device state.
"""
from __future__ import annotations

import dataclasses
import enum
import heapq
import itertools
import math
from typing import List, Optional

import numpy as np


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"     # slot + pages reserved, prompt being chunked
    RUNNING = "running"
    DONE = "done"
    FAILED = "failed"             # prefill dispatch raised; resources released
    CANCELLED = "cancelled"       # caller aborted; resources released


@dataclasses.dataclass
class Request:
    """One generation request. ``priority`` is ascending: 0 is served before
    1 (think nice levels); equal priorities are FIFO by submission order."""
    rid: int
    prompt: np.ndarray            # (prompt_len,) int token ids
    gen_len: int
    priority: int = 0
    state: RequestState = RequestState.QUEUED
    slot: Optional[int] = None
    tokens: List[int] = dataclasses.field(default_factory=list)
    # prefix-cache rows the engine's submit-time probe found for this prompt
    # (0 = none/unknown). A prefix-aware scheduler uses it as an ordering
    # HINT within a priority level; it is advisory — the authoritative
    # lookup happens again at admission.
    prefix_hint: int = 0
    # set when the request leaves via FAILED (the prefill error, stringified)
    # or CANCELLED ("cancelled") instead of completing
    error: Optional[str] = None
    # scheduler-assigned arrival sequence, set once on FIRST submit and kept
    # across re-queues: a preempted request rejoins the FIFO order at its
    # original arrival position instead of the back of its priority level
    seq: Optional[int] = None
    # count of ``tokens`` entries already folded into ``prompt`` by
    # preemption; a later preemption folds only ``tokens[folded:]`` so a
    # twice-preempted request never duplicates context
    folded: int = 0
    # absolute completion deadline (same clock the caller schedules on;
    # the scheduler only compares values). inf = no deadline — sorts after
    # every dated request under EDF and leaves pure-FIFO streams unchanged.
    deadline: float = math.inf

    @property
    def remaining(self) -> int:
        return max(0, self.gen_len - len(self.tokens))

    @property
    def done(self) -> bool:
        return len(self.tokens) >= self.gen_len


@dataclasses.dataclass(frozen=True)
class SchedPolicy:
    """Opt-in scheduling features for the serve engine. EVERY default is
    "off": an engine built with ``SchedPolicy()`` (or ``policy=None``) emits
    bit-identical greedy token streams to the pre-policy engine — the
    standing anchor discipline. Each knob is independent; the bench's burst
    cell enables them together.

    - ``drr``: deficit round-robin across concurrent prefill jobs. Each
      tick every pending job earns ``drr_quantum`` chunk-token credit and
      jobs spend credit to dispatch chunks, so one long prompt can no
      longer monopolize the per-tick chunk budget (FIFO job order is the
      off-behavior). ``drr_quantum=0`` derives the quantum from the chunk
      budget split over pending jobs.
    - ``max_consecutive_prefill_ticks``: decode-starvation guard. After N
      consecutive ticks in which prefill dispatched work while slots were
      decoding, one tick skips prefill so running requests always make
      token progress under sustained admission pressure. 0 disables.
    - ``preemption``: under pool pressure, pause the lowest-priority
      RUNNING slot (strictly lower than the queue head), release its pages
      and re-queue it recompute-style — generated tokens fold into the
      prompt and re-prefill on re-admission (pages are cheap to release/
      alias; KV is reproducible). The request keeps its arrival ``seq``.
    - ``admission_low_water`` / ``admission_shed_priority``: admission
      control. When the free-page fraction drops below the low-water mark,
      queued requests at ``priority >= admission_shed_priority`` are shed
      (FAILED, ``admission_shed=True``) or deferred in place (False)
      instead of admitted. ``low_water=0.0`` disables.
    """
    drr: bool = False
    drr_quantum: int = 0
    max_consecutive_prefill_ticks: int = 0
    preemption: bool = False
    admission_low_water: float = 0.0
    admission_shed_priority: Optional[int] = None
    admission_shed: bool = True
    # SLO-aware admission ordering: earliest-deadline-first WITHIN a
    # priority level (priority still dominates; undated requests keep FIFO
    # among themselves behind every dated one). Off = pure FIFO, the
    # bit-exact anchor.
    edf: bool = False


class Scheduler:
    """Priority + FIFO admission queue, optionally prefix-aware.

    ``submit`` pushes; ``next_request`` pops the lowest (priority, hint
    rank, deadline key, seq) tuple. A monotone sequence number breaks ties
    so equal-priority requests leave in arrival order and the heap never
    compares Request objects directly. The sequence number is assigned once
    per request and survives re-queues (preemption), so a paused request
    keeps its arrival position. ``edf=True`` (SchedPolicy.edf) makes the
    deadline key ``Request.deadline`` — earliest-deadline-first within a
    (priority, hint-rank) class, with undated (inf) requests in FIFO order
    behind the dated ones; off, the key is constant and ordering is the
    exact pre-EDF FIFO.

    Lazily-cancelled requests (``cancel()`` flips a QUEUED request to
    CANCELLED without touching the heap) are pruned here, at the single
    source of truth: ``peek``/``next_request`` skip dead heads and
    ``waiting``/``__len__``/``__bool__`` count only live entries, so every
    consumer agrees and no caller needs its own skip loop.

    ``prefix_aware=True`` turns ``Request.prefix_hint`` (set by the engine's
    submit-time prefix-cache probe) into an ordering HINT: within a priority
    level, requests whose prompt prefix is already cached admit first —
    their pages are resident NOW, and serving them before the cache churns
    converts the hint into real skipped prefill. Strict FIFO is preserved
    within each (priority, hinted?) class, and the default (False) keeps
    the exact PR 1 ordering semantics.

    FAIRNESS: the hint ages. Each time a hinted request pops ahead of an
    older unhinted request of the same priority the bypass counter ticks;
    after ``hint_max_bypasses`` consecutive bypasses the OLDEST bypassed
    unhinted request is promoted to the hinted rank (keeping its seq), so a
    sustained cached-header stream can delay a cold prompt by at most
    ``hint_max_bypasses`` admissions instead of forever. Priorities still
    dominate the hint and have no aging ("think nice levels").
    """

    def __init__(self, prefix_aware: bool = False,
                 hint_max_bypasses: int = 4, edf: bool = False):
        self._heap: list = []
        self._seq = itertools.count()
        self.prefix_aware = prefix_aware
        self.hint_max_bypasses = hint_max_bypasses
        self.edf = edf
        self._bypasses = 0            # consecutive hinted-over-unhinted pops

    def _rank(self, req: Request) -> int:
        if not self.prefix_aware:
            return 0
        return 0 if req.prefix_hint > 0 else 1

    def _dkey(self, req: Request) -> float:
        """EDF sort key between (priority, hint-rank) and arrival seq: the
        request deadline when EDF is on, a constant otherwise (ordering then
        falls through to seq — exact FIFO, the anchor behavior). Undated
        requests carry deadline=inf, so among themselves they stay FIFO and
        every dated request overtakes them within the priority level."""
        return req.deadline if self.edf else 0.0

    def submit(self, req: Request) -> Request:
        if req.state != RequestState.QUEUED:
            raise ValueError(f"request {req.rid} is {req.state}, not QUEUED")
        if req.seq is None:
            req.seq = next(self._seq)
        heapq.heappush(self._heap,
                       (req.priority, self._rank(req), self._dkey(req),
                        req.seq, req))
        return req

    def _prune(self):
        """Drop lazily-cancelled entries from the heap head so peek/pop
        never surface a dead request."""
        while self._heap and \
                self._heap[0][-1].state is RequestState.CANCELLED:
            heapq.heappop(self._heap)

    def _age_hint(self, popped_prio: int, popped_rank: int, popped_seq: int):
        """Hint aging: count pops where a hinted request bypasses an older
        unhinted request of the same priority; at the bound, promote the
        oldest such victim to the hinted rank (seq preserved) and reset."""
        if not self.prefix_aware or self.hint_max_bypasses <= 0:
            return
        if popped_rank != 0:              # an unhinted request was served:
            self._bypasses = 0            # the stream is not starving anyone
            return
        victims = [i for i, (p, rank, dk, seq, r) in enumerate(self._heap)
                   if p == popped_prio and rank == 1 and seq < popped_seq
                   and r.state is not RequestState.CANCELLED]
        if not victims:
            self._bypasses = 0
            return
        self._bypasses += 1
        if self._bypasses < self.hint_max_bypasses:
            return
        oldest = min(victims, key=lambda i: self._heap[i][3])
        prio, _, dk, seq, req = self._heap[oldest]
        self._heap[oldest] = (prio, 0, dk, seq, req)
        heapq.heapify(self._heap)
        self._bypasses = 0

    def next_request(self) -> Optional[Request]:
        self._prune()
        if not self._heap:
            return None
        prio, rank, dk, seq, req = heapq.heappop(self._heap)
        self._age_hint(prio, rank, seq)
        return req

    def peek(self) -> Optional[Request]:
        """Head of the queue WITHOUT popping — the engine's paged admission
        peeks first so a request that cannot be covered by the free-page list
        defers in place (strict priority/FIFO order, no skip-ahead) instead of
        being popped and stranded."""
        self._prune()
        if not self._heap:
            return None
        return self._heap[0][-1]

    @property
    def waiting(self) -> int:
        # O(n): lazily-cancelled entries deeper in the heap must not count.
        # Queues here are small (hundreds at most) and the engine polls this
        # once per tick, so the scan is cheaper than keeping a side index
        # coherent with engine-side state flips.
        return sum(1 for *_, r in self._heap
                   if r.state is not RequestState.CANCELLED)

    def __len__(self) -> int:
        return self.waiting

    def __bool__(self) -> bool:
        return any(r.state is not RequestState.CANCELLED
                   for *_, r in self._heap)
