"""Request queue for the serving engine: priority levels, FIFO within a
level, O(log n) admission. The engine pops a request the moment a batch slot
frees (continuous batching); nothing here touches device state.
"""
from __future__ import annotations

import dataclasses
import enum
import heapq
import itertools
from typing import List, Optional

import numpy as np


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"     # slot + pages reserved, prompt being chunked
    RUNNING = "running"
    DONE = "done"
    FAILED = "failed"             # prefill dispatch raised; resources released
    CANCELLED = "cancelled"       # caller aborted; resources released


@dataclasses.dataclass
class Request:
    """One generation request. ``priority`` is ascending: 0 is served before
    1 (think nice levels); equal priorities are FIFO by submission order."""
    rid: int
    prompt: np.ndarray            # (prompt_len,) int token ids
    gen_len: int
    priority: int = 0
    state: RequestState = RequestState.QUEUED
    slot: Optional[int] = None
    tokens: List[int] = dataclasses.field(default_factory=list)
    # prefix-cache rows the engine's submit-time probe found for this prompt
    # (0 = none/unknown). A prefix-aware scheduler uses it as an ordering
    # HINT within a priority level; it is advisory — the authoritative
    # lookup happens again at admission.
    prefix_hint: int = 0
    # set when the request leaves via FAILED (the prefill error, stringified)
    # or CANCELLED ("cancelled") instead of completing
    error: Optional[str] = None

    @property
    def remaining(self) -> int:
        return max(0, self.gen_len - len(self.tokens))

    @property
    def done(self) -> bool:
        return len(self.tokens) >= self.gen_len


class Scheduler:
    """Priority + FIFO admission queue, optionally prefix-aware.

    ``submit`` pushes; ``next_request`` pops the lowest (priority, hint
    rank, seq) tuple. A monotone sequence number breaks ties so
    equal-priority requests leave in arrival order and the heap never
    compares Request objects directly.

    ``prefix_aware=True`` turns ``Request.prefix_hint`` (set by the engine's
    submit-time prefix-cache probe) into an ordering HINT: within a priority
    level, requests whose prompt prefix is already cached admit first —
    their pages are resident NOW, and serving them before the cache churns
    converts the hint into real skipped prefill. Strict FIFO is preserved
    within each (priority, hinted?) class, and the default (False) keeps
    the exact PR 1 ordering semantics.

    FAIRNESS TRADEOFF: like the priority field itself (a steady priority-0
    stream starves priority 1 forever — "think nice levels"), the hint has
    no aging: under a sustained stream of cached-header traffic an unhinted
    equal-priority request can be bypassed indefinitely. That is the deal
    this opt-in makes — hit locality over strict arrival order. Deployments
    needing a latency floor for cold prompts should encode it in
    ``priority`` (which always dominates the hint) rather than enable this.
    """

    def __init__(self, prefix_aware: bool = False):
        self._heap: list = []
        self._seq = itertools.count()
        self.prefix_aware = prefix_aware

    def _rank(self, req: Request) -> int:
        if not self.prefix_aware:
            return 0
        return 0 if req.prefix_hint > 0 else 1

    def submit(self, req: Request) -> Request:
        if req.state != RequestState.QUEUED:
            raise ValueError(f"request {req.rid} is {req.state}, not QUEUED")
        heapq.heappush(self._heap,
                       (req.priority, self._rank(req), next(self._seq), req))
        return req

    def next_request(self) -> Optional[Request]:
        if not self._heap:
            return None
        *_, req = heapq.heappop(self._heap)
        return req

    def peek(self) -> Optional[Request]:
        """Head of the queue WITHOUT popping — the engine's paged admission
        peeks first so a request that cannot be covered by the free-page list
        defers in place (strict priority/FIFO order, no skip-ahead) instead of
        being popped and stranded."""
        if not self._heap:
            return None
        return self._heap[0][-1]

    @property
    def waiting(self) -> int:
        return len(self._heap)

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)
