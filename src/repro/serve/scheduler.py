"""Request queue for the serving engine: priority levels, FIFO within a
level, O(log n) admission. The engine pops a request the moment a batch slot
frees (continuous batching); nothing here touches device state.
"""
from __future__ import annotations

import dataclasses
import enum
import heapq
import itertools
from typing import List, Optional

import numpy as np


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"     # slot + pages reserved, prompt being chunked
    RUNNING = "running"
    DONE = "done"


@dataclasses.dataclass
class Request:
    """One generation request. ``priority`` is ascending: 0 is served before
    1 (think nice levels); equal priorities are FIFO by submission order."""
    rid: int
    prompt: np.ndarray            # (prompt_len,) int token ids
    gen_len: int
    priority: int = 0
    state: RequestState = RequestState.QUEUED
    slot: Optional[int] = None
    tokens: List[int] = dataclasses.field(default_factory=list)

    @property
    def remaining(self) -> int:
        return max(0, self.gen_len - len(self.tokens))

    @property
    def done(self) -> bool:
        return len(self.tokens) >= self.gen_len


class Scheduler:
    """Priority + FIFO admission queue.

    ``submit`` pushes; ``next_request`` pops the lowest (priority, seq) pair.
    A monotone sequence number breaks priority ties so equal-priority
    requests leave in arrival order and the heap never compares Request
    objects directly.
    """

    def __init__(self):
        self._heap: list = []
        self._seq = itertools.count()

    def submit(self, req: Request) -> Request:
        if req.state != RequestState.QUEUED:
            raise ValueError(f"request {req.rid} is {req.state}, not QUEUED")
        heapq.heappush(self._heap, (req.priority, next(self._seq), req))
        return req

    def next_request(self) -> Optional[Request]:
        if not self._heap:
            return None
        _, _, req = heapq.heappop(self._heap)
        return req

    def peek(self) -> Optional[Request]:
        """Head of the queue WITHOUT popping — the engine's paged admission
        peeks first so a request that cannot be covered by the free-page list
        defers in place (strict priority/FIFO order, no skip-ahead) instead of
        being popped and stranded."""
        if not self._heap:
            return None
        return self._heap[0][2]

    @property
    def waiting(self) -> int:
        return len(self._heap)

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)
