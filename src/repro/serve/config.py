"""Validated serving build configuration: :class:`ServeConfig`.

``ServeEngine.build`` grew one keyword at a time across the serving PRs
(sampling knobs, paging, backends, prefill modes, scheduling policy, tensor
parallelism) until call sites carried a dozen positional-by-name arguments
with the invariants between them enforced late — some only inside
``ServeEngine.__init__`` after params were already initialised, some only
inside a backend constructor. ServeConfig collapses that surface into one
dataclass:

    engine = ServeEngine.build("qwen2.5-32b-mla", config=ServeConfig(
        page_size=16, kv_backend="paged_latent"))

``validate()`` checks every cross-field invariant up front (paged-required-
for-tp, the backend's own ``tp_compatible`` capability answer, page
alignment, backend-name resolution against the :data:`kvcache.BACKENDS`
registry), so a bad combination fails before any model weights are built. The old ``build(**kwargs)`` spelling
still works through a shim that emits a ``DeprecationWarning`` and maps the
kwargs onto a ServeConfig — behaviour is identical by construction, because
the shim produces the same dataclass the config path consumes.

The engine's ``__init__`` keeps its own guards: direct construction with a
hand-built model bypasses build() entirely, and defense there is what the
existing error-message tests pin.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax.numpy as jnp

from repro.serve.kvcache import (BACKENDS, KVBackend, _shards_kv_heads,
                                 check_tp_support)


@dataclasses.dataclass
class ServeConfig:
    """Everything ``ServeEngine.build`` needs beyond the arch id.

    Field groups:

    * model: ``reduced`` (CI-size config), ``cfg_overrides`` (post-reduction
      ``dataclasses.replace`` fields), ``quantize_int8`` (weight PTQ),
      ``compute_dtype``, ``seed``;
    * capacity: ``batch_slots``, ``s_max``;
    * sampling: ``temperature``, ``top_k``, ``top_p``;
    * cache representation: ``page_size``/``num_pages`` (None = dense),
      ``kv_backend`` (a :data:`kvcache.BACKENDS` name, a ready
      :class:`KVBackend`, or None = layout follows page_size),
      ``prefix_cache`` (None = auto);
    * prefill/decode paths: ``prefill_mode``, ``prefill_chunk_tokens``,
      ``prefill_attn_impl``, ``paged_attn_impl``;
    * scheduling: ``policy`` (SchedPolicy; None = all-off defaults);
    * parallelism: ``tp`` (1-axis serving mesh degree; None = no mesh).
    """

    reduced: bool = True
    batch_slots: int = 4
    s_max: int = 64
    seed: int = 0
    quantize_int8: bool = False
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    page_size: Optional[int] = None
    num_pages: Optional[int] = None
    kv_backend: Any = None
    prefix_cache: Optional[bool] = None
    prefill_mode: str = "parallel"
    prefill_chunk_tokens: int = 64
    prefill_attn_impl: str = "auto"
    paged_attn_impl: str = "auto"
    policy: Any = None
    compute_dtype: Any = jnp.float32
    tp: Optional[int] = None
    cfg_overrides: Optional[dict] = None

    def _backend_name(self) -> Optional[str]:
        """The registry name the kv_backend field resolves to (None when the
        layout just follows page_size)."""
        if isinstance(self.kv_backend, KVBackend):
            return self.kv_backend.name
        return self.kv_backend

    def validate(self, cfg=None) -> "ServeConfig":
        """Raise ValueError on any inconsistent field combination; returns
        self so call sites can chain ``ServeConfig(...).validate()``.

        ``cfg``: optional resolved ArchConfig for the arch-dependent checks
        (kv-head divisibility under tp, MLA requirement of the latent
        backend). Without it only arch-independent invariants run."""
        if self.batch_slots < 1:
            raise ValueError(f"batch_slots must be >= 1, got "
                             f"{self.batch_slots}")
        if self.s_max < 1:
            raise ValueError(f"s_max must be >= 1, got {self.s_max}")
        if int(self.top_k) < 0:
            raise ValueError(f"top_k must be >= 0 (0 = off), got "
                             f"{self.top_k}")
        if not 0.0 < float(self.top_p) <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.prefill_mode not in ("parallel", "scan"):
            raise ValueError(f"prefill_mode must be 'parallel' or 'scan', "
                             f"got {self.prefill_mode!r}")
        if self.paged_attn_impl not in ("auto", "kernel", "einsum"):
            raise ValueError(f"paged_attn_impl must be 'auto', 'kernel' or "
                             f"'einsum', got {self.paged_attn_impl!r}")
        if self.prefill_chunk_tokens < 1:
            raise ValueError(f"prefill_chunk_tokens must be >= 1, got "
                             f"{self.prefill_chunk_tokens}")

        name = self._backend_name()
        if isinstance(self.kv_backend, KVBackend):
            paged_backend = self.kv_backend.paged
        elif isinstance(name, str):
            if name not in BACKENDS:
                raise ValueError(f"unknown kv_backend {name!r}; available: "
                                 f"{sorted(BACKENDS)}")
            paged_backend = BACKENDS[name].paged
        else:
            paged_backend = None
        if self.page_size is not None:
            if self.page_size < 1:
                raise ValueError(f"page_size must be >= 1, got "
                                 f"{self.page_size}")
            if self.s_max % self.page_size:
                raise ValueError(f"s_max {self.s_max} must be a multiple of "
                                 f"page_size {self.page_size}")
            if paged_backend is False:
                raise ValueError(f"kv_backend={name!r} conflicts with "
                                 f"page_size={self.page_size}; drop one of "
                                 f"them")
        elif paged_backend:
            raise ValueError(f"kv_backend={name!r} needs page_size")

        tp = self.tp or 1
        if tp > 1:
            if self.page_size is None:
                raise ValueError(
                    "tensor-parallel serving needs a PAGED cache (pass "
                    "page_size=): only the page pool has a mesh layout")
            if isinstance(self.kv_backend, KVBackend):
                cls = type(self.kv_backend)
            elif isinstance(name, str):
                cls = BACKENDS[name]
            else:
                cls = BACKENDS["paged"]     # layout follows page_size
            check_tp_support(cls, tp)
            if (cfg is not None and _shards_kv_heads(cls)
                    and cfg.num_kv_heads % tp):
                raise ValueError(
                    f"num_kv_heads={cfg.num_kv_heads} is not divisible by "
                    f"tp={tp}; pick a tp dividing the kv-head count "
                    "(whole GQA groups must stay shard-local)")
        if (cfg is not None and name == "paged_latent"
                and getattr(cfg, "kv_lora_rank", 0) <= 0):
            raise ValueError(
                f"kv_backend='paged_latent' needs an MLA arch "
                f"(kv_lora_rank > 0); {cfg.name!r} caches per-head K/V — "
                f"use kv_backend='paged'")
        return self

    def engine_kwargs(self) -> dict:
        """The ``ServeEngine.__init__`` keyword subset (build() resolves
        the model/mesh fields — reduced, quantize_int8, tp, cfg_overrides —
        itself)."""
        return dict(
            batch_slots=self.batch_slots, s_max=self.s_max,
            compute_dtype=self.compute_dtype, temperature=self.temperature,
            top_k=self.top_k, top_p=self.top_p, page_size=self.page_size,
            num_pages=self.num_pages, kv_backend=self.kv_backend,
            prefix_cache=self.prefix_cache, prefill_mode=self.prefill_mode,
            prefill_chunk_tokens=self.prefill_chunk_tokens,
            prefill_attn_impl=self.prefill_attn_impl,
            paged_attn_impl=self.paged_attn_impl, policy=self.policy,
            seed=self.seed)
