"""Open-loop SLO traffic harness: seeded workload generation + replay.

The closed-loop bench submits the next request when a slot frees, so the
arrival process adapts to the server and queueing collapse is invisible —
the server sets its own pace. Real load does not: arrivals are OPEN-LOOP
(a Poisson process does not care that the engine is busy), lengths are
heavy-tailed, tenants carry different priorities, and traffic bursts. This
module generates such a workload DETERMINISTICALLY from a seed (same seed
=> identical arrival/length/priority schedule, the property the CI gate
depends on) and replays it against a live engine on a real clock, metering
GOODPUT — tokens/s delivered within the TTFT + per-request p95 inter-token
SLO (:class:`repro.serve.metrics.SLO`) — instead of raw tokens/s.

``python -m repro.serve.workload`` runs a short self-contained smoke replay
(the CI traffic-harness step).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Tuple

import numpy as np

from repro.serve.metrics import ReplaySummary, SLO


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Seeded open-loop workload description.

    Arrivals are Poisson at ``rate_rps`` (exponential inter-arrival gaps);
    inside the burst window — ``[burst_start_frac, burst_start_frac +
    burst_len_frac)`` of the nominal horizon ``n_requests / rate_rps`` —
    the instantaneous rate is multiplied by ``burst_mult``. Prompt and
    generation lengths are lognormal (median/sigma parameterised — the
    heavy tail is the point: a few long requests among many short ones)
    clipped to ``[1, *_max]``. Priorities are drawn from the
    ``priority_weights`` mix ((priority, weight) pairs, ascending priority
    = more important first, "think nice levels")."""
    n_requests: int
    rate_rps: float
    seed: int = 0
    prompt_len_median: int = 24
    prompt_len_sigma: float = 0.6
    prompt_len_max: int = 64
    gen_len_median: int = 8
    gen_len_sigma: float = 0.5
    gen_len_max: int = 32
    priority_weights: Tuple[Tuple[int, float], ...] = ((0, 1.0),)
    burst_start_frac: float = 0.0
    burst_len_frac: float = 0.0
    burst_mult: float = 1.0


@dataclasses.dataclass(frozen=True)
class ArrivalEvent:
    """One generated arrival: submit ``prompt`` (``gen_len`` tokens to
    generate, at ``priority``) ``t`` seconds after replay start."""
    t: float
    prompt: np.ndarray
    gen_len: int
    priority: int


def _clipped_lognormal(rng: np.random.Generator, median: int, sigma: float,
                       upper: int) -> int:
    x = rng.lognormal(mean=float(np.log(max(median, 1))), sigma=sigma)
    return int(np.clip(round(x), 1, upper))


def generate(spec: WorkloadSpec, vocab_size: int) -> List[ArrivalEvent]:
    """Materialise the workload: a list of events sorted by arrival time.
    Every random draw comes from one ``default_rng(seed)`` in a fixed
    per-event order (gap, prompt len, gen len, priority, tokens), so equal
    specs generate byte-identical schedules on any platform."""
    if spec.n_requests <= 0:
        raise ValueError(f"n_requests must be positive, got {spec.n_requests}")
    if spec.rate_rps <= 0:
        raise ValueError(f"rate_rps must be positive, got {spec.rate_rps}")
    rng = np.random.default_rng(spec.seed)
    prios = [p for p, _ in spec.priority_weights]
    weights = np.asarray([w for _, w in spec.priority_weights], np.float64)
    weights = weights / weights.sum()
    horizon = spec.n_requests / spec.rate_rps
    burst_lo = spec.burst_start_frac * horizon
    burst_hi = burst_lo + spec.burst_len_frac * horizon
    events: List[ArrivalEvent] = []
    t = 0.0
    for _ in range(spec.n_requests):
        rate = spec.rate_rps
        if burst_lo <= t < burst_hi:
            rate *= spec.burst_mult
        t += float(rng.exponential(1.0 / rate))
        plen = _clipped_lognormal(rng, spec.prompt_len_median,
                                  spec.prompt_len_sigma, spec.prompt_len_max)
        glen = _clipped_lognormal(rng, spec.gen_len_median,
                                  spec.gen_len_sigma, spec.gen_len_max)
        prio = int(prios[rng.choice(len(prios), p=weights)])
        prompt = rng.integers(0, vocab_size, plen).astype(np.int32)
        events.append(ArrivalEvent(t=t, prompt=prompt, gen_len=glen,
                                   priority=prio))
    return events


def replay(engine, events: List[ArrivalEvent],
           slo: Optional[SLO] = None) -> ReplaySummary:
    """Open-loop replay on a real clock: each event is submitted at its
    arrival offset WHETHER OR NOT the engine has caught up (queueing under
    overload is exactly what the harness measures), with engine ticks in
    between; returns a :class:`ReplaySummary` wrapping
    ``engine.metrics.summary(slo)`` — including the ``goodput`` section
    when an SLO is given. Dict-style indexing keeps working
    (``summary["requests"]``), same as the multi-replica
    ``router.replay``."""
    ev = sorted(events, key=lambda e: e.t)
    m = engine.metrics
    m.on_start()
    t0 = m.now()
    i = 0
    while i < len(ev) or engine.scheduler.waiting or engine.active:
        now = m.now() - t0
        while i < len(ev) and ev[i].t <= now:
            engine.submit(ev[i].prompt, ev[i].gen_len,
                          priority=ev[i].priority)
            i += 1
        if engine.scheduler.waiting or engine.active:
            engine.step()
        elif i < len(ev):
            # fully idle: doze until the next arrival instead of spinning,
            # capped so the loop stays responsive to the clock
            time.sleep(min(0.010, max(0.0, ev[i].t - (m.now() - t0))))
    m.on_stop()
    return ReplaySummary(metrics=m.summary(slo))


def _main(argv=None) -> int:
    """Short self-contained smoke replay (the CI traffic-harness step):
    build a small reduced paged engine, generate a bursty multi-tenant
    workload, replay it under an SLO with the scheduling policy ON, and
    print the summary JSON. Exits non-zero if the replay drops requests on
    the floor (submitted != completed + aborted) or meters zero goodput
    denominator — structural harness failures, not SLO misses (a loaded CI
    machine may legitimately miss latency targets)."""
    import argparse
    import json

    from repro.serve.config import ServeConfig
    from repro.serve.engine import ServeEngine
    from repro.serve.scheduler import SchedPolicy

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2.5-32b")
    ap.add_argument("--n", type=int, default=12)
    ap.add_argument("--rate", type=float, default=20.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--burst-mult", type=float, default=3.0)
    ap.add_argument("--slo-ttft", type=float, default=60.0)
    ap.add_argument("--slo-itl", type=float, default=30.0)
    ap.add_argument("--fifo", action="store_true",
                    help="disable the SLO-aware policy (baseline replay)")
    ap.add_argument("--kv-backend", default=None,
                    help="cache backend registry name (paged | paged_int8 "
                         "| paged_latent; default: layout follows "
                         "page_size). paged_latent needs an MLA --arch")
    ap.add_argument("--tp", type=int, default=None,
                    help="tensor-parallel degree (needs that many local "
                         "devices; any registered backend composes via "
                         "its sharding hooks)")
    args = ap.parse_args(argv)

    policy = None if args.fifo else SchedPolicy(
        drr=True, max_consecutive_prefill_ticks=2, preemption=True,
        admission_low_water=0.15, admission_shed_priority=2)
    eng = ServeEngine.build(args.arch, config=ServeConfig(
        reduced=True, batch_slots=2, s_max=96, page_size=16, policy=policy,
        kv_backend=args.kv_backend, tp=args.tp))
    spec = WorkloadSpec(
        n_requests=args.n, rate_rps=args.rate, seed=args.seed,
        prompt_len_median=16, prompt_len_max=64,
        gen_len_median=4, gen_len_max=16,
        priority_weights=((0, 0.5), (1, 0.3), (2, 0.2)),
        burst_start_frac=0.2, burst_len_frac=0.4,
        burst_mult=args.burst_mult)
    events = generate(spec, eng.cfg.vocab_size)
    summary = replay(eng, events,
                     slo=SLO(ttft_s=args.slo_ttft, itl_p95_s=args.slo_itl))
    print(json.dumps(summary.to_dict(), indent=2, default=float))
    ok = (summary["requests"] == args.n
          and summary["completed"] + summary["aborted"] == args.n
          and summary["goodput"]["submitted"] == args.n)
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(_main())
