"""Serving metrics: per-request time-to-first-token, tokens/s, and request
latency, plus engine-level p50/p95 and throughput. Pure host-side bookkeeping
— the engine calls the ``on_*`` hooks; ``summary()`` aggregates.

The clock is injectable so tests can drive deterministic timelines.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class RequestRecord:
    rid: int
    prompt_len: int
    t_submit: float
    t_admit: Optional[float] = None           # slot reserved, prefill begins
    t_first_token: Optional[float] = None     # prefill done, token 1 sampled
    t_done: Optional[float] = None
    n_tokens: int = 0
    aborted: bool = False     # FAILED/CANCELLED: excluded from completion
    #                           counts and latency percentiles (a request
    #                           cancelled right after submit would otherwise
    #                           enter latency_s p50 as ~0 s)

    @property
    def ttft_s(self) -> Optional[float]:
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.t_submit

    @property
    def queue_wait_s(self) -> Optional[float]:
        """Time spent waiting for a slot/pages — the scheduling share of
        TTFT, split out so chunked prefill's head-of-line win (shorter
        waits behind long prompts) is visible separately from prefill
        compute time."""
        if self.t_admit is None:
            return None
        return self.t_admit - self.t_submit

    @property
    def latency_s(self) -> Optional[float]:
        if self.t_done is None:
            return None
        return self.t_done - self.t_submit

    @property
    def tokens_per_s(self) -> Optional[float]:
        lat = self.latency_s
        if lat is None or self.n_tokens == 0:
            return None
        return self.n_tokens / max(lat, 1e-9)


def percentile(xs: List[float], q: float) -> float:
    if not xs:
        return float("nan")
    return float(np.percentile(np.asarray(xs, np.float64), q))


# Shortest wall interval credited with throughput. Walls below this are clock
# granularity noise (or an injected test clock that never advanced): dividing
# by them reports absurd token rates, so summary() clamps the denominator.
MIN_WALL_S = 1e-6


class MetricsRecorder:
    """Collects request lifecycle timestamps and engine counters."""

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self._clock = clock
        self.requests: Dict[int, RequestRecord] = {}
        self.decode_steps = 0
        self.prefills = 0
        self.prefill_tokens = 0
        self.prefill_chunks = 0
        self.prefill_chunk_tokens = 0         # token·rows pushed through chunks
        self.prefill_wall_s = 0.0             # wall spent inside chunk calls
        self.prefill_chunk_max_tokens = 0     # largest single chunk dispatch
        # prefix cache (one lookup per paged admission when enabled)
        self.prefix_lookups = 0
        self.prefix_hits = 0
        self.prefix_hit_tokens = 0            # prompt rows served from pages
        self.prefix_pages_shared = 0          # full pages aliased, no copy
        self.prefix_cow_copies = 0            # partial pages re-materialised
        self.prefix_evictions = 0             # LRU entries dropped for space
        self._t_start: Optional[float] = None
        self._t_stop: Optional[float] = None

    def now(self) -> float:
        """The recorder's clock — engines time prefill chunks with it so
        injected test clocks drive deterministic rates."""
        return self._clock()

    # ------------------------------------------------------------ hooks
    def on_start(self):
        if self._t_start is None:
            self._t_start = self._clock()

    def on_stop(self):
        self._t_stop = self._clock()

    def on_submit(self, rid: int, prompt_len: int):
        self.requests[rid] = RequestRecord(rid=rid, prompt_len=prompt_len,
                                           t_submit=self._clock())

    def on_admit(self, rid: int):
        rec = self.requests[rid]
        if rec.t_admit is None:
            rec.t_admit = self._clock()

    def on_prefill(self, rid: int, prompt_len: int):
        self.prefills += 1
        self.prefill_tokens += prompt_len

    def on_prefill_chunk(self, n_tokens: int, wall_s: float):
        """One prefill chunk dispatch: ``n_tokens`` = group batch x chunk
        length (the rows of K/V it produced), ``wall_s`` its wall time.
        ``prefill_chunk_tokens / prefill_wall_s`` is the prefill tokens/s the
        bench reports; ``prefill_chunk_max_tokens`` bounds the work a single
        tick can insert between two decode ticks (the head-of-line bound
        chunked interleaving exists to enforce)."""
        self.prefill_chunks += 1
        self.prefill_chunk_tokens += n_tokens
        self.prefill_wall_s += wall_s
        self.prefill_chunk_max_tokens = max(self.prefill_chunk_max_tokens,
                                            n_tokens)

    def on_prefix_lookup(self, hit_tokens: int, pages_shared: int,
                         cow: bool):
        """One prefix-cache lookup at admission: ``hit_tokens`` prompt rows
        will be served from shared pages instead of recomputed
        (0 = miss), ``pages_shared`` full pages alias into the block table,
        ``cow`` marks a partial page re-materialised copy-on-write."""
        self.prefix_lookups += 1
        if hit_tokens > 0:
            self.prefix_hits += 1
            self.prefix_hit_tokens += hit_tokens
        self.prefix_pages_shared += pages_shared
        if cow:
            self.prefix_cow_copies += 1

    def on_prefix_evict(self, n_pages: int):
        self.prefix_evictions += n_pages

    def on_prefix_gather(self, wall_s: float):
        """Wall spent gathering shared prefix rows into a transient prefill
        cache — charged to prefill wall so hit-path prefill tokens/s pays
        for its own overhead (the bench's effective rate stays honest)."""
        self.prefill_wall_s += wall_s

    def on_first_token(self, rid: int):
        rec = self.requests[rid]
        if rec.t_first_token is None:
            rec.t_first_token = self._clock()
        rec.n_tokens += 1

    def on_token(self, rid: int):
        self.requests[rid].n_tokens += 1

    def on_done(self, rid: int):
        # idempotent: a duplicate _finish must not move t_done forward and
        # skew the latency percentiles
        rec = self.requests[rid]
        if rec.t_done is None:
            rec.t_done = self._clock()

    def on_aborted(self, rid: int):
        """Close a record for a FAILED or CANCELLED request: the record is
        finalized (drain-able) but excluded from ``completed`` and the
        latency/tokens-per-second percentiles — an abort is not a served
        request. Idempotent like on_done."""
        rec = self.requests[rid]
        if rec.t_done is None:
            rec.t_done = self._clock()
        rec.aborted = True

    def on_decode_step(self):
        self.decode_steps += 1

    # ------------------------------------------------------------ summary
    def summary(self) -> dict:
        recs = list(self.requests.values())
        done = [r for r in recs if r.t_done is not None and not r.aborted]
        ttfts = [r.ttft_s for r in done if r.ttft_s is not None]
        waits = [r.queue_wait_s for r in done if r.queue_wait_s is not None]
        lats = [r.latency_s for r in done]
        tps = [r.tokens_per_s for r in done if r.tokens_per_s is not None]
        total_tokens = sum(r.n_tokens for r in recs)
        t_end = self._t_stop if self._t_stop is not None else self._clock()
        # without on_start() (engine driven via step(), not run()) there is
        # no wall clock — report NaN like the other missing-data fields, not
        # a 1e9x-inflated throughput over a zero denominator; positive but
        # sub-MIN_WALL_S walls clamp to MIN_WALL_S instead of silently
        # reporting a near-infinite rate
        wall = (t_end - self._t_start) if self._t_start is not None else \
            float("nan")
        return {
            "requests": len(recs),
            "completed": len(done),
            "aborted": sum(1 for r in recs if r.aborted),
            "wall_s": wall,
            "total_tokens": total_tokens,
            "throughput_tokens_per_s": (total_tokens / max(wall, MIN_WALL_S)
                                        if wall > 0 else float("nan")),
            "decode_steps": self.decode_steps,
            "prefills": self.prefills,
            "prefill_tokens": self.prefill_tokens,
            "prefill_chunks": self.prefill_chunks,
            "prefill_chunk_max_tokens": self.prefill_chunk_max_tokens,
            # prefill throughput over the wall spent INSIDE chunk dispatches
            # — measures the forward's arithmetic intensity, not queueing
            "prefill_tokens_per_s": (
                self.prefill_chunk_tokens / max(self.prefill_wall_s,
                                                MIN_WALL_S)
                if self.prefill_wall_s > 0 else float("nan")),
            # prefix cache: hit_rate is per-LOOKUP (one lookup per paged
            # admission when enabled); hit_tokens / prefill_tokens is the
            # fraction of prompt rows served from shared pages
            "prefix": {
                "lookups": self.prefix_lookups,
                "hits": self.prefix_hits,
                "hit_rate": (self.prefix_hits / self.prefix_lookups
                             if self.prefix_lookups else float("nan")),
                "hit_tokens": self.prefix_hit_tokens,
                "pages_shared": self.prefix_pages_shared,
                "cow_copies": self.prefix_cow_copies,
                "evictions": self.prefix_evictions,
            },
            "queue_wait_s": {"mean": float(np.mean(waits)) if waits
                             else float("nan"),
                             "p50": percentile(waits, 50),
                             "p95": percentile(waits, 95)},
            "ttft_s": {"mean": float(np.mean(ttfts)) if ttfts else float("nan"),
                       "p50": percentile(ttfts, 50),
                       "p95": percentile(ttfts, 95)},
            "latency_s": {"p50": percentile(lats, 50),
                          "p95": percentile(lats, 95)},
            "request_tokens_per_s": {"p50": percentile(tps, 50),
                                     "p95": percentile(tps, 95)},
        }
