"""Serving metrics: per-request time-to-first-token, tokens/s, and request
latency, plus engine-level p50/p95 and throughput. Pure host-side bookkeeping
— the engine calls the ``on_*`` hooks; ``summary()`` aggregates.

The clock is injectable so tests can drive deterministic timelines.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class RequestRecord:
    rid: int
    prompt_len: int
    t_submit: float
    priority: int = 0
    t_admit: Optional[float] = None           # slot reserved, prefill begins
    t_first_token: Optional[float] = None     # prefill done, token 1 sampled
    t_last_token: Optional[float] = None      # most recent token (ITL base)
    t_done: Optional[float] = None
    n_tokens: int = 0
    itl_s: List[float] = dataclasses.field(default_factory=list)
    #                           inter-token gaps (len == n_tokens - 1 for a
    #                           normally-streamed request); the per-request
    #                           p95 of these is what the ITL SLO checks
    aborted: bool = False     # FAILED/CANCELLED: excluded from completion
    #                           counts and latency percentiles (a request
    #                           cancelled right after submit would otherwise
    #                           enter latency_s p50 as ~0 s)

    @property
    def ttft_s(self) -> Optional[float]:
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.t_submit

    @property
    def queue_wait_s(self) -> Optional[float]:
        """Time spent waiting for a slot/pages — the scheduling share of
        TTFT, split out so chunked prefill's head-of-line win (shorter
        waits behind long prompts) is visible separately from prefill
        compute time."""
        if self.t_admit is None:
            return None
        return self.t_admit - self.t_submit

    @property
    def latency_s(self) -> Optional[float]:
        if self.t_done is None:
            return None
        return self.t_done - self.t_submit

    @property
    def tokens_per_s(self) -> Optional[float]:
        lat = self.latency_s
        if lat is None or self.n_tokens == 0:
            return None
        return self.n_tokens / max(lat, 1e-9)

    @property
    def itl_p95_s(self) -> Optional[float]:
        """Per-request p95 inter-token gap; None when the request produced
        fewer than two tokens (no gap exists — the ITL SLO is then
        trivially met)."""
        if not self.itl_s:
            return None
        return percentile(self.itl_s, 95)


def percentile(xs: List[float], q: float) -> float:
    if not xs:
        return float("nan")
    return float(np.percentile(np.asarray(xs, np.float64), q))


# Shortest wall interval credited with throughput. Walls below this are clock
# granularity noise (or an injected test clock that never advanced): dividing
# by them reports absurd token rates, so summary() clamps the denominator.
MIN_WALL_S = 1e-6


@dataclasses.dataclass(frozen=True)
class SLO:
    """Service-level objective for the open-loop harness. A request MEETS
    the SLO iff it completed (not aborted), its time-to-first-token is at
    most ``ttft_s``, and the p95 of its inter-token gaps is at most
    ``itl_p95_s`` (single-token requests have no gaps and meet the ITL leg
    trivially). Goodput is tokens/s summed over SLO-meeting requests only;
    attainment denominators count EVERY submitted request — shed and
    aborted load is a miss, not a statistical no-show."""
    ttft_s: float
    itl_p95_s: float

    def met_by(self, rec: RequestRecord) -> bool:
        if rec.aborted or rec.t_done is None:
            return False
        ttft = rec.ttft_s
        if ttft is None or ttft > self.ttft_s:
            return False
        itl = rec.itl_p95_s
        return itl is None or itl <= self.itl_p95_s


@dataclasses.dataclass
class ReplaySummary:
    """Unified result of a traffic replay — the one shape BOTH drivers
    return: ``workload.replay`` (single engine) and ``router.replay``
    (replica tier, with the per-replica breakdown attached).

    ``metrics`` is the engine-level summary dict
    (:meth:`MetricsRecorder.summary`) — for a tier it is the POOLED
    summary over every replica's request records (real pooled percentiles,
    not averages of averages; see :func:`merged_summary`). Dict-style
    access (``summary["goodput"]``, ``summary["replicas"][0]["prefix"]``)
    forwards into ``metrics`` and, on tier results, the
    replicas/router/shed_at_router fields — every pre-ReplaySummary
    consumer keeps indexing exactly as before."""

    metrics: dict
    replicas: Optional[List["ReplaySummary"]] = None   # tier results only
    router: Optional[dict] = None                      # routing/shed counters
    shed_at_router: int = 0

    _TIER_KEYS = ("replicas", "router", "shed_at_router")

    # ------------------------------------------------- dict compatibility
    def __getitem__(self, key):
        if self.replicas is not None and key in self._TIER_KEYS:
            return getattr(self, key)
        return self.metrics[key]

    def get(self, key, default=None):
        try:
            return self[key]
        except KeyError:
            return default

    def keys(self):
        ks = list(self.metrics.keys())
        if self.replicas is not None:
            ks.extend(self._TIER_KEYS)
        return ks

    def __contains__(self, key) -> bool:
        return key in self.keys()

    def to_dict(self) -> dict:
        """Plain-dict view (JSON-dumpable; replicas recurse)."""
        out = dict(self.metrics)
        if self.replicas is not None:
            out["replicas"] = [r.to_dict() if isinstance(r, ReplaySummary)
                               else r for r in self.replicas]
            out["router"] = self.router
            out["shed_at_router"] = self.shed_at_router
        return out

    # ------------------------------------------------- named conveniences
    @property
    def goodput(self) -> Optional[dict]:
        """The goodput/attainment section (None when replayed without an
        SLO)."""
        return self.metrics.get("goodput")

    @property
    def attainment_by_priority(self) -> dict:
        """priority (str) -> attainment section; empty without an SLO."""
        g = self.goodput or {}
        return g.get("by_priority", {})

    @property
    def ttft_p95_s(self) -> float:
        return self.metrics["ttft_s"]["p95"]

    @property
    def itl_p95_s(self) -> float:
        return self.metrics["itl_s"]["p95"]

    @property
    def throughput_tokens_per_s(self) -> float:
        return self.metrics["throughput_tokens_per_s"]


# engine counters pooled by merged_summary: every scalar counter a recorder
# accumulates, except prefill_chunk_max_tokens which merges by max
_SUMMED_COUNTERS = (
    "decode_steps", "prefills", "prefill_tokens", "prefill_chunks",
    "prefill_chunk_tokens", "prefill_wall_s", "prefix_lookups",
    "prefix_hits", "prefix_hit_tokens", "prefix_pages_shared",
    "prefix_cow_copies", "prefix_evictions", "preemptions",
    "shed_requests", "starvation_guard_skips")


def merged_summary(recorders: List["MetricsRecorder"],
                   slo: Optional[SLO] = None) -> dict:
    """Pool several recorders (one per replica) into ONE summary dict: all
    request records land in a single scratch recorder so the percentile /
    goodput / attainment math runs over the pooled population (replica
    averages of percentiles are not percentiles), counters sum, and the
    wall clock spans the earliest start to the latest stop. Recorders
    share the default monotonic clock, so cross-replica timestamps are
    directly comparable."""
    agg = MetricsRecorder()
    i = 0
    for m in recorders:
        for rec in m.requests.values():
            agg.requests[i] = rec
            i += 1
        for name in _SUMMED_COUNTERS:
            setattr(agg, name, getattr(agg, name) + getattr(m, name))
        agg.prefill_chunk_max_tokens = max(agg.prefill_chunk_max_tokens,
                                           m.prefill_chunk_max_tokens)
    starts = [m._t_start for m in recorders if m._t_start is not None]
    stops = [m._t_stop for m in recorders if m._t_stop is not None]
    agg._t_start = min(starts) if starts else None
    agg._t_stop = max(stops) if stops else None
    return agg.summary(slo)


class MetricsRecorder:
    """Collects request lifecycle timestamps and engine counters."""

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self._clock = clock
        self.requests: Dict[int, RequestRecord] = {}
        self.decode_steps = 0
        self.prefills = 0
        self.prefill_tokens = 0
        self.prefill_chunks = 0
        self.prefill_chunk_tokens = 0         # token·rows pushed through chunks
        self.prefill_wall_s = 0.0             # wall spent inside chunk calls
        self.prefill_chunk_max_tokens = 0     # largest single chunk dispatch
        # prefix cache (one lookup per paged admission when enabled)
        self.prefix_lookups = 0
        self.prefix_hits = 0
        self.prefix_hit_tokens = 0            # prompt rows served from pages
        self.prefix_pages_shared = 0          # full pages aliased, no copy
        self.prefix_cow_copies = 0            # partial pages re-materialised
        self.prefix_evictions = 0             # LRU entries dropped for space
        # SLO-aware scheduling counters (all zero with the default policy)
        self.preemptions = 0                  # RUNNING slots paused+re-queued
        self.shed_requests = 0                # admission control gave up early
        self.starvation_guard_skips = 0       # prefill ticks skipped for decode
        self._t_start: Optional[float] = None
        self._t_stop: Optional[float] = None

    def now(self) -> float:
        """The recorder's clock — engines time prefill chunks with it so
        injected test clocks drive deterministic rates."""
        return self._clock()

    # ------------------------------------------------------------ hooks
    def on_start(self):
        if self._t_start is None:
            self._t_start = self._clock()

    def on_stop(self):
        self._t_stop = self._clock()

    def on_submit(self, rid: int, prompt_len: int, priority: int = 0):
        self.requests[rid] = RequestRecord(rid=rid, prompt_len=prompt_len,
                                           t_submit=self._clock(),
                                           priority=priority)

    def on_admit(self, rid: int):
        rec = self.requests[rid]
        if rec.t_admit is None:
            rec.t_admit = self._clock()

    def on_prefill(self, rid: int, prompt_len: int):
        self.prefills += 1
        self.prefill_tokens += prompt_len

    def on_prefill_chunk(self, n_tokens: int, wall_s: float):
        """One prefill chunk dispatch: ``n_tokens`` = group batch x chunk
        length (the rows of K/V it produced), ``wall_s`` its wall time.
        ``prefill_chunk_tokens / prefill_wall_s`` is the prefill tokens/s the
        bench reports; ``prefill_chunk_max_tokens`` bounds the work a single
        tick can insert between two decode ticks (the head-of-line bound
        chunked interleaving exists to enforce)."""
        self.prefill_chunks += 1
        self.prefill_chunk_tokens += n_tokens
        self.prefill_wall_s += wall_s
        self.prefill_chunk_max_tokens = max(self.prefill_chunk_max_tokens,
                                            n_tokens)

    def on_prefix_lookup(self, hit_tokens: int, pages_shared: int,
                         cow: bool):
        """One prefix-cache lookup at admission: ``hit_tokens`` prompt rows
        will be served from shared pages instead of recomputed
        (0 = miss), ``pages_shared`` full pages alias into the block table,
        ``cow`` marks a partial page re-materialised copy-on-write."""
        self.prefix_lookups += 1
        if hit_tokens > 0:
            self.prefix_hits += 1
            self.prefix_hit_tokens += hit_tokens
        self.prefix_pages_shared += pages_shared
        if cow:
            self.prefix_cow_copies += 1

    def on_prefix_evict(self, n_pages: int):
        self.prefix_evictions += n_pages

    def on_prefix_gather(self, wall_s: float):
        """Wall spent gathering shared prefix rows into a transient prefill
        cache — charged to prefill wall so hit-path prefill tokens/s pays
        for its own overhead (the bench's effective rate stays honest)."""
        self.prefill_wall_s += wall_s

    def on_first_token(self, rid: int):
        # idempotent like on_done: the token COUNT rides the same guard as
        # the timestamp, so a duplicate call (retried splice, defensive
        # engine path) cannot double-count token 1
        rec = self.requests[rid]
        if rec.t_first_token is None:
            rec.t_first_token = self._clock()
            rec.t_last_token = rec.t_first_token
            rec.n_tokens += 1

    def on_token(self, rid: int):
        rec = self.requests[rid]
        rec.n_tokens += 1
        now = self._clock()
        if rec.t_last_token is not None:
            rec.itl_s.append(now - rec.t_last_token)
        rec.t_last_token = now

    def on_preempt(self, rid: int):
        """A RUNNING request was paused and re-queued (recompute-style).
        The pause shows up naturally as one long inter-token gap when the
        request resumes — the ITL SLO is exactly what preemption trades
        away for higher-priority TTFT, so nothing is reset here."""
        self.preemptions += 1

    def on_shed(self, rid: int):
        self.shed_requests += 1

    def on_starvation_skip(self):
        self.starvation_guard_skips += 1

    def on_done(self, rid: int):
        # idempotent: a duplicate _finish must not move t_done forward and
        # skew the latency percentiles
        rec = self.requests[rid]
        if rec.t_done is None:
            rec.t_done = self._clock()

    def on_aborted(self, rid: int):
        """Close a record for a FAILED or CANCELLED request: the record is
        finalized (drain-able) but excluded from ``completed`` and the
        latency/tokens-per-second percentiles — an abort is not a served
        request. Idempotent like on_done."""
        rec = self.requests[rid]
        if rec.t_done is None:
            rec.t_done = self._clock()
        rec.aborted = True

    def on_decode_step(self):
        self.decode_steps += 1

    # ------------------------------------------------------------ summary
    def summary(self, slo: Optional[SLO] = None) -> dict:
        recs = list(self.requests.values())
        done = [r for r in recs if r.t_done is not None and not r.aborted]
        ttfts = [r.ttft_s for r in done if r.ttft_s is not None]
        waits = [r.queue_wait_s for r in done if r.queue_wait_s is not None]
        lats = [r.latency_s for r in done]
        tps = [r.tokens_per_s for r in done if r.tokens_per_s is not None]
        itls = [g for r in done for g in r.itl_s]
        # throughput counts SERVED tokens only: a FAILED/CANCELLED request's
        # partial stream was never delivered, so crediting it would inflate
        # tokens/s exactly when the engine is misbehaving (aborts are
        # already excluded from `completed`). Aborted work is still visible,
        # separately, as `aborted_tokens`.
        total_tokens = sum(r.n_tokens for r in recs if not r.aborted)
        aborted_tokens = sum(r.n_tokens for r in recs if r.aborted)
        t_end = self._t_stop if self._t_stop is not None else self._clock()
        # without on_start() (engine driven via step(), not run()) there is
        # no wall clock — report NaN like the other missing-data fields, not
        # a 1e9x-inflated throughput over a zero denominator; positive but
        # sub-MIN_WALL_S walls clamp to MIN_WALL_S instead of silently
        # reporting a near-infinite rate
        wall = (t_end - self._t_start) if self._t_start is not None else \
            float("nan")
        out = {
            "requests": len(recs),
            "completed": len(done),
            "aborted": sum(1 for r in recs if r.aborted),
            "wall_s": wall,
            "total_tokens": total_tokens,
            "aborted_tokens": aborted_tokens,
            "throughput_tokens_per_s": (total_tokens / max(wall, MIN_WALL_S)
                                        if wall > 0 else float("nan")),
            "preemptions": self.preemptions,
            "shed_requests": self.shed_requests,
            "starvation_guard_skips": self.starvation_guard_skips,
            "decode_steps": self.decode_steps,
            "prefills": self.prefills,
            "prefill_tokens": self.prefill_tokens,
            "prefill_chunks": self.prefill_chunks,
            "prefill_chunk_max_tokens": self.prefill_chunk_max_tokens,
            # prefill throughput over the wall spent INSIDE chunk dispatches
            # — measures the forward's arithmetic intensity, not queueing
            "prefill_tokens_per_s": (
                self.prefill_chunk_tokens / max(self.prefill_wall_s,
                                                MIN_WALL_S)
                if self.prefill_wall_s > 0 else float("nan")),
            # prefix cache: hit_rate is per-LOOKUP (one lookup per paged
            # admission when enabled); hit_tokens / prefill_tokens is the
            # fraction of prompt rows served from shared pages
            "prefix": {
                "lookups": self.prefix_lookups,
                "hits": self.prefix_hits,
                "hit_rate": (self.prefix_hits / self.prefix_lookups
                             if self.prefix_lookups else float("nan")),
                "hit_tokens": self.prefix_hit_tokens,
                "pages_shared": self.prefix_pages_shared,
                "cow_copies": self.prefix_cow_copies,
                "evictions": self.prefix_evictions,
            },
            "queue_wait_s": {"mean": float(np.mean(waits)) if waits
                             else float("nan"),
                             "p50": percentile(waits, 50),
                             "p95": percentile(waits, 95)},
            "ttft_s": {"mean": float(np.mean(ttfts)) if ttfts else float("nan"),
                       "p50": percentile(ttfts, 50),
                       "p95": percentile(ttfts, 95)},
            "latency_s": {"p50": percentile(lats, 50),
                          "p95": percentile(lats, 95)},
            "itl_s": {"p50": percentile(itls, 50),
                      "p95": percentile(itls, 95)},
            "request_tokens_per_s": {"p50": percentile(tps, 50),
                                     "p95": percentile(tps, 95)},
        }
        if slo is not None:
            out["goodput"] = self._goodput(recs, slo, wall)
        return out

    def _goodput(self, recs: List[RequestRecord], slo: SLO,
                 wall: float) -> dict:
        """Goodput and SLO attainment, overall and per priority class.
        Attainment denominators are ALL submitted requests of the class —
        a shed or failed request counts as a miss (the alternative, only
        grading survivors, would let admission control buy attainment by
        refusing the very load it is graded on)."""
        def _cls(rs: List[RequestRecord]) -> dict:
            met = [r for r in rs if slo.met_by(r)]
            ttft_ok = [r for r in rs
                       if not r.aborted and r.ttft_s is not None
                       and r.ttft_s <= slo.ttft_s]
            n = len(rs)
            return {
                "submitted": n,
                "completed": sum(1 for r in rs
                                 if r.t_done is not None and not r.aborted),
                "slo_met": len(met),
                "slo_attainment": (len(met) / n) if n else float("nan"),
                "ttft_attainment": (len(ttft_ok) / n) if n else float("nan"),
                "good_tokens": sum(r.n_tokens for r in met),
            }
        overall = _cls(recs)
        by_prio = {}
        for p in sorted({r.priority for r in recs}):
            by_prio[str(p)] = _cls([r for r in recs if r.priority == p])
        return {
            "slo": {"ttft_s": slo.ttft_s, "itl_p95_s": slo.itl_p95_s},
            "goodput_tokens_per_s": (
                overall["good_tokens"] / max(wall, MIN_WALL_S)
                if wall > 0 else float("nan")),
            **overall,
            "by_priority": by_prio,
        }
