"""Prefix-affinity replica router: the front tier over N engine replicas.

Horizontal half of the multi-host story (the vertical half is the
tensor-parallel mesh inside one engine): N independent ``ServeEngine``
replicas, each with its own page pool and ``PrefixIndex``, behind a router
that decides WHERE a request runs. Pure host code — no device state, no new
jit traces; the engines don't know the router exists.

Routing is prefix-AFFINE: requests whose prompts share a page-aligned
header should land on the same replica, because that replica's
``PrefixIndex`` already holds the header's pages — admission then aliases
them (skipped prefill) instead of recomputing them. The affinity key is the
same ``chain_hash`` digest chain ``serve/prefix.py`` keys its index with,
walked over the prompt's first ``header_pages`` FULL pages: two prompts
that would hit the same index chain hash to the same key, and the page
alignment means a differing tail never perturbs the key. Replica choice is
rendezvous (highest-random-weight) hashing of (key, replica): stable under
identical keys, uniform across keys, and no ring state to rebalance.

Load handling, in order:

* headerless prompts (shorter than one page) carry no reusable prefix —
  they go to the least-loaded replica outright;
* a replica above ``queue_limit`` waiting requests exerts BACK-PRESSURE:
  the router spills the request to the least-loaded replica below the
  limit (affinity lost, service retained — counted in ``spills``);
* when every replica is above the limit the request is SHED at the door
  (returned as None, counted per-replica in ``sheds`` against the replica
  affinity wanted) — the same answer the engines' own admission control
  gives under overload, taken one hop earlier.
"""
from __future__ import annotations

import hashlib
import time
from typing import List, Optional

import numpy as np

from repro.serve.metrics import ReplaySummary, SLO, merged_summary
from repro.serve.prefix import _SEED, chain_hash
from repro.serve.workload import ArrivalEvent

__all__ = ["ReplicaRouter"]


class ReplicaRouter:
    """Fan a request stream across engine replicas with prefix affinity.

    ``engines``: the replicas. For affinity routing they must all be PAGED
    with one common page_size (the header key is page-aligned); a mixed or
    dense tier must run with ``affinity=False`` (pure least-loaded +
    round-robin tie-break).

    ``header_pages``: how many leading full pages feed the affinity key.
    Small on purpose — the shared-header traffic the router exists for
    (system prompts, few-shot preambles) concentrates its reuse in the
    first pages, and a short key makes near-miss headers (equal first
    pages, diverging later) still colocate where the index can alias their
    common prefix.

    ``queue_limit``: per-replica waiting-queue depth that triggers spill,
    then shed. None = never spill or shed (pure affinity).
    """

    def __init__(self, engines: List, *, affinity: bool = True,
                 header_pages: int = 4, queue_limit: Optional[int] = None):
        if not engines:
            raise ValueError("ReplicaRouter needs at least one engine")
        self.engines = list(engines)
        self.affinity = bool(affinity)
        self.header_pages = int(header_pages)
        self.queue_limit = queue_limit
        if self.affinity:
            sizes = {getattr(e, "page_size", None) for e in self.engines}
            if len(sizes) != 1 or None in sizes:
                raise ValueError(
                    "prefix-affinity routing needs paged replicas sharing "
                    f"one page_size (got {sorted(map(str, sizes))}); build "
                    "the tier uniformly or pass affinity=False")
            self.page_size = sizes.pop()
        else:
            self.page_size = getattr(self.engines[0], "page_size", None)
        n = len(self.engines)
        self._rr = 0                       # round-robin cursor (affinity off)
        self.routed = [0] * n              # submissions accepted per replica
        self.sheds = [0] * n               # shed at the door, per wanted replica
        self.spills = 0                    # affinity target over limit, rerouted
        self.affine = 0                    # routed by header key
        self.headerless = 0                # routed least-loaded (no full page)

    # ------------------------------------------------------------ routing
    def header_key(self, prompt) -> Optional[bytes]:
        """Page-aligned header digest (None if no full page): the chain
        hash of the prompt's first ``header_pages`` full pages — byte-equal
        to the chain key ``PrefixIndex`` files those pages under."""
        prompt = np.asarray(prompt, np.int32)
        ps = self.page_size
        n_pages = min(len(prompt) // ps, self.header_pages)
        if n_pages <= 0:
            return None
        h = _SEED
        for p in range(n_pages):
            h = chain_hash(h, prompt[p * ps:(p + 1) * ps])
        return h

    def load(self, i: int) -> int:
        """Replica load = queued + occupying a slot (prefilling/decoding)."""
        e = self.engines[i]
        return e.scheduler.waiting + e.active

    def _least_loaded(self, candidates) -> int:
        # round-robin cursor breaks load ties so an idle tier still spreads
        return min(candidates, key=lambda i: (self.load(i), (i - self._rr)
                                              % len(self.engines)))

    def _rendezvous(self, key: bytes) -> int:
        scores = [hashlib.blake2b(key + i.to_bytes(4, "little"),
                                  digest_size=8).digest()
                  for i in range(len(self.engines))]
        return max(range(len(self.engines)), key=lambda i: scores[i])

    def pick(self, prompt) -> int:
        """The replica this prompt WANTS (before back-pressure)."""
        if not self.affinity:
            want = self._rr % len(self.engines)
            return want
        key = self.header_key(prompt)
        if key is None:
            return self._least_loaded(range(len(self.engines)))
        return self._rendezvous(key)

    def submit(self, prompt, gen_len: int, priority: int = 0,
               deadline: Optional[float] = None):
        """Route + submit. Returns ``(request, replica_idx)``, or None when
        the whole tier is saturated (the request is shed, not queued)."""
        want = self.pick(prompt)
        target = want
        if self.affinity:
            if self.header_key(prompt) is None:
                self.headerless += 1
            else:
                self.affine += 1
        lim = self.queue_limit
        if lim is not None and self.engines[target].scheduler.waiting >= lim:
            under = [i for i in range(len(self.engines))
                     if self.engines[i].scheduler.waiting < lim]
            if not under:
                self.sheds[want] += 1
                return None
            target = self._least_loaded(under)
            if target != want:
                self.spills += 1
        req = self.engines[target].submit(prompt, gen_len, priority=priority,
                                          deadline=deadline)
        self.routed[target] += 1
        self._rr += 1
        return req, target

    # ------------------------------------------------------------ driving
    @property
    def pending(self) -> bool:
        return any(e.scheduler.waiting or e.active for e in self.engines)

    def step(self) -> int:
        """One tick across the tier: every replica with work advances once.
        Returns the number of replicas still busy."""
        busy = 0
        for e in self.engines:
            if e.scheduler.waiting or e.active:
                e.step()
                busy += 1
        return busy

    def drain(self, max_ticks: int = 100_000) -> None:
        ticks = 0
        while self.pending:
            self.step()
            ticks += 1
            if ticks > max_ticks:
                raise RuntimeError(f"router drain exceeded {max_ticks} ticks")

    def replay(self, events: List[ArrivalEvent],
               slo: Optional[SLO] = None) -> ReplaySummary:
        """Open-loop replay of a workload stream across the tier (the
        multi-replica twin of ``workload.replay``): events submit at their
        arrival offsets against a real clock, every busy replica ticks in
        between, shed events are dropped at the door. Returns a
        :class:`ReplaySummary` whose top level is the POOLED tier summary
        (percentiles/goodput over every replica's records — the same shape
        the single-engine replay returns) with the per-replica breakdown,
        router counters, and router-shed count attached; the historical
        ``result["replicas"][i]`` / ``result["router"]`` /
        ``result["shed_at_router"]`` indexing still works."""
        ev = sorted(events, key=lambda e: e.t)
        for e in self.engines:
            e.metrics.on_start()
        t0 = time.monotonic()
        i = 0
        shed = 0
        while i < len(ev) or self.pending:
            now = time.monotonic() - t0
            while i < len(ev) and ev[i].t <= now:
                if self.submit(ev[i].prompt, ev[i].gen_len,
                               priority=ev[i].priority) is None:
                    shed += 1
                i += 1
            if not self.step() and i < len(ev):
                time.sleep(min(0.010, max(0.0, ev[i].t - (time.monotonic()
                                                          - t0))))
        for e in self.engines:
            e.metrics.on_stop()
        return ReplaySummary(
            metrics=merged_summary([e.metrics for e in self.engines], slo),
            replicas=[ReplaySummary(metrics=e.metrics.summary(slo))
                      for e in self.engines],
            router=self.stats(),
            shed_at_router=shed,
        )

    def stats(self) -> dict:
        return {
            "routed": list(self.routed),
            "sheds": list(self.sheds),
            "spills": self.spills,
            "affine": self.affine,
            "headerless": self.headerless,
        }
