"""Serving subsystem: batched-prefill engine, paged KV cache, scheduler,
metrics.

The paper's headline FPS ladder comes from restructuring how work is fed to
the accelerator — overlapping movement with compute and keeping state
resident within a hard on-chip budget — without changing the math. This
package reproduces that lesson at the request level twice over: prefill work
is fused into one dispatch, decode state stays resident in per-slot caches,
and (since PR 2) the KV cache is PAGED so resident memory tracks live
tokens, not the slots x s_max worst case — the serving analogue of the
paper's Ultra-RAM layout making memory the first-class design constraint.

Request lifecycle
-----------------

1. **submit** — ``ServeEngine.submit(prompt, gen_len, priority)`` validates
   the request (non-empty prompt, gen_len >= 0, rows it will write fit the
   per-slot bound and — paged — the total pool), wraps it in a
   :class:`~repro.serve.scheduler.Request` and enqueues it on the
   :class:`~repro.serve.scheduler.Scheduler` (priority heap, FIFO within a
   priority level). Metrics record the arrival time. Validation here keeps
   admission infallible: a bad request can never strand popped good ones.
2. **admit / prefill** — the moment batch slots are free, the engine PEEKS
   at the queue head; with a paged cache it first reserves the request's
   worst-case page count from the host-side free list
   (:class:`~repro.serve.engine.PageAllocator`) and DEFERS — strict
   priority/FIFO, no skip-ahead — when pages are short. With the PREFIX
   CACHE enabled (PR 4; paged + parallel prefill + dense/MoE/VLM families),
   admission first resolves the longest cached page-aligned prefix via the
   chain-hash index (:class:`~repro.serve.prefix.PrefixIndex`): hit pages
   alias straight into the request's block table (refcounted — immutable,
   never written), a partial-page hit is re-materialised copy-on-write into
   a fresh page by the completion splice, only the uncached TAIL runs
   ``prefill_chunk`` (seeded from a gather of the shared rows), and LRU
   index-only pages are evicted before admission ever defers. Admission
   reserves the slot and flips the request to PREFILLING; the prompt is
   then ingested
   by the PARALLEL CHUNKED prefill (default, PR 3): chunk lengths BUCKETED
   to a fixed ladder (compile count O(buckets), not O(distinct lengths)),
   each chunk ONE matmul-wide pass per layer (``steps.make_prefill_chunk``)
   that exports the per-layer K/V — ring + recurrent carry for hybrid via an
   associative scan, O(1) state for ssm/rwkv — into a transient request
   cache at the admitted group's batch size (same-length requests batch
   together; never the full slot width). At most one chunk budget of prompt
   positions runs between decode ticks, so a long prompt cannot stall
   in-flight decodes (head-of-line bound). ``prefill_mode='scan'`` keeps the
   teacher-forced single-``lax.scan`` prefill as the bit-exactness anchor.
   Since PR 5 the paged dense/MoE/VLM path splices INCREMENTALLY: each
   chunk scatters its K/V straight into the group's reserved pages and
   attends them through the block-table-gather Pallas kernel
   (``kernels/paged_attention.py`` — fully-masked pages skipped), so no
   transient request cache exists, prefix hits read aliased pages in
   place, and completion only flips the group's positions. On the
   transient (einsum / scan / hybrid / encdec) paths the last chunk's rows
   are spliced into exactly the admitted slots — a batch-axis scatter for
   the dense cache (``registry.insert_cache_rows``), a scatter into
   exactly the slots' OWN pages for the paged one
   (``registry.insert_cache_rows_paged``) — other slots' entries are
   untouched bit-for-bit (the prefill-isolation guarantee). The first
   generated token is sampled from the last chunk's logits; its timestamp
   is the request's time-to-first-token (queue wait, submit -> admit, is
   metered separately). A chunk dispatch that raises — or a ``cancel()``
   from any request state — releases the job's slots, pages, and aliased
   prefix refcounts through ``release_job`` (requests marked
   FAILED/CANCELLED) instead of stranding them. See README.md in this
   package for the admit -> bucket -> chunk -> splice walk-through.
3. **decode** — ``step()`` runs one batched decode tick for all slots
   against the per-slot-position cache (``cache["pos"]`` is a (B,) vector,
   so slots at different sequence depths coexist). Paged caches route
   attention through block-table indirection
   (``layers.attention_decode_paged`` — the Pallas block-gather kernel
   with ``paged_attn_impl='kernel'``, masked-gather einsum otherwise; the
   hybrid ring pages too, and the SSM state stays dense — it is O(1) in
   sequence length). One token per active slot is sampled (greedy or
   temperature); requests that reach ``gen_len`` retire.
4. **complete** — ``_finish`` parks the slot's cache position at the
   ``layers.INACTIVE_POS`` sentinel (all decode paths DROP writes from such
   slots and freeze their recurrent state, so freed rows are bit-stable),
   zeroes the feedback token, and returns the slot's pages to the free
   list; the scheduler admits the next waiting request on the same tick
   (continuous batching). Metrics record completion and compute per-request
   TTFT / tokens-per-second and engine-level p50/p95 latency and throughput
   (idempotent ``on_done``; wall clamped so injectable test clocks cannot
   report absurd rates).

``launch/serve.py`` remains a thin CLI shim over this package.
"""
from repro.serve.config import ServeConfig
from repro.serve.engine import PageAllocator, ServeEngine
from repro.serve.kvcache import (BACKENDS, DenseBackend, KVBackend,
                                 PagedFP32Backend, PagedInt8Backend,
                                 PagedLatentBackend, make_backend,
                                 register_backend)
from repro.serve.metrics import (SLO, MetricsRecorder, ReplaySummary,
                                 merged_summary)
from repro.serve.prefix import PrefixIndex, PrefixPlan
from repro.serve.router import ReplicaRouter
from repro.serve.scheduler import (Request, RequestState, SchedPolicy,
                                   Scheduler)
from repro.serve.workload import ArrivalEvent, WorkloadSpec, generate, replay

__all__ = ["ServeEngine", "ServeConfig", "PageAllocator",
           "MetricsRecorder", "SLO", "ReplaySummary", "merged_summary",
           "KVBackend", "BACKENDS", "register_backend", "make_backend",
           "DenseBackend", "PagedFP32Backend", "PagedInt8Backend",
           "PagedLatentBackend",
           "PrefixIndex", "PrefixPlan", "ReplicaRouter",
           "Request", "RequestState",
           "SchedPolicy", "Scheduler", "ArrivalEvent", "WorkloadSpec",
           "generate", "replay"]
