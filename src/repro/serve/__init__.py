"""Serving subsystem: batched-prefill engine, request scheduler, metrics.

The paper's headline FPS ladder comes from restructuring how work is fed to
the accelerator — overlapping movement with compute and keeping state
resident — without changing the math. This package reproduces that lesson at
the request level: prefill work is fused into one dispatch, decode state
stays resident in per-slot caches, and the scheduler keeps every slot busy.

Request lifecycle
-----------------

1. **submit** — ``ServeEngine.submit(prompt, gen_len, priority)`` wraps the
   prompt in a :class:`~repro.serve.scheduler.Request` and enqueues it on the
   :class:`~repro.serve.scheduler.Scheduler` (priority heap, FIFO within a
   priority level). Metrics record the arrival time.
2. **admit / prefill** — the moment batch slots are free, the engine pops
   waiting requests and prefills them with ONE jitted call
   (``steps.make_prefill(return_cache=True)``): prompts are teacher-forced
   through ``decode_step`` under a single ``lax.scan`` at the admitted
   group's batch size (same-length requests batch together; never the full
   slot width), producing each request's full cache state plus next-token
   logits. The group's cache rows are spliced into exactly the admitted
   slots of the resident batched cache (a batch-axis scatter) — other slots'
   entries are untouched bit-for-bit (the prefill-isolation guarantee). The
   first generated token is sampled from the prefill logits; its timestamp
   is the request's time-to-first-token.
3. **decode** — ``step()`` runs one batched decode tick for all slots against
   the per-slot-position cache (``cache["pos"]`` is a (B,) vector, so slots
   at different sequence depths coexist), samples one token per active slot
   (greedy or temperature), and retires requests that reach ``gen_len``.
4. **complete** — a finished request frees its slot; the scheduler admits the
   next waiting request on the same tick (continuous batching). Metrics
   record completion and compute per-request TTFT / tokens-per-second and
   engine-level p50/p95 latency and throughput.

``launch/serve.py`` remains a thin CLI shim over this package.
"""
from repro.serve.engine import ServeEngine
from repro.serve.metrics import MetricsRecorder
from repro.serve.scheduler import Request, RequestState, Scheduler

__all__ = ["ServeEngine", "MetricsRecorder", "Request", "RequestState",
           "Scheduler"]
