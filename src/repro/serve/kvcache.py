"""Pluggable KV-cache backends: the single seam between the serving engine's
ORCHESTRATION (scheduling, admission, page accounting) and the cache's
REPRESENTATION (pool dtype/shape, splice math, scale metadata).

The engine never touches page-layout internals directly — it holds a
:class:`KVBackend` and calls five representation operations:

    capacity(cfg, s_max)           per-slot row capacity the allocator covers
    init_cache(model, B, s_max)    build the resident cache pytree
    insert_rows(cache, rcache,     completion splice of a transient prefill
                slots, phys_rows)  cache (dense batch scatter / paged pool
                                   scatter, quantizing on the way in for q8)
    copy_rows(cache, src, dst)     COW re-materialisation of a partial
                                   prefix page (q8: the scale rides along)
    seed_prefix(model, s_max, dt)  gather shared prefix rows into a dense
                                   transient cache (q8: dequantized)

plus `resolve_attn_impl` (kernel vs einsum dispatch policy) and the
`page_meta`/`check_page_meta` hooks for per-page metadata invariants.
Everything a representation owns lives here or below (models/layers.py
write/read paths, kernels/paged_attention.py); everything the engine owns
(allocator, block tables, prefix index, job lifecycle) stays in engine.py.

Backends:

* :class:`DenseBackend` — the non-paged (B, s_max) per-slot cache.
* :class:`PagedFP32Backend` — the vLLM-style shared page pool, extracted
  behaviour-preservingly from the pre-backend engine (all bit-exact anchors
  — degenerate page == dense, prefix on == off — hold through this class).
* :class:`PagedInt8Backend` — pages stored int8 with symmetric f32 scales
  (the page is the quantization block, DeepSeek-V3 ``act_quant`` style):
  `k`/`v` pools are int8 and `(L, P, tp)` `k_scale`/`v_scale` leaves ride
  the cache pytree — one scale per page per KV-HEAD GROUP, where group t
  covers the contiguous ``KV/tp`` kv heads shard t owns, so every scale is
  an amax over shard-local values and the quantizing writes never cross
  the mesh (tp=1 keeps one whole-page scale, bitwise the pre-sharding
  layout). Dequant happens inside the paged Pallas kernel's gather (scales
  are scalar-prefetch operands), so decode's HBM KV traffic is ~4x smaller
  where it is bandwidth-bound. Prefix aliasing shares a page's scales with
  its payload; COW re-quantizes the fresh page exactly once (the chunk
  splice that follows the row copy).

* :class:`PagedLatentBackend` — MLA latent pages: each pool row is ONE
  per-token ``(kv_lora_rank + qk_rope_head_dim)``-dim compressed latent
  (shared by every query head via the absorb path) instead of per-head
  K/V. Same allocator/block-table/COW contract as the fp32 pool — COW
  copies a latent row, never per-head K/V — with resident KV per token
  shrunk from ``2 * KV * hd`` to ``c + r`` floats.

Sharding is a first-class property of the protocol, not an engine special
case: ``pool_axes()`` declares each leaf's logical sharding axes (scale
leaves included), ``place(cache, mesh)`` commits a cache pytree onto a
serving mesh from that declaration, and ``tp_compatible(mesh)`` is the
capability query ``ServeConfig.validate`` / ``make_backend`` consult
instead of maintaining a per-backend rejection ladder. A backend that
declares nothing still works under tp — its cache replicates (with a
warning) — so every future representation composes with the mesh for free.

Adding a backend = subclass KVBackend, implement the five operations (and
the layers-level write/read path if the representation changes attention's
view), and register it under a string key with :func:`register_backend`;
:func:`make_backend` resolves names through that :data:`BACKENDS` registry.
"""
from __future__ import annotations

import functools
import logging

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, Family
from repro.core.quantize import page_scale
from repro.models.registry import (Model, cache_capacity, copy_pool_rows,
                                   init_paged_cache, insert_cache_rows,
                                   insert_cache_rows_paged, seed_prefix_cache,
                                   vectorize_cache_pos)

log = logging.getLogger("repro.serve")

# families whose transient prefill state is exactly (k, v, pos) — the ones
# page-level prefix caching (and the int8 backend's dequantizing prefix
# seed) can serve. Hybrid's ring carry and encdec's cross-K/V are not
# reconstructible from pages.
PREFIX_CACHE_FAMILIES = (Family.DENSE, Family.MOE, Family.VLM)

# families whose paged decode/prefill can route through the Pallas
# block-gather kernel (plain causal/windowed attention over the pool; the
# hybrid ring's modular positions need the einsum path)
PAGED_KERNEL_FAMILIES = (Family.DENSE, Family.MOE, Family.VLM, Family.ENCDEC)

# families the int8 backend supports: the quantized write paths live in the
# transformer chunk/decode attention (layers.py); the hybrid ring and
# encdec/ssm extra state keep fp32 representations
INT8_KV_FAMILIES = PREFIX_CACHE_FAMILIES


# ---------------------------------------------------------- jitted helpers
# module-level lru_cache'd jit factories (moved from engine.py): one
# compilation per distinct signature, shared by every engine instance
@functools.lru_cache(maxsize=1)
def _jitted_insert_rows():
    return jax.jit(insert_cache_rows, donate_argnums=(0,))


@functools.lru_cache(maxsize=1)
def _jitted_insert_rows_paged():
    return jax.jit(insert_cache_rows_paged, donate_argnums=(0,))


@functools.lru_cache(maxsize=1)
def _jitted_copy_rows():
    return jax.jit(copy_pool_rows, donate_argnums=(0,))


@functools.lru_cache(maxsize=64)
def _jitted_prefix_seed(model: Model, s_max: int, dtype):
    def seed(cache, phys_rows, row_ok, pos):
        return seed_prefix_cache(model, cache, phys_rows, row_ok, pos,
                                 s_max, dtype)
    return jax.jit(seed)


# ------------------------------------------------------------ int8 splices
def _quantize_pool_rows(req, C: int, ps: int, groups: int = 1):
    """Quantize a transient-cache leaf (L, K, >=C, KV, hd) page-block-wise.
    Returns (q (L,K,C,KV,hd) int8, scale (L,K,C//ps,groups) f32) — one
    symmetric scale per logical page per kv-head GROUP. ``groups`` is the
    serving tp degree: group t covers the contiguous ``KV/groups`` kv heads
    shard t owns, so under a kv-head-sharded pool each scale entry is an
    amax over shard-LOCAL values only and the quantizing write partitions
    comm-free (GSPMD splits the group axis exactly along the shards).
    ``groups=1`` reproduces the original whole-page scale bitwise. The
    engine's write floor is page-aligned, so a splice drops whole pages at
    a time and payload/scale stay consistent."""
    rows = req[:, :, :C].astype(jnp.float32)
    Lr, K = rows.shape[:2]
    KV, hd = rows.shape[3], rows.shape[4]
    blocks = rows.reshape(Lr, K, C // ps, ps, groups, KV // groups, hd)
    scale = page_scale(jnp.max(jnp.abs(blocks), axis=(3, 5, 6)))
    q = jnp.clip(jnp.round(blocks / scale[:, :, :, None, :, None, None]),
                 -127, 127).astype(jnp.int8)
    return q.reshape(Lr, K, C, KV, hd), scale


def insert_cache_rows_paged_q8(cache, request_cache, slots, phys_rows):
    """Int8 completion splice: like ``registry.insert_cache_rows_paged`` but
    the fp32 transient K/V rows are QUANTIZED page-by-page on the way into
    the int8 pools, and each written page's scales land in the (L, P, tp)
    scale tables (the group count rides the scale leaf's trailing dim).
    Rows/pages outside the request's reservation (phys >= P * ps —
    including everything below a page-aligned write floor) are dropped
    from payload AND scale alike."""
    slots = jnp.asarray(slots, jnp.int32)
    phys_rows = jnp.asarray(phys_rows, jnp.int32)
    out = {}
    for key, leaf in cache.items():
        if key == "block_tables" or key.endswith("_scale"):
            out.setdefault(key, leaf)       # scales overwritten with k/v
            continue
        req = request_cache[key]
        if key in ("k", "v"):
            Lr, P, ps = leaf.shape[:3]
            C = phys_rows.shape[1]
            q, scale = _quantize_pool_rows(req, C, ps,
                                           cache[key + "_scale"].shape[-1])
            flat = leaf.reshape((Lr, P * ps) + leaf.shape[3:])
            flat = flat.at[:, phys_rows].set(q, mode="drop")
            out[key] = flat.reshape(leaf.shape)
            # every logical page's rows are pool-contiguous, so the page id
            # is the first covered row's phys // ps (oob rows land on page
            # P and drop, exactly like their payload)
            page_idx = phys_rows[:, ::ps] // ps              # (K, C // ps)
            # scale (L, K, C//ps, T) scatters onto the (L, P, T) table
            out[key + "_scale"] = cache[key + "_scale"].at[:, page_idx].set(
                scale, mode="drop")
        elif key == "pos":
            out[key] = leaf.at[slots].set(jnp.asarray(req, leaf.dtype))
        else:
            out[key] = leaf.at[:, slots].set(req.astype(leaf.dtype))
    return out


def copy_pool_rows_q8(cache, src_rows, dst_rows):
    """Int8 COW materialisation: the int8 rows copy verbatim (the gather/
    scatter in ``registry.copy_pool_rows`` is dtype-agnostic), and the
    DESTINATION page inherits the SOURCE page's scale — the copied payload
    only decodes correctly under it. The tail chunk's splice then
    re-quantizes the fresh page (payload + scale together), so divergence
    re-quantizes exactly once."""
    src_rows = jnp.asarray(src_rows, jnp.int32)
    dst_rows = jnp.asarray(dst_rows, jnp.int32)
    out = dict(copy_pool_rows(cache, src_rows, dst_rows))
    for key in ("k", "v"):
        P, ps = cache[key].shape[1:3]
        src_pg = jnp.clip(src_rows[:, 0] // ps, 0, P - 1)
        dst_pg = jnp.where(dst_rows[:, 0] < P * ps, dst_rows[:, 0] // ps, P)
        sc = out[key + "_scale"]
        out[key + "_scale"] = sc.at[:, dst_pg].set(sc[:, src_pg], mode="drop")
    return out


def seed_prefix_cache_q8(model: Model, cache, phys_rows, row_ok, pos,
                         s_max: int, dtype=jnp.float32):
    """Int8 prefix seed: gather the shared prefix rows like
    ``registry.seed_prefix_cache`` and DEQUANTIZE them with each row's
    per-group page scales, so the transient tail-prefill cache is a
    faithful f32 view of the aliased int8 pages."""
    K = phys_rows.shape[0]
    out = model.init_cache(K, s_max, dtype)
    idx = jnp.where(row_ok, phys_rows, 0)
    for key in ("k", "v"):
        pool = cache[key]                   # (L, P, ps, KV, hd) int8
        Lr, P, ps = pool.shape[:3]
        T = cache[key + "_scale"].shape[-1]
        flat = pool.reshape((Lr, P * ps) + pool.shape[3:])
        pg = jnp.clip(idx // ps, 0, P - 1)
        raw = flat[:, idx].astype(jnp.float32)       # (L, Kr, KV, hd)
        KV, hd = raw.shape[2], raw.shape[3]
        grouped = raw.reshape(Lr, raw.shape[1], T, KV // T, hd)
        sc = cache[key + "_scale"][:, pg]            # (L, Kr, T)
        rows = (grouped * sc[..., None, None]).reshape(raw.shape)
        mask = row_ok.reshape((1,) + row_ok.shape + (1,) * (rows.ndim - 3))
        out[key] = jnp.where(mask, rows, 0).astype(out[key].dtype)
    out["pos"] = jnp.asarray(pos, jnp.int32)
    return out


@functools.lru_cache(maxsize=1)
def _jitted_insert_rows_q8():
    return jax.jit(insert_cache_rows_paged_q8, donate_argnums=(0,))


@functools.lru_cache(maxsize=1)
def _jitted_copy_rows_q8():
    return jax.jit(copy_pool_rows_q8, donate_argnums=(0,))


@functools.lru_cache(maxsize=64)
def _jitted_prefix_seed_q8(model: Model, s_max: int, dtype):
    def seed(cache, phys_rows, row_ok, pos):
        return seed_prefix_cache_q8(model, cache, phys_rows, row_ok, pos,
                                    s_max, dtype)
    return jax.jit(seed)


# -------------------------------------------------------------- the seam
# string-keyed backend registry: name -> KVBackend subclass. Populated by
# the @register_backend decorations below; external representations can
# register their own class under a fresh key and every engine entry point
# (ServeConfig.kv_backend, make_backend) resolves it by name.
BACKENDS: dict = {}


def register_backend(cls=None, *, aliases=()):
    """Class decorator registering a :class:`KVBackend` subclass in
    :data:`BACKENDS` under its ``name`` attribute (plus any ``aliases``).
    Re-registering an existing key raises — a silent overwrite would let a
    typo'd plugin shadow a built-in representation."""
    def _register(cls):
        for key in (cls.name, *aliases):
            if key in BACKENDS:
                raise ValueError(
                    f"KV backend name {key!r} already registered "
                    f"(by {BACKENDS[key].__name__}); pick a fresh key")
            BACKENDS[key] = cls
        return cls
    return _register(cls) if cls is not None else _register


class KVBackend:
    """Protocol every cache representation implements. Attributes:
    ``name`` (registry key), ``paged`` (pool + block tables vs per-slot
    rows), ``quantized`` (carries per-page scale metadata)."""

    name = "abstract"
    paged = False
    quantized = False

    @staticmethod
    def capacity(cfg: ArchConfig, s_max: int) -> int:
        """Per-slot row capacity the page allocator must cover."""
        return cache_capacity(cfg, s_max)

    def init_cache(self, model: Model, batch_slots: int, s_max: int, dtype):
        raise NotImplementedError

    def insert_rows(self, cache, request_cache, slots, phys_rows=None):
        """Completion splice of a transient batch-K prefill cache into the
        resident cache (phys_rows: the paged row map, None for dense)."""
        raise NotImplementedError

    def copy_rows(self, cache, src_rows, dst_rows):
        """COW re-materialisation (paged only)."""
        raise NotImplementedError(f"{self.name} backend has no pages")

    def seed_prefix(self, model: Model, s_max: int, dtype):
        """-> jitted fn(cache, phys_rows, row_ok, pos) building the dense
        transient cache for a prefix-hit tail prefill (paged only)."""
        raise NotImplementedError(f"{self.name} backend has no pages")

    def resolve_attn_impl(self, family: Family, multi_page: bool) -> str:
        """'auto' policy: which paged read path serves this config."""
        return "einsum"

    def page_meta(self, cache) -> dict:
        """Per-page metadata leaves this representation adds (name ->
        (L, P, ...) array); empty for unquantized backends."""
        return {}

    def check_page_meta(self, cache, num_pages: int) -> None:
        """Invariant hook for per-page metadata (assert_page_invariants)."""

    # ------------------------------------------------------ sharding hooks
    @classmethod
    def pool_axes(cls) -> dict:
        """Logical sharding axes per cache leaf (leaf name -> logical-axis
        tuple, resolved under ``specs.TP_POOL_RULES``), SCALE leaves
        included. The base declares nothing — every leaf replicates — so a
        backend without mesh knowledge still places correctly; see
        :meth:`place`."""
        return {}

    @classmethod
    def tp_compatible(cls, mesh) -> bool:
        """Capability query: can this representation serve under the given
        tensor parallelism? ``mesh`` may be a Mesh, None, or a plain int tp
        degree (``ServeConfig.validate`` runs before any mesh exists). The
        base says yes — :meth:`place` has a safe replicated fallback and
        every built-in paged representation composes with tp."""
        return True

    def place(self, cache, mesh):
        """Commit a freshly built cache pytree onto ``mesh``: each leaf
        named in :meth:`pool_axes` gets its declared logical axes (resolved
        under ``specs.TP_POOL_RULES``; non-divisible dims drop to
        replicated), every other leaf replicates. No-op without a mesh.
        A backend that never overrode :meth:`pool_axes` gets a fully
        replicated cache under tp>1 plus a warning — correct, just not
        memory-scaled per shard."""
        if mesh is None:
            return cache
        from repro.sharding import specs as _sp
        axes_map = self.pool_axes()
        if (type(self).pool_axes.__func__ is KVBackend.pool_axes.__func__
                and _tp_degree(mesh) > 1):
            log.warning(
                "KV backend %r declares no pool_axes(); placing its cache "
                "fully replicated on the tp=%d mesh (correct, but the pool "
                "does not shrink per shard)", self.name, _tp_degree(mesh))
        shardings = {}
        with _sp.use_mesh(mesh, _sp.TP_POOL_RULES):
            for key, leaf in cache.items():
                axes = axes_map.get(key)
                if axes is None or len(axes) != leaf.ndim:
                    axes = (None,) * leaf.ndim
                shardings[key] = _sp.sharding_for(leaf.shape, axes)
        return jax.device_put(cache, shardings)


@register_backend
class DenseBackend(KVBackend):
    """The page_size == None degenerate: per-slot (B, s_max) rows, batch-axis
    completion splice, no pages/COW/prefix sharing."""

    name = "dense"

    def init_cache(self, model: Model, batch_slots: int, s_max: int, dtype):
        return vectorize_cache_pos(model.init_cache(batch_slots, s_max, dtype),
                                   batch_slots, inactive=True)

    def insert_rows(self, cache, request_cache, slots, phys_rows=None):
        return _jitted_insert_rows()(cache, request_cache, slots)

    @classmethod
    def tp_compatible(cls, mesh) -> bool:
        # tensor-parallel serving shards the PAGED pool (page indices are
        # shard-invariant); the per-slot dense cache has no mesh layout
        return _tp_degree(mesh) <= 1


def _tp_degree(mesh) -> int:
    """Size of the serving mesh's tensor-parallel axis (1 if no mesh).
    Also accepts a plain int tp degree — ``ServeConfig.validate`` consults
    the capability query before any mesh exists."""
    if mesh is None:
        return 1
    if isinstance(mesh, int):
        return mesh
    from repro.sharding import specs as _sp
    if _sp.TP_AXIS not in mesh.axis_names:
        return 1
    return mesh.shape[_sp.TP_AXIS]


def _shards_kv_heads(cls) -> bool:
    """Does this backend's declared pool layout shard the kv-head axis?
    (Gates the num_kv_heads % tp divisibility requirement — a backend with
    a replicated or head-free pool, e.g. paged_latent, has no such
    constraint.)"""
    return any("kv_heads" in axes for axes in cls.pool_axes().values())


def check_tp_support(spec, tp: int) -> None:
    """Raise the pinned tp-incompatibility error when ``spec``'s (a registry
    name or KVBackend class) capability query refuses the given tp degree.
    Shared by ``ServeConfig.validate`` (preflight) and :func:`make_backend`
    (direct-construction defense)."""
    cls = BACKENDS[spec] if isinstance(spec, str) else spec
    if tp > 1 and not cls.tp_compatible(tp):
        raise ValueError(
            f"kv_backend={cls.name!r} reports tp_compatible=False for "
            f"tp={tp}: this cache representation does not compose with "
            f"tensor-parallel serving; use kv_backend='paged' with tp>1 "
            f"or drop tp")


@register_backend(aliases=("paged_fp32",))
class PagedFP32Backend(KVBackend):
    """The vLLM-style shared fp32/bf16 page pool (the pre-backend layout,
    bit-for-bit).

    ``mesh``: optional serving mesh. When set, ``init_cache`` COMMITS the
    K/V pool leaves sharded on their kv-head axis over the mesh's tp axis
    (each device then holds a ``(L, P, ps, KV/tp, hd)`` resident slice) and
    every other leaf replicated — page ids are shard-invariant, so block
    tables, positions, and the host-side allocator/prefix index never learn
    the mesh exists. The splice/COW/seed jits below need no shard_map: they
    are elementwise scatters/gathers over replicated row indices, which
    GSPMD partitions along the already-sharded kv-head axis without
    introducing any cross-shard reduction (bitwise-safe)."""

    name = "paged"
    paged = True

    def __init__(self, page_size: int, num_pages: int, mesh=None):
        self.page_size = page_size
        self.num_pages = num_pages
        self.mesh = mesh

    @classmethod
    def pool_axes(cls) -> dict:
        from repro.sharding import specs as _sp
        return {"k": _sp.KV_POOL_AXES, "v": _sp.KV_POOL_AXES}

    def init_cache(self, model: Model, batch_slots: int, s_max: int, dtype):
        cache = init_paged_cache(model, batch_slots, s_max,
                                 page_size=self.page_size,
                                 num_pages=self.num_pages, dtype=dtype)
        return self.place(cache, self.mesh)

    def insert_rows(self, cache, request_cache, slots, phys_rows=None):
        return _jitted_insert_rows_paged()(cache, request_cache, slots,
                                           phys_rows)

    def copy_rows(self, cache, src_rows, dst_rows):
        return _jitted_copy_rows()(cache, src_rows, dst_rows)

    def seed_prefix(self, model: Model, s_max: int, dtype):
        return _jitted_prefix_seed(model, s_max, dtype)

    def resolve_attn_impl(self, family: Family, multi_page: bool) -> str:
        # the degenerate one-page-per-slot config stays on the einsum path:
        # it IS the dense bit-exactness anchor
        if family in PAGED_KERNEL_FAMILIES and multi_page:
            return "kernel"
        return "einsum"


@register_backend
class PagedInt8Backend(PagedFP32Backend):
    """Int8 page pools + per-page symmetric scales. Same block tables,
    allocator contract, and attention dispatch as the fp32 pool — only the
    representation ops differ (quantizing splice, scale-carrying COW,
    dequantizing seed/read)."""

    name = "paged_int8"
    quantized = True

    @classmethod
    def pool_axes(cls) -> dict:
        axes = dict(super().pool_axes())
        # scale leaves (L, P, tp): one scale per page per kv-head GROUP,
        # group t covering the contiguous KV/tp heads shard t owns — the
        # trailing group column shards WITH its kv heads, so each shard
        # computes its scales from purely local pool values
        axes["k_scale"] = (None, None, "kv_heads")
        axes["v_scale"] = (None, None, "kv_heads")
        return axes

    def init_cache(self, model: Model, batch_slots: int, s_max: int, dtype):
        base = super().init_cache(model, batch_slots, s_max, dtype)
        out = dict(base)
        tp = _tp_degree(self.mesh)
        for key in ("k", "v"):
            out[key] = jnp.zeros(base[key].shape, jnp.int8)
            # scale 1.0 everywhere: a never-written page dequants to exact
            # zeros, same as the fp32 pool's zero init
            out[key + "_scale"] = jnp.ones(base[key].shape[:2] + (tp,),
                                           jnp.float32)
        return self.place(out, self.mesh)

    def insert_rows(self, cache, request_cache, slots, phys_rows=None):
        return _jitted_insert_rows_q8()(cache, request_cache, slots,
                                        phys_rows)

    def copy_rows(self, cache, src_rows, dst_rows):
        return _jitted_copy_rows_q8()(cache, src_rows, dst_rows)

    def seed_prefix(self, model: Model, s_max: int, dtype):
        return _jitted_prefix_seed_q8(model, s_max, dtype)

    def page_meta(self, cache) -> dict:
        return {"k_scale": cache["k_scale"], "v_scale": cache["v_scale"]}

    def check_page_meta(self, cache, num_pages: int) -> None:
        import numpy as np
        tp = _tp_degree(self.mesh)
        for key in ("k_scale", "v_scale"):
            sc = np.asarray(cache[key])
            L = cache[key[0]].shape[0]
            assert sc.shape == (L, num_pages, tp), \
                f"{key} shape {sc.shape} != {(L, num_pages, tp)}"
            assert np.isfinite(sc).all() and (sc > 0).all(), \
                f"{key} has non-finite or non-positive entries"


@register_backend
class PagedLatentBackend(PagedFP32Backend):
    """MLA latent pages: each pool row is one per-token ``(kv_lora_rank +
    qk_rope_head_dim)``-dim compressed latent shared by EVERY query head
    (the absorb path folds ``wkv_b`` into the query/output einsums, so
    attention reads the latent directly — values are the leading
    ``kv_lora_rank`` columns of the same rows). The cache therefore has a
    single ``k`` pool of shape (L, P, page_size, 1, c + r) and NO ``v``
    leaf; the generic splice/COW/seed machinery is key-generic, so this
    backend inherits every representation op from the fp32 pool — COW
    copies a latent row, never per-head K/V. Block tables, the allocator,
    and the prefix index are untouched: a page is a page.

    Under tensor parallelism the latent pool REPLICATES (see
    :meth:`pool_axes`) and tp instead shards the ABSORBED queries/outputs
    on their head axis (models/layers.py mla paths): per-head attention
    over the shared latent is head-independent, and the all-gather before
    ``wo`` keeps tp>1 greedy streams bitwise equal to tp=1."""

    name = "paged_latent"

    @classmethod
    def pool_axes(cls) -> dict:
        # a latent row has no kv-head axis (KV == 1; every query head reads
        # the same compressed row), and at (c + r) floats per token the
        # pool is small enough to hold per shard — so it replicates, and
        # the head axis of the absorbed queries carries the tp split
        return {}

    def init_cache(self, model: Model, batch_slots: int, s_max: int, dtype):
        if getattr(model.cfg, "kv_lora_rank", 0) <= 0:
            raise ValueError(
                f"kv_backend='paged_latent' needs an MLA arch "
                f"(kv_lora_rank > 0); {model.cfg.name!r} caches per-head "
                f"K/V — use kv_backend='paged' (its pages would hold the "
                f"same rows anyway)")
        return super().init_cache(model, batch_slots, s_max, dtype)


def make_backend(spec, *, family: Family, page_size=None, num_pages=None,
                 mesh=None, num_kv_heads=None):
    """Resolve an engine ``kv_backend`` spec: None (layout follows
    page_size), a name registered in :data:`BACKENDS` ('dense' | 'paged' |
    'paged_fp32' | 'paged_int8' | 'paged_latent'), or a ready KVBackend
    instance. Int8 on an unsupported family degrades to fp32 pages with a
    warning rather than failing — the caller keeps a correct serving path.
    ``mesh``: optional serving mesh the backend's :meth:`KVBackend.place`
    commits its pool onto. ``num_kv_heads``: when given with a tp>1 mesh,
    checked against the backend's declared layout (a kv-head-sharded pool
    needs tp to divide the kv-head count; a replicated/head-free pool does
    not) — the engine passes it so direct ``ServeEngine(...)`` construction
    hits the same preflight as ``ServeConfig.validate``."""
    if isinstance(spec, KVBackend):
        if mesh is not None and getattr(spec, "mesh", None) is not mesh:
            raise ValueError("a ready KVBackend instance must be built with "
                             "the engine's mesh (pass mesh= to its ctor)")
        return spec
    if spec is None:
        spec = "paged" if page_size is not None else "dense"
    cls = BACKENDS.get(spec)
    if cls is None:
        raise ValueError(f"unknown kv_backend {spec!r}; available: "
                         f"{sorted(BACKENDS)}")
    tp = _tp_degree(mesh)
    if not cls.paged:
        if page_size is not None:
            raise ValueError(f"kv_backend={spec!r} conflicts with page_size="
                             f"{page_size}; drop one of them")
        if tp > 1:
            raise ValueError("tensor-parallel serving shards the PAGED pool "
                             "(page indices are shard-invariant); the dense "
                             "backend has no mesh layout — pass page_size=")
        return cls()
    if page_size is None:
        raise ValueError(f"kv_backend={spec!r} needs page_size")
    if cls is PagedInt8Backend and family not in INT8_KV_FAMILIES:
        log.warning("paged_int8 KV backend supports %s (got %s); "
                    "falling back to fp32 pages",
                    [f.name for f in INT8_KV_FAMILIES], family)
        cls = PagedFP32Backend
    check_tp_support(cls, tp)
    if (tp > 1 and num_kv_heads is not None and _shards_kv_heads(cls)
            and num_kv_heads % tp):
        raise ValueError(
            f"num_kv_heads={num_kv_heads} is not divisible by tp={tp}; "
            f"pick a tp dividing the kv-head count (whole GQA groups must "
            f"stay shard-local)")
    return cls(page_size, num_pages, mesh=mesh)
