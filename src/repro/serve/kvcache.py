"""Pluggable KV-cache backends: the single seam between the serving engine's
ORCHESTRATION (scheduling, admission, page accounting) and the cache's
REPRESENTATION (pool dtype/shape, splice math, scale metadata).

The engine never touches page-layout internals directly — it holds a
:class:`KVBackend` and calls five representation operations:

    capacity(cfg, s_max)           per-slot row capacity the allocator covers
    init_cache(model, B, s_max)    build the resident cache pytree
    insert_rows(cache, rcache,     completion splice of a transient prefill
                slots, phys_rows)  cache (dense batch scatter / paged pool
                                   scatter, quantizing on the way in for q8)
    copy_rows(cache, src, dst)     COW re-materialisation of a partial
                                   prefix page (q8: the scale rides along)
    seed_prefix(model, s_max, dt)  gather shared prefix rows into a dense
                                   transient cache (q8: dequantized)

plus `resolve_attn_impl` (kernel vs einsum dispatch policy) and the
`page_meta`/`check_page_meta` hooks for per-page metadata invariants.
Everything a representation owns lives here or below (models/layers.py
write/read paths, kernels/paged_attention.py); everything the engine owns
(allocator, block tables, prefix index, job lifecycle) stays in engine.py.

Backends:

* :class:`DenseBackend` — the non-paged (B, s_max) per-slot cache.
* :class:`PagedFP32Backend` — the vLLM-style shared page pool, extracted
  behaviour-preservingly from the pre-backend engine (all bit-exact anchors
  — degenerate page == dense, prefix on == off — hold through this class).
* :class:`PagedInt8Backend` — pages stored int8 with ONE symmetric f32
  scale per page (the page is the quantization block, DeepSeek-V3
  ``act_quant`` style): `k`/`v` pools are int8 and `(L, P)` `k_scale`/
  `v_scale` leaves ride the cache pytree. Dequant happens inside the paged
  Pallas kernel's gather (scales are scalar-prefetch operands), so decode's
  HBM KV traffic is ~4x smaller where it is bandwidth-bound. Prefix
  aliasing shares a page's scale with its payload; COW re-quantizes the
  fresh page exactly once (the chunk splice that follows the row copy).

* :class:`PagedLatentBackend` — MLA latent pages: each pool row is ONE
  per-token ``(kv_lora_rank + qk_rope_head_dim)``-dim compressed latent
  (shared by every query head via the absorb path) instead of per-head
  K/V. Same allocator/block-table/COW contract as the fp32 pool — COW
  copies a latent row, never per-head K/V — with resident KV per token
  shrunk from ``2 * KV * hd`` to ``c + r`` floats.

Adding a backend = subclass KVBackend, implement the five operations (and
the layers-level write/read path if the representation changes attention's
view), and register it under a string key with :func:`register_backend`;
:func:`make_backend` resolves names through that :data:`BACKENDS` registry.
"""
from __future__ import annotations

import functools
import logging

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, Family
from repro.core.quantize import page_scale
from repro.models.registry import (Model, cache_capacity, copy_pool_rows,
                                   init_paged_cache, insert_cache_rows,
                                   insert_cache_rows_paged, seed_prefix_cache,
                                   vectorize_cache_pos)

log = logging.getLogger("repro.serve")

# families whose transient prefill state is exactly (k, v, pos) — the ones
# page-level prefix caching (and the int8 backend's dequantizing prefix
# seed) can serve. Hybrid's ring carry and encdec's cross-K/V are not
# reconstructible from pages.
PREFIX_CACHE_FAMILIES = (Family.DENSE, Family.MOE, Family.VLM)

# families whose paged decode/prefill can route through the Pallas
# block-gather kernel (plain causal/windowed attention over the pool; the
# hybrid ring's modular positions need the einsum path)
PAGED_KERNEL_FAMILIES = (Family.DENSE, Family.MOE, Family.VLM, Family.ENCDEC)

# families the int8 backend supports: the quantized write paths live in the
# transformer chunk/decode attention (layers.py); the hybrid ring and
# encdec/ssm extra state keep fp32 representations
INT8_KV_FAMILIES = PREFIX_CACHE_FAMILIES


# ---------------------------------------------------------- jitted helpers
# module-level lru_cache'd jit factories (moved from engine.py): one
# compilation per distinct signature, shared by every engine instance
@functools.lru_cache(maxsize=1)
def _jitted_insert_rows():
    return jax.jit(insert_cache_rows, donate_argnums=(0,))


@functools.lru_cache(maxsize=1)
def _jitted_insert_rows_paged():
    return jax.jit(insert_cache_rows_paged, donate_argnums=(0,))


@functools.lru_cache(maxsize=1)
def _jitted_copy_rows():
    return jax.jit(copy_pool_rows, donate_argnums=(0,))


@functools.lru_cache(maxsize=64)
def _jitted_prefix_seed(model: Model, s_max: int, dtype):
    def seed(cache, phys_rows, row_ok, pos):
        return seed_prefix_cache(model, cache, phys_rows, row_ok, pos,
                                 s_max, dtype)
    return jax.jit(seed)


# ------------------------------------------------------------ int8 splices
def _quantize_pool_rows(req, C: int, ps: int):
    """Quantize a transient-cache leaf (L, K, >=C, KV, hd) page-block-wise.
    Returns (q (L,K,C,KV,hd) int8, scale (L,K,C//ps) f32) — one symmetric
    scale per logical page. The engine's write floor is page-aligned, so a
    splice drops whole pages at a time and payload/scale stay consistent."""
    rows = req[:, :, :C].astype(jnp.float32)
    Lr, K = rows.shape[:2]
    blocks = rows.reshape(Lr, K, C // ps, ps, *rows.shape[3:])
    scale = page_scale(jnp.max(jnp.abs(blocks), axis=(3, 4, 5)))
    q = jnp.clip(jnp.round(blocks / scale[..., None, None, None]),
                 -127, 127).astype(jnp.int8)
    return q.reshape(Lr, K, C, *rows.shape[3:]), scale


def insert_cache_rows_paged_q8(cache, request_cache, slots, phys_rows):
    """Int8 completion splice: like ``registry.insert_cache_rows_paged`` but
    the fp32 transient K/V rows are QUANTIZED page-by-page on the way into
    the int8 pools, and each written page's scale lands in the (L, P)
    scale tables. Rows/pages outside the request's reservation (phys >=
    P * ps — including everything below a page-aligned write floor) are
    dropped from payload AND scale alike."""
    slots = jnp.asarray(slots, jnp.int32)
    phys_rows = jnp.asarray(phys_rows, jnp.int32)
    out = {}
    for key, leaf in cache.items():
        if key == "block_tables" or key.endswith("_scale"):
            out.setdefault(key, leaf)       # scales overwritten with k/v
            continue
        req = request_cache[key]
        if key in ("k", "v"):
            Lr, P, ps = leaf.shape[:3]
            C = phys_rows.shape[1]
            q, scale = _quantize_pool_rows(req, C, ps)
            flat = leaf.reshape((Lr, P * ps) + leaf.shape[3:])
            flat = flat.at[:, phys_rows].set(q, mode="drop")
            out[key] = flat.reshape(leaf.shape)
            # every logical page's rows are pool-contiguous, so the page id
            # is the first covered row's phys // ps (oob rows land on page
            # P and drop, exactly like their payload)
            page_idx = phys_rows[:, ::ps] // ps              # (K, C // ps)
            out[key + "_scale"] = cache[key + "_scale"].at[:, page_idx].set(
                scale, mode="drop")
        elif key == "pos":
            out[key] = leaf.at[slots].set(jnp.asarray(req, leaf.dtype))
        else:
            out[key] = leaf.at[:, slots].set(req.astype(leaf.dtype))
    return out


def copy_pool_rows_q8(cache, src_rows, dst_rows):
    """Int8 COW materialisation: the int8 rows copy verbatim (the gather/
    scatter in ``registry.copy_pool_rows`` is dtype-agnostic), and the
    DESTINATION page inherits the SOURCE page's scale — the copied payload
    only decodes correctly under it. The tail chunk's splice then
    re-quantizes the fresh page (payload + scale together), so divergence
    re-quantizes exactly once."""
    src_rows = jnp.asarray(src_rows, jnp.int32)
    dst_rows = jnp.asarray(dst_rows, jnp.int32)
    out = dict(copy_pool_rows(cache, src_rows, dst_rows))
    for key in ("k", "v"):
        P, ps = cache[key].shape[1:3]
        src_pg = jnp.clip(src_rows[:, 0] // ps, 0, P - 1)
        dst_pg = jnp.where(dst_rows[:, 0] < P * ps, dst_rows[:, 0] // ps, P)
        sc = out[key + "_scale"]
        out[key + "_scale"] = sc.at[:, dst_pg].set(sc[:, src_pg], mode="drop")
    return out


def seed_prefix_cache_q8(model: Model, cache, phys_rows, row_ok, pos,
                         s_max: int, dtype=jnp.float32):
    """Int8 prefix seed: gather the shared prefix rows like
    ``registry.seed_prefix_cache`` and DEQUANTIZE them with each row's page
    scale, so the transient tail-prefill cache is a faithful f32 view of
    the aliased int8 pages."""
    K = phys_rows.shape[0]
    out = model.init_cache(K, s_max, dtype)
    idx = jnp.where(row_ok, phys_rows, 0)
    for key in ("k", "v"):
        pool = cache[key]                   # (L, P, ps, KV, hd) int8
        Lr, P, ps = pool.shape[:3]
        flat = pool.reshape((Lr, P * ps) + pool.shape[3:])
        pg = jnp.clip(idx // ps, 0, P - 1)
        rows = (flat[:, idx].astype(jnp.float32)
                * cache[key + "_scale"][:, pg][..., None, None])
        mask = row_ok.reshape((1,) + row_ok.shape + (1,) * (rows.ndim - 3))
        out[key] = jnp.where(mask, rows, 0).astype(out[key].dtype)
    out["pos"] = jnp.asarray(pos, jnp.int32)
    return out


@functools.lru_cache(maxsize=1)
def _jitted_insert_rows_q8():
    return jax.jit(insert_cache_rows_paged_q8, donate_argnums=(0,))


@functools.lru_cache(maxsize=1)
def _jitted_copy_rows_q8():
    return jax.jit(copy_pool_rows_q8, donate_argnums=(0,))


@functools.lru_cache(maxsize=64)
def _jitted_prefix_seed_q8(model: Model, s_max: int, dtype):
    def seed(cache, phys_rows, row_ok, pos):
        return seed_prefix_cache_q8(model, cache, phys_rows, row_ok, pos,
                                    s_max, dtype)
    return jax.jit(seed)


# -------------------------------------------------------------- the seam
# string-keyed backend registry: name -> KVBackend subclass. Populated by
# the @register_backend decorations below; external representations can
# register their own class under a fresh key and every engine entry point
# (ServeConfig.kv_backend, make_backend) resolves it by name.
BACKENDS: dict = {}


def register_backend(cls=None, *, aliases=()):
    """Class decorator registering a :class:`KVBackend` subclass in
    :data:`BACKENDS` under its ``name`` attribute (plus any ``aliases``).
    Re-registering an existing key raises — a silent overwrite would let a
    typo'd plugin shadow a built-in representation."""
    def _register(cls):
        for key in (cls.name, *aliases):
            if key in BACKENDS:
                raise ValueError(
                    f"KV backend name {key!r} already registered "
                    f"(by {BACKENDS[key].__name__}); pick a fresh key")
            BACKENDS[key] = cls
        return cls
    return _register(cls) if cls is not None else _register


class KVBackend:
    """Protocol every cache representation implements. Attributes:
    ``name`` (registry key), ``paged`` (pool + block tables vs per-slot
    rows), ``quantized`` (carries per-page scale metadata)."""

    name = "abstract"
    paged = False
    quantized = False

    @staticmethod
    def capacity(cfg: ArchConfig, s_max: int) -> int:
        """Per-slot row capacity the page allocator must cover."""
        return cache_capacity(cfg, s_max)

    def init_cache(self, model: Model, batch_slots: int, s_max: int, dtype):
        raise NotImplementedError

    def insert_rows(self, cache, request_cache, slots, phys_rows=None):
        """Completion splice of a transient batch-K prefill cache into the
        resident cache (phys_rows: the paged row map, None for dense)."""
        raise NotImplementedError

    def copy_rows(self, cache, src_rows, dst_rows):
        """COW re-materialisation (paged only)."""
        raise NotImplementedError(f"{self.name} backend has no pages")

    def seed_prefix(self, model: Model, s_max: int, dtype):
        """-> jitted fn(cache, phys_rows, row_ok, pos) building the dense
        transient cache for a prefix-hit tail prefill (paged only)."""
        raise NotImplementedError(f"{self.name} backend has no pages")

    def resolve_attn_impl(self, family: Family, multi_page: bool) -> str:
        """'auto' policy: which paged read path serves this config."""
        return "einsum"

    def page_meta(self, cache) -> dict:
        """Per-page metadata leaves this representation adds (name -> (L, P)
        array); empty for unquantized backends."""
        return {}

    def check_page_meta(self, cache, num_pages: int) -> None:
        """Invariant hook for per-page metadata (assert_page_invariants)."""


@register_backend
class DenseBackend(KVBackend):
    """The page_size == None degenerate: per-slot (B, s_max) rows, batch-axis
    completion splice, no pages/COW/prefix sharing."""

    name = "dense"

    def init_cache(self, model: Model, batch_slots: int, s_max: int, dtype):
        return vectorize_cache_pos(model.init_cache(batch_slots, s_max, dtype),
                                   batch_slots, inactive=True)

    def insert_rows(self, cache, request_cache, slots, phys_rows=None):
        return _jitted_insert_rows()(cache, request_cache, slots)


def _tp_degree(mesh) -> int:
    """Size of the serving mesh's tensor-parallel axis (1 if no mesh)."""
    if mesh is None:
        return 1
    from repro.sharding import specs as _sp
    if _sp.TP_AXIS not in mesh.axis_names:
        return 1
    return mesh.shape[_sp.TP_AXIS]


@register_backend(aliases=("paged_fp32",))
class PagedFP32Backend(KVBackend):
    """The vLLM-style shared fp32/bf16 page pool (the pre-backend layout,
    bit-for-bit).

    ``mesh``: optional serving mesh. When set, ``init_cache`` COMMITS the
    K/V pool leaves sharded on their kv-head axis over the mesh's tp axis
    (each device then holds a ``(L, P, ps, KV/tp, hd)`` resident slice) and
    every other leaf replicated — page ids are shard-invariant, so block
    tables, positions, and the host-side allocator/prefix index never learn
    the mesh exists. The splice/COW/seed jits below need no shard_map: they
    are elementwise scatters/gathers over replicated row indices, which
    GSPMD partitions along the already-sharded kv-head axis without
    introducing any cross-shard reduction (bitwise-safe)."""

    name = "paged"
    paged = True

    def __init__(self, page_size: int, num_pages: int, mesh=None):
        self.page_size = page_size
        self.num_pages = num_pages
        self.mesh = mesh

    def init_cache(self, model: Model, batch_slots: int, s_max: int, dtype):
        cache = init_paged_cache(model, batch_slots, s_max,
                                 page_size=self.page_size,
                                 num_pages=self.num_pages, dtype=dtype)
        return self._place(cache)

    def _place(self, cache):
        if self.mesh is None:
            return cache
        from repro.sharding import specs as _sp
        shardings = {}
        with _sp.use_mesh(self.mesh, _sp.TP_POOL_RULES):
            for key, leaf in cache.items():
                if key in ("k", "v") and leaf.ndim == len(_sp.KV_POOL_AXES):
                    axes = _sp.KV_POOL_AXES
                else:
                    axes = (None,) * leaf.ndim
                shardings[key] = _sp.sharding_for(leaf.shape, axes)
        return jax.device_put(cache, shardings)

    def insert_rows(self, cache, request_cache, slots, phys_rows=None):
        return _jitted_insert_rows_paged()(cache, request_cache, slots,
                                           phys_rows)

    def copy_rows(self, cache, src_rows, dst_rows):
        return _jitted_copy_rows()(cache, src_rows, dst_rows)

    def seed_prefix(self, model: Model, s_max: int, dtype):
        return _jitted_prefix_seed(model, s_max, dtype)

    def resolve_attn_impl(self, family: Family, multi_page: bool) -> str:
        # the degenerate one-page-per-slot config stays on the einsum path:
        # it IS the dense bit-exactness anchor
        if family in PAGED_KERNEL_FAMILIES and multi_page:
            return "kernel"
        return "einsum"


@register_backend
class PagedInt8Backend(PagedFP32Backend):
    """Int8 page pools + per-page symmetric scales. Same block tables,
    allocator contract, and attention dispatch as the fp32 pool — only the
    representation ops differ (quantizing splice, scale-carrying COW,
    dequantizing seed/read)."""

    name = "paged_int8"
    quantized = True

    def __init__(self, page_size: int, num_pages: int, mesh=None):
        if _tp_degree(mesh) > 1:
            # the write paths recompute each touched page's symmetric scale
            # as an amax over (page_size, KV, hd) — a CROSS-SHARD max once
            # kv heads shard. (The q8 READ path would work as-is: scales
            # are per-page, replicated.) Follow-on: shard-local amax +
            # a tiny all-reduce-max on the touched-page set.
            raise ValueError(
                "paged_int8 KV backend does not support tensor-parallel "
                "serving yet (per-page requant needs a cross-shard amax); "
                "use kv_backend='paged' with tp>1")
        super().__init__(page_size, num_pages, mesh)

    def init_cache(self, model: Model, batch_slots: int, s_max: int, dtype):
        base = super().init_cache(model, batch_slots, s_max, dtype)
        out = dict(base)
        for key in ("k", "v"):
            out[key] = jnp.zeros(base[key].shape, jnp.int8)
            # scale 1.0 everywhere: a never-written page dequants to exact
            # zeros, same as the fp32 pool's zero init
            out[key + "_scale"] = jnp.ones(base[key].shape[:2], jnp.float32)
        return self._place(out)

    def insert_rows(self, cache, request_cache, slots, phys_rows=None):
        return _jitted_insert_rows_q8()(cache, request_cache, slots,
                                        phys_rows)

    def copy_rows(self, cache, src_rows, dst_rows):
        return _jitted_copy_rows_q8()(cache, src_rows, dst_rows)

    def seed_prefix(self, model: Model, s_max: int, dtype):
        return _jitted_prefix_seed_q8(model, s_max, dtype)

    def page_meta(self, cache) -> dict:
        return {"k_scale": cache["k_scale"], "v_scale": cache["v_scale"]}

    def check_page_meta(self, cache, num_pages: int) -> None:
        import numpy as np
        for key in ("k_scale", "v_scale"):
            sc = np.asarray(cache[key])
            L = cache[key[0]].shape[0]
            assert sc.shape == (L, num_pages), \
                f"{key} shape {sc.shape} != {(L, num_pages)}"
            assert np.isfinite(sc).all() and (sc > 0).all(), \
                f"{key} has non-finite or non-positive entries"


@register_backend
class PagedLatentBackend(PagedFP32Backend):
    """MLA latent pages: each pool row is one per-token ``(kv_lora_rank +
    qk_rope_head_dim)``-dim compressed latent shared by EVERY query head
    (the absorb path folds ``wkv_b`` into the query/output einsums, so
    attention reads the latent directly — values are the leading
    ``kv_lora_rank`` columns of the same rows). The cache therefore has a
    single ``k`` pool of shape (L, P, page_size, 1, c + r) and NO ``v``
    leaf; the generic splice/COW/seed machinery is key-generic, so this
    backend inherits every representation op from the fp32 pool — COW
    copies a latent row, never per-head K/V. Block tables, the allocator,
    and the prefix index are untouched: a page is a page."""

    name = "paged_latent"

    def __init__(self, page_size: int, num_pages: int, mesh=None):
        if _tp_degree(mesh) > 1:
            # a latent row has no kv-head axis to shard (KV == 1 and every
            # query head reads the same row); head-sharding the absorbed
            # queries while replicating the pool is a follow-on
            raise ValueError(
                "paged_latent KV backend does not support tensor-parallel "
                "serving (latent rows have no kv-head axis to shard); "
                "use kv_backend='paged' with tp>1")
        super().__init__(page_size, num_pages, mesh)

    def init_cache(self, model: Model, batch_slots: int, s_max: int, dtype):
        if getattr(model.cfg, "kv_lora_rank", 0) <= 0:
            raise ValueError(
                f"kv_backend='paged_latent' needs an MLA arch "
                f"(kv_lora_rank > 0); {model.cfg.name!r} caches per-head "
                f"K/V — use kv_backend='paged' (its pages would hold the "
                f"same rows anyway)")
        return super().init_cache(model, batch_slots, s_max, dtype)


def make_backend(spec, *, family: Family, page_size=None, num_pages=None,
                 mesh=None):
    """Resolve an engine ``kv_backend`` spec: None (layout follows
    page_size), a name registered in :data:`BACKENDS` ('dense' | 'paged' |
    'paged_fp32' | 'paged_int8' | 'paged_latent'), or a ready KVBackend
    instance. Int8 on an unsupported family degrades to fp32 pages with a
    warning rather than failing — the caller keeps a correct serving path.
    ``mesh``: optional serving mesh the paged backends commit their pool
    onto (kv-head-sharded; see PagedFP32Backend)."""
    if isinstance(spec, KVBackend):
        if mesh is not None and getattr(spec, "mesh", None) is not mesh:
            raise ValueError("a ready KVBackend instance must be built with "
                             "the engine's mesh (pass mesh= to its ctor)")
        return spec
    if spec is None:
        spec = "paged" if page_size is not None else "dense"
    cls = BACKENDS.get(spec)
    if cls is None:
        raise ValueError(f"unknown kv_backend {spec!r}; available: "
                         f"{sorted(BACKENDS)}")
    if not cls.paged:
        if page_size is not None:
            raise ValueError(f"kv_backend={spec!r} conflicts with page_size="
                             f"{page_size}; drop one of them")
        if _tp_degree(mesh) > 1:
            raise ValueError("tensor-parallel serving shards the PAGED pool "
                             "(page indices are shard-invariant); the dense "
                             "backend has no mesh layout — pass page_size=")
        return cls()
    if page_size is None:
        raise ValueError(f"kv_backend={spec!r} needs page_size")
    if cls is PagedInt8Backend and family not in INT8_KV_FAMILIES:
        log.warning("paged_int8 KV backend supports %s (got %s); "
                    "falling back to fp32 pages",
                    [f.name for f in INT8_KV_FAMILIES], family)
        cls = PagedFP32Backend
    return cls(page_size, num_pages, mesh=mesh)
