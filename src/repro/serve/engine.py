"""Batched-prefill continuous-batching serve engine.

Core invariants (see the package docstring for the request lifecycle):

* **One dispatch per prefill wave.** New requests are prefilled by a single
  jitted ``make_prefill(return_cache=True)`` call — prompts are
  teacher-forced under one ``lax.scan``, not one device dispatch per token,
  and never at the full batch width (the legacy path's O(prompt_len)
  full-batch stepping). Same-length requests admitted on the same tick are
  prefilled jointly at batch K (the batched-prefill fan-in); a lone request
  runs at batch 1.
* **Slot isolation.** The batch-K prefill cache is spliced into the resident
  batched cache with ``registry.insert_cache_rows`` — a scatter on the batch
  axis covering exactly the admitted slots — so concurrent prefills cannot
  perturb other slots' cache entries or positions.
* **Per-slot positions.** The batched cache's ``pos`` is a (B,) vector, so
  slots at different sequence depths decode together in one tick.
* **Continuous batching.** The scheduler admits waiting requests the moment a
  slot frees, on the same tick.

Prefill compiles once per distinct prompt length (cached); pad or bucket
prompts client-side to bound compilation count. Chunked prefill and paged KV
are ROADMAP follow-ons.
"""
from __future__ import annotations

import functools
import logging
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch import steps as steps_mod
from repro.models.registry import (Model, get_model, insert_cache_rows,
                                   reduced_config, vectorize_cache_pos)
from repro.serve.metrics import MetricsRecorder
from repro.serve.scheduler import Request, RequestState, Scheduler

log = logging.getLogger("repro.serve.engine")


# Jitted step functions are cached at module level keyed on the (frozen,
# hashable) Model so several engine instances over the same architecture —
# e.g. benchmark repetitions — share one compiled executable instead of
# re-tracing per instance (compile time would otherwise dominate short runs).
@functools.lru_cache(maxsize=64)
def _jitted_decode(model: Model, compute_dtype):
    return jax.jit(steps_mod.make_decode_step(model, compute_dtype=compute_dtype),
                   donate_argnums=(1,))


@functools.lru_cache(maxsize=64)
def _jitted_prefill(model: Model, compute_dtype, s_max: int, cache_dtype):
    return jax.jit(steps_mod.make_prefill(
        model, compute_dtype=compute_dtype, return_cache=True, s_max=s_max,
        cache_dtype=cache_dtype))


@functools.lru_cache(maxsize=1)
def _jitted_insert_rows():
    return jax.jit(insert_cache_rows, donate_argnums=(0,))


class ServeEngine:
    """Slot-based continuous-batching engine over a per-slot-position cache.

    sampling: ``temperature == 0`` is greedy argmax; ``temperature > 0``
    samples from softmax(logits / temperature) with a per-event PRNG fold so
    runs are reproducible for a fixed seed.
    """

    def __init__(self, model: Model, params, *, batch_slots: int, s_max: int,
                 compute_dtype=jnp.float32, cache_dtype=None,
                 temperature: float = 0.0, seed: int = 0,
                 scheduler: Optional[Scheduler] = None,
                 metrics: Optional[MetricsRecorder] = None):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.batch_slots = batch_slots
        self.s_max = s_max
        self.compute_dtype = compute_dtype
        self.cache_dtype = cache_dtype or compute_dtype
        self.temperature = float(temperature)
        self.scheduler = scheduler or Scheduler()
        self.metrics = metrics or MetricsRecorder()

        self.cache = vectorize_cache_pos(
            model.init_cache(batch_slots, s_max, self.cache_dtype), batch_slots)
        self._decode = _jitted_decode(model, compute_dtype)
        self._insert_rows = _jitted_insert_rows()

        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.cur_token = np.zeros((batch_slots, 1), np.int32)
        self.requests: Dict[int, Request] = {}
        self._next_rid = 0
        self._key = jax.random.PRNGKey(seed)
        self._events = 0      # PRNG fold counter (one per sampling event)

    # ------------------------------------------------------------ factory
    @classmethod
    def build(cls, arch: str = "hymba-1.5b", *, reduced: bool = True,
              batch_slots: int = 4, s_max: int = 64, seed: int = 0,
              quantize_int8: bool = False, temperature: float = 0.0,
              compute_dtype=jnp.float32) -> "ServeEngine":
        """Construct model + params from an arch id; the int8 PTQ path is the
        same structural quantize->dequant-on-load as the paper's C5 (the
        pallas quant_matmul kernel consumes q directly on TPU)."""
        cfg = configs.get_config(arch)
        if reduced:
            cfg = reduced_config(cfg)
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(seed))
        if quantize_int8:
            from repro.core.quantize import dequantize_params, quantize_params
            params = dequantize_params(quantize_params(params), compute_dtype)
        return cls(model, params, batch_slots=batch_slots, s_max=s_max,
                   compute_dtype=compute_dtype, temperature=temperature,
                   seed=seed)

    # ------------------------------------------------------------ extras
    def _decode_extras(self) -> dict:
        return self._prefill_extras(self.batch_slots)

    def _prefill_extras(self, batch: int) -> dict:
        if self.cfg.cross_attn_every:
            return {"image_embeds": jnp.zeros(
                (batch, self.cfg.num_image_tokens, self.cfg.d_model),
                self.compute_dtype)}
        return {}

    def _prefill_fn(self) -> Callable:
        return _jitted_prefill(self.model, self.compute_dtype, self.s_max,
                               self.cache_dtype)

    # ------------------------------------------------------------ sampling
    def _sample_rows(self, logits) -> np.ndarray:
        """logits: (B, 1, V_padded) -> (B,) sampled token per row."""
        row = logits[:, 0, : self.cfg.vocab_size]
        if self.temperature <= 0.0:
            return np.asarray(jnp.argmax(row, axis=-1), np.int32)
        key = jax.random.fold_in(self._key, self._events)
        self._events += 1
        toks = jax.random.categorical(key, row / self.temperature, axis=-1)
        return np.asarray(toks, np.int32)

    # ------------------------------------------------------------ lifecycle
    def submit(self, prompt, gen_len: int, priority: int = 0) -> Request:
        """Enqueue a request; admission happens on the next step()/run().

        Rejects up front anything that cannot fit the slot cache: prefill
        writes K/V at positions 0 .. prompt_len-1 and the gen_len-1 fed-back
        decode tokens write at prompt_len .. prompt_len+gen_len-2 (the final
        sampled token is never written), so the last write lands at index
        prompt_len+gen_len-2 and must stay < s_max. A write past s_max would
        be silently DROPPED by the scatter (attention then reads
        never-written zero rows — wrong tokens, no error). Validating here
        also keeps admission infallible, so a bad request can never strand
        already-popped good ones."""
        prompt = np.asarray(prompt, np.int32)
        if len(prompt) > self.s_max or \
                len(prompt) + int(gen_len) - 1 > self.s_max:
            raise ValueError(
                f"prompt_len {len(prompt)} + gen_len {gen_len} does not fit "
                f"s_max {self.s_max}; raise s_max or shorten the request")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, prompt=prompt,
                      gen_len=int(gen_len), priority=priority)
        self.requests[rid] = req
        self.metrics.on_submit(rid, len(req.prompt))
        self.scheduler.submit(req)
        return req

    @property
    def free_slots(self) -> List[int]:
        return [s for s, r in enumerate(self.slot_req) if r is None]

    @property
    def active(self) -> int:
        return sum(1 for r in self.slot_req if r is not None)

    def admit(self) -> int:
        """Prefill waiting requests into free slots; returns #admitted.

        Requests admitted on the same tick are grouped by prompt length and
        prefilled JOINTLY — one dispatch fills K slots (the batched-prefill
        part of the engine; mixed lengths fall back to one group each).
        Isolation holds either way: the group's batch-K cache rows scatter
        into exactly the group's slots."""
        pairs = []
        for slot in self.free_slots:
            req = self.scheduler.next_request()
            if req is None:
                break
            pairs.append((slot, req))
        groups: Dict[int, list] = {}
        for slot, req in pairs:
            groups.setdefault(len(req.prompt), []).append((slot, req))
        for group in groups.values():
            self._prefill_group(group)
        return len(pairs)

    def _prefill_group(self, group):
        """Jointly prefill K same-length requests into their slots. Cannot
        fail on request contents: submit() already validated capacity, so
        popped requests are never stranded mid-admission."""
        plen = len(group[0][1].prompt)
        prompts = jnp.asarray(np.stack([r.prompt for _, r in group]))  # (K,P)
        for _, req in group:
            self.metrics.on_prefill(req.rid, plen)
        logits, rcache = self._prefill_fn()(
            self.params,
            {"tokens": prompts, **self._prefill_extras(len(group))})
        slots = jnp.asarray(np.array([s for s, _ in group], np.int32))
        self.cache = self._insert_rows(self.cache, rcache, slots)
        toks = self._sample_rows(logits)
        for i, (slot, req) in enumerate(group):
            req.state = RequestState.RUNNING
            req.slot = slot
            self.slot_req[slot] = req
            if req.gen_len <= 0:                 # nothing to generate
                self._finish(slot)
                continue
            req.tokens.append(int(toks[i]))
            self.cur_token[slot, 0] = int(toks[i])
            self.metrics.on_first_token(req.rid)
            if req.done:
                self._finish(slot)

    def _finish(self, slot: int):
        req = self.slot_req[slot]
        req.state = RequestState.DONE
        self.metrics.on_done(req.rid)
        self.slot_req[slot] = None

    def step(self) -> int:
        """Admit waiting requests, then one decode tick for every active
        slot; returns #active after the tick."""
        self.admit()
        if self.active == 0:
            return 0
        batch = {"token": jnp.asarray(self.cur_token), **self._decode_extras()}
        logits, self.cache = self._decode(self.params, self.cache, batch)
        self.metrics.on_decode_step()
        nxt = self._sample_rows(logits)
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            req.tokens.append(int(nxt[slot]))
            self.cur_token[slot, 0] = int(nxt[slot])
            self.metrics.on_token(req.rid)
            if req.done:
                self._finish(slot)
        self.admit()        # refill freed slots on the SAME tick
        return self.active

    def drain_completed(self) -> List[Request]:
        """Remove and return finished requests (the engine otherwise retains
        every request — prompt and token list — for its lifetime; a
        long-running deployment should drain periodically). Metric records
        are kept so summary() percentiles stay complete."""
        done = [r for r in self.requests.values()
                if r.state == RequestState.DONE]
        for r in done:
            del self.requests[r.rid]
        return done

    def run(self) -> dict:
        """Serve until queue and slots drain; returns the metrics summary."""
        self.metrics.on_start()
        while self.scheduler.waiting or self.active:
            self.step()
        self.metrics.on_stop()
        return self.metrics.summary()
