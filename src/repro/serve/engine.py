"""Batched-prefill continuous-batching serve engine with a paged KV cache.

Core invariants (see the package docstring for the request lifecycle):

* **One dispatch per prefill wave.** New requests are prefilled by a single
  jitted ``make_prefill(return_cache=True)`` call — prompts are
  teacher-forced under one ``lax.scan``, not one device dispatch per token,
  and never at the full batch width (the legacy path's O(prompt_len)
  full-batch stepping). Same-length requests admitted on the same tick are
  prefilled jointly at batch K (the batched-prefill fan-in); a lone request
  runs at batch 1.
* **Slot isolation.** The batch-K prefill cache is spliced into the resident
  cache through the KV backend's ``insert_rows`` (dense: a batch-row
  scatter; paged: a scatter into exactly the pages the admitted slots own)
  — other slots' cache entries and positions are untouched bit-for-bit.
* **Per-slot positions, inactive sentinel.** The resident cache's ``pos`` is
  a (B,) vector, so slots at different sequence depths decode together in
  one tick. A freed (or never-admitted) slot's pos is parked at
  ``layers.INACTIVE_POS``: every decode path drops its cache writes and
  freezes its recurrent state, so inactive rows are bit-stable — they cannot
  scatter stale K/V into recycled pages.
* **Paged KV (vLLM-style block tables).** With ``page_size`` set, K/V live
  in a shared page pool ``(L, num_pages, page_size, KV, hd)`` addressed
  through per-slot block tables; a host-side free-list ``PageAllocator``
  hands pages out at admission and reclaims them on completion. Memory
  scales with allocated pages — s_max bounds a single request's length (the
  block-table width), not the pool's footprint, so a long request no longer
  dictates every slot's memory. ``page_size == s_max`` is the degenerate
  one-page-per-slot config and reproduces the dense path bit-for-bit.
* **Continuous batching with page-aware admission.** The scheduler admits
  waiting requests the moment a slot frees, on the same tick; paged
  admission PEEKS first and defers (in strict priority/FIFO order) when the
  free list cannot cover the request's worst-case page count.
* **Parallel chunked prefill (default).** Prompts are ingested by the
  matmul-wide ``make_prefill_chunk`` path: every chunk position is computed
  in one full-width pass per layer and the per-layer K/V (ring + recurrent
  carry for hybrid, O(1) state for ssm/rwkv) land in a transient request
  cache that is spliced into the resident cache when the prompt completes.
  Chunks are INTERLEAVED with decode ticks — at most one chunk of at most
  ``prefill_chunk_tokens`` tokens runs between consecutive decode ticks, so
  a max-length prompt cannot stall in-flight decodes (head-of-line bound).
  Chunk lengths are BUCKETED to a fixed ladder (the chunk size plus the
  powers of two below it), so prefill compiles O(ladder), not O(distinct
  prompt lengths); the trace count is hard-capped (jit caches are cleared
  past ``max_prefill_traces``). ``prefill_mode='scan'`` keeps the
  teacher-forced scan prefill as the bit-exactness anchor.

* **Page-level prefix caching (paged dense/MoE/VLM, default on).** Completed
  prompt pages are chain-hashed into a refcounted ``PrefixIndex``; admission
  aliases the longest cached page-aligned prefix into the request's block
  table and runs only the uncached tail. Shared pages are immutable: a
  write that would land in one (partial-page tails, decode appending past
  the prefix) instead targets a fresh page that is re-materialised by the
  same pool scatter — copy-on-write with no extra device pass. Eviction is
  LRU over pages only the index references, and runs before admission ever
  defers.

* **Paged-attention kernel + incremental splice (default with the kernel).**
  With ``paged_attn_impl='kernel'`` (auto on multi-page dense/MoE/VLM/encdec
  pools) decode reads go through the Pallas block-table-gather kernel
  (``kernels/paged_attention.py``) that SKIPS fully-masked pages, and —
  for dense/MoE/VLM parallel prefill — continuation chunks splice their
  K/V into the reserved pages INCREMENTALLY per chunk and attend the pages
  directly: the transient dense request cache disappears, per-chunk mask
  work stops scaling with s_max, prefix hits read aliased pages in place
  (no gather seeding), and COW re-materialisation reuses the same scatter.
  ``paged_attn_impl='einsum'`` keeps the masked-gather transient path (the
  bit-exactness anchor; auto for the degenerate one-page config).

* **Failure / cancellation release.** A prefill chunk dispatch that raises
  aborts its job through ``release_job`` — slots freed, reserved pages and
  aliased prefix refcounts released, requests marked FAILED — and
  ``cancel()`` does the same from every request state, so an errored or
  cancelled mid-prefill job can no longer strand pages until process exit.

* **Pluggable KV-cache backends.** The engine is pure ORCHESTRATION: every
  representation decision (pool dtype/shape, splice math, COW copy, prefix
  seed, per-page metadata) lives behind the :class:`~repro.serve.kvcache
  .KVBackend` seam — ``DenseBackend``, ``PagedFP32Backend`` (the layout
  above, bit-for-bit), and ``PagedInt8Backend`` (int8 pages + per-page
  symmetric scales, dequantized inside the paged kernel's gather). Select
  with ``kv_backend=``; None keeps the historical layout-follows-page_size
  behaviour.

Multi-host serving is a ROADMAP follow-on.
"""
from __future__ import annotations

import dataclasses
import functools
import logging
import warnings
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import Family
from repro.launch import steps as steps_mod
from repro.models.layers import INACTIVE_POS
from repro.models.registry import Model, get_model, reduced_config
from repro.serve.kvcache import (PAGED_KERNEL_FAMILIES, PREFIX_CACHE_FAMILIES,
                                 KVBackend, make_backend)
from repro.serve.metrics import MetricsRecorder
from repro.serve.prefix import PrefixIndex, PrefixPlan
from repro.serve.scheduler import (Request, RequestState, SchedPolicy,
                                   Scheduler)

# PREFIX_CACHE_FAMILIES / PAGED_KERNEL_FAMILIES moved to serve/kvcache.py
# with the rest of the representation layer; re-imported above so existing
# callers (`engine.PREFIX_CACHE_FAMILIES`) keep working.

log = logging.getLogger("repro.serve.engine")


def _under_mesh(mesh, fn):
    """Trace ``fn`` inside the tensor-parallel serving mesh context
    (identity when mesh is None). The engine only forwards the mesh token —
    which rules apply and what they mean lives in sharding/specs.py
    (:func:`specs.serve_trace`), keeping mesh internals out of this
    module."""
    if mesh is None:
        return fn
    from repro.sharding import specs as _specs
    return _specs.serve_trace(mesh, fn)


# Jitted step functions are cached at module level keyed on the (frozen,
# hashable) Model so several engine instances over the same architecture —
# e.g. benchmark repetitions — share one compiled executable instead of
# re-tracing per instance (compile time would otherwise dominate short runs).
# The (hashable) mesh is part of every key: a mesh trace bakes shard_map
# calls into the jaxpr, so mesh and no-mesh engines must never share one.
@functools.lru_cache(maxsize=64)
def _jitted_decode(model: Model, compute_dtype, paged_impl=None, mesh=None):
    return jax.jit(_under_mesh(mesh, steps_mod.make_decode_step(
        model, compute_dtype=compute_dtype, paged_attn_impl=paged_impl)),
        donate_argnums=(1,))


@functools.lru_cache(maxsize=64)
def _jitted_prefill(model: Model, compute_dtype, s_max: int, cache_dtype,
                    mesh=None):
    return jax.jit(_under_mesh(mesh, steps_mod.make_prefill(
        model, compute_dtype=compute_dtype, return_cache=True, s_max=s_max,
        cache_dtype=cache_dtype)))


@functools.lru_cache(maxsize=64)
def _jitted_prefill_chunk(model: Model, compute_dtype, s_max: int,
                          cache_dtype, first: bool, attn_impl: str,
                          mesh=None):
    """Parallel-prefill chunk executables. One jitted callable per
    (model, first) pair; jax retraces it per (batch K, chunk C) SHAPE — the
    engine's bucketed chunk ladder is what keeps that inner cache O(buckets)
    rather than O(distinct prompt lengths), and ``_note_prefill_trace``
    clears these caches if a caller defeats the bucketing."""
    fn = _under_mesh(mesh, steps_mod.make_prefill_chunk(
        model, compute_dtype=compute_dtype, s_max=s_max,
        cache_dtype=cache_dtype, first=first, attn_impl=attn_impl))
    if first:
        return jax.jit(fn)
    return jax.jit(fn, donate_argnums=(1,))     # donate the transient cache


def chunk_ladder(chunk_tokens: int) -> List[int]:
    """The bucketed chunk-length ladder: the chunk size plus every power of
    two below it, descending. Any prompt length decomposes greedily into
    ladder chunks, so prefill compile count is O(len(ladder)) under mixed
    traffic instead of O(distinct prompt lengths)."""
    if chunk_tokens < 1:
        raise ValueError(f"chunk_tokens must be >= 1, got {chunk_tokens}")
    ladder = {chunk_tokens}
    p = 1
    while p < chunk_tokens:
        ladder.add(p)
        p <<= 1
    return sorted(ladder, reverse=True)


def chunk_plan(prompt_len: int, ladder: List[int]) -> List[int]:
    """Greedy largest-first decomposition of a prompt into ladder chunks —
    every token is real (no padding/masking), the last chunks just narrow."""
    plan, rem = [], prompt_len
    for c in ladder:
        while rem >= c:
            plan.append(c)
            rem -= c
    return plan


@dataclasses.dataclass
class _PrefillJob:
    """One in-flight chunked prefill: K same-length requests being ingested
    jointly. ``cache`` is the dense transient request cache at batch K
    (created inside the first-chunk jit — or PRE-SEEDED with gathered
    shared-prefix rows on a prefix-cache hit, in which case every chunk is a
    continuation); slots/pages are already reserved, so completion (the
    splice) cannot fail. ``prompts`` holds only the TAIL the chunks compute
    (positions ``tail_start`` onward); ``write_floor`` is the first cache
    row the completion splice may write — rows below it live in shared
    immutable pages (aliased full pages) and are dropped by the scatter."""
    slots: List[int]
    reqs: List[Request]
    prompts: np.ndarray            # (K, P - tail_start) uncached tail tokens
    plan: List[int]                # bucketed chunk lengths, sums to the tail
    idx: int = 0                   # next chunk index
    filled: int = 0                # tail tokens already ingested
    cache: Optional[dict] = None   # None until the first chunk runs
    tail_start: int = 0            # first prompt position the chunks compute
    write_floor: int = 0           # splice drops rows below this
    prefix_plans: Optional[List[PrefixPlan]] = None   # per-request, for
    # registration at splice (None in scan mode / prefix-cache off)
    deficit: int = 0               # DRR chunk-token credit (policy.drr only)


@functools.lru_cache(maxsize=64)
def _jitted_prefill_chunk_paged(model: Model, compute_dtype, attn_impl: str,
                                mesh=None):
    """Incremental paged-prefill chunk executables: ONE callable per model
    (no first/continuation split — every chunk writes into pages and attends
    them through the block table), retraced per (group K, chunk C) shape
    like the transient chunk path. The resident cache is donated: the pools
    update in place each chunk instead of round-tripping a transient copy."""
    fn = _under_mesh(mesh, steps_mod.make_prefill_chunk_paged(
        model, compute_dtype=compute_dtype, attn_impl=attn_impl))
    return jax.jit(fn, donate_argnums=(1,))


class PageAllocator:
    """Host-side REFCOUNTED free-list allocator over a fixed pool of KV-cache
    pages.

    Pure bookkeeping: page ids index the device pool's page axis; nothing
    here touches device memory. ``alloc`` is all-or-nothing (a request's
    worst case is reserved up front, so admission can never strand a
    half-allocated request) and hands pages out at refcount 1. ``share``
    adds a reference — a prefix-cache index entry, or a second block table
    aliasing the same immutable prefix page — and ``release`` drops one: a
    page returns to the free list only when its LAST reference goes (so a
    page can never be simultaneously free and referenced by a live block
    table or prefix entry), and releasing a page with no references raises
    (the double-free guard the property tests exercise)."""

    def __init__(self, num_pages: int):
        self.num_pages = num_pages
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._ref: Dict[int, int] = {}

    @property
    def free(self) -> int:
        return len(self._free)

    @property
    def held(self) -> set:
        """Pages with at least one live reference (test/debug view)."""
        return set(self._ref)

    def refcount(self, page: int) -> int:
        return self._ref.get(page, 0)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Reserve n pages at refcount 1; returns their ids or None if the
        free list is short (caller defers admission — nothing is partially
        allocated)."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._ref[p] = 1
        return pages

    def share(self, page: int):
        """Add a reference to a held page (block-table alias or prefix-index
        entry). Sharing an unreferenced page is a bookkeeping bug."""
        if page not in self._ref:
            raise ValueError(f"share of unheld page {page}")
        self._ref[page] += 1

    def release(self, pages: List[int]):
        """Drop one reference per page; pages reaching zero return to the
        free list. Releasing an already-free page raises."""
        for p in pages:
            n = self._ref.get(p, 0)
            if n <= 0:
                raise ValueError(f"double free of page {p}")
            if n == 1:
                del self._ref[p]
                self._free.append(p)
            else:
                self._ref[p] = n - 1


class ServeEngine:
    """Slot-based continuous-batching engine over a per-slot-position cache,
    dense or paged (``page_size``/``num_pages``).

    sampling: ``temperature == 0`` is greedy argmax; ``temperature > 0``
    samples from softmax(logits / temperature) — optionally restricted to the
    ``top_k`` highest logits and/or the smallest ``top_p`` nucleus — with a
    per-event PRNG fold so runs are reproducible for a fixed seed.

    prefill: ``prefill_mode='parallel'`` (default) ingests prompts with the
    matmul-wide chunked path, at most one chunk of ``prefill_chunk_tokens``
    tokens between decode ticks; ``'scan'`` is the teacher-forced
    one-dispatch scan prefill (the bit-exactness anchor).
    ``prefill_attn_impl='auto'`` resolves to the K/V-exporting flash kernel
    on TPU and the jnp reference elsewhere.
    """

    def __init__(self, model: Model, params, *, batch_slots: int, s_max: int,
                 compute_dtype=jnp.float32, cache_dtype=None,
                 temperature: float = 0.0, seed: int = 0,
                 top_k: int = 0, top_p: float = 1.0,
                 page_size: Optional[int] = None,
                 num_pages: Optional[int] = None,
                 kv_backend=None,
                 prefix_cache: Optional[bool] = None,
                 prefill_mode: str = "parallel",
                 prefill_chunk_tokens: int = 64,
                 prefill_attn_impl: str = "auto",
                 paged_attn_impl: str = "auto",
                 max_prefill_traces: Optional[int] = None,
                 scheduler: Optional[Scheduler] = None,
                 metrics: Optional[MetricsRecorder] = None,
                 policy: Optional[SchedPolicy] = None,
                 mesh=None):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.batch_slots = batch_slots
        self.s_max = s_max
        self.compute_dtype = compute_dtype
        self.cache_dtype = cache_dtype or compute_dtype
        self.temperature = float(temperature)
        if int(top_k) < 0:
            raise ValueError(f"top_k must be >= 0 (0 = off), got {top_k}")
        if not 0.0 < float(top_p) <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {top_p}")
        self.top_k = int(top_k)
        self.top_p = float(top_p)
        if prefill_mode not in ("parallel", "scan"):
            raise ValueError(f"prefill_mode must be 'parallel' or 'scan', "
                             f"got {prefill_mode!r}")
        self.prefill_mode = prefill_mode
        self.prefill_chunk_tokens = min(int(prefill_chunk_tokens), s_max)
        self.prefill_ladder = chunk_ladder(self.prefill_chunk_tokens)
        if prefill_attn_impl == "auto":
            prefill_attn_impl = ("pallas" if jax.default_backend() == "tpu"
                                 else "einsum")
        self.prefill_attn_impl = prefill_attn_impl
        # hard cap on distinct prefill trace shapes: first/cont x ladder x
        # group widths; past it the chunk jit caches are cleared (and the
        # overflow counted) so a bucketing-defeating caller cannot leak
        # compiled executables without bound
        self.max_prefill_traces = (max_prefill_traces if max_prefill_traces
                                   is not None else
                                   2 * len(self.prefill_ladder) * batch_slots)
        self._trace_keys: set = set()
        self.prefill_trace_evictions = 0
        self._jobs: List[_PrefillJob] = []
        self.max_prefill_tokens_per_tick = 0   # head-of-line bound witness
        # SLO-aware scheduling policy: every SchedPolicy default is OFF, so
        # policy=None keeps greedy token streams bit-identical to the
        # pre-policy engine (the standing anchor discipline). Resolved
        # before the scheduler so a default-built Scheduler inherits
        # policy.edf.
        self.policy = SchedPolicy() if policy is None else policy
        # explicit None checks: an EMPTY Scheduler is falsy (__bool__ tracks
        # queue depth), so `scheduler or Scheduler()` would silently discard
        # a caller's configured (e.g. prefix-aware) scheduler
        self.scheduler = (Scheduler(edf=self.policy.edf)
                          if scheduler is None else scheduler)
        self.metrics = MetricsRecorder() if metrics is None else metrics
        self._drr_cursor = 0          # rotates the DRR starting job per tick
        self._consec_prefill_ticks = 0  # starvation-guard state

        # tensor-parallel serving mesh: the cache leaves commit through the
        # backend's place() hook, params/activations replicate, and the
        # attention cores route through shard_map wrappers resolved at the
        # kernels layer. Every mesh/axis-name decision lives behind the
        # backend seam or the sharding/specs helpers — the engine holds the
        # mesh as an opaque token and never reads its internals (pinned by
        # the AST guard in tests/test_kvcache.py).
        self.mesh = mesh
        if mesh is not None:
            from repro.sharding import specs as _specs
            if page_size is None:
                raise ValueError(
                    "tensor-parallel serving needs a PAGED cache (pass "
                    "page_size=): only the page pool has a mesh layout")
            self.params = _specs.replicate_params(self.params, mesh)

        if page_size is not None and model.cfg.family == Family.SSM:
            log.warning("ssm/rwkv state is O(1) in s_max — ignoring paging")
            page_size = None
        self.page_size = page_size
        self.paged = page_size is not None
        if self.paged:
            if s_max % page_size:
                raise ValueError(f"s_max {s_max} must be a multiple of "
                                 f"page_size {page_size}")
            self.max_pages_per_slot = s_max // page_size
            self.num_pages = (num_pages if num_pages is not None
                              else batch_slots * self.max_pages_per_slot)
            # the backend owns every REPRESENTATION decision (pool layout,
            # splice/COW/seed math, per-page metadata); the engine keeps the
            # orchestration state that follows (allocator, block tables)
            self.backend: KVBackend = make_backend(
                kv_backend, family=self.cfg.family, page_size=page_size,
                num_pages=self.num_pages, mesh=mesh,
                num_kv_heads=self.cfg.num_kv_heads)
            # rows one slot's attention cache can hold (ring width for hybrid)
            self.capacity = self.backend.capacity(self.cfg, s_max)
            self.allocator = PageAllocator(self.num_pages)
            self.slot_pages: List[List[int]] = [[] for _ in range(batch_slots)]
            self._bt_host = np.full((batch_slots, self.max_pages_per_slot),
                                    -1, np.int32)
        else:
            self.backend = make_backend(kv_backend, family=self.cfg.family,
                                        mesh=mesh,
                                        num_kv_heads=self.cfg.num_kv_heads)
        self.cache = self.backend.init_cache(model, batch_slots, s_max,
                                             self.cache_dtype)

        # prefix cache: paged + parallel prefill + an attention-pure family
        # only (the tail-only restart needs the full mid-prompt state to be
        # reconstructible from K/V pages). None = auto-enable when supported;
        # an explicit True on an unsupported config warns and falls back to
        # full prefill rather than erroring (serving keeps working).
        supported = (self.paged and self.prefill_mode == "parallel"
                     and self.cfg.family in PREFIX_CACHE_FAMILIES)
        if prefix_cache is None:
            prefix_cache = supported
        elif prefix_cache and not supported:
            log.warning("prefix_cache unsupported here (needs paged cache, "
                        "parallel prefill, and a dense/MoE/VLM family; got "
                        "paged=%s mode=%s family=%s) — falling back to full "
                        "prefill", self.paged, self.prefill_mode,
                        self.cfg.family)
            prefix_cache = False
        self.prefix_cache = bool(prefix_cache)
        self.prefix_index = (PrefixIndex(self.allocator, self.page_size)
                             if self.prefix_cache else None)

        # paged attention read path: 'kernel' = the Pallas block-gather
        # kernel (and, with parallel prefill on a supported family, the
        # INCREMENTAL per-chunk page splice — no transient request cache);
        # 'einsum' = the masked-gather reference read + transient-cache
        # prefill with a completion splice (the PR 2-4 path, kept as the
        # bit-exactness anchor and the unsupported-family fallback).
        if paged_attn_impl not in ("auto", "kernel", "einsum"):
            raise ValueError(f"paged_attn_impl must be 'auto', 'kernel' or "
                             f"'einsum', got {paged_attn_impl!r}")
        kernel_ok = self.paged and self.cfg.family in PAGED_KERNEL_FAMILIES
        if paged_attn_impl == "auto":
            # the backend's dispatch policy; for paged pools the degenerate
            # one-page-per-slot config (page_size == s_max) is the dense
            # bit-exactness anchor and has no pages to skip — auto keeps it
            # on the einsum path so the anchor stays bit-for-bit
            paged_attn_impl = (self.backend.resolve_attn_impl(
                self.cfg.family, self.max_pages_per_slot > 1)
                if self.paged else "einsum")
        elif paged_attn_impl == "kernel" and not kernel_ok:
            log.warning("paged_attn_impl='kernel' unsupported here (needs a "
                        "paged cache on a dense/MoE/VLM/encdec family; got "
                        "paged=%s family=%s) — using the masked-einsum path",
                        self.paged, self.cfg.family)
            paged_attn_impl = "einsum"
        self.paged_attn_impl = paged_attn_impl
        # incremental splice: continuation chunks write K/V straight into
        # their reserved pages and attend them through the block table —
        # the transient dense request cache disappears and per-chunk mask
        # work stops scaling with s_max
        self.incremental_splice = (
            self.paged and self.prefill_mode == "parallel"
            and self.paged_attn_impl == "kernel"
            and model.supports_paged_prefill)
        self.prefill_failures = 0
        self.max_transient_cache_bytes = 0
        self._cancel_at_splice: set = set()
        self._decode = _jitted_decode(
            model, compute_dtype,
            self.paged_attn_impl if self.paged else None, mesh)

        # (head rid, free pages, index version) at the last deferral: admit()
        # short-circuits while nothing that could change the outcome has
        # changed, instead of re-running the O(prompt) prefix lookup, the
        # share/release churn, and a futile whole-index eviction walk on
        # every decode tick a head request spends waiting for pages
        self._defer_state: Optional[tuple] = None
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.cur_token = np.zeros((batch_slots, 1), np.int32)
        self.requests: Dict[int, Request] = {}
        self.deferrals = 0    # admissions postponed for lack of free pages
        self._next_rid = 0
        self._key = jax.random.PRNGKey(seed)
        self._events = 0      # PRNG fold counter (one per sampling event)

    # ------------------------------------------------------------ factory
    @classmethod
    def build(cls, arch: str = "hymba-1.5b", *, config=None,
              **legacy) -> "ServeEngine":
        """Construct model + params from an arch id and a
        :class:`~repro.serve.config.ServeConfig`:

            ServeEngine.build("qwen2.5-32b-mla", config=ServeConfig(
                page_size=16, kv_backend="paged_latent"))

        ``config.validate(cfg)`` runs against the resolved arch BEFORE any
        weights are built, so cross-field mistakes (dense + tp, a backend
        whose capability query refuses the tp degree, unknown backend name,
        page misalignment) fail fast. The int8
        PTQ path is the same structural quantize->dequant-on-load as the
        paper's C5 (the pallas quant_matmul kernel consumes q directly on
        TPU). ``config.tp`` builds a 1-axis serving mesh over the first
        ``tp`` local devices (tp=1 is a legal 1-device mesh: it exercises
        the whole mesh code path and is the bit-exactness anchor against
        mesh=None). ``config.cfg_overrides``: dataclasses.replace fields
        applied AFTER reduction — reduced configs can shrink num_kv_heads
        to 1, which blocks kv-head sharding; the tp tests/bench override
        the head counts while keeping everything else reduced.

        DEPRECATED spelling: ``build(arch, page_size=..., s_max=...)`` —
        the pre-ServeConfig kwarg surface. Still accepted (each kwarg maps
        onto the ServeConfig field of the same name, so behaviour is
        identical by construction) but emits a DeprecationWarning; passing
        both ``config`` and legacy kwargs is an error."""
        from repro.serve.config import ServeConfig
        if legacy:
            if config is not None:
                raise ValueError(
                    "pass either config=ServeConfig(...) or the legacy "
                    "keyword arguments, not both; the legacy kwargs are "
                    f"deprecated (got {sorted(legacy)})")
            known = {f.name for f in dataclasses.fields(ServeConfig)}
            unknown = sorted(set(legacy) - known)
            if unknown:
                raise TypeError(f"unknown ServeEngine.build arguments "
                                f"{unknown}; ServeConfig fields: "
                                f"{sorted(known)}")
            warnings.warn(
                "ServeEngine.build(**kwargs) is deprecated; pass "
                "config=ServeConfig(...) instead (same field names)",
                DeprecationWarning, stacklevel=2)
            config = ServeConfig(**legacy)
        elif config is None:
            config = ServeConfig()
        cfg = configs.get_config(arch)
        if config.reduced:
            cfg = reduced_config(cfg)
        if config.cfg_overrides:
            cfg = dataclasses.replace(cfg, **config.cfg_overrides)
        # the device-count guard outranks validate(): "you don't have the
        # devices" is the actionable error on a 1-device host even when the
        # reduced config's kv-head count would also reject the tp degree
        mesh = None
        if config.tp is not None:
            tp = config.tp
            ndev = len(jax.devices())
            if tp < 1 or tp > ndev:
                raise ValueError(f"tp={tp} needs 1..{ndev} local devices "
                                 "(CPU tests force 8 via XLA_FLAGS="
                                 "--xla_force_host_platform_device_count=8)")
        config.validate(cfg)
        if config.tp is not None:
            from repro.sharding import specs as _specs
            mesh = _specs.serve_mesh(config.tp)
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(config.seed))
        if config.quantize_int8:
            from repro.core.quantize import dequantize_params, quantize_params
            params = dequantize_params(quantize_params(params),
                                       config.compute_dtype)
        return cls(model, params, mesh=mesh, **config.engine_kwargs())

    # ------------------------------------------------------------ extras
    def _decode_extras(self) -> dict:
        return self._prefill_extras(self.batch_slots)

    def _prefill_extras(self, batch: int) -> dict:
        if self.cfg.cross_attn_every:
            return {"image_embeds": jnp.zeros(
                (batch, self.cfg.num_image_tokens, self.cfg.d_model),
                self.compute_dtype)}
        return {}

    def _prefill_fn(self) -> Callable:
        return _jitted_prefill(self.model, self.compute_dtype, self.s_max,
                               self.cache_dtype, self.mesh)

    def _chunk_fn(self, first: bool) -> Callable:
        return _jitted_prefill_chunk(self.model, self.compute_dtype,
                                     self.s_max, self.cache_dtype, first,
                                     self.prefill_attn_impl, self.mesh)

    def _chunk_paged_fn(self) -> Callable:
        return _jitted_prefill_chunk_paged(self.model, self.compute_dtype,
                                           self.paged_attn_impl, self.mesh)

    @property
    def prefill_trace_count(self) -> int:
        """Distinct (first, group K, chunk C) prefill shapes traced so far —
        bucketing keeps this O(ladder x group widths) under mixed-length
        traffic (the compile-count bound tests assert on it)."""
        return len(self._trace_keys)

    def _note_prefill_trace(self, first: bool, K: int, C: int):
        key = (first, K, C)
        if key in self._trace_keys:
            return
        self._trace_keys.add(key)
        if len(self._trace_keys) > self.max_prefill_traces:
            # bucketing was defeated (e.g. a pathological chunk ladder):
            # drop the compiled executables instead of leaking them forever
            log.warning("prefill trace count %d exceeded cap %d; clearing "
                        "chunk jit caches", len(self._trace_keys),
                        self.max_prefill_traces)
            for f in (True, False):
                self._chunk_fn(f).clear_cache()
            if self.incremental_splice:
                self._chunk_paged_fn().clear_cache()
            self._trace_keys = {key}
            self.prefill_trace_evictions += 1

    # ------------------------------------------------------------ sampling
    def _filter_logits(self, scaled):
        """Restrict temperature-scaled logits to the top-k highest and then
        the nucleus (smallest prefix of the remaining sorted distribution
        whose cumulative probability reaches top_p); masked entries go to
        -inf so ``jax.random.categorical`` can never draw them. Hot-path
        cost: top-k alone is one O(V) ``lax.top_k`` threshold; with top_p
        one full sort is shared by both filters (the kept set is a prefix
        of the sorted order, so a single scalar threshold per row masks the
        unsorted logits)."""
        V = scaled.shape[-1]
        neg = jnp.asarray(-jnp.inf, scaled.dtype)
        use_k = 0 < self.top_k < V
        if self.top_p >= 1.0:
            if not use_k:
                return scaled
            kth = jax.lax.top_k(scaled, self.top_k)[0][:, -1:]
            return jnp.where(scaled < kth, neg, scaled)
        srt = jnp.sort(scaled, axis=-1)[:, ::-1]
        if use_k:
            srt = jnp.where(jnp.arange(V) < self.top_k, srt, neg)
        probs = jax.nn.softmax(srt, axis=-1)    # -inf rows carry zero mass
        cum = jnp.cumsum(probs, axis=-1)
        keep = cum - probs < self.top_p         # minimal prefix reaching p
        thresh = jnp.min(jnp.where(keep, srt, jnp.inf), axis=-1,
                         keepdims=True)
        return jnp.where(scaled < thresh, neg, scaled)

    def _sample_rows(self, logits) -> np.ndarray:
        """logits: (B, 1, V_padded) -> (B,) sampled token per row."""
        row = logits[:, 0, : self.cfg.vocab_size]
        if self.temperature <= 0.0:
            return np.asarray(jnp.argmax(row, axis=-1), np.int32)
        key = jax.random.fold_in(self._key, self._events)
        self._events += 1
        toks = jax.random.categorical(
            key, self._filter_logits(row / self.temperature), axis=-1)
        return np.asarray(toks, np.int32)

    # ------------------------------------------------------------ paging
    @staticmethod
    def _rows_needed(prompt_len: int, gen_len: int) -> int:
        """Cache rows a request writes: prefill writes positions
        0..prompt_len-1; the gen_len-1 fed-back decode tokens write at
        prompt_len..prompt_len+gen_len-2 (the final sampled token is never
        written)."""
        return prompt_len + max(int(gen_len) - 1, 0)

    def _pages_for_rows(self, rows: int) -> int:
        """THE page-accounting rule — submit() validation and admit()
        reservation must agree on it or admission stops being infallible."""
        return -(-min(rows, self.capacity) // self.page_size)

    def _pages_needed(self, req: Request) -> int:
        # ``remaining`` (== gen_len for a fresh request) rather than gen_len:
        # a PREEMPTED request re-admits with its generated tokens folded into
        # the prompt, and charging full gen_len again would overcount its
        # reservation by len(tokens) — past s_max in the worst case
        return self._pages_for_rows(
            self._rows_needed(len(req.prompt), req.remaining))

    def _phys_rows(self, slots: List[int], floor: int = 0) -> np.ndarray:
        """(K, capacity) flattened pool-row index per logical cache row for a
        prefill group; rows beyond a slot's reservation map out of bounds and
        are dropped by the paged splice. ``floor`` additionally maps rows
        BELOW it out of bounds — a prefix-hit group's leading rows live in
        shared immutable pages aliased by other block tables, and the splice
        must never write them (copy-on-write's no-write half)."""
        ps = self.page_size
        C = self.capacity
        oob = self.num_pages * ps
        phys = np.full((len(slots), C), oob, np.int32)
        j = np.arange(C)
        for i, slot in enumerate(slots):
            pages = np.asarray(self.slot_pages[slot], np.int64)
            cov = min(C, len(pages) * ps)
            phys[i, :cov] = pages[j[:cov] // ps] * ps + j[:cov] % ps
        if floor > 0:
            phys[:, :min(floor, C)] = oob
        return phys

    def _prefix_gather_rows(self, plans: List[PrefixPlan], cached_len: int):
        """(K, s_max) flattened pool rows + validity mask covering each
        request's cached prefix: rows [0, cached_len) map through the hit's
        full pages and (for an unaligned hit) the partial COW SOURCE page —
        NOT the fresh page the block table holds in its place."""
        ps = self.page_size
        K = len(plans)
        phys = np.zeros((K, self.s_max), np.int32)
        ok = np.zeros((K, self.s_max), bool)
        j = np.arange(cached_len)
        for i, plan in enumerate(plans):
            pages = list(plan.shared_pages)
            if plan.partial is not None:
                pages.append(plan.partial[0])
            pages = np.asarray(pages, np.int64)
            phys[i, :cached_len] = pages[j // ps] * ps + j % ps
            ok[i, :cached_len] = True
        return phys, ok

    def resident_cache_bytes(self) -> int:
        """Device bytes held by the resident serving cache (the paged pool
        plus per-slot leaves; for dense, the full slots x s_max block).
        GLOBAL logical bytes — under a tp mesh the pool is spread over the
        shards; see per_shard_kv_bytes for the per-device footprint."""
        return int(sum(l.size * l.dtype.itemsize
                       for l in jax.tree.leaves(self.cache)))

    def per_shard_kv_bytes(self) -> int:
        """PER-DEVICE resident bytes of the cache's pool leaves (payload
        plus per-page scale metadata — every leaf the backend declared,
        not a hardcoded k/v tuple, so a single-leaf latent pool or a
        custom backend's extra leaves count too), via each leaf's
        committed sharding — the number the tp bench gates against the
        global pool. Orchestration metadata (block tables, positions) is
        excluded. Works unmeshed too (single-device sharding: per-shard ==
        global)."""
        if not isinstance(self.cache, dict):
            return 0
        total = 0
        for key, leaf in self.cache.items():
            if key in ("block_tables", "pos"):
                continue
            shard_shape = leaf.sharding.shard_shape(leaf.shape)
            total += int(np.prod(shard_shape)) * leaf.dtype.itemsize
        return total

    @property
    def free_pages(self) -> int:
        return self.allocator.free if self.paged else 0

    # ------------------------------------------------------------ lifecycle
    def submit(self, prompt, gen_len: int, priority: int = 0,
               deadline: Optional[float] = None) -> Request:
        """Enqueue a request; admission happens on the next step()/run().

        ``deadline``: optional absolute completion deadline (caller's
        clock). Consumed by an EDF scheduler (SchedPolicy.edf) to order
        same-priority admissions earliest-deadline-first; inert otherwise.

        Rejects up front anything that can never be served, so admission is
        infallible and a bad request cannot strand already-popped good ones:
        empty prompts (a zero-length prefill scan has undefined logits),
        negative gen_len, and requests whose written rows
        (prompt_len + gen_len - 1, see _rows_needed) exceed the per-slot
        bound — s_max for the dense cache (a write past s_max would be
        silently DROPPED by the scatter and attention would read
        never-written rows), the block-table span AND total pool capacity
        for the paged cache. Transient page shortage is NOT rejected here:
        admit() defers until enough pages free up."""
        prompt = np.asarray(prompt, np.int32)
        if prompt.ndim != 1:
            raise ValueError(f"prompt must be a 1-D token vector, got shape "
                             f"{prompt.shape}")
        if prompt.size == 0:
            raise ValueError("empty prompt: prefill needs at least one token")
        if int(gen_len) < 0:
            raise ValueError(f"gen_len must be >= 0, got {gen_len}")
        rows = self._rows_needed(len(prompt), gen_len)
        if len(prompt) > self.s_max or rows > self.s_max:
            raise ValueError(
                f"prompt_len {len(prompt)} + gen_len {gen_len} does not fit "
                f"s_max {self.s_max}; raise s_max or shorten the request")
        if self.paged:
            need = self._pages_for_rows(rows)
            if need > self.num_pages:
                raise ValueError(
                    f"request needs {need} pages but the pool only has "
                    f"{self.num_pages}; grow num_pages")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, prompt=prompt,
                      gen_len=int(gen_len), priority=priority)
        if deadline is not None:
            req.deadline = float(deadline)
        if (self.prefix_index is not None
                and getattr(self.scheduler, "prefix_aware", False)):
            # advisory ordering hint for a prefix-aware scheduler; does not
            # touch the LRU order and is re-resolved authoritatively at
            # admission (the index may have churned by then). Skipped for
            # the default FIFO scheduler — the hint would be dead weight
            # (an O(prompt) hash walk per submit with no consumer).
            req.prefix_hint = self.prefix_index.probe_len(prompt)
        self.requests[rid] = req
        self.metrics.on_submit(rid, len(req.prompt), priority)
        self.scheduler.submit(req)
        return req

    @property
    def free_slots(self) -> List[int]:
        return [s for s, r in enumerate(self.slot_req) if r is None]

    @property
    def active(self) -> int:
        return sum(1 for r in self.slot_req if r is not None)

    def admit(self) -> int:
        """Admit waiting requests into free slots; returns #admitted.

        Requests admitted on the same tick are grouped by prompt length and
        prefilled JOINTLY — one dispatch (or one chunk stream) fills K slots
        (the batched-prefill fan-in; mixed lengths fall back to one group
        each). Isolation holds either way: the group's batch-K cache rows
        scatter into exactly the group's slots (dense) or pages (paged).

        In ``parallel`` mode admission only RESERVES (slot + pages) and
        enqueues a chunked :class:`_PrefillJob`; the prompt is ingested one
        bucketed chunk per tick by ``_prefill_tick`` so in-flight decodes
        are never stalled behind a long prompt. In ``scan`` mode the whole
        prompt is prefilled here in one teacher-forced scan dispatch.

        Paged admission PEEKS before popping: when the free-page list cannot
        cover the head request's worst case, admission stops — the request
        stays queued at the head (strict priority/FIFO, no skip-ahead that
        could starve long requests) until completions release pages. With
        the prefix cache enabled, admission first resolves the longest
        cached page-aligned prefix: hit pages alias into the block table
        (one allocator reference each) and only the remainder is freshly
        allocated — and when the free list is still short, LRU index-only
        pages are EVICTED before deferring, so caching never makes
        admission defer earlier than the uncached engine would."""
        pairs = []
        plans: Dict[int, Optional[PrefixPlan]] = {}
        for slot in self.free_slots:
            # lazily-cancelled heads are pruned inside Scheduler.peek — the
            # scheduler is the single source of truth for queue liveness
            req = self.scheduler.peek()
            while req is not None and self._shed_head(req):
                req = self.scheduler.peek()
            if req is None:
                break
            if self._defer_head(req):
                break
            plan = None
            if self.paged:
                defer_state = (req.rid, self.allocator.free,
                               self.prefix_index.version
                               if self.prefix_index is not None else 0)
                if defer_state == self._defer_state:
                    break       # same head, same pages, same index: still short
                shared: List[int] = []
                refs: List[int] = []
                if self.prefix_index is not None:
                    plan = self.prefix_index.lookup(req.prompt)
                    shared = list(plan.shared_pages)
                    # ref every page the plan READS — block-table aliases
                    # AND the partial COW source (gathered at seed time, not
                    # aliased) — so eviction for a later slot in this same
                    # loop can never free-and-reallocate them out from under
                    # the plan. The partial ref is dropped after the seed
                    # gather (_seed_prefix_job); the aliases at _finish.
                    refs = shared + ([plan.partial[0]] if plan.partial
                                     else [])
                    for pg in refs:
                        self.allocator.share(pg)
                need = self._pages_needed(req) - len(shared)
                fresh = self.allocator.alloc(need)
                if fresh is None and self.prefix_index is not None:
                    evicted = self.prefix_index.evict(
                        need - self.allocator.free)
                    if evicted:
                        self.metrics.on_prefix_evict(evicted)
                    fresh = self.allocator.alloc(need)
                if fresh is None and self.policy.preemption:
                    # pool pressure: pause strictly-lower-priority RUNNING
                    # slots (recompute-style re-queue) until the head fits
                    # or no eligible victim remains. Each preemption demotes
                    # the victim's registered prompt pages to index-only, so
                    # eviction re-runs before the retry — otherwise a cached
                    # victim frees nothing and admission deadlocks
                    while fresh is None and \
                            self._preempt_lowest(below=req.priority):
                        if (self.prefix_index is not None
                                and need > self.allocator.free):
                            evicted = self.prefix_index.evict(
                                need - self.allocator.free)
                            if evicted:
                                self.metrics.on_prefix_evict(evicted)
                        fresh = self.allocator.alloc(need)
                if fresh is None:
                    if refs:
                        self.allocator.release(refs)     # back to index-only
                    self.deferrals += 1
                    self._defer_state = (req.rid, self.allocator.free,
                                         self.prefix_index.version
                                         if self.prefix_index is not None
                                         else 0)
                    break
                if plan is not None:
                    self.metrics.on_prefix_lookup(
                        plan.cached_len, len(shared), plan.cow)
                pages = shared + fresh
                self.slot_pages[slot] = pages
                self._bt_host[slot, :] = -1
                self._bt_host[slot, :len(pages)] = pages
            self.scheduler.next_request()       # pop the peeked head
            req.state = RequestState.PREFILLING
            req.slot = slot
            self.slot_req[slot] = req
            self.metrics.on_admit(req.rid)
            self.metrics.on_prefill(req.rid, len(req.prompt))
            plans[slot] = plan
            pairs.append((slot, req))
        if self.paged and pairs:
            self.cache["block_tables"] = jnp.asarray(self._bt_host)
        # group by (prompt_len, cached_len): joint prefill needs equal tail
        # shapes AND an equal gather offset across the group's requests
        groups: Dict[tuple, list] = {}
        for slot, req in pairs:
            plan = plans[slot]
            cached = plan.cached_len if plan is not None else 0
            groups.setdefault((len(req.prompt), cached), []).append(
                (slot, req))
        for (plen, cached), group in groups.items():
            if self.prefill_mode == "scan":
                self._prefill_group_scan(group)
                continue
            # a tail of at least one position always runs: the splice needs
            # last-position logits to sample the first token, so a full-hit
            # prompt recomputes (only) its final position
            tail_start = min(cached, plen - 1)
            group_plans = ([plans[s] for s, _ in group]
                           if self.prefix_index is not None else None)
            job = _PrefillJob(
                slots=[s for s, _ in group],
                reqs=[r for _, r in group],
                prompts=np.stack([r.prompt[tail_start:] for _, r in group]),
                plan=chunk_plan(plen - tail_start, self.prefill_ladder),
                tail_start=tail_start,
                write_floor=(cached // self.page_size * self.page_size
                             if cached else 0),
                prefix_plans=group_plans)
            if cached:
                if self.incremental_splice:
                    # aliased full pages are read IN PLACE by the paged
                    # chunk attention — only a partial hit's COW page needs
                    # materialising, with the same pool scatter
                    self._cow_materialise_job(job, cached)
                else:
                    self._seed_prefix_job(job, cached)
            self._jobs.append(job)
        return len(pairs)

    # ------------------------------------------- admission control / preempt
    def _admission_pressure(self) -> bool:
        """True when the AVAILABLE-page fraction is below the policy's
        low-water mark — the signal admission control sheds/defers on.
        Available counts the free list PLUS the prefix index's reclaimable
        (index-only) pages: a warm cache parks most of the free list in
        evictable pages, and a raw free-list reading would shed load the
        pool could trivially serve. Always False for dense caches and with
        the default policy (low_water == 0)."""
        pol = self.policy
        if not (self.paged and pol.admission_low_water > 0.0):
            return False
        avail = self.allocator.free
        if self.prefix_index is not None:
            avail += self.prefix_index.reclaimable
        return avail < pol.admission_low_water * self.num_pages

    def _gated(self, req: Request) -> bool:
        pol = self.policy
        return (pol.admission_shed_priority is not None
                and req.priority >= pol.admission_shed_priority
                and self._admission_pressure())

    def _shed_head(self, req: Request) -> bool:
        """Admission control, shedding flavor: under pool pressure a queued
        head at/below the shed priority is popped and FAILED outright so the
        pool's remaining headroom serves the load the SLO protects. Returns
        True when the head was shed (the caller re-peeks)."""
        if not (self.policy.admission_shed and self._gated(req)):
            return False
        self.scheduler.next_request()
        req.state = RequestState.FAILED
        req.error = "shed: free pages below admission low water"
        self.metrics.on_shed(req.rid)
        self.metrics.on_aborted(req.rid)
        return True

    def _defer_head(self, req: Request) -> bool:
        """Admission control, deferring flavor (``admission_shed=False``):
        the gated head stays queued — strict order, no skip-ahead — until
        completions lift the pool back over the low-water mark."""
        return (not self.policy.admission_shed) and self._gated(req)

    def _preempt_lowest(self, below: int) -> bool:
        """Preempt the worst-priority RUNNING slot whose priority is
        STRICTLY greater (worse) than ``below``; among equals the most
        recently submitted loses (least generated work to recompute).
        Returns False when no eligible victim exists."""
        victim_slot, victim = None, None
        for slot, r in enumerate(self.slot_req):
            if r is None or r.state is not RequestState.RUNNING:
                continue
            if r.priority <= below:
                continue
            if victim is None or (r.priority, r.rid) > (victim.priority,
                                                        victim.rid):
                victim_slot, victim = slot, r
        if victim is None:
            return False
        self._preempt(victim_slot)
        return True

    def _preempt(self, slot: int):
        """Pause a RUNNING request recompute-style: release its slot and
        pages (K/V is reproducible — vLLM's recompute preemption), fold the
        tokens generated so far into the prompt, and re-queue it under its
        ORIGINAL arrival seq. On re-admission the folded prompt re-prefills
        (through the prefix cache when enabled, which typically still holds
        its pages) and the completion splice samples exactly the token the
        uninterrupted decode would have produced — greedy streams stay
        bit-identical across a preemption. The request record stays open:
        the pause surfaces as one long inter-token gap, which is precisely
        what preemption trades against higher-priority TTFT."""
        req = self.slot_req[slot]
        fresh = req.tokens[req.folded:]   # tokens[:folded] are already in
        if fresh:                         # the prompt from an earlier pause
            req.prompt = np.concatenate(
                [req.prompt, np.asarray(fresh, np.int32)])
            req.folded = len(req.tokens)
        req.state = RequestState.QUEUED
        req.slot = None
        self.slot_req[slot] = None
        self.cur_token[slot, 0] = 0
        self.cache["pos"] = self.cache["pos"].at[slot].set(INACTIVE_POS)
        if self.paged:
            self.allocator.release(self.slot_pages[slot])
            self.slot_pages[slot] = []
            self._bt_host[slot, :] = -1
            self.cache["block_tables"] = jnp.asarray(self._bt_host)
        self.metrics.on_preempt(req.rid)
        self._defer_state = None      # freed pages can change the outcome
        self.scheduler.submit(req)

    def _seed_prefix_job(self, job: _PrefillJob, cached_len: int):
        """Materialise a prefix-hit group's transient cache: gather the
        cached rows out of the shared pages (full pages AND the partial COW
        source) into a fresh dense batch-K cache positioned at the tail
        start. Every subsequent chunk is a continuation; the gather wall is
        charged to prefill so hit-path rates stay honest."""
        phys, ok = self._prefix_gather_rows(job.prefix_plans, cached_len)
        t0 = self.metrics.now()
        job.cache = self.backend.seed_prefix(self.model, self.s_max,
                                             self.cache_dtype)(
            self.cache, jnp.asarray(phys), jnp.asarray(ok),
            jnp.asarray(job.tail_start, jnp.int32))
        jax.block_until_ready(job.cache["k"])
        self.metrics.on_prefix_gather(self.metrics.now() - t0)
        # the gather has consumed the partial COW sources; drop the temporary
        # admission-time references (aliased full pages stay ref'd via
        # slot_pages until _finish)
        for plan in job.prefix_plans:
            if plan.partial is not None:
                self.allocator.release([plan.partial[0]])

    def _cow_materialise_job(self, job: _PrefillJob, cached_len: int):
        """Incremental-path half of a prefix hit: aliased FULL pages need no
        work at all (the paged chunk attention reads them through the block
        table), but a partial hit's rows ``[write_floor, cached_len)`` live
        in a shared SOURCE page while the block table holds a fresh page in
        that position — copy them across with the same flattened-pool
        scatter the per-chunk splice uses (the backend's ``copy_rows``;
        the int8 backend carries the source page's scale with the payload),
        then drop the admission-time source references. The copy wall is
        charged to prefill like the transient path's gather, so hit-path
        rates stay honest."""
        ps = self.page_size
        n = cached_len - job.write_floor          # partial rows to copy
        if n > 0:
            oob = self.num_pages * ps
            K = len(job.slots)
            src = np.zeros((K, ps), np.int64)
            dst = np.full((K, ps), oob, np.int64)
            offs = np.arange(ps)
            for i, (slot, plan) in enumerate(zip(job.slots,
                                                 job.prefix_plans)):
                if plan.partial is None:
                    continue
                fresh = self.slot_pages[slot][cached_len // ps]
                src[i, :n] = plan.partial[0] * ps + offs[:n]
                dst[i, :n] = fresh * ps + offs[:n]
            t0 = self.metrics.now()
            self.cache = self.backend.copy_rows(self.cache, jnp.asarray(src),
                                                jnp.asarray(dst))
            jax.block_until_ready(self.cache["k"])
            self.metrics.on_prefix_gather(self.metrics.now() - t0)
        for plan in job.prefix_plans:
            if plan.partial is not None:
                self.allocator.release([plan.partial[0]])

    def _prefill_group_scan(self, group):
        """Jointly prefill K same-length requests in ONE teacher-forced scan
        dispatch (the bit-exactness anchor path). Cannot fail on request
        contents: submit() already validated capacity and admit() already
        reserved pages, so popped requests are never stranded."""
        plen = len(group[0][1].prompt)
        prompts = jnp.asarray(np.stack([r.prompt for _, r in group]))  # (K,P)
        t0 = self.metrics.now()
        logits, rcache = self._prefill_fn()(
            self.params,
            {"tokens": prompts, **self._prefill_extras(len(group))})
        jax.block_until_ready(logits)
        self.metrics.on_prefill_chunk(len(group) * plen,
                                      self.metrics.now() - t0)
        self._splice_and_start([s for s, _ in group], [r for _, r in group],
                               rcache, logits)

    # ------------------------------------------------- chunked prefill
    def _prefill_tick(self) -> int:
        """Ingest at most ``prefill_chunk_tokens`` prompt positions of
        queued prefill work — the engine's head-of-line bound: between any
        two decode ticks the prefill interleave is capped by the chunk
        budget, whatever the longest queued prompt is. Bucketed ladder
        chunks that fit the remaining budget run back-to-back (a 12-token
        prompt under a 64 budget still completes in one tick as 8 + 4), in
        strict job-FIFO order — or deficit-round-robin across jobs when
        ``policy.drr`` is set (same budget, fairly split; see
        ``_prefill_tick_drr``). Returns prompt positions ingested.

        With ``incremental_splice`` the chunk dispatch writes its K/V rows
        straight into the group's reserved pages and attends them through
        the block table (``make_prefill_chunk_paged``) — no transient
        request cache exists and completion only flips the group's ``pos``.

        A chunk dispatch that RAISES aborts its whole job through
        :meth:`release_job` (slots freed, pages and aliased prefix
        refcounts released, requests marked FAILED) and the tick moves on —
        an errored prompt can neither strand pages until process exit nor
        wedge the queue behind it."""
        budget = self.prefill_chunk_tokens
        if self.policy.drr and len(self._jobs) > 1:
            ingested = self._prefill_tick_drr(budget)
        else:
            # default: strict job-FIFO (the pre-policy behavior, bit-exact)
            ingested = 0
            while self._jobs and budget > 0:
                job = self._jobs[0]
                if job.plan[job.idx] > budget:
                    break
                got = self._run_chunk(job)
                if got is None:     # dispatch raised; job released/pool reset
                    continue
                budget -= got
                ingested += got
        self.max_prefill_tokens_per_tick = max(
            self.max_prefill_tokens_per_tick, ingested)
        return ingested

    def _prefill_tick_drr(self, budget: int) -> int:
        """Deficit round-robin across pending prefill jobs: every job earns
        a quantum of chunk-token credit per tick (carry capped at 2x the
        tick budget) and spends it in rotation, so K concurrent prompts
        interleave at chunk granularity instead of the head job draining
        the whole budget every tick until it completes. The rotation start
        advances each tick so leftover budget is not always offered to the
        same job first. The per-tick budget (head-of-line bound) is
        unchanged — DRR only redistributes it."""
        ingested = 0
        q = self.policy.drr_quantum or max(1, budget // len(self._jobs))
        for job in self._jobs:
            job.deficit = min(job.deficit + q, 2 * self.prefill_chunk_tokens)
        self._drr_cursor += 1
        while budget > 0 and self._jobs:
            n = len(self._jobs)
            order = [self._jobs[(self._drr_cursor + k) % n] for k in range(n)]
            ran = False
            for job in order:
                if budget <= 0 or job not in self._jobs:
                    continue        # completed/released by an earlier chunk
                C = job.plan[job.idx]
                if C > budget or C > job.deficit:
                    continue
                got = self._run_chunk(job)
                ran = True
                if got is None:     # failure path mutated the job list:
                    break           # rebuild the rotation from live state
                job.deficit -= got
                budget -= got
                ingested += got
            if not ran:
                break               # nobody could spend: credit accrues
        return ingested

    def _run_chunk(self, job: _PrefillJob) -> Optional[int]:
        """Dispatch ``job``'s next bucketed chunk; on the final chunk,
        splice-and-start the group. Returns the chunk length ingested, or
        None when the dispatch raised — the job was released (or the whole
        poisoned pool reset) and the caller must re-read the job list."""
        C = job.plan[job.idx]
        K = len(job.slots)
        toks = jnp.asarray(job.prompts[:, job.filled:job.filled + C])
        t0 = self.metrics.now()
        try:
            if self.incremental_splice:
                self._note_prefill_trace(False, K, C)
                batch = {
                    "tokens": toks,
                    "bt": jnp.asarray(self._bt_host[job.slots]),
                    "start": jnp.asarray(job.tail_start + job.filled,
                                         jnp.int32),
                    "floor": jnp.asarray(job.write_floor, jnp.int32),
                    **self._prefill_extras(K)}
                logits, self.cache = self._chunk_paged_fn()(
                    self.params, self.cache, batch)
            else:
                # a prefix-seeded job already has its transient cache
                # (gathered from shared pages): every chunk continues
                first = job.cache is None
                self._note_prefill_trace(first, K, C)
                batch = {"tokens": toks, **self._prefill_extras(K)}
                if first:
                    logits, job.cache = self._chunk_fn(True)(self.params,
                                                             batch)
                else:
                    logits, job.cache = self._chunk_fn(False)(
                        self.params, job.cache, batch)
            jax.block_until_ready(logits)
        except Exception as err:  # noqa: BLE001 — released, not resumed
            log.exception("prefill chunk failed for rids %s; releasing "
                          "the job", [r.rid for r in job.reqs])
            self.prefill_failures += 1
            # the incremental dispatch DONATES the resident cache: a
            # failure at EXECUTION time (not trace time) may have
            # consumed or poisoned the shared pools every other live
            # slot reads. Check BEFORE release_job — its _finish writes
            # into the cache and would raise on dead buffers — and fail
            # over to a fresh pool instead of crashing the next tick.
            if self.incremental_splice and not self._cache_healthy():
                self._reset_poisoned_cache(err)
            else:
                self.release_job(job, error=err)
            return None
        self.metrics.on_prefill_chunk(K * C, self.metrics.now() - t0)
        self.max_transient_cache_bytes = max(
            self.max_transient_cache_bytes, self.transient_cache_bytes())
        job.idx += 1
        job.filled += C
        if job.idx == len(job.plan):
            self._jobs.remove(job)
            self._splice_and_start(
                job.slots, job.reqs,
                None if self.incremental_splice else job.cache, logits,
                write_floor=job.write_floor,
                prefix_plans=job.prefix_plans)
        return C

    def _splice_and_start(self, slot_ids, reqs, rcache, logits, *,
                          write_floor: int = 0, prefix_plans=None):
        """Complete a group prefill: land its K/V in the resident cache,
        sample each request's first token from the prefill logits, and flip
        the group to RUNNING.

        ``rcache`` is the group's transient request cache (dense row scatter
        or paged page scatter — other slots untouched bit-for-bit), or None
        on the INCREMENTAL path, where every chunk already spliced its rows
        into the group's pages and completion only flips the group's
        ``pos`` from the INACTIVE sentinel to prompt_len.

        Prefix caching rides the same scatter: rows below ``write_floor``
        (aliased immutable full pages) are dropped, while a partial hit's
        gathered rows land in the FRESH page standing in for the shared
        source — the copy-on-write copy costs no extra device pass. After
        the splice the group's freshly computed prompt pages (now complete
        and never written again) register in the prefix index."""
        slots = jnp.asarray(np.array(slot_ids, np.int32))
        if rcache is None:
            plens = jnp.asarray([len(r.prompt) for r in reqs], jnp.int32)
            self.cache["pos"] = self.cache["pos"].at[slots].set(plens)
        elif self.paged:
            self.cache = self.backend.insert_rows(
                self.cache, rcache, slots,
                jnp.asarray(self._phys_rows(slot_ids, write_floor)))
        else:
            self.cache = self.backend.insert_rows(self.cache, rcache, slots)
        if self.prefix_index is not None and prefix_plans is not None:
            for slot, req, plan in zip(slot_ids, reqs, prefix_plans):
                self.prefix_index.register(plan, self.slot_pages[slot],
                                           len(req.prompt))
        toks = self._sample_rows(logits)
        for i, (slot, req) in enumerate(zip(slot_ids, reqs)):
            req.state = RequestState.RUNNING
            if req.rid in self._cancel_at_splice:   # grouped mid-prefill
                self._cancel_at_splice.discard(req.rid)   # cancel lands here
                self._finish(slot, RequestState.CANCELLED)
                continue
            if req.gen_len <= 0:                 # nothing to generate
                self._finish(slot)
                continue
            # a request resumed after preemption already streamed tokens:
            # this splice's sample is its NEXT token, not its first —
            # on_first_token is idempotent and would silently drop it
            resumed = bool(req.tokens)
            req.tokens.append(int(toks[i]))
            self.cur_token[slot, 0] = int(toks[i])
            if resumed:
                self.metrics.on_token(req.rid)
            else:
                self.metrics.on_first_token(req.rid)
            if req.done:
                self._finish(slot)

    def _finish(self, slot: int, state: RequestState = RequestState.DONE):
        """Retire a slot: park its cache position at the INACTIVE_POS
        sentinel (decode drops its writes from now on — freed rows stay
        bit-stable), zero its feedback token, and return its pages to the
        free list. Idempotent: a second call is a no-op. ``state`` records
        WHY the slot retired (DONE / FAILED / CANCELLED) — the resource
        reclamation is identical."""
        req = self.slot_req[slot]
        if req is None:
            return
        req.state = state
        if state is RequestState.DONE:
            self.metrics.on_done(req.rid)
        else:                       # FAILED/CANCELLED: finalized, not served
            self.metrics.on_aborted(req.rid)
        self.slot_req[slot] = None
        self.cur_token[slot, 0] = 0
        self.cache["pos"] = self.cache["pos"].at[slot].set(INACTIVE_POS)
        if self.paged:
            self.allocator.release(self.slot_pages[slot])
            self.slot_pages[slot] = []
            self._bt_host[slot, :] = -1
            self.cache["block_tables"] = jnp.asarray(self._bt_host)

    def _cache_healthy(self) -> bool:
        """True when every resident-cache buffer is live and readable. A
        failed donated dispatch leaves either deleted input buffers (the
        exception fired mid-execution) or error-poisoned output buffers
        (async backends surface execution errors on first access)."""
        try:
            jax.block_until_ready(self.cache["k"])
        except Exception:  # noqa: BLE001 — any access error means poisoned
            return False
        return not any(getattr(leaf, "is_deleted", lambda: False)()
                       for leaf in jax.tree.leaves(self.cache))

    def _reset_poisoned_cache(self, error):
        """Scorched-earth failover after a donated dispatch destroyed the
        shared paged cache: every in-flight request is FAILED (their K/V
        lived in the poisoned pools — there is nothing to resume), the
        allocator and prefix index rebuild from scratch (index entries
        would otherwise point at zeroed pages), and a FRESH pool cache is
        installed so queued and future requests keep being served. Pure
        host-side bookkeeping plus one cache re-init; never touches the
        poisoned buffers."""
        log.error("resident paged cache lost to a failed donated dispatch; "
                  "failing %d in-flight request(s) and rebuilding the pool",
                  self.active)
        for job in list(self._jobs):        # PREFILLING jobs not yet failed
            self._jobs.remove(job)
            job.cache = None
        msg = f"cache lost to failed dispatch: {error!r}"
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            req.state = RequestState.FAILED
            req.error = msg
            self.metrics.on_aborted(req.rid)
            self.slot_req[slot] = None
            self.cur_token[slot, 0] = 0
        self._cancel_at_splice.clear()
        self.allocator = PageAllocator(self.num_pages)
        if self.prefix_index is not None:
            self.prefix_index = PrefixIndex(self.allocator, self.page_size)
        self.slot_pages = [[] for _ in range(self.batch_slots)]
        self._bt_host[:] = -1
        self._defer_state = None
        self.cache = self.backend.init_cache(
            self.model, self.batch_slots, self.s_max, self.cache_dtype)

    def release_job(self, job: _PrefillJob, error=None,
                    state: RequestState = RequestState.FAILED):
        """Abort an in-flight prefill job and reclaim EVERYTHING it holds:
        the group's slots, reserved pages (including aliased prefix-page
        refcounts — released through the same ``_finish`` path completion
        uses), the transient request cache, and the feedback tokens.
        Invoked by ``_prefill_tick`` when a chunk dispatch raises and by
        :meth:`cancel` — before this path existed, an errored or cancelled
        mid-prefill job held its pages until process exit."""
        if job in self._jobs:
            self._jobs.remove(job)
        job.cache = None
        msg = "cancelled" if state is RequestState.CANCELLED else repr(error)
        for slot, req in zip(job.slots, job.reqs):
            req.error = msg
            self._cancel_at_splice.discard(req.rid)
            self._finish(slot, state)

    def cancel(self, rid: int) -> bool:
        """Cancel a request; returns True if it was still live. QUEUED
        requests are marked and skipped at the next admission (lazy heap
        removal); a PREFILLING request aborts immediately when it is its
        job's only member (``release_job``) and at group completion
        otherwise (the splice retires its slot without sampling — the
        group's batch shape cannot change mid-stream); RUNNING requests
        retire their slot on the spot. Either way every reserved page and
        aliased prefix refcount is released."""
        req = self.requests.get(rid)
        if req is None or req.state in (RequestState.DONE,
                                        RequestState.FAILED,
                                        RequestState.CANCELLED):
            return False
        if req.state is RequestState.QUEUED:
            req.state = RequestState.CANCELLED
            req.error = "cancelled"
            self.metrics.on_aborted(rid)
            return True
        if req.state is RequestState.PREFILLING:
            job = next((j for j in self._jobs if req in j.reqs), None)
            if job is None:                  # no chunk job (scan-mode window)
                self._finish(req.slot, RequestState.CANCELLED)
                req.error = "cancelled"
                return True
            if len(job.reqs) == 1:
                self.release_job(job, state=RequestState.CANCELLED)
            else:
                req.error = "cancelled"
                self._cancel_at_splice.add(rid)
            return True
        self._finish(req.slot, RequestState.CANCELLED)   # RUNNING
        req.error = "cancelled"
        return True

    def transient_cache_bytes(self) -> int:
        """Device bytes held RIGHT NOW by in-flight prefill jobs' transient
        request caches. On the incremental-splice path this is 0 by
        construction — chunks write straight into the resident pools and
        only one chunk's activations are ever live — which is the
        acceptance bound the bench records (``max_transient_cache_bytes``
        tracks the high-water mark across a run)."""
        total = 0
        for job in self._jobs:
            if job.cache is not None:
                total += int(sum(l.size * l.dtype.itemsize
                                 for l in jax.tree.leaves(job.cache)))
        return total

    def assert_page_invariants(self):
        """Walk the allocator / block-table / prefix-index bookkeeping and
        raise on any violated invariant: no page simultaneously free and
        referenced, every live block-table or index page holds >= 1
        reference, and nothing leaks (free + held partitions the pool).
        Host-side only — tests call this per tick; release_job keeps it
        true through failures and cancellations."""
        if not self.paged:
            return
        free = set(self.allocator._free)
        held = self.allocator.held
        assert not (free & held), f"pages both free and referenced: {free & held}"
        assert free | held == set(range(self.num_pages)), "page leaked"
        live = {pg for pages in self.slot_pages for pg in pages}
        assert not (free & live), "page both free and in a live block table"
        for pg in live:
            assert self.allocator.refcount(pg) >= 1, f"live page {pg} unref'd"
        if self.prefix_index is not None:
            idx = set(self.prefix_index.pages)
            assert not (free & idx), "page both free and in the prefix index"
            for pg in idx:
                assert self.allocator.refcount(pg) >= 1, \
                    f"indexed page {pg} unref'd"
        # per-page metadata invariants (int8: scale tables well-formed)
        self.backend.check_page_meta(self.cache, self.num_pages)

    @property
    def running(self) -> int:
        """Slots actively decoding (excludes slots still being prefilled)."""
        return sum(1 for r in self.slot_req
                   if r is not None and r.state == RequestState.RUNNING)

    def step(self) -> int:
        """One engine tick: admit waiting requests, ingest at most one
        prefill-chunk BUDGET of prompt work (the interleave that bounds
        decode inter-token latency under long-prompt ingestion), then one
        decode tick for every RUNNING slot; returns #active after the tick.

        With ``policy.max_consecutive_prefill_ticks`` set, the decode-
        starvation guard skips the prefill interleave for one tick after N
        consecutive ticks in which prefill dispatched work while slots were
        decoding — under sustained admission pressure the per-tick chunk
        budget alone bounds each tick's prefill share, but nothing else
        guarantees decode ever gets a prefill-free tick."""
        self.admit()
        pol = self.policy
        if (pol.max_consecutive_prefill_ticks > 0 and self._jobs
                and self.running > 0
                and self._consec_prefill_ticks
                >= pol.max_consecutive_prefill_ticks):
            self._consec_prefill_ticks = 0
            self.metrics.on_starvation_skip()
        else:
            ingested = self._prefill_tick()
            if ingested > 0 and self.running > 0:
                self._consec_prefill_ticks += 1
            else:
                self._consec_prefill_ticks = 0
        if self.running:
            batch = {"token": jnp.asarray(self.cur_token),
                     **self._decode_extras()}
            logits, self.cache = self._decode(self.params, self.cache, batch)
            self.metrics.on_decode_step()
            nxt = self._sample_rows(logits)
            for slot, req in enumerate(self.slot_req):
                if req is None or req.state != RequestState.RUNNING:
                    continue
                req.tokens.append(int(nxt[slot]))
                self.cur_token[slot, 0] = int(nxt[slot])
                self.metrics.on_token(req.rid)
                if req.done:
                    self._finish(slot)
        self.admit()        # refill freed slots/pages on the SAME tick
        return self.active

    def drain_completed(self) -> List[Request]:
        """Remove and return finished requests (the engine otherwise retains
        every request — prompt and token list — for its lifetime; a
        long-running deployment should drain periodically). Metric records
        are kept so summary() percentiles stay complete."""
        done = [r for r in self.requests.values()
                if r.state in (RequestState.DONE, RequestState.FAILED,
                               RequestState.CANCELLED)]
        for r in done:
            del self.requests[r.rid]
        return done

    def run(self) -> dict:
        """Serve until queue and slots drain; returns the metrics summary."""
        self.metrics.on_start()
        while self.scheduler.waiting or self.active:
            self.step()
        self.metrics.on_stop()
        return self.metrics.summary()
