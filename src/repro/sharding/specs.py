"""Logical-axis sharding: model code names *logical* axes; a rule table maps
them to mesh axes. Keeps model definitions mesh-agnostic (single-pod, multi-pod,
pipeline) — the same pattern MaxText/flax-linen use, reimplemented standalone.

Usage::

    with use_mesh(mesh, DEFAULT_RULES):
        y = shard(x, "batch", "seq", None)   # inside jit: with_sharding_constraint

Outside a mesh context ``shard`` is the identity, so models run untouched in
single-device tests.
"""
from __future__ import annotations

import contextlib
import contextvars
import functools
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Logical = Union[str, None, Tuple[str, ...]]

# logical axis -> mesh axis (or tuple of mesh axes). Entries whose mesh axes
# are absent from the active mesh are dropped at resolution time.
DEFAULT_RULES: Tuple[Tuple[str, Logical], ...] = (
    ("batch", ("pod", "data")),      # data parallel over pod x data
    ("seq_sp", "model"),             # sequence parallelism at layer boundaries
    ("heads", "model"),              # tensor parallel attention heads
    ("kv_heads", "model"),
    ("d_ff", "model"),               # tensor parallel MLP
    ("vocab", "model"),
    ("expert", "model"),             # expert parallel
    ("fsdp", "data"),                # ZeRO-3 weight sharding
    ("kv_seq", None),                # KV-cache sequence dim (kept unsharded)
    ("stage", "pod"),                # pipeline axis (when PP enabled)
)

# Rules for pure-DP pods (default production config): identical to DEFAULT_RULES.
# Rules for pipeline-parallel pods: batch only over "data", stage over "pod".
PIPELINE_RULES: Tuple[Tuple[str, Logical], ...] = tuple(
    ("batch", "data") if k == "batch" else (k, v) for k, v in DEFAULT_RULES
)

# Serving rules: weights sharded over the model axis ONLY (replicated across
# data) — no optimizer state exists at serve time, so ZeRO-3 'fsdp' sharding
# buys nothing and costs a full per-layer weight all-gather every step; with
# model-only sharding each chip streams its resident 1/TP weight slice.
# (hillclimb A iteration 1 — EXPERIMENTS.md §Perf.)
SERVE_RULES: Tuple[Tuple[str, Logical], ...] = tuple(
    (k, None) if k == "fsdp" else (k, v) for k, v in DEFAULT_RULES
)

# Tensor-parallel SERVING rules (the serve engine's mesh trace context):
# every logical axis resolves to None, so each existing with_sharding_
# constraint in model code becomes a replicate — the entire decode/prefill
# dataflow outside the head-sharded attention core stays replicated. That is
# deliberate, not a placeholder: replicated projections + per-head-
# independent attention + an all-gather of head outputs before the output
# projection make a tp>1 tick BITWISE identical to tp=1 (no float sum is
# ever split across shards), which is the anchor the tp equivalence tests
# gate on. The KV pool is the one sharded resident — its placement goes
# through TP_POOL_RULES below, and the kernel's head slicing through
# shard_map (see kernels/paged_attention.py::paged_attention_head_sharded).
TP_SERVE_RULES: Tuple[Tuple[str, Logical], ...] = tuple(
    (k, None) for k, _ in DEFAULT_RULES
)

# Rules used ONLY to place the paged KV pool: the kv-head axis shards over
# 'model'; page geometry (page ids, page rows) is shard-invariant so block
# tables and the host-side allocator/prefix index stay replicated.
TP_POOL_RULES: Tuple[Tuple[str, Logical], ...] = (("kv_heads", "model"),)

# Logical axes of one paged K/V pool leaf (L, num_pages, page_size, KV, hd):
# only the kv-head axis is shardable — every page holds all of a shard's
# kv-head slice for its rows, so page indices mean the same thing on every
# shard and the block tables replicate untouched.
KV_POOL_AXES: Tuple[Logical, ...] = (None, None, None, "kv_heads", None)


class _Ctx:
    def __init__(self, mesh: Optional[Mesh], rules):
        self.mesh = mesh
        self.rules = dict(rules) if rules else {}


_CTX: contextvars.ContextVar[_Ctx] = contextvars.ContextVar(
    "shard_ctx", default=_Ctx(None, DEFAULT_RULES)
)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules=DEFAULT_RULES):
    token = _CTX.set(_Ctx(mesh, rules))
    try:
        # NamedShardings built here carry the mesh explicitly, so no global
        # jax mesh context is required; `with mesh:` also works but is not
        # needed for with_sharding_constraint/jit in_shardings.
        yield mesh
    finally:
        _CTX.reset(token)


def active_mesh() -> Optional[Mesh]:
    return _CTX.get().mesh


def _resolve_one(logical: Logical, mesh: Mesh) -> Logical:
    if logical is None:
        return None
    rules = _CTX.get().rules
    mapped = rules.get(logical, None) if isinstance(logical, str) else logical
    if mapped is None:
        return None
    if isinstance(mapped, str):
        mapped = (mapped,)
    present = tuple(a for a in mapped if a in mesh.axis_names)
    if not present:
        return None
    return present if len(present) > 1 else present[0]


def resolve(*logical_axes: Logical) -> P:
    """Resolve logical axes to a PartitionSpec under the active mesh."""
    mesh = active_mesh()
    if mesh is None:
        return P(*([None] * len(logical_axes)))
    return P(*(_resolve_one(a, mesh) for a in logical_axes))


def named_sharding(*logical_axes: Logical) -> Optional[NamedSharding]:
    mesh = active_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, resolve(*logical_axes))


def axis_size(logical: Logical) -> int:
    """Product of mesh-axis sizes a logical axis resolves to (1 if unmapped)."""
    mesh = active_mesh()
    if mesh is None:
        return 1
    resolved = _resolve_one(logical, mesh)
    if resolved is None:
        return 1
    if isinstance(resolved, str):
        resolved = (resolved,)
    size = 1
    for a in resolved:
        size *= mesh.shape[a]
    return size


def _fit_axes(shape, logical_axes):
    """Drop logical axes whose resolved mesh size does not divide the dim —
    the shape-aware fallback (replicate) for non-divisible dims (e.g. kv=5
    heads on a 16-way model axis, or batch=1 long-context cells)."""
    out = []
    for dim, ax in zip(shape, logical_axes):
        out.append(ax if (ax is not None and dim % max(axis_size(ax), 1) == 0
                          and axis_size(ax) > 1) else None)
    return tuple(out)


def shard(x, *logical_axes: Logical):
    """with_sharding_constraint against the active mesh (identity if none).
    Non-divisible axes are dropped (replicated) rather than erroring."""
    mesh = active_mesh()
    if mesh is None:
        return x
    if x.ndim != len(logical_axes):
        raise ValueError(
            f"rank mismatch: array rank {x.ndim} vs {len(logical_axes)} logical axes"
        )
    fitted = _fit_axes(x.shape, logical_axes)
    return jax.lax.with_sharding_constraint(x, named_sharding(*fitted))


def sharding_for(shape, logical_axes) -> Optional[NamedSharding]:
    """Shape-aware ``named_sharding`` for ONE array: logical axes whose mesh
    size does not divide the dim are dropped (replicated). None if no mesh."""
    mesh = active_mesh()
    if mesh is None:
        return None
    return named_sharding(*_fit_axes(shape, logical_axes))


def replicate(x):
    """Constrain x fully replicated under the active mesh (identity if none).

    The tensor-parallel serve path calls this on the head-sharded attention
    output right BEFORE the output projection: it is the one all-gather of
    the tp decode tick, and putting it before (not after, as a psum of
    partial projections) keeps the wo contraction un-split and the tick
    bitwise equal to tp=1."""
    mesh = active_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*([None] * x.ndim))))


# Mesh axis the serve engine uses for tensor parallelism. Checked directly
# against mesh.axis_names (not through the rules table) because the tp serve
# trace context deliberately maps every logical axis to None — the kernel's
# head slicing happens inside shard_map, not via GSPMD constraints.
TP_AXIS = "model"


def head_shard_axis(num_heads: int, num_kv_heads: int):
    """Resolve the head-sharding decision for a paged-attention call site.

    Returns ``(mesh, axis_name)`` when the active mesh has a >1-sized
    ``TP_AXIS`` that divides BOTH head counts (each shard then owns whole
    GQA groups: kv head ``k`` and its query heads ``k*G..k*G+G-1`` land on
    the same shard, so the kernel's ``h // G`` pool indexing stays local).
    Returns ``(None, None)`` otherwise — callers fall back to the exact
    single-device dispatch, keeping non-divisible configs correct."""
    mesh = active_mesh()
    if mesh is None or TP_AXIS not in mesh.axis_names:
        return None, None
    tp = mesh.shape[TP_AXIS]
    if tp <= 1 or num_kv_heads % tp or num_heads % tp:
        return None, None
    return mesh, TP_AXIS


def latent_head_shard_axis(num_heads: int):
    """``head_shard_axis`` for the MLA latent path: the latent pool has no
    kv-head axis (every head reads the same compressed rows), so only the
    query-head count needs to divide the mesh. Returns ``(mesh, axis_name)``
    when the active mesh has a >1-sized ``TP_AXIS`` dividing ``num_heads``,
    else ``(None, None)`` (callers fall back to the exact replicated
    dispatch)."""
    mesh = active_mesh()
    if mesh is None or TP_AXIS not in mesh.axis_names:
        return None, None
    tp = mesh.shape[TP_AXIS]
    if tp <= 1 or num_heads % tp:
        return None, None
    return mesh, TP_AXIS


def serve_trace(mesh: Optional[Mesh], fn):
    """Wrap a step function so it TRACES inside the tensor-parallel serving
    mesh context (identity when mesh is None): the with-block runs at trace
    time, so every shard/replicate/head_shard_axis call in model code
    resolves against this mesh. :data:`TP_SERVE_RULES` maps every logical
    axis to None — the whole dataflow stays replicated except the cache
    pool (committed sharded by the KV backend) and the attention cores'
    shard_map wrappers; that split is what keeps tp>1 ticks bitwise equal
    to tp=1."""
    if mesh is None:
        return fn

    @functools.wraps(fn)
    def wrapped(*args, **kwargs):
        with use_mesh(mesh, TP_SERVE_RULES):
            return fn(*args, **kwargs)
    return wrapped


def serve_mesh(tp: int) -> Mesh:
    """Build the canonical 1-axis serving mesh over the first ``tp`` local
    devices. The axis is named :data:`TP_AXIS`; keeping the construction
    here means callers (notably the serve engine) never spell the axis name
    themselves — the backend seam and these helpers own every mesh
    internal."""
    return jax.make_mesh((tp,), (TP_AXIS,))


def replicate_params(params, mesh: Optional[Mesh]):
    """Place a parameter pytree fully replicated on ``mesh`` (identity when
    mesh is None). Replicated weights keep every contraction — in particular
    the output projection after the attention all-gather — un-split across
    shards, which is what makes a tp>1 serve tick bitwise equal to tp=1."""
    if mesh is None:
        return params
    return jax.device_put(params, NamedSharding(mesh, P()))


def _is_logical_leaf(v):
    return isinstance(v, tuple) and all(
        isinstance(a, (str, type(None), tuple)) for a in v)


def spec_tree(tree_of_logical):
    """Map a pytree of logical-axis tuples to NamedShardings (for in_shardings)."""
    return jax.tree.map(lambda ax: named_sharding(*ax), tree_of_logical,
                        is_leaf=_is_logical_leaf)


def shardings_for(tree_of_logical, sds_tree):
    """Shape-aware spec_tree: builds NamedShardings per leaf, dropping logical
    axes whose mesh size does not divide that leaf's dim (pjit *arguments*
    require exact divisibility, unlike internal constraints)."""
    flat_log, _ = jax.tree.flatten(tree_of_logical, is_leaf=_is_logical_leaf)
    flat_sds, treedef = jax.tree.flatten(sds_tree)
    assert len(flat_log) == len(flat_sds), (len(flat_log), len(flat_sds))
    out = []
    for ax, s in zip(flat_log, flat_sds):
        fitted = _fit_axes(s.shape, ax)
        out.append(named_sharding(*fitted))
    return jax.tree.unflatten(treedef, out)
