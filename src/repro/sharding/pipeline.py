"""Pipeline parallelism over the 'pod' (or any) mesh axis: GPipe schedule via
shard_map + collective_permute.

Each pipeline stage owns L/P contiguous layers (stage-stacked params). The
microbatch loop runs as a lax.scan over (n_micro + P - 1) ticks; at each tick
a stage processes the activation it holds and collective_permutes it to the
next stage. Bubble fraction = (P-1)/(n_micro+P-1), the GPipe bound.

This is the inter-POD alternative to pure DP when a model's layers do not fit
a single pod's HBM even fully sharded: `PIPELINE_RULES` in sharding/specs.py
re-maps 'batch' to the data axis only, and stage params get the 'stage' axis.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def _bcast_from(x, axis_name, src):
    """Broadcast x from shard `src` along axis_name to all shards."""
    idx = jax.lax.axis_index(axis_name)
    masked = jnp.where(idx == src, x, jnp.zeros_like(x))
    return jax.lax.psum(masked, axis_name)


def make_pipeline(mesh, stage_fn: Callable, params_spec=None, *,
                  stage_axis: str = "pod", n_micro: int):
    """GPipe pipeline for stage-stacked params.

    stage_fn(stage_params, x) -> x applies ONE stage's layers.

    Returns pipe(stage_params, x_micro):
      stage_params leaves: [P, ...] sharded over stage_axis (leading dim)
      x_micro: (n_micro, B_micro, ...) replicated over stage_axis
      -> (n_micro, B_micro, ...) final-stage outputs (valid on every shard)
    """
    n_stages = mesh.shape[stage_axis]

    def per_stage(params_stage, x_micro):
        params_local = jax.tree.map(lambda t: t[0], params_stage)
        stage_id = jax.lax.axis_index(stage_axis)
        n_ticks = n_micro + n_stages - 1
        buf = jnp.zeros_like(x_micro[0])
        outs = jnp.zeros_like(x_micro)

        def tick(carry, t):
            buf, outs = carry
            inject = jnp.where(t < n_micro, t, 0)
            x_in = jnp.where(stage_id == 0, x_micro[inject].astype(buf.dtype),
                             buf)
            active = (t - stage_id >= 0) & (t - stage_id < n_micro)
            y = stage_fn(params_local, x_in)
            y = jnp.where(active, y, x_in)
            # last stage records its finished microbatch
            mb = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            write = (active & (stage_id == n_stages - 1)).astype(outs.dtype)
            cur = jax.lax.dynamic_index_in_dim(outs, mb, 0, keepdims=False)
            upd = write * y + (1 - write) * cur
            outs = jax.lax.dynamic_update_index_in_dim(outs, upd, mb, 0)
            # shift activations to the next stage (ring; wraparound unused)
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf = jax.lax.ppermute(y, stage_axis, perm)
            return (buf, outs), None

        (_, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(n_ticks))
        return _bcast_from(outs, stage_axis, n_stages - 1)

    if params_spec is None:
        params_spec = P(stage_axis)
    return shard_map(per_stage, mesh=mesh,
                     in_specs=(params_spec, P()),
                     out_specs=P(), check_rep=False)


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)
