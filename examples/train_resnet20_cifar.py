"""The paper's end-to-end experiment, reproduced: train ResNet20 on (synthetic)
CIFAR, fold BN, quantize to the paper's 16-bit fixed point AND int8, measure
accuracy drop, and run the four-strategy FPS ladder through the calibrated
performance model — printing our predictions against the paper's Fig. 6.

Run:  PYTHONPATH=src python examples/train_resnet20_cifar.py [--steps 300]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MemoryStrategy
from repro.configs.resnet20_cifar import CONFIG as FULL_CFG, ResNetConfig
from repro.core import perfmodel as pm
from repro.core.dataflow import Gemm
from repro.core.quantize import dequantize_params, fixed_point_tree, quantize_params
from repro.data.synthetic import synthetic_cifar
from repro.models import resnet
from repro.models.resnet import conv_layer_shapes
from repro.optim.adamw import AdamW, apply_updates


def accuracy(cfg, params, xs, ys, folded=False):
    logits = resnet.forward(params, cfg, jnp.asarray(xs), folded=folded)
    return float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(ys)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--width", type=int, default=8,
                    help="base width (paper: 16; 8 is CPU-fast)")
    args = ap.parse_args()

    cfg = ResNetConfig(widths=(args.width, args.width * 2, args.width * 4))
    params = resnet.init(cfg, jax.random.PRNGKey(0))
    opt = AdamW(learning_rate=3e-3, weight_decay=1e-4)
    opt_state = opt.init(params)
    xs, ys = synthetic_cifar(4096, seed=1)
    xt, yt = synthetic_cifar(1024, seed=2)

    @jax.jit
    def step(params, opt_state, bx, by):
        def loss_fn(p):
            logits = resnet.forward(p, cfg, bx)
            onehot = jax.nn.one_hot(by, cfg.num_classes)
            return -jnp.mean(jnp.sum(onehot * jax.nn.log_softmax(logits), -1))
        loss, grads = jax.value_and_grad(loss_fn)(params)
        updates, opt_state, _ = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, loss

    bs = 128
    t0 = time.time()
    for i in range(args.steps):
        j = (i * bs) % (len(ys) - bs)
        params, opt_state, loss = step(params, opt_state, xs[j:j + bs], ys[j:j + bs])
        if (i + 1) % 50 == 0:
            print(f"step {i+1}: loss {float(loss):.4f}")
    print(f"trained {args.steps} steps in {time.time()-t0:.1f}s")

    # ---- quantization accuracy (the paper's 92% -> 90% experiment) ----
    folded = resnet.fold_bn(params)
    acc32 = accuracy(cfg, folded, xt, yt, folded=True)
    acc16 = accuracy(cfg, fixed_point_tree(folded), xt, yt, folded=True)
    acc8 = accuracy(cfg, dequantize_params(quantize_params(folded), jnp.float32),
                    xt, yt, folded=True)
    print(f"\naccuracy: fp32 {acc32:.3f} | fixed16 {acc16:.3f} "
          f"(drop {acc32-acc16:+.3f}) | int8 {acc8:.3f} (drop {acc32-acc8:+.3f})")
    print(f"paper:    fp32 0.92  | fixed16 0.90  (drop +0.020)")

    # ---- measured CPU inference FPS (jitted, batch 64) ----
    infer = jax.jit(lambda p, x: resnet.forward(p, cfg, x, folded=True))
    xb = jnp.asarray(xt[:64])
    infer(folded, xb).block_until_ready()
    t0 = time.time()
    for _ in range(20):
        infer(folded, xb).block_until_ready()
    fps = 64 * 20 / (time.time() - t0)
    print(f"\nmeasured CPU inference: {fps:.0f} FPS (batch 64, jitted)")

    # ---- the paper's FPS ladder through the calibrated perf model ----
    gemms = [Gemm(n, m, k, nn, in_elems=m * k // 9 if k % 9 == 0 else m * k,
                  out_elems=m * nn)
             for (n, m, k, nn) in conv_layer_shapes(FULL_CFG, batch=1)]
    fit = pm.calibrate(gemms)
    print(f"\nZCU104 ladder (calibrated model vs paper Fig. 6):")
    print(f"  {'strategy':24s} {'model FPS':>10s} {'paper FPS':>10s} {'err':>7s}")
    for r in pm.ladder(gemms, fit=fit):
        tgt = pm.PAPER_FPS[r.strategy]
        print(f"  {r.strategy:24s} {r.fps:10.2f} {tgt:10.2f} "
              f"{100*(r.fps-tgt)/tgt:+6.1f}%")
    print(f"\npaper GOP/s 21.12 @ 5.21 W; model final rung "
          f"{pm.ladder(gemms, fit=fit)[-1].gops:.2f} GOP/s")


if __name__ == "__main__":
    main()
