"""Serving example: continuous-batched decode across mixed request lengths,
comparing bf16 vs int8-quantized weights (the paper's C5 on the serving path).

Run:  PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-7b
"""
import argparse
import time

import numpy as np

from repro.launch.serve import ServeConfig, Server


def bench(sc: ServeConfig) -> float:
    server = Server(sc)
    rng = np.random.default_rng(0)
    for _ in range(sc.batch_slots):
        server.add_request(rng.integers(0, server.cfg.vocab_size, sc.prompt_len),
                           sc.gen_len)
    t0 = time.time()
    ticks = 0
    while not all(server.slot_free):
        server.step_all()
        ticks += 1
    dt = time.time() - t0
    return sc.batch_slots * sc.gen_len / dt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-7b")
    ap.add_argument("--gen-len", type=int, default=24)
    args = ap.parse_args()

    base = dict(arch=args.arch, reduced=True, batch_slots=4, s_max=64,
                requests=4, prompt_len=6, gen_len=args.gen_len)
    tps_bf16 = bench(ServeConfig(**base))
    tps_int8 = bench(ServeConfig(**base, quantize_int8=True))
    print(f"{args.arch}: bf16 {tps_bf16:.1f} tok/s | int8-weights "
          f"{tps_int8:.1f} tok/s (CPU; on TPU int8 halves the weight-stream "
          f"memory term — see EXPERIMENTS.md §Perf)")


if __name__ == "__main__":
    main()
