"""Post-training quantization walkthrough: per-channel int8 + fixed-16 on an
LM, with per-layer error report and a quantized-vs-float logits comparison —
the paper's quantization methodology (C5) applied to the LM zoo.

Run:  PYTHONPATH=src python examples/quantize_ptq.py --arch minicpm-2b
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.quantize import (dequantize_params, fixed_point_tree,
                                 quantization_error, quantize_params,
                                 quantized_bytes)
from repro.models.registry import get_model, reduced_config


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    args = ap.parse_args()

    cfg = reduced_config(configs.get_config(args.arch))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab_size)

    ref_logits, _ = model.forward(params, toks, compute_dtype=jnp.float32)

    qp = quantize_params(params)
    fp_bytes = sum(x.nbytes for x in jax.tree.leaves(params))
    print(f"{args.arch}: fp32 {fp_bytes/1e6:.1f} MB -> int8 "
          f"{quantized_bytes(qp)/1e6:.1f} MB "
          f"({fp_bytes/quantized_bytes(qp):.2f}x smaller)")

    errs = quantization_error(params, qp)
    worst = sorted(errs.items(), key=lambda kv: -kv[1])[:5]
    print("worst per-layer relative L2 error:")
    for name, e in worst:
        print(f"  {e:.5f}  {name}")

    for name, tree in [("int8", dequantize_params(qp, jnp.float32)),
                       ("fixed16", fixed_point_tree(params))]:
        logits, _ = model.forward(tree, toks, compute_dtype=jnp.float32)
        real = slice(0, cfg.vocab_size)
        top1_match = float(jnp.mean(
            jnp.argmax(logits[..., real], -1) == jnp.argmax(ref_logits[..., real], -1)))
        err = float(jnp.abs(logits[..., real] - ref_logits[..., real]).max())
        print(f"{name}: top-1 agreement {top1_match:.3f}, max |dlogit| {err:.4f}")


if __name__ == "__main__":
    main()
