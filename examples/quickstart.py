"""Quickstart: the framework in ~60 lines.

1. Pick an assigned architecture, shrink it to CPU scale.
2. Train a few steps on the synthetic pipeline.
3. Plan a layer with the paper's capacity planner (all four strategies).
4. Serve a few tokens through the decode path.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import MemoryStrategy
from repro.core.dataflow import Gemm
from repro.core.planner import plan_gemm
from repro.core.strategies import TPU_V5E, planner_config
from repro.data.synthetic import TokenStream
from repro.launch import steps as steps_mod
from repro.models.registry import get_model, reduced_config
from repro.optim.adamw import AdamW

# ---- 1. model ---------------------------------------------------------
cfg = reduced_config(configs.get_config("qwen2.5-32b"))
model = get_model(cfg)
print(f"arch={cfg.name}  (reduced: {cfg.num_layers}L d={cfg.d_model})")

# ---- 2. train ---------------------------------------------------------
opt = AdamW(learning_rate=1e-3)
state = steps_mod.init_train_state(model, opt, jax.random.PRNGKey(0))
step = jax.jit(steps_mod.make_train_step(model, opt, compute_dtype=jnp.float32,
                                         remat=False))
stream = TokenStream(cfg.vocab_size, batch=4, seq_len=64, seed=0)
for i in range(10):
    batch = {k: jnp.asarray(v) for k, v in stream.batch_at(i).items()}
    state, metrics = step(state, batch)
print(f"train: 10 steps, loss {float(metrics['loss']):.3f}")

# ---- 3. the paper's planner ------------------------------------------
g = Gemm("ffn_up", m=4096, k=5120, n=27648)   # one qwen2.5-32b FFN GEMM
for strat in MemoryStrategy:
    plan = plan_gemm(g, planner_config(strat, TPU_V5E))
    print(f"plan[{strat.value:22s}] tiles={plan.tiling.bm}x{plan.tiling.bk}"
          f"x{plan.tiling.bn} stages={plan.stages} parts={plan.partitions} "
          f"reload={plan.reload:.2f} AI={plan.arithmetic_intensity:.0f} flop/B")

# ---- 4. serve ---------------------------------------------------------
decode = jax.jit(steps_mod.make_decode_step(model, compute_dtype=jnp.float32),
                 donate_argnums=(1,))
cache = model.init_cache(2, 32, jnp.float32)
tok = jnp.array([[1], [2]], jnp.int32)
out = []
for _ in range(8):
    logits, cache = decode(state["params"], cache, {"token": tok})
    tok = jnp.argmax(logits[:, :, : cfg.vocab_size], -1).astype(jnp.int32)
    out.append(int(tok[0, 0]))
print("decode:", out)
print("quickstart OK")
