"""Serving benchmark: batched-prefill engine vs the seed's token-by-token
legacy path (hymba, as in PR 1), a PAGED-vs-DENSE KV cache column (tokens/s
and resident cache bytes) on a full-attention arch, a PREFILL column
(parallel chunked vs teacher-forced scan prefill tokens/s on the
qwen2.5-32b reduced cell), and a PREFIX column (page-level prefix caching
on vs off under shared-header traffic — effective prefill tokens/s,
hit rate, pages shared, COW copies). Writes ``BENCH_serve.json`` next to
the repo root; ``benchmarks/check_bench.py`` gates CI on it.

The engine's win has two mechanical sources, mirroring the paper's ladder:
fewer dispatches (one jitted scan per prefill instead of one dispatch per
prompt token — the paper's instruction/DRAM block overhead) and less compute
(batch-1 prefill instead of stepping the full batch width per prompt token —
the paper's "don't move/compute what you don't need"). The paged column is
the paper's memory-as-first-class-constraint lesson applied to serving. The
prefill column is the paper's loop-width/tiling lever: the scan anchor
teacher-forces decode_step — ONE token of matmul width per sequential step —
while the parallel path computes a whole bucketed chunk per pass at full
matmul width; the acceptance bar is >= 2x prefill tokens/s at
prompt_len >= 128.

Run: PYTHONPATH=src python -m benchmarks.serve_bench [--quick]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

from repro.launch.serve import ServeConfig, run, run_legacy

OUT = pathlib.Path(__file__).resolve().parents[1] / "BENCH_serve.json"

# paged sweep: a full-attention arch (hymba's ring cache is already O(window)
# resident — paging it proves correctness, not memory), a serving-realistic
# per-request bound, and a pool sized to the concurrent workload
PAGED_ARCH = "qwen2.5-32b"
PAGED_S_MAX = 256
PAGE_SIZE = 16


def bench_cell(batch_slots: int, prompt_len: int, *, requests: int,
               gen_len: int, arch: str = "hymba-1.5b") -> dict:
    sc = ServeConfig(arch=arch, reduced=True, batch_slots=batch_slots,
                     s_max=max(64, prompt_len + gen_len + 1),
                     requests=requests, prompt_len=prompt_len,
                     gen_len=gen_len)
    # warm each path once (compile), then measure
    run(sc)
    t0 = time.time()
    new = run(sc)
    new_wall = time.time() - t0
    run_legacy(sc)
    t0 = time.time()
    old = run_legacy(sc)
    old_wall = time.time() - t0
    cell = {
        "batch_slots": batch_slots,
        "prompt_len": prompt_len,
        "requests": requests,
        "gen_len": gen_len,
        "engine_tokens_per_s": new["tokens_per_s"],
        "legacy_tokens_per_s": old["tokens_per_s"],
        "speedup": new["tokens_per_s"] / max(old["tokens_per_s"], 1e-9),
        "engine_wall_s": new_wall,
        "legacy_wall_s": old_wall,
        "engine_ttft_p50_s": new["metrics"]["ttft_s"]["p50"],
        "engine_latency_p95_s": new["metrics"]["latency_s"]["p95"],
    }
    print(f"slots={batch_slots:2d} prompt={prompt_len:3d}: "
          f"engine {cell['engine_tokens_per_s']:8.1f} tok/s | "
          f"legacy {cell['legacy_tokens_per_s']:8.1f} tok/s | "
          f"{cell['speedup']:.2f}x")
    return cell


def _paged_run(sc: ServeConfig) -> dict:
    """One timed engine run that also reports resident cache bytes (run()
    only surfaces metrics)."""
    from repro.launch.serve import build_engine, make_prompts
    engine = build_engine(sc)
    for prompt in make_prompts(sc, engine.cfg.vocab_size):
        engine.submit(prompt, sc.gen_len)
    summary = engine.run()
    return {"tokens_per_s": summary["throughput_tokens_per_s"],
            "resident_cache_bytes": engine.resident_cache_bytes()}


def bench_paged_cell(batch_slots: int, prompt_len: int, *, requests: int,
                     gen_len: int) -> dict:
    """Dense vs paged at EQUAL workload: same arch/slots/prompts, one cache
    preallocated at slots x s_max, the other a page pool sized to the
    concurrent worst case."""
    pages_per_req = -(-(prompt_len + gen_len - 1) // PAGE_SIZE)
    base = dict(arch=PAGED_ARCH, reduced=True, batch_slots=batch_slots,
                s_max=PAGED_S_MAX, requests=requests, prompt_len=prompt_len,
                gen_len=gen_len)
    dense_sc = ServeConfig(**base)
    paged_sc = ServeConfig(**base, page_size=PAGE_SIZE,
                           num_pages=batch_slots * pages_per_req)
    _paged_run(dense_sc)                     # warm (compile)
    dense = _paged_run(dense_sc)
    _paged_run(paged_sc)
    paged = _paged_run(paged_sc)
    cell = {
        "batch_slots": batch_slots,
        "prompt_len": prompt_len,
        "requests": requests,
        "gen_len": gen_len,
        "dense_tokens_per_s": dense["tokens_per_s"],
        "paged_tokens_per_s": paged["tokens_per_s"],
        "dense_resident_cache_bytes": dense["resident_cache_bytes"],
        "paged_resident_cache_bytes": paged["resident_cache_bytes"],
        "resident_bytes_ratio": paged["resident_cache_bytes"]
        / max(dense["resident_cache_bytes"], 1),
    }
    print(f"slots={batch_slots:2d} prompt={prompt_len:3d} [paged]: "
          f"dense {cell['dense_tokens_per_s']:8.1f} tok/s "
          f"{cell['dense_resident_cache_bytes']:>10d} B | "
          f"paged {cell['paged_tokens_per_s']:8.1f} tok/s "
          f"{cell['paged_resident_cache_bytes']:>10d} B | "
          f"{cell['resident_bytes_ratio']:.2f}x bytes")
    return cell


def _prefill_rate(sc: ServeConfig) -> float:
    """Prefill tokens/s over the wall spent INSIDE prefill dispatches (the
    engine metric) — isolates the forward's arithmetic intensity from
    queueing and decode."""
    from repro.launch.serve import build_engine, make_prompts
    engine = build_engine(sc)
    for prompt in make_prompts(sc, engine.cfg.vocab_size):
        engine.submit(prompt, sc.gen_len)
    summary = engine.run()
    return summary["prefill_tokens_per_s"]


def bench_prefill_cell(prompt_len: int, *, requests: int, gen_len: int,
                       chunk: int = 64) -> dict:
    """Parallel chunked vs scan prefill at equal workload on the qwen cell."""
    base = dict(arch=PAGED_ARCH, reduced=True, batch_slots=4,
                s_max=max(64, prompt_len + gen_len + 1), requests=requests,
                prompt_len=prompt_len, gen_len=gen_len)
    scan_sc = ServeConfig(**base, prefill_mode="scan")
    par_sc = ServeConfig(**base, prefill_mode="parallel", prefill_chunk=chunk)
    _prefill_rate(scan_sc)                   # warm (compile)
    scan = _prefill_rate(scan_sc)
    _prefill_rate(par_sc)
    par = _prefill_rate(par_sc)
    cell = {
        "prompt_len": prompt_len,
        "requests": requests,
        "gen_len": gen_len,
        "prefill_chunk": chunk,
        "scan_prefill_tokens_per_s": scan,
        "parallel_prefill_tokens_per_s": par,
        "speedup": par / max(scan, 1e-9),
    }
    print(f"prompt={prompt_len:3d} [prefill]: scan {scan:9.1f} tok/s | "
          f"parallel {par:9.1f} tok/s | {cell['speedup']:.2f}x")
    return cell


def bench_prefix_cell(prompt_len: int, overlap: int, *, requests: int,
                      gen_len: int) -> dict:
    """Prefix-cached vs uncached prefill at equal workload on the qwen cell.

    ``requests`` prompts share a page-aligned ``overlap``-token header and
    differ in their tails — the production few-shot/system-prompt pattern.
    A warm-up request registers the header (modelling prior traffic), then
    the measured batch is served with the prefix cache on vs off. The rate
    is EFFECTIVE prefill tokens/s: total prompt tokens ingested over the
    wall spent inside prefill dispatches INCLUDING the hit path's
    page-gather overhead — cached prompts ingest the same logical tokens in
    less wall, which is the whole point."""
    import numpy as np

    from repro.serve.engine import ServeEngine

    pages_per_req = -(-(prompt_len + gen_len - 1) // PAGE_SIZE)
    # pool = concurrent worst case + the retained header's pages (+1 for an
    # unaligned header tail)
    num_pages = 4 * pages_per_req + -(-overlap // PAGE_SIZE) + 1

    rng = np.random.default_rng(0)

    def build(prefix_on: bool) -> "ServeEngine":
        return ServeEngine.build(
            PAGED_ARCH, reduced=True, batch_slots=4, s_max=PAGED_S_MAX,
            page_size=PAGE_SIZE, num_pages=num_pages,
            prefix_cache=None if prefix_on else False, seed=0)

    def run_once(prefix_on: bool) -> dict:
        engine = build(prefix_on)
        vocab = engine.cfg.vocab_size
        header = rng.integers(0, vocab, overlap).astype(np.int32)
        prompts = [np.concatenate(
            [header, rng.integers(0, vocab,
                                  prompt_len - overlap).astype(np.int32)])
            for _ in range(requests)]
        engine.submit(header, 1)             # prior traffic warms the index
        engine.run()
        w0 = engine.metrics.prefill_wall_s
        for p in prompts:
            engine.submit(p, gen_len)
        engine.run()
        wall = engine.metrics.prefill_wall_s - w0
        m = engine.metrics
        return {"eff_tokens_per_s": requests * prompt_len / max(wall, 1e-9),
                "hit_rate": m.prefix_hits / max(m.prefix_lookups, 1),
                "pages_shared": m.prefix_pages_shared,
                "cow_copies": m.prefix_cow_copies}

    run_once(False)                          # warm (compile)
    off = run_once(False)
    run_once(True)
    on = run_once(True)
    cell = {
        "prompt_len": prompt_len,
        "overlap_tokens": overlap,
        "overlap_frac": overlap / prompt_len,
        "requests": requests,
        "gen_len": gen_len,
        "uncached_prefill_tokens_per_s": off["eff_tokens_per_s"],
        "cached_prefill_tokens_per_s": on["eff_tokens_per_s"],
        "speedup": on["eff_tokens_per_s"] / max(off["eff_tokens_per_s"],
                                                1e-9),
        "hit_rate": on["hit_rate"],
        "pages_shared": on["pages_shared"],
        "cow_copies": on["cow_copies"],
    }
    print(f"prompt={prompt_len:3d} overlap={overlap:3d} [prefix]: "
          f"uncached {cell['uncached_prefill_tokens_per_s']:9.1f} tok/s | "
          f"cached {cell['cached_prefill_tokens_per_s']:9.1f} tok/s | "
          f"{cell['speedup']:.2f}x (hit rate {cell['hit_rate']:.2f})")
    return cell


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="only the acceptance cells (slots=4, prompt=32)")
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args()

    cells = [(4, 32)] if args.quick else [
        (2, 8), (2, 32), (4, 8), (4, 32), (4, 64), (8, 32)]
    results = [bench_cell(bs, pl, requests=args.requests, gen_len=args.gen_len)
               for bs, pl in cells]
    accept = next(r for r in results
                  if r["batch_slots"] == 4 and r["prompt_len"] == 32)

    paged_cells = [(4, 32)] if args.quick else [
        (4, 32), (4, 128), (8, 32), (8, 128)]
    paged_results = [bench_paged_cell(bs, pl, requests=args.requests,
                                      gen_len=args.gen_len)
                     for bs, pl in paged_cells]
    paged_accept = next(r for r in paged_results
                        if r["batch_slots"] == 4 and r["prompt_len"] == 32)

    prefill_cells = [128] if args.quick else [32, 128, 256]
    prefill_results = [bench_prefill_cell(pl, requests=args.requests,
                                          gen_len=4)
                       for pl in prefill_cells]
    prefill_accept = next(r for r in prefill_results
                          if r["prompt_len"] == 128)

    # prefix caching: (prompt_len, shared header tokens) — the acceptance
    # cell is prompt 128 at 75% overlap (>= the 50% bar), the production
    # few-shot-header pattern
    prefix_cells = [(128, 96)] if args.quick else [(128, 64), (128, 96),
                                                   (128, 112)]
    prefix_results = [bench_prefix_cell(pl, ov, requests=args.requests,
                                        gen_len=4)
                      for pl, ov in prefix_cells]
    prefix_accept = next(r for r in prefix_results
                         if r["prompt_len"] == 128 and
                         r["overlap_tokens"] == 96)

    out = {
        "arch": "hymba-1.5b (reduced)",
        "device": "cpu",
        "cells": results,
        "acceptance": {
            "cell": "batch_slots=4, prompt_len=32",
            "speedup": accept["speedup"],
            "passes_2x": accept["speedup"] >= 2.0,
        },
        "paged": {
            "arch": f"{PAGED_ARCH} (reduced)",
            "page_size": PAGE_SIZE,
            "s_max": PAGED_S_MAX,
            "cells": paged_results,
            "acceptance": {
                "cell": "batch_slots=4, prompt_len=32",
                "resident_bytes_ratio": paged_accept["resident_bytes_ratio"],
                "passes_memory_drop":
                    paged_accept["resident_bytes_ratio"] < 1.0,
            },
        },
        "prefill": {
            "arch": f"{PAGED_ARCH} (reduced)",
            "cells": prefill_results,
            "acceptance": {
                "cell": "prompt_len=128",
                "speedup": prefill_accept["speedup"],
                "passes_2x": prefill_accept["speedup"] >= 2.0,
            },
        },
        "prefix": {
            "arch": f"{PAGED_ARCH} (reduced)",
            "page_size": PAGE_SIZE,
            "cells": prefix_results,
            "acceptance": {
                "cell": (f"prompt_len=128, overlap="
                         f"{prefix_accept['overlap_tokens']} "
                         f"({prefix_accept['overlap_frac']:.0%})"),
                "speedup": prefix_accept["speedup"],
                "hit_rate": prefix_accept["hit_rate"],
                "passes_2x": prefix_accept["speedup"] >= 2.0,
            },
        },
    }
    OUT.write_text(json.dumps(out, indent=2))
    print(f"wrote {OUT} (acceptance speedup {accept['speedup']:.2f}x, "
          f">=2x: {out['acceptance']['passes_2x']}; paged resident bytes "
          f"{paged_accept['resident_bytes_ratio']:.2f}x of dense, drop: "
          f"{out['paged']['acceptance']['passes_memory_drop']}; parallel "
          f"prefill {prefill_accept['speedup']:.2f}x scan at prompt 128, "
          f">=2x: {out['prefill']['acceptance']['passes_2x']}; prefix-cached "
          f"prefill {prefix_accept['speedup']:.2f}x uncached at "
          f"{prefix_accept['overlap_frac']:.0%} overlap, >=2x: "
          f"{out['prefix']['acceptance']['passes_2x']})")


if __name__ == "__main__":
    main()
