"""Serving benchmark: batched-prefill engine vs the seed's token-by-token
legacy path, swept over batch_slots x prompt_len on the reduced hymba-1.5b
(CPU). Writes ``BENCH_serve.json`` next to the repo root.

The engine's win has two mechanical sources, mirroring the paper's ladder:
fewer dispatches (one jitted scan per prefill instead of one dispatch per
prompt token — the paper's instruction/DRAM block overhead) and less compute
(batch-1 prefill instead of stepping the full batch width per prompt token —
the paper's "don't move/compute what you don't need").

Run: PYTHONPATH=src python -m benchmarks.serve_bench [--quick]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

from repro.launch.serve import ServeConfig, run, run_legacy

OUT = pathlib.Path(__file__).resolve().parents[1] / "BENCH_serve.json"


def bench_cell(batch_slots: int, prompt_len: int, *, requests: int,
               gen_len: int, arch: str = "hymba-1.5b") -> dict:
    sc = ServeConfig(arch=arch, reduced=True, batch_slots=batch_slots,
                     s_max=max(64, prompt_len + gen_len + 1),
                     requests=requests, prompt_len=prompt_len,
                     gen_len=gen_len)
    # warm each path once (compile), then measure
    run(sc)
    t0 = time.time()
    new = run(sc)
    new_wall = time.time() - t0
    run_legacy(sc)
    t0 = time.time()
    old = run_legacy(sc)
    old_wall = time.time() - t0
    cell = {
        "batch_slots": batch_slots,
        "prompt_len": prompt_len,
        "requests": requests,
        "gen_len": gen_len,
        "engine_tokens_per_s": new["tokens_per_s"],
        "legacy_tokens_per_s": old["tokens_per_s"],
        "speedup": new["tokens_per_s"] / max(old["tokens_per_s"], 1e-9),
        "engine_wall_s": new_wall,
        "legacy_wall_s": old_wall,
        "engine_ttft_p50_s": new["metrics"]["ttft_s"]["p50"],
        "engine_latency_p95_s": new["metrics"]["latency_s"]["p95"],
    }
    print(f"slots={batch_slots:2d} prompt={prompt_len:3d}: "
          f"engine {cell['engine_tokens_per_s']:8.1f} tok/s | "
          f"legacy {cell['legacy_tokens_per_s']:8.1f} tok/s | "
          f"{cell['speedup']:.2f}x")
    return cell


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="only the acceptance cell (slots=4, prompt=32)")
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args()

    cells = [(4, 32)] if args.quick else [
        (2, 8), (2, 32), (4, 8), (4, 32), (4, 64), (8, 32)]
    results = [bench_cell(bs, pl, requests=args.requests, gen_len=args.gen_len)
               for bs, pl in cells]
    accept = next(r for r in results
                  if r["batch_slots"] == 4 and r["prompt_len"] == 32)
    out = {
        "arch": "hymba-1.5b (reduced)",
        "device": "cpu",
        "cells": results,
        "acceptance": {
            "cell": "batch_slots=4, prompt_len=32",
            "speedup": accept["speedup"],
            "passes_2x": accept["speedup"] >= 2.0,
        },
    }
    OUT.write_text(json.dumps(out, indent=2))
    print(f"wrote {OUT} (acceptance speedup "
          f"{accept['speedup']:.2f}x, >=2x: {out['acceptance']['passes_2x']})")


if __name__ == "__main__":
    main()
