"""Serving benchmark: batched-prefill engine vs the seed's token-by-token
legacy path (hymba, as in PR 1), a PAGED-vs-DENSE KV cache column (tokens/s
and resident cache bytes) on a full-attention arch, a PREFILL column
(parallel chunked vs teacher-forced scan prefill tokens/s on the
qwen2.5-32b reduced cell), a PREFIX column (page-level prefix caching
on vs off under shared-header traffic — effective prefill tokens/s,
hit rate, pages shared, COW copies), and a PREFILL_PAGED column (the
incremental paged-kernel prefill vs the transient masked-einsum path —
continuation-chunk tokens/s and the transient-cache bytes bound), and a
KV_QUANT column (the int8 KV-page backend vs fp32 pages — decode tokens/s,
resident K/V pool bytes, greedy-stream divergence), an MLA column (the
latent-page KV backend on the MLA arch vs per-head fp32 pages on its parent
arch — resident KV pool bytes at <= 0.35x and greedy divergence vs a dense
MLA engine), a TP column
(tensor-parallel paged decode on a forced-8-device host mesh — greedy
bitwise equality vs the mesh-free engine and per-shard resident KV pool
bytes at 1/tp), and a ROUTER column (prefix-affinity replica routing vs
round-robin under shared-header traffic — effective prefill tokens/s
across a 2-replica tier). Writes ``BENCH_serve.json`` next to the repo
root; ``benchmarks/check_bench.py`` gates CI on it.

``--sections a,b`` reruns only those sections and MERGES them into the
existing ``BENCH_serve.json`` (other sections keep their previous values),
so CI can split the bench across steps and a developer can iterate on one
column without paying for the rest.

The engine's win has two mechanical sources, mirroring the paper's ladder:
fewer dispatches (one jitted scan per prefill instead of one dispatch per
prompt token — the paper's instruction/DRAM block overhead) and less compute
(batch-1 prefill instead of stepping the full batch width per prompt token —
the paper's "don't move/compute what you don't need"). The paged column is
the paper's memory-as-first-class-constraint lesson applied to serving. The
prefill column is the paper's loop-width/tiling lever: the scan anchor
teacher-forces decode_step — ONE token of matmul width per sequential step —
while the parallel path computes a whole bucketed chunk per pass at full
matmul width; the acceptance bar is >= 2x prefill tokens/s at
prompt_len >= 128.

Run: PYTHONPATH=src python -m benchmarks.serve_bench [--quick]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

from repro.launch.serve import ServeConfig, run, run_legacy

OUT = pathlib.Path(__file__).resolve().parents[1] / "BENCH_serve.json"

# paged sweep: a full-attention arch (hymba's ring cache is already O(window)
# resident — paging it proves correctness, not memory), a serving-realistic
# per-request bound, and a pool sized to the concurrent workload
PAGED_ARCH = "qwen2.5-32b"
PAGED_S_MAX = 256
PAGE_SIZE = 16


def bench_cell(batch_slots: int, prompt_len: int, *, requests: int,
               gen_len: int, arch: str = "hymba-1.5b") -> dict:
    sc = ServeConfig(arch=arch, reduced=True, batch_slots=batch_slots,
                     s_max=max(64, prompt_len + gen_len + 1),
                     requests=requests, prompt_len=prompt_len,
                     gen_len=gen_len)
    # warm each path once (compile), then measure
    run(sc)
    t0 = time.time()
    new = run(sc)
    new_wall = time.time() - t0
    run_legacy(sc)
    t0 = time.time()
    old = run_legacy(sc)
    old_wall = time.time() - t0
    cell = {
        "batch_slots": batch_slots,
        "prompt_len": prompt_len,
        "requests": requests,
        "gen_len": gen_len,
        "engine_tokens_per_s": new["tokens_per_s"],
        "legacy_tokens_per_s": old["tokens_per_s"],
        "speedup": new["tokens_per_s"] / max(old["tokens_per_s"], 1e-9),
        "engine_wall_s": new_wall,
        "legacy_wall_s": old_wall,
        "engine_ttft_p50_s": new["metrics"]["ttft_s"]["p50"],
        "engine_latency_p95_s": new["metrics"]["latency_s"]["p95"],
    }
    print(f"slots={batch_slots:2d} prompt={prompt_len:3d}: "
          f"engine {cell['engine_tokens_per_s']:8.1f} tok/s | "
          f"legacy {cell['legacy_tokens_per_s']:8.1f} tok/s | "
          f"{cell['speedup']:.2f}x")
    return cell


def _paged_run(sc: ServeConfig) -> dict:
    """One timed engine run that also reports resident cache bytes (run()
    only surfaces metrics)."""
    from repro.launch.serve import build_engine, make_prompts
    engine = build_engine(sc)
    for prompt in make_prompts(sc, engine.cfg.vocab_size):
        engine.submit(prompt, sc.gen_len)
    summary = engine.run()
    return {"tokens_per_s": summary["throughput_tokens_per_s"],
            "resident_cache_bytes": engine.resident_cache_bytes()}


def bench_paged_cell(batch_slots: int, prompt_len: int, *, requests: int,
                     gen_len: int) -> dict:
    """Dense vs paged at EQUAL workload: same arch/slots/prompts, one cache
    preallocated at slots x s_max, the other a page pool sized to the
    concurrent worst case."""
    pages_per_req = -(-(prompt_len + gen_len - 1) // PAGE_SIZE)
    base = dict(arch=PAGED_ARCH, reduced=True, batch_slots=batch_slots,
                s_max=PAGED_S_MAX, requests=requests, prompt_len=prompt_len,
                gen_len=gen_len)
    dense_sc = ServeConfig(**base)
    paged_sc = ServeConfig(**base, page_size=PAGE_SIZE,
                           num_pages=batch_slots * pages_per_req)
    _paged_run(dense_sc)                     # warm (compile)
    dense = _paged_run(dense_sc)
    _paged_run(paged_sc)
    paged = _paged_run(paged_sc)
    cell = {
        "batch_slots": batch_slots,
        "prompt_len": prompt_len,
        "requests": requests,
        "gen_len": gen_len,
        "dense_tokens_per_s": dense["tokens_per_s"],
        "paged_tokens_per_s": paged["tokens_per_s"],
        "dense_resident_cache_bytes": dense["resident_cache_bytes"],
        "paged_resident_cache_bytes": paged["resident_cache_bytes"],
        "resident_bytes_ratio": paged["resident_cache_bytes"]
        / max(dense["resident_cache_bytes"], 1),
    }
    print(f"slots={batch_slots:2d} prompt={prompt_len:3d} [paged]: "
          f"dense {cell['dense_tokens_per_s']:8.1f} tok/s "
          f"{cell['dense_resident_cache_bytes']:>10d} B | "
          f"paged {cell['paged_tokens_per_s']:8.1f} tok/s "
          f"{cell['paged_resident_cache_bytes']:>10d} B | "
          f"{cell['resident_bytes_ratio']:.2f}x bytes")
    return cell


def _prefill_rate(sc: ServeConfig) -> float:
    """Prefill tokens/s over the wall spent INSIDE prefill dispatches (the
    engine metric) — isolates the forward's arithmetic intensity from
    queueing and decode."""
    from repro.launch.serve import build_engine, make_prompts
    engine = build_engine(sc)
    for prompt in make_prompts(sc, engine.cfg.vocab_size):
        engine.submit(prompt, sc.gen_len)
    summary = engine.run()
    return summary["prefill_tokens_per_s"]


def bench_prefill_cell(prompt_len: int, *, requests: int, gen_len: int,
                       chunk: int = 64) -> dict:
    """Parallel chunked vs scan prefill at equal workload on the qwen cell.
    Best-of-3 per mode: single runs on a shared CPU swing 2x+ and this
    cell's ``passes_2x`` flag gates CI — the max is the machine's honest
    rate (same practice as the prefill_paged cell)."""
    base = dict(arch=PAGED_ARCH, reduced=True, batch_slots=4,
                s_max=max(64, prompt_len + gen_len + 1), requests=requests,
                prompt_len=prompt_len, gen_len=gen_len)
    scan_sc = ServeConfig(**base, prefill_mode="scan")
    par_sc = ServeConfig(**base, prefill_mode="parallel", prefill_chunk=chunk)
    _prefill_rate(scan_sc)                   # warm (compile)
    scan = max(_prefill_rate(scan_sc) for _ in range(3))
    _prefill_rate(par_sc)
    par = max(_prefill_rate(par_sc) for _ in range(3))
    cell = {
        "prompt_len": prompt_len,
        "requests": requests,
        "gen_len": gen_len,
        "prefill_chunk": chunk,
        "scan_prefill_tokens_per_s": scan,
        "parallel_prefill_tokens_per_s": par,
        "speedup": par / max(scan, 1e-9),
    }
    print(f"prompt={prompt_len:3d} [prefill]: scan {scan:9.1f} tok/s | "
          f"parallel {par:9.1f} tok/s | {cell['speedup']:.2f}x")
    return cell


# paged-kernel prefill cell: a long-context per-request capacity (s_max is
# the BLOCK-TABLE SPAN, not resident memory — the pool is sized to the live
# workload) so the block skip has dead span to skip: the transient einsum
# path masks all s_max rows per continuation chunk regardless of how many
# are live, which is exactly the O(C x s_max) cost the kernel removes, and
# the margin grows with capacity (s_max 512 measures ~1.3x on this CPU,
# 1024 a stable ~1.8x; on TPU the skip is free of interpret overhead)
PKERN_S_MAX = 1024
PKERN_PAGE = 128
PKERN_CHUNK = 64
PKERN_SLOTS = 4         # batch slots AND the worst-case prefill group width
PKERN_REQUESTS = 16     # enough chunks that the measured wall amortises
PKERN_REPS = 3          # best-of-N per impl: single runs on a shared CPU
#                         swing 2x+, the max is the machine's honest rate


def bench_prefill_paged_cell(prompt_len: int, *, requests: int,
                             gen_len: int) -> dict:
    """Incremental paged-kernel prefill vs the transient masked-einsum path
    at equal workload on the qwen cell.

    'off' is the PR 2-4 lineage: continuation chunks attend a DENSE
    transient request cache with a masked einsum over all s_max rows and
    the job pays a completion splice; 'on' is the tentpole: chunks scatter
    K/V straight into their reserved pages and attend them through the
    block-table-gather Pallas kernel, which skips unallocated and
    beyond-frontier pages — mask work scales with live pages, and the
    transient request cache disappears (``max_transient_cache_bytes`` is 0
    by construction, recorded as the acceptance memory bound)."""
    import numpy as np

    from repro.serve.engine import ServeEngine

    pages_per_req = -(-(prompt_len + gen_len - 1) // PKERN_PAGE)
    rng = np.random.default_rng(0)
    requests = max(requests, PKERN_REQUESTS)

    def run_once(impl: str) -> dict:
        engine = ServeEngine.build(
            PAGED_ARCH, reduced=True, batch_slots=PKERN_SLOTS,
            s_max=PKERN_S_MAX, page_size=PKERN_PAGE,
            num_pages=PKERN_SLOTS * pages_per_req,
            prefix_cache=False, paged_attn_impl=impl,
            prefill_chunk_tokens=PKERN_CHUNK, seed=0)
        for _ in range(requests):
            engine.submit(rng.integers(0, engine.cfg.vocab_size, prompt_len),
                          gen_len)
        summary = engine.run()
        return {"rate": summary["prefill_tokens_per_s"],
                "transient_bytes": engine.max_transient_cache_bytes}

    def best_of(impl: str) -> dict:
        run_once(impl)                            # warm (compile)
        runs = [run_once(impl) for _ in range(PKERN_REPS)]
        return max(runs, key=lambda r: r["rate"])

    off = best_of("einsum")
    on = best_of("kernel")
    # one chunk's K/V rows across layers at the widest group — the bound the
    # incremental path's transient residency must stay under (it is 0: the
    # pages ARE the prefill cache). Config read directly, no throwaway
    # engine; float32 cache dtype (engine default).
    from repro import configs as _cfgs
    from repro.models.registry import reduced_config as _reduced
    cfg = _reduced(_cfgs.get_config(PAGED_ARCH))
    chunk_bound = (2 * cfg.num_layers * PKERN_SLOTS * PKERN_CHUNK
                   * cfg.num_kv_heads * cfg.head_dim * 4)
    cell = {
        "prompt_len": prompt_len,
        "requests": requests,
        "gen_len": gen_len,
        "s_max": PKERN_S_MAX,
        "page_size": PKERN_PAGE,
        "prefill_chunk": PKERN_CHUNK,
        "reps_best_of": PKERN_REPS,
        "einsum_prefill_tokens_per_s": off["rate"],
        "kernel_prefill_tokens_per_s": on["rate"],
        "speedup": on["rate"] / max(off["rate"], 1e-9),
        "einsum_transient_cache_bytes": off["transient_bytes"],
        "kernel_transient_cache_bytes": on["transient_bytes"],
        "one_chunk_bytes_bound": chunk_bound,
    }
    print(f"prompt={prompt_len:3d} [prefill_paged]: einsum "
          f"{off['rate']:9.1f} tok/s ({off['transient_bytes']:>8d} B "
          f"transient) | kernel {on['rate']:9.1f} tok/s "
          f"({on['transient_bytes']} B) | {cell['speedup']:.2f}x")
    return cell


def bench_prefix_cell(prompt_len: int, overlap: int, *, requests: int,
                      gen_len: int) -> dict:
    """Prefix-cached vs uncached prefill at equal workload on the qwen cell.

    ``requests`` prompts share a page-aligned ``overlap``-token header and
    differ in their tails — the production few-shot/system-prompt pattern.
    A warm-up request registers the header (modelling prior traffic), then
    the measured batch is served with the prefix cache on vs off. The rate
    is EFFECTIVE prefill tokens/s: total prompt tokens ingested over the
    wall spent inside prefill dispatches INCLUDING the hit path's
    page-gather overhead — cached prompts ingest the same logical tokens in
    less wall, which is the whole point."""
    import numpy as np

    from repro.serve.engine import ServeEngine

    pages_per_req = -(-(prompt_len + gen_len - 1) // PAGE_SIZE)
    # pool = concurrent worst case + the retained header's pages (+1 for an
    # unaligned header tail)
    num_pages = 4 * pages_per_req + -(-overlap // PAGE_SIZE) + 1

    rng = np.random.default_rng(0)

    def build(prefix_on: bool) -> "ServeEngine":
        return ServeEngine.build(
            PAGED_ARCH, reduced=True, batch_slots=4, s_max=PAGED_S_MAX,
            page_size=PAGE_SIZE, num_pages=num_pages,
            prefix_cache=None if prefix_on else False, seed=0)

    def run_once(prefix_on: bool) -> dict:
        engine = build(prefix_on)
        vocab = engine.cfg.vocab_size
        header = rng.integers(0, vocab, overlap).astype(np.int32)
        prompts = [np.concatenate(
            [header, rng.integers(0, vocab,
                                  prompt_len - overlap).astype(np.int32)])
            for _ in range(requests)]
        engine.submit(header, 1)             # prior traffic warms the index
        engine.run()
        w0 = engine.metrics.prefill_wall_s
        for p in prompts:
            engine.submit(p, gen_len)
        engine.run()
        wall = engine.metrics.prefill_wall_s - w0
        m = engine.metrics
        return {"eff_tokens_per_s": requests * prompt_len / max(wall, 1e-9),
                "hit_rate": m.prefix_hits / max(m.prefix_lookups, 1),
                "pages_shared": m.prefix_pages_shared,
                "cow_copies": m.prefix_cow_copies}

    run_once(False)                          # warm (compile)
    off = run_once(False)
    run_once(True)
    on = run_once(True)
    cell = {
        "prompt_len": prompt_len,
        "overlap_tokens": overlap,
        "overlap_frac": overlap / prompt_len,
        "requests": requests,
        "gen_len": gen_len,
        "uncached_prefill_tokens_per_s": off["eff_tokens_per_s"],
        "cached_prefill_tokens_per_s": on["eff_tokens_per_s"],
        "speedup": on["eff_tokens_per_s"] / max(off["eff_tokens_per_s"],
                                                1e-9),
        "hit_rate": on["hit_rate"],
        "pages_shared": on["pages_shared"],
        "cow_copies": on["cow_copies"],
    }
    print(f"prompt={prompt_len:3d} overlap={overlap:3d} [prefix]: "
          f"uncached {cell['uncached_prefill_tokens_per_s']:9.1f} tok/s | "
          f"cached {cell['cached_prefill_tokens_per_s']:9.1f} tok/s | "
          f"{cell['speedup']:.2f}x (hit rate {cell['hit_rate']:.2f})")
    return cell


# kv-quant cell: the int8 KV backend vs fp32 pages at EQUAL geometry. The
# headline is the resident K/V pool footprint (int8 payload = 0.25x, plus
# two (L, P) f32 scale tables — ~0.25x + epsilon, gated at <= 0.30x) at no
# quality loss beyond the greedy-divergence gate; decode tokens/s rides
# along best-of-3 (on TPU the 4x-smaller HBM KV stream is the decode win;
# on this CPU the interpret-mode dequant makes the rate informational, so
# only bytes and divergence gate CI)
KVQ_S_MAX = 256
KVQ_PAGE = 16
KVQ_SLOTS = 4
KVQ_REPS = 3


def bench_kv_quant_cell(prompt_len: int, *, requests: int,
                        gen_len: int) -> dict:
    """Int8 vs fp32 KV pages at equal workload/geometry on the qwen cell:
    decode tokens/s (best-of-N), resident K/V pool bytes, and the greedy
    stream divergence between the two backends (mean per-request
    prefix-match fraction — the same gate tests/test_kvcache.py applies
    per family)."""
    import numpy as np

    from repro.serve.engine import ServeEngine

    pages_per_req = -(-(prompt_len + gen_len - 1) // KVQ_PAGE)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 2 ** 31 - 1, prompt_len)
               for _ in range(requests)]

    def run_once(backend: str) -> dict:
        engine = ServeEngine.build(
            PAGED_ARCH, reduced=True, batch_slots=KVQ_SLOTS, s_max=KVQ_S_MAX,
            page_size=KVQ_PAGE, num_pages=KVQ_SLOTS * pages_per_req,
            kv_backend=backend, prefix_cache=False, seed=0)
        vocab = engine.cfg.vocab_size
        reqs = [engine.submit(p % vocab, gen_len) for p in prompts]
        t0 = time.time()
        summary = engine.run()
        wall = time.time() - t0
        decode_wall = max(wall - engine.metrics.prefill_wall_s, 1e-9)
        kv_keys = [k for k in engine.cache
                   if k in ("k", "v") or k.endswith("_scale")]
        return {
            "decode_tokens_per_s": requests * gen_len / decode_wall,
            "tokens_per_s": summary["throughput_tokens_per_s"],
            "resident_kv_bytes": int(sum(
                engine.cache[k].size * engine.cache[k].dtype.itemsize
                for k in kv_keys)),
            "streams": [r.tokens for r in reqs],
        }

    def best_of(backend: str) -> dict:
        run_once(backend)                         # warm (compile)
        runs = [run_once(backend) for _ in range(KVQ_REPS)]
        return max(runs, key=lambda r: r["decode_tokens_per_s"])

    fp32 = best_of("paged_fp32")
    int8 = best_of("paged_int8")

    def match(a, b):
        n = 0
        for x, y in zip(a, b):
            if x != y:
                break
            n += 1
        return n / max(len(a), len(b), 1)

    divergence = [match(a, b) for a, b in zip(fp32["streams"],
                                              int8["streams"])]
    cell = {
        "prompt_len": prompt_len,
        "requests": requests,
        "gen_len": gen_len,
        "page_size": KVQ_PAGE,
        "reps_best_of": KVQ_REPS,
        "fp32_decode_tokens_per_s": fp32["decode_tokens_per_s"],
        "int8_decode_tokens_per_s": int8["decode_tokens_per_s"],
        "decode_speed_ratio": int8["decode_tokens_per_s"]
        / max(fp32["decode_tokens_per_s"], 1e-9),
        "fp32_resident_kv_bytes": fp32["resident_kv_bytes"],
        "int8_resident_kv_bytes": int8["resident_kv_bytes"],
        "resident_bytes_ratio": int8["resident_kv_bytes"]
        / max(fp32["resident_kv_bytes"], 1),
        "greedy_prefix_match_mean": float(np.mean(divergence)),
        "greedy_prefix_match_min": float(np.min(divergence)),
    }
    print(f"prompt={prompt_len:3d} [kv_quant]: fp32 "
          f"{cell['fp32_decode_tokens_per_s']:8.1f} tok/s "
          f"{cell['fp32_resident_kv_bytes']:>9d} B | int8 "
          f"{cell['int8_decode_tokens_per_s']:8.1f} tok/s "
          f"{cell['int8_resident_kv_bytes']:>9d} B | "
          f"{cell['resident_bytes_ratio']:.2f}x bytes, match "
          f"{cell['greedy_prefix_match_mean']:.2f}")
    return cell


# mla cell: the latent-page KV backend (PagedLatentBackend) vs per-head fp32
# pages at EQUAL workload. MLA pages store one (c_kv + r)-dim latent row per
# token instead of (2, H, hd) per-head K/V, so the headline is the resident
# KV pool footprint: reduced dims cache 10 floats/token/layer vs the parent
# GQA cell's 32 (k+v) — 0.3125x, gated at <= 0.35x (the full arch is
# 576/2048 = 0.28x). The baseline runs paged_fp32 on the PARENT arch: fp32
# pages on the MLA arch would cache the same latent rows and the ratio would
# read 1.0. Decode tokens/s rides along best-of-N (informational on CPU —
# the absorb-path einsum dominates under interpret); quality is gated by
# greedy divergence vs a DENSE engine on the same MLA arch (paged latent
# decode is the same math through block-table indirection).
MLA_ARCH = "qwen2.5-32b-mla"
MLA_S_MAX = 256
MLA_PAGE = 16
MLA_SLOTS = 4
MLA_REPS = 3


def bench_mla_cell(prompt_len: int, *, requests: int, gen_len: int) -> dict:
    """Latent-page MLA KV backend vs per-head fp32 pages at equal
    workload/geometry: decode tokens/s (best-of-N), resident KV pool bytes
    (latent vs the parent arch's k+v pools), and the greedy stream
    divergence between the paged-latent engine and a dense engine on the
    same MLA arch (mean per-request prefix-match fraction — the kv_quant
    gate applied to the latent path)."""
    import numpy as np

    from repro.serve.config import ServeConfig as EngineConfig
    from repro.serve.engine import ServeEngine

    pages_per_req = -(-(prompt_len + gen_len - 1) // MLA_PAGE)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 2 ** 31 - 1, prompt_len)
               for _ in range(requests)]

    def run_once(arch: str, backend) -> dict:
        kw = dict(reduced=True, batch_slots=MLA_SLOTS, s_max=MLA_S_MAX,
                  prefix_cache=False, seed=0)
        if backend is not None:
            kw.update(page_size=MLA_PAGE,
                      num_pages=MLA_SLOTS * pages_per_req,
                      kv_backend=backend)
        engine = ServeEngine.build(arch, config=EngineConfig(**kw))
        vocab = engine.cfg.vocab_size
        reqs = [engine.submit(p % vocab, gen_len) for p in prompts]
        t0 = time.time()
        summary = engine.run()
        wall = time.time() - t0
        decode_wall = max(wall - engine.metrics.prefill_wall_s, 1e-9)
        kv_keys = [k for k in engine.cache
                   if k in ("k", "v") or k.endswith("_scale")]
        return {
            "decode_tokens_per_s": requests * gen_len / decode_wall,
            "tokens_per_s": summary["throughput_tokens_per_s"],
            "resident_kv_bytes": int(sum(
                engine.cache[k].size * engine.cache[k].dtype.itemsize
                for k in kv_keys)),
            "streams": [r.tokens for r in reqs],
        }

    def best_of(arch: str, backend: str) -> dict:
        run_once(arch, backend)                   # warm (compile)
        runs = [run_once(arch, backend) for _ in range(MLA_REPS)]
        best = max(runs, key=lambda r: r["decode_tokens_per_s"])
        best["streams"] = runs[0]["streams"]      # deterministic anyway
        return best

    latent = best_of(MLA_ARCH, "paged_latent")
    fp32 = best_of(PAGED_ARCH, "paged_fp32")
    dense = run_once(MLA_ARCH, None)              # greedy reference

    def match(a, b):
        n = 0
        for x, y in zip(a, b):
            if x != y:
                break
            n += 1
        return n / max(len(a), len(b), 1)

    divergence = [match(a, b) for a, b in zip(dense["streams"],
                                              latent["streams"])]
    cell = {
        "prompt_len": prompt_len,
        "requests": requests,
        "gen_len": gen_len,
        "page_size": MLA_PAGE,
        "reps_best_of": MLA_REPS,
        "latent_decode_tokens_per_s": latent["decode_tokens_per_s"],
        "fp32_decode_tokens_per_s": fp32["decode_tokens_per_s"],
        "decode_speed_ratio": latent["decode_tokens_per_s"]
        / max(fp32["decode_tokens_per_s"], 1e-9),
        "latent_resident_kv_bytes": latent["resident_kv_bytes"],
        "fp32_resident_kv_bytes": fp32["resident_kv_bytes"],
        "resident_bytes_ratio": latent["resident_kv_bytes"]
        / max(fp32["resident_kv_bytes"], 1),
        "greedy_prefix_match_mean": float(np.mean(divergence)),
        "greedy_prefix_match_min": float(np.min(divergence)),
    }
    print(f"prompt={prompt_len:3d} [mla]: latent "
          f"{cell['latent_decode_tokens_per_s']:8.1f} tok/s "
          f"{cell['latent_resident_kv_bytes']:>9d} B | fp32 "
          f"{cell['fp32_decode_tokens_per_s']:8.1f} tok/s "
          f"{cell['fp32_resident_kv_bytes']:>9d} B | "
          f"{cell['resident_bytes_ratio']:.2f}x bytes, match "
          f"{cell['greedy_prefix_match_mean']:.2f}")
    return cell


# goodput cell: the open-loop SLO traffic harness (repro.serve.workload)
# replayed against a pool-pressured engine. Geometry makes PAGES the binding
# resource rather than slots (slots x typical request > pool) because every
# SLO-aware lever — priority preemption, admission shed, low-water deferral —
# acts on the page pool: a high-priority arrival that would otherwise defer
# behind low-priority decodes (strict no-skip-ahead admission) reclaims pages
# immediately. SLOs are MACHINE-RELATIVE — multiples of the same machine's
# measured unloaded latency percentiles — so the passes_* flags survive
# machine-class changes, the same flag-stability rationale as check_bench's
# relative-only CI gating.
GOODPUT_S_MAX = 96
GOODPUT_PAGE = 16
GOODPUT_SLOTS = 4       # > pool / typical request: a slot is always free, so
#                         admission pressure lands on the PAGE pool, where
#                         preemption/shed can act (a preemption needs a free
#                         slot to hand the reclaimed pages to)
GOODPUT_POOL_PAGES = 8  # ~2.6 typical concurrent requests (3 pages each)
GOODPUT_SLO_TTFT_MULT = 2.5    # x unloaded TTFT p95
GOODPUT_SLO_ITL_MULT = 8.0     # x unloaded inter-token p95
GOODPUT_BURST_OVER = 2.0       # burst-cell base arrival rate, x sustainable
GOODPUT_ROOFLINE_SLACK = 1.25  # run-to-run variance allowance vs roofline
GOODPUT_POLICY_KW = dict(drr=True, max_consecutive_prefill_ticks=2,
                         preemption=True, admission_low_water=0.15,
                         admission_shed_priority=2)


def bench_goodput_cell(*, requests: int) -> dict:
    """Open-loop SLO goodput: calibrate, then steady + burst cells.

    Calibration replays a closed-loop workload (rate ~ inf: every arrival
    due immediately) to measure the machine's capacity tokens/s, and an
    n=slots workload (no queue wait) for its unloaded latency percentiles;
    SLOs and the sustainable request rate derive from those, so the cell
    asks the same question on any machine. The steady cell (0.5x
    sustainable) must mostly meet SLO; the burst cell replays ONE seeded
    event schedule twice — FIFO baseline vs the SLO-aware policy — at
    >= 2x sustainable arrivals, and the policy must strictly improve
    priority-0 TTFT attainment (preemption + shed keep the premium class
    inside its SLO by sacrificing the shed class). Measured goodput is
    cross-checked against ``core.perfmodel.decode_roofline`` on a profile
    calibrated from the same capacity run: goodput can only ever lose to
    the roofline — queueing and SLO misses subtract."""
    import dataclasses as _dc

    import numpy as np

    from repro.core.perfmodel import FitConstants, decode_roofline
    from repro.core.strategies import ZCU104
    from repro.serve.engine import ServeEngine
    from repro.serve.metrics import SLO
    from repro.serve.scheduler import SchedPolicy
    from repro.serve.workload import WorkloadSpec, generate, replay

    policy = SchedPolicy(**GOODPUT_POLICY_KW)

    def build(pol):
        return ServeEngine.build(
            PAGED_ARCH, reduced=True, batch_slots=GOODPUT_SLOTS,
            s_max=GOODPUT_S_MAX, page_size=GOODPUT_PAGE,
            num_pages=GOODPUT_POOL_PAGES, policy=pol, seed=0)

    # generations are LONG relative to prefill (median 10 decode ticks) so a
    # running low-priority request holds its pages long enough that FIFO's
    # no-skip-ahead deferral visibly delays a premium arrival — the regime
    # preemption and shedding exist for. The premium class is the MINORITY
    # (20%): preemption only fires when the running slots hold
    # lower-priority work, so a p0-dominated mix would leave it nothing to
    # evict and the two replays would converge
    lengths = dict(prompt_len_median=24, prompt_len_sigma=0.5,
                   prompt_len_max=48, gen_len_median=10, gen_len_sigma=0.5,
                   gen_len_max=24,
                   priority_weights=((0, 0.2), (1, 0.2), (2, 0.6)))
    probe = build(None)
    vocab = probe.cfg.vocab_size
    n_params = probe.cfg.active_params()

    n_cal = max(12, requests)
    cal_events = generate(WorkloadSpec(n_requests=n_cal, rate_rps=1e9,
                                       seed=0, **lengths), vocab)
    replay(build(policy), cal_events)               # warm (compile both paths)
    cap = replay(build(None), cal_events)
    capacity = cap["throughput_tokens_per_s"]
    # unloaded percentiles: n=2 so BOTH requests admit instantly (2 typical
    # requests fit the pool together) — at n=slots the pool itself queues
    # the tail and the "unloaded" p95 silently absorbs the very wait the
    # SLO is supposed to bound
    un_events = generate(WorkloadSpec(n_requests=2, rate_rps=1e9,
                                      seed=1, **lengths), vocab)
    un = replay(build(None), un_events)
    mean_gen = float(np.mean([e.gen_len for e in cal_events]))
    sustainable_rps = capacity / max(mean_gen, 1.0)
    # floors guard against sub-clock-granularity SLOs only — anything
    # larger would detach the SLO from the machine on fast hosts (a 50 ms
    # floor was observed to swallow the whole burst backlog and gate
    # nothing once the host sped up 4x)
    slo = SLO(ttft_s=max(GOODPUT_SLO_TTFT_MULT * un["ttft_s"]["p95"], 0.02),
              itl_p95_s=max(GOODPUT_SLO_ITL_MULT * un["itl_s"]["p95"], 0.005))
    print(f"[goodput] capacity {capacity:.1f} tok/s, sustainable "
          f"{sustainable_rps:.1f} rps, SLO ttft<={slo.ttft_s * 1e3:.0f}ms "
          f"itl-p95<={slo.itl_p95_s * 1e3:.0f}ms")

    steady = replay(build(policy),
                    generate(WorkloadSpec(n_requests=n_cal,
                                          rate_rps=0.5 * sustainable_rps,
                                          seed=2, **lengths), vocab), slo)
    burst_events = generate(
        WorkloadSpec(n_requests=max(32, 2 * n_cal),
                     rate_rps=GOODPUT_BURST_OVER * sustainable_rps, seed=3,
                     burst_start_frac=0.1, burst_len_frac=0.5,
                     burst_mult=2.5, **lengths), vocab)
    # shape-warm the burst path (preempt-resume prompts, narrow admission
    # groups) so neither measured replay pays a compile stall mid-flight —
    # a single XLA compile is longer than the whole TTFT SLO
    replay(build(policy), burst_events)
    fifo = replay(build(None), burst_events, slo)
    slo_run = replay(build(policy), burst_events, slo)

    def _p0_ttft(s):
        by = s["goodput"]["by_priority"]
        return by.get("0", {"ttft_attainment": 1.0})["ttft_attainment"]

    p0_fifo, p0_slo = _p0_ttft(fifo), _p0_ttft(slo_run)

    # roofline cross-check: a profile whose peak delivers exactly the
    # machine's best observed decode rate at efficiency 1 (memory
    # unbounded), so decode_roofline(n_params) == that rate — open-loop
    # goodput must stay under it modulo run-to-run variance. Calibrated
    # from the max across ALL replays, not the capacity run alone: on a
    # shared host the capacity sample can land in a slow moment and a
    # later replay would "beat" a ceiling that was never the machine's
    peak_rate = max(capacity, *(s["throughput_tokens_per_s"]
                                for s in (steady, fifo, slo_run)))
    host = _dc.replace(ZCU104, name="host-calibrated",
                       peak_flops=peak_rate * 2.0 * n_params)
    roof = decode_roofline(n_params, host,
                           FitConstants(efficiency=1.0, bw_slow=1e18,
                                        bw_fast=1e18, block_overhead=0.0))
    best_goodput = max(s["goodput"]["goodput_tokens_per_s"]
                       for s in (steady, fifo, slo_run))

    def _trim(s):
        return {"requests": s["requests"], "completed": s["completed"],
                "aborted": s["aborted"], "shed_requests": s["shed_requests"],
                "preemptions": s["preemptions"],
                "starvation_guard_skips": s["starvation_guard_skips"],
                "throughput_tokens_per_s": s["throughput_tokens_per_s"],
                "ttft_p95_s": s["ttft_s"]["p95"],
                "itl_p95_s": s["itl_s"]["p95"],
                "goodput": s["goodput"]}

    print(f"[goodput] steady attainment "
          f"{steady['goodput']['slo_attainment']:.2f} | burst p0 TTFT "
          f"attainment fifo {p0_fifo:.2f} -> slo {p0_slo:.2f} "
          f"(shed {slo_run['shed_requests']}, preempt "
          f"{slo_run['preemptions']}) | goodput "
          f"{slo_run['goodput']['goodput_tokens_per_s']:.1f} tok/s vs "
          f"roofline {roof['tokens_per_s']:.1f}")
    return {
        "arch": f"{PAGED_ARCH} (reduced)",
        "batch_slots": GOODPUT_SLOTS,
        "page_size": GOODPUT_PAGE,
        "num_pages": GOODPUT_POOL_PAGES,
        "s_max": GOODPUT_S_MAX,
        "policy": dict(GOODPUT_POLICY_KW),
        "slo": {"ttft_s": slo.ttft_s, "itl_p95_s": slo.itl_p95_s,
                "ttft_mult": GOODPUT_SLO_TTFT_MULT,
                "itl_mult": GOODPUT_SLO_ITL_MULT},
        "calibration": {"capacity_tokens_per_s": capacity,
                        "unloaded_ttft_p95_s": un["ttft_s"]["p95"],
                        "unloaded_itl_p95_s": un["itl_s"]["p95"],
                        "mean_gen_len": mean_gen,
                        "sustainable_rps": sustainable_rps},
        "cells": [
            dict(cell="steady", rate_x_sustainable=0.5, policy_on=True,
                 **_trim(steady)),
            dict(cell="burst", rate_x_sustainable=GOODPUT_BURST_OVER,
                 policy_on=False, **_trim(fifo)),
            dict(cell="burst", rate_x_sustainable=GOODPUT_BURST_OVER,
                 policy_on=True, **_trim(slo_run)),
        ],
        "roofline": roof,
        "acceptance": {
            "cell": (f"slots={GOODPUT_SLOTS}, pool={GOODPUT_POOL_PAGES} "
                     f"pages, burst {GOODPUT_BURST_OVER}x sustainable"),
            "steady_slo_attainment": steady["goodput"]["slo_attainment"],
            "passes_steady_slo": steady["goodput"]["slo_attainment"] >= 0.75,
            "p0_ttft_attainment_fifo": p0_fifo,
            "p0_ttft_attainment_slo": p0_slo,
            # a saturated baseline (FIFO already at ~1.0 attainment on a
            # fast runner) leaves no headroom for a strict gain; treat
            # both-saturated as a pass so the flag stays run-stable
            "passes_slo_gain": (p0_slo > p0_fifo
                                or (p0_fifo >= 0.999 and p0_slo >= 0.999)),
            "goodput_tokens_per_s":
                slo_run["goodput"]["goodput_tokens_per_s"],
            "roofline_tokens_per_s": roof["tokens_per_s"],
            "passes_roofline_bound":
                best_goodput <= GOODPUT_ROOFLINE_SLACK * roof["tokens_per_s"],
        },
    }


# tp cell: the tensor-parallel mesh engine (PR 8) on a FORCED-8-DEVICE host
# mesh. XLA fixes the process device count at backend init, so the mesh runs
# in a subprocess probe (the conftest run_multidevice pattern) and the parent
# stays single-device. reduced qwen collapses kv heads to 1 (nothing to
# shard), so the probe overrides the head counts back to 8h/4kv — GQA G=2 —
# while staying reduced everywhere else. The contract is BITWISE: tp shards
# only the KV pool + paged attention core and all-gathers heads before the
# output projection, so every tp's greedy stream must EQUAL the mesh-free
# engine's, and per-shard resident pool bytes must be exactly global/tp.
TP_OVERRIDES = {"num_heads": 8, "num_kv_heads": 4}
TP_PAGE = 16
TP_S_MAX = 64
TP_SLOTS = 4
TP_DEVICES = 8
TP_GEN_LEN = 8
TP_PROMPT_LENS = (19, 35, 24, 7)
TP_REPS = 2


def _tp_probe(spec: dict) -> None:
    """Subprocess half of the tp cell (hidden ``--tp-probe`` mode): runs
    under XLA_FLAGS=--xla_force_host_platform_device_count=8, builds the
    mesh-free anchor plus one engine per tp degree, and prints one
    machine-readable result line the parent parses."""
    import numpy as np

    from repro.serve.engine import ServeEngine

    rng = np.random.default_rng(0)
    lens = [TP_PROMPT_LENS[i % len(TP_PROMPT_LENS)]
            for i in range(spec["requests"])]
    prompts = [rng.integers(1, 400, n).astype(np.int32) for n in lens]
    gen_len = spec["gen_len"]

    def run(tp):
        eng = ServeEngine.build(PAGED_ARCH, reduced=True,
                                batch_slots=TP_SLOTS, s_max=TP_S_MAX,
                                page_size=TP_PAGE, cfg_overrides=TP_OVERRIDES,
                                tp=tp, seed=0)
        rs = [eng.submit(p, gen_len) for p in prompts]
        t0 = time.time()
        eng.run()
        wall = time.time() - t0
        assert all(r.error is None for r in rs), [r.error for r in rs]
        decode_wall = max(wall - eng.metrics.prefill_wall_s, 1e-9)
        return {"tokens": [r.tokens for r in rs],
                "decode_tokens_per_s": len(prompts) * gen_len / decode_wall,
                "per_shard_kv_bytes": eng.per_shard_kv_bytes()}

    def best_of(tp):
        first = run(tp)                           # warm (compile)
        runs = [first] + [run(tp) for _ in range(TP_REPS - 1)]
        best = max(runs, key=lambda r: r["decode_tokens_per_s"])
        best["tokens"] = first["tokens"]          # deterministic anyway
        return best

    # int8/mla companion rows (sharding-aware backend seam): one run per
    # (backend, tp) — these rows gate representation facts (prefix match,
    # per-shard bytes), not throughput, so no best-of reps
    def run_backend(arch, backend, overrides, tp):
        eng = ServeEngine.build(arch, reduced=True, batch_slots=TP_SLOTS,
                                s_max=TP_S_MAX, page_size=TP_PAGE,
                                kv_backend=backend, cfg_overrides=overrides,
                                tp=tp, seed=0)
        rs = [eng.submit(p, gen_len) for p in prompts]
        eng.run()
        assert all(r.error is None for r in rs), [r.error for r in rs]
        return {"tokens": [r.tokens for r in rs],
                "per_shard_kv_bytes": eng.per_shard_kv_bytes()}

    out = {"plain": best_of(None),
           "runs": {str(tp): best_of(tp) for tp in spec["tps"]},
           "int8": {str(tp): run_backend(PAGED_ARCH, "paged_int8",
                                         TP_OVERRIDES, tp)
                    for tp in (1, 2)},
           "mla": {str(tp): run_backend(MLA_ARCH, "paged_latent", None, tp)
                   for tp in (1, 2)}}
    print("TP_PROBE_RESULT " + json.dumps(out))


def bench_tp_cell(tps, *, requests: int) -> dict:
    import os
    import subprocess
    import sys

    repo = pathlib.Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count="
                          f"{TP_DEVICES}").strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = (str(repo / "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    spec = json.dumps({"tps": list(tps), "requests": requests,
                       "gen_len": TP_GEN_LEN})
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.serve_bench", "--tp-probe", spec],
        cwd=repo, env=env, capture_output=True, text=True, timeout=2400)
    if proc.returncode != 0:
        raise RuntimeError(f"tp probe failed (rc={proc.returncode}):\n"
                           f"{proc.stdout}\n{proc.stderr}")
    line = next(ln for ln in proc.stdout.splitlines()
                if ln.startswith("TP_PROBE_RESULT "))
    res = json.loads(line[len("TP_PROBE_RESULT "):])

    base = res["runs"]["1"]
    plain = res["plain"]
    cells = []
    greedy_ok = plain["tokens"] == base["tokens"]
    shard_ok = True
    for tp in tps:
        r = res["runs"][str(tp)]
        greedy_ok = greedy_ok and r["tokens"] == plain["tokens"]
        ratio = r["per_shard_kv_bytes"] / max(base["per_shard_kv_bytes"], 1)
        shard_ok = shard_ok and r["per_shard_kv_bytes"] * tp == \
            base["per_shard_kv_bytes"]
        cells.append({"tp": tp,
                      "decode_tokens_per_s": r["decode_tokens_per_s"],
                      "per_shard_kv_bytes": r["per_shard_kv_bytes"],
                      "kv_bytes_ratio_vs_tp1": ratio})
        print(f"tp={tp} [tp]: decode {r['decode_tokens_per_s']:8.1f} tok/s | "
              f"per-shard KV {r['per_shard_kv_bytes']:>9d} B "
              f"({ratio:.3f}x tp=1)")
    # int8 row: per-page per-SHARD scale groups mean tp=2 is NOT bitwise vs
    # tp=1 (finer amax granularity rounds differently) — gate the mean
    # greedy prefix match instead; per-shard bytes land just above 1/2
    # (the int8 pool halves exactly, each shard keeps its own (L, P, 1)
    # scale column)
    def _match_frac(a, b):
        n = 0
        for x, y in zip(a, b):
            if x != y:
                break
            n += 1
        return n / max(len(a), len(b), 1)

    i1, i2 = res["int8"]["1"], res["int8"]["2"]
    fr = [_match_frac(a, b) for a, b in zip(i2["tokens"], i1["tokens"])]
    int8_match = sum(fr) / len(fr)
    int8_ratio = i2["per_shard_kv_bytes"] / max(i1["per_shard_kv_bytes"], 1)
    tp_int8 = {
        "greedy_prefix_match_mean": int8_match,
        "per_shard_kv_bytes_ratio": int8_ratio,
        "passes_greedy_match": int8_match >= 0.6,
        "passes_shard_bytes": int8_ratio <= 0.55,
    }
    print(f"tp=2 [tp_int8]: greedy prefix match {int8_match:.3f} "
          f"(passes: {tp_int8['passes_greedy_match']}); per-shard KV "
          f"{int8_ratio:.3f}x tp=1 (passes: {tp_int8['passes_shard_bytes']})")

    # mla row: the latent pool REPLICATES (tp shards the absorbed head
    # axis instead), so the expected per-shard bytes ratio is exactly 1.0
    # and the greedy contract is BITWISE
    m1, m2 = res["mla"]["1"], res["mla"]["2"]
    mla_ratio = m2["per_shard_kv_bytes"] / max(m1["per_shard_kv_bytes"], 1)
    tp_mla = {
        "per_shard_kv_bytes_ratio": mla_ratio,
        "passes_greedy_match": m2["tokens"] == m1["tokens"],
        "passes_replicated_pool": mla_ratio == 1.0,
    }
    print(f"tp=2 [tp_mla]: greedy bitwise match "
          f"{tp_mla['passes_greedy_match']}; latent pool per-shard "
          f"{mla_ratio:.3f}x tp=1 (replicated: "
          f"{tp_mla['passes_replicated_pool']})")

    # the gated ratio is pinned to tp=2 (present in quick AND full runs, the
    # same pin-the-workload rationale as the prefix cell); the boolean flag
    # still checks exact global/tp at EVERY measured degree
    pinned = next(c for c in cells if c["tp"] == 2)
    return {
        "arch": f"{PAGED_ARCH} (reduced, heads {TP_OVERRIDES['num_heads']}/"
                f"{TP_OVERRIDES['num_kv_heads']}kv)",
        "page_size": TP_PAGE,
        "s_max": TP_S_MAX,
        "devices": TP_DEVICES,
        "plain_decode_tokens_per_s": plain["decode_tokens_per_s"],
        "cells": cells,
        "tp_int8": tp_int8,
        "tp_mla": tp_mla,
        "acceptance": {
            "cell": f"tp=2 of {sorted(tps)}, {TP_DEVICES} host devices",
            "passes_greedy_match": greedy_ok,
            "per_shard_kv_bytes_ratio": pinned["kv_bytes_ratio_vs_tp1"],
            "passes_shard_bytes": shard_ok,
        },
    }


# router cell: the prefix-affinity replica tier (serve/router.py) vs blind
# round-robin on IDENTICAL shared-header traffic — the workload the router
# exists for. batch_slots=1 serializes each replica so prefix registration
# is deterministic (a request's pages are indexed before the next admits):
# under affinity every measured request lands where its header is already
# cached; under round-robin half of each group lands on a replica that has
# never seen the header and pays a full prefill. The rate is the prefix
# cell's EFFECTIVE prefill tokens/s — logical prompt tokens ingested over
# the tier's summed prefill wall.
ROUTER_REPLICAS = 2
ROUTER_GROUPS = 4
ROUTER_PER_GROUP = 2
ROUTER_PROMPT = 128
ROUTER_OVERLAP = 96          # 75% shared header = 6 full pages
ROUTER_SLOTS = 1
ROUTER_GEN_LEN = 1
ROUTER_POOL_PAGES = 64       # generous: the comparison is affinity, not LRU
ROUTER_REPS = 2


def bench_router_cell() -> dict:
    import numpy as np

    from repro.serve.engine import ServeEngine
    from repro.serve.router import ReplicaRouter

    rng = np.random.default_rng(0)

    def run_once(affinity: bool) -> dict:
        engines = [ServeEngine.build(
            PAGED_ARCH, reduced=True, batch_slots=ROUTER_SLOTS,
            s_max=PAGED_S_MAX, page_size=PAGE_SIZE,
            num_pages=ROUTER_POOL_PAGES, seed=0)
            for _ in range(ROUTER_REPLICAS)]
        router = ReplicaRouter(engines, affinity=affinity)
        vocab = engines[0].cfg.vocab_size
        headers = [rng.integers(0, vocab, ROUTER_OVERLAP).astype(np.int32)
                   for _ in range(ROUTER_GROUPS)]
        # group-major order: consecutive same-group submissions, so blind
        # round-robin NECESSARILY splits every group across both replicas
        # (an interleaved order can accidentally align the rr cursor's
        # parity with the warm placement and hand rr free hits)
        prompts = [np.concatenate(
            [headers[g],
             rng.integers(0, vocab,
                          ROUTER_PROMPT - ROUTER_OVERLAP).astype(np.int32)])
            for g in range(ROUTER_GROUPS) for _ in range(ROUTER_PER_GROUP)]
        # prior traffic: one header-only request per group, routed by the
        # SAME policy under test — affinity files it where later requests
        # will look, round-robin spreads it blindly
        for h in headers:
            router.submit(h, 1)
        router.drain()
        w0 = sum(e.metrics.prefill_wall_s for e in engines)
        for p in prompts:
            router.submit(p, ROUTER_GEN_LEN)
        router.drain()
        wall = sum(e.metrics.prefill_wall_s for e in engines) - w0
        hits = sum(e.metrics.prefix_hits for e in engines)
        lookups = sum(e.metrics.prefix_lookups for e in engines)
        return {"eff_tokens_per_s":
                len(prompts) * ROUTER_PROMPT / max(wall, 1e-9),
                "hit_rate": hits / max(lookups, 1),
                "routed": list(router.routed),
                "affine": router.affine}

    def best_of(affinity: bool) -> dict:
        run_once(affinity)                        # warm (compile)
        runs = [run_once(affinity) for _ in range(ROUTER_REPS)]
        return max(runs, key=lambda r: r["eff_tokens_per_s"])

    rr = best_of(False)
    aff = best_of(True)
    speedup = aff["eff_tokens_per_s"] / max(rr["eff_tokens_per_s"], 1e-9)
    print(f"replicas={ROUTER_REPLICAS} overlap={ROUTER_OVERLAP} [router]: "
          f"round-robin {rr['eff_tokens_per_s']:9.1f} tok/s (hit "
          f"{rr['hit_rate']:.2f}) | affinity {aff['eff_tokens_per_s']:9.1f} "
          f"tok/s (hit {aff['hit_rate']:.2f}) | {speedup:.2f}x")
    return {
        "arch": f"{PAGED_ARCH} (reduced)",
        "replicas": ROUTER_REPLICAS,
        "page_size": PAGE_SIZE,
        "prompt_len": ROUTER_PROMPT,
        "overlap_tokens": ROUTER_OVERLAP,
        "overlap_frac": ROUTER_OVERLAP / ROUTER_PROMPT,
        "header_groups": ROUTER_GROUPS,
        "requests_per_group": ROUTER_PER_GROUP,
        "round_robin_prefill_tokens_per_s": rr["eff_tokens_per_s"],
        "affinity_prefill_tokens_per_s": aff["eff_tokens_per_s"],
        "round_robin_hit_rate": rr["hit_rate"],
        "affinity_hit_rate": aff["hit_rate"],
        "affinity_routed": aff["routed"],
        "acceptance": {
            "cell": (f"{ROUTER_REPLICAS} replicas, "
                     f"{ROUTER_OVERLAP}/{ROUTER_PROMPT} header overlap"),
            "affinity_speedup": speedup,
            "passes_affinity_gain": speedup > 1.0,
        },
    }


SECTIONS = ("core", "paged", "prefill", "prefix", "prefill_paged",
            "kv_quant", "mla", "goodput", "tp", "router")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="only the acceptance cells (slots=4, prompt=32)")
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--sections", default=None,
                    help="comma-separated subset of "
                         f"{','.join(SECTIONS)}; reruns only those and "
                         "merges into the existing BENCH_serve.json")
    ap.add_argument("--tp-probe", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()

    if args.tp_probe:
        _tp_probe(json.loads(args.tp_probe))
        return

    if args.sections:
        want = {s.strip() for s in args.sections.split(",") if s.strip()}
        unknown = want - set(SECTIONS)
        if unknown:
            raise SystemExit(f"unknown sections {sorted(unknown)}; "
                             f"choose from {', '.join(SECTIONS)}")
        out = json.loads(OUT.read_text()) if OUT.exists() else {}
    else:
        want = set(SECTIONS)
        out = {}

    if "core" in want:
        cells = [(4, 32)] if args.quick else [
            (2, 8), (2, 32), (4, 8), (4, 32), (4, 64), (8, 32)]
        results = [bench_cell(bs, pl, requests=args.requests,
                              gen_len=args.gen_len)
                   for bs, pl in cells]
        accept = next(r for r in results
                      if r["batch_slots"] == 4 and r["prompt_len"] == 32)
        out["arch"] = "hymba-1.5b (reduced)"
        out["device"] = "cpu"
        out["cells"] = results
        out["acceptance"] = {
            "cell": "batch_slots=4, prompt_len=32",
            "speedup": accept["speedup"],
            "passes_2x": accept["speedup"] >= 2.0,
        }
        print(f"core: acceptance speedup {accept['speedup']:.2f}x, >=2x: "
              f"{out['acceptance']['passes_2x']}")

    if "paged" in want:
        paged_cells = [(4, 32)] if args.quick else [
            (4, 32), (4, 128), (8, 32), (8, 128)]
        paged_results = [bench_paged_cell(bs, pl, requests=args.requests,
                                          gen_len=args.gen_len)
                         for bs, pl in paged_cells]
        paged_accept = next(r for r in paged_results
                            if r["batch_slots"] == 4
                            and r["prompt_len"] == 32)
        out["paged"] = {
            "arch": f"{PAGED_ARCH} (reduced)",
            "page_size": PAGE_SIZE,
            "s_max": PAGED_S_MAX,
            "cells": paged_results,
            "acceptance": {
                "cell": "batch_slots=4, prompt_len=32",
                "resident_bytes_ratio": paged_accept["resident_bytes_ratio"],
                "passes_memory_drop":
                    paged_accept["resident_bytes_ratio"] < 1.0,
            },
        }
        print(f"paged: resident bytes "
              f"{paged_accept['resident_bytes_ratio']:.2f}x of dense, drop: "
              f"{out['paged']['acceptance']['passes_memory_drop']}")

    if "prefill" in want:
        prefill_cells = [128] if args.quick else [32, 128, 256]
        prefill_results = [bench_prefill_cell(pl, requests=args.requests,
                                              gen_len=4)
                           for pl in prefill_cells]
        prefill_accept = next(r for r in prefill_results
                              if r["prompt_len"] == 128)
        out["prefill"] = {
            "arch": f"{PAGED_ARCH} (reduced)",
            "cells": prefill_results,
            "acceptance": {
                "cell": "prompt_len=128",
                "speedup": prefill_accept["speedup"],
                "passes_2x": prefill_accept["speedup"] >= 2.0,
            },
        }
        print(f"prefill: parallel {prefill_accept['speedup']:.2f}x scan at "
              f"prompt 128, >=2x: "
              f"{out['prefill']['acceptance']['passes_2x']}")

    if "prefix" in want:
        # prefix caching: (prompt_len, shared header tokens) — the
        # acceptance cell is prompt 128 at 75% overlap (>= the 50% bar),
        # the production few-shot-header pattern
        prefix_cells = [(128, 96)] if args.quick else [(128, 64), (128, 96),
                                                       (128, 112)]
        prefix_results = [bench_prefix_cell(pl, ov, requests=args.requests,
                                            gen_len=4)
                          for pl, ov in prefix_cells]
        prefix_accept = next(r for r in prefix_results
                             if r["prompt_len"] == 128 and
                             r["overlap_tokens"] == 96)
        out["prefix"] = {
            "arch": f"{PAGED_ARCH} (reduced)",
            "page_size": PAGE_SIZE,
            "cells": prefix_results,
            "acceptance": {
                "cell": (f"prompt_len=128, overlap="
                         f"{prefix_accept['overlap_tokens']} "
                         f"({prefix_accept['overlap_frac']:.0%})"),
                "speedup": prefix_accept["speedup"],
                "hit_rate": prefix_accept["hit_rate"],
                "passes_2x": prefix_accept["speedup"] >= 2.0,
            },
        }
        print(f"prefix: cached prefill {prefix_accept['speedup']:.2f}x "
              f"uncached at {prefix_accept['overlap_frac']:.0%} overlap, "
              f">=2x: {out['prefix']['acceptance']['passes_2x']}")

    if "prefill_paged" in want:
        pkern_cells = [128] if args.quick else [64, 128]
        pkern_results = [bench_prefill_paged_cell(pl, requests=args.requests,
                                                  gen_len=4)
                         for pl in pkern_cells]
        pkern_accept = next(r for r in pkern_results
                            if r["prompt_len"] == 128)
        out["prefill_paged"] = {
            "arch": f"{PAGED_ARCH} (reduced)",
            "s_max": PKERN_S_MAX,
            "page_size": PKERN_PAGE,
            "cells": pkern_results,
            "acceptance": {
                "cell": f"prompt_len=128, s_max={PKERN_S_MAX}",
                "speedup": pkern_accept["speedup"],
                "passes_1_5x": pkern_accept["speedup"] >= 1.5,
                "transient_bytes": pkern_accept
                ["kernel_transient_cache_bytes"],
                "passes_transient_bound": (
                    pkern_accept["kernel_transient_cache_bytes"]
                    <= pkern_accept["one_chunk_bytes_bound"]),
            },
        }
        print(f"prefill_paged: kernel {pkern_accept['speedup']:.2f}x einsum "
              f"at prompt 128, >=1.5x: "
              f"{out['prefill_paged']['acceptance']['passes_1_5x']}; "
              f"transient bytes "
              f"{pkern_accept['kernel_transient_cache_bytes']} (bound "
              f"{pkern_accept['one_chunk_bytes_bound']})")

    if "kv_quant" in want:
        kvq_cells = [32] if args.quick else [32, 128]
        kvq_results = [bench_kv_quant_cell(pl, requests=args.requests,
                                           gen_len=args.gen_len)
                       for pl in kvq_cells]
        kvq_accept = kvq_results[0]
        out["kv_quant"] = {
            "arch": f"{PAGED_ARCH} (reduced)",
            "page_size": KVQ_PAGE,
            "s_max": KVQ_S_MAX,
            "cells": kvq_results,
            "acceptance": {
                "cell": f"prompt_len={kvq_accept['prompt_len']}, "
                        f"page_size={KVQ_PAGE}",
                "resident_bytes_ratio": kvq_accept["resident_bytes_ratio"],
                "passes_bytes_ratio":
                    kvq_accept["resident_bytes_ratio"] <= 0.30,
                "greedy_prefix_match_mean":
                    kvq_accept["greedy_prefix_match_mean"],
                "passes_divergence_bound":
                    kvq_accept["greedy_prefix_match_mean"] >= 0.6,
                # informational on CPU: interpret-mode dequant dominates;
                # the HBM-stream win this tracks is a TPU property
                "decode_speed_ratio": kvq_accept["decode_speed_ratio"],
            },
        }
        ka = out["kv_quant"]["acceptance"]
        print(f"kv_quant: int8 resident KV {ka['resident_bytes_ratio']:.2f}x"
              f" fp32 (<=0.30: {ka['passes_bytes_ratio']}); greedy prefix "
              f"match {ka['greedy_prefix_match_mean']:.2f} (>=0.6: "
              f"{ka['passes_divergence_bound']}); decode speed ratio "
              f"{ka['decode_speed_ratio']:.2f}x")

    if "mla" in want:
        mla_cells = [32] if args.quick else [32, 128]
        mla_results = [bench_mla_cell(pl, requests=args.requests,
                                      gen_len=args.gen_len)
                       for pl in mla_cells]
        mla_accept = mla_results[0]
        out["mla"] = {
            "arch": f"{MLA_ARCH} (reduced) vs {PAGED_ARCH} (reduced)",
            "page_size": MLA_PAGE,
            "s_max": MLA_S_MAX,
            "cells": mla_results,
            "acceptance": {
                "cell": f"prompt_len={mla_accept['prompt_len']}, "
                        f"page_size={MLA_PAGE}",
                "resident_bytes_ratio": mla_accept["resident_bytes_ratio"],
                "passes_bytes_ratio":
                    mla_accept["resident_bytes_ratio"] <= 0.35,
                "greedy_prefix_match_mean":
                    mla_accept["greedy_prefix_match_mean"],
                "passes_divergence_bound":
                    mla_accept["greedy_prefix_match_mean"] >= 0.6,
                # informational on CPU: the absorb-path einsum runs under
                # interpret; the smaller-KV-stream decode win is a TPU
                # property, same caveat as the kv_quant cell
                "decode_speed_ratio": mla_accept["decode_speed_ratio"],
            },
        }
        ma = out["mla"]["acceptance"]
        print(f"mla: latent resident KV {ma['resident_bytes_ratio']:.2f}x "
              f"fp32 (<=0.35: {ma['passes_bytes_ratio']}); greedy prefix "
              f"match vs dense {ma['greedy_prefix_match_mean']:.2f} (>=0.6: "
              f"{ma['passes_divergence_bound']}); decode speed ratio "
              f"{ma['decode_speed_ratio']:.2f}x")

    if "goodput" in want:
        # one goodput cell in both modes: the section is self-calibrating,
        # so quick runs still produce every gated flag
        out["goodput"] = bench_goodput_cell(requests=args.requests)
        ga = out["goodput"]["acceptance"]
        print(f"goodput: steady attainment "
              f"{ga['steady_slo_attainment']:.2f} "
              f"(passes: {ga['passes_steady_slo']}); burst p0 TTFT "
              f"attainment {ga['p0_ttft_attainment_fifo']:.2f} -> "
              f"{ga['p0_ttft_attainment_slo']:.2f} (gain: "
              f"{ga['passes_slo_gain']}); goodput "
              f"{ga['goodput_tokens_per_s']:.1f} tok/s <= roofline "
              f"{ga['roofline_tokens_per_s']:.1f} x "
              f"{GOODPUT_ROOFLINE_SLACK} "
              f"(passes: {ga['passes_roofline_bound']})")

    if "tp" in want:
        tps = (1, 2) if args.quick else (1, 2, 4)
        out["tp"] = bench_tp_cell(tps, requests=min(args.requests, 4))
        ta = out["tp"]["acceptance"]
        print(f"tp: greedy bitwise match across tp={sorted(tps)}: "
              f"{ta['passes_greedy_match']}; per-shard KV at tp=2 "
              f"{ta['per_shard_kv_bytes_ratio']:.3f}x tp=1 (exact 1/tp "
              f"everywhere: {ta['passes_shard_bytes']})")

    if "router" in want:
        out["router"] = bench_router_cell()
        ra = out["router"]["acceptance"]
        print(f"router: prefix-affinity {ra['affinity_speedup']:.2f}x "
              f"round-robin effective prefill at 75% overlap (gain: "
              f"{ra['passes_affinity_gain']})")

    OUT.write_text(json.dumps(out, indent=2))
    print(f"wrote {OUT} (sections: "
          f"{', '.join(s for s in SECTIONS if s in want)})")


if __name__ == "__main__":
    main()
