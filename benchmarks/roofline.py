"""Roofline analysis from the dry-run artifacts (deliverable (g)).

Composition (DESIGN.md §5): XLA counts scan bodies once, so
    total(metric) = gate(metric) + sum_probes mult * probe(metric) + extras
with every quantity PER-DEVICE (cost_analysis of an SPMD-partitioned program
reports the per-device program; verified against a hand-counted matmul).

Terms (TPU v5e):
    compute_s    = flops_per_chip / 197e12        (bf16 peak)
    memory_s     = bytes_per_chip / 819e9         (HBM)
    collective_s = coll_bytes_per_chip / 50e9     (ICI per link)
These equal the assignment's global/(chips*rate) forms.

MODEL_FLOPS uses 6*N_active*tokens for training (2* for prefill/decode), so
MODEL_FLOPS / (HLO_flops * chips) exposes remat/dispatch/attention overhead.
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"

SHAPES_TOKENS = {  # tokens processed per step (global)
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,        # one token x batch
    "long_500k": 1,
}


def load_cells(mesh: str = "pod16x16") -> List[dict]:
    cells = []
    for p in sorted(ART.glob(f"*__{mesh}.json")):
        cells.append(json.loads(p.read_text()))
    return cells


def composed(rec: dict) -> Dict[str, float]:
    g = rec["gate"]
    flops = g["cost"]["flops"]
    bytes_ = g["cost"]["bytes"]
    coll = g["collectives"].get("total", 0)
    for pr in rec.get("probes", []):
        flops += pr["mult"] * pr["cost"]["flops"]
        bytes_ += pr["mult"] * pr["cost"]["bytes"]
        coll += pr["mult"] * pr["collectives"].get("total", 0)
    extra = rec.get("recurrence_extra", {"flops": 0, "bytes": 0})
    chips = rec["chips"]
    flops += extra["flops"] / chips     # analytic extras are global
    bytes_ += extra["bytes"] / chips
    return {"flops": flops, "bytes": bytes_, "coll": coll}


def model_flops(rec: dict) -> float:
    n = rec["params_active"]
    toks = SHAPES_TOKENS[rec["shape"]]
    mult = 6.0 if rec["kind"] == "train" else 2.0
    return mult * n * toks


def analyze(rec: dict) -> dict:
    c = composed(rec)
    chips = rec["chips"]
    terms = {
        "compute_s": c["flops"] / PEAK_FLOPS,
        "memory_s": c["bytes"] / HBM_BW,
        "collective_s": c["coll"] / LINK_BW,
    }
    dominant = max(terms, key=terms.get)
    step_s = max(terms.values())
    mf = model_flops(rec)
    useful_ratio = mf / max(c["flops"] * chips, 1)
    # achievable fraction of the compute roofline at the current bottleneck
    roofline_fraction = terms["compute_s"] / step_s if step_s else 0.0
    mfu = mf / (chips * PEAK_FLOPS * step_s) if step_s else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec["kind"], "chips": chips,
        "microbatches": rec.get("microbatches", 1),
        **{k: round(v * 1e3, 4) for k, v in terms.items()},  # -> ms
        "dominant": dominant.replace("_s", ""),
        "model_flops": mf,
        "hlo_flops_per_chip": c["flops"],
        "useful_ratio": round(useful_ratio, 3),
        "roofline_fraction": round(roofline_fraction, 3),
        "mfu_bound": round(mfu, 4),
        "footprint_gib": round(
            (rec["gate"]["memory"]["argument_bytes"]
             + rec["gate"]["memory"]["temp_bytes"]
             + rec["gate"]["memory"]["output_bytes"]
             - rec["gate"]["memory"]["alias_bytes"]) / 2**30, 2),
    }


ADVICE = {
    ("compute",): "compute-bound: raise MXU occupancy (larger per-chip tiles, "
                  "fewer remat recomputations) or shrink HLO/model flops gap",
    ("memory",): "HBM-bound: cut bytes moved — fuse (flash attention), "
                 "quantize weights/KV to int8, or raise arithmetic intensity "
                 "with larger microbatches",
    ("collective",): "ICI-bound: reshard to cut cross-chip traffic, overlap "
                     "collectives with compute, or compress the reduced tensors",
}


def advice(row: dict) -> str:
    return ADVICE[(row["dominant"],)]


def table(mesh: str = "pod16x16") -> List[dict]:
    return [analyze(r) for r in load_cells(mesh)]


def main():
    rows = table()
    hdr = ["arch", "shape", "mb", "compute_ms", "memory_ms", "coll_ms",
           "dominant", "useful", "roofline_frac", "GiB/dev"]
    print(",".join(hdr))
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        print(f"{r['arch']},{r['shape']},{r['microbatches']},"
              f"{r['compute_s']},{r['memory_s']},{r['collective_s']},"
              f"{r['dominant']},{r['useful_ratio']},{r['roofline_fraction']},"
              f"{r['footprint_gib']}")


if __name__ == "__main__":
    main()
