"""Benchmark harness — one entry per paper artifact (Tables 1-3, Fig. 6)
plus kernel microbenchmarks and the roofline summary.

Output: ``name,us_per_call,derived`` CSV lines per the assignment, grouped by
paper table. Run: PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _timeit(fn, *args, iters=10, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6  # us


def _gemms():
    from repro.configs.resnet20_cifar import CONFIG
    from repro.core.dataflow import Gemm
    from repro.models.resnet import conv_layer_shapes
    return [Gemm(n, m, k, nn, in_elems=m * k // 9 if k % 9 == 0 else m * k,
                 out_elems=m * nn)
            for (n, m, k, nn) in conv_layer_shapes(CONFIG, batch=1)]


def fig6_ladder():
    """Paper Fig. 6: the four-strategy FPS ladder (calibrated model vs paper)."""
    from repro.core import perfmodel as pm
    gemms = _gemms()
    fit = pm.calibrate(gemms)
    rows = []
    for r in pm.ladder(gemms, fit=fit):
        tgt = pm.PAPER_FPS[r.strategy]
        rows.append((f"fig6_{r.strategy}", 1e6 / r.fps,
                     f"fps={r.fps:.2f};paper={tgt};err={100*(r.fps-tgt)/tgt:+.1f}%"))
    return rows


def table2_eval():
    """Paper Table 2: throughput/power across devices. We measure our CPU
    inference, model the ZCU104 (calibrated), and project TPU v5e."""
    from repro.configs.resnet20_cifar import ResNetConfig
    from repro.core import perfmodel as pm
    from repro.core.strategies import TPU_V5E
    from repro.models import resnet
    rows = []
    # measured: this host's CPU running our jitted inference (batch 64)
    cfg = ResNetConfig(widths=(8, 16, 32))
    params = resnet.fold_bn(resnet.init(cfg, jax.random.PRNGKey(0)))
    x = jnp.zeros((64, 32, 32, 3))
    infer = jax.jit(lambda p, x: resnet.forward(p, cfg, x, folded=True))
    us = _timeit(infer, params, x)
    fps = 64 / (us / 1e6)
    flops = sum(g.flops for g in _gemms()) * (8 / 16) ** 2  # width-reduced
    rows.append(("table2_cpu_measured", us,
                 f"fps={fps:.0f};gops={flops*fps/1e9:.1f}"))
    gemms = _gemms()
    fit = pm.calibrate(gemms)
    zcu = pm.evaluate(gemms, "compiler_large_local", fit=fit)
    rows.append(("table2_zcu104_model", 1e6 / zcu.fps,
                 f"fps={zcu.fps:.1f};gops={zcu.gops:.2f};paper_gops={pm.PAPER_GOPS};"
                 f"gops_w={zcu.gops_per_watt:.2f}"))
    v5e = pm.evaluate(gemms, "compiler_large_local", TPU_V5E, pm.V5E_FIT)
    rows.append(("table2_v5e_projection", 1e6 / v5e.fps,
                 f"fps={v5e.fps:.0f};gops={v5e.gops:.0f};gops_w={v5e.gops_per_watt:.1f}"))
    return rows


PAPER_TABLE3 = [  # Work, device, FPS, GOP/s, GOP/s/W (paper Table 3)
    ("ma2017", "arria10", None, 645.25, 30.44),
    ("mei2017", "virtex7", 6.58, 202.42, 1.64),
    ("zhang2019", "zu7ev", None, 290.40, 0.80),
    ("blott2018", "zu3eg", 200.0, 400.0, 39.21),
    ("zhang2020", "virtex7", 6.77, 209.60, 33.16),
    ("li2019", "zynq7010", None, 452.8, 23.20),
    ("suda2016", "stratixv", None, 117.8, 4.56),
    ("paper_ours", "zu7ev", 290.58, 21.12, 4.05),
]


def table3_compare():
    """Paper Table 3: cross-implementation comparison. Static reference rows
    + our calibrated reproduction row."""
    from repro.core import perfmodel as pm
    rows = [(f"table3_{name}", 0.0,
             f"device={dev};fps={fps};gops={gops};gops_w={gw}")
            for (name, dev, fps, gops, gw) in PAPER_TABLE3]
    gemms = _gemms()
    fit = pm.calibrate(gemms)
    ours = pm.evaluate(gemms, "compiler_large_local", fit=fit)
    rows.append(("table3_repro_model", 1e6 / ours.fps,
                 f"device=zu7ev-model;fps={ours.fps:.1f};gops={ours.gops:.2f};"
                 f"gops_w={ours.gops_per_watt:.2f}"))
    return rows


def table1_resources():
    """Paper Table 1 analogue: planner VMEM use per strategy (bytes in place
    of LUT/DSP/BRAM/URAM counts)."""
    from repro.configs.base import MemoryStrategy
    from repro.core.planner import plan_network
    from repro.core.strategies import ZCU104, planner_config
    rows = []
    gemms = _gemms()
    for strat in MemoryStrategy:
        cfgp = planner_config(strat, ZCU104)
        plans = plan_network(gemms, cfgp)
        peak = max(p.vmem_used for p in plans)
        stages = sum(p.stages for p in plans)
        rows.append((f"table1_{strat.value}", 0.0,
                     f"peak_local_bytes={peak};total_stages={stages};"
                     f"budget={cfgp.vmem_budget}"))
    return rows


def kernel_micro():
    """Pallas kernels (interpret mode) wall-time + allclose vs oracle."""
    from repro.kernels import ops, ref
    rows = []
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (256, 256))
    w = jax.random.normal(key, (256, 256))
    for df in ("output_stationary", "weight_stationary", "input_stationary"):
        us = _timeit(lambda: ops.matmul(x, w, block_m=128, block_n=128,
                                        block_k=128, dataflow=df), iters=3)
        ok = bool(np.allclose(np.asarray(ops.matmul(x, w, dataflow=df)),
                              np.asarray(ref.matmul(x, w)), atol=1e-4))
        rows.append((f"kernel_matmul_{df}", us, f"allclose={ok}"))
    q = jax.random.normal(key, (1, 256, 4, 64))
    k = jax.random.normal(key, (1, 256, 2, 64))
    us = _timeit(lambda: ops.flash_attention(q, k, k, block_q=128, block_k=128),
                 iters=3)
    rows.append(("kernel_flash_attention", us, "interpret=True"))
    r = jax.random.normal(key, (1, 64, 2, 16)) * 0.5
    wdec = jax.nn.sigmoid(jax.random.normal(key, (1, 64, 2, 16))) * 0.5 + 0.5
    u = jax.random.normal(key, (2, 16)) * 0.1
    s0 = jnp.zeros((1, 2, 16, 16))
    us = _timeit(lambda: ops.wkv6(r, r, r, wdec, u, s0, chunk=32), iters=3)
    rows.append(("kernel_wkv6", us, "interpret=True"))
    return rows


def roofline_summary():
    """Headline roofline rows per §Roofline (full table in EXPERIMENTS.md)."""
    try:
        from benchmarks import roofline as R
        rows = []
        for r in sorted(R.table(), key=lambda r: -r["roofline_fraction"])[:8]:
            rows.append((f"roofline_{r['arch']}_{r['shape']}", 0.0,
                         f"dominant={r['dominant']};frac={r['roofline_fraction']};"
                         f"compute_ms={r['compute_s']};mem_ms={r['memory_s']};"
                         f"coll_ms={r['collective_s']}"))
        return rows
    except Exception as e:   # artifacts not generated yet
        return [("roofline_missing", 0.0, f"run launch.dryrun first ({e})")]


def main() -> None:
    print("name,us_per_call,derived")
    for section in (fig6_ladder, table2_eval, table3_compare, table1_resources,
                    kernel_micro, roofline_summary):
        for name, us, derived in section():
            print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
