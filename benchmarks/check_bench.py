"""CI bench-regression gate: compare a fresh ``BENCH_serve.json`` against the
committed baseline and FAIL on a >threshold tokens/s regression in any
acceptance cell (previously the bench was informational only — nothing
consumed its trajectory).

Two classes of checks:

* **Relative metrics** (speedups, byte ratios) are machine-independent —
  engine-vs-legacy, parallel-vs-scan, cached-vs-uncached prefix speedups and
  the paged resident-bytes ratio must not regress by more than ``--threshold``
  (default 20%). These are the load-bearing gate.
* **Absolute tokens/s** in the acceptance cells are gated at the LOOSER
  ``--abs-threshold`` (default 50%) and can be skipped entirely with
  ``--relative-only``: the committed baseline comes from a developer
  machine while CI runs on a shared runner of a different machine class —
  same-machine reruns alone have been observed to swing these 25-40%, and
  a cross-class gap stacks on top, so an absolute cross-machine gate would
  train people to ignore a red job. CI therefore passes ``--relative-only``
  (ratios are same-run, machine-independent, and ARE tokens/s comparisons
  of the gated cells); the absolute rows are for same-machine use — a
  developer re-running the bench locally against the committed baseline
  gets the cliff check for free.

``--require-acceptance`` additionally fails if any ``passes_*`` flag in the
fresh result is false (the bench's own absolute bars: >=2x engine speedup,
paged memory drop, >=2x parallel prefill, >=2x prefix-cached prefill).

``--sections a,b`` restricts the gate (rows AND flags) to those bench
sections — the partner of ``serve_bench --sections``, so a CI leg that
reran only part of the bench gates exactly what it measured.

Run: python -m benchmarks.check_bench --baseline BENCH_baseline.json \
         --fresh BENCH_serve.json [--threshold 0.2] [--require-acceptance]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

# (json path, higher_is_better, absolute_rate, threshold_override) — every
# acceptance-cell rate the gate watches. absolute_rate=True rows are raw
# tokens/s (machine-class sensitive, gated at --abs-threshold); False rows
# are same-run ratios gated at --threshold unless overridden.
#
# Why the 0.5 overrides: the wall-clock SPEEDUP rows are ratios of two
# separate engine runs on a shared host, and two healthy runs of identical
# code have been observed to land 2.0x and 3.4x an hour apart (2026-07,
# prefill cell) — a 20% gate on those rows is a flake machine. Their
# absolute floor (>= 2x / >= 1.5x) lives in the passes_* flags that
# --require-acceptance enforces on every fresh run; the relative row only
# needs to catch genuine collapse. The resident-bytes ratio is a
# deterministic function of config and stays at the tight default. Paths
# into the per-section acceptance CELL dictionaries resolved below.
GATED_METRICS = [
    ("acceptance.speedup", True, False, 0.5),
    ("acceptance_cell.engine_tokens_per_s", True, True, None),
    ("paged.acceptance.resident_bytes_ratio", False, False, None),
    ("paged_cell.paged_tokens_per_s", True, True, None),
    ("prefill.acceptance.speedup", True, False, 0.5),
    ("prefill_cell.parallel_prefill_tokens_per_s", True, True, None),
    ("prefix.acceptance.speedup", True, False, 0.5),
    ("prefix_cell.cached_prefill_tokens_per_s", True, True, None),
    ("prefill_paged.acceptance.speedup", True, False, 0.5),
    ("prefill_paged_cell.kernel_prefill_tokens_per_s", True, True, None),
    # kv_quant (PR 7): the bytes ratio is a deterministic function of
    # config (lower is better, tight default threshold) and the greedy
    # prefix-match mean is same-run/same-seed (higher is better); the int8
    # decode rate row is absolute and machine-class sensitive
    ("kv_quant.acceptance.resident_bytes_ratio", False, False, None),
    ("kv_quant.acceptance.greedy_prefix_match_mean", True, False, None),
    ("kv_quant_cell.int8_decode_tokens_per_s", True, True, None),
    # mla (PR 9): the latent-vs-fp32 bytes ratio is a deterministic function
    # of config (lower is better, tight default threshold) and the greedy
    # prefix-match mean vs the dense MLA engine is same-run/same-seed; the
    # latent decode rate row is absolute and machine-class sensitive
    ("mla.acceptance.resident_bytes_ratio", False, False, None),
    ("mla.acceptance.greedy_prefix_match_mean", True, False, None),
    ("mla_cell.latent_decode_tokens_per_s", True, True, None),
    # goodput SLO flags (PR 6): BOOLEAN rows, compared as 0/1 — a
    # True -> False flip under higher_is_better regresses at any threshold.
    # They are machine-independent (relative-only safe): the SLOs are
    # multiples of the SAME machine's measured unloaded percentiles and the
    # slo-gain flag compares two replays of one seeded schedule in one run.
    ("goodput.acceptance.passes_steady_slo", True, False, None),
    ("goodput.acceptance.passes_slo_gain", True, False, None),
    ("goodput.acceptance.passes_roofline_bound", True, False, None),
    ("goodput.acceptance.goodput_tokens_per_s", True, True, None),
    # tensor-parallel serving (PR 8): greedy bitwise equality and the exact
    # global/tp per-shard pool split are BOOLEAN same-run facts (relative-
    # only safe); the pinned tp=2 bytes ratio is a deterministic function
    # of config (lower is better, tight default threshold); the tp=2 decode
    # rate is absolute and machine-class sensitive
    ("tp.acceptance.passes_greedy_match", True, False, None),
    ("tp.acceptance.passes_shard_bytes", True, False, None),
    ("tp.acceptance.per_shard_kv_bytes_ratio", False, False, None),
    ("tp_cell.decode_tokens_per_s", True, True, None),
    # backend x tp rows (sharding-aware KV seam): int8 pages under tp gate
    # the mean greedy prefix match vs tp=1 (per-shard scale groups round
    # differently — bitwise is not the contract) and the just-above-1/2
    # per-shard bytes ratio; the latent row gates bitwise equality and the
    # exactly-1.0 replicated-pool ratio. All same-run facts, relative-safe.
    ("tp.tp_int8.passes_greedy_match", True, False, None),
    ("tp.tp_int8.greedy_prefix_match_mean", True, False, None),
    ("tp.tp_int8.per_shard_kv_bytes_ratio", False, False, None),
    ("tp.tp_mla.passes_greedy_match", True, False, None),
    ("tp.tp_mla.per_shard_kv_bytes_ratio", False, False, None),
    # replica router (PR 8): the affinity-vs-round-robin speedup is a ratio
    # of two tier runs in ONE process (same loosened 0.5 collapse threshold
    # as the other wall-clock speedup rows — its absolute floor is the
    # passes_affinity_gain flag); the affinity rate row is absolute
    ("router.acceptance.passes_affinity_gain", True, False, None),
    ("router.acceptance.affinity_speedup", True, False, 0.5),
    ("router.affinity_prefill_tokens_per_s", True, True, None),
]

# metric-path root -> bench section name, for --sections filtering (the
# split-bench CI legs gate only the sections they just reran)
def _section_of(path: str) -> str:
    root = path.split(".")[0].split("[")[0]
    if root.endswith("_cell"):
        root = root[: -len("_cell")]
    return "core" if root in ("acceptance", "cells") else root


def _acceptance_cells(bench: dict) -> dict:
    """Flatten each section's acceptance CELL into addressable roots."""
    out = dict(bench)
    for cell in bench.get("cells", []):
        if cell.get("batch_slots") == 4 and cell.get("prompt_len") == 32:
            out["acceptance_cell"] = cell
    for cell in bench.get("paged", {}).get("cells", []):
        if cell.get("batch_slots") == 4 and cell.get("prompt_len") == 32:
            out["paged_cell"] = cell
    for cell in bench.get("prefill", {}).get("cells", []):
        if cell.get("prompt_len") == 128:
            out["prefill_cell"] = cell
    for cell in bench.get("prefix", {}).get("cells", []):
        # the acceptance overlap (75%) only: full runs also record 50%/87.5%
        # cells and quick runs record just this one — pin the comparison so
        # full-baseline vs quick-fresh gates the SAME workload
        if cell.get("prompt_len") == 128 and cell.get("overlap_tokens") == 96:
            out["prefix_cell"] = cell
    for cell in bench.get("prefill_paged", {}).get("cells", []):
        if cell.get("prompt_len") == 128:
            out["prefill_paged_cell"] = cell
    for cell in bench.get("kv_quant", {}).get("cells", []):
        # prompt 32 is the acceptance cell (quick runs record only it)
        if cell.get("prompt_len") == 32:
            out["kv_quant_cell"] = cell
    for cell in bench.get("mla", {}).get("cells", []):
        # prompt 32 is the acceptance cell (quick runs record only it)
        if cell.get("prompt_len") == 32:
            out["mla_cell"] = cell
    for cell in bench.get("tp", {}).get("cells", []):
        # tp=2 is the pinned acceptance degree (quick AND full runs have it)
        if cell.get("tp") == 2:
            out["tp_cell"] = cell
    return out


def _resolve(tree: dict, path: str):
    node = tree
    for part in path.split("."):
        if not isinstance(node, dict) or part not in node:
            return None
        node = node[part]
    return node


def _pass_flags(tree: dict, prefix: str = "") -> list:
    flags = []
    if isinstance(tree, dict):
        for key, val in tree.items():
            where = f"{prefix}.{key}" if prefix else key
            if key.startswith("passes_"):
                flags.append((where, bool(val)))
            else:
                flags.extend(_pass_flags(val, where))
    elif isinstance(tree, list):
        for i, val in enumerate(tree):
            flags.extend(_pass_flags(val, f"{prefix}[{i}]"))
    return flags


def check(baseline: dict, fresh: dict, threshold: float,
          require_acceptance: bool, abs_threshold: float = 0.5,
          relative_only: bool = False, sections=None) -> list:
    """Returns a list of human-readable failure strings (empty = gate open).

    ``sections``: optional set of bench section names — gate only metric
    rows (and passes_* flags) belonging to those sections. Lets a CI leg
    that reran ``serve_bench --sections a,b`` gate exactly what it
    measured without tripping over sections another leg owns."""
    base = _acceptance_cells(baseline)
    new = _acceptance_cells(fresh)
    failures = []
    for path, higher, absolute, override in GATED_METRICS:
        if sections is not None and _section_of(path) not in sections:
            continue
        if absolute and relative_only:
            continue
        if absolute:
            thr = max(threshold, abs_threshold)
        else:
            thr = max(threshold, override or 0.0)
        b, f = _resolve(base, path), _resolve(new, path)
        if f is None:
            failures.append(f"{path}: missing from fresh bench")
            continue
        # acceptance FLAGS gate as 0/1: a baseline-True row that comes back
        # False is a regression at any threshold (0 >= (1-t)*1 never holds),
        # and a False -> True flip always passes
        if isinstance(f, bool):
            f = int(f)
        if isinstance(b, bool):
            b = int(b)
        if not isinstance(f, (int, float)):
            failures.append(f"{path}: fresh value {f!r} is not numeric")
            continue
        if b is None or not isinstance(b, (int, float)):
            # baseline predates this section (the first PR that adds a bench
            # section MUST still pass the gate — there is nothing to regress
            # against yet) or holds a non-numeric relic: skip with a warning,
            # never KeyError/fail. The next commit's baseline picks it up.
            print(f"  [skip] {path}: {f:.3f} — section missing from "
                  f"baseline, nothing to gate against", file=sys.stderr)
            continue
        ok = (f >= (1 - thr) * b) if higher else (f <= (1 + thr) * b)
        arrow = ">=" if higher else "<="
        status = "ok" if ok else "REGRESSION"
        print(f"  [{status}] {path}: {f:.3f} vs baseline {b:.3f} "
              f"(gate: {arrow} {1 - thr if higher else 1 + thr:.2f}x)")
        if not ok:
            failures.append(
                f"{path}: {f:.3f} regressed beyond {thr:.0%} of "
                f"baseline {b:.3f}")
    if require_acceptance:
        for where, val in _pass_flags(fresh):
            if sections is not None and _section_of(where) not in sections:
                continue
            if not val:
                failures.append(f"acceptance flag {where} is false")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", type=pathlib.Path, required=True,
                    help="committed BENCH_serve.json (pre-bench copy)")
    ap.add_argument("--fresh", type=pathlib.Path, required=True,
                    help="BENCH_serve.json the bench just wrote")
    ap.add_argument("--threshold", type=float, default=0.20,
                    help="max fractional regression per relative metric")
    ap.add_argument("--abs-threshold", type=float, default=0.50,
                    help="max fractional regression for absolute tokens/s "
                         "rows (looser: machine-class + runner noise)")
    ap.add_argument("--relative-only", action="store_true",
                    help="gate only machine-independent ratio rows (what CI "
                         "uses: its runner class differs from the committed "
                         "baseline's machine)")
    ap.add_argument("--require-acceptance", action="store_true",
                    help="also fail on any false passes_* flag in fresh")
    ap.add_argument("--sections", default=None,
                    help="comma-separated bench section names: gate only "
                         "rows and flags in those sections (matches "
                         "serve_bench --sections legs)")
    args = ap.parse_args()

    sections = ({s.strip() for s in args.sections.split(",") if s.strip()}
                if args.sections else None)
    baseline = json.loads(args.baseline.read_text())
    fresh = json.loads(args.fresh.read_text())
    failures = check(baseline, fresh, args.threshold,
                     args.require_acceptance, args.abs_threshold,
                     args.relative_only, sections)
    if failures:
        print("\nBENCH GATE FAILED:")
        for f in failures:
            print(f"  - {f}")
        sys.exit(1)
    print("\nbench gate passed")


if __name__ == "__main__":
    main()
