"""End-to-end behaviour: train driver (resume path), serve driver
(continuous batching), and the quantized-serve path."""
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest


def test_train_driver_end_to_end(tmp_path):
    from repro.launch.train import TrainConfig, train
    tc = TrainConfig(arch="codeqwen1.5-7b", reduced=True, steps=20, batch=4,
                     seq_len=32, ckpt_dir=str(tmp_path / "run"),
                     checkpoint_every=10, log_every=5)
    stats = train(tc)
    assert np.isfinite(stats["final_loss"])
    # resume: second invocation starts from the step-20 checkpoint and extends
    tc2 = TrainConfig(**{**tc.__dict__, "steps": 25})
    stats2 = train(tc2)
    assert np.isfinite(stats2["final_loss"])


def test_train_driver_wsd_schedule():
    from repro.launch.train import TrainConfig, train
    tc = TrainConfig(arch="minicpm-2b", reduced=True, steps=12, batch=2,
                     seq_len=16, schedule="wsd", log_every=4)
    stats = train(tc)
    assert np.isfinite(stats["final_loss"])


def test_serve_driver_continuous_batching():
    from repro.launch.serve import ServeConfig, run
    sc = ServeConfig(arch="hymba-1.5b", reduced=True, batch_slots=2,
                     s_max=32, requests=4, prompt_len=4, gen_len=6)
    stats = run(sc)
    assert stats["requests"] == 4
    assert stats["tokens_per_s"] > 0


def test_serve_driver_quantized():
    from repro.launch.serve import ServeConfig, Server
    sc = ServeConfig(arch="codeqwen1.5-7b", reduced=True, batch_slots=2,
                     s_max=32, requests=2, prompt_len=2, gen_len=4,
                     quantize_int8=True)
    server = Server(sc)
    slot = server.add_request(np.array([1, 2]), 4)
    assert slot is not None
    for _ in range(4):
        server.step_all()
    assert len(server.outputs[slot]) >= 4
    assert all(0 <= t < server.cfg.vocab_size for t in server.outputs[slot])


def test_quickstart_example_runs():
    repo = pathlib.Path(__file__).resolve().parents[1]
    proc = subprocess.run(
        [sys.executable, str(repo / "examples" / "quickstart.py")],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": str(repo / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root"})
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "quickstart OK" in proc.stdout
