"""Capacity planner invariants — property tests when hypothesis is
available, a fixed deterministic GEMM sample otherwise (the suite must run,
and collect, without the optional dependency)."""
import math

import numpy as np
import pytest

from repro.configs.base import MemoryStrategy
from repro.core.dataflow import DATAFLOWS, Gemm, Tiling, reload_factor, traffic_bytes
from repro.core.planner import MXU_DIM, PlannerConfig, plan_gemm
from repro.core.strategies import ZCU104, TPU_V5E, planner_config

try:
    from hypothesis import given, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _sample_gemms(n=25, seed=0):
    rng = np.random.default_rng(seed)
    return [Gemm("g", int(m), int(k), int(nn))
            for m, k, nn in rng.integers(1, 8192, (n, 3))]


def _check_plan_fits_budget(g, budget, overlap):
    cfg = PlannerConfig(vmem_budget=budget, overlap=overlap)
    plan = plan_gemm(g, cfg)
    assert plan.vmem_used <= budget
    assert plan.stages >= 1 and plan.partitions >= 1


def _check_traffic_at_least_resident_optimum(g):
    """No dataflow can move fewer bytes than touching each tensor once."""
    t = Tiling(128, 128, 128)
    opt = g.a_size + g.w_size + g.o_size
    for df in DATAFLOWS:
        assert traffic_bytes(g, t, df) >= opt * 0.999
        assert reload_factor(g, t, df) >= 0.999


def _check_bigger_budget_never_more_traffic(g):
    """The paper's Ultra-RAM claim as an invariant: more local memory can
    only reduce (or keep) planned HBM traffic."""
    small = plan_gemm(g, PlannerConfig(vmem_budget=2 * 2**20, overlap=False))
    big = plan_gemm(g, PlannerConfig(vmem_budget=64 * 2**20, overlap=False))
    assert big.traffic <= small.traffic


def test_planner_invariants_deterministic():
    for i, g in enumerate(_sample_gemms()):
        _check_plan_fits_budget(g, [4, 16, 64][i % 3] * 2**20, bool(i % 2))
        _check_traffic_at_least_resident_optimum(g)
        _check_bigger_budget_never_more_traffic(g)


if HAVE_HYPOTHESIS:
    gemm_st = st.builds(
        Gemm,
        name=st.just("g"),
        m=st.integers(1, 8192),
        k=st.integers(1, 8192),
        n=st.integers(1, 8192),
    )

    @given(gemm_st, st.sampled_from([4 * 2**20, 16 * 2**20, 64 * 2**20]),
           st.booleans())
    def test_plan_fits_budget(g, budget, overlap):
        _check_plan_fits_budget(g, budget, overlap)

    @given(gemm_st)
    def test_traffic_at_least_resident_optimum(g):
        _check_traffic_at_least_resident_optimum(g)

    @given(gemm_st)
    def test_bigger_budget_never_more_traffic(g):
        _check_bigger_budget_never_more_traffic(g)


def test_resident_plan_when_fits():
    """§4.4: when the whole layer fits, the planner pins it (1 stage, 1
    partition, reload factor 1)."""
    g = Gemm("small", 512, 512, 512)
    cfg = planner_config(MemoryStrategy.COMPILER_LARGE_LOCAL, TPU_V5E)
    plan = plan_gemm(g, cfg)
    assert plan.dataflow == "resident"
    assert plan.stages == 1 and plan.partitions == 1
    assert abs(plan.reload - 1.0) < 1e-6


def test_partitioning_when_too_big():
    """A GEMM far beyond the budget must split into multiple stages (Fig. 3)."""
    g = Gemm("big", 16384, 16384, 16384)
    cfg = planner_config(MemoryStrategy.BASELINE, ZCU104)
    plan = plan_gemm(g, cfg)
    assert plan.stages > 1
    assert plan.reload > 1.0


def test_overlap_halves_usable_tiles():
    """Double buffering (dual-clock analogue) needs 2x stream buffers, so the
    same budget admits smaller tiles."""
    g = Gemm("g", 4096, 4096, 4096)
    no = plan_gemm(g, PlannerConfig(vmem_budget=8 * 2**20, overlap=False))
    yes = plan_gemm(g, PlannerConfig(vmem_budget=8 * 2**20, overlap=True))
    assert yes.vmem_used <= 8 * 2**20
    assert yes.tiling.bm * yes.tiling.bk <= no.tiling.bm * no.tiling.bk * 2


def _check_mxu_alignment(m, k, n):
    plan = plan_gemm(Gemm("g", m, k, n),
                     PlannerConfig(vmem_budget=64 * 2**20, overlap=True))
    t = plan.tiling
    assert t.bm % MXU_DIM == 0 and t.bk % MXU_DIM == 0 and t.bn % MXU_DIM == 0


def test_mxu_alignment_deterministic():
    rng = np.random.default_rng(1)
    for m, k, n in rng.integers(1, 4096, (10, 3)):
        _check_mxu_alignment(int(m), int(k), int(n))


if HAVE_HYPOTHESIS:
    @given(st.integers(1, 4096), st.integers(1, 4096), st.integers(1, 4096))
    def test_mxu_alignment(m, k, n):
        _check_mxu_alignment(m, k, n)
