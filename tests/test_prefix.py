"""Page-level prefix caching: bit-exact greedy equivalence with caching on
vs off (per family, including full-prompt hits and mid-stream copy-on-write
divergence), allocator refcount/COW invariants (no page simultaneously free
and referenced by a live block table or the prefix index; double-release
raises), LRU eviction under pool pressure, and the prefix-aware scheduler
ordering hint.

Equivalence leans on two anchors: shared pages hold EXACTLY the bytes the
donor request's splice wrote (the same bytes an uncached run would write,
since chunk plans for a shared prefix decompose identically under the
greedy ladder), and every row a request writes lies beyond its aliased
pages (partial hits are re-materialised into a fresh page by the splice —
copy-on-write — before any write can land)."""
import logging

import jax
import numpy as np
import pytest

from repro import configs
from repro.models.registry import get_model, reduced_config
from repro.serve.engine import PageAllocator, ServeEngine
from repro.serve.prefix import PrefixIndex
from repro.serve.scheduler import Scheduler

PS = 8          # page size: small so few-token prompts span several pages
S_MAX = 48


@pytest.fixture(scope="module")
def qwen():
    cfg = reduced_config(configs.get_config("qwen2.5-32b"))
    model = get_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _engine(model, params, *, prefix_cache, **kw):
    kw.setdefault("batch_slots", 2)
    kw.setdefault("s_max", S_MAX)
    kw.setdefault("page_size", PS)
    return ServeEngine(model, params, prefix_cache=prefix_cache, **kw)


def _prompts(vocab, seed=5):
    """A shared 16-token (2-page) header plus aligned, unaligned, and
    identical continuations — covers full-page alias, a full-prompt aligned
    hit (tail recompute), and an unaligned full-prompt re-hit whose partial
    page must be re-materialised copy-on-write (decode appends past it)."""
    rng = np.random.default_rng(seed)
    X = rng.integers(0, vocab, 16).astype(np.int32)
    u = np.concatenate([X, rng.integers(0, vocab, 5).astype(np.int32)])
    a = np.concatenate([X, rng.integers(0, vocab, 8).astype(np.int32)])
    return [(X, 4), (a, 6), (X, 5), (u, 6), (u, 3)]


def _serve_sequential(model, params, workload, *, prefix_cache, **kw):
    eng = _engine(model, params, prefix_cache=prefix_cache, **kw)
    toks = []
    for prompt, gen in workload:
        req = eng.submit(prompt, gen)
        eng.run()
        toks.append(list(req.tokens))
    return eng, toks


# ------------------------------------------------------------ equivalence
@pytest.mark.parametrize("arch", ["qwen2.5-32b", "dbrx-132b",
                                  "llama-3.2-vision-11b"])
def test_prefix_bit_exact_greedy_supported_families(arch):
    """Caching on vs off: identical greedy token streams for every cacheable
    family (dense / MoE / VLM), across full-page hits, full-prompt hits
    (tail recompute for logits), and unaligned partial-page COW hits."""
    cfg = reduced_config(configs.get_config(arch))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    wl = _prompts(cfg.vocab_size)
    e_on, on = _serve_sequential(model, params, wl, prefix_cache=None)
    _, off = _serve_sequential(model, params, wl, prefix_cache=False)
    assert on == off
    m = e_on.metrics
    assert m.prefix_hits >= 3 and m.prefix_hit_tokens > 0
    assert m.prefix_pages_shared >= 2
    assert m.prefix_cow_copies >= 1          # the 21-token unaligned reuse


@pytest.mark.parametrize("arch", ["whisper-large-v3", "hymba-1.5b"])
def test_unsupported_family_falls_back_to_full_prefill(arch, caplog):
    """encdec (cross-K/V not page-resident) and hybrid (mamba carry not
    reconstructible) warn on an explicit prefix_cache=True, fall back to
    full prefill, and still serve bit-exactly vs prefix off."""
    cfg = reduced_config(configs.get_config(arch))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    wl = _prompts(cfg.vocab_size)[:3]
    with caplog.at_level(logging.WARNING, logger="repro.serve.engine"):
        e_on, on = _serve_sequential(model, params, wl, prefix_cache=True)
    _, off = _serve_sequential(model, params, wl, prefix_cache=False)
    assert on == off
    if e_on.paged:          # hymba pages its ring; both are prefix-off
        assert not e_on.prefix_cache
        assert any("prefix_cache unsupported" in r.message
                   for r in caplog.records)
    assert e_on.metrics.prefix_lookups == 0


def test_ssm_prefix_request_is_served_dense():
    """rwkv ignores paging entirely; prefix_cache=None auto-disables and the
    request still completes (the ISSUE's 'otherwise full prefill' leg)."""
    eng = ServeEngine.build("rwkv6-7b", reduced=True, batch_slots=2,
                            s_max=16, page_size=8, prefix_cache=None)
    assert not eng.paged and not eng.prefix_cache
    req = eng.submit(np.array([1, 2, 3], np.int32), 4)
    eng.run()
    assert len(req.tokens) == 4


def test_mid_stream_cow_divergence_matches_uncached(qwen):
    """Two live requests share an UNALIGNED 21-token prefix then diverge:
    each sharer's admission re-materialises the partial page copy-on-write
    (its tail splice and decode write into that page's row range), the donor
    is still decoding while the first sharer admits, and ALL token streams
    match their uncached runs — mutating one request never changes a sibling
    sharing its prefix."""
    model, params = qwen
    vocab = model.cfg.vocab_size
    rng = np.random.default_rng(9)
    X21 = rng.integers(0, vocab, 21).astype(np.int32)     # 2 pages + 5 rows
    pA = np.concatenate([X21, rng.integers(0, vocab, 6).astype(np.int32)])
    pB = np.concatenate([X21, rng.integers(0, vocab, 6).astype(np.int32)])

    def run(prefix_cache):
        eng = _engine(model, params, prefix_cache=prefix_cache)
        r0 = eng.submit(X21, 8)
        for _ in range(4):               # donor mid-decode when A arrives
            eng.step()
        rA = eng.submit(pA, 8)
        for _ in range(3):               # A mid-decode when B arrives
            eng.step()
        rB = eng.submit(pB, 8)
        eng.run()
        return eng, list(r0.tokens), list(rA.tokens), list(rB.tokens)

    e_on, t0_on, ta_on, tb_on = run(None)
    _, t0_off, ta_off, tb_off = run(False)
    assert (t0_on, ta_on, tb_on) == (t0_off, ta_off, tb_off)
    assert ta_on != tb_on                # genuinely diverged
    m = e_on.metrics
    assert m.prefix_hits == 2            # both A and B hit the 21-row prefix
    assert m.prefix_cow_copies == 2      # each re-materialised the partial


def test_full_prompt_hit_skips_all_but_last_position(qwen):
    """An identical repeated prompt re-computes exactly ONE position (the
    last, for its logits): chunk-token accounting shows the skip and the
    stream still matches."""
    model, params = qwen
    vocab = model.cfg.vocab_size
    prompt = np.random.default_rng(13).integers(0, vocab, 16).astype(np.int32)
    eng = _engine(model, params, prefix_cache=None)
    r1 = eng.submit(prompt, 4)
    eng.run()
    before = eng.metrics.prefill_chunk_tokens
    r2 = eng.submit(prompt, 4)
    eng.run()
    assert eng.metrics.prefill_chunk_tokens - before == 1
    assert r1.tokens == r2.tokens
    assert eng.metrics.prefix_hit_tokens == len(prompt)


# ------------------------------------------------------- invariants / LRU
def _check_invariants(eng):
    # the engine's own walker covers free/held disjointness, refcount >= 1
    # for every live block-table and index page, and the no-leak partition
    # (release_job keeps these true through failures and cancellations)
    eng.assert_page_invariants()
    free = set(eng.allocator._free)
    held = eng.allocator.held
    assert not free & held, "page both free and referenced"
    live = {pg for pages in eng.slot_pages for pg in pages}
    assert not free & live, "page both free and in a live block table"
    idx_pages = set(eng.prefix_index.pages)
    assert not free & idx_pages, "page both free and in the prefix index"
    assert free | held == set(range(eng.num_pages)), "page leaked"


def test_refcount_invariants_hold_through_serving(qwen):
    """Step-by-step engine walk over a sharing+recycling workload: at every
    tick, no page is simultaneously on the free list and in a live block
    table or the prefix index, and no page leaks."""
    model, params = qwen
    vocab = model.cfg.vocab_size
    eng = _engine(model, params, prefix_cache=None, batch_slots=2)
    for prompt, gen in _prompts(vocab) * 2:
        eng.submit(prompt, gen)
    guard = 0
    while (eng.scheduler.waiting or eng.active) and guard < 500:
        eng.step()
        _check_invariants(eng)
        guard += 1
    assert guard < 500 and not eng.active
    _check_invariants(eng)


def test_lru_eviction_under_pool_pressure(qwen):
    """A pool too small to retain every prefix forces LRU eviction of
    index-only pages; admission never deadlocks, streams still match the
    uncached engine, and evictions are counted."""
    model, params = qwen
    vocab = model.cfg.vocab_size
    rng = np.random.default_rng(21)
    wl = [(rng.integers(0, vocab, 16).astype(np.int32), 4)
          for _ in range(6)]
    # 6 pages: one 16-token/gen-4 request needs ceil(19/8)=3, so at most one
    # retired prefix (2 pages) survives beside a live request
    kw = dict(batch_slots=1, num_pages=6)
    e_on, on = _serve_sequential(model, params, wl, prefix_cache=None, **kw)
    _, off = _serve_sequential(model, params, wl, prefix_cache=False, **kw)
    assert on == off
    assert e_on.metrics.prefix_evictions > 0
    assert e_on.prefix_index.evictions > 0
    _check_invariants(e_on)


def test_deferral_logic_unchanged_with_retention(qwen):
    """Admission deferral semantics survive prefix retention: while a live
    request holds the pool, a second distinct-prompt request DEFERS exactly
    as the uncached engine would (retained pages that CAN be evicted are,
    before deferring; pages held by live requests are not)."""
    model, params = qwen
    vocab = model.cfg.vocab_size
    rng = np.random.default_rng(41)
    eng = _engine(model, params, prefix_cache=None, batch_slots=2,
                  num_pages=3)                  # one 8+13 request needs all 3
    a = eng.submit(rng.integers(0, vocab, 8).astype(np.int32), 13)
    b = eng.submit(rng.integers(0, vocab, 8).astype(np.int32), 13)
    eng.step()
    assert a.slot is not None and b.slot is None
    assert eng.deferrals >= 1
    eng.run()
    assert a.done and b.done
    assert len(a.tokens) == 13 and len(b.tokens) == 13
    # b's admission evicted a's retained prompt page to cover itself
    assert eng.metrics.prefix_evictions >= 1
    _check_invariants(eng)


def test_eviction_spares_pages_aliased_by_live_requests(qwen):
    """Pages a running request aliases (refcount > 1) are skipped by
    eviction: the donor's header stays valid mid-flight even under pressure
    from new admissions."""
    model, params = qwen
    vocab = model.cfg.vocab_size
    rng = np.random.default_rng(23)
    X = rng.integers(0, vocab, 16).astype(np.int32)
    eng = _engine(model, params, prefix_cache=None, batch_slots=2,
                  num_pages=8)
    eng.submit(X, 2)
    eng.run()
    rA = eng.submit(np.concatenate(
        [X, rng.integers(0, vocab, 8).astype(np.int32)]), 12)
    for _ in range(3):
        eng.step()
    shared = set(eng.prefix_index.pages) & set(eng.slot_pages[rA.slot])
    assert shared                       # A aliases the indexed header
    # churn: distinct prompts force eviction of whatever is evictable
    for _ in range(3):
        p = rng.integers(0, vocab, 16).astype(np.int32)
        eng.submit(p, 4)
    eng.run()
    assert rA.done and len(rA.tokens) == 12
    _check_invariants(eng)


# --------------------------------------------------------- allocator unit
def test_allocator_share_release_refcounting():
    a = PageAllocator(4)
    pages = a.alloc(2)
    assert a.free == 2 and all(a.refcount(p) == 1 for p in pages)
    a.share(pages[0])
    a.release(pages)                    # pages[0] survives at refcount 1
    assert a.free == 3 and a.refcount(pages[0]) == 1
    a.release([pages[0]])
    assert a.free == 4
    with pytest.raises(ValueError):
        a.release([pages[0]])           # double free
    with pytest.raises(ValueError):
        a.share(pages[1])               # share of an unheld page


def test_allocator_property_refcount_cow_invariants():
    """Property test: random alloc/share/release traffic against a
    reference model — the free list and the refcount map always partition
    the pool, counts always match, and over-release always raises."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, strategies as st

    @given(st.lists(st.tuples(st.integers(0, 2), st.integers(0, 7)),
                    max_size=60))
    def run(ops):
        a = PageAllocator(8)
        ref = {}                       # page -> refcount (the model)
        for kind, arg in ops:
            if kind == 0:              # alloc
                got = a.alloc(arg)
                if arg > 8 - len(ref):
                    assert got is None
                else:
                    assert got is not None and len(got) == arg
                    for p in got:
                        assert p not in ref
                        ref[p] = 1
            elif kind == 1:            # share page `arg` if held
                if arg in ref:
                    a.share(arg)
                    ref[arg] += 1
                else:
                    with pytest.raises(ValueError):
                        a.share(arg)
            else:                      # release page `arg`
                if arg in ref:
                    a.release([arg])
                    ref[arg] -= 1
                    if ref[arg] == 0:
                        del ref[arg]
                else:
                    with pytest.raises(ValueError):
                        a.release([arg])
            assert a.held == set(ref)
            assert a.free == 8 - len(ref)
            assert all(a.refcount(p) == n for p, n in ref.items())
    run()


# ------------------------------------------------------------- index unit
def test_prefix_index_chain_and_partial_lookup():
    a = PageAllocator(8)
    idx = PrefixIndex(a, page_size=4)
    prompt = np.arange(10, dtype=np.int32)          # 2 full pages + 2 tail
    pages = a.alloc(3)
    plan = idx.lookup(prompt)
    assert plan.cached_len == 0 and len(plan.full_hashes) == 2
    idx.register(plan, pages, len(prompt))
    assert len(idx) == 3                            # 2 full + 1 partial
    # full replay hits everything, including the partial tail
    hit = idx.lookup(prompt)
    assert hit.cached_len == 10 and hit.shared_pages == pages[:2]
    assert hit.partial == (pages[2], 2) and hit.cow
    # longer prompt with the same header hits only the chain prefix
    longer = np.concatenate([prompt[:8], np.full(4, 99, np.int32)])
    hit2 = idx.lookup(longer)
    assert hit2.cached_len == 8 and hit2.partial is None
    # diverging second page breaks the chain after page 0
    forked = prompt.copy()
    forked[5] = 77
    hit3 = idx.lookup(forked)
    assert hit3.cached_len == 4 and hit3.shared_pages == pages[:1]


def test_eviction_shrinks_chains_from_the_deep_end():
    """Evicting a chain must shorten the hit, never zero it: chains are
    LRU-touched deepest-first (root most-recent), so eviction reclaims the
    deepest page while the root keeps matching — the failure mode where the
    root went first left descendants index-held but unreachable."""
    a = PageAllocator(4)
    idx = PrefixIndex(a, page_size=4)
    prompt = np.arange(12, dtype=np.int32)          # 3 full pages
    pages = a.alloc(3)
    idx.register(idx.lookup(prompt), pages, len(prompt))
    a.release(pages)                                # index-only now
    assert idx.evict(1) == 1
    hit = idx.lookup(prompt)
    assert hit.cached_len == 8                      # root + middle survive
    assert a.refcount(pages[2]) == 0                # the DEEPEST page freed
    assert idx.evict(1) == 1
    assert idx.lookup(prompt).cached_len == 4       # shrinks, never zeroes
    assert idx.evict(1) == 1
    assert idx.lookup(prompt).cached_len == 0 and len(idx) == 0


def test_prefix_index_eviction_is_lru_and_ref_gated():
    a = PageAllocator(6)
    idx = PrefixIndex(a, page_size=4)
    pa = a.alloc(1)
    pb = a.alloc(1)
    plan_a = idx.lookup(np.arange(4, dtype=np.int32))
    idx.register(plan_a, pa, 4)
    plan_b = idx.lookup(np.arange(4, 8, dtype=np.int32))
    idx.register(plan_b, pb, 4)
    a.release(pa)
    a.release(pb)                       # both now index-only (refcount 1)
    idx.lookup(np.arange(4, dtype=np.int32))        # touch A -> B is LRU
    assert idx.evict(1) == 1 and a.refcount(pb[0]) == 0
    assert idx.lookup(np.arange(4, dtype=np.int32)).cached_len == 4
    a.share(pa[0])                      # a live block table aliases A
    assert idx.evict(1) == 0            # ref-gated: nothing evictable
    a.release([pa[0]])
    assert idx.evict(1) == 1 and len(idx) == 0


# ---------------------------------------------------------- scheduler hint
def test_scheduler_prefix_aware_ordering_hint(qwen):
    """With prefix_aware=True, a request whose prompt prefix is cached
    admits before an earlier same-priority request with no cached prefix;
    the default scheduler keeps strict FIFO."""
    model, params = qwen
    vocab = model.cfg.vocab_size
    rng = np.random.default_rng(31)
    X = rng.integers(0, vocab, 16).astype(np.int32)
    Y = rng.integers(0, vocab, 16).astype(np.int32)

    def order(prefix_aware):
        eng = _engine(model, params, prefix_cache=None, batch_slots=1,
                      scheduler=Scheduler(prefix_aware=prefix_aware))
        eng.submit(X, 2)
        eng.run()                       # X's pages now cached
        r_cold = eng.submit(Y, 2)       # submitted FIRST, no cached prefix
        r_hot = eng.submit(X, 2)        # submitted second, cached prefix
        if prefix_aware:
            assert r_hot.prefix_hint == len(X) and r_cold.prefix_hint == 0
        else:                           # probe skipped: no consumer
            assert r_hot.prefix_hint == 0
        eng.run()
        recs = eng.metrics.requests
        return recs[r_cold.rid].t_admit, recs[r_hot.rid].t_admit

    cold_t, hot_t = order(True)
    assert hot_t < cold_t               # hinted request jumped ahead
    cold_t, hot_t = order(False)
    assert cold_t < hot_t               # default stays FIFO
