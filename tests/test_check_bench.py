"""Unit tests for the CI bench-regression gate itself (benchmarks/
check_bench.py). The gate has been load-bearing since PR 4 but untested —
in particular the rule that a section PRESENT in the fresh bench but MISSING
from the committed baseline (the first PR that adds a bench section) must
skip with a warning, never fail or crash: otherwise no PR could ever
introduce a new bench section and pass CI with it in the same change."""
import copy

import pytest

from benchmarks.check_bench import GATED_METRICS, check

BASE = {
    "cells": [{"batch_slots": 4, "prompt_len": 32,
               "engine_tokens_per_s": 1000.0}],
    "acceptance": {"speedup": 3.0, "passes_2x": True},
    "paged": {
        "cells": [{"batch_slots": 4, "prompt_len": 32,
                   "paged_tokens_per_s": 900.0}],
        "acceptance": {"resident_bytes_ratio": 0.2,
                       "passes_memory_drop": True},
    },
    "prefill": {
        "cells": [{"prompt_len": 128,
                   "parallel_prefill_tokens_per_s": 5000.0}],
        "acceptance": {"speedup": 3.0, "passes_2x": True},
    },
    "prefix": {
        "cells": [{"prompt_len": 128, "overlap_tokens": 96,
                   "cached_prefill_tokens_per_s": 8000.0}],
        "acceptance": {"speedup": 2.5, "passes_2x": True},
    },
    "prefill_paged": {
        "cells": [{"prompt_len": 128,
                   "kernel_prefill_tokens_per_s": 7000.0}],
        "acceptance": {"speedup": 1.8, "passes_1_5x": True},
    },
    "kv_quant": {
        "cells": [{"prompt_len": 32,
                   "int8_decode_tokens_per_s": 1400.0}],
        "acceptance": {"resident_bytes_ratio": 0.25,
                       "greedy_prefix_match_mean": 0.7,
                       "passes_bytes_ratio": True,
                       "passes_divergence_bound": True},
    },
    "mla": {
        "cells": [{"prompt_len": 32,
                   "latent_decode_tokens_per_s": 1500.0}],
        "acceptance": {"resident_bytes_ratio": 0.31,
                       "greedy_prefix_match_mean": 1.0,
                       "passes_bytes_ratio": True,
                       "passes_divergence_bound": True},
    },
    "goodput": {
        "cells": [{"cell": "burst", "policy_on": True}],
        "acceptance": {"passes_steady_slo": True, "passes_slo_gain": True,
                       "passes_roofline_bound": True,
                       "goodput_tokens_per_s": 120.0},
    },
    "tp": {
        "cells": [{"tp": 2, "decode_tokens_per_s": 300.0,
                   "per_shard_kv_bytes": 65536,
                   "kv_bytes_ratio_vs_tp1": 0.5}],
        "tp_int8": {"greedy_prefix_match_mean": 0.94,
                    "per_shard_kv_bytes_ratio": 0.502,
                    "passes_greedy_match": True,
                    "passes_shard_bytes": True},
        "tp_mla": {"per_shard_kv_bytes_ratio": 1.0,
                   "passes_greedy_match": True,
                   "passes_replicated_pool": True},
        "acceptance": {"passes_greedy_match": True,
                       "passes_shard_bytes": True,
                       "per_shard_kv_bytes_ratio": 0.5},
    },
    "router": {
        "affinity_prefill_tokens_per_s": 9000.0,
        "round_robin_prefill_tokens_per_s": 5000.0,
        "acceptance": {"affinity_speedup": 1.8,
                       "passes_affinity_gain": True},
    },
}


def test_identical_benches_pass():
    assert check(copy.deepcopy(BASE), copy.deepcopy(BASE), 0.2, True) == []


def test_relative_regression_fails():
    # speedup rows are ratio-of-runs and carry a loosened 50% collapse
    # threshold (their absolute floor is the passes_* flag) — a 30% wobble
    # passes, a 60% collapse fails
    fresh = copy.deepcopy(BASE)
    fresh["prefill"]["acceptance"]["speedup"] = 3.0 * 0.7
    assert check(copy.deepcopy(BASE), fresh, 0.2, False) == []
    fresh["prefill"]["acceptance"]["speedup"] = 3.0 * 0.4   # collapse
    fails = check(copy.deepcopy(BASE), fresh, 0.2, False)
    assert any("prefill.acceptance.speedup" in f for f in fails)
    # the deterministic byte ratio keeps the TIGHT default threshold: a
    # 30% worsening there is a real regression, not noise
    fresh = copy.deepcopy(BASE)
    fresh["paged"]["acceptance"]["resident_bytes_ratio"] = 0.2 * 1.3
    fails = check(copy.deepcopy(BASE), fresh, 0.2, False)
    assert any("resident_bytes_ratio" in f for f in fails)


def test_lower_is_better_metric_gated_in_the_right_direction():
    fresh = copy.deepcopy(BASE)
    fresh["paged"]["acceptance"]["resident_bytes_ratio"] = 0.2 * 1.5  # worse
    fails = check(copy.deepcopy(BASE), fresh, 0.2, False)
    assert any("resident_bytes_ratio" in f for f in fails)
    # improving (shrinking) the ratio must NOT fail
    fresh["paged"]["acceptance"]["resident_bytes_ratio"] = 0.1
    assert check(copy.deepcopy(BASE), fresh, 0.2, False) == []


def test_section_missing_from_baseline_skips_with_warning(capsys):
    """The first-PR case: the fresh bench adds a section (here: every
    section beyond the original engine cells) that the committed baseline
    predates. The gate must SKIP those rows — warning on stderr — and pass,
    not KeyError and not fail."""
    base = {"cells": copy.deepcopy(BASE["cells"]),
            "acceptance": copy.deepcopy(BASE["acceptance"])}
    fails = check(base, copy.deepcopy(BASE), 0.2, True)
    assert fails == []
    err = capsys.readouterr().err
    assert "missing from baseline" in err
    assert "prefill_paged.acceptance.speedup" in err


def test_section_missing_from_fresh_fails():
    """The inverse is a real failure: the fresh bench silently dropping a
    gated section would let regressions hide behind a truncated run."""
    fresh = copy.deepcopy(BASE)
    del fresh["prefix"]
    fails = check(copy.deepcopy(BASE), fresh, 0.2, False)
    assert any("prefix.acceptance.speedup" in f and "missing from fresh" in f
               for f in fails)


def test_non_numeric_values_reported_not_crashed():
    fresh = copy.deepcopy(BASE)
    fresh["acceptance"]["speedup"] = "fast"
    fails = check(copy.deepcopy(BASE), fresh, 0.2, False)
    assert any("not numeric" in f for f in fails)
    base = copy.deepcopy(BASE)
    base["acceptance"]["speedup"] = None
    assert check(base, copy.deepcopy(BASE), 0.2, False) == []   # skip-warn


def test_false_acceptance_flag_fails_only_when_required():
    fresh = copy.deepcopy(BASE)
    fresh["prefill_paged"]["acceptance"]["passes_1_5x"] = False
    assert check(copy.deepcopy(BASE), fresh, 0.9, False) == []
    fails = check(copy.deepcopy(BASE), fresh, 0.9, True)
    assert any("passes_1_5x" in f for f in fails)


def test_relative_only_skips_absolute_rows():
    fresh = copy.deepcopy(BASE)
    fresh["cells"][0]["engine_tokens_per_s"] = 1.0      # huge absolute drop
    assert check(copy.deepcopy(BASE), fresh, 0.2, False,
                 relative_only=True) == []
    fails = check(copy.deepcopy(BASE), fresh, 0.2, False,
                  abs_threshold=0.5, relative_only=False)
    assert any("engine_tokens_per_s" in f for f in fails)


def test_boolean_flag_rows_gate_true_to_false_flips():
    """Goodput SLO flags gate as 0/1: a baseline-True row coming back False
    is a regression at any threshold, and — being same-run relative facts —
    the flag rows stay gated under CI's --relative-only mode."""
    fresh = copy.deepcopy(BASE)
    fresh["goodput"]["acceptance"]["passes_slo_gain"] = False
    fails = check(copy.deepcopy(BASE), fresh, 0.2, False)
    assert any("goodput.acceptance.passes_slo_gain" in f for f in fails)
    fails = check(copy.deepcopy(BASE), fresh, 0.2, False, relative_only=True)
    assert any("goodput.acceptance.passes_slo_gain" in f for f in fails)
    # a False -> True flip is an improvement, never a failure
    base = copy.deepcopy(BASE)
    base["goodput"]["acceptance"]["passes_slo_gain"] = False
    assert check(base, copy.deepcopy(BASE), 0.2, False) == []


def test_sections_filter_scopes_rows_and_flags():
    """--sections gates only the named sections: a failing row/flag outside
    the scope is invisible to that leg, inside it still fails."""
    fresh = copy.deepcopy(BASE)
    fresh["router"]["acceptance"]["passes_affinity_gain"] = False
    fresh["tp"]["acceptance"]["per_shard_kv_bytes_ratio"] = 1.0   # worse
    fails = check(copy.deepcopy(BASE), fresh, 0.2, True)
    assert any("passes_affinity_gain" in f for f in fails)
    assert any("per_shard_kv_bytes_ratio" in f for f in fails)
    assert check(copy.deepcopy(BASE), fresh, 0.2, True,
                 sections={"goodput"}) == []
    fails = check(copy.deepcopy(BASE), fresh, 0.2, True,
                  sections={"tp", "router"})
    assert any("passes_affinity_gain" in f for f in fails)
    assert any("per_shard_kv_bytes_ratio" in f for f in fails)


def test_every_gated_metric_resolvable_in_reference_shape():
    """Keep GATED_METRICS and the reference bench shape in sync: a metric
    path that resolves in neither direction would silently gate nothing."""
    from benchmarks.check_bench import _acceptance_cells, _resolve
    tree = _acceptance_cells(copy.deepcopy(BASE))
    for path, _, _, _ in GATED_METRICS:
        assert _resolve(tree, path) is not None, path
