"""The pluggable KV-cache backend seam (serve/kvcache.py).

Four claim groups:

* **Backend-swap anchors.** The extraction is behaviour-preserving: an
  engine built with an EXPLICIT ``kv_backend`` name streams bit-identical
  greedy tokens to the implicit layout-follows-page_size engine, for both
  the dense and the paged fp32 representations (test_paged.py already pins
  paged == dense; these pin explicit == implicit through the new seam).
* **Int8 page round-trip.** ``quantize_page`` reconstructs within half a
  quantization step everywhere, masks partial pages' stale rows to exact
  zeros, and maps an all-zero page to scale 1.0 (hypothesis property +
  deterministic anchors).
* **Int8 serving quality.** Per int8-supported family, the quantized
  backend's greedy streams stay close to the fp32 backend's — gated on
  mean per-request prefix-match fraction — and the int8 pools' resident
  K/V bytes are <= 0.30x the fp32 pools'.
* **Int8 x prefix-cache interplay.** Aliased prefix pages carry their
  scale with them (a second hit changes neither payload nor scale), COW
  re-materialisation re-quantizes the fresh page exactly once, and
  ``assert_page_invariants`` rejects a corrupted scale table.

Plus the refactor's structural guard: serve/engine.py must not import
page-layout internals from models/registry (checked against the module AST,
so it cannot silently regress).
"""
import ast
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.quantize import page_scale, quantize_page
from repro.models.registry import get_model, reduced_config
from repro.serve.engine import ServeEngine
from repro.serve.kvcache import (INT8_KV_FAMILIES, DenseBackend,
                                 PagedFP32Backend, PagedInt8Backend,
                                 make_backend)

try:
    from hypothesis import given, strategies as st
    from hypothesis.extra import numpy as hnp
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

S_MAX = 32
PS = 8

INT8_ARCHS = ["qwen2.5-32b", "moonshot-v1-16b-a3b", "llama-3.2-vision-11b"]


@pytest.fixture(scope="module")
def qwen():
    cfg = reduced_config(configs.get_config("qwen2.5-32b"))
    model = get_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _workload(engine, vocab):
    """Same slot-recycling workload test_paged.py anchors on."""
    rng = np.random.default_rng(11)
    gens = [6, 4, 8, 5]
    return [engine.submit(rng.integers(0, vocab, 8), g) for g in gens]


def _serve(model, params, **kw):
    eng = ServeEngine(model, params, batch_slots=2, s_max=S_MAX, **kw)
    reqs = _workload(eng, model.cfg.vocab_size)
    eng.run()
    return eng, [r.tokens for r in reqs]


# ------------------------------------------------------- registry/resolution
def test_make_backend_resolution():
    fam = configs.get_config("qwen2.5-32b").family
    assert isinstance(make_backend(None, family=fam), DenseBackend)
    assert isinstance(make_backend(None, family=fam, page_size=8,
                                   num_pages=4), PagedFP32Backend)
    for name in ("paged", "paged_fp32"):
        be = make_backend(name, family=fam, page_size=8, num_pages=4)
        assert type(be) is PagedFP32Backend
    be = make_backend("paged_int8", family=fam, page_size=8, num_pages=4)
    assert isinstance(be, PagedInt8Backend) and be.quantized
    # instance passthrough
    assert make_backend(be, family=fam) is be
    with pytest.raises(ValueError, match="conflicts"):
        make_backend("dense", family=fam, page_size=8)
    with pytest.raises(ValueError, match="page_size"):
        make_backend("paged_int8", family=fam)
    with pytest.raises(ValueError, match="unknown"):
        make_backend("latent_mla", family=fam, page_size=8)


def test_int8_unsupported_family_degrades_to_fp32(caplog):
    """Hybrid's ring carry is not page-reconstructible: int8 on it falls
    back to fp32 pages with a warning instead of failing, and serving
    still works end to end."""
    fam = configs.get_config("hymba-1.5b").family
    assert fam not in INT8_KV_FAMILIES
    with caplog.at_level("WARNING", logger="repro.serve"):
        be = make_backend("paged_int8", family=fam, page_size=8, num_pages=8)
    assert type(be) is PagedFP32Backend
    assert any("falling back" in r.message for r in caplog.records)
    eng = ServeEngine.build("hymba-1.5b", batch_slots=2, s_max=S_MAX,
                            page_size=PS, kv_backend="paged_int8")
    assert not eng.backend.quantized
    req = eng.submit(np.arange(1, 9, dtype=np.int32), 4)
    eng.run()
    assert req.done and len(req.tokens) == 4


# ------------------------------------------------------- backend-swap anchors
def test_explicit_dense_backend_bit_exact(qwen):
    model, params = qwen
    _, implicit = _serve(model, params)
    eng, explicit = _serve(model, params, kv_backend="dense")
    assert isinstance(eng.backend, DenseBackend)
    assert implicit == explicit


@pytest.mark.parametrize("page_size", [PS, S_MAX])
def test_explicit_paged_backend_bit_exact(qwen, page_size):
    """Multi-page (kernel path) AND degenerate one-page (einsum anchor)
    configs: the seam changes zero greedy tokens."""
    model, params = qwen
    _, implicit = _serve(model, params, page_size=page_size)
    eng, explicit = _serve(model, params, page_size=page_size,
                           kv_backend="paged_fp32")
    assert type(eng.backend) is PagedFP32Backend
    assert implicit == explicit


# -------------------------------------------------------- page round-trip
def _roundtrip_page(x, valid=None):
    q, scale = quantize_page(jnp.asarray(x), None if valid is None
                             else jnp.asarray(valid))
    q, scale = np.asarray(q), float(scale)
    deq = q.astype(np.float32) * scale
    live = (np.ones(len(x), bool) if valid is None
            else np.asarray(valid, bool))
    err = np.abs(x[live] - deq[live])
    assert (err <= scale * 0.5 + 1e-6).all(), err.max()
    assert (deq[~live] == 0).all()           # masked rows exactly zero
    assert np.isfinite(scale) and scale > 0
    return q, scale


def test_page_roundtrip_deterministic():
    rng = np.random.default_rng(0)
    x = (rng.integers(-10000, 10000, (PS, 2, 4)) / 100.0).astype(np.float32)
    _roundtrip_page(x)
    # partial page: stale tail rows excluded from amax AND zeroed
    x[0] = 1000.0                            # huge stale row
    valid = np.zeros(PS, bool)
    valid[1:] = True
    q, scale = _roundtrip_page(x, valid)
    assert scale <= page_scale(jnp.abs(jnp.asarray(x[1:])).max()) + 1e-6


def test_all_zero_page_scale_is_one():
    q, scale = quantize_page(jnp.zeros((PS, 2, 4), jnp.float32))
    assert float(scale) == 1.0
    assert (np.asarray(q) == 0).all()
    # fully-masked partial page behaves the same
    q, scale = quantize_page(jnp.ones((PS, 2, 4), jnp.float32),
                             jnp.zeros(PS, bool))
    assert float(scale) == 1.0 and (np.asarray(q) == 0).all()


if HAVE_HYPOTHESIS:
    @given(hnp.arrays(np.float32, (PS, 2, 4),
                      elements=st.integers(-100000, 100000).map(
                          lambda i: np.float32(i / 1000.0))),
           st.integers(0, PS))
    def test_page_roundtrip_property(x, n_valid):
        """Round-trip within scale/2 for full AND partial pages (integer-
        derived floats: hypothesis float strategies trip over subnormals
        the quantizer legitimately flushes)."""
        valid = np.arange(PS) < n_valid
        _roundtrip_page(x, valid)
        if n_valid == PS:
            _roundtrip_page(x)


# ----------------------------------------------------- int8 serving quality
def _prefix_match_fraction(a, b):
    if not a and not b:
        return 1.0
    n = 0
    for x, y in zip(a, b):
        if x != y:
            break
        n += 1
    return n / max(len(a), len(b))


@pytest.mark.parametrize("arch", INT8_ARCHS)
def test_int8_greedy_divergence_bounded(arch):
    """Per int8 family: quantized-KV greedy streams keep a mean per-request
    prefix-match fraction >= 0.6 vs the fp32 backend (random reduced models
    leave a wide top-1 logit margin, so ~1e-3-relative KV perturbation flips
    few argmaxes; the gate catches a broken scale path, which collapses the
    match to ~0)."""
    cfg = reduced_config(configs.get_config(arch))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    _, fp32 = _serve(model, params, page_size=PS)
    eng, int8 = _serve(model, params, page_size=PS, kv_backend="paged_int8")
    assert isinstance(eng.backend, PagedInt8Backend)
    match = [_prefix_match_fraction(a, b) for a, b in zip(fp32, int8)]
    assert np.mean(match) >= 0.6, (match, fp32, int8)


def _pool_bytes(cache):
    keys = [k for k in cache if k in ("k", "v") or k.endswith("_scale")]
    return sum(int(cache[k].size * cache[k].dtype.itemsize) for k in keys)


def test_int8_resident_kv_bytes_ratio(qwen):
    """Equal pool geometry: int8 K/V + scale tables <= 0.30x the fp32
    pools (int8 payload is 0.25x; the (L, P) scale tables are noise)."""
    model, params = qwen
    fp32, _ = _serve(model, params, page_size=PS)
    int8, _ = _serve(model, params, page_size=PS, kv_backend="paged_int8")
    ratio = _pool_bytes(int8.cache) / _pool_bytes(fp32.cache)
    assert ratio <= 0.30, ratio
    assert int8.resident_cache_bytes() < fp32.resident_cache_bytes()


# ------------------------------------------------- int8 x prefix interplay
def _scale_tables(cache):
    return {k: np.asarray(v) for k, v in cache.items()
            if k.endswith("_scale")}


def test_int8_prefix_hit_aliases_pages_and_scales(qwen):
    """A repeat prompt aliases the donor's prefix pages; the shared pages'
    payload AND scales are untouched by the second request, and its greedy
    stream matches its prefix-cache-off int8 twin (the int8 analogue of the
    fp32 prefix bit-exactness anchor — same representation both sides, so
    the comparison is exact, not gated)."""
    model, params = qwen
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, model.cfg.vocab_size, 16).astype(np.int32)

    def serve_twice(prefix_cache):
        eng = ServeEngine(model, params, batch_slots=2, s_max=S_MAX,
                          page_size=PS, kv_backend="paged_int8",
                          prefix_cache=prefix_cache)
        toks = []
        for _ in range(2):
            r = eng.submit(prompt, 5)
            eng.run()
            toks.append(r.tokens)
            eng.assert_page_invariants()
        return eng, toks

    eng_on, toks_on = serve_twice(True)
    _, toks_off = serve_twice(False)
    assert toks_on == toks_off
    assert eng_on.metrics.summary()["prefix"]["hit_rate"] > 0

    # shared full pages' scales survive the aliasing request: serve the
    # repeat while SNAPSHOTTING the scale tables around it
    eng = ServeEngine(model, params, batch_slots=2, s_max=S_MAX,
                      page_size=PS, kv_backend="paged_int8",
                      prefix_cache=True)
    r1 = eng.submit(prompt, 5)
    eng.run()
    donor_pages = sorted(eng.prefix_index.pages)
    before = _scale_tables(eng.cache)
    r2 = eng.submit(prompt, 5)
    eng.run()
    after = _scale_tables(eng.cache)
    assert r1.tokens == r2.tokens
    for key in before:
        np.testing.assert_array_equal(before[key][:, donor_pages],
                                      after[key][:, donor_pages],
                                      err_msg=f"aliased {key} rewritten")


def test_int8_cow_requantizes_fresh_page_once(qwen):
    """An unaligned repeat (prefix ends mid-page) re-materialises the
    partial page copy-on-write: the fresh page's scale equals the SOURCE
    page's right after the copy, then the tail splice re-quantizes exactly
    that one page — and the diverging stream still matches the cache-off
    int8 twin."""
    model, params = qwen
    rng = np.random.default_rng(7)
    # the donor's prompt IS the unaligned head (1 page + 4 rows): its
    # register leaves a partial-page entry the sharers must COW to extend
    head = rng.integers(0, model.cfg.vocab_size, 12).astype(np.int32)
    tails = [rng.integers(0, model.cfg.vocab_size, 6).astype(np.int32)
             for _ in range(2)]
    workload = [(head, 5)] + [(np.concatenate([head, t]), 5) for t in tails]

    def serve(prefix_cache):
        eng = ServeEngine(model, params, batch_slots=2, s_max=S_MAX,
                          page_size=PS, kv_backend="paged_int8",
                          prefix_cache=prefix_cache)
        toks = []
        for prompt, gen in workload:
            r = eng.submit(prompt, gen)
            eng.run()
            toks.append(r.tokens)
            eng.assert_page_invariants()
        return eng, toks

    eng_on, toks_on = serve(True)
    _, toks_off = serve(False)
    assert toks_on == toks_off
    assert eng_on.metrics.summary()["prefix"]["cow_copies"] >= 1


def test_invariants_reject_corrupt_scale_table(qwen):
    model, params = qwen
    eng = ServeEngine(model, params, batch_slots=2, s_max=S_MAX,
                      page_size=PS, kv_backend="paged_int8")
    eng.assert_page_invariants()
    eng.cache["k_scale"] = eng.cache["k_scale"].at[0, 0].set(0.0)
    with pytest.raises(AssertionError, match="k_scale"):
        eng.assert_page_invariants()


# ------------------------------------------------------- structural guard
def test_engine_does_not_import_page_layout_internals():
    """The refactor's contract, checked at the AST so it cannot silently
    regress: engine.py orchestrates through the KVBackend seam and must not
    import the page-layout internals it used to own — nor, since the
    sharding-aware seam, any mesh/axis internals (placement lives behind
    KVBackend.place/pool_axes, trace context and mesh construction behind
    specs.serve_trace/serve_mesh; the engine holds the mesh as an opaque
    token)."""
    banned = {"init_paged_cache", "insert_cache_rows",
              "insert_cache_rows_paged", "copy_pool_rows",
              "seed_prefix_cache", "vectorize_cache_pos",
              "cache_capacity", "extract_cache_slot", "PAGED_POOL_LEAVES",
              # mesh/axis internals: every one of these appearing in
              # engine.py means a layout decision leaked out of the seam
              "NamedSharding", "PartitionSpec", "shard_map", "TP_AXIS",
              "use_mesh", "TP_SERVE_RULES", "TP_POOL_RULES",
              "KV_POOL_AXES", "axis_names", "head_shard_axis",
              "latent_head_shard_axis", "sharding_for", "make_mesh"}
    path = (pathlib.Path(__file__).resolve().parents[1]
            / "src" / "repro" / "serve" / "engine.py")
    tree = ast.parse(path.read_text())
    imported = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom):
            imported |= {a.name for a in node.names}
        elif isinstance(node, ast.Import):
            imported |= {a.name for a in node.names}
    hit = banned & imported
    assert not hit, (f"engine.py imports page-layout internals {sorted(hit)};"
                     " route them through serve/kvcache.py's KVBackend")
    # and the registry names must not be referenced as bare identifiers
    # either (a `registry.insert_cache_rows` attribute access would dodge
    # the import check only by re-importing the module wholesale)
    names = {n.id for n in ast.walk(tree) if isinstance(n, ast.Name)}
    attrs = {n.attr for n in ast.walk(tree) if isinstance(n, ast.Attribute)}
    hit = banned & (names | attrs)
    assert not hit, f"engine.py references page-layout internals {sorted(hit)}"
