"""Paged KV cache: paged-vs-dense equivalence (the degenerate
page_size == s_max config must be bit-exact; smaller pages must produce
identical greedy tokens), page allocator exhaustion/recycling, admission
deferral when the free list is short, and admission of requests longer than
an equivalent dense engine's s_max would allow.

Equivalence leans on the design anchor stated in
``models/layers.py::attention_decode_paged``: the gathered block-table view
of a slot's pages holds exactly the rows the dense cache would, in the same
logical order, and masked rows contribute exactly 0 — so greedy argmax
streams must match token-for-token at ANY page size, and bit-for-bit at the
degenerate one.
"""
import jax
import numpy as np
import pytest

from repro import configs
from repro.models.registry import (cache_capacity, extract_cache_slot,
                                   get_model, reduced_config)
from repro.serve.engine import PageAllocator, ServeEngine

S_MAX = 32


@pytest.fixture(scope="module")
def hymba():
    cfg = reduced_config(configs.get_config("hymba-1.5b"))
    model = get_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def qwen():
    cfg = reduced_config(configs.get_config("qwen2.5-32b"))
    model = get_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _workload(engine, vocab):
    """requests > batch_slots so slots recycle mid-run (continuous batching
    over page alloc/free, not just a single prefill+decode)."""
    rng = np.random.default_rng(11)
    gens = [6, 4, 8, 5]
    return [engine.submit(rng.integers(0, vocab, 8), g) for g in gens]


def _run_pair(model, params, page_size, **paged_kw):
    dense = ServeEngine(model, params, batch_slots=2, s_max=S_MAX)
    d_reqs = _workload(dense, model.cfg.vocab_size)
    dense.run()
    paged = ServeEngine(model, params, batch_slots=2, s_max=S_MAX,
                        page_size=page_size, **paged_kw)
    p_reqs = _workload(paged, model.cfg.vocab_size)
    paged.run()
    return dense, d_reqs, paged, p_reqs


# ------------------------------------------------------------ equivalence
@pytest.mark.parametrize("arch_fixture", ["qwen", "hymba"])
def test_degenerate_page_equals_dense_bit_exact(arch_fixture, request):
    """page_size == s_max (one page per slot): greedy tokens match the dense
    engine for a slot-recycling workload, and a mid-flight slot's cache —
    gathered through its block table — is bit-identical to the dense slot
    (K/V rows, ring positions, recurrent state, pos)."""
    model, params = request.getfixturevalue(arch_fixture)
    dense, d_reqs, paged, p_reqs = _run_pair(model, params, S_MAX)
    for d, p in zip(d_reqs, p_reqs):
        assert d.tokens == p.tokens
    # bit-exactness of live cache state: step both engines mid-request
    de = ServeEngine(model, params, batch_slots=2, s_max=S_MAX)
    pe = ServeEngine(model, params, batch_slots=2, s_max=S_MAX,
                     page_size=S_MAX)
    dr = de.submit(np.arange(1, 9, dtype=np.int32), 10)
    pr = pe.submit(np.arange(1, 9, dtype=np.int32), 10)
    for _ in range(4):
        de.step()
        pe.step()
    dc = extract_cache_slot(de.cache, dr.slot)
    pc = extract_cache_slot(pe.cache, pr.slot)
    assert set(dc) == set(pc)
    cap = cache_capacity(model.cfg, S_MAX)
    for key in dc:
        d_leaf = np.asarray(dc[key])
        if key in ("k", "v"):
            d_leaf = d_leaf[:, :, :cap]
        np.testing.assert_array_equal(d_leaf, np.asarray(pc[key]),
                                      err_msg=key)


@pytest.mark.parametrize("page_size", [4, 16])
def test_small_pages_identical_greedy_tokens(qwen, page_size):
    """page_size < s_max: same greedy streams; the pool is smaller than the
    dense slots x s_max block for page_size 4 with a workload-sized pool."""
    model, params = qwen
    need_pages = -(-(8 + 8 - 1) // page_size)           # worst request
    dense, d_reqs, paged, p_reqs = _run_pair(
        model, params, page_size, num_pages=2 * need_pages)
    for d, p in zip(d_reqs, p_reqs):
        assert d.tokens == p.tokens
    assert paged.resident_cache_bytes() < dense.resident_cache_bytes()


def test_small_pages_hybrid_ring(hymba):
    """The hybrid ring (width = window) pages too: ring writes/reads go
    through the block table and still match the dense ring exactly."""
    model, params = hymba
    ps = cache_capacity(model.cfg, S_MAX) // 2          # 2 pages per ring
    dense, d_reqs, paged, p_reqs = _run_pair(model, params, ps)
    for d, p in zip(d_reqs, p_reqs):
        assert d.tokens == p.tokens


def test_paged_encdec_equivalence():
    """Whisper decode: paged self-attn KV + dense cross K/V."""
    engine_kw = dict(batch_slots=2, s_max=S_MAX)
    cfg = reduced_config(configs.get_config("whisper-large-v3"))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    dense = ServeEngine(model, params, **engine_kw)
    d = dense.submit(np.arange(1, 7, dtype=np.int32), 5)
    dense.run()
    paged = ServeEngine(model, params, page_size=8, **engine_kw)
    p = paged.submit(np.arange(1, 7, dtype=np.int32), 5)
    paged.run()
    assert d.tokens == p.tokens and len(p.tokens) == 5


def test_paged_vlm_super_layer_equivalence():
    """VLM decode threads block tables through the super-layer unroll
    (self-attn paged, gated image cross-attn untouched)."""
    cfg = reduced_config(configs.get_config("llama-3.2-vision-11b"))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    dense = ServeEngine(model, params, batch_slots=2, s_max=S_MAX)
    d = dense.submit(np.arange(1, 9, dtype=np.int32), 5)
    dense.run()
    paged = ServeEngine(model, params, batch_slots=2, s_max=S_MAX,
                        page_size=8)
    p = paged.submit(np.arange(1, 9, dtype=np.int32), 5)
    paged.run()
    assert d.tokens == p.tokens and len(p.tokens) == 5


def test_ssm_family_falls_back_to_dense():
    """rwkv state is O(1) in s_max: paging is a no-op, not an error."""
    engine = ServeEngine.build("rwkv6-7b", reduced=True, batch_slots=2,
                               s_max=16, page_size=8)
    assert not engine.paged
    req = engine.submit(np.array([1, 2, 3], np.int32), 4)
    engine.run()
    assert req.done and len(req.tokens) == 4


# ------------------------------------------------------------ allocator
def test_page_allocator_exhaustion_and_recycling():
    a = PageAllocator(4)
    p1 = a.alloc(3)
    assert sorted(p1) == [0, 1, 2] and a.free == 1
    assert a.alloc(2) is None and a.free == 1    # all-or-nothing
    p2 = a.alloc(1)
    assert a.free == 0
    a.release(p1)
    assert a.free == 3
    assert sorted(a.alloc(3) + p2) == [0, 1, 2, 3]
    with pytest.raises(ValueError, match="double free"):
        a.release(p2 + p2)


def test_admission_defers_until_pages_free(qwen):
    """Pool covers ONE request's worst case: the second waits (deferral
    counter ticks) and is admitted only after the first's pages release —
    and both still complete with full token counts."""
    model, params = qwen
    # prefix_cache off: this test pins the RAW free-list recycling contract
    # (every page back after the run); with caching on, prompt pages are
    # deliberately RETAINED by the prefix index — tests/test_prefix.py
    # covers that retention/eviction accounting
    engine = ServeEngine(model, params, batch_slots=2, s_max=S_MAX,
                         page_size=8, num_pages=3,      # need 2 pages/request
                         prefix_cache=False)
    a = engine.submit(np.arange(1, 9, dtype=np.int32), 5)
    b = engine.submit(np.arange(9, 17, dtype=np.int32), 5)
    engine.step()
    assert a.slot is not None and b.slot is None
    assert engine.deferrals >= 1
    engine.run()
    assert a.done and b.done
    assert len(a.tokens) == 5 and len(b.tokens) == 5
    assert engine.free_pages == engine.num_pages         # fully recycled


def test_pool_exhaustion_recycles_across_many_requests(qwen):
    """8 requests through a pool that can hold ~2 concurrently: slots defer,
    pages recycle, everything completes (the continuous-batching loop cannot
    deadlock on page pressure)."""
    model, params = qwen
    # prefix_cache off: pins full free-list recycling (see the note in
    # test_admission_defers_until_pages_free)
    engine = ServeEngine(model, params, batch_slots=4, s_max=S_MAX,
                         page_size=8, num_pages=4, prefix_cache=False)
    rng = np.random.default_rng(3)
    reqs = [engine.submit(rng.integers(0, model.cfg.vocab_size, 8), 4)
            for _ in range(8)]
    engine.run()
    assert all(r.done and len(r.tokens) == 4 for r in reqs)
    assert engine.deferrals > 0
    assert engine.free_pages == engine.num_pages


def test_long_request_admittable_when_pool_allows(qwen):
    """The acceptance case: rows = prompt+gen-1 = 56 exceeds a dense
    engine's s_max=32 equivalent, but the paged engine admits it because
    admission is bounded by pool capacity (and the block-table span), not a
    per-slot dense preallocation."""
    model, params = qwen
    dense = ServeEngine(model, params, batch_slots=2, s_max=S_MAX)
    with pytest.raises(ValueError, match="s_max"):
        dense.submit(np.arange(0, 40, dtype=np.int32), 17)
    paged = ServeEngine(model, params, batch_slots=2, s_max=2 * S_MAX,
                        page_size=8, num_pages=8)
    req = paged.submit(np.arange(0, 40, dtype=np.int32), 17)
    paged.run()
    assert req.done and len(req.tokens) == 17
    # and the pool is SMALLER than the dense engine's k/v even at 2x s_max:
    # 8 pages x 8 rows = 64 resident rows vs dense 2 slots x 64 rows
    assert paged.resident_cache_bytes() < \
        ServeEngine(model, params, batch_slots=2,
                    s_max=2 * S_MAX).resident_cache_bytes()


def test_submit_rejects_pool_impossible_request(qwen):
    """A request no amount of recycling can serve fails at submit, keeping
    admission infallible."""
    model, params = qwen
    engine = ServeEngine(model, params, batch_slots=1, s_max=S_MAX,
                         page_size=8, num_pages=2)
    with pytest.raises(ValueError, match="pages"):
        engine.submit(np.arange(0, 20, dtype=np.int32), 10)  # 29 rows > 16
