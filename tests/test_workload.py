"""Tests for the open-loop traffic harness (repro.serve.workload): seeded
determinism is the property the CI bench gate depends on — same spec must
generate a byte-identical schedule on any platform — plus the burst-window
and clipping semantics, spec validation, and a tiny end-to-end replay.
"""
import jax
import numpy as np
import pytest

from repro import configs
from repro.models.registry import get_model, reduced_config
from repro.serve.engine import ServeEngine
from repro.serve.metrics import SLO
from repro.serve.workload import ArrivalEvent, WorkloadSpec, generate, replay

VOCAB = 512


def _spec(**kw):
    base = dict(n_requests=64, rate_rps=50.0, seed=7)
    base.update(kw)
    return WorkloadSpec(**base)


def test_same_seed_is_byte_identical():
    a = generate(_spec(), VOCAB)
    b = generate(_spec(), VOCAB)
    assert len(a) == len(b) == 64
    for ea, eb in zip(a, b):
        assert ea.t == eb.t
        assert ea.gen_len == eb.gen_len
        assert ea.priority == eb.priority
        assert np.array_equal(ea.prompt, eb.prompt)


def test_different_seed_diverges():
    a = generate(_spec(seed=7), VOCAB)
    b = generate(_spec(seed=8), VOCAB)
    assert [e.t for e in a] != [e.t for e in b]
    assert any(not np.array_equal(ea.prompt, eb.prompt)
               for ea, eb in zip(a, b))


def test_arrivals_sorted_and_lengths_clipped():
    ev = generate(_spec(n_requests=200, prompt_len_median=24,
                        prompt_len_sigma=1.5, prompt_len_max=48,
                        gen_len_median=8, gen_len_sigma=1.5, gen_len_max=16),
                  VOCAB)
    ts = [e.t for e in ev]
    assert ts == sorted(ts) and ts[0] > 0.0
    assert all(1 <= len(e.prompt) <= 48 for e in ev)
    assert all(1 <= e.gen_len <= 16 for e in ev)
    assert all(e.prompt.dtype == np.int32 for e in ev)
    assert all(0 <= e.prompt.min() and e.prompt.max() < VOCAB for e in ev)
    # heavy tail actually exercised: the clip boundaries are both reached
    assert any(len(e.prompt) == 48 for e in ev)


def test_burst_window_densifies_arrivals():
    """Inside the burst window the instantaneous rate is multiplied, so the
    mean inter-arrival gap inside the window must be well below the gap
    outside it (4x burst => ~4x denser, compare with slack for variance)."""
    spec = _spec(n_requests=400, rate_rps=100.0, burst_start_frac=0.25,
                 burst_len_frac=0.5, burst_mult=4.0)
    ev = generate(spec, VOCAB)
    horizon = spec.n_requests / spec.rate_rps
    lo, hi = 0.25 * horizon, 0.75 * horizon
    gaps_in, gaps_out = [], []
    prev = 0.0
    for e in ev:
        (gaps_in if lo <= prev < hi else gaps_out).append(e.t - prev)
        prev = e.t
    assert len(gaps_in) > 20 and len(gaps_out) > 20
    assert np.mean(gaps_in) < 0.5 * np.mean(gaps_out)


def test_priority_mix_respects_weights():
    ev = generate(_spec(n_requests=300, priority_weights=((0, 0.2), (2, 0.8))),
                  VOCAB)
    counts = {p: sum(1 for e in ev if e.priority == p) for p in (0, 2)}
    assert set(e.priority for e in ev) <= {0, 2}
    assert counts[2] > counts[0]          # 80/20 mix, generous margin


def test_spec_validation():
    with pytest.raises(ValueError, match="n_requests"):
        generate(_spec(n_requests=0), VOCAB)
    with pytest.raises(ValueError, match="rate_rps"):
        generate(_spec(rate_rps=0.0), VOCAB)


def test_replay_smoke_meters_goodput():
    """End-to-end: replay a tiny workload against a real reduced engine and
    check the summary accounts for every submitted request and carries the
    per-priority goodput section."""
    cfg = reduced_config(configs.get_config("qwen2.5-32b"))
    model = get_model(cfg)
    eng = ServeEngine(model, model.init(jax.random.PRNGKey(0)),
                      batch_slots=2, s_max=48)
    events = generate(WorkloadSpec(
        n_requests=3, rate_rps=1e9, seed=0, prompt_len_median=8,
        prompt_len_max=16, gen_len_median=3, gen_len_max=4,
        priority_weights=((0, 0.5), (1, 0.5))), cfg.vocab_size)
    s = replay(eng, events, slo=SLO(ttft_s=60.0, itl_p95_s=60.0))
    assert s["requests"] == 3
    assert s["completed"] + s["aborted"] == 3
    g = s["goodput"]
    assert g["submitted"] == 3
    assert set(g["by_priority"]) <= {"0", "1"}
    assert 0.0 <= g["slo_attainment"] <= 1.0
