"""Regression tests for the PR 6 scheduler/metrics accounting fixes and the
goodput-aware scheduling features (SchedPolicy).

The accounting bugs each had a real failure mode: aborted requests inflated
throughput exactly when the engine misbehaved, a duplicated first-token
callback double-counted the very first token, lazily-cancelled requests
made ``waiting``/``peek`` disagree with ``next_request``, and the
prefix-hint ordering could starve a cold prompt indefinitely. The policy
features all default OFF — the bit-exactness anchor — so every test here
that turns one on also checks the token streams stay bit-identical to the
featureless engine: scheduling may reorder WORK, never change RESULTS.
"""
import jax
import numpy as np
import pytest

from repro import configs
from repro.models.registry import get_model, reduced_config
from repro.serve.engine import ServeEngine
from repro.serve.metrics import SLO, MetricsRecorder
from repro.serve.scheduler import (Request, RequestState, SchedPolicy,
                                   Scheduler)


@pytest.fixture(scope="module")
def qwen_mp():
    cfg = reduced_config(configs.get_config("qwen2.5-32b"))
    model = get_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _req(rid, priority=0, hint=0, plen=2):
    return Request(rid=rid, prompt=np.arange(1, plen + 1, dtype=np.int32),
                   gen_len=1, priority=priority, prefix_hint=hint)


def _drain(engine, *reqs, ticks=400):
    for _ in range(ticks):
        if all(r.done or r.state in (RequestState.FAILED,
                                     RequestState.CANCELLED) for r in reqs):
            return
        engine.step()
    raise AssertionError(
        f"requests did not finish in {ticks} ticks: "
        f"{[(r.rid, r.state) for r in reqs]}")


# ------------------------------------------------------ metrics accounting
def test_throughput_excludes_aborted_tokens():
    """An aborted request's partial stream was never delivered: it must not
    count toward throughput (the old accounting inflated tokens/s exactly
    when requests were failing) but stays visible as ``aborted_tokens``."""
    t = {"now": 0.0}
    m = MetricsRecorder(clock=lambda: t["now"])
    m.on_start()
    for rid in (0, 1):
        m.on_submit(rid, prompt_len=4)
        m.on_first_token(rid)
    t["now"] = 1.0
    m.on_token(0)
    m.on_token(1)
    m.on_done(0)
    m.on_aborted(1)                       # 2 tokens generated, then failed
    t["now"] = 2.0
    m.on_stop()
    s = m.summary()
    assert s["total_tokens"] == 2         # served request only
    assert s["aborted_tokens"] == 2       # visible, but separate
    assert s["throughput_tokens_per_s"] == pytest.approx(2 / 2.0)


def test_on_first_token_idempotent():
    """A retried/duplicated first-token callback must not double-count the
    token: the increment rides the same guard as the timestamp."""
    t = {"now": 1.0}
    m = MetricsRecorder(clock=lambda: t["now"])
    m.on_submit(0, prompt_len=2)
    m.on_first_token(0)
    t["now"] = 5.0
    m.on_first_token(0)                   # duplicate: must be a no-op
    rec = m.requests[0]
    assert rec.n_tokens == 1
    assert rec.t_first_token == 1.0       # first call's stamp survives


def test_goodput_attainment_counts_shed_as_miss():
    """Attainment denominators are ALL submitted requests: admission
    control cannot buy attainment by refusing the load it is graded on."""
    t = {"now": 0.0}
    m = MetricsRecorder(clock=lambda: t["now"])
    m.on_start()
    m.on_submit(0, prompt_len=2, priority=0)          # meets the SLO
    m.on_first_token(0)
    t["now"] = 1.0
    m.on_token(0)
    m.on_done(0)
    m.on_submit(1, prompt_len=2, priority=0)          # late first token
    t["now"] = 10.0
    m.on_first_token(1)
    m.on_done(1)
    m.on_submit(2, prompt_len=2, priority=2)          # shed: never served
    m.on_shed(2)
    m.on_aborted(2)
    m.on_stop()
    g = m.summary(SLO(ttft_s=2.0, itl_p95_s=5.0))["goodput"]
    assert g["submitted"] == 3 and g["slo_met"] == 1
    assert g["slo_attainment"] == pytest.approx(1 / 3)
    assert g["by_priority"]["0"]["slo_attainment"] == pytest.approx(1 / 2)
    assert g["by_priority"]["2"]["slo_attainment"] == 0.0
    assert m.shed_requests == 1


# ------------------------------------------------- scheduler: cancellation
def test_scheduler_skips_cancelled_everywhere():
    """Lazy cancellation is pruned at the single source of truth: peek,
    next_request, waiting, len and bool must all agree — before this fix
    ``waiting`` counted dead entries and the engine carried its own skip
    loop that could disagree with ``peek``."""
    s = Scheduler()
    r1, r2, r3 = _req(1), _req(2), _req(3)
    for r in (r1, r2, r3):
        s.submit(r)
    r2.state = RequestState.CANCELLED     # mid-heap
    assert s.waiting == 2 and len(s) == 2 and bool(s)
    r1.state = RequestState.CANCELLED     # head
    assert s.peek() is r3
    assert s.next_request() is r3
    assert s.next_request() is None
    assert s.waiting == 0 and not s


def test_hint_aging_bounds_cold_prompt_starvation():
    """A sustained cached-header stream may bypass an older cold prompt at
    most ``hint_max_bypasses`` times before the cold prompt is promoted —
    unbounded deferral was the bug; priorities still dominate the hint."""
    s = Scheduler(prefix_aware=True, hint_max_bypasses=2)
    cold = _req(0, hint=0)
    s.submit(cold)
    hot = [_req(i, hint=8) for i in range(1, 6)]
    for r in hot:
        s.submit(r)
    order = [s.next_request().rid for _ in range(6)]
    assert order == [1, 2, 0, 3, 4, 5]    # exactly two bypasses, then cold
    # a HIGHER priority hinted stream is not aged against a lower-priority
    # cold prompt: priorities are nice levels, the hint only reorders peers
    s2 = Scheduler(prefix_aware=True, hint_max_bypasses=1)
    low_cold = _req(10, priority=1, hint=0)
    s2.submit(low_cold)
    for i in (11, 12, 13):
        s2.submit(_req(i, priority=0, hint=8))
    assert [s2.next_request().rid for _ in range(4)] == [11, 12, 13, 10]


def test_preempted_request_keeps_arrival_seq():
    """A re-queued (preempted) request rejoins FIFO at its ORIGINAL arrival
    position, not the back of its priority level."""
    s = Scheduler()
    r1, r2 = _req(1), _req(2)
    s.submit(r1)
    s.submit(r2)
    popped = s.next_request()
    assert popped is r1
    s.submit(popped)                      # re-queue, seq preserved
    assert s.next_request() is r1         # still ahead of r2


# --------------------------------------------- EDF admission ordering (PR 8)
def test_edf_urgent_deadline_overtakes_earlier_arrival():
    """SchedPolicy.edf: within a priority level an urgent-deadline request
    admits before an EARLIER same-priority arrival; priorities still
    dominate deadlines, and undated requests queue FIFO behind dated ones."""
    s = Scheduler(edf=True)
    early = _req(1)                       # arrives first, no deadline (inf)
    s.submit(early)
    urgent = _req(2)
    urgent.deadline = 5.0                 # arrives later, tight deadline
    s.submit(urgent)
    relaxed = _req(3)
    relaxed.deadline = 50.0
    s.submit(relaxed)
    undated = _req(4)                     # second undated arrival
    s.submit(undated)
    assert [s.next_request().rid for _ in range(4)] == [2, 3, 1, 4]

    # priority dominates: a priority-1 request never beats priority-0,
    # however urgent its deadline
    s2 = Scheduler(edf=True)
    lo = _req(10, priority=1)
    lo.deadline = 1.0
    hi = _req(11, priority=0)             # undated but higher priority
    s2.submit(lo)
    s2.submit(hi)
    assert [s2.next_request().rid for _ in range(2)] == [11, 10]


def test_edf_off_is_exact_fifo():
    """The default (edf off) ignores deadlines entirely — arrival order is
    preserved even when later requests carry tighter deadlines (the
    bit-exact anchor: the deadline key is constant, ordering falls through
    to seq exactly as before the field existed)."""
    s = Scheduler()
    rs = [_req(i) for i in range(4)]
    rs[2].deadline = 0.001                # would win under EDF
    for r in rs:
        s.submit(r)
    assert [s.next_request().rid for _ in range(4)] == [0, 1, 2, 3]


def test_edf_engine_wiring():
    """ServeEngine wires policy.edf into its default Scheduler and
    submit(deadline=) lands on the request; defaults stay FIFO."""
    eng = ServeEngine.build("qwen2.5-32b", batch_slots=1, s_max=32,
                            policy=SchedPolicy(edf=True))
    assert eng.scheduler.edf
    a = eng.submit(np.arange(1, 4, dtype=np.int32), 1)
    b = eng.submit(np.arange(1, 4, dtype=np.int32), 1, deadline=2.5)
    assert a.deadline == float("inf") and b.deadline == 2.5
    assert eng.scheduler.peek() is b      # dated overtakes undated peer
    assert not ServeEngine.build("qwen2.5-32b", batch_slots=1,
                                 s_max=32).scheduler.edf


# --------------------------------------------------- policy: bit-exactness
def test_default_policy_is_bit_exact_anchor(qwen_mp):
    """SchedPolicy() is all-off: an engine built with it emits the same
    greedy streams as policy=None (the pre-policy engine)."""
    assert SchedPolicy() == SchedPolicy(
        drr=False, drr_quantum=0, max_consecutive_prefill_ticks=0,
        preemption=False, admission_low_water=0.0,
        admission_shed_priority=None)
    model, params = qwen_mp
    streams = []
    for pol in (None, SchedPolicy()):
        eng = ServeEngine(model, params, batch_slots=2, s_max=48,
                          page_size=8, policy=pol)
        ra = eng.submit(np.arange(1, 9, dtype=np.int32), 6)
        rb = eng.submit(np.arange(40, 52, dtype=np.int32), 6)
        _drain(eng, ra, rb)
        streams.append((list(ra.tokens), list(rb.tokens)))
    assert streams[0] == streams[1]


def test_drr_interleaves_prefill_fairly(qwen_mp):
    """With DRR a short prompt admitted behind a long one reaches its first
    token FIRST (the long job no longer drains every tick's whole chunk
    budget); token contents stay bit-identical to the FIFO engine."""
    model, params = qwen_mp

    def run(pol):
        eng = ServeEngine(model, params, batch_slots=2, s_max=48,
                          prefill_chunk_tokens=8, policy=pol)
        long_r = eng.submit(np.arange(1, 33, dtype=np.int32), 4)
        short_r = eng.submit(np.arange(50, 58, dtype=np.int32), 4)
        _drain(eng, long_r, short_r)
        rec = eng.metrics.requests
        return (list(long_r.tokens), list(short_r.tokens),
                rec[long_r.rid].t_first_token, rec[short_r.rid].t_first_token)

    f_long, f_short, f_tl, f_ts = run(None)
    d_long, d_short, d_tl, d_ts = run(SchedPolicy(drr=True))
    assert f_ts > f_tl        # FIFO: the long head prefills first
    assert d_ts < d_tl        # DRR: the short job overtakes at chunk grain
    assert (d_long, d_short) == (f_long, f_short)   # results unchanged


def test_starvation_guard_keeps_decode_progress(qwen_mp):
    """Under sustained admission pressure the guard periodically skips a
    prefill tick so running requests still make token progress; everything
    completes and the skip counter records the interventions."""
    model, params = qwen_mp
    eng = ServeEngine(model, params, batch_slots=2, s_max=48,
                      prefill_chunk_tokens=8,
                      policy=SchedPolicy(max_consecutive_prefill_ticks=1))
    # one long decoder holds a slot RUNNING while the long-prompt followers
    # chunk through prefill — the overlap the guard exists to police (a
    # lockstep workload where prefill and decode never coincide cannot
    # trigger it)
    reqs = [eng.submit(np.arange(1, 9, dtype=np.int32), 24)]
    reqs += [eng.submit(np.arange(1, 25, dtype=np.int32), 2)
             for _ in range(4)]
    _drain(eng, *reqs)
    assert eng.metrics.starvation_guard_skips > 0
    assert all(r.done for r in reqs)


def test_preemption_pauses_lowest_and_resumes_bit_exact(qwen_mp):
    """Pool pressure + a premium arrival: the running low-priority request
    is paused (pages released, re-queued with its seq) and, once resumed,
    its final greedy stream is bit-identical to an uninterrupted run —
    recompute-style preemption changes timing, never tokens."""
    model, params = qwen_mp
    kw = dict(batch_slots=2, s_max=48, page_size=8, num_pages=4,
              prefix_cache=False)
    eng = ServeEngine(model, params, policy=SchedPolicy(preemption=True),
                      **kw)
    victim = eng.submit(np.arange(1, 9, dtype=np.int32), 8, priority=1)
    for _ in range(4):                    # victim prefills + decodes a bit
        eng.step()
    assert victim.state is RequestState.RUNNING
    prem = eng.submit(np.arange(20, 36, dtype=np.int32), 4, priority=0)
    _drain(eng, victim, prem)
    assert eng.metrics.preemptions >= 1
    assert victim.done and prem.done

    ref = ServeEngine(model, params, policy=None, **kw)
    ref_victim = ref.submit(np.arange(1, 9, dtype=np.int32), 8, priority=1)
    _drain(ref, ref_victim)
    assert list(victim.tokens) == list(ref_victim.tokens)


def test_double_preemption_folds_tokens_once(qwen_mp):
    """Preempting the SAME request twice must not re-fold already-folded
    tokens: each pause appends only the tokens generated since the last
    fold (``Request.folded`` watermark), so the re-prefilled context never
    duplicates and the resumed greedy stream still matches an
    uninterrupted run bit-for-bit."""
    model, params = qwen_mp
    kw = dict(batch_slots=2, s_max=48, page_size=8, num_pages=6,
              prefix_cache=False)
    eng = ServeEngine(model, params, policy=SchedPolicy(preemption=True),
                      **kw)
    prompt = np.arange(1, 9, dtype=np.int32)
    victim = eng.submit(prompt, 8, priority=1)
    for _ in range(4):                    # prefill + decode a little
        eng.step()
    assert victim.state is RequestState.RUNNING and victim.tokens
    eng._preempt(victim.slot)             # first pause
    n_first = len(victim.tokens)
    assert len(victim.prompt) == len(prompt) + n_first

    for _ in range(400):                  # resume, decode past the fold...
        eng.step()
        if (victim.state is RequestState.RUNNING
                and len(victim.tokens) > n_first):
            break
        assert not victim.done, "victim finished before second preemption"
    else:
        raise AssertionError("victim never resumed past the first fold")
    eng._preempt(victim.slot)             # ...second pause
    # only the tokens generated SINCE the first fold were appended
    assert len(victim.prompt) == len(prompt) + len(victim.tokens)
    assert eng.metrics.preemptions == 2
    _drain(eng, victim)

    ref = ServeEngine(model, params, policy=None, **kw)
    ref_victim = ref.submit(prompt, 8, priority=1)
    _drain(ref, ref_victim)
    assert list(victim.tokens) == list(ref_victim.tokens)


def test_admission_control_sheds_and_defers(qwen_mp):
    """Below the low-water mark a queued head at/beyond the shed priority
    is FAILED (shed=True) or parked in place (shed=False); premium heads
    are never gated."""
    model, params = qwen_mp
    kw = dict(batch_slots=2, s_max=48, page_size=8, num_pages=4,
              prefix_cache=False)

    def pressurize(policy):
        eng = ServeEngine(model, params, policy=policy, **kw)
        # 3 of 4 pages held -> free fraction 0.25 < 0.5 low water
        hog = eng.submit(np.arange(1, 17, dtype=np.int32), 6, priority=0)
        for _ in range(3):
            eng.step()
        assert hog.state is RequestState.RUNNING
        return eng, hog

    eng, hog = pressurize(SchedPolicy(admission_low_water=0.5,
                                      admission_shed_priority=1))
    low = eng.submit(np.arange(30, 34, dtype=np.int32), 2, priority=1)
    eng.step()
    assert low.state is RequestState.FAILED
    assert "shed" in low.error
    assert eng.metrics.shed_requests == 1
    _drain(eng, hog)

    eng, hog = pressurize(SchedPolicy(admission_low_water=0.5,
                                      admission_shed_priority=1,
                                      admission_shed=False))
    low = eng.submit(np.arange(30, 34, dtype=np.int32), 2, priority=1)
    eng.step()
    assert low.state is RequestState.QUEUED       # deferred, not dropped
    _drain(eng, hog, low)                         # pressure lifts -> served
    assert low.done and eng.metrics.shed_requests == 0
