"""Tensor-parallel serving equivalence (PR 8).

The tp mesh engine's contract is BITWISE: only the KV pool and the paged
attention core shard (heads partition cleanly over the kernel's (B, H,
pages) grid, all-gather before the output projection); weights and every
other activation replicate, so no float reduction is ever split across
shards. That makes the anchors exact token equality, not allclose:

* tp=1 mesh engine == plain (mesh-free) engine, bit-for-bit;
* tp=2 / tp=4 == tp=1, bit-for-bit, for dense, MoE, and VLM families,
  on both the kernel read path and the degenerate einsum anchor
  (page_size == s_max);
* per-shard resident KV pool bytes == global / tp, exactly.

Since the sharding-aware backend seam, EVERY cache backend composes with
tp, each under its own contract:

* fp32 pages: bitwise (the anchors above);
* int8 pages: scales are per-page per-kv-head-GROUP (L, P, tp) so each
  shard's amax is computed from purely local values — tp=1 stays bitwise
  vs mesh-free (one group == whole page), tp>1 is gated on greedy prefix
  match >= 0.6 vs tp=1 (different scale granularity, legitimately
  different rounding);
* latent pages: the pool replicates, the ABSORBED head axis shards —
  bitwise again (per-head attention over a shared latent row is
  head-independent and wb_v contracts only the head-local latent dim).

Multi-device cases run in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the conftest
run_multidevice pattern — the parent process stays single-device).
Build-time validation (tp too large, non-divisible kv heads, dense + mesh)
runs in-process.
"""
import numpy as np
import pytest

# reduced_config can collapse num_kv_heads to 1 (qwen2.5-32b 40h/8kv -> 4h/1kv,
# llama-vision 32h/8kv -> 4h/1kv), which leaves nothing to shard — the tp
# engines override the head counts (keeping GQA G=2 for dense) while staying
# reduced everywhere else.
_CASES = {
    "dense": ("qwen2.5-32b", dict(num_heads=8, num_kv_heads=4)),
    "moe": ("moonshot-v1-16b-a3b", None),          # reduced keeps kv=4
    "vlm": ("llama-3.2-vision-11b", dict(num_heads=8, num_kv_heads=4)),
}


def _equivalence_code(arch: str, overrides, page_size: int = 16,
                      s_max: int = 64, tps=(1, 2, 4)) -> str:
    return f"""
        import numpy as np
        from repro.serve.engine import ServeEngine

        rng = np.random.default_rng(0)
        prompts = [rng.integers(1, 400, n).astype(np.int32)
                   for n in (19, 35, 7)]

        def run(tp):
            eng = ServeEngine.build({arch!r}, batch_slots=2, s_max={s_max},
                                    page_size={page_size},
                                    cfg_overrides={overrides!r}, tp=tp)
            rs = [eng.submit(p, 8) for p in prompts]
            eng.run()
            assert all(r.error is None for r in rs), [r.error for r in rs]
            return eng, [r.tokens for r in rs]

        _, base = run(None)           # mesh-free engine: today's anchor
        e1, t1 = run(1)
        assert t1 == base, "tp=1 mesh engine is not bit-exact vs plain"
        b1 = e1.per_shard_kv_bytes()
        for tp in {tuple(tps)!r}:
            if tp == 1:
                continue
            e, t = run(tp)
            assert t == base, f"tp={{tp}} diverged from tp=1: {{t}} != {{base}}"
            b = e.per_shard_kv_bytes()
            assert b * tp == b1, (tp, b, b1)
        print("TOKENS", base)
        print("OK")
    """


@pytest.mark.parametrize("family", sorted(_CASES))
def test_tp_greedy_bitwise_equal(multidevice, family):
    """tp=1 == plain engine and tp>1 == tp=1, exact greedy tokens, with
    per-shard pool bytes at exactly global/tp — per family, kernel path."""
    arch, overrides = _CASES[family]
    out = multidevice(_equivalence_code(arch, overrides))
    assert "OK" in out


def test_tp_degenerate_einsum_anchor(multidevice):
    """page_size == s_max forces the masked-einsum read path (the dense
    bit-exactness anchor). Under tp the pool is still kv-head-sharded but
    attention runs via GSPMD, not shard_map — tokens must STILL be exact
    (no contraction dim is sharded, so partitioning cannot reassociate)."""
    arch, overrides = _CASES["dense"]
    out = multidevice(_equivalence_code(arch, overrides, page_size=64,
                                        s_max=64, tps=(1, 2)))
    assert "OK" in out


def test_tp_prefix_cache_and_cow(multidevice):
    """Prefix aliasing + COW against a SHARDED pool: two requests sharing a
    page-aligned header alias its pages, then diverge mid-stream; greedy
    tokens must match the mesh-free engine exactly for both."""
    arch, overrides = _CASES["dense"]
    out = multidevice(f"""
        import numpy as np
        from repro.serve.engine import ServeEngine

        header = np.arange(1, 33, dtype=np.int32)          # 2 full pages
        prompts = [np.concatenate([header, np.full(5, 7, np.int32)]),
                   np.concatenate([header, np.full(9, 11, np.int32)])]

        def run(tp):
            eng = ServeEngine.build({arch!r}, batch_slots=2, s_max=64,
                                    page_size=16, cfg_overrides={overrides!r},
                                    tp=tp, prefix_cache=True)
            out = []
            for p in prompts:                 # sequential: second hits index
                r = eng.submit(p, 8)
                eng.run()
                out.append(r.tokens)
            assert eng.prefix_index is not None and eng.prefix_index.pages
            return out

        base = run(None)
        assert run(2) == base
        print("OK")
    """)
    assert "OK" in out


def test_tp_build_validation():
    """Mesh/tp misconfiguration fails loudly at build, in-process (single
    device, so any tp>1 must be rejected before touching the mesh)."""
    from repro.serve.engine import ServeEngine
    import jax

    ndev = len(jax.devices())
    with pytest.raises(ValueError, match="local devices"):
        ServeEngine.build("qwen2.5-32b", page_size=16, tp=ndev + 1)
    with pytest.raises(ValueError, match="local devices"):
        ServeEngine.build("qwen2.5-32b", page_size=16, tp=0)


def test_tp_requires_paged_and_divisible_heads(multidevice):
    """tp>1 demands a paged cache and (for a kv-head-sharded pool) a
    kv-head count the axis divides; int8 pages are NO LONGER rejected —
    their per-shard scale groups make the quantizing writes mesh-local."""
    out = multidevice("""
        import numpy as np
        from repro.serve.engine import ServeEngine

        def expect(fn, frag):
            try:
                fn()
            except ValueError as e:
                assert frag in str(e), (frag, str(e))
            else:
                raise AssertionError(f"no error containing {frag!r}")

        # dense cache has no mesh layout
        expect(lambda: ServeEngine.build("qwen2.5-32b", tp=2), "PAGED")
        # reduced qwen kv-heads = 1: nothing to shard at tp=2
        expect(lambda: ServeEngine.build("qwen2.5-32b", page_size=16, tp=2),
               "divisible")
        # int8 pages COMPOSE with tp now: the build must succeed, with the
        # scale leaves grown to one group per shard
        eng = ServeEngine.build(
            "qwen2.5-32b", page_size=16, tp=2, kv_backend="paged_int8",
            cfg_overrides=dict(num_heads=8, num_kv_heads=4))
        L, P = eng.cache["k"].shape[:2]
        assert eng.cache["k_scale"].shape == (L, P, 2), \\
            eng.cache["k_scale"].shape
        print("OK")
    """)
    assert "OK" in out


# --------------------------------------------------- int8 pages under tp
def _int8_tp_code(arch: str, overrides, tps=(2, 4)) -> str:
    return f"""
        import numpy as np
        from repro.serve.engine import ServeEngine

        rng = np.random.default_rng(3)
        prompts = [rng.integers(1, 400, n).astype(np.int32)
                   for n in (19, 35, 7)]

        def run(tp):
            eng = ServeEngine.build({arch!r}, batch_slots=2, s_max=64,
                                    page_size=16, kv_backend="paged_int8",
                                    cfg_overrides={overrides!r}, tp=tp)
            rs = [eng.submit(p, 8) for p in prompts]
            eng.run()
            assert all(r.error is None for r in rs), [r.error for r in rs]
            return eng, [r.tokens for r in rs]

        def match_frac(a, b):
            n = 0
            for x, y in zip(a, b):
                if x != y:
                    break
                n += 1
            return n / max(len(a), len(b), 1)

        _, base = run(None)
        e1, t1 = run(1)
        # one scale group == whole-page amax: tp=1 must stay BITWISE
        assert t1 == base, "tp=1 int8 mesh engine is not bit-exact vs plain"
        L, P = e1.cache["k"].shape[:2]
        assert e1.cache["k_scale"].shape == (L, P, 1)
        for tp in {tuple(tps)!r}:
            e, t = run(tp)
            # per-page per-SHARD scale groups ride the cache pytree
            assert e.cache["k_scale"].shape == (L, P, tp), \\
                (tp, e.cache["k_scale"].shape)
            assert e.cache["v_scale"].shape == (L, P, tp)
            # finer amax granularity rounds differently -> not bitwise;
            # the contract is a long shared greedy prefix ON AVERAGE (one
            # early flip cascades for the rest of that stream, so a single
            # request can legitimately sit low while the family matches)
            fr = [match_frac(a, b) for a, b in zip(t, t1)]
            mean = sum(fr) / len(fr)
            assert mean >= 0.6, (tp, fr, t, t1)
        print("OK")
    """


@pytest.mark.parametrize("family", sorted(_CASES))
def test_tp_int8_greedy_prefix_match(multidevice, family):
    """Int8 pages under tp: tp=1 is bitwise vs mesh-free (single scale
    group == the pre-seam whole-page scale), tp=2/4 run without rejection,
    carry (L, P, tp) scale leaves, and hold >= 0.6 mean greedy prefix
    match vs tp=1 — per family."""
    arch, overrides = _CASES[family]
    out = multidevice(_int8_tp_code(arch, overrides))
    assert "OK" in out


# ------------------------------------------------- latent pages under tp
def test_tp_latent_bitwise(multidevice):
    """Tensor-parallel latent serving: the latent pool replicates, the
    ABSORBED query/output head axis shards, and the all-gather before wo
    keeps tp=2/4 greedy streams BITWISE equal to tp=1 (which is itself
    bitwise vs the mesh-free latent engine)."""
    out = multidevice("""
        import numpy as np
        from repro.serve.engine import ServeEngine
        from repro.sharding import specs

        rng = np.random.default_rng(5)
        prompts = [rng.integers(1, 400, n).astype(np.int32)
                   for n in (19, 35, 7)]

        def run(tp):
            eng = ServeEngine.build("qwen2.5-32b-mla", batch_slots=2,
                                    s_max=64, page_size=16,
                                    kv_backend="paged_latent", tp=tp)
            rs = [eng.submit(p, 8) for p in prompts]
            eng.run()
            assert all(r.error is None for r in rs), [r.error for r in rs]
            return eng, [r.tokens for r in rs]

        _, base = run(None)
        e1, t1 = run(1)
        assert t1 == base, "tp=1 latent mesh engine is not bit-exact"
        for tp in (2, 4):
            e, t = run(tp)
            assert t == t1, (tp, t, t1)
            # the latent pool REPLICATES: every shard holds the full pool
            k = e.cache["k"]
            assert k.sharding.shard_shape(k.shape) == k.shape
            # ... and the absorbed head axis is what tp actually shards
            with specs.use_mesh(e.mesh, specs.TP_SERVE_RULES):
                m, ax = specs.latent_head_shard_axis(e.cfg.num_heads)
            assert m is e.mesh and ax is not None
        print("OK")
    """)
    assert "OK" in out
