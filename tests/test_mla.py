"""MLA latent-page KV backend (serve/kvcache.PagedLatentBackend + the
models/kernels layers underneath it).

Claim groups:

* **Absorb-path math.** The absorbed MLA attention (wkv_b folded into the
  query/output einsums, attention run directly over cached latents) stays
  allclose to the naive per-head expansion oracle
  (``kernels.ref.mla_attention_naive``) — same math, reassociated
  contractions.
* **Latent kernel.** The latent-page Pallas kernel (interpret mode on this
  CPU) matches the masked-gather einsum oracle, including partial last
  pages and a freed slot's all--1 block table returning exact zeros.
* **Serving equivalence anchors.** A dense-latent-cache engine streams
  BIT-IDENTICAL greedy tokens to the degenerate single-page latent engine
  (page_size == s_max: same gather, same reduction order), and the
  multi-page kernel-path engine matches the dense stream greedily. The
  latent cache stores ONE (c_kv + r)-dim row per token — no "v" leaf
  anywhere.
* **Prefix sharing on latent pages.** Alias + COW operate on latent rows
  exactly as they do on per-head K/V pages (the generic page machinery is
  representation-agnostic): hits alias pages, an unaligned repeat COWs,
  and the streams match the prefix-off twin bit-for-bit.
* **Backend guards.** ``paged_latent`` on a per-head-K/V arch is rejected
  up front with a pointer at ``kv_backend='paged'``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.kernels import ref as kref
from repro.kernels.paged_attention import paged_attention_latent
from repro.models import layers as L
from repro.models.registry import get_model, reduced_config
from repro.models.transformer import _mla_dims
from repro.serve.config import ServeConfig
from repro.serve.engine import ServeEngine
from repro.serve.kvcache import PagedLatentBackend, make_backend

MLA_ARCH = "qwen2.5-32b-mla"
S_MAX = 32
PS = 8


@pytest.fixture(scope="module")
def mla():
    cfg = reduced_config(configs.get_config(MLA_ARCH))
    model = get_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


# ------------------------------------------------------------ absorb math
def test_absorb_path_matches_naive_expansion():
    """Full prefill attention through the absorbed einsums == materialising
    per-head K/V from the latents and attending conventionally, through the
    shared wo projection."""
    cfg = reduced_config(configs.get_config(MLA_ARCH))
    dims = _mla_dims(cfg)
    key = jax.random.PRNGKey(3)
    params = L.mla_init(key, dims)
    B, S = 2, 12
    x = jax.random.normal(jax.random.PRNGKey(4), (B, S, dims.d_model),
                          jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))

    cache = jnp.zeros((B, S, 1, dims.latent_dim), jnp.float32)
    absorbed, _ = L.mla_attention_prefill_chunk(params, x, dims, cache, 0,
                                                pos)

    # naive expansion: pre-absorption queries + materialised per-head K/V
    H, hd, r = dims.num_heads, dims.head_dim, dims.qk_rope_head_dim
    q = (x @ params["wq"]).reshape(B, S, H, hd + r)
    q_nope, q_pe = q[..., :hd], q[..., hd:]
    q_pe = L.apply_rope(q_pe, pos, dims.rope_theta)
    wb_k, wb_v = L._mla_wkv_b(params, dims, x.dtype)
    latent = L.mla_latent_rows(params, x, dims, pos)[:, :, 0, :]
    attn = kref.mla_attention_naive(q_nope, q_pe, latent, wb_k, wb_v,
                                    pos, pos)
    naive = attn.reshape(B, S, H * hd) @ params["wo"]
    np.testing.assert_allclose(np.asarray(absorbed), np.asarray(naive),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------- latent kernel
def test_latent_kernel_matches_einsum_oracle():
    """Interpret-mode latent-page kernel vs the masked-gather oracle across
    slots at different depths (partial last pages included)."""
    rng = np.random.default_rng(0)
    B, H, c, r, ps, mps = 3, 4, 8, 2, 8, 4
    L_dim, d_v = c + r, c
    P = B * mps
    pool = jnp.asarray(rng.standard_normal((P, ps, 1, L_dim)), jnp.float32)
    bt = np.full((B, mps), -1, np.int32)
    start = np.asarray([13, 7, 26], np.int32)   # mid-page frontiers
    nxt = 0
    for b in range(B):
        for j in range(-(-int(start[b] + 1) // ps)):
            bt[b, j] = nxt
            nxt += 1
    bt, start = jnp.asarray(bt), jnp.asarray(start)
    for sq in (1, 4):
        q = jnp.asarray(rng.standard_normal((B, sq, H, L_dim)), jnp.float32)
        want = kref.paged_attention_latent(q, pool, bt, start,
                                           scale_dim=L_dim + 6, d_v=d_v)
        got = paged_attention_latent(q, pool, bt, start,
                                     scale_dim=L_dim + 6, d_v=d_v,
                                     interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


def test_latent_kernel_freed_slot_exact_zero():
    rng = np.random.default_rng(1)
    B, H, ps, mps, L_dim = 2, 2, 8, 2, 10
    pool = jnp.asarray(rng.standard_normal((4, ps, 1, L_dim)), jnp.float32)
    bt = jnp.asarray([[0, 1], [-1, -1]], jnp.int32)   # slot 1 freed
    start = jnp.asarray([9, 0], jnp.int32)
    q = jnp.asarray(rng.standard_normal((B, 1, H, L_dim)), jnp.float32)
    out = paged_attention_latent(q, pool, bt, start, scale_dim=16, d_v=8,
                                 interpret=True)
    assert (np.asarray(out)[1] == 0).all()
    assert np.abs(np.asarray(out)[0]).max() > 0


# ---------------------------------------------------- serving equivalence
def _serve(model, params, **kw):
    eng = ServeEngine(model, params, batch_slots=2, s_max=S_MAX, **kw)
    rng = np.random.default_rng(11)
    gens = [6, 4, 8, 5]
    reqs = [eng.submit(rng.integers(0, model.cfg.vocab_size, 8), g)
            for g in gens]
    eng.run()
    return eng, [r.tokens for r in reqs]


def test_dense_vs_degenerate_page_bitexact(mla):
    """page_size == s_max: one page per slot, same gather and reduction
    order as the dense latent cache — greedy streams must be IDENTICAL."""
    model, params = mla
    dense_eng, dense = _serve(model, params)
    eng, paged = _serve(model, params, page_size=S_MAX,
                        kv_backend="paged_latent")
    assert isinstance(eng.backend, PagedLatentBackend)
    assert dense == paged
    # latent representation: one shared row per token, no per-head V pool
    for cache in (dense_eng.cache, eng.cache):
        assert "v" not in cache
        assert cache["k"].shape[-2:] == (1, _mla_dims(model.cfg).latent_dim)


def test_multi_page_kernel_greedy_equal(mla):
    """Multi-page block tables through the latent kernel path (incremental
    splice on): greedy streams match the dense reference."""
    model, params = mla
    _, dense = _serve(model, params)
    eng, paged = _serve(model, params, page_size=PS,
                        kv_backend="paged_latent")
    assert type(eng.backend) is PagedLatentBackend
    assert dense == paged


def test_implicit_paged_matches_explicit_latent(mla):
    """On an MLA arch the implicit layout-follows-page_size backend pages
    the SAME latent rows: explicit paged_latent changes zero tokens."""
    model, params = mla
    _, implicit = _serve(model, params, page_size=PS)
    _, explicit = _serve(model, params, page_size=PS,
                         kv_backend="paged_latent")
    assert implicit == explicit


# ------------------------------------------------------- prefix alias/COW
def test_prefix_alias_and_cow_on_latent_pages(mla):
    """Sequential requests sharing an unaligned header: the second aliases
    full prefix pages and COWs the partial one — latent rows are copied as
    whole page rows (never expanded to per-head K/V) and the streams match
    the prefix-off twin bit-for-bit."""
    model, params = mla
    rng = np.random.default_rng(7)
    head = rng.integers(0, model.cfg.vocab_size, 12).astype(np.int32)
    tails = [rng.integers(0, model.cfg.vocab_size, 6).astype(np.int32)
             for _ in range(2)]
    workload = [(head, 5)] + [(np.concatenate([head, t]), 5) for t in tails]

    def serve(prefix_cache):
        eng = ServeEngine(model, params, batch_slots=2, s_max=S_MAX,
                          page_size=PS, kv_backend="paged_latent",
                          prefix_cache=prefix_cache)
        toks = []
        for prompt, gen in workload:
            r = eng.submit(prompt, gen)
            eng.run()
            toks.append(r.tokens)
            eng.assert_page_invariants()
        return eng, toks

    eng_on, toks_on = serve(True)
    _, toks_off = serve(False)
    assert toks_on == toks_off
    prefix = eng_on.metrics.summary()["prefix"]
    assert prefix["hit_rate"] > 0
    assert prefix["cow_copies"] >= 1


# ------------------------------------------------------------------ guards
def test_latent_backend_rejects_per_head_kv_arch():
    with pytest.raises(ValueError, match="kv_lora_rank"):
        ServeEngine.build("qwen2.5-32b", config=ServeConfig(
            batch_slots=2, s_max=S_MAX, page_size=PS,
            kv_backend="paged_latent"))


def test_make_backend_resolves_latent():
    fam = configs.get_config(MLA_ARCH).family
    be = make_backend("paged_latent", family=fam, page_size=PS, num_pages=4)
    assert type(be) is PagedLatentBackend and be.paged
    with pytest.raises(ValueError, match="page_size"):
        make_backend("paged_latent", family=fam)
