"""Perf-model guardrails (paper Fig. 6): the optimization ladder must be
monotone, and the calibrated model must reproduce each measured FPS point
within 10% relative error — tighter than test_substrate's 15% sanity bound,
so regressions in the planner/traffic model surface here first.
"""
import pytest

from repro.configs.resnet20_cifar import CONFIG as RCFG
from repro.core import perfmodel as pm
from repro.core.dataflow import Gemm
from repro.models.resnet import conv_layer_shapes


@pytest.fixture(scope="module")
def resnet_gemms():
    return [Gemm(n, m, k, nn, in_elems=m * k // 9 if k % 9 == 0 else m * k,
                 out_elems=m * nn)
            for (n, m, k, nn) in conv_layer_shapes(RCFG, batch=1)]


@pytest.fixture(scope="module")
def calibrated(resnet_gemms):
    return pm.calibrate(resnet_gemms)


def test_ladder_fps_monotone_increasing(resnet_gemms):
    """Each rung of the paper's ladder must not be slower than the previous,
    and the full ladder must show a real end-to-end win (the paper's is
    2.2x; rungs 2-3 may tie when every layer already fits local memory)."""
    fps = [r.fps for r in pm.ladder(resnet_gemms)]
    assert len(fps) == len(pm.LADDER_ORDER)
    for lo, hi in zip(fps, fps[1:]):
        assert hi >= lo - 1e-9, fps
    assert fps[-1] > fps[0], fps


def test_calibrate_reproduces_paper_within_10pct(resnet_gemms, calibrated):
    for r in pm.ladder(resnet_gemms, fit=calibrated):
        tgt = pm.PAPER_FPS[r.strategy]
        assert abs(r.fps - tgt) / tgt < 0.10, (r.strategy, r.fps, tgt)


def test_calibrated_ladder_monotone(resnet_gemms, calibrated):
    fps = [r.fps for r in pm.ladder(resnet_gemms, fit=calibrated)]
    for lo, hi in zip(fps, fps[1:]):
        assert hi >= lo - 1e-9, fps


def test_calibrated_end_to_end_speedup_matches_paper(resnet_gemms, calibrated):
    """The headline ratio (compiler_large_local / baseline = 2.2x) must
    survive calibration within 20%."""
    rungs = {r.strategy: r.fps
             for r in pm.ladder(resnet_gemms, fit=calibrated)}
    ours = rungs["compiler_large_local"] / rungs["baseline"]
    paper = pm.PAPER_FPS["compiler_large_local"] / pm.PAPER_FPS["baseline"]
    assert abs(ours - paper) / paper < 0.20, (ours, paper)


def test_physical_fit_constraints(calibrated):
    """Calibration must land in the physically plausible regime the search
    constrains to (dual-clock path 1-3.4x the single-clock path)."""
    assert 0 < calibrated.efficiency <= 1.0
    assert calibrated.bw_slow <= calibrated.bw_fast <= 3.4 * calibrated.bw_slow
    assert calibrated.block_overhead >= 0
