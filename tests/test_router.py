"""Prefix-affinity replica router (serve/router.py).

Host-only logic over real reduced engines (single device — the router
never touches the mesh): affinity keying, rendezvous stability, least-
loaded fallback, spill/shed back-pressure, and end-to-end integrity of a
routed stream (every submission either completes on exactly one replica or
is counted shed at the door).
"""
import numpy as np
import pytest

from repro.serve.engine import ServeEngine
from repro.serve.router import ReplicaRouter

PS = 16


def _engine(**kw):
    kw.setdefault("batch_slots", 2)
    kw.setdefault("s_max", 64)
    kw.setdefault("page_size", PS)
    return ServeEngine.build("qwen2.5-32b", **kw)


def _tier(n=2, **kw):
    return [_engine(**kw) for _ in range(n)]


def _prompt(header_token: int, tail_len: int, tail_token: int = 7):
    header = np.full(2 * PS, header_token, np.int32)    # 2 full pages
    return np.concatenate([header, np.full(tail_len, tail_token, np.int32)])


class TestKeying:
    def test_equal_headers_one_replica(self):
        router = ReplicaRouter(_tier(4))
        picks = {router.pick(_prompt(3, tail)) for tail in (1, 5, 9, 13)}
        assert len(picks) == 1, "same page-aligned header must colocate"

    def test_tail_inside_header_page_changes_key(self):
        router = ReplicaRouter(_tier(4), header_pages=4)
        a = router.header_key(_prompt(3, 1))
        b = np.concatenate([np.full(2 * PS, 3, np.int32),
                            np.full(PS, 9, np.int32)])   # 3rd FULL page differs
        assert router.header_key(b) != a

    def test_headerless_goes_least_loaded(self):
        engines = _tier(2)
        router = ReplicaRouter(engines)
        short = np.arange(1, PS, dtype=np.int32)         # < one page
        assert router.header_key(short) is None
        # load replica 0 so least-loaded must answer 1
        engines[0].submit(_prompt(5, 3), 4)
        assert router.pick(short) == 1
        router.submit(short, 1)
        assert router.headerless == 1

    def test_affinity_needs_uniform_paged_tier(self):
        mixed = [_engine(), _engine(page_size=32)]
        with pytest.raises(ValueError, match="page_size"):
            ReplicaRouter(mixed)
        ReplicaRouter(mixed, affinity=False)             # least-loaded is fine


class TestBackpressure:
    def test_spill_to_least_loaded(self):
        engines = _tier(2)
        router = ReplicaRouter(engines, queue_limit=2)
        p = _prompt(3, 5)
        want = router.pick(p)
        # saturate the affinity target's queue without ticking
        for _ in range(2):
            engines[want].submit(_prompt(3, 5), 4)
        res = router.submit(p, 4)
        assert res is not None
        _, target = res
        assert target != want
        assert router.spills == 1

    def test_shed_when_tier_saturated(self):
        engines = _tier(2)
        router = ReplicaRouter(engines, queue_limit=1)
        for e in engines:
            e.submit(_prompt(3, 5), 4)
        assert router.submit(_prompt(3, 5), 4) is None
        assert sum(router.sheds) == 1

    def test_no_limit_never_sheds(self):
        router = ReplicaRouter(_tier(2))
        for i in range(8):
            assert router.submit(_prompt(i, 3), 2) is not None
        assert sum(router.sheds) == 0 and router.spills == 0


class TestEndToEnd:
    def test_routed_stream_completes_everywhere(self):
        router = ReplicaRouter(_tier(2))
        reqs = [router.submit(_prompt(i % 3, 3 + i), 4)[0] for i in range(6)]
        router.drain()
        assert all(r.done and r.error is None for r in reqs)
        assert sum(router.routed) == 6
        # three header groups over rendezvous: tokens generated on whichever
        # replica must match a single-engine run of the same prompt
        solo = _engine()
        r = solo.submit(_prompt(0, 3), 4)
        solo.run()
        match = [q for q in reqs if len(q.prompt) == 2 * PS + 3
                 and q.prompt[0] == 0]
        assert match and match[0].tokens == r.tokens

    def test_round_robin_mode_spreads(self):
        router = ReplicaRouter(_tier(2), affinity=False)
        for i in range(6):
            router.submit(_prompt(3, 5), 2)   # identical headers on purpose
        assert router.routed == [3, 3]

    def test_affinity_partitions_headers(self):
        router = ReplicaRouter(_tier(2))
        for g in range(6):
            for _ in range(3):
                router.submit(_prompt(g, 4), 2)
        # every header group lands wholly on one replica
        assert router.affine == 18
        per_group = {}
        for g in range(6):
            per_group[g] = router.pick(_prompt(g, 9))
        router.drain()
        counts = router.routed
        assert sum(counts) == 18
        expected = [3 * sum(1 for g, i in per_group.items() if i == 0),
                    3 * sum(1 for g, i in per_group.items() if i == 1)]
        assert counts == expected

    def test_replay_accounts_for_everything(self):
        from repro.serve.workload import ArrivalEvent
        rng = np.random.default_rng(1)
        events = [ArrivalEvent(t=i * 1e-4,
                               prompt=_prompt(int(rng.integers(0, 3)), 3),
                               gen_len=2, priority=0)
                  for i in range(8)]
        router = ReplicaRouter(_tier(2))
        out = router.replay(events)
        assert out["shed_at_router"] == 0
        assert sum(out["router"]["routed"]) == 8
        done = sum(s["completed"] for s in out["replicas"])
        assert done == 8
