"""Data pipeline, checkpoint manager, perf model, fault-tolerance runtime."""
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import MemoryStrategy
from repro.core import perfmodel as pm
from repro.core.dataflow import Gemm
from repro.data import cifar
from repro.data.synthetic import TokenStream, synthetic_cifar
from repro.models.resnet import conv_layer_shapes
from repro.configs.resnet20_cifar import CONFIG as RCFG
from repro.runtime.fault import RestartPolicy, StragglerDetector, run_with_recovery


# ------------------------------------------------------------------ data
def test_token_stream_deterministic_restart():
    s1 = TokenStream(1000, 4, 32, seed=3)
    s2 = TokenStream(1000, 4, 32, seed=3)
    b1 = s1.batch_at(17)
    b2 = s2.batch_at(17)   # fresh object, same step -> identical batch
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(s1.batch_at(18)["tokens"], b1["tokens"])


def test_token_stream_has_structure():
    """labels are next-tokens of a sparse Markov chain, not iid noise."""
    s = TokenStream(100, 2, 64, seed=0, branching=4)
    b = s.batch_at(0)
    assert b["tokens"].shape == (2, 64)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    follows = set()
    for t in range(63):
        follows.add((b["tokens"][0, t], b["tokens"][0, t + 1]))
    # each token has only 4 successors => pairs repeat far below 63 unique
    assert len(follows) <= 63


def test_cifar_binary_roundtrip(tmp_path):
    xs, ys = synthetic_cifar(64, seed=0)
    xs = np.clip(xs * 0.2 + 0.5, 0, 1)
    path = tmp_path / "test_batch.bin"
    cifar.write_binary(path, xs, ys)
    xs2, ys2 = cifar.read_binary(path)
    np.testing.assert_array_equal(ys, ys2)
    assert np.abs(xs - xs2).max() < 1 / 255.0 + 1e-6
    batches = list(cifar.batches(xs2, ys2, 16, train=False))
    assert len(batches) == 4 and batches[0][0].shape == (16, 32, 32, 3)


# ------------------------------------------------------------------ ckpt
def _tree(step):
    return {"params": {"w": jnp.full((4, 4), float(step)),
                       "b": jnp.arange(3.0)},
            "opt": {"m": (jnp.zeros(2), jnp.ones(2)), "count": jnp.int32(step)},
            "step": jnp.int32(step)}


def test_checkpoint_roundtrip_and_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    for s in (1, 2, 3):
        mgr.save(s, _tree(s))
    assert mgr.all_steps() == [2, 3]          # keep=2 retention
    tree, meta = mgr.restore()
    assert meta["step"] == 3
    assert float(tree["params"]["w"][0, 0]) == 3.0
    assert isinstance(tree["opt"]["m"], tuple)  # tuple structure preserved
    tree2, meta2 = mgr.restore(step=2)
    assert meta2["step"] == 2


def test_checkpoint_async_and_atomic(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3, async_save=True)
    mgr.save(10, _tree(10))
    mgr.wait()
    assert mgr.latest_step() == 10
    # no stray tmp dirs after commit
    assert not list(pathlib.Path(tmp_path).glob(".tmp*"))


def test_checkpoint_kill_resume_bitwise(tmp_path):
    """Simulated failure: the run crashes mid-flight, restarts from the last
    checkpoint, and the recovered state stream is bitwise identical."""
    mgr = CheckpointManager(tmp_path, async_save=False)
    state = {"x": jnp.zeros(()), "step": jnp.int32(0)}

    def reference_run(n):
        s = {"x": jnp.zeros(()), "step": jnp.int32(0)}
        for i in range(n):
            s = {"x": s["x"] * 1.5 + i, "step": s["step"] + 1}
        return s

    holder = {"state": state, "crashed": False}

    def step_fn(i):
        if i == 7 and not holder["crashed"]:
            holder["crashed"] = True
            raise RuntimeError("simulated chip failure")
        s = holder["state"]
        holder["state"] = {"x": s["x"] * 1.5 + i, "step": s["step"] + 1}

    def save_fn(i):
        mgr.save(i, holder["state"])

    def restore_fn():
        tree, meta = mgr.restore()
        if tree is None:
            holder["state"] = {"x": jnp.zeros(()), "step": jnp.int32(0)}
            return 0
        holder["state"] = jax.tree.map(jnp.asarray, tree)
        return meta["step"]

    stats = run_with_recovery(num_steps=12, step_fn=step_fn, save_fn=save_fn,
                              restore_fn=restore_fn, checkpoint_every=5,
                              sleep=lambda s: None)
    assert stats["failures"] == 1
    ref = reference_run(12)
    assert float(holder["state"]["x"]) == float(ref["x"])
    assert int(holder["state"]["step"]) == 12


# ------------------------------------------------------------------ fault
def test_straggler_detector():
    det = StragglerDetector(window=30, z_threshold=4.0, min_steps=10)
    rng = np.random.default_rng(0)
    for _ in range(20):
        det.record(0.100 + rng.normal(0, 0.002))
    assert det.record(0.500) is True          # 5x median => flagged
    assert det.record(0.101) is False


def test_restart_policy_budget():
    pol = RestartPolicy(max_restarts=2, backoff_s=0.1)
    assert pol.on_failure(ValueError()) == pytest.approx(0.1)
    assert pol.on_failure(ValueError()) == pytest.approx(0.2)
    with pytest.raises(RuntimeError):
        pol.on_failure(ValueError())


# ------------------------------------------------------------------ perf
@pytest.fixture(scope="module")
def resnet_gemms():
    return [Gemm(n, m, k, nn, in_elems=m * k // 9 if k % 9 == 0 else m * k,
                 out_elems=m * nn)
            for (n, m, k, nn) in conv_layer_shapes(RCFG, batch=1)]


def test_ladder_monotone(resnet_gemms):
    """Each paper optimization rung must not be slower than the previous."""
    fps = [r.fps for r in pm.ladder(resnet_gemms)]
    assert fps[0] <= fps[1] <= fps[2] <= fps[3] + 1e-9


def test_calibrated_ladder_matches_paper(resnet_gemms):
    fit = pm.calibrate(resnet_gemms)
    for r in pm.ladder(resnet_gemms, fit=fit):
        tgt = pm.PAPER_FPS[r.strategy]
        assert abs(r.fps - tgt) / tgt < 0.15, (r.strategy, r.fps, tgt)


def test_final_rung_traffic_amortized(resnet_gemms):
    """§4.4 mechanism: whole-model residency eliminates steady-state traffic."""
    evals = {r.strategy: r for r in pm.ladder(resnet_gemms)}
    assert evals["compiler_large_local"].traffic < \
        0.1 * evals["baseline"].traffic
