import os
import sys
import pathlib
import subprocess
import textwrap

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))

# NOTE: no XLA_FLAGS here — tests run single-device; multi-device tests spawn
# subprocesses with their own device-count flag (see run_multidevice).

# hypothesis is optional: property tests skip themselves via importorskip,
# and the whole suite must still COLLECT when it is absent (the seed died at
# collection here). Register the "ci" profile only when it is available.
try:
    from hypothesis import settings
except ImportError:
    settings = None
else:
    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")


def run_multidevice(code: str, n_devices: int = 8, timeout: int = 420) -> str:
    """Run `code` in a subprocess with n host devices; returns stdout.
    Raises on nonzero exit (stderr shown in the assertion)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert proc.returncode == 0, f"subprocess failed:\n{proc.stderr[-4000:]}"
    return proc.stdout


@pytest.fixture
def multidevice():
    return run_multidevice
