"""Serving engine: prefill isolation (the seed's cross-slot corruption bug),
continuous batching, greedy determinism vs a straight-line prefill+decode
loop, scheduler/metrics units.

The isolation tests exploit a property established for the engine design:
batched decode is row-independent at a FIXED batch shape, so a slot's token
stream must be bit-identical no matter what other slots contain. The legacy
token-by-token prefill violates this by stepping the whole batch once per
prompt token; the engine's batch-axis cache splice does not.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.launch import steps as steps_mod
from repro.launch.serve import LegacyServer, ServeConfig, Server
from repro.models.registry import (extract_cache_slot, get_model,
                                   insert_cache_slot, reduced_config,
                                   vectorize_cache_pos)
from repro.serve.engine import ServeEngine
from repro.serve.metrics import MetricsRecorder
from repro.serve.scheduler import Request, Scheduler

ARCH = "hymba-1.5b"
S_MAX = 48


@pytest.fixture(scope="module")
def mp():
    cfg = reduced_config(configs.get_config(ARCH))
    model = get_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def make_engine(model, params, **kw):
    kw.setdefault("batch_slots", 2)
    kw.setdefault("s_max", S_MAX)
    return ServeEngine(model, params, **kw)


def promptA():
    return np.arange(1, 9, dtype=np.int32)          # len 8


def promptB():
    return np.arange(40, 52, dtype=np.int32)        # len 12


# ------------------------------------------------------------ (a) isolation
def test_prefill_isolation(mp):
    """Slot A's tokens are identical whether or not slot B is prefilled
    mid-generation (bit-exact, same batch shape both runs)."""
    model, params = mp
    gen = 10

    e1 = make_engine(model, params)
    r_alone = e1.submit(promptA(), gen)
    while not r_alone.done:
        e1.step()

    e2 = make_engine(model, params)
    r_conc = e2.submit(promptA(), gen)
    e2.step()
    e2.step()
    e2.submit(promptB(), 4)       # admitted + prefilled while A is decoding
    while not r_conc.done:
        e2.step()

    assert r_alone.tokens == r_conc.tokens
    assert len(r_conc.tokens) == gen
    # stronger than token equality: slot A's cache entries themselves are
    # bit-identical — B's prefill/decodes never touched them
    c1 = extract_cache_slot(e1.cache, 0)
    c2 = extract_cache_slot(e2.cache, 0)
    for key in c1:
        np.testing.assert_array_equal(np.asarray(c1[key]),
                                      np.asarray(c2[key]), err_msg=key)


def test_legacy_prefill_corrupts_other_slots(mp):
    """The seed bug, demonstrated: LegacyServer's token-by-token prefill of B
    advances slot A's cache, changing A's tokens. This is exactly the
    scenario test_prefill_isolation proves clean for the engine — run
    against the old path, isolation FAILS."""
    del mp
    sc = ServeConfig(arch=ARCH, reduced=True, batch_slots=2, s_max=S_MAX,
                     prompt_len=8, gen_len=10)

    l1 = LegacyServer(sc)
    slot = l1.add_request(promptA(), sc.gen_len)
    for _ in range(sc.gen_len):
        l1.step_all()
    alone = list(l1.outputs[slot])

    l2 = LegacyServer(sc)
    slot = l2.add_request(promptA(), sc.gen_len)
    l2.step_all()
    l2.step_all()
    l2.add_request(promptB(), 4)          # corrupts slot A's cache
    for _ in range(sc.gen_len):
        l2.step_all()
    concurrent = list(l2.outputs[slot])[: sc.gen_len]

    assert alone[:2] == concurrent[:2]    # identical until B arrives
    assert alone != concurrent            # ...then A's stream is corrupted


def test_server_shim_fixed_regression(mp):
    """Satellite fix: Server.add_request (now engine-backed) must not advance
    other active slots' caches — same scenario as above, now clean."""
    del mp
    sc = ServeConfig(arch=ARCH, reduced=True, batch_slots=2, s_max=S_MAX,
                     prompt_len=8, gen_len=10)

    s1 = Server(sc)
    slot = s1.add_request(promptA(), sc.gen_len)
    for _ in range(sc.gen_len):
        s1.step_all()
    alone = list(s1.outputs[slot])

    s2 = Server(sc)
    slot = s2.add_request(promptA(), sc.gen_len)
    s2.step_all()
    s2.step_all()
    s2.add_request(promptB(), 4)
    for _ in range(sc.gen_len):
        s2.step_all()
    concurrent = list(s2.outputs[slot])[: sc.gen_len]

    assert alone == concurrent
    assert len(alone) == sc.gen_len


# ------------------------------------------------------ (b) continuous batch
def test_continuous_batching_completes_all(mp):
    """requests > batch_slots all complete with exactly gen_len tokens."""
    model, params = mp
    engine = make_engine(model, params, batch_slots=2)
    rng = np.random.default_rng(7)
    gens = [6, 3, 9, 5, 4]
    reqs = [engine.submit(rng.integers(0, model.cfg.vocab_size, 8), g)
            for g in gens]
    summary = engine.run()
    for req, g in zip(reqs, gens):
        assert req.done and len(req.tokens) == g
        assert all(0 <= t < model.cfg.vocab_size for t in req.tokens)
    assert summary["completed"] == len(gens)
    assert summary["prefills"] == len(gens)
    # continuous batching refills freed slots: fewer ticks than serial decode
    assert summary["decode_steps"] < sum(gens)


def test_priority_admission_order(mp):
    """With one slot, a priority-0 request admitted after a priority-1 one
    still starts first once submitted before admission."""
    model, params = mp
    engine = make_engine(model, params, batch_slots=1)
    lo = engine.submit(promptA(), 4, priority=1)
    hi = engine.submit(promptB(), 4, priority=0)
    engine.step()                  # admits exactly one request: the hi-prio
    assert hi.slot == 0 and len(hi.tokens) >= 1
    assert lo.slot is None and not lo.tokens     # still queued behind hi
    engine.run()
    assert hi.done and lo.done


# ------------------------------------------------------- (c) determinism
def test_greedy_matches_straightline_prefill_decode(mp):
    """Engine greedy output == straight-line make_prefill + decode loop (no
    scheduler, no metrics), bit-for-bit."""
    model, params = mp
    gen = 8
    engine = make_engine(model, params, batch_slots=2)
    req = engine.submit(promptA(), gen)
    engine.run()

    prefill = jax.jit(steps_mod.make_prefill(
        model, compute_dtype=jnp.float32, return_cache=True, s_max=S_MAX))
    decode = jax.jit(steps_mod.make_decode_step(model, compute_dtype=jnp.float32))
    logits, rcache = prefill(params, {"tokens": jnp.asarray(promptA()[None])})
    cache = vectorize_cache_pos(model.init_cache(2, S_MAX, jnp.float32), 2)
    cache = insert_cache_slot(cache, rcache, 0)
    toks = [int(jnp.argmax(logits[0, 0, : model.cfg.vocab_size]))]
    cur = np.zeros((2, 1), np.int32)
    for _ in range(gen - 1):
        cur[0, 0] = toks[-1]
        logits, cache = decode(params, cache, {"token": jnp.asarray(cur)})
        toks.append(int(jnp.argmax(logits[0, 0, : model.cfg.vocab_size])))

    assert req.tokens == toks


def test_temperature_sampling_reproducible(mp):
    """temperature > 0 samples in-vocab tokens, reproducibly per seed."""
    model, params = mp
    outs = []
    for _ in range(2):
        engine = make_engine(model, params, temperature=0.8, seed=3)
        req = engine.submit(promptA(), 6)
        engine.run()
        assert all(0 <= t < model.cfg.vocab_size for t in req.tokens)
        outs.append(req.tokens)
    assert outs[0] == outs[1]


# ------------------------------------------------------------ units
def test_scheduler_priority_then_fifo():
    s = Scheduler()
    r1 = Request(rid=1, prompt=np.zeros(2, np.int32), gen_len=1, priority=1)
    r2 = Request(rid=2, prompt=np.zeros(2, np.int32), gen_len=1, priority=0)
    r3 = Request(rid=3, prompt=np.zeros(2, np.int32), gen_len=1, priority=0)
    for r in (r1, r2, r3):
        s.submit(r)
    assert [s.next_request().rid for _ in range(3)] == [2, 3, 1]
    assert s.next_request() is None


def test_metrics_summary_math():
    t = {"now": 0.0}
    m = MetricsRecorder(clock=lambda: t["now"])
    m.on_start()
    m.on_submit(0, prompt_len=4)
    t["now"] = 0.5
    m.on_prefill(0, 4)
    m.on_first_token(0)
    for dt in (1.0, 1.5, 2.0):
        t["now"] = dt
        m.on_token(0)
        m.on_decode_step()
    m.on_done(0)
    m.on_stop()
    s = m.summary()
    assert s["completed"] == 1 and s["total_tokens"] == 4
    assert s["ttft_s"]["p50"] == pytest.approx(0.5)
    assert s["latency_s"]["p95"] == pytest.approx(2.0)
    assert s["throughput_tokens_per_s"] == pytest.approx(4 / 2.0)
    assert s["request_tokens_per_s"]["p50"] == pytest.approx(4 / 2.0)


def test_submit_rejects_requests_that_cannot_fit(mp):
    """Validation happens at submit, not admission: a bad request raises
    immediately and can never strand other queued requests."""
    model, params = mp
    engine = make_engine(model, params)
    ok = engine.submit(promptA(), 4)
    # exact bound: last cache write is at prompt_len+gen_len-2, so
    # prompt_len == s_max with gen_len 1 still fits...
    fits = engine.submit(np.zeros(S_MAX, np.int32), 1)
    # ...but one more generated token would write past the cache end
    with pytest.raises(ValueError, match="s_max"):
        engine.submit(np.zeros(S_MAX, np.int32), 2)
    engine.run()
    assert ok.done and len(ok.tokens) == 4            # queue undamaged
    assert fits.done and len(fits.tokens) == 1


def test_freed_slot_cache_rows_bit_stable(mp):
    """The stale freed-slot bugfix: after _finish parks a slot's pos at the
    INACTIVE_POS sentinel, the slot's cache rows (ring K/V, slot_pos, AND the
    hybrid SSM h/conv state) are bit-identical N ticks later while another
    slot keeps decoding — freed slots no longer advance positions or scatter
    stale K/V (the corruption was previously masked only by the re-admission
    overwrite)."""
    model, params = mp
    engine = make_engine(model, params)
    short = engine.submit(promptA(), 3)
    long = engine.submit(promptB(), 24)
    while not short.done:
        engine.step()
    freed = short.slot
    assert engine.slot_req[freed] is None and not long.done
    snap = {k: np.asarray(v)
            for k, v in extract_cache_slot(engine.cache, freed).items()
            if k != "pos"}
    for _ in range(8):                       # long keeps decoding
        engine.step()
    after = extract_cache_slot(engine.cache, freed)
    for key, before in snap.items():
        np.testing.assert_array_equal(before, np.asarray(after[key]),
                                      err_msg=key)
    # the freed slot's feedback token was zeroed (no stale token re-fed)
    assert engine.cur_token[freed, 0] == 0


def test_submit_rejects_empty_prompt_and_negative_gen(mp):
    """Admission edge cases: a zero-length prompt would reach a zero-length
    prefill scan (undefined logits) and a negative gen_len would underflow
    the remaining-token accounting — both fail loudly at submit."""
    model, params = mp
    engine = make_engine(model, params)
    with pytest.raises(ValueError, match="empty prompt"):
        engine.submit(np.array([], np.int32), 4)
    with pytest.raises(ValueError, match="gen_len"):
        engine.submit(promptA(), -1)
    with pytest.raises(ValueError, match="1-D"):
        engine.submit(promptA()[None], 4)     # accidentally batched prompt
    zero = engine.submit(promptB(), 0)        # gen_len 0 IS valid: prefill
    ok = engine.submit(promptA(), 2)          # only, zero tokens returned
    engine.run()
    assert ok.done and len(ok.tokens) == 2
    assert zero.done and zero.tokens == []


def test_metrics_wall_clamp_and_idempotent_on_done():
    """summary() must not report a near-infinite rate for a positive but
    sub-microsecond wall (injectable test clocks), must stay NaN for a zero
    wall, and a duplicate on_done must not move t_done."""
    t = {"now": 0.0}
    m = MetricsRecorder(clock=lambda: t["now"])
    m.on_start()
    m.on_submit(0, prompt_len=2)
    m.on_first_token(0)
    t["now"] = 1.0
    m.on_done(0)
    t["now"] = 5.0
    m.on_done(0)                              # double _finish: no-op
    m.on_stop()
    s = m.summary()
    assert s["latency_s"]["p50"] == pytest.approx(1.0)   # not 5.0
    # zero wall: NaN, not inf and not a huge number
    m2 = MetricsRecorder(clock=lambda: 0.0)
    m2.on_start()
    m2.on_submit(0, prompt_len=2)
    m2.on_first_token(0)
    m2.on_stop()
    assert np.isnan(m2.summary()["throughput_tokens_per_s"])
    # sub-microsecond wall: clamped to MIN_WALL_S, not 1e9x-inflated
    t3 = {"now": 0.0}
    m3 = MetricsRecorder(clock=lambda: t3["now"])
    m3.on_start()
    m3.on_submit(0, prompt_len=2)
    m3.on_first_token(0)
    t3["now"] = 1e-9
    m3.on_stop()
    from repro.serve.metrics import MIN_WALL_S
    assert m3.summary()["throughput_tokens_per_s"] == pytest.approx(
        1 / MIN_WALL_S)


# ------------------------------------------- release_job / cancellation
@pytest.fixture(scope="module")
def qwen_mp():
    cfg = reduced_config(configs.get_config("qwen2.5-32b"))
    model = get_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def test_prefill_failure_releases_pages_and_engine_serves_on(qwen_mp,
                                                             monkeypatch):
    """The mid-prefill failure satellite: a chunk dispatch that raises must
    release the job's slots, reserved pages, and aliased prefix refcounts
    (before release_job existed they were held until process exit), mark
    its requests FAILED, and leave the engine fully serviceable."""
    from repro.serve.scheduler import RequestState
    model, params = qwen_mp
    engine = ServeEngine(model, params, batch_slots=2, s_max=32, page_size=8,
                         prefill_chunk_tokens=4, prefix_cache=False)
    assert engine.incremental_splice
    real = engine._chunk_paged_fn
    calls = {"n": 0}

    def flaky():
        fn = real()

        def wrapped(params, cache, batch):
            calls["n"] += 1
            if calls["n"] == 2:                  # fail MID-prefill
                raise RuntimeError("injected chunk failure")
            return fn(params, cache, batch)
        return wrapped

    monkeypatch.setattr(engine, "_chunk_paged_fn", flaky)
    doomed = engine.submit(np.arange(1, 14, dtype=np.int32), 4)
    engine.step()                                # chunk 1 ok
    assert doomed.state is RequestState.PREFILLING
    engine.step()                                # chunk 2 raises -> released
    assert doomed.state is RequestState.FAILED
    assert "injected chunk failure" in doomed.error
    assert engine.prefill_failures == 1
    assert engine.free_pages == engine.num_pages
    assert engine.slot_req == [None, None] and not engine._jobs
    engine.assert_page_invariants()
    monkeypatch.setattr(engine, "_chunk_paged_fn", real)
    ok = engine.submit(promptA(), 4)             # engine still serves
    engine.run()
    assert ok.done and len(ok.tokens) == 4
    assert engine.free_pages == engine.num_pages
    engine.assert_page_invariants()


def test_prefill_failure_transient_path_also_releases(mp, monkeypatch):
    """Same contract on the transient (non-incremental) chunk path — the
    hybrid family here — including the batch-K grouped case."""
    from repro.serve.scheduler import RequestState
    model, params = mp
    engine = make_engine(model, params, page_size=8)
    assert not engine.incremental_splice

    def boom(first):
        def fail(*a, **k):
            raise RuntimeError("boom")
        return fail

    monkeypatch.setattr(engine, "_chunk_fn", boom)
    a = engine.submit(promptA(), 4)
    b = engine.submit(promptA(), 4)              # same length: one K=2 job
    engine.step()
    assert a.state is RequestState.FAILED and b.state is RequestState.FAILED
    assert engine.free_pages == engine.num_pages
    assert engine.transient_cache_bytes() == 0
    engine.assert_page_invariants()


def test_cancel_in_every_state(qwen_mp):
    """cancel() releases resources from QUEUED (lazy heap skip), PREFILLING
    (immediate job release for a singleton job), and RUNNING (slot retired
    on the spot); double-cancel and cancel-after-done return False."""
    from repro.serve.scheduler import RequestState
    model, params = qwen_mp
    engine = ServeEngine(model, params, batch_slots=1, s_max=32, page_size=8,
                         prefill_chunk_tokens=2, prefix_cache=False)
    running = engine.submit(promptA(), 8)
    while running.state is not RequestState.RUNNING:
        engine.step()
    prefilling = engine.submit(np.arange(1, 13, dtype=np.int32), 4)
    queued = engine.submit(promptB(), 4)
    survivor = engine.submit(promptA(), 3)
    assert engine.cancel(running.rid) and running.state is \
        RequestState.CANCELLED
    engine.step()                                # admits `prefilling`
    assert prefilling.state is RequestState.PREFILLING
    assert engine.cancel(prefilling.rid)
    assert prefilling.state is RequestState.CANCELLED
    assert engine.free_pages == engine.num_pages
    assert engine.cancel(queued.rid) and queued.state is \
        RequestState.CANCELLED
    assert not engine.cancel(queued.rid)         # already cancelled
    engine.run()
    assert survivor.done and len(survivor.tokens) == 3   # queue undamaged
    assert not queued.tokens and queued.error == "cancelled"
    assert engine.free_pages == engine.num_pages
    engine.assert_page_invariants()
    assert not engine.cancel(survivor.rid)
    # aborted requests are counted separately and never pollute completion
    # counts or the latency percentiles (a cancel-right-after-submit would
    # otherwise enter latency p50 as ~0 s)
    s = engine.metrics.summary()
    assert s["aborted"] == 3 and s["completed"] == 1


def test_cancel_grouped_prefill_member_lands_at_splice(qwen_mp):
    """Cancelling ONE member of a batch-K prefill job cannot change the
    group's batch shape mid-stream: the cancelled member retires at the
    splice without sampling while its group-mates run to completion."""
    from repro.serve.scheduler import RequestState
    model, params = qwen_mp
    engine = ServeEngine(model, params, batch_slots=2, s_max=32, page_size=8,
                         prefill_chunk_tokens=2, prefix_cache=False)
    a = engine.submit(np.arange(1, 13, dtype=np.int32), 4)
    b = engine.submit(np.arange(21, 33, dtype=np.int32), 4)
    engine.step()                                # one K=2 job, chunk 1
    assert a.state is RequestState.PREFILLING
    assert engine.cancel(b.rid)
    engine.run()
    assert a.done and len(a.tokens) == 4
    assert b.state is RequestState.CANCELLED and not b.tokens
    assert engine.free_pages == engine.num_pages
    engine.assert_page_invariants()


def test_poisoned_cache_failover_keeps_serving(qwen_mp, monkeypatch):
    """The incremental chunk dispatch DONATES the shared resident cache; a
    failure at execution time can therefore destroy every live slot's K/V,
    not just the failed job's. The engine must detect the dead buffers,
    fail ALL in-flight requests, rebuild the pool/allocator/prefix index,
    and keep serving queued and future requests."""
    from repro.serve.scheduler import RequestState
    model, params = qwen_mp
    engine = ServeEngine(model, params, batch_slots=2, s_max=32, page_size=8,
                         prefill_chunk_tokens=4)
    assert engine.incremental_splice
    bystander = engine.submit(promptA(), 12)
    while bystander.state is not RequestState.RUNNING:
        engine.step()

    def dead():
        def fail(params, cache, batch):
            for leaf in jax.tree.leaves(cache):   # donated-and-lost buffers
                leaf.delete()
            raise RuntimeError("device OOM mid-dispatch")
        return fail

    monkeypatch.setattr(engine, "_chunk_paged_fn", dead)
    doomed = engine.submit(np.arange(1, 14, dtype=np.int32), 4)
    engine.step()                                # chunk raises -> failover
    assert doomed.state is RequestState.FAILED
    assert bystander.state is RequestState.FAILED   # its K/V died too
    assert "cache lost" in bystander.error
    assert engine.free_pages == engine.num_pages
    engine.assert_page_invariants()
    monkeypatch.undo()
    ok = engine.submit(promptB(), 4)
    engine.run()
    assert ok.done and len(ok.tokens) == 4
    s = engine.metrics.summary()
    assert s["aborted"] == 2 and s["completed"] == 1


# --------------------------------------------------- mask-sentinel fixes
def test_all_freed_batch_bf16_decode_is_finite(qwen_mp):
    """The -1e30 sentinel satellite, engine-level: with EVERY slot freed
    (pos parked at INACTIVE_POS, block tables all -1) a bf16-compute decode
    tick must produce finite logits — a fully-masked attention row comes
    out harmless (zeros/uniform), never NaN out of softmax."""
    import jax.numpy as jnp_
    model, params = qwen_mp
    engine = ServeEngine(model, params, batch_slots=2, s_max=32, page_size=8,
                         compute_dtype=jnp_.bfloat16,
                         cache_dtype=jnp_.bfloat16, prefix_cache=False)
    req = engine.submit(promptA(), 2)
    engine.run()
    assert req.done
    assert all(r is None for r in engine.slot_req)       # all freed
    logits, engine.cache = engine._decode(
        engine.params, engine.cache,
        {"token": jax.numpy.asarray(engine.cur_token),
         **engine._decode_extras()})
    assert np.isfinite(np.asarray(logits)).all()


def test_lm_logits_fp16_padding_mask_is_finite():
    """Regression for the overflow itself: in float16 the old -1e30
    sentinel became -inf (fp16 max is 65504) in the vocab-padding mask;
    the dtype-aware sentinel keeps every logit finite."""
    from repro.models import layers as L
    table = jax.random.normal(jax.random.PRNGKey(0), (16, 8),
                              jax.numpy.float16) * 0.1
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 8),
                          jax.numpy.float16)
    logits = L.lm_logits({"table": table}, x, None, vocab=10)
    assert logits.dtype == jax.numpy.float16
    out = np.asarray(logits, np.float32)
    assert np.isfinite(out).all()
    # padding columns still lose every argmax
    assert (out.argmax(-1) < 10).all()


def test_int8_ptq_path_through_engine():
    """The PTQ path is wired through the engine unchanged."""
    engine = ServeEngine.build(ARCH, reduced=True, batch_slots=2, s_max=32,
                               quantize_int8=True)
    req = engine.submit(np.array([1, 2, 3], np.int32), 4)
    engine.run()
    assert req.done and len(req.tokens) == 4
    assert all(0 <= t < engine.cfg.vocab_size for t in req.tokens)
