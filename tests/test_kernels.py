"""Per-kernel shape/dtype sweeps asserting allclose against the ref.py
pure-jnp oracles (kernels run interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def _rand(key, shape, dtype=jnp.float32, scale=1.0):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype) * scale


@pytest.mark.parametrize("dataflow", ["output_stationary", "weight_stationary",
                                      "input_stationary"])
@pytest.mark.parametrize("mkn", [(256, 256, 256), (192, 320, 128),
                                 (130, 70, 200), (64, 512, 96)])
def test_matmul_dataflows(dataflow, mkn):
    m, k, n = mkn
    ks = jax.random.split(KEY, 2)
    x = _rand(ks[0], (m, k))
    w = _rand(ks[1], (k, n))
    got = ops.matmul(x, w, block_m=64, block_n=64, block_k=64, dataflow=dataflow)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.matmul(x, w)),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_dtypes(dtype):
    ks = jax.random.split(KEY, 2)
    x = _rand(ks[0], (128, 128), dtype)
    w = _rand(ks[1], (128, 128), dtype)
    got = ops.matmul(x, w, block_m=64, block_n=64, block_k=64)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref.matmul(x, w), np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("shape", [(128, 256, 192), (256, 128, 64)])
def test_quant_matmul(shape):
    m, k, n = shape
    ks = jax.random.split(KEY, 3)
    x = _rand(ks[0], (m, k))
    wq = jax.random.randint(ks[1], (k, n), -127, 127, jnp.int8)
    sc = jax.random.uniform(ks[2], (n,), jnp.float32, 0.01, 0.1)
    got = ops.quant_matmul(x, wq, sc, block_m=64, block_n=64, block_k=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.quant_matmul(x, wq, sc)),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("cfg", [
    dict(Sq=256, Sk=256, H=4, KV=2, causal=True, window=0),
    dict(Sq=256, Sk=256, H=4, KV=4, causal=False, window=0),
    dict(Sq=256, Sk=256, H=8, KV=2, causal=True, window=64),
    dict(Sq=128, Sk=512, H=2, KV=1, causal=False, window=0),
])
def test_flash_attention(cfg):
    ks = jax.random.split(KEY, 3)
    q = _rand(ks[0], (2, cfg["Sq"], cfg["H"], 64))
    k = _rand(ks[1], (2, cfg["Sk"], cfg["KV"], 64))
    v = _rand(ks[2], (2, cfg["Sk"], cfg["KV"], 64))
    got = ops.flash_attention(q, k, v, causal=cfg["causal"],
                              window=cfg["window"], block_q=64, block_k=64)
    want = ref.flash_attention(q, k, v, causal=cfg["causal"],
                               window=cfg["window"])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("cfg", [
    dict(S=256, H=4, KV=2, window=0),
    dict(S=256, H=8, KV=2, window=64),
    dict(S=96, H=2, KV=1, window=0),      # non-block-multiple -> oracle path
])
def test_flash_prefill_exports_kv(cfg):
    """The K/V-exporting prefill variant: O matches flash attention and the
    exported K/V tiles are the inputs bit-for-bit (the cache rows a serving
    prefill scatters through block tables)."""
    ks = jax.random.split(KEY, 3)
    q = _rand(ks[0], (2, cfg["S"], cfg["H"], 64))
    k = _rand(ks[1], (2, cfg["S"], cfg["KV"], 64))
    v = _rand(ks[2], (2, cfg["S"], cfg["KV"], 64))
    o, ko, vo = ops.flash_prefill(q, k, v, causal=True, window=cfg["window"],
                                  block_q=64, block_k=64)
    want = ref.flash_attention(q, k, v, causal=True, window=cfg["window"])
    np.testing.assert_allclose(np.asarray(o), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_array_equal(np.asarray(ko), np.asarray(k))
    np.testing.assert_array_equal(np.asarray(vo), np.asarray(v))


# --------------------------------------------------------- paged attention
def _paged_setup(key, B, Sq, H, KV, hd, P, ps, mps, fill):
    """Random pool + per-slot block tables whose first ``fill[b] // ps + 1``
    entries are allocated (non-contiguous page ids — the gather must really
    go through the table)."""
    ks = jax.random.split(key, 3)
    q = _rand(ks[0], (B, Sq, H, hd))
    pk = _rand(ks[1], (P, ps, KV, hd))
    pv = _rand(ks[2], (P, ps, KV, hd))
    rng = np.random.default_rng(3)
    bt = np.full((B, mps), -1, np.int32)
    perm = rng.permutation(P)
    nxt = 0
    for b in range(B):
        need = -(-fill[b] // ps)
        bt[b, :need] = perm[nxt:nxt + need]
        nxt += need
    return q, pk, pv, jnp.asarray(bt)


@pytest.mark.parametrize("cfg", [
    dict(Sq=8, H=4, KV=2, ps=8, mps=4, window=0),     # GQA prefill chunk
    dict(Sq=1, H=4, KV=4, ps=8, mps=4, window=0),     # decode shape
    dict(Sq=16, H=8, KV=2, ps=4, mps=8, window=6),    # sliding window
    dict(Sq=8, H=2, KV=1, ps=16, mps=2, window=0),    # page > chunk
])
def test_paged_attention_matches_ref(cfg):
    """Block-table gather + block-skip kernel vs the masked-gather oracle,
    across GQA grouping, decode/prefill query widths, sliding windows, and
    partially-filled last pages (start positions land mid-page)."""
    Sq, H, KV, ps, mps = (cfg["Sq"], cfg["H"], cfg["KV"], cfg["ps"],
                          cfg["mps"])
    B, hd, P = 2, 16, 2 * mps + 3
    # starts chosen so the last allocated page is PARTIALLY filled
    fill = [ps + ps // 2 + Sq, ps // 2 + Sq]
    q, pk, pv, bt = _paged_setup(KEY, B, Sq, H, KV, hd, P, ps, mps, fill)
    start = jnp.asarray([f - Sq for f in fill], jnp.int32)
    got = ops.paged_prefill(q, pk, pv, bt, start, window=cfg["window"])
    want = ref.paged_attention(q, pk, pv, bt, start, window=cfg["window"])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_paged_attention_skips_unallocated_and_future_pages():
    """Rows of unallocated pages and pages beyond the causal frontier can
    never contribute: poisoning them with huge values must not change the
    output (the block-skip + masking contract), and a freed slot (all--1
    table) returns exactly zero."""
    B, Sq, H, KV, hd, P, ps, mps = 2, 4, 4, 2, 16, 8, 8, 4
    q, pk, pv, bt = _paged_setup(KEY, B, Sq, H, KV, hd, P, ps, mps, [12, 4])
    start = jnp.asarray([8, 0], jnp.int32)
    base = ops.paged_prefill(q, pk, pv, bt, start)
    # poison every pool row that is NOT a valid row of some slot's prefix
    valid = np.zeros(P * ps, bool)
    btn = np.asarray(bt)
    for b, last in enumerate([11, 3]):
        for r in range(last + 1):
            valid[btn[b, r // ps] * ps + r % ps] = True
    poison = jnp.where(jnp.asarray(valid)[:, None, None],
                       pk.reshape(P * ps, KV, hd), 1e9).reshape(pk.shape)
    poison_v = jnp.where(jnp.asarray(valid)[:, None, None],
                         pv.reshape(P * ps, KV, hd), 1e9).reshape(pv.shape)
    got = ops.paged_prefill(q, poison, poison_v, bt, start)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(base))
    freed = ops.paged_decode(q[:, :1], pk, pv,
                             jnp.full((B, mps), -1, jnp.int32),
                             jnp.asarray([1 << 30, (1 << 30) + 7], jnp.int32))
    assert np.all(np.asarray(freed) == 0)


def test_paged_attention_prefix_aliased_pages_shared_across_slots():
    """Two slots whose block tables alias the SAME physical prefix pages
    (the prefix-cache layout) read identical prefix rows: with identical
    queries and identical tail pages, their outputs coincide."""
    Sq, H, KV, hd, P, ps, mps = 4, 4, 2, 16, 6, 8, 3
    ks = jax.random.split(KEY, 3)
    q1 = _rand(ks[0], (1, Sq, H, hd))
    q = jnp.concatenate([q1, q1], axis=0)
    pk = _rand(ks[1], (P, ps, KV, hd))
    pv = _rand(ks[2], (P, ps, KV, hd))
    flat_k = pk.reshape(P * ps, KV, hd)
    flat_v = pv.reshape(P * ps, KV, hd)
    # shared prefix page 2 for both slots; tail pages 0 vs 4 hold the SAME
    # rows copied across (so outputs must match exactly)
    rows = jnp.arange(ps)
    flat_k = flat_k.at[4 * ps + rows].set(flat_k[0 * ps + rows])
    flat_v = flat_v.at[4 * ps + rows].set(flat_v[0 * ps + rows])
    pk = flat_k.reshape(P, ps, KV, hd)
    pv = flat_v.reshape(P, ps, KV, hd)
    bt = jnp.asarray([[2, 0, -1], [2, 4, -1]], jnp.int32)
    start = jnp.asarray([ps + 2, ps + 2], jnp.int32)   # mid tail page
    out = ops.paged_prefill(q, pk, pv, bt, start)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(out[1]))


def test_paged_attention_degenerate_one_page_spans_s_max():
    """page_size == s_max (one page per slot): the paged kernel collapses to
    plain causal attention over the slot's rows — cross-checked against the
    flash-attention oracle on the same rows."""
    B, S, H, KV, hd = 2, 16, 4, 2, 16
    ks = jax.random.split(KEY, 3)
    q = _rand(ks[0], (B, S, H, hd))
    k = _rand(ks[1], (B, S, KV, hd))
    v = _rand(ks[2], (B, S, KV, hd))
    # pool with one page per slot holding that slot's rows
    pk = jnp.stack([k[0], k[1]])
    pv = jnp.stack([v[0], v[1]])
    bt = jnp.asarray([[0], [1]], jnp.int32)
    start = jnp.zeros((B,), jnp.int32)
    got = ops.paged_prefill(q, pk, pv, bt, start)
    want = ref.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_mask_value_dtype_aware():
    """The -1e30 sentinel satellite: finite in every dtype (fp16's max is
    65504, so the historical constant overflowed to -inf there and a fully
    masked row softmaxed to NaN), unchanged for f32/bf16."""
    from repro.models.layers import mask_value
    assert mask_value(jnp.float32) == -1e30
    assert mask_value(jnp.bfloat16) == -1e30
    f16 = mask_value(jnp.float16)
    assert np.isfinite(np.float16(f16))
    assert f16 < -1e4
    for dt in (jnp.float32, jnp.bfloat16, jnp.float16):
        assert np.isfinite(np.asarray(jnp.asarray(mask_value(dt), dt)))


@pytest.mark.parametrize("T,chunk", [(64, 16), (64, 32), (128, 64), (33, 16)])
def test_wkv6(T, chunk):
    B, H, N = 2, 3, 16
    ks = jax.random.split(KEY, 6)
    r, k, v = (_rand(kk, (B, T, H, N), scale=0.5) for kk in ks[:3])
    w = jax.nn.sigmoid(_rand(ks[3], (B, T, H, N))) * 0.5 + 0.5
    u = _rand(ks[4], (H, N), scale=0.1)
    s0 = _rand(ks[5], (B, H, N, N), scale=0.1)
    y1, sT1 = ops.wkv6(r, k, v, w, u, s0, chunk=chunk)
    y2, sT2 = ref.wkv6(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(sT1), np.asarray(sT2), rtol=3e-4, atol=3e-4)


def test_wkv6_strong_decay():
    """Numerical safety with aggressive decays (w near 0)."""
    B, T, H, N = 1, 64, 2, 8
    ks = jax.random.split(KEY, 5)
    r, k, v = (_rand(kk, (B, T, H, N), scale=0.5) for kk in ks[:3])
    w = jnp.full((B, T, H, N), 0.05)
    u = _rand(ks[3], (H, N), scale=0.1)
    s0 = jnp.zeros((B, H, N, N))
    y1, _ = ops.wkv6(r, k, v, w, u, s0, chunk=16)
    y2, _ = ref.wkv6(r, k, v, w, u, s0)
    assert np.isfinite(np.asarray(y1)).all()
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("T,chunk", [(64, 16), (128, 64)])
def test_selective_scan(T, chunk):
    B, D, N = 2, 32, 8
    ks = jax.random.split(KEY, 6)
    x = _rand(ks[0], (B, T, D))
    dt = jax.nn.softplus(_rand(ks[1], (B, T, D)))
    b = _rand(ks[2], (B, T, N))
    c = _rand(ks[3], (B, T, N))
    a = -jnp.exp(_rand(ks[4], (D, N), scale=0.5))
    h0 = _rand(ks[5], (B, D, N), scale=0.1)
    y1, h1 = ops.selective_scan(x, dt, b, c, a, h0, chunk=chunk)
    y2, h2 = ref.selective_scan(x, dt, b, c, a, h0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=2e-4, atol=2e-4)


def test_matmul_grad_flows():
    """Kernels are differentiable via interpret mode (training usability)."""
    ks = jax.random.split(KEY, 2)
    x = _rand(ks[0], (64, 64))
    w = _rand(ks[1], (64, 64))

    def f(x, w):
        return jnp.sum(ops.matmul(x, w, block_m=64, block_n=64, block_k=64) ** 2)
    gx, gw = jax.grad(f, argnums=(0, 1))(x, w)
    gx2, gw2 = jax.grad(lambda x, w: jnp.sum((x @ w) ** 2), argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx2), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(gw2), rtol=1e-3, atol=1e-3)
