"""Distributed step builders: chunked CE correctness, microbatch-accumulation
equivalence, training convergence on the synthetic Markov task."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data.synthetic import TokenStream
from repro.launch import steps as steps_mod
from repro.models.registry import get_model, reduced_config
from repro.optim.adamw import AdamW

KEY = jax.random.PRNGKey(0)


def test_chunked_ce_equals_full():
    B, S, D, V = 2, 64, 16, 50
    ks = jax.random.split(KEY, 3)
    feats = jax.random.normal(ks[0], (B, S, D))
    w = jax.random.normal(ks[1], (D, V)) * 0.1
    labels = jax.random.randint(ks[2], (B, S), 0, V)
    full = steps_mod.cross_entropy((feats @ w)[None][0].astype(jnp.float32), labels)
    chunked = steps_mod.chunked_cross_entropy(feats, w, labels, V, tied=False,
                                              chunk=16)
    np.testing.assert_allclose(float(full), float(chunked), rtol=1e-5)
    # tied head + ragged chunk + padded vocab masking
    table = jax.random.normal(ks[1], (V + 14, D)) * 0.1
    full_t = steps_mod.cross_entropy(
        jnp.where(jnp.arange(V + 14) < V, (feats @ table.T).astype(jnp.float32),
                  -1e30), labels)
    chunked_t = steps_mod.chunked_cross_entropy(feats, table, labels, V,
                                                tied=True, chunk=24)
    np.testing.assert_allclose(float(full_t), float(chunked_t), rtol=1e-5)


def test_chunked_ce_grads_match():
    B, S, D, V = 2, 32, 8, 30
    ks = jax.random.split(KEY, 3)
    feats = jax.random.normal(ks[0], (B, S, D))
    w = jax.random.normal(ks[1], (D, V)) * 0.1
    labels = jax.random.randint(ks[2], (B, S), 0, V)

    g1 = jax.grad(lambda w: steps_mod.cross_entropy(
        (feats @ w).astype(jnp.float32), labels))(w)
    g2 = jax.grad(lambda w: steps_mod.chunked_cross_entropy(
        feats, w, labels, V, tied=False, chunk=8))(w)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-4,
                               atol=1e-6)


def test_microbatch_equivalence():
    """mb=1 and mb=4 produce (near-)identical updated params."""
    cfg = reduced_config(configs.get_config("minicpm-2b"))
    model = get_model(cfg)
    opt = AdamW(learning_rate=1e-3)
    B, S = 8, 32
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    outs = {}
    for mb in (1, 4):
        state = steps_mod.init_train_state(model, opt, KEY)
        step = steps_mod.make_train_step(model, opt, compute_dtype=jnp.float32,
                                         remat=False, microbatches=mb)
        state, metrics = jax.jit(step)(state, batch)
        outs[mb] = (state, float(metrics["loss"]))
    p1 = jax.tree.leaves(outs[1][0]["params"])
    p4 = jax.tree.leaves(outs[4][0]["params"])
    for a, b in zip(p1, p4):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3,
                                   atol=2e-5)


@pytest.mark.slow
def test_training_learns_markov_structure():
    """CE drops well below the uniform log(V) baseline => the model learns
    the synthetic chain (deliverable (b) substance)."""
    cfg = reduced_config(configs.get_config("codeqwen1.5-7b"),
                         vocab_size=256, num_layers=2, d_model=64, d_ff=128)
    model = get_model(cfg)
    opt = AdamW(learning_rate=3e-3, weight_decay=0.0)
    state = steps_mod.init_train_state(model, opt, KEY)
    step = jax.jit(steps_mod.make_train_step(model, opt,
                                             compute_dtype=jnp.float32,
                                             remat=False))
    stream = TokenStream(cfg.vocab_size, 8, 64, seed=5, branching=4)
    first = None
    for i in range(120):
        b = stream.batch_at(i)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        state, metrics = step(state, batch)
        if first is None:
            first = float(metrics["loss"])
    last = float(metrics["loss"])
    # uniform over 4 successors = log(4) ~ 1.39; start near log(256) ~ 5.5
    assert last < first - 1.5, (first, last)
