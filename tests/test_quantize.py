"""Quantization: error bounds (hypothesis), and the paper's headline claim —
fixed-16 rounding costs <= 2% accuracy on a trained ResNet20 (92% -> 90%)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quantize as q

# hypothesis is optional: the property tests below only exist when it is
# installed; deterministic bound checks always run so CPU-only environments
# still exercise the quantizers (the seed suite died at collection here).
try:
    from hypothesis import given, strategies as st
    from hypothesis.extra import numpy as hnp
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

KEY = jax.random.PRNGKey(0)


def _roundtrip_bound(w: np.ndarray):
    """|w - dequant(quant(w))| <= scale/2 per channel (symmetric rounding)."""
    qt = q.quantize_per_channel(jnp.asarray(w))
    err = np.abs(w - np.asarray(qt.dequant()))
    bound = np.asarray(qt.scale) * 0.5 + 1e-6
    assert (err <= np.broadcast_to(bound, err.shape) + 1e-6).all()


def _fixed_point_quantum(x: float):
    """Q4.11: error <= 2^-12 within range; idempotent."""
    fx = float(q.fixed_point(jnp.float32(x)))
    assert abs(fx - x) <= 2.0 ** -11  # round-to-nearest => half-quantum 2^-12
    assert float(q.fixed_point(jnp.float32(fx))) == pytest.approx(fx, abs=1e-9)


def test_int8_roundtrip_error_bound_deterministic():
    rng = np.random.default_rng(0)
    for shape in [(2, 2), (8, 16), (4, 4, 8)]:
        w = (rng.integers(-10000, 10000, shape) / 100.0).astype(np.float32)
        _roundtrip_bound(w)


def test_fixed_point_quantum_deterministic():
    for xi in (-159000, -4096, -1, 0, 1, 777, 4095, 158999):
        _fixed_point_quantum(xi / 10000.0)


if HAVE_HYPOTHESIS:
    @given(hnp.arrays(np.float32,
                      hnp.array_shapes(min_dims=2, max_dims=3,
                                       min_side=2, max_side=32),
                      elements=st.integers(-10000, 10000).map(
                          lambda i: np.float32(i / 100.0))))
    def test_int8_roundtrip_error_bound(w):
        _roundtrip_bound(w)

    @given(st.integers(-159000, 159000))
    def test_fixed_point_quantum(xi):
        """(integer-derived floats: hypothesis float strategies trip over the
        fast-math -0.0 handling of XLA's bundled libs)"""
        _fixed_point_quantum(xi / 10000.0)


def test_quantize_params_structure():
    params = {"w": jnp.ones((8, 16)), "b": jnp.ones((16,)),
              "nested": {"w2": jnp.ones((4, 4, 8))}}
    qp = q.quantize_params(params)
    assert isinstance(qp["w"], q.QuantizedTensor)
    assert not isinstance(qp["b"], q.QuantizedTensor)  # 1-D left alone
    assert isinstance(qp["nested"]["w2"], q.QuantizedTensor)
    assert q.quantized_bytes(qp) < sum(x.nbytes for x in jax.tree.leaves(params))


# --------------------------------------------------------------- paper claim
@pytest.fixture(scope="module")
def trained_resnet():
    """Train reduced-width ResNet20 on the synthetic CIFAR task for a few
    hundred steps (CPU-feasible)."""
    from repro.configs.resnet20_cifar import ResNetConfig
    from repro.data.synthetic import synthetic_cifar
    from repro.models import resnet
    from repro.optim.adamw import AdamW

    cfg = ResNetConfig(widths=(8, 16, 32))
    params = resnet.init(cfg, KEY)
    opt = AdamW(learning_rate=3e-3, weight_decay=1e-4)
    opt_state = opt.init(params)
    xs, ys = synthetic_cifar(2048, seed=1)
    xt, yt = synthetic_cifar(512, seed=2)

    @jax.jit
    def step(params, opt_state, bx, by):
        def loss_fn(p):
            logits = resnet.forward(p, cfg, bx)
            onehot = jax.nn.one_hot(by, cfg.num_classes)
            return -jnp.mean(jnp.sum(onehot * jax.nn.log_softmax(logits), -1))
        loss, grads = jax.value_and_grad(loss_fn)(params)
        updates, opt_state, _ = opt.update(grads, opt_state, params)
        from repro.optim.adamw import apply_updates
        return apply_updates(params, updates), opt_state, loss

    bs = 128
    for i in range(160):
        j = (i * bs) % (len(ys) - bs)
        params, opt_state, loss = step(params, opt_state, xs[j:j + bs],
                                       ys[j:j + bs])
    return cfg, params, xt, yt


def _acc(cfg, params, xs, ys, folded=False):
    from repro.models import resnet
    logits = resnet.forward(params, cfg, jnp.asarray(xs), folded=folded)
    return float(jnp.mean((jnp.argmax(logits, -1) == jnp.asarray(ys))))


def test_fixed16_accuracy_drop_within_2pct(trained_resnet):
    """The paper: fp32 92% -> fixed-16 90% (<= 2% drop). We assert the same
    bound on our trained model + test set."""
    cfg, params, xt, yt = trained_resnet
    from repro.core.quantize import fixed_point_tree
    from repro.models import resnet
    acc_fp32 = _acc(cfg, params, xt, yt)
    assert acc_fp32 > 0.8, f"training failed to converge: {acc_fp32}"
    folded = resnet.fold_bn(params)
    acc_folded = _acc(cfg, folded, xt, yt, folded=True)
    q16 = fixed_point_tree(folded)
    acc_q16 = _acc(cfg, q16, xt, yt, folded=True)
    assert acc_folded - acc_q16 <= 0.02 + 1e-9, (acc_folded, acc_q16)


def test_int8_accuracy_drop_within_2pct(trained_resnet):
    """Beyond-paper: the TPU-idiomatic int8 path meets the same bound."""
    cfg, params, xt, yt = trained_resnet
    from repro.core.quantize import dequantize_params, quantize_params
    from repro.models import resnet
    folded = resnet.fold_bn(params)
    acc_folded = _acc(cfg, folded, xt, yt, folded=True)
    q8 = dequantize_params(quantize_params(folded), jnp.float32)
    acc_q8 = _acc(cfg, q8, xt, yt, folded=True)
    assert acc_folded - acc_q8 <= 0.02 + 1e-9, (acc_folded, acc_q8)
