"""The redesigned serve build API: ServeConfig + the legacy-kwarg shim +
the string-keyed backend registry + the package API surface.

* **Shim equivalence.** ``ServeEngine.build(arch, **kwargs)`` still works —
  each kwarg maps onto the ServeConfig field of the same name, so the
  greedy streams are identical by construction — but emits a
  DeprecationWarning; mixing ``config=`` with legacy kwargs is an error,
  and an unknown kwarg raises TypeError naming the valid fields.
* **validate().** Every cross-field invariant fails fast with a pinned
  message BEFORE any weights are built: capacity/sampling bounds, page
  alignment, dense-vs-page_size conflicts, paged-backend-needs-page_size,
  unknown backend names (listing the registry), tp-needs-paged, and a
  backend whose ``tp_compatible`` capability query refuses the tp degree.
* **Registry.** ``kvcache.BACKENDS`` is the single name->class table:
  duplicate registration raises, a freshly registered class resolves
  through ``make_backend`` and validates through ServeConfig, and a ready
  KVBackend INSTANCE passes validate() whether or not its name is
  registered (custom backends plug in without touching the table).
* **API surface.** ``repro.serve.__all__`` is snapshot-pinned so an
  accidental export removal (or an unexported new seam) fails loudly.
"""
import dataclasses

import numpy as np
import pytest

import repro.serve as serve
from repro.serve.config import ServeConfig
from repro.serve.engine import ServeEngine
from repro.serve.kvcache import (BACKENDS, KVBackend, PagedFP32Backend,
                                 make_backend, register_backend)

ARCH = "qwen2.5-32b"
S_MAX = 32
PS = 8


def _streams(engine):
    rng = np.random.default_rng(11)
    reqs = [engine.submit(rng.integers(0, engine.cfg.vocab_size, 8), g)
            for g in (6, 4, 8, 5)]
    engine.run()
    return [r.tokens for r in reqs]


# -------------------------------------------------------------------- shim
def test_legacy_kwargs_equivalent_and_deprecated():
    with pytest.warns(DeprecationWarning, match="ServeConfig"):
        legacy = ServeEngine.build(ARCH, batch_slots=2, s_max=S_MAX,
                                   page_size=PS, seed=0)
    config = ServeEngine.build(ARCH, config=ServeConfig(
        batch_slots=2, s_max=S_MAX, page_size=PS, seed=0))
    assert _streams(legacy) == _streams(config)


def test_config_plus_legacy_kwargs_rejected():
    with pytest.raises(ValueError, match="not both"):
        ServeEngine.build(ARCH, config=ServeConfig(), batch_slots=2)


def test_unknown_legacy_kwarg_raises_typeerror():
    with pytest.raises(TypeError, match="batch_slotz"):
        ServeEngine.build(ARCH, batch_slotz=2)


def test_config_path_emits_no_warning(recwarn):
    ServeEngine.build(ARCH, config=ServeConfig(batch_slots=2, s_max=S_MAX))
    assert not [w for w in recwarn.list
                if issubclass(w.category, DeprecationWarning)]


# --------------------------------------------------------------- validate()
@pytest.mark.parametrize("fields,msg", [
    (dict(batch_slots=0), "batch_slots"),
    (dict(s_max=0), "s_max"),
    (dict(top_k=-1), "top_k"),
    (dict(top_p=0.0), "top_p"),
    (dict(top_p=1.5), "top_p"),
    (dict(prefill_mode="chunked"), "prefill_mode"),
    (dict(paged_attn_impl="pallas"), "paged_attn_impl"),
    (dict(prefill_chunk_tokens=0), "prefill_chunk_tokens"),
    (dict(page_size=0), "page_size must be"),
    (dict(page_size=24), "multiple of"),
    (dict(kv_backend="dense", page_size=8), "conflicts"),
    (dict(kv_backend="paged_int8"), "needs page_size"),
    (dict(kv_backend="paged_latent"), "needs page_size"),
    (dict(kv_backend="latent_mla", page_size=8), "unknown kv_backend"),
    (dict(tp=2), "PAGED"),
])
def test_validate_rejects(fields, msg):
    with pytest.raises(ValueError, match=msg):
        ServeConfig(**{"s_max": 64, **fields}).validate()


def test_validate_returns_self_and_accepts_good_configs():
    good = ServeConfig(page_size=8, s_max=64, kv_backend="paged_fp32")
    assert good.validate() is good
    ServeConfig().validate()
    ServeConfig(tp=2, page_size=8, s_max=64).validate()
    # int8 and latent pages compose with tp since the sharding-aware seam:
    # the capability query accepts, so validate must NOT reject these
    ServeConfig(tp=2, page_size=8, s_max=64,
                kv_backend="paged_int8").validate()
    ServeConfig(tp=4, page_size=8, s_max=64,
                kv_backend="paged_latent").validate()


def test_validate_tp_incompatible_backend_pins_capability_message():
    """A backend answering tp_compatible=False surfaces through validate()
    with the pinned capability-query message — the single remaining tp
    rejection path (the old per-name ladder is gone)."""
    @register_backend
    class Refuses(PagedFP32Backend):
        name = "test_tp_refusenik"

        @classmethod
        def tp_compatible(cls, mesh) -> bool:
            return False
    try:
        with pytest.raises(ValueError, match="tp_compatible=False"):
            ServeConfig(tp=2, page_size=8, s_max=64,
                        kv_backend="test_tp_refusenik").validate()
        # tp=1 never consults the capability query
        ServeConfig(tp=1, page_size=8, s_max=64,
                    kv_backend="test_tp_refusenik").validate()
    finally:
        BACKENDS.pop("test_tp_refusenik", None)


def test_unknown_backend_error_lists_registry():
    with pytest.raises(ValueError) as e:
        ServeConfig(kv_backend="nope", page_size=8, s_max=64).validate()
    for name in sorted(BACKENDS):
        assert name in str(e.value)


def test_engine_kwargs_cover_init_surface():
    """Every engine_kwargs() key must be a real ServeEngine.__init__
    parameter — the seam that keeps the two surfaces from drifting."""
    import inspect
    params = set(inspect.signature(ServeEngine.__init__).parameters)
    kw = set(ServeConfig().engine_kwargs())
    missing = kw - params
    assert not missing, f"engine_kwargs not accepted by __init__: {missing}"


# ---------------------------------------------------------------- registry
def test_registry_names():
    assert {"dense", "paged", "paged_fp32", "paged_int8",
            "paged_latent"} <= set(BACKENDS)


def test_duplicate_registration_raises():
    with pytest.raises(ValueError, match="already registered"):
        @register_backend
        class Clash(PagedFP32Backend):
            name = "paged"


def test_custom_backend_registers_resolves_and_validates():
    @register_backend
    class Custom(PagedFP32Backend):
        name = "test_custom_fp32"
    try:
        assert BACKENDS["test_custom_fp32"] is Custom
        be = make_backend("test_custom_fp32", family="dense", page_size=PS,
                          num_pages=4)
        assert type(be) is Custom
        ServeConfig(kv_backend="test_custom_fp32", page_size=8,
                    s_max=64).validate()
        # a ready INSTANCE passes validate even if its name left the table
        del BACKENDS["test_custom_fp32"]
        ServeConfig(kv_backend=be, page_size=8, s_max=64).validate()
        assert isinstance(be, KVBackend)
    finally:
        BACKENDS.pop("test_custom_fp32", None)


# ------------------------------------------- registry error paths under tp
def test_make_backend_unknown_error_lists_sorted_registry():
    """make_backend's unknown-name message lists the registry names SORTED
    — pinned, because the list is how users discover valid spellings and a
    dict-order listing would churn with registration order."""
    with pytest.raises(ValueError) as e:
        make_backend("nope", family="dense", page_size=8, num_pages=4)
    assert str(sorted(BACKENDS)) in str(e.value)


def test_hookless_custom_backend_replicates_with_warning(multidevice):
    """A custom backend that never declared pool_axes() still serves under
    tp>1: place() falls back to a fully replicated cache and logs a warning
    (correct, just not memory-scaled per shard)."""
    out = multidevice("""
        import logging
        from repro.serve.config import ServeConfig
        from repro.serve.engine import ServeEngine
        from repro.serve.kvcache import (KVBackend, PagedFP32Backend,
                                         register_backend)
        import repro.serve.kvcache as kvmod

        @register_backend
        class Hookless(PagedFP32Backend):
            name = "test_hookless_paged"
            # simulate a custom backend predating the sharding hooks: its
            # effective pool_axes is the base KVBackend declaration
            pool_axes = classmethod(KVBackend.pool_axes.__func__)

        records = []
        class Tap(logging.Handler):
            def emit(self, r):
                records.append(r.getMessage())
        kvmod.log.addHandler(Tap())

        eng = ServeEngine.build("qwen2.5-32b", config=ServeConfig(
            page_size=16, s_max=64, batch_slots=2,
            kv_backend="test_hookless_paged", tp=2,
            cfg_overrides=dict(num_heads=8, num_kv_heads=4)))
        assert any("pool_axes" in m and "replicated" in m
                   for m in records), records
        # replicated fallback: every shard holds the FULL pool
        k = eng.cache["k"]
        assert k.sharding.shard_shape(k.shape) == k.shape
        r = eng.submit([1, 2, 3, 4], 3)
        eng.run()
        print("OK", r.tokens)
    """)
    assert "OK" in out


# ------------------------------------------------------------- API surface
def test_serve_api_surface_snapshot():
    assert sorted(serve.__all__) == sorted([
        "ServeEngine", "ServeConfig", "PageAllocator",
        "MetricsRecorder", "SLO", "ReplaySummary", "merged_summary",
        "KVBackend", "BACKENDS", "register_backend", "make_backend",
        "DenseBackend", "PagedFP32Backend", "PagedInt8Backend",
        "PagedLatentBackend",
        "PrefixIndex", "PrefixPlan", "ReplicaRouter",
        "Request", "RequestState", "SchedPolicy", "Scheduler",
        "ArrivalEvent", "WorkloadSpec", "generate", "replay"])
    for name in serve.__all__:
        assert hasattr(serve, name), name


def test_serve_config_fields_are_build_surface():
    """The shim maps legacy kwargs 1:1 onto ServeConfig fields; pin the
    field list so an added knob must consciously extend the config."""
    assert sorted(f.name for f in dataclasses.fields(ServeConfig)) == sorted([
        "reduced", "batch_slots", "s_max", "seed", "quantize_int8",
        "temperature", "top_k", "top_p", "page_size", "num_pages",
        "kv_backend", "prefix_cache", "prefill_mode",
        "prefill_chunk_tokens", "prefill_attn_impl", "paged_attn_impl",
        "policy", "compute_dtype", "tp", "cfg_overrides"])
