"""Parallel chunked prefill: equivalence against the scan-prefill anchor
(greedy tokens identical, cache rows allclose at dtype tolerance) across
transformer / hybrid / encdec / VLM, chunk-size sweeps (chunk > prompt and
chunk = 1 included), the paged splice, the bucketed-compile bound under
mixed-length traffic, the head-of-line latency bound during long-prompt
ingestion, and the top-k / top-p sampling satellite.

The design anchor: ``prefill_chunk`` mirrors ``decode_step``'s math exactly
(same residual structure, same masked-softmax validity over the same cache
rows), differing only in reduction width — so greedy argmax streams must
match token-for-token, and cache leaves to ~1e-5 in float32.
"""
import time

import jax
import numpy as np
import pytest

from repro import configs
from repro.models.registry import (extract_cache_slot, get_model,
                                   reduced_config)
from repro.serve.engine import ServeEngine, chunk_ladder, chunk_plan
from repro.serve.metrics import MetricsRecorder

S_MAX = 32
CACHE_TOL = 1e-5          # float32 serving cache


@pytest.fixture(scope="module")
def qwen():
    cfg = reduced_config(configs.get_config("qwen2.5-32b"))
    model = get_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def hymba():
    cfg = reduced_config(configs.get_config("hymba-1.5b"))
    model = get_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _model(arch):
    cfg = reduced_config(configs.get_config(arch))
    model = get_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _workload(engine, vocab, prompt_len=8):
    """requests > batch_slots so slots recycle mid-run (prefill jobs overlap
    live decodes, not just a single prefill+decode)."""
    rng = np.random.default_rng(11)
    gens = [6, 4, 8, 5]
    return [engine.submit(rng.integers(0, vocab, prompt_len), g) for g in gens]


def _run_modes(model, params, prompt_len=8, **parallel_kw):
    scan = ServeEngine(model, params, batch_slots=2, s_max=S_MAX,
                       prefill_mode="scan")
    s_reqs = _workload(scan, model.cfg.vocab_size, prompt_len)
    scan.run()
    par = ServeEngine(model, params, batch_slots=2, s_max=S_MAX,
                      prefill_mode="parallel", **parallel_kw)
    p_reqs = _workload(par, model.cfg.vocab_size, prompt_len)
    par.run()
    return scan, s_reqs, par, p_reqs


# ------------------------------------------------------------ equivalence
@pytest.mark.parametrize("arch", ["qwen2.5-32b", "hymba-1.5b",
                                  "whisper-large-v3", "llama-3.2-vision-11b"])
def test_parallel_matches_scan_greedy(arch):
    """Greedy token streams are identical between the parallel chunked
    prefill and the teacher-forced scan anchor, for a slot-recycling
    workload, on every attention-bearing family."""
    model, params = _model(arch)
    _, s_reqs, _, p_reqs = _run_modes(model, params)
    for s, p in zip(s_reqs, p_reqs):
        assert s.tokens == p.tokens and len(s.tokens) == s.gen_len


@pytest.mark.parametrize("arch", ["qwen2.5-32b", "hymba-1.5b",
                                  "whisper-large-v3", "llama-3.2-vision-11b"])
def test_parallel_cache_rows_allclose(arch):
    """Mid-flight, a slot prefilled by the parallel path holds the same
    cache rows (K/V, ring positions, recurrent state, pos) as one prefilled
    by the scan anchor — allclose at float32 tolerance, positions exact."""
    model, params = _model(arch)
    se = ServeEngine(model, params, batch_slots=2, s_max=S_MAX,
                     prefill_mode="scan")
    pe = ServeEngine(model, params, batch_slots=2, s_max=S_MAX,
                     prefill_mode="parallel", prefill_chunk_tokens=4)
    prompt = np.arange(1, 13, dtype=np.int32) % model.cfg.vocab_size
    sr = se.submit(prompt, 6)
    pr = pe.submit(prompt, 6)
    for _ in range(3):
        se.step()
    while len(pr.tokens) < len(sr.tokens):      # chunked start is staggered
        pe.step()
    sc = extract_cache_slot(se.cache, sr.slot)
    pc = extract_cache_slot(pe.cache, pr.slot)
    assert set(sc) == set(pc)
    for key in sc:
        a, b = np.asarray(sc[key]), np.asarray(pc[key])
        if a.dtype.kind in "iu":                # positions: exact
            np.testing.assert_array_equal(a, b, err_msg=key)
        else:
            np.testing.assert_allclose(a, b, atol=CACHE_TOL, rtol=1e-4,
                                       err_msg=key)
    assert sr.tokens == pr.tokens


@pytest.mark.parametrize("chunk", [1, 4, 8, 64])
def test_chunk_size_sweep(qwen, chunk):
    """Any chunk size — including chunk = 1 (pure narrow) and chunk >
    prompt (single wide pass) — reproduces the scan stream."""
    model, params = qwen
    _, s_reqs, par, p_reqs = _run_modes(model, params, prompt_len=12,
                                        prefill_chunk_tokens=chunk)
    for s, p in zip(s_reqs, p_reqs):
        assert s.tokens == p.tokens
    assert par.max_prefill_tokens_per_tick <= chunk


def test_chunked_prefill_paged_splice(qwen):
    """Chunked parallel prefill splices into a PAGED cache (scatter into the
    slots' own pages) with streams identical to the dense scan anchor."""
    model, params = qwen
    scan = ServeEngine(model, params, batch_slots=2, s_max=S_MAX,
                       prefill_mode="scan")
    s_reqs = _workload(scan, model.cfg.vocab_size)
    scan.run()
    paged = ServeEngine(model, params, batch_slots=2, s_max=S_MAX,
                        page_size=8, prefill_mode="parallel",
                        prefill_chunk_tokens=4)
    p_reqs = _workload(paged, model.cfg.vocab_size)
    paged.run()
    for s, p in zip(s_reqs, p_reqs):
        assert s.tokens == p.tokens


def test_kernel_prefill_path_matches(qwen):
    """prefill_attn_impl='pallas' (the K/V-exporting flash kernel, interpret
    on CPU) produces the same greedy streams as the einsum reference."""
    model, params = qwen
    ein = ServeEngine(model, params, batch_slots=2, s_max=S_MAX,
                      prefill_attn_impl="einsum")
    e_reqs = _workload(ein, model.cfg.vocab_size)
    ein.run()
    ker = ServeEngine(model, params, batch_slots=2, s_max=S_MAX,
                      prefill_attn_impl="pallas")
    k_reqs = _workload(ker, model.cfg.vocab_size)
    ker.run()
    for e, k in zip(e_reqs, k_reqs):
        assert e.tokens == k.tokens


# ------------------------------------------------- paged-kernel prefill
@pytest.mark.parametrize("arch", ["qwen2.5-32b", "dbrx-132b",
                                  "llama-3.2-vision-11b",
                                  "whisper-large-v3"])
def test_paged_kernel_on_off_greedy_equality(arch):
    """The tentpole equivalence: paged_attn_impl='kernel' (Pallas
    block-gather decode + incremental per-chunk page splice where the
    family supports it) produces the same greedy streams as the
    masked-einsum transient path for dense / MoE / VLM / encdec — with a
    slot-recycling workload and a non-aligned prompt so partially-filled
    last pages are exercised."""
    model, params = _model(arch)
    kw = dict(batch_slots=2, s_max=S_MAX, page_size=8,
              prefill_chunk_tokens=4, prefix_cache=False)
    off = ServeEngine(model, params, paged_attn_impl="einsum", **kw)
    o_reqs = _workload(off, model.cfg.vocab_size, prompt_len=13)
    off.run()
    on = ServeEngine(model, params, paged_attn_impl="kernel", **kw)
    assert on.paged_attn_impl == "kernel"
    from repro.configs.base import Family
    assert on.incremental_splice == (model.cfg.family != Family.ENCDEC)
    n_reqs = _workload(on, model.cfg.vocab_size, prompt_len=13)
    on.run()
    for o, n in zip(o_reqs, n_reqs):
        assert o.tokens == n.tokens and len(n.tokens) == n.gen_len
    # the tentpole's memory claim: no transient request cache ever existed
    if on.incremental_splice:
        assert on.max_transient_cache_bytes == 0
    assert off.max_transient_cache_bytes > 0


@pytest.mark.parametrize("page_size", [4, 8, 16, 32])
def test_paged_kernel_page_size_sweep(qwen, page_size):
    """Explicit kernel impl across the page-size ladder INCLUDING the
    degenerate page_size == s_max single-page config: greedy streams equal
    the dense scan anchor at every size."""
    model, params = qwen
    scan = ServeEngine(model, params, batch_slots=2, s_max=S_MAX,
                       prefill_mode="scan")
    s_reqs = _workload(scan, model.cfg.vocab_size, prompt_len=13)
    scan.run()
    eng = ServeEngine(model, params, batch_slots=2, s_max=S_MAX,
                      page_size=page_size, paged_attn_impl="kernel",
                      prefill_chunk_tokens=4)
    assert eng.incremental_splice
    reqs = _workload(eng, model.cfg.vocab_size, prompt_len=13)
    eng.run()
    for s, p in zip(s_reqs, reqs):
        assert s.tokens == p.tokens
    assert eng.max_transient_cache_bytes == 0
    eng.assert_page_invariants()


def test_paged_kernel_prefix_aliased_pages_with_write_floor(qwen):
    """Prefix-aliased pages under the incremental splice: sharers read the
    donor's pages in place (no gather seeding), the chunk scatter drops
    writes below ``write_floor`` (the aliased full pages stay immutable),
    and an unaligned header's partial page is COW-materialised with the
    pool scatter — streams identical to the uncached engine throughout."""
    model, params = qwen
    vocab = model.cfg.vocab_size
    rng = np.random.default_rng(17)
    X = rng.integers(0, vocab, 21).astype(np.int32)   # 2 pages + 5 rows @ 8
    pA = np.concatenate([X, rng.integers(0, vocab, 6).astype(np.int32)])
    pB = np.concatenate([X, rng.integers(0, vocab, 6).astype(np.int32)])

    def serve(prefix_cache):
        eng = ServeEngine(model, params, batch_slots=2, s_max=S_MAX,
                          page_size=8, paged_attn_impl="kernel",
                          prefix_cache=prefix_cache, prefill_chunk_tokens=4)
        out = []
        for prompt, gen in [(X, 4), (pA, 6), (pB, 6)]:
            req = eng.submit(prompt, gen)
            eng.run()
            eng.assert_page_invariants()
            out.append(list(req.tokens))
        return eng, out

    e_on, on = serve(None)
    assert e_on.incremental_splice
    _, off = serve(False)
    assert on == off
    m = e_on.metrics
    assert m.prefix_hits == 2 and m.prefix_pages_shared >= 4
    assert m.prefix_cow_copies == 2            # partial page per sharer
    assert e_on.max_transient_cache_bytes == 0


def test_paged_kernel_engine_vs_flag_defaults(qwen):
    """'auto' resolves to the kernel for multi-page dense configs and to
    einsum for the degenerate single-page anchor (which must stay
    bit-exact with the dense path)."""
    model, params = qwen
    multi = ServeEngine(model, params, batch_slots=2, s_max=S_MAX,
                        page_size=8)
    assert multi.paged_attn_impl == "kernel" and multi.incremental_splice
    degen = ServeEngine(model, params, batch_slots=2, s_max=S_MAX,
                        page_size=S_MAX)
    assert degen.paged_attn_impl == "einsum" and not degen.incremental_splice
    dense = ServeEngine(model, params, batch_slots=2, s_max=S_MAX)
    assert not dense.incremental_splice


# ------------------------------------------------------------ bucketing
def test_chunk_ladder_and_plan_units():
    assert chunk_ladder(64) == [64, 32, 16, 8, 4, 2, 1]
    assert chunk_ladder(1) == [1]
    assert chunk_ladder(12) == [12, 8, 4, 2, 1]
    assert chunk_plan(100, chunk_ladder(64)) == [64, 32, 4]
    assert chunk_plan(12, chunk_ladder(64)) == [8, 4]
    assert chunk_plan(5, chunk_ladder(1)) == [1] * 5
    for n in range(1, 200):
        assert sum(chunk_plan(n, chunk_ladder(64))) == n


def test_mixed_length_traffic_bounded_compiles(qwen):
    """Mixed-length traffic: compile (trace) count stays <= the bucket-ladder
    bound, strictly below the number of distinct prompt lengths — the
    O(buckets)-not-O(lengths) property bucketing exists for."""
    model, params = qwen
    engine = ServeEngine(model, params, batch_slots=1, s_max=S_MAX,
                         prefill_chunk_tokens=16)
    rng = np.random.default_rng(5)
    lengths = list(range(3, 27, 2))             # 12 distinct prompt lengths
    reqs = [engine.submit(rng.integers(0, model.cfg.vocab_size, n), 1)
            for n in lengths]
    engine.run()
    assert all(r.done for r in reqs)
    ladder_bound = 2 * len(engine.prefill_ladder) * engine.batch_slots
    assert engine.prefill_trace_count <= ladder_bound
    assert engine.prefill_trace_count < len(set(lengths))
    assert engine.prefill_trace_evictions == 0
    assert engine.max_prefill_traces == ladder_bound


def test_trace_cap_clears_instead_of_leaking(qwen):
    """Past the cap the engine clears the chunk jit caches (counted) rather
    than leaking compiled executables without bound."""
    model, params = qwen
    engine = ServeEngine(model, params, batch_slots=1, s_max=S_MAX,
                         prefill_chunk_tokens=16, max_prefill_traces=2)
    rng = np.random.default_rng(5)
    for n in (3, 7, 13):
        engine.submit(rng.integers(0, model.cfg.vocab_size, n), 1)
    engine.run()
    assert engine.prefill_trace_evictions >= 1
    assert engine.prefill_trace_count <= 2


# ------------------------------------------------- head-of-line latency
def test_decode_latency_bounded_during_ingest():
    """The acceptance bound: while max-length prompts are being ingested,
    p95 decode inter-token latency of busy slots stays < 2x the no-prefill
    baseline (plus the hard structural bound: no tick ingests more than the
    chunk budget).

    Measurement design, for reliability on a noisy shared CPU: a cell big
    enough that compute (not per-dispatch overhead) dominates the tick —
    on the overhead-bound smoke cells every tick costs ~1 dispatch, so
    interleaving trivially reads as ~2x regardless of chunk size — a chunk
    budget below the busy decode width (the regime the bound targets), and
    the baseline/ingest engines stepped ALTERNATELY so both windows face
    the same machine-load profile (GC off inside the window)."""
    import gc

    cfg = reduced_config(configs.get_config("qwen2.5-32b"), d_model=256,
                         d_ff=768, num_heads=8, num_kv_heads=4, head_dim=32,
                         num_layers=4)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    chunk, s_max, long_len = 4, 256, 192

    def make():
        e = ServeEngine(model, params, batch_slots=4, s_max=s_max,
                        prefill_chunk_tokens=chunk)
        busy = [e.submit(np.arange(1, 9, dtype=np.int32) + i, 240)
                for i in range(3)]
        # warm every shape this test will hit (chunk ladder, decode, splice)
        warm = e.submit(np.arange(1, long_len + 1, dtype=np.int32), 1)
        while not warm.done:
            e.step()
        return e, busy

    def measure():
        base_e, base_busy = make()
        ingest_e, ingest_busy = make()
        for _ in range(3):   # continuous ingest pressure across the window
            ingest_e.submit(np.arange(1, long_len + 1, dtype=np.int32), 1)
        base, ticks = [], []
        gc.collect()
        gc.disable()
        try:
            for _ in range(48):
                t0 = time.perf_counter()
                base_e.step()
                base.append(time.perf_counter() - t0)
                t0 = time.perf_counter()
                ingest_e.step()
                ticks.append(time.perf_counter() - t0)
        finally:
            gc.enable()
        # the long prompts really were mid-ingestion during the window, the
        # busy decodes never finished, and no tick broke the chunk budget —
        # these structural properties must hold on EVERY attempt
        assert ingest_e.metrics.prefill_chunks > base_e.metrics.prefill_chunks
        assert all(not b.done for b in base_busy + ingest_busy)
        assert ingest_e.max_prefill_tokens_per_tick <= chunk
        return (float(np.percentile(base, 95)),
                float(np.percentile(ticks, 95)))

    # wall-clock ratio: allow a couple of fresh windows — a shared-CI load
    # burst landing inside one window is noise, a systematic >= 2x is not
    ratios = []
    for _ in range(3):
        p95_base, p95_ingest = measure()
        ratios.append(p95_ingest / p95_base)
        if ratios[-1] < 2.0:
            break
    assert ratios[-1] < 2.0, ratios


# ------------------------------------------------------------ sampling
def test_top_k_one_is_greedy(hymba):
    """top_k=1 at temperature > 0 collapses sampling to argmax — the stream
    equals the greedy engine's token-for-token (seeded determinism of the
    filtering path, independent of the PRNG draw)."""
    model, params = hymba
    greedy = ServeEngine(model, params, batch_slots=2, s_max=S_MAX)
    g = greedy.submit(np.arange(1, 9, dtype=np.int32), 8)
    greedy.run()
    topk = ServeEngine(model, params, batch_slots=2, s_max=S_MAX,
                       temperature=0.8, top_k=1, seed=3)
    t = topk.submit(np.arange(1, 9, dtype=np.int32), 8)
    topk.run()
    assert g.tokens == t.tokens


def test_top_p_tiny_is_greedy(hymba):
    """A vanishing nucleus keeps exactly the top-1 token."""
    model, params = hymba
    greedy = ServeEngine(model, params, batch_slots=2, s_max=S_MAX)
    g = greedy.submit(np.arange(1, 9, dtype=np.int32), 8)
    greedy.run()
    topp = ServeEngine(model, params, batch_slots=2, s_max=S_MAX,
                       temperature=0.8, top_p=1e-9, seed=3)
    t = topp.submit(np.arange(1, 9, dtype=np.int32), 8)
    topp.run()
    assert g.tokens == t.tokens


def test_top_k_top_p_seeded_determinism(hymba):
    """top-k + top-p sampling is reproducible per seed and stays in-vocab."""
    model, params = hymba
    outs = []
    for _ in range(2):
        engine = ServeEngine(model, params, batch_slots=2, s_max=S_MAX,
                             temperature=0.9, top_k=5, top_p=0.8, seed=7)
        req = engine.submit(np.arange(1, 9, dtype=np.int32), 10)
        engine.run()
        assert all(0 <= t < model.cfg.vocab_size for t in req.tokens)
        outs.append(req.tokens)
    assert outs[0] == outs[1]


def test_sampling_param_validation(hymba):
    model, params = hymba
    with pytest.raises(ValueError, match="top_k"):
        ServeEngine(model, params, batch_slots=1, s_max=S_MAX, top_k=-1)
    with pytest.raises(ValueError, match="top_p"):
        ServeEngine(model, params, batch_slots=1, s_max=S_MAX, top_p=0.0)
    with pytest.raises(ValueError, match="prefill_mode"):
        ServeEngine(model, params, batch_slots=1, s_max=S_MAX,
                    prefill_mode="bogus")


# ------------------------------------------------------------ metrics
def test_queue_wait_and_prefill_rate_metrics():
    """Unit math: queue wait (submit -> admit) is split out of TTFT, and
    prefill tokens/s aggregates over the wall spent INSIDE chunk calls."""
    t = {"now": 0.0}
    m = MetricsRecorder(clock=lambda: t["now"])
    m.on_start()
    m.on_submit(0, prompt_len=8)
    t["now"] = 2.0
    m.on_admit(0)
    m.on_prefill(0, 8)
    m.on_prefill_chunk(8, 0.5)
    t["now"] = 3.0
    m.on_first_token(0)
    m.on_done(0)
    m.on_stop()
    s = m.summary()
    assert s["queue_wait_s"]["p50"] == pytest.approx(2.0)
    assert s["ttft_s"]["p50"] == pytest.approx(3.0)
    assert s["prefill_tokens_per_s"] == pytest.approx(8 / 0.5)
    assert s["prefill_chunks"] == 1
    assert s["prefill_chunk_max_tokens"] == 8


def test_engine_reports_prefill_rate_and_queue_wait(qwen):
    """End-to-end: the engine summary carries a finite prefill tokens/s and
    queue-wait percentiles for a real run."""
    model, params = qwen
    engine = ServeEngine(model, params, batch_slots=2, s_max=S_MAX)
    _workload(engine, model.cfg.vocab_size)
    s = engine.run()
    assert np.isfinite(s["prefill_tokens_per_s"])
    assert s["prefill_tokens_per_s"] > 0
    assert np.isfinite(s["queue_wait_s"]["p95"])
    assert s["prefill_chunk_max_tokens"] <= \
        engine.prefill_chunk_tokens * engine.batch_slots
