"""Per-architecture smoke tests on REDUCED configs (assignment requirement):
one forward + one train step on CPU, asserting output shapes and no NaNs;
plus decode==forward consistency per family.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.launch import steps as steps_mod
from repro.models.registry import get_model, reduced_config
from repro.optim.adamw import AdamW

ARCHS = configs.list_archs()
KEY = jax.random.PRNGKey(0)


def _extras(cfg, B, dtype=jnp.float32):
    out = {}
    if cfg.cross_attn_every:
        out["image_embeds"] = jnp.ones((B, cfg.num_image_tokens, cfg.d_model),
                                       dtype) * 0.02
    if cfg.encoder_layers:
        out["frames"] = jnp.ones((B, 12, cfg.d_model), dtype) * 0.02
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_smoke(arch):
    cfg = reduced_config(configs.get_config(arch))
    model = get_model(cfg)
    params = model.init(KEY)
    B, S = 2, 16
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    logits, aux = model.forward(params, tokens, compute_dtype=jnp.float32,
                                **_extras(cfg, B))
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits[..., : cfg.vocab_size])).all()
    # padded vocab columns masked to -inf
    if cfg.padded_vocab > cfg.vocab_size:
        assert float(logits[..., cfg.vocab_size:].max()) < -1e20


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = reduced_config(configs.get_config(arch))
    model = get_model(cfg)
    opt = AdamW(learning_rate=1e-3)
    state = steps_mod.init_train_state(model, opt, KEY)
    B, S = 2, 16
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1),
             **_extras(cfg, B)}
    step = steps_mod.make_train_step(model, opt, compute_dtype=jnp.float32,
                                     remat=False)
    state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state["step"]) == 1
    # params actually changed
    before = jax.tree.leaves(steps_mod.init_train_state(model, opt, KEY)["params"])[0]
    after = jax.tree.leaves(state["params"])[0]
    assert not np.allclose(np.asarray(before), np.asarray(after))


@pytest.mark.parametrize("arch", ["codeqwen1.5-7b", "moonshot-v1-16b-a3b",
                                  "rwkv6-7b", "hymba-1.5b",
                                  "llama-3.2-vision-11b", "whisper-large-v3"])
def test_decode_matches_forward(arch):
    """Token-by-token decode reproduces teacher-forced forward logits.
    (MoE: generous capacity_factor so no token drops — capacity dropping is
    a train/prefill-only behaviour that decode paths never see.)"""
    import dataclasses
    from repro.configs.base import MoEConfig
    cfg = reduced_config(configs.get_config(arch))
    if cfg.moe:
        cfg = dataclasses.replace(cfg, moe=MoEConfig(
            cfg.moe.num_experts, cfg.moe.top_k, capacity_factor=8.0))
    model = get_model(cfg)
    params = model.init(KEY)
    B, S = 1, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    kw = _extras(cfg, B)
    full, _ = model.forward(params, toks, compute_dtype=jnp.float32, **kw)
    cache = model.init_cache(B, 16, jnp.float32)
    if cfg.encoder_layers:
        from repro.models import encdec
        enc_out = encdec.encode(params, cfg, kw["frames"],
                                compute_dtype=jnp.float32)
        xk, xv = encdec.precompute_cross_kv(params, cfg, enc_out)
        cache["xk"], cache["xv"] = xk, xv
    dkw = {k: v for k, v in kw.items() if k == "image_embeds"}
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, toks[:, t:t + 1], cache,
                                      compute_dtype=jnp.float32, **dkw)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec),
                               rtol=3e-3, atol=3e-3)


def test_hymba_window_ring_buffer():
    """Ring-buffer cache gives the same logits as an oversized cache once
    positions exceed the window."""
    cfg = reduced_config(configs.get_config("hymba-1.5b"))
    assert cfg.window == 8
    model = get_model(cfg)
    params = model.init(KEY)
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 20), 0, cfg.vocab_size)
    full, _ = model.forward(params, toks, compute_dtype=jnp.float32)
    cache = model.init_cache(1, cfg.window, jnp.float32)  # ring of window size
    outs = []
    for t in range(20):
        lg, cache = model.decode_step(params, toks[:, t:t + 1], cache,
                                      compute_dtype=jnp.float32)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec),
                               rtol=3e-3, atol=3e-3)


def test_moe_aux_losses_positive():
    cfg = reduced_config(configs.get_config("dbrx-132b"))
    model = get_model(cfg)
    params = model.init(KEY)
    tokens = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    _, aux = model.forward(params, tokens, compute_dtype=jnp.float32)
    assert float(aux["moe_aux"]) > 0.0
    assert float(aux["moe_z"]) > 0.0


def test_resnet20_paths_agree():
    from repro.configs.resnet20_cifar import CONFIG as RCFG
    from repro.models import resnet
    params = resnet.init(RCFG, KEY)
    imgs = jax.random.normal(KEY, (4, 32, 32, 3))
    l1 = resnet.forward(params, RCFG, imgs)
    l2 = resnet.forward(params, RCFG, imgs, impl="im2col")
    l3 = resnet.forward(resnet.fold_bn(params), RCFG, imgs, folded=True)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l3), rtol=2e-3, atol=2e-3)


def test_resnet20_pallas_matmul_path():
    """The im2col path routed through the Pallas systolic kernel (the Tensil
    execution model) matches lax.conv."""
    from repro.configs.resnet20_cifar import CONFIG as RCFG
    from repro.kernels import ops
    from repro.models import resnet
    params = resnet.init(RCFG, KEY)
    imgs = jax.random.normal(KEY, (2, 32, 32, 3))
    l1 = resnet.forward(params, RCFG, imgs)
    l2 = resnet.forward(params, RCFG, imgs, impl="im2col",
                        matmul_fn=lambda a, b: ops.matmul(
                            a, b, block_m=128, block_n=64, block_k=64,
                            dataflow="weight_stationary"))
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=5e-4, atol=5e-4)
