"""Multi-device tests (subprocess with --xla_force_host_platform_device_count):
sharded training equivalence, elastic re-shard restore, pipeline parallelism,
compressed gradient all-reduce, and the sharding-spec resolution logic."""
import numpy as np
import pytest

from repro.sharding import specs


# ---------------------------------------------------- spec resolution (local)
def test_resolve_without_mesh_is_identity():
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    assert specs.shard(x, "batch", None) is x
    assert specs.axis_size("batch") == 1


def test_rule_filtering():
    """Axes absent from the active mesh drop out of resolved specs."""
    import jax
    mesh = jax.make_mesh((1,), ("data",))
    with specs.use_mesh(mesh):
        p = specs.resolve("batch", "heads", None)
        # 'pod' filtered (absent), 'model' filtered (absent) -> heads -> None
        assert p[1] is None


def test_shard_rank_mismatch_raises():
    """Under an active mesh, shard() validates rank BEFORE fitting axes —
    a wrong-arity call is a bug at the call site, not a layout decision."""
    import jax
    import jax.numpy as jnp
    import pytest
    mesh = jax.make_mesh((1,), ("model",))
    x = jnp.ones((4, 4))
    with specs.use_mesh(mesh):
        with pytest.raises(ValueError, match="rank mismatch"):
            specs.shard(x, "batch", None, "heads")
    # no mesh: identity, rank never checked (models run untouched)
    assert specs.shard(x, "batch", None, "heads") is x


def test_use_mesh_nesting_restores_outer():
    """Nested use_mesh contexts stack: the inner mesh/rules win inside,
    the outer (or the no-mesh default) is restored on exit."""
    import jax
    outer = jax.make_mesh((1,), ("data",))
    inner = jax.make_mesh((1,), ("model",))
    assert specs.active_mesh() is None
    with specs.use_mesh(outer, specs.DEFAULT_RULES):
        assert specs.active_mesh() is outer
        with specs.use_mesh(inner, specs.TP_SERVE_RULES):
            assert specs.active_mesh() is inner
            # TP serve rules: every logical axis resolves replicated
            assert specs.resolve("heads", "d_ff") == jax.sharding.PartitionSpec(
                None, None)
        assert specs.active_mesh() is outer
        # DEFAULT_RULES restored: batch maps through ('pod','data') -> data
        assert specs.resolve("batch")[0] == "data"
    assert specs.active_mesh() is None


def test_spec_helpers_on_real_axes(multidevice):
    """axis_size / resolve / _fit_axes divisibility fallback / sharding_for
    against a mesh whose axes actually have size > 1 (subprocess: the parent
    test process is single-device)."""
    out = multidevice("""
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.sharding import specs

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with specs.use_mesh(mesh):
            assert specs.axis_size("heads") == 4          # heads -> model
            assert specs.axis_size("batch") == 2          # (pod,data) -> data
            assert specs.axis_size("kv_seq") == 1         # unmapped
            assert specs.resolve("batch", "heads") == P("data", "model")

            # _fit_axes: axes whose size does not divide the dim DROP
            assert specs._fit_axes((8, 12), ("batch", "heads")) == \\
                ("batch", "heads")
            assert specs._fit_axes((8, 10), ("batch", "heads")) == \\
                ("batch", None)                            # 10 % 4 != 0
            assert specs._fit_axes((3, 12), ("batch", "heads")) == \\
                (None, "heads")                            # 3 % 2 != 0

            # sharding_for is the one-array, shape-aware named_sharding
            sh = specs.sharding_for((2, 8, 16, 4, 8), specs.KV_POOL_AXES)
            assert sh.spec == P(None, None, None, "model", None)
            sh = specs.sharding_for((2, 8, 16, 5, 8), specs.KV_POOL_AXES)
            assert sh.spec == P(None, None, None, None, None)  # 5 % 4

        with specs.use_mesh(mesh, specs.TP_POOL_RULES):
            assert specs.axis_size("kv_heads") == 4
            assert specs.axis_size("heads") == 1          # not in pool rules

        # head_shard_axis: resolves only when tp divides BOTH head counts
        tp_mesh = jax.make_mesh((4,), ("model",))
        with specs.use_mesh(tp_mesh, specs.TP_SERVE_RULES):
            assert specs.head_shard_axis(8, 4) == (tp_mesh, "model")
            assert specs.head_shard_axis(8, 2) == (None, None)   # 2 % 4
            assert specs.head_shard_axis(6, 4) == (None, None)   # 6 % 4
        assert specs.head_shard_axis(8, 4) == (None, None)       # no mesh
        print("OK")
    """)
    assert "OK" in out


# ------------------------------------------------------------- multi-device
def test_sharded_training_matches_single_device(multidevice):
    out = multidevice("""
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.launch import steps as steps_mod
from repro.models.registry import get_model, reduced_config
from repro.optim.adamw import AdamW
from repro.sharding import specs

cfg = reduced_config(configs.get_config("codeqwen1.5-7b"))
model = get_model(cfg)
opt = AdamW(learning_rate=1e-3)
toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size)
batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}

losses = {}
for mesh_shape in [None, (2, 4)]:
    mesh = jax.make_mesh(mesh_shape, ("data", "model")) if mesh_shape else None
    with specs.use_mesh(mesh):
        state = steps_mod.init_train_state(model, opt, jax.random.PRNGKey(0))
        step = steps_mod.make_train_step(model, opt, compute_dtype=jnp.float32,
                                         remat=False)
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            sds = jax.eval_shape(lambda: state)
            sh = steps_mod.state_shardings(model, sds)
            bsh = steps_mod.batch_shardings(model, jax.eval_shape(lambda: batch))
            state = jax.tree.map(lambda x, s: jax.device_put(x, s), state, sh)
            # constrain OUTPUT state to the planned shardings too: with
            # in_shardings alone, GSPMD may pick a different layout for an
            # output leaf and the committed array then mismatches
            # in_shardings on the next iteration (pjit ValueError)
            _, metrics_sds = jax.eval_shape(step, sds, jax.eval_shape(lambda: batch))
            msh = jax.tree.map(lambda _: NamedSharding(mesh, P()), metrics_sds)
            fn = jax.jit(step, in_shardings=(sh, bsh), out_shardings=(sh, msh))
        else:
            fn = jax.jit(step)
        for _ in range(3):
            state, metrics = fn(state, batch)
        losses[str(mesh_shape)] = float(metrics["loss"])
vals = list(losses.values())
assert abs(vals[0] - vals[1]) < 1e-3, losses
print("SHARDED_OK", vals[0], vals[1])
""")
    assert "SHARDED_OK" in out


def test_elastic_reshard_restore(multidevice):
    """Save on a (2,4) mesh, restore on (4,2) and (8,1): losses continue
    identically — a pod loss / re-slice survival scenario."""
    out = multidevice("""
import jax, jax.numpy as jnp, numpy as np, tempfile
from repro import configs
from repro.checkpoint.manager import CheckpointManager
from repro.launch import steps as steps_mod
from repro.models.registry import get_model, reduced_config
from repro.optim.adamw import AdamW
from repro.runtime.elastic import choose_mesh_shape
from repro.sharding import specs

cfg = reduced_config(configs.get_config("minicpm-2b"))
model = get_model(cfg)
opt = AdamW(learning_rate=1e-3)
toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab_size)
batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
tmp = tempfile.mkdtemp()
mgr = CheckpointManager(tmp, async_save=False)

def one_step_from(mesh_shape, state=None):
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    with specs.use_mesh(mesh):
        sds = jax.eval_shape(lambda k: steps_mod.init_train_state(model, opt, k),
                             jax.random.PRNGKey(0))
        sh = steps_mod.state_shardings(model, sds)
        if state is None:
            state, meta = mgr.restore(shardings=sh)
        step = jax.jit(steps_mod.make_train_step(model, opt,
                       compute_dtype=jnp.float32, remat=False),
                       in_shardings=(sh, steps_mod.batch_shardings(
                           model, jax.eval_shape(lambda: batch))))
        state, metrics = step(state, batch)
        return float(metrics["loss"])

# train 2 steps on (2,4), checkpoint
mesh = jax.make_mesh((2, 4), ("data", "model"))
with specs.use_mesh(mesh):
    state = steps_mod.init_train_state(model, opt, jax.random.PRNGKey(0))
    step = jax.jit(steps_mod.make_train_step(model, opt,
                   compute_dtype=jnp.float32, remat=False))
    state, m = step(state, batch)
    mgr.save(1, state)

losses = [one_step_from(s) for s in [(2, 4), (4, 2), (8, 1)]]
assert max(losses) - min(losses) < 1e-4, losses
shape, axes = choose_mesh_shape(6, model_parallel=4)
assert shape[0] * shape[1] == 6
print("ELASTIC_OK", losses)
""")
    assert "ELASTIC_OK" in out


def test_pipeline_parallel_matches_sequential(multidevice):
    out = multidevice("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.sharding.pipeline import bubble_fraction, make_pipeline

mesh = jax.make_mesh((4,), ("pod",))
P_stages, n_micro, B, D = 4, 8, 2, 16
key = jax.random.PRNGKey(0)
# stage params: [P, D, D]
ws = jax.random.normal(key, (P_stages, D, D)) / np.sqrt(D)

def stage_fn(p, x):
    return jnp.tanh(x @ p["w"])

pipe = make_pipeline(mesh, stage_fn, {"w": P("pod")}, stage_axis="pod",
                     n_micro=n_micro)
x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, B, D))
got = pipe({"w": ws}, x)

# sequential reference
ref = x
for s in range(P_stages):
    ref = jax.vmap(lambda xm: stage_fn({"w": ws[s]}, xm))(ref)
np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5, atol=2e-5)
assert abs(bubble_fraction(4, 8) - 3/11) < 1e-9
print("PIPELINE_OK")
""")
    assert "PIPELINE_OK" in out


def test_compressed_allreduce(multidevice):
    out = multidevice("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.optim.grad_compress import init_error, make_compressed_allreduce

mesh = jax.make_mesh((8,), ("data",))
key = jax.random.PRNGKey(0)
g_global = jax.random.normal(key, (8, 64, 32))   # per-shard grads
specs_tree = {"w": P()}                          # grads replicated per shard
ar = make_compressed_allreduce(mesh, {"w": P("data", None, None)},
                               dp_axes=("data",))
grads = {"w": jax.device_put(g_global, NamedSharding(mesh, P("data", None, None)))}
err = init_error(grads)
mean, new_err = jax.jit(ar)(grads, err)
want = np.mean(np.asarray(g_global), axis=0)
got = np.asarray(mean["w"])   # every shard row should now hold the mean
for i in range(8):
    np.testing.assert_allclose(got[i], want, rtol=0.04, atol=0.04)
# error feedback: residual bounded by quantization step
scale = np.abs(np.asarray(g_global)).max(axis=(1,2), keepdims=True) / 127.0
assert np.abs(np.asarray(new_err["w"])).max() <= scale.max() * 0.51 + 1e-6
# over repeated steps with the same gradient, EF keeps mean error ~0
total = np.zeros_like(want)
err = init_error(grads)
for _ in range(8):
    mean, err = jax.jit(ar)(grads, err)
    total += np.asarray(mean["w"])[0]
np.testing.assert_allclose(total / 8, want, rtol=0.02, atol=0.002)
print("COMPRESS_OK")
""")
    assert "COMPRESS_OK" in out


def test_moe_expert_parallel_consistency(multidevice):
    """MoE forward agrees between single-device and expert-parallel meshes."""
    out = multidevice("""
import jax, jax.numpy as jnp, numpy as np
from repro import configs
from repro.models.registry import get_model, reduced_config
from repro.sharding import specs

cfg = reduced_config(configs.get_config("dbrx-132b"))
model = get_model(cfg)
params = model.init(jax.random.PRNGKey(0))
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size)

ref, _ = model.forward(params, toks, compute_dtype=jnp.float32)
mesh = jax.make_mesh((2, 4), ("data", "model"))
with specs.use_mesh(mesh):
    fn = jax.jit(lambda p, t: model.forward(p, t, compute_dtype=jnp.float32)[0])
    got = fn(params, toks)
np.testing.assert_allclose(np.asarray(ref), np.asarray(got), rtol=2e-3, atol=2e-3)
print("MOE_EP_OK")
""")
    assert "MOE_EP_OK" in out
